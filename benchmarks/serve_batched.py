"""Batched serving A/B: cascade vs tree vs chain drafting, fused vs seed.

Three questions, one request stream:

  1. dispatch honesty (PR 1): fused one-dispatch chain drafting vs the
     seed's per-step loop — identical greedy outputs, fewer host syncs;
  2. tree economics (DyTC §4.2): batched on-device tree drafting
     (``tree_fused``) vs chain drafting — the paper's +47%/+48%
     tree-over-chain gains show up here as accepted tokens/step, which must
     be >= the chain path on the synthetic workload (trees hedge the
     target's choice with top-K siblings, so a round survives a wrong
     top-1). Round wall-clock is reported alongside: on CPU the tree's
     bigger verify block costs latency that the TPU's MXU absorbs.
  3. cascade economics (§4.1 + Alg. 1): the multi-level ``cascade_fused``
     mode (cheapest DSIA level drafts, stronger level rescores, target
     verifies) vs the single-level ``tree_fused`` arm — the namesake
     hierarchy must accept at least as many tokens/step as one-level
     drafting on the same stream (``serve/cascade_vs_tree``; the smoke
     canary fails below 0.9).

  4. draft-KV economics (staged-KV carry): tree drafting at the N=32
     bucket with ``draft_kv="carry"`` (each expansion decodes only the
     <= top_k appended tokens against carried staged KV) vs
     ``"recompute"`` (each expansion re-decodes the 32-wide padded block)
     — identical tokens/step by the parity contract
     (``serve/carry_vs_recompute_n32``; the smoke canary fails outside
     0.97–1.03), rounds/s reported as the speed story.

  5. round-pipeline economics (single-dispatch rounds): one fused
     device-resident dispatch per round with ``sync_every`` pipelining
     (``round_mode="single"``) vs the split draft+verify structure with
     per-round host syncs (``serve/round_single_vs_split``: rounds/s plus
     a host-vs-device per-round time breakdown), measured in the
     STEADY-STATE host-gated regime — adaptive routing under an
     unmeetable t_min stops neural drafting on both paths, leaving the
     per-round PLD retrieval / routing / sync overhead that the fused
     round moves on device (deterministic same-regime A/B, independent
     of per-machine cost coefficients). Alongside: the donated vs
     non-donated cache tps parity (``serve/donate_tps_parity``; the smoke
     canary fails outside 0.999–1.001 — donation is pure aliasing and
     must never change tokens).

  6. mesh-sharded round parity (docs/sharding.md): the same single-
     dispatch chain round on a forced 8-device host mesh (``model=2,
     data=4``) vs the single-device server — tokens/step must match
     EXACTLY (sharding is placement, never sampling; the smoke canary
     fails outside 0.999–1.001) with rounds/s reported as the
     communication-overhead story (``serve/sharded_vs_single``; smoke
     only, in a subprocess because the forced device count must precede
     jax initialization).

  7. telemetry economics (docs/observability.md): the device-carried
     round-telemetry buffer rides the single-dispatch round, so enabling
     it must add ZERO round dispatches and ZERO host syncs (exact
     equality; the runtime twin of the static
     ``assert_telemetry_transparent`` contract) and keep rounds/s within
     5% of the disabled server (``serve/telemetry_overhead``; the smoke
     canary fails either way), with the telemetry-derived acceptance
     report riding along (``serve/telemetry_report``).

  8. sampled-serving economics (docs/serving.md): a SAMPLED build
     (stochastic verify fused into the same round executables) vs the
     greedy build on the same stream — dispatch/sync discipline must be
     IDENTICAL per round (exact equality: 1 donated dispatch, 1 drain per
     single-mode round, sampled or not — the runtime twin of the sampled
     dispatch contracts) and rounds/s must stay within 10%
     (``serve/sampled_vs_greedy``; the smoke canary fails either way).

All variants are lossless (greedy output == AR exactly; sampled output ==
the target distribution in law), so tokens/step and round latency are the
whole story.
"""
from __future__ import annotations

import sys
import time

import numpy as np

from repro.core.dsia import layer_sparsity
from repro.serving import BatchedSpecServer, Request, RequestScheduler, ServeLoop

sys.path.insert(0, "benchmarks")
from common import CACHE_DIR, csv_line, task_prompts, trained_params

MAX_BATCH = 4
DRAFT_K = 4


def _serve_stream(cfg, params, prompts, n_tokens, *, mode, adaptive,
                  with_summary=False, passes=1, **srv_kw):
    kw = (
        # default mixing hierarchy: a layer-sparsity level + an int8 level
        {} if mode == "cascade_fused"
        else {"draft_spec": layer_sparsity(cfg, 0.5)}
    )
    kw.update(srv_kw)
    max_batch = kw.pop("max_batch", MAX_BATCH)
    max_len = kw.pop("max_len", 512)
    srv = BatchedSpecServer(cfg, params, max_batch=max_batch, max_len=max_len,
                            draft_k=DRAFT_K,
                            mode=mode, adaptive=adaptive, **kw)

    def one_pass():
        sched = RequestScheduler(max_batch=max_batch)
        for p in prompts:
            sched.submit(Request(prompt=p[:48], max_new_tokens=n_tokens))
        t0 = time.perf_counter()
        steps0, tokens0 = srv.stats["steps"], srv.stats["tokens"]
        wait0, syncs0 = srv.stats["device_wait"], srv.stats["host_syncs"]
        rdisp0 = srv.stats["round_dispatches"]
        ServeLoop(srv, sched).run()
        srv.flush()                 # drain pipelined tails into this pass
        return (time.perf_counter() - t0,
                srv.stats["steps"] - steps0, srv.stats["tokens"] - tokens0,
                srv.stats["device_wait"] - wait0,
                srv.stats["host_syncs"] - syncs0,
                srv.stats["round_dispatches"] - rdisp0)

    one_pass()                      # warmup: compiles every scan-length variant
    # best-of-``passes`` on wall time: identical work each pass (fixed
    # stream, greedy), so the fastest pass is the least-noise estimate —
    # the timing-sensitive A/Bs (telemetry overhead) use passes=2
    results = [one_pass() for _ in range(max(passes, 1))]
    wall, steps, tokens, dev_wait, syncs, rdisp = min(results,
                                                      key=lambda r: r[0])
    steps = max(steps, 1)
    r = {
        "tokens_per_step": tokens / steps,
        "us_per_round": wall / steps * 1e6,
        "rounds_per_s": steps / max(wall, 1e-9),
        "draft_dispatches_per_round": srv.stats["draft_dispatches"] / max(srv.stats["steps"], 1),
        # host-overhead breakdown: device_us = wall the host spent BLOCKED
        # on device results, host_us = everything else (python bookkeeping,
        # dispatch, retrieval). A pipelined round hides both behind the
        # in-flight dispatches, so its host_us is the true overhead story.
        "device_us_per_round": dev_wait / steps * 1e6,
        "host_us_per_round": (wall - dev_wait) / steps * 1e6,
        "host_syncs_per_round": syncs / steps,
        # raw per-pass dispatch/sync counts: the telemetry-overhead arm
        # pins these to EXACT equality between telemetry on and off
        "round_dispatches": rdisp,
        "host_syncs": syncs,
        "steps": steps,
    }
    if with_summary:
        # telemetry-derived report (docs/observability.md) — cumulative
        # over warmup + timed pass, drained at this sync point only
        r["telemetry"] = srv.metrics_summary()
    return r


def main(n_tokens: int = 32, smoke: bool = False) -> dict:
    # draft-KV carry vs full-block recompute at the N=32 tree bucket: the
    # same stream drafted both ways MUST accept identical tokens/step
    # (deterministic parity canary) while carry decodes <= top_k tokens
    # per expansion instead of the 32-wide padded block (rounds/s A/B)
    n32 = (("tree_carry_n32", "tree_fused", False,
            {"tree_bucket": 32, "draft_kv": "carry"}),
           ("tree_recompute_n32", "tree_fused", False,
            {"tree_bucket": 32, "draft_kv": "recompute"}))
    if smoke:
        # tiny model (half-depth, briefly trained), few rounds: the CI
        # drafting-path canary, cached apart from the full bench model
        import dataclasses

        from common import bench_config

        n_tokens = min(n_tokens, 8)
        cfg = dataclasses.replace(bench_config(), num_layers=4)
        cfg, params = trained_params(cfg, steps=12,
                                     cache_dir=CACHE_DIR + "_smoke")
        prompts = [p for ps in task_prompts(cfg, 1).values() for p in ps][:4]
        variants = (("fused", "chain_fused", False, {}),
                    ("tree", "tree_fused", False, {}),
                    ("cascade", "cascade_fused", False, {})) + n32
    else:
        cfg, params = trained_params()
        prompts = [p for ps in task_prompts(cfg, 2).values() for p in ps][:8]
        # fused-vs-seedloop is a pure dispatch A/B (identical draft
        # semantics); tree-vs-fused is the DyTC structure A/B; *_adaptive
        # additionally lets Eq. 5 budgets trim per-slot drafting online
        variants = (("fused", "chain_fused", False, {}),
                    ("seedloop", "legacy", False, {}),
                    ("fused_adaptive", "chain_fused", True, {}),
                    ("tree", "tree_fused", False, {}),
                    ("tree_adaptive", "tree_fused", True, {}),
                    ("cascade", "cascade_fused", False, {}),
                    ("cascade_adaptive", "cascade_fused", True, {})) + n32
    out = {}
    for name, mode, adaptive, extra in variants:
        r = _serve_stream(cfg, params, prompts, n_tokens,
                          mode=mode, adaptive=adaptive, **extra)
        out[name] = r
        print(csv_line(
            f"serve/{name}", r["us_per_round"],
            f"tokens_per_step={r['tokens_per_step']:.3f};"
            f"draft_dispatches_per_round={r['draft_dispatches_per_round']:.2f}",
        ))
    # round-pipeline A/B (question 5): the STEADY-STATE host-gated round —
    # adaptive routing under an unmeetable t_min stops neural drafting
    # after one observation on BOTH paths (deterministic same-regime A/B,
    # independent of per-machine cost coefficients), leaving PLD retrieval
    # + routing + verify per round: exactly the per-round host overhead the
    # single-dispatch path moves on device. B=8 slots and a lean cache
    # keep the device share small so the overhead story is measurable on
    # CPU; the donate arm re-runs single with buffer donation forced ON
    # (the CPU default is off — donating an in-flight round's output
    # serializes async dispatch) for the exact-parity canary.
    round_prompts = [p for ps in task_prompts(cfg, 2).values() for p in ps][:8]
    for name, extra in (
        ("round_split", {"round_mode": "split"}),
        ("round_single", {"round_mode": "single", "sync_every": 4}),
        ("round_single_donate",
         {"round_mode": "single", "sync_every": 4, "donate": True}),
    ):
        r = _serve_stream(
            cfg, params, round_prompts, max(n_tokens, 16),
            mode="chain_fused", adaptive=True, min_obs=1, t_min=10.0,
            max_batch=8, max_len=192, **extra,
        )
        out[name] = r
        print(csv_line(
            f"serve/{name}", r["us_per_round"],
            f"tokens_per_step={r['tokens_per_step']:.3f};"
            f"host_us={r['host_us_per_round']:.1f};"
            f"device_us={r['device_us_per_round']:.1f};"
            f"syncs_per_round={r['host_syncs_per_round']:.2f}",
        ))
    if "seedloop" in out:
        speedup = out["seedloop"]["us_per_round"] / max(out["fused"]["us_per_round"], 1e-9)
        print(csv_line("serve/fused_round_speedup", out["fused"]["us_per_round"],
                       f"round_speedup={speedup:.3f}"))
        out["round_speedup"] = speedup
    # DyTC §4.2 headline: tree drafting must accept at least as many
    # tokens/step as chain drafting on the same stream
    ratio = out["tree"]["tokens_per_step"] / max(out["fused"]["tokens_per_step"], 1e-9)
    print(csv_line("serve/tree_vs_chain", out["tree"]["us_per_round"],
                   f"accept_ratio={ratio:.3f};"
                   f"tree_tps={out['tree']['tokens_per_step']:.3f};"
                   f"chain_tps={out['fused']['tokens_per_step']:.3f}"))
    out["tree_accept_ratio"] = ratio
    if ratio < 1.0:
        print(f"WARNING: tree accepted fewer tokens/step than chain ({ratio:.3f})")
    # §4.1/Alg. 1 headline: the multi-level cascade must accept at least as
    # many tokens/step as single-level tree drafting on the same stream
    c_ratio = (out["cascade"]["tokens_per_step"]
               / max(out["tree"]["tokens_per_step"], 1e-9))
    print(csv_line("serve/cascade_vs_tree", out["cascade"]["us_per_round"],
                   f"accept_ratio={c_ratio:.3f};"
                   f"cascade_tps={out['cascade']['tokens_per_step']:.3f};"
                   f"tree_tps={out['tree']['tokens_per_step']:.3f}"))
    out["cascade_accept_ratio"] = c_ratio
    if c_ratio < 1.0:
        print(f"WARNING: cascade accepted fewer tokens/step than tree ({c_ratio:.3f})")
    # staged-KV carry headline at N=32: identical tokens/step by parity
    # (deterministic canary) and rounds/s at least as good as recompute
    # (timing — reported, warned on, but never a hard failure on shared
    # runners)
    ck, rk = out["tree_carry_n32"], out["tree_recompute_n32"]
    carry_speed = rk["us_per_round"] / max(ck["us_per_round"], 1e-9)
    kv_parity = ck["tokens_per_step"] / max(rk["tokens_per_step"], 1e-9)
    print(csv_line("serve/carry_vs_recompute_n32", ck["us_per_round"],
                   f"round_speedup={carry_speed:.3f};tps_parity={kv_parity:.3f};"
                   f"carry_tps={ck['tokens_per_step']:.3f};"
                   f"recompute_tps={rk['tokens_per_step']:.3f}"))
    out["carry_speedup_n32"] = carry_speed
    out["carry_tps_parity_n32"] = kv_parity
    if carry_speed < 1.0:
        print(f"WARNING: carry rounds slower than recompute at N=32 ({carry_speed:.3f})")
    # round-pipeline headline: the single-dispatch pipelined round vs the
    # split draft/verify round — rounds/s is the story (the host-overhead
    # breakdown rides along), tokens/step must match (both are the same
    # lossless drafts). The donated-vs-nondonated tps parity is exact by
    # construction (donation is pure aliasing) and is the deterministic
    # canary here.
    sg, sp = out["round_single"], out["round_split"]
    single_speed = sp["us_per_round"] / max(sg["us_per_round"], 1e-9)
    print(csv_line(
        "serve/round_single_vs_split", sg["us_per_round"],
        f"round_speedup={single_speed:.3f};"
        f"single_host_us={sg['host_us_per_round']:.1f};"
        f"single_device_us={sg['device_us_per_round']:.1f};"
        f"split_host_us={sp['host_us_per_round']:.1f};"
        f"split_device_us={sp['device_us_per_round']:.1f};"
        f"single_syncs_per_round={sg['host_syncs_per_round']:.2f}",
    ))
    out["single_round_speedup"] = single_speed
    donate_parity = (sg["tokens_per_step"]
                     / max(out["round_single_donate"]["tokens_per_step"], 1e-9))
    print(csv_line("serve/donate_tps_parity", sg["us_per_round"],
                   f"tps_parity={donate_parity:.4f}"))
    out["donate_tps_parity"] = donate_parity
    if single_speed < 1.15:
        print(f"WARNING: single-dispatch round below the 1.15x target "
              f"vs split ({single_speed:.3f})")
    # telemetry-overhead A/B (docs/observability.md): the device-carried
    # telemetry buffer rides the SAME single-dispatch round, so enabling
    # it must add ZERO dispatches and ZERO host syncs (exact equality —
    # deterministic, the runtime twin of assert_telemetry_transparent)
    # and must keep rounds/s within 5% of the disabled server (timing).
    telem_kw = dict(mode="chain_fused", adaptive=True, min_obs=1, t_min=10.0,
                    max_batch=8, max_len=192,
                    round_mode="single", sync_every=4)
    t_on = _serve_stream(cfg, params, round_prompts, max(n_tokens, 16),
                         telemetry=True, with_summary=True, passes=2,
                         **telem_kw)
    t_off = _serve_stream(cfg, params, round_prompts, max(n_tokens, 16),
                          telemetry=False, passes=2, **telem_kw)
    out["telemetry_on"], out["telemetry_off"] = t_on, t_off
    telem_speed = t_on["rounds_per_s"] / max(t_off["rounds_per_s"], 1e-9)
    telem_transparent = (
        t_on["round_dispatches"] == t_off["round_dispatches"]
        and t_on["host_syncs"] == t_off["host_syncs"]
    )
    print(csv_line(
        "serve/telemetry_overhead", t_on["us_per_round"],
        f"rounds_ratio={telem_speed:.3f};"
        f"transparent={int(telem_transparent)};"
        f"on_dispatches={t_on['round_dispatches']};"
        f"off_dispatches={t_off['round_dispatches']};"
        f"on_syncs={t_on['host_syncs']};off_syncs={t_off['host_syncs']}",
    ))
    out["telemetry_rounds_ratio"] = telem_speed
    out["telemetry_transparent"] = telem_transparent
    summ = t_on["telemetry"]
    print(csv_line(
        "serve/telemetry_report", t_on["us_per_round"],
        f"tokens_per_step={summ['tokens_per_step']:.3f};"
        f"accepted={sum(summ['accepted_per_slot'])};"
        f"drafted={sum(summ['drafted_per_slot'])};"
        f"pld_tokens={sum(summ['pld_tokens_per_slot'])};"
        f"device_wait_s={summ['device_wait_s']:.3f}",
    ))
    if telem_speed < 0.95:
        print(f"WARNING: telemetry-on rounds/s below 0.95x of disabled "
              f"({telem_speed:.3f})")
    # sampled-vs-greedy A/B (question 8): the stochastic verify is fused
    # INTO the round executable (PRNG split + acceptance draws on device),
    # so a sampled build must keep the exact single-dispatch discipline —
    # round_dispatches == steps and host_syncs == steps on BOTH builds
    # (sync_every=1: one drain per round, nothing in flight at admission)
    # — and rounds/s within 10% of greedy on the same stream.
    from repro.serving.sampler import SamplingParams

    samp_kw = dict(mode="chain_fused", adaptive=False, round_mode="single",
                   passes=2)
    s_on = _serve_stream(cfg, params, prompts, n_tokens,
                         sampling=SamplingParams(temperature=0.8, top_k=20,
                                                 top_p=0.9, seed=7),
                         **samp_kw)
    s_off = _serve_stream(cfg, params, prompts, n_tokens, **samp_kw)
    out["sampled_on"], out["sampled_off"] = s_on, s_off
    sampled_speed = s_on["rounds_per_s"] / max(s_off["rounds_per_s"], 1e-9)
    sampled_transparent = (
        s_on["round_dispatches"] == s_on["steps"]
        and s_off["round_dispatches"] == s_off["steps"]
        and s_on["host_syncs"] == s_on["steps"]
        and s_off["host_syncs"] == s_off["steps"]
    )
    print(csv_line(
        "serve/sampled_vs_greedy", s_on["us_per_round"],
        f"rounds_ratio={sampled_speed:.3f};"
        f"transparent={int(sampled_transparent)};"
        f"sampled_tps={s_on['tokens_per_step']:.3f};"
        f"greedy_tps={s_off['tokens_per_step']:.3f};"
        f"sampled_dispatches={s_on['round_dispatches']};"
        f"sampled_syncs={s_on['host_syncs']}",
    ))
    out["sampled_rounds_ratio"] = sampled_speed
    out["sampled_transparent"] = sampled_transparent
    if sampled_speed < 0.90:
        print(f"WARNING: sampled rounds/s below 0.90x of greedy "
              f"({sampled_speed:.3f})")
    shard_parity = 1.0
    paged_parity, paged_overlap = 1.0, 1
    if smoke:
        shard_parity = _sharded_arm(out)
        paged_parity, paged_overlap = _paged_arm(cfg, params, out)
    if smoke and (ratio < 0.9 or c_ratio < 0.9
                  or not (0.97 <= kv_parity <= 1.03)
                  or not (0.999 <= shard_parity <= 1.001)
                  or not (0.999 <= donate_parity <= 1.001)
                  or not (0.999 <= paged_parity <= 1.001)
                  or paged_overlap <= 0
                  or telem_speed < 0.95 or not telem_transparent
                  or sampled_speed < 0.90 or not sampled_transparent):
        # the canaries must be able to FAIL: tokens/step is deterministic
        # for a fixed stream/model (no timing noise), so a clear
        # accept-ratio regression exits nonzero and marks the non-blocking
        # CI job red. The measured numbers ride on the exception so the
        # uploaded bench.json still carries them (benchmarks/run.py).
        # (carry/recompute tps parity tolerates 3% for softmax-merge ULP
        # near-ties on a freshly trained model; real divergence is larger.)
        err = SystemExit(
            f"smoke canary: accept ratio below 0.9 or a parity broken "
            f"(tree/chain {ratio:.3f}, cascade/tree {c_ratio:.3f}, "
            f"carry/recompute tps {kv_parity:.3f}, "
            f"sharded/single tps {shard_parity:.4f}, "
            f"donated/nondonated tps {donate_parity:.4f}, "
            f"telemetry rounds/s {telem_speed:.3f} "
            f"transparent={telem_transparent}, "
            f"sampled rounds/s {sampled_speed:.3f} "
            f"transparent={sampled_transparent}, "
            f"paged/dense tps {paged_parity:.4f}, "
            f"chunked overlap tokens {paged_overlap})"
        )
        err.results = out
        raise err
    return out


def _paged_arm(cfg, params, out: dict):
    """Question 9 (docs/paging.md): block-paged KV + chunked prefill.

    Two canaries on the SAME bursty heavy-tailed load-gen trace:

      a. ``serve/paged_vs_dense`` — a paged server must route EXACTLY the
         token streams of the dense server (paging is placement, never
         math), with tokens/round parity recorded into the trend;
      b. ``serve/chunked_prefill_overlap`` — with ``prefill_chunk`` on, a
         LONG prompt admitted mid-stream must NOT stall the loop: other
         slots keep routing tokens during the rounds its prompt is still
         chunk-prefilling (overlap tokens > 0 — the non-blocking-admission
         headline), with rounds + TTFT-in-rounds reported alongside.
    """
    import load_gen

    from repro.serving import BatchedSpecServer

    trace = load_gen.heavy_tailed_trace(
        vocab=cfg.vocab_size, n_requests=16, seed=11,
        rate=0.7, prompt_max=96, out_max=16,
    )
    runs = {}
    for name, kw in (
        ("dense", {}),
        ("paged", {"paged": True, "page_size": 64}),
    ):
        srv = BatchedSpecServer(
            cfg, params, max_batch=MAX_BATCH, max_len=256, draft_k=DRAFT_K,
            draft_spec=layer_sparsity(cfg, 0.5), mode="chain_fused",
            adaptive=False, **kw,
        )
        t0 = time.perf_counter()
        runs[name] = load_gen.run_trace(srv, trace, max_batch=MAX_BATCH)
        runs[name]["us_per_round"] = (
            (time.perf_counter() - t0) * 1e6 / max(runs[name]["rounds"], 1)
        )
    exact = runs["paged"]["token_streams"] == runs["dense"]["token_streams"]
    parity = (runs["paged"]["tokens_per_round"]
              / max(runs["dense"]["tokens_per_round"], 1e-9)) if exact else 0.0
    out["paged_run"], out["dense_run"] = (
        {k: v for k, v in runs[n].items()
         if k not in ("finished", "token_streams")}
        for n in ("paged", "dense")
    )
    # trend-shaped rows (tokens_per_step + us_per_round): the tokens/round
    # parity of the paged build rides BENCH_smoke.json alongside the other
    # serve variants
    for name in ("dense", "paged"):
        out[f"loadgen_{name}"] = {
            "tokens_per_step": runs[name]["tokens_per_round"],
            "us_per_round": runs[name]["us_per_round"],
        }
    print(csv_line(
        "serve/paged_vs_dense", runs["paged"]["us_per_round"],
        f"tps_parity={parity:.4f};exact_streams={int(exact)};"
        + load_gen.summarize(runs["paged"]),
    ))
    out["paged_tps_parity"] = parity

    # (b) three short prompts decode while one long prompt chunk-prefills
    rng = np.random.default_rng(5)
    shorts = [
        load_gen.TraceRequest(0, rng.integers(
            1, cfg.vocab_size, size=12).astype(np.int32), 24)
        for _ in range(3)
    ]
    long_req = load_gen.TraceRequest(2, rng.integers(
        1, cfg.vocab_size, size=192).astype(np.int32), 8)
    srv = BatchedSpecServer(
        cfg, params, max_batch=MAX_BATCH, max_len=256, draft_k=DRAFT_K,
        draft_spec=layer_sparsity(cfg, 0.5), mode="chain_fused",
        adaptive=False, paged=True, page_size=64, prefill_chunk=16,
    )
    t0 = time.perf_counter()
    rep = load_gen.run_trace(srv, shorts + [long_req], max_batch=MAX_BATCH)
    rep["us_per_round"] = (
        (time.perf_counter() - t0) * 1e6 / max(rep["rounds"], 1)
    )
    # tokens routed to OTHER requests while the long prompt was still
    # prefilling: every token before its first token is someone else's
    long_first = rep["ttft_rounds_max"]    # the 192-token prompt dominates
    overlap = int(sum(rep["routed_per_round"][2:2 + int(long_first)]))
    print(csv_line(
        "serve/chunked_prefill_overlap", rep["us_per_round"],
        f"overlap_tokens={overlap};long_ttft_rounds={long_first};"
        + load_gen.summarize(rep),
    ))
    out["chunked_overlap_tokens"] = overlap
    out["loadgen_chunked_prefill"] = {
        "tokens_per_step": rep["tokens_per_round"],
        "us_per_round": rep["us_per_round"],
    }
    out["chunked_run"] = {
        k: v for k, v in rep.items() if k not in ("finished", "token_streams")
    }
    return parity, overlap


_SHARD_SCRIPT = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
os.environ.setdefault("JAX_PLATFORMS", "cpu")
import dataclasses, json, sys
sys.path.insert(0, "benchmarks")
from serve_batched import _serve_stream
from common import CACHE_DIR, bench_config, task_prompts, trained_params
from repro.launch.mesh import make_mesh_compat

cfg = dataclasses.replace(bench_config(), num_layers=4)
cfg, params = trained_params(cfg, steps=12, cache_dir=CACHE_DIR + "_smoke")
prompts = [p for ps in task_prompts(cfg, 1).values() for p in ps][:4]
mesh = make_mesh_compat((4, 2), ("data", "model"))
out = {}
for name, mesh_kw in (("single", {}), ("sharded", {"mesh": mesh})):
    out[name] = _serve_stream(cfg, params, prompts, 8,
                              mode="chain_fused", adaptive=False, **mesh_kw)
print(json.dumps(out))
"""


def _sharded_arm(out: dict) -> float:
    """Question 6: the sharded-vs-single round A/B, in a subprocess (the
    forced host-device count must be set before jax initializes, and the
    parent bench must keep seeing the real devices). Reuses the parent's
    smoke model cache; both variants land in ``out`` with the
    us_per_round/tokens_per_step keys ``trend.py`` records."""
    import json
    import os
    import subprocess

    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(root, "src"), os.path.join(root, "benchmarks")]
    )
    proc = subprocess.run(
        [sys.executable, "-c", _SHARD_SCRIPT], capture_output=True,
        text=True, env=env, cwd=root, timeout=900,
    )
    if proc.returncode != 0:
        print(f"WARNING: sharded arm subprocess failed:\n{proc.stderr[-2000:]}")
        return 0.0                   # trips the smoke canary
    res = json.loads(proc.stdout.strip().splitlines()[-1])
    sg, sh = res["single"], res["sharded"]
    out["mesh_single_base"], out["mesh_sharded_n8"] = sg, sh
    parity = sh["tokens_per_step"] / max(sg["tokens_per_step"], 1e-9)
    overhead = sh["us_per_round"] / max(sg["us_per_round"], 1e-9)
    print(csv_line(
        "serve/sharded_vs_single", sh["us_per_round"],
        f"tps_parity={parity:.4f};round_overhead={overhead:.3f};"
        f"sharded_tps={sh['tokens_per_step']:.3f};"
        f"single_tps={sg['tokens_per_step']:.3f}",
    ))
    out["sharded_tps_parity"] = parity
    out["sharded_round_overhead"] = overhead
    return parity


if __name__ == "__main__":
    main()
