"""Batched serving: fused one-dispatch chain drafting vs the seed's
per-step dispatch loop.

The seed server drafted each neural chain token with a separate jitted call
and a host sync in between; App. A's large-batch chain-cascade path is only
honest if the drafting loop is device-resident. We serve the same request
stream through both paths (identical greedy outputs — drafts only change
speed) and report accepted tokens/step plus wall-clock per round. The fused
path must be no worse on tokens/step and faster per round on CPU.
"""
from __future__ import annotations

import sys
import time

import numpy as np

from repro.core.dsia import layer_sparsity
from repro.serving import BatchedSpecServer, Request, RequestScheduler, ServeLoop

sys.path.insert(0, "benchmarks")
from common import csv_line, task_prompts, trained_params

MAX_BATCH = 4
DRAFT_K = 4


def _serve_stream(cfg, params, prompts, n_tokens, *, fused, adaptive):
    srv = BatchedSpecServer(cfg, params, max_batch=MAX_BATCH, max_len=512,
                            draft_k=DRAFT_K,
                            draft_spec=layer_sparsity(cfg, 0.5),
                            fused=fused, adaptive=adaptive)

    def one_pass():
        sched = RequestScheduler(max_batch=MAX_BATCH)
        for p in prompts:
            sched.submit(Request(prompt=p[:48], max_new_tokens=n_tokens))
        t0 = time.perf_counter()
        steps0, tokens0 = srv.stats["steps"], srv.stats["tokens"]
        ServeLoop(srv, sched).run()
        return (time.perf_counter() - t0,
                srv.stats["steps"] - steps0, srv.stats["tokens"] - tokens0)

    one_pass()                      # warmup: compiles every scan-length variant
    wall, steps, tokens = one_pass()
    return {
        "tokens_per_step": tokens / max(steps, 1),
        "us_per_round": wall / max(steps, 1) * 1e6,
        "draft_dispatches_per_round": srv.stats["draft_dispatches"] / max(srv.stats["steps"], 1),
        "steps": steps,
    }


def main(n_tokens: int = 32) -> dict:
    cfg, params = trained_params()
    prompts = [p for ps in task_prompts(cfg, 2).values() for p in ps][:8]
    out = {}
    # fused-vs-seedloop is a pure dispatch A/B (identical draft semantics);
    # fused+adaptive additionally trims per-slot draft lengths online
    variants = (("fused", True, False), ("seedloop", False, False),
                ("fused_adaptive", True, True))
    for name, fused, adaptive in variants:
        r = _serve_stream(cfg, params, prompts, n_tokens,
                          fused=fused, adaptive=adaptive)
        out[name] = r
        print(csv_line(
            f"serve/{name}", r["us_per_round"],
            f"tokens_per_step={r['tokens_per_step']:.3f};"
            f"draft_dispatches_per_round={r['draft_dispatches_per_round']:.2f}",
        ))
    speedup = out["seedloop"]["us_per_round"] / max(out["fused"]["us_per_round"], 1e-9)
    print(csv_line("serve/fused_round_speedup", out["fused"]["us_per_round"],
                   f"round_speedup={speedup:.3f}"))
    out["round_speedup"] = speedup
    return out


if __name__ == "__main__":
    main()
