"""Heavy-tailed load generator for the batched serving benchmarks.

Fixed prompt sets exercise the steady state; production serving lives in
the transient: Poisson bursts of requests whose prompt and output lengths
are heavy-tailed (a few very long prompts among many short ones — the
regime block-paged KV + chunked prefill exists for). This module builds
DynaNDE-style seeded traces and drives a ``ServeLoop`` with them:

  * arrivals — Poisson process (exponential inter-arrival gaps), measured
    in ROUNDS of the serving loop so the trace is deterministic and
    machine-independent;
  * prompt/output lengths — lognormal (median/sigma parameterized), the
    standard heavy-tailed length model, clipped to the server's limits.

``run_trace`` submits each request when its arrival round comes up,
steps the loop once per round, and records the queue-depth series; the
returned report carries TTFT / queue-depth / throughput digests pulled
from the PR 8 telemetry (request-level ttft fields + the registry's
``serve_queue_depth`` gauge), so bench arms can print one line per
server variant. Everything is seeded — two runs of the same trace on
token-identical servers route identical tokens.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np

from repro.serving import Request, RequestScheduler, ServeLoop


@dataclasses.dataclass
class TraceRequest:
    arrival_round: int
    prompt: np.ndarray
    max_new_tokens: int


def heavy_tailed_trace(
    *,
    vocab: int,
    n_requests: int,
    seed: int,
    rate: float = 0.5,            # mean arrivals per serving round
    prompt_median: int = 24,
    prompt_sigma: float = 0.9,    # lognormal shape: ~1 gives a fat tail
    prompt_max: int = 256,
    out_median: int = 12,
    out_sigma: float = 0.6,
    out_max: int = 64,
) -> List[TraceRequest]:
    """A seeded Poisson + lognormal-length request trace."""
    rng = np.random.default_rng(seed)
    t = 0.0
    reqs: List[TraceRequest] = []
    for _ in range(n_requests):
        t += rng.exponential(1.0 / max(rate, 1e-9))
        plen = int(np.clip(
            round(float(rng.lognormal(np.log(prompt_median), prompt_sigma))),
            1, prompt_max,
        ))
        olen = int(np.clip(
            round(float(rng.lognormal(np.log(out_median), out_sigma))),
            1, out_max,
        ))
        prompt = rng.integers(1, vocab, size=plen).astype(np.int32)
        reqs.append(TraceRequest(int(t), prompt, olen))
    return reqs


def run_trace(
    server,
    trace: List[TraceRequest],
    *,
    max_batch: int,
    max_rounds: int = 10_000,
    sampling=None,
) -> Dict:
    """Drive ``server`` with ``trace`` through a ``ServeLoop``.

    Returns a report dict: the finished ``Request`` objects (token streams
    + latency fields), the per-round queue-depth series, per-round routed
    token counts, and summary digests (TTFT quantiles over the rounds
    clock, peak queue depth, tokens/round)."""
    sched = RequestScheduler(max_batch=max_batch)
    loop = ServeLoop(server, sched)
    pending = sorted(trace, key=lambda r: r.arrival_round)
    i = 0
    rounds = 0
    queue_depth: List[int] = []
    routed_per_round: List[int] = []
    admitted_round: Dict[int, int] = {}          # id(request) -> round
    first_token_round: Dict[int, int] = {}
    reqs: List[Request] = []
    while (i < len(pending) or sched.busy) and rounds < max_rounds:
        while i < len(pending) and pending[i].arrival_round <= rounds:
            tr = pending[i]
            req = Request(
                prompt=tr.prompt, max_new_tokens=tr.max_new_tokens,
                sampling=sampling,
            )
            sched.submit(req)
            admitted_round[id(req)] = rounds
            reqs.append(req)
            i += 1
        out = loop.step_once()
        routed = 0
        for req in reqs:
            if req.generated and id(req) not in first_token_round:
                first_token_round[id(req)] = rounds
        for toks in out.values():
            routed += len(toks)
        routed_per_round.append(routed)
        queue_depth.append(len(sched.queue))
        rounds += 1
    finished = sched.finished
    ttfts = [r.ttft for r in finished if r.ttft is not None]
    ttft_rounds = [
        first_token_round[id(r)] - admitted_round[id(r)]
        for r in reqs if id(r) in first_token_round
    ]
    total_tokens = sum(len(r.generated) for r in finished)
    return {
        "finished": finished,
        "rounds": rounds,
        "total_tokens": total_tokens,
        "tokens_per_round": total_tokens / max(rounds, 1),
        "queue_depth": queue_depth,
        "peak_queue_depth": max(queue_depth, default=0),
        "mean_queue_depth": float(np.mean(queue_depth)) if queue_depth else 0.0,
        "routed_per_round": routed_per_round,
        # TTFT on the wall clock (PR 8 request telemetry) and on the
        # deterministic rounds clock (admission round -> first-token round)
        "ttft_s_p50": float(np.median(ttfts)) if ttfts else 0.0,
        "ttft_s_p99": float(np.quantile(ttfts, 0.99)) if ttfts else 0.0,
        "ttft_rounds_p50": float(np.median(ttft_rounds)) if ttft_rounds else 0.0,
        "ttft_rounds_max": max(ttft_rounds, default=0),
        "token_streams": {
            idx: list(r.generated) for idx, r in enumerate(finished)
        },
    }


def summarize(report: Dict) -> str:
    """One-line digest for csv_line derived fields."""
    return (
        f"tokens_per_round={report['tokens_per_round']:.3f};"
        f"ttft_rounds_p50={report['ttft_rounds_p50']:.1f};"
        f"ttft_rounds_max={report['ttft_rounds_max']};"
        f"peak_queue={report['peak_queue_depth']};"
        f"mean_queue={report['mean_queue_depth']:.2f}"
    )


def main(seed: int = 0, n_requests: int = 24) -> Optional[Dict]:
    """Standalone smoke: a bursty trace against the tiny bench model."""
    import dataclasses as dc
    import sys

    sys.path.insert(0, "benchmarks")
    from common import CACHE_DIR, bench_config, csv_line, trained_params

    from repro.serving import BatchedSpecServer

    cfg = dc.replace(bench_config(), num_layers=4)
    cfg, params = trained_params(cfg, steps=12, cache_dir=CACHE_DIR + "_smoke")
    trace = heavy_tailed_trace(
        vocab=cfg.vocab_size, n_requests=n_requests, seed=seed,
        prompt_max=96, out_max=24,
    )
    srv = BatchedSpecServer(
        cfg, params, max_batch=4, max_len=256, draft_k=4,
        mode="chain_fused", adaptive=False,
    )
    rep = run_trace(srv, trace, max_batch=4)
    print(csv_line("serve/load_gen_smoke", 0.0, summarize(rep)))
    return rep


if __name__ == "__main__":
    main()
