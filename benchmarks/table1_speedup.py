"""Table 1: speedup over autoregressive decoding per Spec-Bench-style task.

Methods (the training-free rows of Table 1): AR (reference), PLD, SWIFT
(layer-sparse chain SD — the paper's SWIFT row), CAS-Spec (DyTC over the
Scaling-DSIA hierarchy with PLD bottom). CPU wall-clock; the validated
claims are the ORDERINGS (CAS-Spec > PLD overall and on copy-heavy tasks;
CAS-Spec > SWIFT everywhere), not the absolute H100 numbers.
"""
from __future__ import annotations

import sys

from repro.core.cascade import ARScheduler, PLDScheduler, SDScheduler
from repro.core.dsia import build_hierarchy, layer_sparsity
from repro.core.dytc import DyTCScheduler

sys.path.insert(0, "benchmarks")
from common import bench_config, csv_line, task_prompts, time_scheduler, trained_params


def methods(cfg):
    ls4 = layer_sparsity(cfg, 0.4)
    return {
        "AR": lambda e: ARScheduler(e),
        "PLD": lambda e: PLDScheduler(e, k=8),
        "SWIFT": lambda e: SDScheduler(e, ls4, k=4),
        "CAS-Spec": lambda e: DyTCScheduler(e, build_hierarchy(cfg)),
    }


def main(n_tokens: int = 32) -> dict:
    cfg, params = trained_params()
    prompts = task_prompts(cfg)
    meths = methods(cfg)
    table: dict = {}
    for task, ps in prompts.items():
        ar_spt, ar_stats = time_scheduler(cfg, params, ps, meths["AR"], n_tokens)
        row = {}
        for name, builder in meths.items():
            if name == "AR":
                row[name] = 1.0
                continue
            spt, stats = time_scheduler(cfg, params, ps, builder, n_tokens)
            row[name] = ar_stats["modeled_cost_per_token"] / stats["modeled_cost_per_token"]
        table[task] = row
        print(csv_line(f"table1/{task}/AR", ar_spt * 1e6, "speedup=1.000"))
        for name in ("PLD", "SWIFT", "CAS-Spec"):
            print(csv_line(f"table1/{task}/{name}", 0.0,
                           f"modeled_speedup={row[name]:.3f}"))
    overall = {
        m: sum(r[m] for r in table.values()) / len(table) for m in next(iter(table.values()))
    }
    for m, v in overall.items():
        print(csv_line(f"table1/overall/{m}", 0.0, f"speedup={v:.3f}"))
    return {"per_task": table, "overall": overall}


if __name__ == "__main__":
    main()
