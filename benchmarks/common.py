"""Shared benchmark setup: a small trained target model + task prompts.

The paper's absolute H100 speedups are not reproducible on CPU; what IS
reproducible (and what we assert) are the *orderings* and the per-round
token economics: mean accepted tokens, target-call reduction, and the
relative speedups between scheduling strategies. We therefore benchmark a
reduced Llama-class model (the paper's Vicuna family, scaled down) briefly
trained on a synthetic corpus so drafts correlate with the target.
"""
from __future__ import annotations

import dataclasses
import os
import time
from typing import Callable, Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import get_config
from repro.core.engine import SpecEngine
from repro.data import SPEC_TASKS, make_task_prompts, lm_batches, synthetic_corpus
from repro.models import model as M
from repro.training import adamw_init, make_train_step, save_checkpoint, load_checkpoint

CACHE_DIR = os.environ.get("REPRO_BENCH_CACHE", "results/bench_model")


def bench_config():
    return dataclasses.replace(
        get_config("vicuna-7b").reduced(), num_layers=8, vocab_size=512
    )


def trained_params(cfg=None, steps: int = 60, cache_dir: str = None):
    """Train briefly on the synthetic corpus (cached on disk).

    ``cache_dir`` keeps differently-trained variants apart (e.g. the CI
    ``--smoke`` model must never poison the full benchmark cache)."""
    cfg = cfg or bench_config()
    cache_dir = cache_dir or CACHE_DIR
    key = jax.random.PRNGKey(0)
    params = M.init_params(cfg, key)
    if os.path.isdir(cache_dir):
        try:
            (params,) = load_checkpoint(cache_dir, params)[:1]
            return cfg, params
        except Exception:
            pass
    opt = adamw_init(params)
    step = jax.jit(make_train_step(cfg, peak_lr=1e-3, warmup=10,
                                   total_steps=steps, remat=False))
    corpus = synthetic_corpus(cfg.vocab_size, 60_000)
    it = lm_batches(corpus, 8, 96)
    for _ in range(steps):
        b = {k: jnp.asarray(v) for k, v in next(it).items()}
        params, opt, _ = step(params, opt, b)
    os.makedirs(os.path.dirname(cache_dir) or ".", exist_ok=True)
    save_checkpoint(cache_dir, params, step=steps)
    return cfg, params


def task_prompts(cfg, n_per_task: int = 1) -> Dict[str, List[np.ndarray]]:
    return {
        name: make_task_prompts(task, n_per_task, cfg.vocab_size, seed=7)
        for name, task in SPEC_TASKS.items()
    }


def time_scheduler(
    cfg, params, prompts: List[np.ndarray], builder: Callable, n_tokens: int = 32,
) -> Tuple[float, dict]:
    """Returns (seconds per token, engine stats) across prompts.

    The first prompt warms the jit caches; timed separately and discarded.
    """
    # warmup (compilation)
    eng = SpecEngine(cfg, params, max_len=512)
    eng.start(prompts[0])
    builder(eng).generate(8)

    total_t, total_tok = 0.0, 0
    calls, mcost = 0, 0.0
    stats = None
    for p in prompts:
        eng = SpecEngine(cfg, params, max_len=512)
        eng.start(p)
        sched = builder(eng)
        t0 = time.perf_counter()
        out = sched.generate(n_tokens)
        total_t += time.perf_counter() - t0
        total_tok += len(out)
        stats = dict(eng.stats)
        calls += eng.stats["target_calls"]
        mcost += eng.stats["modeled_draft_cost"]
    # modeled cost per token in target-forward units (TPU cost coefficients):
    # verify forwards + DSIA-weighted draft forwards; AR = 1.0 by definition
    stats["modeled_cost_per_token"] = (calls + mcost) / max(total_tok, 1)
    return total_t / total_tok, stats


def csv_line(name: str, us_per_call: float, derived: str) -> str:
    return f"{name},{us_per_call:.1f},{derived}"
