"""Perf-trajectory helpers for the serve smoke benchmark (stdlib-only).

Two jobs:

  - ``append_entry(path, results)`` — called by ``benchmarks/run.py
    --trend-out``: appends one entry (commit, UTC time, per-variant
    tokens/step + rounds/s) to a trajectory JSON. CI runs this on every
    bench-smoke job and commits the file as ``BENCH_smoke.json`` on pushes
    to main — the canonical perf history of the drafting path.
  - CLI compare — called by the CI ``bench-trend`` step: renders a
    markdown table comparing the previous main run's ``bench.json``
    against the current one (tokens/step and rounds/s with deltas) into
    ``$GITHUB_STEP_SUMMARY``.

Usage:
  python benchmarks/trend.py --cur results/bench_smoke/bench.json \
      [--prev prev_bench/bench.json] [--summary "$GITHUB_STEP_SUMMARY"]
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import time


def _commit() -> str:
    sha = os.environ.get("GITHUB_SHA")
    if sha:
        return sha
    try:
        return subprocess.check_output(
            ["git", "rev-parse", "HEAD"], text=True,
            stderr=subprocess.DEVNULL,
        ).strip()
    except Exception:
        return "unknown"


def serve_metrics(results: dict) -> dict:
    """Extract {variant: {tokens_per_step, us_per_round, rounds_per_s}}
    from a bench.json dict (or its serve-suite slice)."""
    serve = results.get("serve", results)
    out = {}
    if not isinstance(serve, dict):
        return out
    for name, r in serve.items():
        # a serve variant carries BOTH keys — other suites' sub-dicts
        # (table1, fig3, ...) must never be mislabeled as serve rows
        if isinstance(r, dict) and "us_per_round" in r and "tokens_per_step" in r:
            us = max(float(r["us_per_round"]), 1e-9)
            out[name] = {
                "tokens_per_step": round(float(r["tokens_per_step"]), 4),
                "us_per_round": round(us, 1),
                "rounds_per_s": round(1e6 / us, 3),
            }
    return out


def append_entry(path: str, results: dict) -> dict:
    """Append this run's serve metrics to the trajectory file at ``path``."""
    traj = {"entries": []}
    if os.path.exists(path):
        try:
            with open(path) as f:
                traj = json.load(f)
        except Exception:
            pass
    traj.setdefault("entries", [])
    entry = {
        "commit": _commit(),
        "utc": time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime()),
        "serve": serve_metrics(results),
    }
    canary = results.get("serve", {})
    if isinstance(canary, dict) and canary.get("canary_failed"):
        entry["canary_failed"] = str(canary["canary_failed"])
    traj["entries"].append(entry)
    with open(path, "w") as f:
        json.dump(traj, f, indent=1)
        f.write("\n")
    return entry


def compare_table(prev: dict | None, cur: dict) -> str:
    """Markdown table: previous-main vs current serve metrics with deltas."""
    prev_m = serve_metrics(prev) if prev else {}
    cur_m = serve_metrics(cur)
    lines = [
        "### bench-smoke perf trend (serve suite)",
        "",
        "| variant | tokens/step | rounds/s |",
        "|---|---|---|",
    ]

    def cell(p, c, key, fmt):
        if p is None or key not in p:
            return fmt.format(c[key])
        delta = (c[key] - p[key]) / max(abs(p[key]), 1e-9) * 100
        return f"{fmt.format(p[key])} → {fmt.format(c[key])} ({delta:+.1f}%)"

    for name, c in cur_m.items():
        p = prev_m.get(name)
        lines.append(
            f"| {name} | {cell(p, c, 'tokens_per_step', '{:.3f}')} "
            f"| {cell(p, c, 'rounds_per_s', '{:.2f}')} |"
        )
    if not cur_m:
        lines.append("| _no serve metrics in current bench.json_ | | |")
    serve = cur.get("serve", cur)
    if isinstance(serve, dict) and serve.get("canary_failed"):
        lines += ["", f"⚠️ smoke canary tripped: `{serve['canary_failed']}`"]
    if not prev_m:
        lines += ["", "_no previous main artifact — deltas omitted_"]
    return "\n".join(lines) + "\n"


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--cur", required=True, help="current bench.json")
    ap.add_argument("--prev", default="", help="previous main bench.json ('' = none)")
    ap.add_argument("--summary", default="", help="file to append the markdown table to")
    args = ap.parse_args()

    with open(args.cur) as f:
        cur = json.load(f)
    prev = None
    if args.prev and os.path.exists(args.prev):
        try:
            with open(args.prev) as f:
                prev = json.load(f)
        except Exception:
            prev = None
    table = compare_table(prev, cur)
    if args.summary:
        with open(args.summary, "a") as f:
            f.write(table)
    print(table)


if __name__ == "__main__":
    main()
