"""Fig. 3: speedup of every scheduling strategy relative to AR.

Reproduces the ablation ladder: AR, PLD, LS (layer-sparse chain, no tree),
VC, HC, VC+HC (CS-Drafting), Tr (SWIFT + tree attention), Tr+VC, DyTC.
Validated claims: DyTC is the best; DyTC > VC+HC and DyTC > Tr by a clear
margin (paper: +73% and +47% on H100 — we assert the ordering and report
the CPU-scale margins)."""
from __future__ import annotations

import sys

from repro.core.cascade import (
    ARScheduler,
    HCScheduler,
    PLDScheduler,
    SDScheduler,
    TreeScheduler,
    TreeVCScheduler,
    VCHCScheduler,
    VCScheduler,
)
from repro.core.dsia import build_hierarchy, layer_sparsity
from repro.core.dytc import DyTCScheduler

sys.path.insert(0, "benchmarks")
from common import csv_line, task_prompts, time_scheduler, trained_params


def main(n_tokens: int = 32) -> dict:
    cfg, params = trained_params()
    prompts = [p for ps in task_prompts(cfg).values() for p in ps][:3]
    ls4 = layer_sparsity(cfg, 0.4)
    meths = {
        "AR": lambda e: ARScheduler(e),
        "PLD": lambda e: PLDScheduler(e, k=8),
        "LS": lambda e: SDScheduler(e, ls4, k=4),
        "VC": lambda e: VCScheduler(e, ls4, n=2, k2=5),
        "HC": lambda e: HCScheduler(e, ls4, k1=3, k2=5),
        "VC+HC": lambda e: VCHCScheduler(e, ls4, n=2, k2=4, tail=4),
        "Tr": lambda e: TreeScheduler(e, ls4, depth=4, top_k=2),
        "Tr+VC": lambda e: TreeVCScheduler(e, ls4, depth=4, top_k=2),
        "DyTC": lambda e: DyTCScheduler(e, build_hierarchy(cfg)),
    }
    ar_spt, ar_stats = time_scheduler(cfg, params, prompts, meths["AR"], n_tokens)
    out = {}
    for name, builder in meths.items():
        spt, stats = time_scheduler(cfg, params, prompts, builder, n_tokens)
        modeled = ar_stats["modeled_cost_per_token"] / stats["modeled_cost_per_token"]
        out[name] = {"wall": ar_spt / spt, "modeled": modeled}
        print(csv_line(f"fig3/{name}", spt * 1e6,
                       f"wall_speedup={ar_spt/spt:.3f};modeled_speedup={modeled:.3f}"))
    return out


if __name__ == "__main__":
    main()
