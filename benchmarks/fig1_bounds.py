"""Fig. 1b/1c: theoretical effective bound of the intermediate draft's cost
coefficient c_d1 for VC / HC to beat SD-with-PLD alone.

Reproduces the paper's numerical simulation: c_d2 = 0.01 (PLD-like bottom),
alpha(M_t,M_d2) = alpha(M_d1,M_d2); sweep alpha(M_t,M_d1) and report the
borderline c_d1 where max-hyperparameter cascade EWIF crosses max-k SD EWIF.
The SWIFT data points from Spec-Bench mostly sit ABOVE the bound — the
paper's motivation for DyTC (RQ1)."""
from __future__ import annotations

import sys

import numpy as np

from repro.core import ewif

sys.path.insert(0, "benchmarks")
from common import csv_line

# representative (alpha, c) of SWIFT on Spec-Bench (Fig. 1b reading)
SWIFT_POINTS = [(0.55, 0.55), (0.6, 0.5), (0.65, 0.55), (0.7, 0.5)]


def main() -> dict:
    alphas = np.linspace(0.3, 0.95, 14)
    alpha_d2 = 0.35                 # PLD-like acceptance
    c_d2 = 0.01
    vc, hc = [], []
    for a1 in alphas:
        b_vc = ewif.vc_bound_c_d1_numeric(a1, alpha_d2, alpha_d2, c_d2,
                                          n_max=4, k_max=10)
        b_hc = ewif.hc_bound_c_d1_numeric(a1, alpha_d2, c_d2, k_max=10)
        vc.append(b_vc)
        hc.append(b_hc)
        print(csv_line(f"fig1/alpha={a1:.2f}", 0.0,
                       f"vc_bound={b_vc:.3f};hc_bound={b_hc:.3f}"))
    # bounds increase with alpha_d1 (better drafts tolerate higher cost)
    assert all(b2 >= b1 - 1e-6 for b1, b2 in zip(hc, hc[1:]))
    above = sum(
        1 for a, c in SWIFT_POINTS
        if c > ewif.hc_bound_c_d1_numeric(a, alpha_d2, c_d2, k_max=10)
    )
    print(csv_line("fig1/swift_points_above_bound", 0.0,
                   f"count={above}/{len(SWIFT_POINTS)}"))
    return {"alphas": list(alphas), "vc": vc, "hc": hc, "swift_above": above}


if __name__ == "__main__":
    main()
