"""Table 2: mean accepted tokens per verification round + speedup.

Paper (Vicuna-7B, H100): PLD 1.75 / SWIFT 3.01 / CAS-Spec 3.43 mean
accepted tokens. We reproduce the ORDERING CAS-Spec > PLD on mean accepted
tokens and CAS-Spec >= both on speedup, at CPU scale.
"""
from __future__ import annotations

import sys

from repro.core.cascade import ARScheduler, PLDScheduler, SDScheduler
from repro.core.dsia import build_hierarchy, layer_sparsity
from repro.core.dytc import DyTCScheduler

sys.path.insert(0, "benchmarks")
from common import bench_config, csv_line, task_prompts, time_scheduler, trained_params


def main(n_tokens: int = 32) -> dict:
    cfg, params = trained_params()
    prompts = [p for ps in task_prompts(cfg).values() for p in ps][:4]
    ls4 = layer_sparsity(cfg, 0.4)
    meths = {
        "PLD": lambda e: PLDScheduler(e, k=8),
        "SWIFT": lambda e: SDScheduler(e, ls4, k=4),
        "CAS-Spec": lambda e: DyTCScheduler(e, build_hierarchy(cfg)),
    }
    ar_spt, ar_stats = time_scheduler(cfg, params, prompts, lambda e: ARScheduler(e), n_tokens)
    out = {}
    for name, builder in meths.items():
        spt, stats = time_scheduler(cfg, params, prompts, builder, n_tokens)
        mean_acc = stats["accepted_tokens"] / max(stats["rounds"], 1)
        modeled = ar_stats["modeled_cost_per_token"] / stats["modeled_cost_per_token"]
        out[name] = {"mean_accepted": mean_acc, "speedup": modeled}
        print(csv_line(f"table2/{name}", spt * 1e6,
                       f"mean_accepted={mean_acc:.2f};modeled_speedup={modeled:.3f}"))
    return out


if __name__ == "__main__":
    main()
