"""Ablation: DyTC scheduling hyperparameters under the EWIF model.

Sweeps the Eq.-5 objective over (k_max, alpha, c) grids to answer:
  - how sensitive is the chosen draft length k* to the acceptance estimate?
  - when does the admissible objective (Eq. 5) pick a DIFFERENT config than
    the greedy objective (the paper's §4.2 motivation), and how much EWIF
    does that recover?
Closed-form + Monte-Carlo (no model execution — runs in seconds).
"""
from __future__ import annotations

import sys

import numpy as np

from repro.core import ewif

sys.path.insert(0, "benchmarks")
from common import csv_line


def optimal_k_surface():
    out = {}
    for alpha in (0.5, 0.7, 0.9):
        for c in (0.1, 0.3, 0.5):
            best = max(
                range(1, 13),
                key=lambda k: ewif.dytc_step_objective(alpha, c, k, 0.3, 0.01),
            )
            out[(alpha, c)] = best
            print(csv_line(f"ablation/kstar/a={alpha}_c={c}", 0.0, f"k_star={best}"))
    # k* must grow with alpha and shrink with c
    assert out[(0.9, 0.1)] >= out[(0.5, 0.1)]
    assert out[(0.9, 0.5)] <= out[(0.9, 0.1)]
    return out


def greedy_vs_admissible_gap():
    """Fraction of (a1,c1,a2,c2) space where the schedulers disagree, and the
    EWIF recovered by the admissible choice when they do."""
    rng = np.random.default_rng(0)
    disagree, gains = 0, []
    trials = 400
    for _ in range(trials):
        a1, a2 = sorted(rng.uniform(0.3, 0.95, 2))[::-1]
        c2, c1 = sorted(rng.uniform(0.05, 0.6, 2))
        g1 = ewif.greedy_step_objective(a1, c1, 1)
        g2 = ewif.greedy_step_objective(a2, c2, 1)
        o1 = max(ewif.dytc_step_objective(a1, c1, k, 0.3, 0.01) for k in range(1, 8))
        o2 = max(ewif.dytc_step_objective(a2, c2, k, 0.3, 0.01) for k in range(1, 8))
        pick_greedy = 0 if g1 > g2 else 1
        pick_adm = 0 if o1 > o2 else 1
        if pick_greedy != pick_adm:
            disagree += 1
            # realized EWIF of each pick as standalone SD
            t_greedy = ewif.best_sd(*( (a1, c1) if pick_greedy == 0 else (a2, c2)))[0]
            t_adm = ewif.best_sd(*( (a1, c1) if pick_adm == 0 else (a2, c2)))[0]
            gains.append(t_adm / t_greedy - 1.0)
    frac = disagree / trials
    mean_gain = float(np.mean(gains)) if gains else 0.0
    print(csv_line("ablation/greedy_vs_eq5", 0.0,
                   f"disagree_frac={frac:.3f};mean_ewif_gain_when_disagree={mean_gain:+.3f}"))
    return {"disagree_frac": frac, "mean_gain": mean_gain}


def tmin_sensitivity():
    """Paper sets t_min=1.1: EWIF of stopping rules across acceptance mixes."""
    for t_min in (1.0, 1.1, 1.5, 2.0):
        # expected tree size before the stop rule triggers (alpha=0.7 chain)
        alpha, a_dn, c_dn = 0.7, 0.3, 0.01
        depth = 0
        p = 1.0
        while p * (a_dn / c_dn) >= t_min and depth < 32:
            depth += 1
            p *= alpha
        print(csv_line(f"ablation/tmin={t_min}", 0.0, f"max_chain_depth={depth}"))


def main() -> dict:
    ks = optimal_k_surface()
    gap = greedy_vs_admissible_gap()
    tmin_sensitivity()
    return {"k_star": {f"{a}/{c}": v for (a, c), v in ks.items()}, **gap}


if __name__ == "__main__":
    main()
