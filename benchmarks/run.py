"""Benchmark driver — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines. Usage:
  PYTHONPATH=src python -m benchmarks.run [--only table1,fig3] [--quick]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time
from typing import Optional

sys.path.insert(0, os.path.dirname(__file__))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, help="comma-separated subset")
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--out", default="results/bench")
    ap.add_argument("--smoke", action="store_true",
                    help="serve suite only: tiny model, few rounds — the CI "
                         "drafting-path canary (own model cache, exits "
                         "nonzero on a clear tree-vs-chain regression); "
                         "other suites ignore this flag")
    ap.add_argument("--trend-out", default=None,
                    help="append this run's serve-suite metrics to a perf "
                         "trajectory JSON (CI commits it as BENCH_smoke.json "
                         "on main) — written even when a canary trips")
    args = ap.parse_args()

    import ablation_dytc
    import fig1_bounds
    import fig3_methods
    import serve_batched
    import table1_speedup
    import table2_accepted

    suites = {
        "fig1": lambda: fig1_bounds.main(),
        "ablation": lambda: ablation_dytc.main(),
        "table1": lambda: table1_speedup.main(args.tokens),
        "table2": lambda: table2_accepted.main(args.tokens),
        "fig3": lambda: fig3_methods.main(args.tokens),
        "serve": lambda: serve_batched.main(args.tokens, smoke=args.smoke),
    }
    only = set(args.only.split(",")) if args.only else set(suites)
    os.makedirs(args.out, exist_ok=True)
    results = {}
    canary: Optional[SystemExit] = None
    for name, fn in suites.items():
        if name not in only:
            continue
        print(f"### {name}")
        t0 = time.perf_counter()
        try:
            results[name] = fn()
        except SystemExit as e:
            # a smoke canary tripped — still persist the JSON, including
            # any measured numbers riding on the exception (CI uploads it
            # as an artifact; it is most useful exactly on failure)
            canary = e
            results[name] = dict(getattr(e, "results", {}),
                                 canary_failed=str(e))
        print(f"### {name} done in {time.perf_counter()-t0:.1f}s")
    with open(os.path.join(args.out, "bench.json"), "w") as f:
        json.dump(results, f, indent=1, default=float)
    if args.trend_out and "serve" in results:
        import trend

        # trajectory entries record canary failures too — a regression is
        # exactly the point a perf history must not lose. Guarded on the
        # serve suite: the trajectory tracks serve metrics only.
        trend.append_entry(args.trend_out, json.loads(json.dumps(results, default=float)))
    elif args.trend_out:
        print("trend-out skipped: serve suite did not run")
    if canary is not None:
        raise canary


if __name__ == "__main__":
    main()
