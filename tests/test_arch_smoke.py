"""Per-architecture smoke tests (spec requirement f).

Every assigned architecture instantiates a REDUCED variant of its family
(<=4 layers at reduced width, <=4 experts) and runs one forward/train step
plus a prefill->decode consistency check on CPU, asserting shapes + no NaNs.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import get_config, list_configs
from repro.configs import ASSIGNED_ARCHS
from repro.models import model as M
from repro.training import adamw_init, make_train_step

ARCHS = ASSIGNED_ARCHS + ["vicuna-7b"]


def make_batch(cfg, B, S, key):
    if cfg.num_codebooks:
        toks = jax.random.randint(key, (B, S, cfg.num_codebooks), 0, cfg.vocab_size)
    else:
        toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": toks}
    if cfg.num_image_tokens:
        Ti = cfg.num_image_tokens
        batch["image_embeds"] = (
            jax.random.normal(key, (B, Ti, cfg.d_model), jnp.float32) * 0.02
        )
        batch["image_mask"] = jnp.zeros((B, S), jnp.int32).at[:, :Ti].set(1)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_reduced_smoke(arch):
    cfg = get_config(arch).reduced()
    assert cfg.num_layers <= 8 and cfg.d_model <= 512
    if cfg.moe:
        assert cfg.moe.num_experts <= 4
    key = jax.random.PRNGKey(0)
    params = M.init_params(cfg, key)
    B, S = 2, 24
    batch = make_batch(cfg, B, S, key)

    # ---- forward + shapes + no NaN
    logits, aux = M.forward_train(cfg, params, batch, remat=False)
    exp = (B, S, cfg.num_codebooks, cfg.padded_vocab) if cfg.num_codebooks else (
        B, S, cfg.padded_vocab)
    assert logits.shape == exp
    assert not bool(jnp.isnan(logits).any())

    # ---- one train step
    step = jax.jit(make_train_step(cfg, warmup=1, total_steps=10, remat=False))
    opt = adamw_init(params)
    p2, opt2, metrics = step(params, opt, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))

    # ---- prefill -> decode consistency (the serving path)
    cache = M.init_cache(cfg, B, 64)
    last, cache = M.prefill(cfg, params, batch, cache)
    assert not bool(jnp.isnan(last).any())
    nxt = (
        jnp.argmax(last, -1)[:, None, :]
        if cfg.num_codebooks
        else jnp.argmax(last, -1)[:, None]
    )
    lg, staged = M.decode_step(cfg, params, cache, nxt)
    cache2 = M.init_cache(cfg, B, 64)
    b2 = dict(batch)
    b2["tokens"] = jnp.concatenate([batch["tokens"], nxt], axis=1)
    if cfg.num_image_tokens:
        b2["image_mask"] = jnp.pad(batch["image_mask"], ((0, 0), (0, 1)))
    last2, _ = M.prefill(cfg, params, b2, cache2)
    np.testing.assert_allclose(
        np.asarray(lg[:, 0]), np.asarray(last2), rtol=5e-3, atol=5e-5
    )


@pytest.mark.parametrize("arch", ["mixtral-8x22b", "jamba-v0.1-52b", "gemma3-1b"])
def test_commit_chain_vs_sequential(arch):
    """Joint T-token decode + commit == sequential decode (cache coherence)."""
    cfg = get_config(arch).reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    B = 2
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, 16), 0, cfg.vocab_size)
    cache = M.init_cache(cfg, B, 64)
    _, cache = M.prefill(cfg, params, {"tokens": toks}, cache)
    t3 = jax.random.randint(jax.random.PRNGKey(2), (B, 3), 0, cfg.vocab_size)
    lg_joint, _ = M.decode_step(cfg, params, cache, t3)
    lg2, st2 = M.decode_step(cfg, params, cache, t3[:, :2])
    cc = M.commit_cache(cfg, cache, st2, jnp.arange(2), jnp.asarray(2, jnp.int32))
    lg1, _ = M.decode_step(cfg, params, cc, t3[:, 2:])
    np.testing.assert_allclose(
        np.asarray(lg1[:, 0]), np.asarray(lg_joint[:, 2]), rtol=5e-3, atol=5e-5
    )


def test_param_count_matches_analytic():
    """config.param_count() is the contract for the roofline MODEL_FLOPS."""
    for arch in ["vicuna-7b", "qwen2-moe-a2.7b", "mamba2-130m"]:
        cfg = get_config(arch).reduced()
        params = M.init_params(cfg, jax.random.PRNGKey(0))
        actual = sum(x.size for x in jax.tree.leaves(params))
        # padded vocab inflates embed/lm_head relative to the analytic count
        pad = (cfg.padded_vocab - cfg.vocab_size) * cfg.d_model
        nheads = max(cfg.num_codebooks, 1) * (1 if cfg.tie_embeddings else 2)
        assert actual == cfg.param_count() + pad * nheads


def test_sliding_window_ring_decode():
    """Ring cache (window-sized) decode == full-cache decode with window."""
    cfg = dataclasses.replace(
        get_config("mixtral-8x22b").reduced(), sliding_window=16
    )
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    B, S = 1, 40            # prompt longer than the window
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)
    full = M.init_cache(cfg, B, 128, ring_window=False)
    ring = M.init_cache(cfg, B, 128, ring_window=True)
    lf, full = M.prefill(cfg, params, {"tokens": toks}, full)
    lr, ring = M.prefill(cfg, params, {"tokens": toks}, ring)
    np.testing.assert_allclose(np.asarray(lf), np.asarray(lr), rtol=5e-3, atol=5e-5)
    nxt = jnp.argmax(lf, -1)[:, None]
    of, _ = M.decode_step(cfg, params, full, nxt)
    orr, _ = M.decode_step(cfg, params, ring, nxt)
    np.testing.assert_allclose(
        np.asarray(of), np.asarray(orr), rtol=5e-3, atol=5e-5
    )
