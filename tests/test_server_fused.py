"""Fused batched drafting: single-dispatch proposals, batched-vs-B=1
equivalence, and adaptive per-slot draft lengths (chain DyTC analogue)."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.config import get_config
from repro.core.cascade import ARScheduler
from repro.core.dsia import layer_sparsity
from repro.core.engine import SpecEngine
from repro.core.latency import best_chain_length
from repro.models import model as M
from repro.serving import Request, RequestScheduler, ServeLoop
from repro.serving.server import BatchedSpecServer

CFG = dataclasses.replace(get_config("vicuna-7b").reduced(), num_layers=3)
PARAMS = M.init_params(CFG, jax.random.PRNGKey(0))
SPEC = layer_sparsity(CFG, 0.5)


def _random_prompts(n, length, seed=0):
    """High-entropy prompts: no n-gram reuse, so PLD proposes nothing and
    every draft token must come from the neural chain scan."""
    rng = np.random.default_rng(seed)
    return [rng.integers(4, CFG.vocab_size - 1, size=length).astype(np.int32)
            for _ in range(n)]


def _repetitive_prompts():
    return [
        np.array([5, 6, 7, 8] * 4, np.int32),
        np.array([9, 10, 11] * 5, np.int32),
        np.array([3, 4] * 6, np.int32),
    ]


def test_batched_matches_single_stream():
    """Fused + adaptive batched serving must emit exactly the B=1 greedy
    stream for every slot (losslessness under divergent accepted lengths)."""
    srv = BatchedSpecServer(CFG, PARAMS, max_batch=3, max_len=256, draft_k=4,
                            draft_spec=SPEC, fused=True, adaptive=True,
                            min_obs=1)
    prompts = _repetitive_prompts()
    for i, p in enumerate(prompts):
        srv.add_request(i, p)
    gen = {i: [] for i in range(3)}
    for _ in range(8):
        for b, toks in srv.step().items():
            gen[b].extend(toks)
    for i, p in enumerate(prompts):
        eng = SpecEngine(CFG, PARAMS, max_len=256)
        eng.start(p)
        ref = ARScheduler(eng).generate(len(gen[i]))
        assert ref == gen[i], f"slot {i} diverged"


def test_equivalence_when_drafting_stops():
    """A t_min no slot can meet forces adaptive limits to 0 (pure AR +
    PLD inside the batched verify) — output must be unchanged. Random
    prompts keep PLD silent, so every round observes a neural outcome."""
    srv = BatchedSpecServer(CFG, PARAMS, max_batch=2, max_len=256, draft_k=4,
                            draft_spec=SPEC, fused=True, adaptive=True,
                            min_obs=1, t_min=1e9)
    prompts = _random_prompts(2, 16, seed=3)
    for i, p in enumerate(prompts):
        srv.add_request(i, p)
    gen = {i: [] for i in range(2)}
    for _ in range(6):
        for b, toks in srv.step().items():
            gen[b].extend(toks)
    for i, p in enumerate(prompts):
        eng = SpecEngine(CFG, PARAMS, max_len=256)
        eng.start(p)
        ref = ARScheduler(eng).generate(len(gen[i]))
        assert ref == gen[i], f"slot {i} diverged"
    # after warmup the unmeetable threshold must have stopped neural drafting
    assert srv._slot_limit(0) == 0 and srv._slot_limit(1) == 0


def test_one_draft_dispatch_per_propose_round():
    """Regression: the fused SPLIT path issues exactly ONE jitted drafting
    dispatch per propose round (the seed issued one per draft token; the
    single-dispatch round is pinned in tests/test_server_round.py)."""
    srv = BatchedSpecServer(CFG, PARAMS, max_batch=2, max_len=256, draft_k=4,
                            draft_spec=SPEC, fused=True, adaptive=False,
                            round_mode="split")
    calls = []
    orig = srv._draft_fn

    def counting(steps):
        fn = orig(steps)

        def wrapped(*a, **kw):
            calls.append(steps)
            return fn(*a, **kw)

        return wrapped

    srv._draft_fn = counting
    for i, p in enumerate(_random_prompts(2, 24)):
        srv.add_request(i, p)
    n_rounds = 5
    for _ in range(n_rounds):
        srv.step()
    assert len(calls) == n_rounds                      # one dispatch per round
    assert srv.stats["draft_dispatches"] == n_rounds
    assert srv.stats["target_calls"] == n_rounds       # one verify per round
    assert len(srv._draft_fns) <= srv.k                # bounded compile cache
    # PLD silent -> every round observes a first-NEURAL-token outcome
    assert srv.acceptance.counts(srv._slot_key(0)) == n_rounds

    # contrast: the legacy (seed) loop pays one dispatch per draft token
    leg = BatchedSpecServer(CFG, PARAMS, max_batch=2, max_len=256, draft_k=4,
                            draft_spec=SPEC, fused=False, adaptive=False)
    for i, p in enumerate(_random_prompts(2, 24, seed=1)):
        leg.add_request(i, p)
    for _ in range(n_rounds):
        leg.step()
    assert leg.stats["draft_dispatches"] == n_rounds * leg.k


def test_fused_and_legacy_paths_agree():
    """Same greedy tokens whether drafting is fused or per-step (both are
    lossless; drafts only change speed)."""
    outs = []
    for fused in (True, False):
        srv = BatchedSpecServer(CFG, PARAMS, max_batch=2, max_len=256,
                                draft_k=4, draft_spec=SPEC, fused=fused,
                                adaptive=False)
        for i, p in enumerate(_repetitive_prompts()[:2]):
            srv.add_request(i, p)
        gen = {0: [], 1: []}
        for _ in range(6):
            for b, toks in srv.step().items():
                gen[b].extend(toks)
        outs.append(gen)
    assert outs[0] == outs[1]


def test_decode_commit_token_matches_decode_plus_commit():
    """The scan-friendly single-token entry point is exactly decode_step +
    commit_cache of one accepted token (the O(k) state-carrying drafting
    alternative for large k)."""
    import jax.numpy as jnp

    prompts = jnp.asarray(
        np.stack([[5, 6, 7, 8, 5, 6], [9, 10, 11, 9, 10, 11]]), jnp.int32
    )
    cache = M.init_cache(CFG, 2, 64)
    _, cache = M.prefill(CFG, PARAMS, {"tokens": prompts}, cache)
    tok = jnp.asarray([3, 7], jnp.int32)

    logits1, c1 = M.decode_commit_token(CFG, PARAMS, cache, tok)
    logits2, staged = M.decode_step(CFG, PARAMS, cache, tok[:, None])
    c2 = M.commit_cache(CFG, cache, staged, jnp.zeros((2, 1), jnp.int32),
                        jnp.ones((2,), jnp.int32))
    np.testing.assert_allclose(np.asarray(logits1), np.asarray(logits2[:, 0]))
    leaves1, leaves2 = jax.tree.leaves(c1), jax.tree.leaves(c2)
    assert len(leaves1) == len(leaves2)
    for a, b in zip(leaves1, leaves2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b))
    assert np.all(np.asarray(c1["pos"]) == np.asarray(cache["pos"]) + 1)


def test_adaptive_chain_length_monotone():
    """best_chain_length is monotone: longer chains for higher acceptance,
    shorter for costlier drafts, zero below the speedup threshold."""
    ks_alpha = [best_chain_length(a, 0.3, 8, t_min=1.0)
                for a in (0.05, 0.3, 0.6, 0.9, 0.99)]
    assert ks_alpha == sorted(ks_alpha)
    assert ks_alpha[-1] > ks_alpha[0]

    ks_cost = [best_chain_length(0.8, c, 8, t_min=1.0)
               for c in (0.02, 0.1, 0.3, 0.6, 0.95)]
    assert ks_cost == sorted(ks_cost, reverse=True)

    # hopeless economics -> stop drafting entirely
    assert best_chain_length(0.1, 0.9, 8, t_min=1.1) == 0
    # near-free, near-certain drafts -> draft the full budget
    assert best_chain_length(0.99, 0.01, 8, t_min=1.1) == 8


def test_server_slot_limits_track_acceptance():
    """A slot with collapsed acceptance stops drafting; a healthy slot keeps
    its full budget. Admission resets the slot estimator. (Split rounds:
    this drives the HOST trackers directly; the device-side analogue is
    tests/test_server_round.py::test_device_routing_stops_drafting.)"""
    srv = BatchedSpecServer(CFG, PARAMS, max_batch=2, max_len=128, draft_k=4,
                            draft_spec=SPEC, fused=True, adaptive=True,
                            min_obs=4, t_min=1.05, round_mode="split")
    # healthy draft economics: drafts cost ~10% of a verify round
    srv.costs.observe_target(1.0, tokens=1)
    srv.costs.observe("chain_draft", 0.1, tokens=1)
    for _ in range(12):
        srv.acceptance.observe(srv._slot_key(0), True)
        srv.acceptance.observe(srv._slot_key(1), False)
    assert srv._slot_limit(0) == srv.k
    assert srv._slot_limit(1) == 0
    # continuous batching: a new request on the dead slot starts fresh
    srv.add_request(1, np.array([7, 8, 9, 7, 8, 9], np.int32))
    assert srv.acceptance.counts(srv._slot_key(1)) == 0
    assert srv._slot_limit(1) == srv.k   # below min_obs -> full budget
