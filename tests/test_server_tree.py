"""Batched on-device DyTC tree drafting (`tree_fused` serving mode):
losslessness vs the B=1 reference, one drafting + one verify dispatch per
round, Eq. 5 budgets, and pallas/jnp verify-backend parity."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import get_config
from repro.core.cascade import ARScheduler
from repro.core.dsia import layer_sparsity
from repro.core.engine import SpecEngine
from repro.core.latency import best_tree_expansions
from repro.core.tree import DraftTree, tree_seed_arrays
from repro.core.verify import greedy_accept_tree, greedy_accept_tree_batched
from repro.models import model as M
from repro.serving.server import BatchedSpecServer

CFG = dataclasses.replace(get_config("vicuna-7b").reduced(), num_layers=3)
PARAMS = M.init_params(CFG, jax.random.PRNGKey(0))
SPEC = layer_sparsity(CFG, 0.5)


def _random_prompts(n, length, seed=0):
    """High-entropy prompts: PLD proposes nothing, every draft token comes
    from the neural tree scan."""
    rng = np.random.default_rng(seed)
    return [rng.integers(4, CFG.vocab_size - 1, size=length).astype(np.int32)
            for _ in range(n)]


def _repetitive_prompts():
    return [
        np.array([5, 6, 7, 8] * 4, np.int32),
        np.array([9, 10, 11] * 5, np.int32),
        np.array([3, 4] * 6, np.int32),
    ]


def _assert_matches_ar(srv, prompts, rounds):
    for i, p in enumerate(prompts):
        srv.add_request(i, p)
    gen = {i: [] for i in range(len(prompts))}
    for _ in range(rounds):
        for b, toks in srv.step().items():
            gen[b].extend(toks)
    for i, p in enumerate(prompts):
        eng = SpecEngine(CFG, PARAMS, max_len=256)
        eng.start(p)
        ref = ARScheduler(eng).generate(len(gen[i]))
        assert ref == gen[i], f"slot {i} diverged"
    return gen


def test_tree_fused_matches_single_stream():
    """tree_fused batched serving must emit exactly the B=1 greedy stream
    for every slot (losslessness under divergent accepted path lengths)."""
    srv = BatchedSpecServer(CFG, PARAMS, max_batch=3, max_len=256, draft_k=4,
                            draft_spec=SPEC, mode="tree_fused",
                            adaptive=True, min_obs=1)
    _assert_matches_ar(srv, _repetitive_prompts(), rounds=8)


def test_tree_fused_lossless_random_prompts():
    """Random prompts keep PLD silent: every tree node is neural, and the
    committed output must still be token-identical to AR."""
    srv = BatchedSpecServer(CFG, PARAMS, max_batch=2, max_len=256, draft_k=4,
                            draft_spec=SPEC, mode="tree_fused",
                            adaptive=False)
    _assert_matches_ar(srv, _random_prompts(2, 16, seed=3), rounds=6)


def test_one_tree_dispatch_per_round():
    """The fused SPLIT tree path issues exactly ONE drafting dispatch and
    ONE verify dispatch per round (the host DyTC loop pays one dispatch per
    expansion plus one per verify; the single-dispatch round is pinned in
    tests/test_server_round.py)."""
    srv = BatchedSpecServer(CFG, PARAMS, max_batch=2, max_len=256, draft_k=4,
                            draft_spec=SPEC, mode="tree_fused",
                            round_mode="split",
                            adaptive=False)
    calls = []
    orig = srv._tree_draft_fn

    def counting(expansions):
        fn = orig(expansions)

        def wrapped(*a, **kw):
            calls.append(expansions)
            return fn(*a, **kw)

        return wrapped

    srv._tree_draft_fn = counting
    for i, p in enumerate(_random_prompts(2, 24)):
        srv.add_request(i, p)
    n_rounds = 5
    for _ in range(n_rounds):
        srv.step()
    assert len(calls) == n_rounds                    # one drafting dispatch/round
    assert srv.stats["draft_dispatches"] == n_rounds
    assert srv.stats["target_calls"] == n_rounds     # one verify dispatch/round
    assert len(srv._tree_draft_fns) == 1             # fixed budget -> one compile
    # PLD silent -> the first neural node hangs off the (always accepted)
    # root, so every round observes an Eq. 4 outcome
    assert srv.acceptance.counts(srv._slot_key(0)) == n_rounds


def test_tree_budget_stops_drafting():
    """An unmeetable t_min drives every slot's Eq. 5 budget to 0 — the
    server degrades to PLD + AR inside the batched verify, losslessly."""
    srv = BatchedSpecServer(CFG, PARAMS, max_batch=2, max_len=256, draft_k=4,
                            draft_spec=SPEC, mode="tree_fused",
                            adaptive=True, min_obs=1, t_min=1e9)
    _assert_matches_ar(srv, _random_prompts(2, 16, seed=5), rounds=6)
    assert srv._slot_tree_budget(0) == 0 and srv._slot_tree_budget(1) == 0


def test_tree_backend_parity():
    """The pallas tree-attention verify backend and the pure-jnp dense pass
    must produce identical greedy outputs."""
    outs = []
    for backend in ("pallas", None):
        srv = BatchedSpecServer(CFG, PARAMS, max_batch=2, max_len=256,
                                draft_k=4, draft_spec=SPEC, mode="tree_fused",
                                adaptive=False, attn_backend=backend)
        for i, p in enumerate(_repetitive_prompts()[:2]):
            srv.add_request(i, p)
        gen = {0: [], 1: []}
        for _ in range(6):
            for b, toks in srv.step().items():
                gen[b].extend(toks)
        outs.append(gen)
    assert outs[0] == outs[1]


def test_tree_and_chain_modes_agree():
    """Same greedy stream whether proposals are trees or chains (both are
    lossless; drafts only change how many tokens a round accepts, never
    which tokens come out)."""
    outs = []
    for mode in ("tree_fused", "chain_fused"):
        srv = BatchedSpecServer(CFG, PARAMS, max_batch=2, max_len=256,
                                draft_k=4, draft_spec=SPEC, mode=mode,
                                adaptive=False)
        for i, p in enumerate(_repetitive_prompts()[:2]):
            srv.add_request(i, p)
        gen = {0: [], 1: []}
        for _ in range(6):
            for b, toks in srv.step().items():
                gen[b].extend(toks)
        outs.append(gen)
    for b in (0, 1):
        n = min(len(outs[0][b]), len(outs[1][b]))
        assert n > 0 and outs[0][b][:n] == outs[1][b][:n]


def test_batched_accept_walk_matches_host():
    """greedy_accept_tree_batched must agree with the host-side walk on
    branchy trees, including first-matching-child tie-breaks."""
    rng = np.random.default_rng(0)
    N = 16
    for _ in range(20):
        t = DraftTree(int(rng.integers(0, 50)))
        for _ in range(int(rng.integers(0, 12))):
            parent = int(rng.integers(0, len(t)))
            t.add_child(parent, int(rng.integers(0, 50)), "c", 0.8)
        n = len(t)
        nxt = rng.integers(0, 50, size=N).astype(np.int32)
        path_ref, bonus_ref = greedy_accept_tree(t, nxt[:n])

        tokens = np.zeros((1, N), np.int32)
        parents = np.full((1, N), -1, np.int32)
        tokens[0, :n] = t.tokens
        parents[0, :n] = t.parents
        path, n_acc, bonus = map(np.asarray, greedy_accept_tree_batched(
            jnp.asarray(tokens), jnp.asarray(parents),
            jnp.asarray([n], jnp.int32), jnp.asarray(nxt[None]),
        ))
        assert list(path[0, : n_acc[0]]) == path_ref
        assert int(bonus[0]) == bonus_ref


def test_tree_scan_dedups_against_pld_seed():
    """When the drafter's top-1 for the root equals the PLD-seeded child,
    the scan must NOT add a duplicate sibling, and first_neural must alias
    the PLD node — otherwise the Eq. 4 estimator records a rejection every
    round the drafter AGREES with PLD and adaptively shuts off drafting on
    exactly the good slots."""
    import functools

    from repro.core.engine import tree_draft_scan
    from repro.core.tree import tree_seed_arrays

    gates = jnp.asarray(SPEC.gates_array(CFG.num_layers))
    prompt = np.array([5, 6, 7, 8] * 3, np.int32)
    cache = M.init_cache(CFG, 1, 128)
    last, cache = M.prefill(CFG, PARAMS, {"tokens": jnp.asarray(prompt[None])}, cache)
    pending = np.argmax(np.asarray(last), -1).astype(np.int32)
    # the drafter's actual top-1 after the root
    lg, _ = M.decode_step(CFG, PARAMS, cache, jnp.asarray(pending[:, None]),
                          gates=gates)
    top1 = int(np.argmax(np.asarray(lg)[0, 0]))

    chains = np.zeros((1, 4), np.int32)
    chains[0, 0] = top1                       # PLD "proposed" the same token
    have = np.array([1], np.int32)
    seed = tree_seed_arrays(pending, chains, have, bucket=16)
    fn = jax.jit(functools.partial(tree_draft_scan, CFG, 1, 2))
    out = fn(PARAMS, cache, *(jnp.asarray(a) for a in seed),
             jnp.asarray([1], jnp.int32), jnp.asarray([0.7], jnp.float32),
             jnp.asarray(0.3, jnp.float32), jnp.asarray(1.0, jnp.float32),
             gates)
    tokens, parents, depth, p_acc, count, first_neural = (
        np.asarray(out[i]) for i in (0, 1, 2, 3, 5, 6)
    )
    root_children = [i for i in range(count[0]) if parents[0, i] == 0]
    child_tokens = [int(tokens[0, i]) for i in root_children]
    assert len(set(child_tokens)) == len(child_tokens), "duplicate sibling"
    assert child_tokens.count(top1) == 1
    assert int(first_neural[0]) == 1          # aliases the PLD-seeded node
    # ... and the confirmed node's P_acc is refreshed from the PLD prior
    # to the neural score, so best-leaf selection keeps growing the chain
    # the drafter just agreed with
    assert p_acc[0, 1] >= 0.7 - 1e-6


def test_eq5_tree_budget_monotone():
    """best_tree_expansions: deeper budgets for better acceptance, shallower
    for costlier drafts, zero when the best speedup misses t_min."""
    es_alpha = [best_tree_expansions(a, 0.3, 8, t_min=1.0)
                for a in (0.05, 0.3, 0.6, 0.9, 0.99)]
    assert es_alpha == sorted(es_alpha)
    assert es_alpha[-1] > es_alpha[0]

    es_cost = [best_tree_expansions(0.8, c, 8, t_min=1.0)
               for c in (0.02, 0.1, 0.3, 0.6, 0.95)]
    assert es_cost == sorted(es_cost, reverse=True)

    assert best_tree_expansions(0.1, 0.9, 8, t_min=1.1) == 0
    assert best_tree_expansions(0.99, 0.01, 8, t_min=1.1) > 0


def test_mode_validation():
    with pytest.raises(ValueError, match="unknown proposal mode"):
        BatchedSpecServer(CFG, {}, mode="nope")
    # attention-only guard: codebook (audio) stacks cannot run tree_fused
    audio_cfg = dataclasses.replace(CFG, num_codebooks=4)
    with pytest.raises(ValueError, match="attention-only"):
        BatchedSpecServer(audio_cfg, {}, mode="tree_fused")


def test_tree_seed_arrays_shapes_and_masks():
    pending = np.array([7, 9], np.int32)
    chains = np.array([[1, 2, 3, 0], [4, 0, 0, 0]], np.int32)
    have = np.array([3, 1], np.int32)
    tokens, parents, depth, p_acc, mask, count = tree_seed_arrays(
        pending, chains, have, bucket=8, pld_alpha=0.5
    )
    assert list(count) == [4, 2]
    assert tokens[0, 0] == 7 and list(tokens[0, 1:4]) == [1, 2, 3]
    assert list(parents[0, :4]) == [-1, 0, 1, 2]
    assert list(depth[1, :2]) == [0, 1]
    np.testing.assert_allclose(p_acc[0, :4], [1.0, 0.5, 0.25, 0.125])
    # chain closure: node i sees exactly 0..i; unused slots are self-only
    for b, n in enumerate(count):
        for i in range(n):
            assert set(np.flatnonzero(mask[b, i])) == set(range(i + 1))
        for i in range(n, 8):
            assert mask[b, i].sum() == 1 and mask[b, i, i]
            assert not mask[b, :n, i].any()
    with pytest.raises(ValueError, match="cannot hold"):
        tree_seed_arrays(pending, chains, have, bucket=4)
