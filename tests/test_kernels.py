"""Pallas kernels vs pure-jnp oracles (interpret mode), shape/dtype sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import quantized_matmul, verify_attention
from repro.kernels import ref as R
from repro.kernels.int8_matmul import quantize_cols, quantize_rows


def _mk(B, T, H, KV, hd, S, dtype, seed=0, pos=None, tree=True):
    ks = jax.random.split(jax.random.PRNGKey(seed), 8)
    q = jax.random.normal(ks[0], (B, T, H, hd), dtype)
    kc = jax.random.normal(ks[1], (B, S, KV, hd), dtype)
    vc = jax.random.normal(ks[2], (B, S, KV, hd), dtype)
    kn = jax.random.normal(ks[3], (B, T, KV, hd), dtype)
    vn = jax.random.normal(ks[4], (B, T, KV, hd), dtype)
    pos = S - 5 if pos is None else pos
    kv_pos = jnp.broadcast_to(
        jnp.where(jnp.arange(S)[None] < pos, jnp.arange(S)[None], -1).astype(jnp.int32),
        (B, S),
    )
    q_pos = (pos + jnp.arange(T))[None].repeat(B, 0).astype(jnp.int32)
    tm = np.tril(np.ones((T, T), bool))
    if tree and T >= 4:
        tm[3, 2] = False               # a branch
    tmask = jnp.broadcast_to(jnp.asarray(tm), (B, T, T))
    return q, kc, vc, kv_pos, q_pos, kn, vn, tmask


def _oracle(q, kc, vc, kv_pos, q_pos, kn, vn, tmask, **kw):
    B, T, H, hd = q.shape
    KV = kc.shape[2]
    rep = H // KV
    qr = q.reshape(B, T, KV, rep, hd).transpose(0, 2, 3, 1, 4).reshape(B, KV, rep * T, hd)
    ref = R.ref_verify_attention(
        qr, kc.transpose(0, 2, 1, 3), vc.transpose(0, 2, 1, 3),
        kv_pos, jnp.tile(q_pos, (1, rep)),
        kn.transpose(0, 2, 1, 3), vn.transpose(0, 2, 1, 3), tmask, **kw,
    )
    return ref.reshape(B, KV, rep, T, hd).transpose(0, 3, 1, 2, 4).reshape(B, T, H, hd)


@pytest.mark.parametrize(
    "B,T,H,KV,hd,S",
    [
        (1, 4, 2, 1, 32, 64),      # MQA
        (2, 8, 4, 2, 64, 128),     # GQA
        (1, 16, 8, 8, 80, 100),    # MHA, non-128 hd, ragged S
        (2, 8, 4, 4, 128, 256),
    ],
)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_verify_attention_matches_oracle(B, T, H, KV, hd, S, dtype):
    args = _mk(B, T, H, KV, hd, S, dtype)
    out = verify_attention(*args, interpret=True)
    ref = _oracle(*[a.astype(jnp.float32) if a.dtype in (jnp.bfloat16,) else a for a in args])
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32), np.asarray(ref), atol=tol, rtol=tol)


@pytest.mark.parametrize("kind,window,sink", [("window", 16, 0), ("streaming", 8, 2)])
def test_verify_attention_masked_kinds(kind, window, sink):
    args = _mk(1, 4, 4, 2, 64, 96, jnp.float32, seed=3)
    out = verify_attention(*args, kind=kind, window=window, sink=sink, interpret=True)
    ref = _oracle(*args, kind=kind, window=window, sink=sink)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5, rtol=2e-5)


def test_verify_attention_empty_cache():
    """pos=0 (nothing committed): only the tree part contributes."""
    args = _mk(1, 4, 2, 2, 32, 64, jnp.float32, pos=0)
    out = verify_attention(*args, interpret=True)
    ref = _oracle(*args)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("M,K,N", [(8, 16, 8), (100, 200, 300), (128, 128, 128), (1, 512, 64)])
def test_int8_matmul_matches_oracle(M, K, N):
    k1, k2 = jax.random.split(jax.random.PRNGKey(1))
    x = jax.random.normal(k1, (M, K))
    w = jax.random.normal(k2, (K, N))
    out = quantized_matmul(x, w, interpret=True)
    xq, xs = quantize_rows(x)
    wq, ws = quantize_cols(w)
    ref = R.ref_int8_matmul(xq, wq, xs, ws)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-4, rtol=1e-4)
    # and the quantization error vs f32 is small
    rel = float(jnp.mean(jnp.abs(out - x @ w)) / jnp.mean(jnp.abs(x @ w)))
    assert rel < 0.05
