"""Pallas kernels vs pure-jnp oracles (interpret mode), shape/dtype sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import quantized_matmul, verify_attention
from repro.kernels import ref as R
from repro.kernels.int8_matmul import quantize_cols, quantize_rows


def _mk(B, T, H, KV, hd, S, dtype, seed=0, pos=None, tree=True):
    ks = jax.random.split(jax.random.PRNGKey(seed), 8)
    q = jax.random.normal(ks[0], (B, T, H, hd), dtype)
    kc = jax.random.normal(ks[1], (B, S, KV, hd), dtype)
    vc = jax.random.normal(ks[2], (B, S, KV, hd), dtype)
    kn = jax.random.normal(ks[3], (B, T, KV, hd), dtype)
    vn = jax.random.normal(ks[4], (B, T, KV, hd), dtype)
    pos = S - 5 if pos is None else pos
    kv_pos = jnp.broadcast_to(
        jnp.where(jnp.arange(S)[None] < pos, jnp.arange(S)[None], -1).astype(jnp.int32),
        (B, S),
    )
    q_pos = (pos + jnp.arange(T))[None].repeat(B, 0).astype(jnp.int32)
    tm = np.tril(np.ones((T, T), bool))
    if tree and T >= 4:
        tm[3, 2] = False               # a branch
    tmask = jnp.broadcast_to(jnp.asarray(tm), (B, T, T))
    return q, kc, vc, kv_pos, q_pos, kn, vn, tmask


def _oracle(q, kc, vc, kv_pos, q_pos, kn, vn, tmask, **kw):
    B, T, H, hd = q.shape
    KV = kc.shape[2]
    rep = H // KV
    qr = q.reshape(B, T, KV, rep, hd).transpose(0, 2, 3, 1, 4).reshape(B, KV, rep * T, hd)
    ref = R.ref_verify_attention(
        qr, kc.transpose(0, 2, 1, 3), vc.transpose(0, 2, 1, 3),
        kv_pos, jnp.tile(q_pos, (1, rep)),
        kn.transpose(0, 2, 1, 3), vn.transpose(0, 2, 1, 3), tmask, **kw,
    )
    return ref.reshape(B, KV, rep, T, hd).transpose(0, 3, 1, 2, 4).reshape(B, T, H, hd)


@pytest.mark.parametrize(
    "B,T,H,KV,hd,S",
    [
        (1, 4, 2, 1, 32, 64),      # MQA
        (2, 8, 4, 2, 64, 128),     # GQA
        (1, 16, 8, 8, 80, 100),    # MHA, non-128 hd, ragged S
        (2, 8, 4, 4, 128, 256),
    ],
)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_verify_attention_matches_oracle(B, T, H, KV, hd, S, dtype):
    args = _mk(B, T, H, KV, hd, S, dtype)
    out = verify_attention(*args, interpret=True)
    ref = _oracle(*[a.astype(jnp.float32) if a.dtype in (jnp.bfloat16,) else a for a in args])
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32), np.asarray(ref), atol=tol, rtol=tol)


@pytest.mark.parametrize("kind,window,sink", [("window", 16, 0), ("streaming", 8, 2)])
def test_verify_attention_masked_kinds(kind, window, sink):
    args = _mk(1, 4, 4, 2, 64, 96, jnp.float32, seed=3)
    out = verify_attention(*args, kind=kind, window=window, sink=sink, interpret=True)
    ref = _oracle(*args, kind=kind, window=window, sink=sink)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5, rtol=2e-5)


def test_verify_attention_empty_cache():
    """pos=0 (nothing committed): only the tree part contributes."""
    args = _mk(1, 4, 2, 2, 32, 64, jnp.float32, pos=0)
    out = verify_attention(*args, interpret=True)
    ref = _oracle(*args)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5, rtol=2e-5)


# ---------------------------------------------------------------------------
# invalid-position masking property tests (satellite of the paged cache):
# the page gather relies ENTIRELY on the kv_pos = -1 contract to hide
# unallocated pages and partially-filled tails — these pin that contract on
# flash_decode_partial itself against the dense oracle.
# ---------------------------------------------------------------------------
from repro.kernels.flash_decode import (       # noqa: E402
    flash_decode_paged_partial, flash_decode_partial,
)


def _norm(acc, m, l):
    """Normalize flash partials to a full softmax (no staged half)."""
    return acc / jnp.maximum(l[..., None], 1e-30)


def _dense_oracle(q, k, v, kv_pos, q_pos, *, kind="causal", window=0, sink=0):
    """Full-softmax reference over the cache only (f32)."""
    s = jnp.einsum("bgrh,bgsh->bgrs", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * (q.shape[-1] ** -0.5)
    qp = q_pos[:, None, :, None]
    kp = kv_pos[:, None, None, :]
    valid = (kp >= 0) & (kp <= qp)
    if kind == "window":
        valid &= kp > qp - window
    elif kind == "streaming":
        valid &= (kp < sink) | (kp > qp - window)
    s = jnp.where(valid, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bgrs,bgsh->bgrh", p, v.astype(jnp.float32))


def _mk_partial(B, KV, R_, hd, S, seed, pos):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (B, KV, R_, hd), jnp.float32)
    k = jax.random.normal(ks[1], (B, KV, S, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, KV, S, hd), jnp.float32)
    kv_pos = jnp.where(
        jnp.arange(S)[None] < np.asarray(pos)[:, None],
        jnp.arange(S)[None], -1,
    ).astype(jnp.int32)
    q_pos = (np.asarray(pos)[:, None]
             + np.arange(R_)[None]).astype(np.int32)
    return q, k, v, kv_pos, jnp.asarray(q_pos)


@pytest.mark.parametrize("pos", [[0, 1], [5, 64], [37, 13]])
def test_flash_decode_invalid_rows_inert(pos):
    """Property: kv_pos=-1 slots NEVER contribute — poisoning their K/V
    with huge values must not change any query row that has at least one
    valid slot (bitwise: the poisoned lanes hit -inf before the softmax
    either way). A row with ZERO valid slots keeps garbage in its raw
    partials BY DESIGN: its ``m`` comes back as the -inf sentinel, which
    zeroes the whole cache half in the downstream logsumexp merge (the
    staged half always sees its own diagonal) — the exact contract the
    paged gather relies on for unallocated pages."""
    B, KV, R_, hd, S = 2, 2, 4, 64, 64
    q, k, v, kv_pos, q_pos = _mk_partial(B, KV, R_, hd, S, 7, pos)
    acc0, m0, l0 = flash_decode_partial(q, k, v, kv_pos, q_pos, block_s=32)
    bad = jnp.where((kv_pos < 0)[:, None, :, None], 1e4, 0.0)
    acc1, m1, l1 = flash_decode_partial(
        q, k + bad, v + bad, kv_pos, q_pos, block_s=32)
    has_valid = (jnp.asarray(pos) > 0)[:, None, None]   # any committed slot
    assert bool(jnp.all(jnp.where(has_valid, m0 == m1, True)))
    assert bool(jnp.all(jnp.where(has_valid, l0 == l1, True)))
    assert bool(jnp.all(jnp.where(has_valid[..., None], acc0 == acc1, True)))
    # all-invalid rows: the -inf sentinel that guarantees zero merge weight
    assert bool(jnp.all(jnp.where(~has_valid, m1 <= -1e30, True)))
    base = _norm(acc0, m0, l0)
    ref = _dense_oracle(q, k, v, kv_pos, q_pos)
    ok = np.asarray(jnp.broadcast_to(has_valid[..., None], ref.shape))
    np.testing.assert_allclose(np.asarray(base)[ok], np.asarray(ref)[ok],
                               atol=2e-5, rtol=2e-5)


def test_flash_decode_ring_wraparound():
    """Ring-buffer semantics: kv_pos carries ABSOLUTE positions that wrap
    modulo the window, so a scrambled (rolled) storage order with matching
    kv_pos must give the same output as the sorted order."""
    B, KV, R_, hd, S = 1, 2, 2, 64, 64
    window = S
    pos0 = 90                                   # wrapped: slot i holds
    ks = jax.random.split(jax.random.PRNGKey(9), 3)
    q = jax.random.normal(ks[0], (B, KV, R_, hd), jnp.float32)
    k = jax.random.normal(ks[1], (B, KV, S, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, KV, S, hd), jnp.float32)
    # ring layout: slot i holds absolute position (pos0 - window) + ...
    abs_pos = (pos0 - window + (jnp.arange(S) - pos0 % S) % S + S) % (10 * S)
    abs_pos = jnp.where(abs_pos < pos0, abs_pos, -1).astype(jnp.int32)[None]
    q_pos = jnp.asarray([[pos0, pos0 + 1]], jnp.int32)
    out_ring = _norm(*flash_decode_partial(
        q, k, v, abs_pos, q_pos, kind="window", window=window, block_s=32))
    # sorted layout: same (position, K, V) association, rolled into order
    order = jnp.argsort(jnp.where(abs_pos[0] < 0, 10**6, abs_pos[0]))
    out_sorted = _norm(*flash_decode_partial(
        q, jnp.take(k, order, 2), jnp.take(v, order, 2),
        jnp.take(abs_pos, order, 1), q_pos,
        kind="window", window=window, block_s=32))
    np.testing.assert_allclose(np.asarray(out_ring), np.asarray(out_sorted),
                               atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("kind,window,sink",
                         [("causal", 0, 0), ("window", 24, 0),
                          ("streaming", 16, 4)])
def test_flash_decode_paged_matches_dense(kind, window, sink):
    """The paged kernel (scalar-prefetched page table in the index_maps)
    is BITWISE the dense kernel on the gathered view — including a
    scrambled table, an unallocated (-1) tail and a partial tail page."""
    B, KV, R_, hd, P, n_pp = 2, 2, 4, 64, 16, 4
    S = n_pp * P
    NP = B * n_pp + 2
    rng = np.random.default_rng(3)
    perm = rng.permutation(NP)
    tbl = np.full((B, n_pp), -1, np.int32)
    tbl[0] = perm[:n_pp]
    tbl[1, :3] = perm[n_pp:n_pp + 3]            # slot 1: unallocated tail
    pos = [S - 7, 2 * P + 5]                    # partial tail pages
    q, _, _, kv_pos, q_pos = _mk_partial(B, KV, R_, hd, S, 5, pos)
    ks = jax.random.split(jax.random.PRNGKey(11), 2)
    pool_k = jax.random.normal(ks[0], (NP, KV, P, hd), jnp.float32)
    pool_v = jax.random.normal(ks[1], (NP, KV, P, hd), jnp.float32)
    k_dense = R.ref_paged_gather(pool_k, jnp.asarray(tbl))
    v_dense = R.ref_paged_gather(pool_v, jnp.asarray(tbl))
    ap, mp, lp = flash_decode_paged_partial(
        q, pool_k, pool_v, jnp.asarray(tbl), kv_pos, q_pos,
        kind=kind, window=window, sink=sink)
    ad, md, ld = flash_decode_partial(
        q, k_dense, v_dense, kv_pos, q_pos,
        kind=kind, window=window, sink=sink, block_s=P)
    assert bool(jnp.all(ap == ad) and jnp.all(mp == md) and jnp.all(lp == ld))


@pytest.mark.parametrize("M,K,N", [(8, 16, 8), (100, 200, 300), (128, 128, 128), (1, 512, 64)])
def test_int8_matmul_matches_oracle(M, K, N):
    k1, k2 = jax.random.split(jax.random.PRNGKey(1))
    x = jax.random.normal(k1, (M, K))
    w = jax.random.normal(k2, (K, N))
    out = quantized_matmul(x, w, interpret=True)
    xq, xs = quantize_rows(x)
    wq, ws = quantize_cols(w)
    ref = R.ref_int8_matmul(xq, wq, xs, ws)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-4, rtol=1e-4)
    # and the quantization error vs f32 is small
    rel = float(jnp.mean(jnp.abs(out - x @ w)) / jnp.mean(jnp.abs(x @ w)))
    assert rel < 0.05
