"""EWIF theory (§3, App. B): closed forms, the paper's worked example,
Monte-Carlo agreement, and bound properties."""
import math

import numpy as np
import pytest
pytest.importorskip("hypothesis", reason="needs hypothesis — pip install -r requirements-dev.txt")
from hypothesis import given, settings, strategies as st

from repro.core import ewif


def test_paper_worked_example():
    """§4.2: greedy always picks M_d2 -> 1.554; HC(M_d1,M_d2) -> 1.615."""
    v_greedy, k = ewif.best_sd(0.8, 0.3)
    assert abs(v_greedy - 1.554) < 2e-3
    assert k == 3
    v_hc = ewif.t_hc(0.9, 0.8, 0.4, 0.3, 2, 2)
    assert abs(v_hc - 1.615) < 2e-3
    # the HC schedule beats the greedy schedule, as the paper argues
    assert v_hc > v_greedy


def test_t_sd_limits():
    # k=0 degenerates to AR (factor 1)
    assert ewif.t_sd(0.5, 0.3, 0) == pytest.approx(1.0)
    # perfect acceptance, free draft -> k+1 tokens per verify
    assert ewif.t_sd(1.0, 0.0, 7) == pytest.approx(8.0)


@given(
    alpha=st.floats(0.05, 0.95),
    c=st.floats(0.01, 0.9),
    k=st.integers(1, 12),
)
@settings(max_examples=60, deadline=None)
def test_mc_agrees_with_closed_form(alpha, c, k):
    closed = ewif.t_sd(alpha, c, k)
    mc = ewif.simulate_ewif_sd(alpha, c, k, steps=40_000, seed=1)
    assert mc == pytest.approx(closed, rel=0.05)


@given(alpha=st.floats(0.1, 0.9), c=st.floats(0.02, 0.5))
@settings(max_examples=30, deadline=None)
def test_expected_accepted_monotone_in_alpha(alpha, c):
    lo = ewif.expected_accepted(alpha * 0.9, 5)
    hi = ewif.expected_accepted(alpha, 5)
    assert hi >= lo


def test_hc_bound_monotone_in_alpha_d1():
    """Higher intermediate-draft acceptance tolerates a higher cost (Fig 1c)."""
    bounds = [
        ewif.hc_bound_c_d1_numeric(a, 0.4, 0.01, k_max=10) for a in (0.5, 0.7, 0.9)
    ]
    assert bounds[0] <= bounds[1] <= bounds[2]


def test_vc_bound_positive_region():
    b = ewif.vc_bound_c_d1_numeric(0.8, 0.5, 0.5, 0.01, n_max=4, k_max=8)
    assert 0.0 < b < 1.0


def test_dytc_objective_prefers_cheap_high_alpha():
    good = ewif.dytc_step_objective(0.9, 0.2, 3, 0.3, 0.01)
    bad = ewif.dytc_step_objective(0.4, 0.6, 3, 0.3, 0.01)
    assert good > bad


def test_greedy_vs_admissible_counterexample():
    """The Eq.-5 objective must NOT always agree with the greedy objective
    (that disagreement is DyTC's entire point)."""
    a1, c1, a2, c2 = 0.9, 0.4, 0.8, 0.3
    g1 = ewif.greedy_step_objective(a1, c1, 1)
    g2 = ewif.greedy_step_objective(a2, c2, 1)
    assert g2 > g1            # greedy prefers M_d2
    o1 = max(ewif.dytc_step_objective(a1, c1, k, 0.3, 0.01) for k in range(1, 6))
    o2 = max(ewif.dytc_step_objective(a2, c2, k, 0.3, 0.01) for k in range(1, 6))
    # the admissible objective ranks them differently or at least closer
    assert (o1 > o2) or abs(o1 - o2) / max(o1, o2) < abs(g1 - g2) / max(g1, g2)
