"""Fixture: host-sync hazards reachable from a round root (REPRO001).

`chain_round` is a lint root by name; the hazards live two call-graph hops
down so the test also exercises the reachability walk."""
import jax
import jax.numpy as jnp
import numpy as np


def leaf_helper(x):
    n = x.item()                      # REPRO001: .item() host sync
    arr = np.asarray(x)               # REPRO001: host materialization
    return n + int(arr[0])            # REPRO001: int() on indexed value


def mid_helper(x):
    jax.block_until_ready(x)          # REPRO001: pipeline stall
    return leaf_helper(x)


def chain_round(params, cache, toks):
    y = jnp.cumsum(toks)
    f = float(jnp.max(y))             # REPRO001: float() on jnp result
    return mid_helper(y), f
