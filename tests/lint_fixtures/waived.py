"""Fixture: waiver syntax. One justified waiver (silenced), one bare waiver
(reported as REPRO000), one unrelated-rule waiver (finding still reported)."""
import time

import jax
import jax.numpy as jnp

decode = jax.jit(lambda p, x: jnp.dot(p, x))


def startup_banner():
    return time.time()  # repro: noqa-REPRO005: wall-clock wanted for log timestamps


def bare_waiver():
    return time.time()  # repro: noqa-REPRO005


def wrong_rule():
    return time.time()  # repro: noqa-REPRO001: misattributed waiver
