"""Fixture: use-after-donate (REPRO002).

`step_bad` reads `cache` after handing it to a call whose argument
position 1 is donated; `step_ok` rebinds it in the same statement (the
pattern the server uses) and must NOT be flagged."""
import jax


def _round(params, cache, state):
    return cache, state


round_fn = jax.jit(_round, donate_argnums=(1, 2))


def step_bad(params, cache, state):
    new_cache, new_state = round_fn(params, cache, state)
    leak = cache["pos"]               # REPRO002: cache was donated above
    return new_cache, new_state, leak


def step_ok(params, cache, state):
    cache, state = round_fn(params, cache, state)
    return cache, state, cache["pos"]     # fine: rebound by the call itself
