"""Fixture: impure scan/while/cond bodies (REPRO004)."""
import time

import jax
import jax.numpy as jnp
import numpy as np


class Tracker:
    def run(self, xs):
        def body(carry, x):
            print("step", x)                  # REPRO004: host side effect
            self.count = self.count + 1       # REPRO004: self mutation
            t = time.perf_counter()           # REPRO004: trace-time only
            h = np.asarray(x)                 # REPRO004: numpy on a tracer
            return carry + x, (t, h)

        return jax.lax.scan(body, jnp.zeros(()), xs)

    def spin(self, x):
        def cond(c):
            return c[0] < 4

        def step(c):
            global COUNTER                    # REPRO004: global mutation
            return (c[0] + 1, c[1].item())    # REPRO004: .item() on tracer

        return jax.lax.while_loop(cond, step, (0, x))
