"""Fixture: recompilation hazards (REPRO003)."""
import jax
import jax.numpy as jnp


def serve_loop(params, batches):
    outs = []
    for b in batches:
        fn = jax.jit(lambda p, x: jnp.dot(p, x))   # REPRO003: jit in loop
        outs.append(fn(params, b))
    return outs


def one_shot(params, x):
    # REPRO003: constructed-and-called — a fresh executable every call
    return jax.jit(lambda p, v: p @ v)(params, x)
