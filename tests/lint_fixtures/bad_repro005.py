"""Fixture: timing hygiene (REPRO005)."""
import time

import jax
import jax.numpy as jnp

decode = jax.jit(lambda p, x: jnp.dot(p, x))


def bench_wall_clock(params, x):
    t0 = time.time()                          # REPRO005: non-monotonic clock
    y = decode(params, x)
    return y, time.time() - t0                # REPRO005 (same)


def bench_unsynced(params, x):
    t0 = time.perf_counter()
    y = decode(params, x)
    dt = time.perf_counter() - t0             # REPRO005: no block_until_ready
    return y, dt


def bench_ok(params, x):
    t0 = time.perf_counter()
    y = jax.block_until_ready(decode(params, x))
    dt = time.perf_counter() - t0             # fine: device work settled
    return y, dt
