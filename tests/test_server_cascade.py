"""Multi-level DSIA draft cascade in the batched server (`cascade_fused`):
losslessness vs the B=1 AR reference, bounded dispatches per round (one per
cascade level + one target verify), Eq. 5 multi-level routing collapse,
draft-bank materialization, and the level-to-level rescore semantics."""
import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import get_config
from repro.core.cascade import ARScheduler
from repro.core.dsia import (
    activation_quant,
    build_hierarchy,
    layer_sparsity,
    streaming_attention,
)
from repro.core.engine import SpecEngine, cascade_rescore
from repro.core.ewif import t_cascade, t_sd
from repro.core.latency import best_cascade_plan
from repro.core.tree import tree_seed_arrays
from repro.models import model as M
from repro.serving.draft_bank import DraftBank
from repro.serving.server import BatchedSpecServer

CFG = dataclasses.replace(get_config("vicuna-7b").reduced(), num_layers=4)
PARAMS = M.init_params(CFG, jax.random.PRNGKey(0))
HIER = build_hierarchy(CFG, "mixing")      # LS + LS+int8 + PLD


def _random_prompts(n, length, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(4, CFG.vocab_size - 1, size=length).astype(np.int32)
            for _ in range(n)]


def _repetitive_prompts():
    return [
        np.array([5, 6, 7, 8] * 4, np.int32),
        np.array([9, 10, 11] * 5, np.int32),
    ]


def _assert_matches_ar(srv, prompts, rounds):
    for i, p in enumerate(prompts):
        srv.add_request(i, p)
    gen = {i: [] for i in range(len(prompts))}
    for _ in range(rounds):
        for b, toks in srv.step().items():
            gen[b].extend(toks)
    for i, p in enumerate(prompts):
        eng = SpecEngine(CFG, PARAMS, max_len=256)
        eng.start(p)
        ref = ARScheduler(eng).generate(len(gen[i]))
        assert ref == gen[i], f"slot {i} diverged"
    return gen


# ------------------------------------------------------------- losslessness
def test_cascade_fused_matches_single_stream():
    """cascade_fused with the default mixing hierarchy (layer-sparsity level
    + int8 activation-quant level) must emit exactly the B=1 greedy stream
    for every slot."""
    srv = BatchedSpecServer(CFG, PARAMS, max_batch=2, max_len=256, draft_k=4,
                            mode="cascade_fused", adaptive=True, min_obs=1)
    # the acceptance-criteria hierarchy shape: >= 2 executable levels, one
    # gates-only and one int8
    assert len(srv.bank) >= 2
    assert any(l.quantize == "int8" or l.owns_params for l in srv.bank.levels)
    assert any(l.gates is not None and not l.owns_params and l.quantize is None
               for l in srv.bank.levels)
    _assert_matches_ar(srv, _repetitive_prompts(), rounds=8)


def test_cascade_fused_lossless_random_prompts():
    """High-entropy prompts keep PLD silent: every node is neural (drafted
    by the cheapest level, rescored by the stronger one) and the committed
    output must still be token-identical to AR."""
    srv = BatchedSpecServer(CFG, PARAMS, max_batch=2, max_len=256, draft_k=4,
                            mode="cascade_fused", adaptive=False)
    _assert_matches_ar(srv, _random_prompts(2, 16, seed=3), rounds=6)


def test_cascade_fused_scaling_hierarchy_lossless():
    """A pure layer-sparsity (scaling) hierarchy is lossless too — the
    invariant holds for every hierarchy mode."""
    srv = BatchedSpecServer(CFG, PARAMS, max_batch=2, max_len=256, draft_k=4,
                            mode="cascade_fused", adaptive=False,
                            hierarchy=build_hierarchy(CFG, "scaling"))
    _assert_matches_ar(srv, _repetitive_prompts(), rounds=6)


# ------------------------------------------------------- dispatch discipline
def test_bounded_dispatches_per_round():
    """Per round: ONE drafting scan + ONE rescore per stronger level, with
    the target verify riding the LAST rescore dispatch — never more,
    regardless of per-slot routing."""
    srv = BatchedSpecServer(CFG, PARAMS, max_batch=2, max_len=256, draft_k=4,
                            mode="cascade_fused", adaptive=False)
    n_levels = len(srv.bank)
    for i, p in enumerate(_random_prompts(2, 24)):
        srv.add_request(i, p)
    n_rounds = 5
    for _ in range(n_rounds):
        srv.step()
    assert srv.stats["draft_dispatches"] == n_rounds
    assert srv.stats["rescore_dispatches"] == n_rounds * (n_levels - 1)
    assert srv.stats["target_calls"] == n_rounds
    assert len(srv._casc_draft_fns) == 1      # fixed budget -> one compile
    # bounded compile caches: one executable per rescoring level (the
    # strongest level's carries the folded target verify)
    assert (len(srv._rescore_fns) + len(srv._rescore_verify_fns)
            == n_levels - 1)


def test_cascade_budget_collapses_to_pld_only():
    """An unmeetable t_min drives the Eq. 5 plan to PLD-only: no drafting
    scan, no rescore — and the output stays lossless (plain AR inside the
    same batched verify)."""
    srv = BatchedSpecServer(CFG, PARAMS, max_batch=2, max_len=256, draft_k=4,
                            mode="cascade_fused", adaptive=True, min_obs=1,
                            t_min=1e9)
    _assert_matches_ar(srv, _random_prompts(2, 16, seed=5), rounds=6)
    exp, use_rescore, _, _ = srv._slot_cascade_plan(0)
    assert exp == 0 and not use_rescore
    # once every slot is warmed up, rounds stop dispatching neural work
    d0, r0 = srv.stats["draft_dispatches"], srv.stats["rescore_dispatches"]
    srv.step()
    assert srv.stats["draft_dispatches"] == d0
    assert srv.stats["rescore_dispatches"] == r0


def test_single_level_hierarchy_still_adapts():
    """A 1-level hierarchy has no rescorer, so slot_key(0) is fed through
    the single-level (direct) observation path — the warm-up gate must not
    starve and the PLD-only collapse must still engage."""
    hier = [layer_sparsity(CFG, 0.5), build_hierarchy(CFG, "mixing")[-1]]
    srv = BatchedSpecServer(CFG, PARAMS, max_batch=1, max_len=256, draft_k=4,
                            mode="cascade_fused", adaptive=True, min_obs=1,
                            t_min=1e9, hierarchy=hier)
    assert len(srv.bank) == 1
    srv.add_request(0, _random_prompts(1, 16, seed=9)[0])
    for _ in range(4):
        srv.step()
    exp, use_rescore, _, _ = srv._slot_cascade_plan(0)
    assert exp == 0 and not use_rescore
    d0 = srv.stats["draft_dispatches"]
    srv.step()
    assert srv.stats["draft_dispatches"] == d0
    assert srv.stats["rescore_dispatches"] == 0


def test_cascade_plan_routes_single_level():
    """When the rescorer's own acceptance is no better than the cheap
    level's direct acceptance, the plan drops the rescore dispatch."""
    # strong level adds nothing (alpha_direct == product) but costs c0
    exp, use_rescore = best_cascade_plan(
        [0.9, 0.9], [0.5, 0.1], alpha_direct=0.81, e_max=6, t_min=1.0
    )
    assert exp > 0 and not use_rescore
    # a cheap strong level with high acceptance over a weak drafter: rescore
    exp2, use2 = best_cascade_plan(
        [0.95, 0.5], [0.05, 0.04], alpha_direct=0.3, e_max=6, t_min=1.0
    )
    assert exp2 > 0 and use2
    # nothing pays -> PLD-only
    assert best_cascade_plan([0.05, 0.05], [0.9, 0.9], 0.01, 6, 1.05) == (0, False)


def test_t_cascade_degenerates_to_t_sd():
    for a, c, k in [(0.7, 0.3, 4), (0.9, 0.1, 5), (0.2, 0.8, 3)]:
        assert t_cascade([a], [c], k) == pytest.approx(t_sd(a, c, k))
    with pytest.raises(ValueError):
        t_cascade([0.5], [0.1, 0.2], 3)


# ------------------------------------------------------------ spec handling
def test_unsupported_spec_fields_raise():
    """Gates-only modes must refuse quantize/attn_override specs instead of
    silently dropping them (they used to run gates-only)."""
    q_spec = activation_quant(CFG, 8, base=layer_sparsity(CFG, 0.5))
    for mode in ("chain_fused", "legacy", "tree_fused"):
        with pytest.raises(ValueError, match="cannot honor"):
            BatchedSpecServer(CFG, PARAMS, mode=mode, draft_spec=q_spec)
    sa_spec = streaming_attention(CFG, window=64)
    with pytest.raises(ValueError, match="attn_override"):
        BatchedSpecServer(CFG, PARAMS, mode="chain_fused", draft_spec=sa_spec)
    # plain gates specs stay accepted everywhere
    BatchedSpecServer(CFG, PARAMS, mode="tree_fused",
                      draft_spec=layer_sparsity(CFG, 0.5))


def test_cascade_mode_arg_validation():
    with pytest.raises(ValueError, match="hierarchy"):
        BatchedSpecServer(CFG, PARAMS, mode="cascade_fused",
                          draft_spec=layer_sparsity(CFG, 0.5))
    with pytest.raises(ValueError, match="cascade_fused"):
        BatchedSpecServer(CFG, PARAMS, mode="tree_fused", hierarchy=HIER)
    audio_cfg = dataclasses.replace(CFG, num_codebooks=4)
    with pytest.raises(ValueError, match="attention-only"):
        BatchedSpecServer(audio_cfg, PARAMS, mode="cascade_fused")


# --------------------------------------------------------------- draft bank
def test_draft_bank_materialization_sim_vs_kernel():
    bank_sim = DraftBank(CFG, PARAMS, HIER, int8_exec="sim")
    assert len(bank_sim) == 2
    strong, cheap = bank_sim.levels
    assert strong.gates is not None and not strong.owns_params
    assert strong.params is PARAMS            # gates-only levels share params
    assert cheap.owns_params and cheap.quantize is None
    assert cheap.params is not PARAMS         # one materialized int8 copy
    assert bank_sim.param_bytes > 0
    # the copy is actually fake-quantized
    w0 = jax.tree.leaves(PARAMS["segments"][0])[0]
    wq = jax.tree.leaves(cheap.params["segments"][0])[0]
    assert not np.allclose(np.asarray(w0), np.asarray(wq))

    bank_k = DraftBank(CFG, PARAMS, HIER, int8_exec="kernel")
    cheap_k = bank_k.levels[-1]
    assert cheap_k.quantize == "int8" and not cheap_k.owns_params
    assert cheap_k.params is PARAMS           # dynamic in-kernel quantization
    assert bank_k.param_bytes == 0

    with pytest.raises(ValueError, match="int8_exec"):
        DraftBank(CFG, PARAMS, HIER, int8_exec="gpu")
    with pytest.raises(ValueError, match="no neural level"):
        DraftBank(CFG, PARAMS, [HIER[-1]])


def test_draft_bank_priors_and_keys():
    bank = DraftBank(CFG, PARAMS, HIER, int8_exec="sim")
    assert bank.slot_key(0, 3) != bank.slot_key(1, 3)
    assert bank.slot_key(0, 0) != bank.slot_key(0, 1)
    # level-to-level prior >= the cheap level's target-facing prior
    assert bank.alpha_prior(1) >= bank.levels[1].spec.prior_alpha
    assert 0 < bank.direct_prior() <= bank.alpha_prior(0)
    assert bank.rescorers == [bank.levels[0]]
    assert bank.drafter is bank.levels[-1]


# ------------------------------------------------------- rescore semantics
def test_cascade_rescore_hedges_and_extends():
    """Level-to-level acceptance on a real (tiny) model: the rescored tree
    is a SUPERSET of the drafted tree (hedging, not overwriting), with this
    level's own continuation added as a sibling at the first mismatch and
    as a child of the deepest endorsed node."""
    rng = np.random.default_rng(7)
    prompt = rng.integers(2, CFG.vocab_size, size=12).astype(np.int32)
    cache = M.init_cache(CFG, 1, 64)
    last, cache = M.prefill(CFG, PARAMS, {"tokens": jnp.asarray(prompt[None])}, cache)
    pending = np.argmax(np.asarray(last), -1).astype(np.int32)
    # a 3-token chain the level will (almost surely) disagree with
    chains = rng.integers(2, CFG.vocab_size, size=(1, 4)).astype(np.int32)
    have = np.array([3], np.int32)
    seed = tree_seed_arrays(pending, chains, have, bucket=8)
    gates = jnp.asarray(layer_sparsity(CFG, 0.5).gates_array(CFG.num_layers))
    fn = jax.jit(functools.partial(cascade_rescore, CFG))
    out = fn(PARAMS, cache, *(jnp.asarray(a) for a in seed),
             jnp.asarray([1], jnp.int32),           # probe: first chain node
             jnp.asarray([True]),
             jnp.asarray([0.7], jnp.float32),
             gates)
    (tokens, parents, depth, p_acc, mask, count,
     level_node, probe_ok, probe_valid) = (np.asarray(a) for a in out)
    # the level's own argmax along the chain, for reference
    lg, _ = M.decode_step(CFG, PARAMS, cache, jnp.asarray(seed[0]),
                          gates=gates, tree_mask=jnp.asarray(seed[4]),
                          q_pos=cache["pos"][:, None] + jnp.asarray(seed[2]))
    nxt = np.argmax(np.asarray(lg)[0], -1)
    assert bool(probe_valid[0])                     # parent is the root
    agrees = int(chains[0, 0]) == int(nxt[0])
    assert bool(probe_ok[0]) == agrees
    # superset: every drafted node survives verbatim
    n0 = int(seed[5][0])
    np.testing.assert_array_equal(tokens[0, :n0], seed[0][0, :n0])
    np.testing.assert_array_equal(parents[0, :n0], seed[1][0, :n0])
    assert int(count[0]) >= n0
    if not agrees:
        # a hedge sibling of node 1 carries the level's root continuation
        # (and doubles as the frontier extension — root is the frontier)
        hedge = [i for i in range(n0, count[0])
                 if parents[0, i] == 0 and tokens[0, i] == int(nxt[0])]
        assert len(hedge) == 1
        assert int(level_node[0]) == hedge[0]
        assert int(depth[0, hedge[0]]) == 1
        # the hedge node sees exactly the root and itself
        assert set(np.flatnonzero(mask[0, hedge[0]])) == {0, hedge[0]}
    # apply=False slots pass through untouched
    out2 = fn(PARAMS, cache, *(jnp.asarray(a) for a in seed),
              jnp.asarray([1], jnp.int32), jnp.asarray([False]),
              jnp.asarray([0.7], jnp.float32), gates)
    np.testing.assert_array_equal(np.asarray(out2[0]), seed[0])
    np.testing.assert_array_equal(np.asarray(out2[1]), seed[1])
    assert int(np.asarray(out2[5])[0]) == int(seed[5][0])
    assert not bool(np.asarray(out2[8])[0])         # probe invalid when off


def test_cascade_and_tree_modes_agree_on_prefix():
    """Both modes are lossless, so their greedy streams must agree token
    for token on the shared prefix."""
    outs = []
    for mode, kw in (("cascade_fused", {}),
                     ("tree_fused", {"draft_spec": layer_sparsity(CFG, 0.5)})):
        srv = BatchedSpecServer(CFG, PARAMS, max_batch=2, max_len=256,
                                draft_k=4, mode=mode, adaptive=False, **kw)
        for i, p in enumerate(_repetitive_prompts()):
            srv.add_request(i, p)
        gen = {0: [], 1: []}
        for _ in range(6):
            for b, toks in srv.step().items():
                gen[b].extend(toks)
        outs.append(gen)
    for b in (0, 1):
        n = min(len(outs[0][b]), len(outs[1][b]))
        assert n > 0 and outs[0][b][:n] == outs[1][b][:n]
