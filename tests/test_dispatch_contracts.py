"""Compiled-HLO dispatch contracts for all four server modes
(``analysis.contracts`` over ``BatchedSpecServer.round_executables``):

  - chain/tree single rounds are exactly ONE executable, with the donated
    cache + carried state lowered to real ``input_output_alias`` entries
    and the draft/expansion scans surviving at their known trip counts;
  - the cascade round stays within L executables (<= L+1 bound of §4.1);
  - NO executable of any round re-enters the host (callbacks, infeed/
    outfeed) — and a round body with a deliberately injected host sync
    FAILS the checker;
  - the static executable counts agree with the runtime
    ``round_dispatches``/``draft_dispatches``/``rescore_dispatches``
    counters, so the compiled claims and the observed counters can't
    drift apart.
"""
import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.contracts import (
    ContractViolation,
    HloContract,
    assert_telemetry_transparent,
    server_round_contracts,
)
from repro.config import get_config
from repro.core.dsia import layer_sparsity
from repro.models import model as M
from repro.serving.sampler import SamplingParams
from repro.serving.server import BatchedSpecServer

CFG = dataclasses.replace(get_config("vicuna-7b").reduced(), num_layers=3)
PARAMS = M.init_params(CFG, jax.random.PRNGKey(0))
SPEC = layer_sparsity(CFG, 0.5)
DRAFT_K = 4
EXPANSIONS = 5


def _server(mode, **kw):
    kwargs = dict(max_batch=2, max_len=128, draft_k=DRAFT_K,
                  tree_expansions=EXPANSIONS, adaptive=False, donate=True)
    if mode != "cascade_fused":
        kwargs["draft_spec"] = SPEC
    kwargs.update(kw)
    return BatchedSpecServer(CFG, PARAMS, mode=mode, **kwargs)


# --------------------------------------------------- single-dispatch rounds
@pytest.mark.parametrize("mode,trip", [("chain_fused", DRAFT_K),
                                       ("tree_fused", EXPANSIONS)])
def test_single_round_is_one_donated_executable(mode, trip):
    """THE tentpole contract: a single-mode round is ONE executable whose
    donated cache/state lowered to real aliasing, whose draft scan kept its
    trip count, and whose body never re-enters the host."""
    srv = _server(mode, round_mode="single")
    cons = server_round_contracts(srv)
    assert srv.expected_dispatches_per_round() == 1
    assert set(cons) == {"round"}
    con = cons["round"]
    # cache + dstate donation became input_output_alias entries (one per
    # donated leaf — at minimum the KV segments, pos, and carried state)
    con.assert_donated(at_least=3)
    con.assert_no_host_callbacks()
    con.assert_trip_count(trip)                # the fused draft scan
    con.assert_trip_count(CFG.num_layers)      # the layer-stack scan


@pytest.mark.parametrize("mode", ["chain_fused", "tree_fused"])
def test_single_round_donation_off_is_alias_free(mode):
    """Negative control: donate=False must lower WITHOUT aliasing — the
    checker distinguishes real donation from its absence."""
    srv = _server(mode, round_mode="single", donate=False)
    server_round_contracts(srv)["round"].assert_not_donated()


# ------------------------------------------------------------- split rounds
def test_split_round_contracts():
    srv = _server("chain_fused", round_mode="split")
    cons = server_round_contracts(srv)
    assert len(cons) == srv.expected_dispatches_per_round() == 2
    cons["chain_draft"].assert_no_host_callbacks().assert_trip_count(DRAFT_K)
    cons["verify"].assert_donated(at_least=1).assert_no_host_callbacks()


def test_legacy_round_contracts():
    srv = _server("legacy")
    cons = server_round_contracts(srv)
    # legacy re-dispatches ONE decode executable per draft step: distinct
    # executables stay at 2 while dispatches/round go to draft_k + 1
    assert srv.expected_dispatches_per_round() == DRAFT_K + 1
    assert len(cons) == 2
    for con in cons.values():
        con.assert_no_host_callbacks()


# ----------------------------------------------------------- cascade rounds
def test_cascade_round_within_levels_plus_one():
    srv = _server("cascade_fused")
    L = len(srv.bank)
    assert L >= 2
    cons = server_round_contracts(srv)
    assert len(cons) == srv.expected_dispatches_per_round() == max(L, 2)
    assert len(cons) <= L + 1                  # the §4.1 dispatch bound
    for con in cons.values():
        con.assert_no_host_callbacks()
    # the LAST rescore carries the folded target verify + donated commit
    cons["rescore_verify"].assert_donated(at_least=1)
    cons["cascade_draft"].assert_not_donated()
    cons["cascade_draft"].assert_trip_count(EXPANSIONS)


# ------------------------------------------------- telemetry transparency
@pytest.mark.parametrize("mode,kw", [
    ("chain_fused", {"round_mode": "single"}),
    ("chain_fused", {"round_mode": "split"}),
    ("tree_fused", {"round_mode": "single"}),
    ("cascade_fused", {}),
])
def test_telemetry_is_dispatch_transparent(mode, kw):
    """Turning telemetry ON must not change the compiled round story: same
    executables, same scan trip counts, no host callbacks, and donation
    aliasing no weaker than the telemetry-off lowering (the buffer rides
    existing dispatches — it never adds one)."""
    off = server_round_contracts(_server(mode, telemetry=False, **kw))
    srv_on = _server(mode, **kw)
    on = server_round_contracts(srv_on)
    assert_telemetry_transparent(off, on)
    assert srv_on.expected_dispatches_per_round() == \
        _server(mode, telemetry=False, **kw).expected_dispatches_per_round()


def test_legacy_telemetry_transparent():
    off = server_round_contracts(_server("legacy", telemetry=False))
    on = server_round_contracts(_server("legacy"))
    assert_telemetry_transparent(off, on)


# ---------------------------------------------- injected host sync must fail
def test_injected_host_sync_fails_contract():
    """The acceptance gate: fold a deliberate host re-entry into the round
    body — the SAME lowering pipeline must now flunk the checker. (The
    round body carries the telemetry buffer — telemetry defaults on — so
    the leaky wrappers use the telemetry-on signature.)"""
    srv = _server("chain_fused", round_mode="single")
    inner = srv._round_fn.__wrapped__           # the un-jitted round body
    _, args = srv.round_executables()["round"]

    def leaky(params, cache, dstate, telem, c, gates):
        cache, dstate, telem, out = inner(params, cache, dstate, telem, c, gates)
        jax.debug.print("n_acc={n}", n=out["n_acc"])   # deliberate host sync
        return cache, dstate, telem, out

    con = HloContract.from_jitted(jax.jit(leaky), *args, name="leaky-round")
    assert con.host_callbacks                    # the callback IS in the HLO
    with pytest.raises(ContractViolation, match="callback"):
        con.assert_no_host_callbacks()

    def leaky2(params, cache, dstate, telem, c, gates):
        cache, dstate, telem, out = inner(params, cache, dstate, telem, c, gates)
        n = jax.pure_callback(
            lambda x: np.asarray(x), jax.ShapeDtypeStruct((2,), jnp.int32),
            out["n_acc"],
        )
        return cache, dstate, telem, dict(out, n_acc=n)

    con2 = HloContract.from_jitted(jax.jit(leaky2), *args, name="leaky2")
    with pytest.raises(ContractViolation):
        con2.assert_no_host_callbacks()


# ------------------------------------------- static vs runtime cross-check
def test_static_contract_matches_runtime_counters():
    """The compiled executable count and the runtime dispatch counters
    must tell the same story (per round, after warm-up)."""
    srv = _server("chain_fused", round_mode="single", sync_every=2)
    n = srv.expected_dispatches_per_round()
    assert len(server_round_contracts(srv)) == n == 1
    for i, p in enumerate([np.array([5, 6, 7, 8] * 4, np.int32),
                           np.array([9, 10, 11] * 5, np.int32)]):
        srv.add_request(i, p)
    rounds = 4
    for _ in range(rounds):
        srv.step()
    srv.flush()
    assert srv.stats["round_dispatches"] == rounds * n
    assert srv.stats["host_syncs"] == rounds // 2   # sync_every=2 drains only
    if hasattr(srv._round_fn, "_cache_size"):
        assert srv._round_fn._cache_size() == 1


def test_cascade_static_matches_runtime_counters():
    srv = _server("cascade_fused")
    n = srv.expected_dispatches_per_round()
    assert len(server_round_contracts(srv)) == n
    rng = np.random.default_rng(0)
    for i in range(2):
        srv.add_request(i, rng.integers(4, CFG.vocab_size - 1,
                                        size=24).astype(np.int32))
    rounds = 3
    for _ in range(rounds):
        srv.step()
    dispatches = (srv.stats["draft_dispatches"]
                  + srv.stats["rescore_dispatches"])
    assert dispatches == rounds * n
    assert srv.stats["target_calls"] == rounds     # folded, still counted


# ----------------------------------------------------- sampled-build rounds
SAMPLED = SamplingParams(temperature=0.8, top_k=20, top_p=0.9, seed=1)


@pytest.mark.parametrize("mode,trip", [("chain_fused", DRAFT_K),
                                       ("tree_fused", EXPANSIONS)])
def test_sampled_single_round_keeps_the_contract(mode, trip):
    """Stochastic verify must not cost a dispatch: the sampled single-mode
    round is STILL one donated executable with the same scan trip counts
    and no host re-entry — the PRNG split and acceptance draws are fused
    into the round body, never round-tripped through the host."""
    srv = _server(mode, round_mode="single", sampling=SAMPLED)
    cons = server_round_contracts(srv)
    assert srv.expected_dispatches_per_round() == 1
    assert set(cons) == {"round"}
    con = cons["round"]
    con.assert_donated(at_least=3)
    con.assert_no_host_callbacks()
    con.assert_trip_count(trip)
    con.assert_trip_count(CFG.num_layers)


def test_sampled_split_round_keeps_the_contract():
    srv = _server("chain_fused", round_mode="split", sampling=SAMPLED)
    cons = server_round_contracts(srv)
    assert len(cons) == srv.expected_dispatches_per_round() == 2
    cons["chain_draft"].assert_no_host_callbacks().assert_trip_count(DRAFT_K)
    cons["verify"].assert_donated(at_least=1).assert_no_host_callbacks()


def test_sampled_cascade_round_keeps_the_contract():
    srv = _server("cascade_fused", sampling=SAMPLED)
    L = len(srv.bank)
    cons = server_round_contracts(srv)
    assert len(cons) == srv.expected_dispatches_per_round() == max(L, 2)
    assert len(cons) <= L + 1
    for con in cons.values():
        con.assert_no_host_callbacks()
    cons["rescore_verify"].assert_donated(at_least=1)


@pytest.mark.parametrize("mode,kw", [
    ("chain_fused", {"round_mode": "single"}),
    ("cascade_fused", {}),
])
def test_sampled_telemetry_is_dispatch_transparent(mode, kw):
    """Telemetry transparency holds on sampled builds too: same executables,
    trip counts, and no-weaker donation with the buffer on."""
    off = server_round_contracts(
        _server(mode, telemetry=False, sampling=SAMPLED, **kw)
    )
    on = server_round_contracts(_server(mode, sampling=SAMPLED, **kw))
    assert_telemetry_transparent(off, on)


# -------------------------------------------------------- parser edge cases
def test_alias_parser_handles_nested_tuple_indices():
    """input_output_alias nests {tuple,index} braces inside the outer map —
    a naive regex truncates at the first '}' and undercounts."""
    hdr = ("HloModule jit_f, input_output_alias={ {0}: (1, {}, may-alias), "
           "{1, 2}: (3, {0}, must-alias) }, entry_computation_layout=...")
    con = HloContract("synthetic", hdr)
    assert con.alias_count == 2
    assert con.donated_params == (1, 3)
    con.assert_donated(1, 3, at_least=2)


def test_contract_assertions_raise_with_context():
    con = HloContract("empty", "HloModule jit_f\nENTRY %main () -> f32[] {}")
    with pytest.raises(ContractViolation, match=r"\[empty\].*donation"):
        con.assert_donated()
    with pytest.raises(ContractViolation, match="known_trip_count=7"):
        con.assert_trip_count(7)
    con.assert_not_donated().assert_no_host_callbacks()
