"""PLD semantics + host/device parity (no hypothesis dependency).

Pins the documented ``core.pld`` semantics directly — "never propose the
suffix itself, must have a continuation" — against a brute-force reference,
and uses the host implementation as the exact-parity oracle for the
vectorized device path the single-dispatch serving round traces in.
"""
import numpy as np
import jax.numpy as jnp

from repro.core.pld import PromptLookup, propose_device


# ------------------------------------------------------------ host semantics
def _brute_force(ctx, k, max_ngram=4, min_ngram=1):
    """Reference: longest suffix n-gram, most recent admissible occurrence,
    continuation cropped before the suffix start."""
    ctx = list(ctx)
    n = len(ctx)
    for ng in range(min(max_ngram, n - 1), min_ngram - 1, -1):
        suffix = ctx[n - ng:]
        best = None
        for s in range(0, n - 1 - ng + 1):
            if ctx[s:s + ng] == suffix and s + 2 * ng < n:
                best = s
        if best is not None:
            cont = ctx[best + ng : min(best + ng + k, n - ng)]
            return cont, ng
    return [], 0


def test_never_proposes_the_suffix_itself():
    """The only earlier occurrence overlaps the suffix region — proposing
    its continuation would re-propose suffix tokens, so PLD must fall back
    to a shorter n-gram whose continuation lies strictly before it."""
    pld = PromptLookup(max_ngram=3)
    out = pld.propose(np.array([1, 2, 3, 1, 2, 3], np.int64), 3)
    # 3-gram [1,2,3] at s=0 has no admissible continuation (it would start
    # at the suffix); the 2-gram [2,3] at s=1 continues with [1]
    assert list(out) == [1]


def test_must_have_a_continuation():
    pld = PromptLookup(max_ngram=2)
    assert list(pld.propose(np.array([4, 5, 4, 5], np.int64), 4)) == [4]
    # no earlier occurrence at all -> nothing proposed
    assert len(pld.propose(np.array([1, 2, 3, 4, 5], np.int64), 4)) == 0


def test_continuation_cropped_at_suffix_start():
    """A long continuation stops at the suffix start, not at k."""
    pld = PromptLookup(max_ngram=2)
    #          [7,8] -> 1, 2, 3   then the suffix [7,8] again
    ctx = np.array([7, 8, 1, 2, 3, 7, 8], np.int64)
    out = pld.propose(ctx, 10)
    assert list(out) == [1, 2, 3]


def test_confidence_scales_with_ngram():
    pld = PromptLookup(max_ngram=4)
    ctx = np.array([7, 8, 1, 0, 5, 6, 7, 8, 2, 0, 5, 6, 7, 8], np.int64)
    toks, conf = pld.propose_with_confidence(ctx, 1)
    assert list(toks) == [2] and conf == 1.0          # 4-gram match
    toks, conf = pld.propose_with_confidence(np.array([4, 5, 4, 5], np.int64), 1)
    assert conf == 0.25                               # 1-gram fallback


def test_host_matches_brute_force():
    """The numpy implementation equals the O(n^2) reference on random
    low-entropy streams (where matches are plentiful) for every k."""
    rng = np.random.default_rng(0)
    pld = PromptLookup(max_ngram=4)
    for _ in range(300):
        n = int(rng.integers(2, 40))
        ctx = rng.integers(0, 5, size=n)
        k = int(rng.integers(1, 7))
        got, conf = pld.propose_with_confidence(ctx, k)
        want, ng = _brute_force(ctx, k)
        assert list(got) == list(want), (list(ctx), k)
        if want:
            assert conf == ng / pld.max_ngram


# ------------------------------------------------------------- device parity
def _device_batch(ctxs, k, L=64, max_ngram=4, min_ngram=1):
    B = len(ctxs)
    buf = np.zeros((B, L), np.int32)
    length = np.zeros((B,), np.int32)
    for b, c in enumerate(ctxs):
        buf[b, : len(c)] = c
        length[b] = len(c)
    chains, have = propose_device(
        jnp.asarray(buf), jnp.asarray(length), k,
        max_ngram=max_ngram, min_ngram=min_ngram,
    )
    return np.asarray(chains), np.asarray(have)


def test_device_matches_host_random():
    """Exact parity: the batched jnp window-compare equals the host loop on
    random streams of mixed lengths and entropies."""
    rng = np.random.default_rng(1)
    pld = PromptLookup(max_ngram=4)
    for vocab in (3, 5, 50):
        ctxs = [rng.integers(0, vocab, size=int(rng.integers(2, 60)))
                for _ in range(32)]
        k = 5
        chains, have = _device_batch(ctxs, k)
        for b, ctx in enumerate(ctxs):
            want = pld.propose(ctx, k)
            assert have[b] == len(want), (list(ctx),)
            assert list(chains[b, : have[b]]) == list(want)
            assert (chains[b, have[b]:] == 0).all()   # zero-padded tail


def test_device_matches_host_edge_lengths():
    """Tiny contexts (n <= min_ngram) and exact-boundary overlaps."""
    cases = [
        [1], [1, 1], [1, 2], [2, 2, 2], [1, 2, 3, 1, 2, 3],
        [4, 5, 4, 5], [9] * 12, list(range(8)) + list(range(8)),
    ]
    pld = PromptLookup(max_ngram=4)
    chains, have = _device_batch(cases, 4)
    for b, ctx in enumerate(cases):
        want = pld.propose(np.asarray(ctx), 4)
        assert have[b] == len(want) and list(chains[b, : have[b]]) == list(want)


def test_device_pld_is_jittable():
    import jax

    fn = jax.jit(lambda c, n: propose_device(c, n, 4))
    # suffix [6,7] recurs at s=1 with continuation [5] (the 3-gram match at
    # s=0 is inadmissible: its continuation would be the suffix itself)
    ctx = jnp.asarray(np.array([[5, 6, 7, 5, 6, 7, 0, 0]], np.int32))
    chains, have = fn(ctx, jnp.asarray([6], jnp.int32))
    assert int(have[0]) == 1 and int(chains[0, 0]) == 5
