"""The dispatch-discipline lint (``analysis.lint``): every REPRO00x rule
must trip on its fixture snippet, the safe idioms in the fixtures must NOT
be flagged, waivers need justifications, and — the self-scan gate — the
repo's own ``src/repro`` tree must be clean."""
import os
import subprocess
import sys

import pytest

from repro.analysis.lint import DEFAULT_ROOTS, RULES, run_paths

HERE = os.path.dirname(__file__)
FIXTURES = os.path.join(HERE, "lint_fixtures")
SRC_REPRO = os.path.join(HERE, "..", "src", "repro")


def _fixture(name):
    return os.path.join(FIXTURES, name)


def _rules_and_lines(findings):
    return {(f.rule, f.line) for f in findings}


# ------------------------------------------------------------ rule coverage
@pytest.mark.parametrize("rule", ["REPRO001", "REPRO002", "REPRO003",
                                  "REPRO004", "REPRO005"])
def test_each_rule_trips_on_its_fixture(rule):
    findings = run_paths([_fixture(f"bad_{rule.lower()}.py")])
    assert findings, f"{rule} fixture produced no findings"
    assert {f.rule for f in findings} == {rule}


def test_repro001_reaches_through_the_call_graph():
    """Hazards two hops below `chain_round` are found (the reachability
    walk), and each finding names the function it was reached through."""
    findings = run_paths([_fixture("bad_repro001.py")])
    assert len(findings) == 5
    assert any("leaf_helper" in f.msg for f in findings)
    assert any(".item()" in f.msg for f in findings)


def test_repro001_not_flagged_outside_reachable_set():
    """The same hazards in a function NOT reachable from a root are not
    REPRO001 findings — the rule is scoped to the round/scan hot paths."""
    findings = run_paths([_fixture("bad_repro001.py")],
                         roots=["nonexistent_root"])
    assert not [f for f in findings if f.rule == "REPRO001"]


def test_repro002_accepts_same_statement_rebind():
    """`cache, state = round_fn(params, cache, state)` — the server's
    donate idiom — must pass; reading the stale name afterwards must not."""
    findings = run_paths([_fixture("bad_repro002.py")])
    assert len(findings) == 1
    assert findings[0].line == 18            # the read in step_bad only


def test_repro004_catches_each_impurity():
    findings = run_paths([_fixture("bad_repro004.py")])
    msgs = " | ".join(f.msg for f in findings)
    for needle in ("host side effect", "self state", "trace time",
                   "tracer", "global/nonlocal", ".item()"):
        assert needle in msgs, f"missing REPRO004 case: {needle}"


def test_repro005_unsynced_timing_but_not_synced():
    findings = run_paths([_fixture("bad_repro005.py")])
    lines = _rules_and_lines(findings)
    assert ("REPRO005", 19) in lines         # bench_unsynced delta
    # bench_ok's block_until_ready-guarded delta is clean
    assert not any(line > 20 for _, line in lines)


# ------------------------------------------------------------------ waivers
def test_waivers_require_justification():
    findings = run_paths([_fixture("waived.py")])
    rules = [f.rule for f in findings]
    # justified waiver silenced its finding; bare waiver -> REPRO000 AND
    # the finding stays; wrong-rule waiver does not silence anything
    assert rules.count("REPRO000") == 1
    assert rules.count("REPRO005") == 2
    assert not any(f.line == 12 for f in findings)     # justified: silenced


# ------------------------------------------------------------ self-scan gate
def test_src_repro_is_clean():
    """THE gate: the repo's own serving/engine/analysis tree passes its own
    dispatch-discipline rules. A new host sync, use-after-donate, or
    wall-clock timer in src/repro fails this test."""
    findings = run_paths([SRC_REPRO])
    assert not findings, "\n" + "\n".join(f.render() for f in findings)


# ---------------------------------------------------------------------- CLI
def test_cli_exit_codes_and_output():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(HERE, "..", "src")
    ok = subprocess.run(
        [sys.executable, "-m", "repro.analysis.lint", SRC_REPRO],
        capture_output=True, text=True, env=env,
    )
    assert ok.returncode == 0, ok.stdout + ok.stderr
    bad = subprocess.run(
        [sys.executable, "-m", "repro.analysis.lint",
         _fixture("bad_repro003.py")],
        capture_output=True, text=True, env=env,
    )
    assert bad.returncode == 1
    assert "REPRO003" in bad.stdout
    listing = subprocess.run(
        [sys.executable, "-m", "repro.analysis.lint", "--list-rules"],
        capture_output=True, text=True, env=env,
    )
    assert listing.returncode == 0
    for rule in RULES:
        assert rule in listing.stdout


def test_default_roots_cover_the_engine_entrypoints():
    for root in ("chain_round", "tree_round", "cascade_rescore",
                 "chain_draft_scan", "tree_draft_scan"):
        assert root in DEFAULT_ROOTS
