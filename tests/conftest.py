import os
import sys

# NOTE: no --xla_force_host_platform_device_count here — smoke tests and
# benches must see the single real CPU device (dry-run sets its own flags).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
