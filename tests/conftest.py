import os
import sys
import warnings

# NOTE: no --xla_force_host_platform_device_count here — smoke tests and
# benches must see the single real CPU device (dry-run sets its own flags).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np
import pytest

# Property-based test modules guard their import with
# ``pytest.importorskip("hypothesis", ...)`` so a missing dev dependency
# skips them (with a reason) instead of killing collection for the whole
# suite. Surface one loud session-level warning here so the skip cause is
# obvious in the run header.
try:
    import hypothesis  # noqa: F401
except ImportError:
    warnings.warn(
        "hypothesis is not installed — property-based test modules will be "
        "SKIPPED. Install dev deps with: pip install -r requirements-dev.txt",
        stacklevel=0,
    )


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
