"""Prompt Lookup Decoding: retrieval correctness properties."""
import numpy as np
import pytest
pytest.importorskip("hypothesis", reason="needs hypothesis — pip install -r requirements-dev.txt")
from hypothesis import given, settings, strategies as st

from repro.core.pld import PromptLookup


def test_basic_repeat():
    pld = PromptLookup(max_ngram=3)
    ctx = np.array([1, 2, 3, 9, 9, 1, 2, 3], np.int32)
    out = pld.propose(ctx, 3)
    # suffix [1,2,3] matched at position 0; continuation is [9,9,...]
    assert list(out[:2]) == [9, 9]


def test_no_match():
    pld = PromptLookup()
    out = pld.propose(np.array([1, 2, 3, 4, 5], np.int32), 4)
    assert len(out) == 0


def test_prefers_longest_ngram():
    pld = PromptLookup(max_ngram=4)
    #       [7,8] -> 1   ...   [5,6,7,8] -> 2
    ctx = np.array([7, 8, 1, 0, 5, 6, 7, 8, 2, 0, 5, 6, 7, 8], np.int32)
    out = pld.propose(ctx, 1)
    assert list(out) == [2]     # 4-gram match wins over 2-gram


@given(
    data=st.lists(st.integers(0, 6), min_size=8, max_size=60),
    k=st.integers(1, 6),
)
@settings(max_examples=80, deadline=None)
def test_proposal_is_a_real_continuation(data, k):
    """Whatever PLD proposes must literally appear after a matching n-gram
    occurrence inside the context (retrieval soundness)."""
    pld = PromptLookup(max_ngram=4)
    ctx = np.asarray(data, np.int32)
    toks, conf = pld.propose_with_confidence(ctx, k)
    if len(toks) == 0:
        return
    assert 0 < conf <= 1.0
    n = len(ctx)
    found = False
    for ng in range(pld.max_ngram, 0, -1):
        if ng >= n:
            continue
        suffix = list(ctx[n - ng:])
        for s in range(0, n - ng):
            if list(ctx[s : s + ng]) == suffix:
                cont = list(ctx[s + ng : s + ng + len(toks)])
                if cont == list(toks):
                    found = True
    assert found
