"""Attention execution paths agree: chunk-scan vs split-KV decode, windowed
chunk-skipping vs dense reference, ring-buffer caches."""
import subprocess
import sys
import os
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis", reason="needs hypothesis — pip install -r requirements-dev.txt")
from hypothesis import given, settings, strategies as st

from repro.models.attention import blockwise_attention, decode_attention


def dense_ref(q, k, v, q_pos, kv_pos, kind="causal", window=0, sink=0):
    B, S, H, hd = q.shape
    rep = H // k.shape[2]
    kx = jnp.repeat(k, rep, 2)
    vx = jnp.repeat(v, rep, 2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q * hd ** -0.5, kx)
    qq = q_pos[:, None]
    kk = kv_pos[None, :]
    m = (kk >= 0) & (kk <= qq)
    if kind == "window":
        m &= kk > qq - window
    elif kind == "streaming":
        m &= (kk < sink) | (kk > qq - window)
    s = jnp.where(m[None, None], s, -1e30)
    return jnp.einsum("bhqk,bkhd->bqhd", jax.nn.softmax(s, -1), vx)


@pytest.mark.parametrize("kind,window", [("causal", 0), ("window", 48), ("window", 130)])
@pytest.mark.parametrize("chunks", [(32, 32), (64, 128)])
def test_blockwise_matches_dense(kind, window, chunks):
    cq, ck = chunks
    B, S, H, KV, hd = 2, 300, 4, 2, 32
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, KV, hd))
    v = jax.random.normal(ks[2], (B, S, KV, hd))
    pos = jnp.arange(S, dtype=jnp.int32)
    out = blockwise_attention(q, k, v, pos, pos, kind=kind, window=window,
                              chunk_q=cq, chunk_kv=ck)
    ref = dense_ref(q, k, v, pos, pos, kind=kind, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5, rtol=2e-5)


@given(
    pos=st.integers(1, 60),
    T=st.sampled_from([1, 4, 8]),
    window=st.sampled_from([0, 16]),
)
@settings(max_examples=12, deadline=None)
def test_decode_matches_dense_ref(pos, T, window):
    B, H, KV, hd, S = 2, 4, 2, 16, 64
    ks = jax.random.split(jax.random.PRNGKey(pos), 5)
    q = jax.random.normal(ks[0], (B, T, H, hd))
    kc = jax.random.normal(ks[1], (B, S, KV, hd))
    vc = jax.random.normal(ks[2], (B, S, KV, hd))
    kn = jax.random.normal(ks[3], (B, T, KV, hd))
    vn = jax.random.normal(ks[4], (B, T, KV, hd))
    kind = "window" if window else "causal"
    cp = jnp.full((B,), pos, jnp.int32)
    qpos = cp[:, None] + jnp.arange(T)[None]
    out = decode_attention(q, kc, vc, cp, kn, vn, qpos, kind=kind, window=window)
    # dense: concat cache (masked by pos) and staged
    kv_pos = jnp.where(jnp.arange(S) < pos, jnp.arange(S), -1)
    kall = jnp.concatenate([kc, kn], 1)
    vall = jnp.concatenate([vc, vn], 1)
    pall = jnp.concatenate([kv_pos, qpos[0]])
    ref = dense_ref(q, kall, vall, qpos[0], pall, kind=kind, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=3e-5, rtol=3e-5)


SPLIT_KV_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from repro.models.attention import decode_attention
    from repro.launch.mesh import make_mesh_compat, set_global_mesh
    mesh = make_mesh_compat((2, 4), ("data", "model"))
    set_global_mesh(mesh)
    B, T, H, KV, hd, S = 4, 8, 8, 2, 32, 64
    ks = jax.random.split(jax.random.PRNGKey(0), 8)
    q = jax.random.normal(ks[0], (B, T, H, hd))
    kc = jax.random.normal(ks[1], (B, S, KV, hd))
    vc = jax.random.normal(ks[2], (B, S, KV, hd))
    kn = jax.random.normal(ks[3], (B, T, KV, hd))
    vn = jax.random.normal(ks[4], (B, T, KV, hd))
    pos = jnp.full((B,), 50, jnp.int32)
    qpos = pos[:, None] + jnp.arange(T)[None]
    tm = jnp.asarray(np.tril(np.ones((T, T), bool)))
    for axes in [("model",), ("data", "model")]:
        a = jax.jit(lambda *x: decode_attention(*x, tree_mask=tm, seq_axes=axes))(
            q, kc, vc, pos, kn, vn, qpos)
        b = jax.jit(lambda *x: decode_attention(*x, tree_mask=tm))(
            q, kc, vc, pos, kn, vn, qpos)
        d = float(jnp.max(jnp.abs(a - b)))
        assert d < 1e-5, (axes, d)
    print("OK")
    """
)


@pytest.mark.slow
def test_split_kv_matches_scan_on_mesh():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", SPLIT_KV_SCRIPT],
                         capture_output=True, text=True, env=env, timeout=540)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "OK" in out.stdout
