"""EMA acceptance tracker (Eq. 4) + BLR latency model."""
import numpy as np
import pytest
pytest.importorskip("hypothesis", reason="needs hypothesis — pip install -r requirements-dev.txt")
from hypothesis import given, settings, strategies as st

from repro.core.acceptance import AcceptanceTracker
from repro.core.latency import BayesianLinearLatency, CostTracker, roofline_features


def test_ema_update_matches_eq4():
    t = AcceptanceTracker(lam=0.7, window=20, prior=0.5)
    t.set_prior("x", 0.8)
    t.observe("x", True)
    # recent = 1.0 -> a = 0.7*0.8 + 0.3*1.0
    assert t.alpha("x") == pytest.approx(0.7 * 0.8 + 0.3 * 1.0)
    t.observe("x", False)
    # recent = 0.5 over the 2-entry window
    prev = 0.7 * 0.8 + 0.3
    assert t.alpha("x") == pytest.approx(0.7 * prev + 0.3 * 0.5)


def test_window_limits_history():
    t = AcceptanceTracker(window=5)
    for _ in range(50):
        t.observe("x", False)
    for _ in range(5):
        t.observe("x", True)
    # recent window is all-True now
    assert t.alpha("x") > 0.2
    assert t.counts("x") == 5


@given(st.lists(st.booleans(), min_size=1, max_size=60))
@settings(max_examples=50, deadline=None)
def test_alpha_always_in_unit_interval(outcomes):
    t = AcceptanceTracker()
    for o in outcomes:
        t.observe("c", o)
        assert 0.0 <= t.alpha("c") <= 1.0


def test_blr_recovers_linear_model():
    rng = np.random.default_rng(0)
    w_true = np.array([0.5, 2.0, 1.0, 3.0])
    blr = BayesianLinearLatency(dim=4, noise=1e-4)
    for _ in range(200):
        x = np.concatenate([[1.0], rng.random(3)])
        blr.observe(x, float(w_true @ x) + rng.normal(0, 1e-3))
    assert np.allclose(blr.weights, w_true, atol=0.05)
    mean, var = blr.predict_with_var([1.0, 0.5, 0.5, 0.5])
    assert var > 0


def test_roofline_features_units():
    f = roofline_features(197e12, 819e9, 50e9)
    assert f[1] == pytest.approx(1.0)   # one second of compute
    assert f[2] == pytest.approx(1.0)
    assert f[3] == pytest.approx(1.0)


def test_cost_tracker_ratio():
    c = CostTracker()
    c.observe_target(0.1, tokens=1)
    c.observe("d", 0.03, tokens=1)
    assert c.c_hat("d") == pytest.approx(0.3, rel=0.05)
