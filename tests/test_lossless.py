"""THE paper invariant: every scheduler's greedy output is token-identical
to autoregressive decoding — lossless acceleration (§5.1)."""
import dataclasses

import jax
import numpy as np
import pytest
pytest.importorskip("hypothesis", reason="needs hypothesis — pip install -r requirements-dev.txt")
from hypothesis import given, settings, strategies as st

from repro.config import get_config
from repro.core.cascade import (
    ARScheduler,
    HCScheduler,
    PLDScheduler,
    SDScheduler,
    TreeScheduler,
    TreeVCScheduler,
    VCHCScheduler,
    VCScheduler,
)
from repro.core.dsia import build_hierarchy, layer_sparsity, early_exit, streaming_attention
from repro.core.dytc import DyTCScheduler
from repro.core.engine import SpecEngine
from repro.models import model as M

CFG = dataclasses.replace(get_config("vicuna-7b").reduced(), num_layers=8)
PARAMS = M.init_params(CFG, jax.random.PRNGKey(0))
N_TOK = 24


def ar_reference(prompt):
    eng = SpecEngine(CFG, PARAMS, max_len=256)
    eng.start(prompt)
    return ARScheduler(eng).generate(N_TOK)


def run_sched(prompt, builder):
    eng = SpecEngine(CFG, PARAMS, max_len=256)
    eng.start(prompt)
    return builder(eng).generate(N_TOK), eng


PROMPT = np.array([5, 6, 7, 8, 9, 5, 6, 7, 8, 9, 5, 6, 7], np.int32)
LS4 = layer_sparsity(CFG, 0.4)
LS6 = layer_sparsity(CFG, 0.6)

SCHEDULERS = {
    "PLD": lambda e: PLDScheduler(e, k=6),
    "SD-LS": lambda e: SDScheduler(e, LS4, k=4),
    "SD-EE": lambda e: SDScheduler(e, early_exit(CFG, 0.5), k=4),
    "VC": lambda e: VCScheduler(e, LS4, n=2, k2=5),
    "HC": lambda e: HCScheduler(e, LS4, k1=3, k2=4),
    "VC+HC": lambda e: VCHCScheduler(e, LS4),
    "Tree": lambda e: TreeScheduler(e, LS4, depth=3),
    "Tr+VC": lambda e: TreeVCScheduler(e, LS4, depth=3),
    "DyTC": lambda e: DyTCScheduler(e, build_hierarchy(CFG)),
    "DyTC-mask": None,  # filled below
}


def _dytc_mask(e):
    return DyTCScheduler(e, build_hierarchy(CFG))


@pytest.mark.parametrize("name", [k for k in SCHEDULERS if SCHEDULERS[k]])
def test_scheduler_lossless(name):
    ref = ar_reference(PROMPT)
    out, eng = run_sched(PROMPT, SCHEDULERS[name])
    assert out == ref, f"{name} diverged from AR"
    assert eng.stats["rounds"] <= N_TOK   # never worse than AR in rounds


def test_mask_exec_lossless():
    """gates-as-input (mask) execution must match slice execution."""
    ref = ar_reference(PROMPT)
    eng = SpecEngine(CFG, PARAMS, max_len=256, draft_exec="mask")
    eng.start(PROMPT)
    out = DyTCScheduler(eng, build_hierarchy(CFG)).generate(N_TOK)
    assert out == ref


def test_streaming_dsia_lossless():
    """Efficient-attention drafting changes only the DRAFTS, never the output."""
    ref = ar_reference(PROMPT)
    eng = SpecEngine(CFG, PARAMS, max_len=256, draft_exec="mask")
    eng.start(PROMPT)
    spec = streaming_attention(CFG, window=8, sink=2)
    out = SDScheduler(eng, spec, k=4).generate(N_TOK)
    assert out == ref


@given(
    seed=st.integers(0, 10_000),
    plen=st.integers(4, 24),
    rep=st.integers(1, 4),
)
@settings(max_examples=8, deadline=None)
def test_dytc_lossless_random_prompts(seed, plen, rep):
    rng = np.random.default_rng(seed)
    base = rng.integers(2, CFG.vocab_size, size=plen)
    prompt = np.tile(base, rep).astype(np.int32)[:48]
    ref = ar_reference(prompt)
    out, _ = run_sched(prompt, SCHEDULERS["DyTC"])
    assert out == ref


def test_dytc_accepts_more_than_ar():
    """On a repetitive prompt, DyTC must average > 1 token per round."""
    out, eng = run_sched(PROMPT, SCHEDULERS["DyTC"])
    assert eng.stats["accepted_tokens"] / eng.stats["rounds"] > 1.1


@given(seed=st.integers(0, 10_000), plen=st.integers(4, 20))
@settings(max_examples=4, deadline=None)
def test_server_tree_fused_lossless(seed, plen):
    """The batched ``tree_fused`` serving mode is lossless: greedy output is
    token-identical to AR decoding for every slot, on arbitrary prompts."""
    from repro.core.dsia import layer_sparsity
    from repro.serving.server import BatchedSpecServer

    rng = np.random.default_rng(seed)
    base = rng.integers(2, CFG.vocab_size, size=plen)
    prompts = [
        np.tile(base, 3).astype(np.int32)[:32],
        rng.integers(2, CFG.vocab_size, size=16).astype(np.int32),
    ]
    srv = BatchedSpecServer(CFG, PARAMS, max_batch=2, max_len=256, draft_k=4,
                            draft_spec=layer_sparsity(CFG, 0.4),
                            mode="tree_fused", adaptive=True, min_obs=1)
    gen = {0: [], 1: []}
    for i, p in enumerate(prompts):
        srv.add_request(i, p)
    for _ in range(6):
        for b, toks in srv.step().items():
            gen[b].extend(toks)
    for i, p in enumerate(prompts):
        assert gen[i] == ar_reference_n(p, len(gen[i])), f"slot {i} diverged"


def ar_reference_n(prompt, n):
    eng = SpecEngine(CFG, PARAMS, max_len=256)
    eng.start(prompt)
    return ARScheduler(eng).generate(n)


@given(seed=st.integers(0, 10_000), plen=st.integers(4, 20))
@settings(max_examples=3, deadline=None)
def test_server_cascade_fused_lossless(seed, plen):
    """The batched ``cascade_fused`` mode — a ≥2-level DSIA hierarchy with
    a layer-sparsity level AND an int8 activation-quant level — is
    lossless: greedy output is token-identical to AR for every slot, on
    arbitrary prompts. Drafting/rescoring levels only change how many
    tokens a round accepts, never which tokens come out."""
    from repro.serving.server import BatchedSpecServer

    rng = np.random.default_rng(seed)
    base = rng.integers(2, CFG.vocab_size, size=plen)
    prompts = [
        np.tile(base, 3).astype(np.int32)[:32],
        rng.integers(2, CFG.vocab_size, size=16).astype(np.int32),
    ]
    srv = BatchedSpecServer(CFG, PARAMS, max_batch=2, max_len=256, draft_k=4,
                            mode="cascade_fused", adaptive=True, min_obs=1)
    assert len(srv.bank) >= 2
    gen = {0: [], 1: []}
    for i, p in enumerate(prompts):
        srv.add_request(i, p)
    for _ in range(6):
        for b, toks in srv.step().items():
            gen[b].extend(toks)
    for i, p in enumerate(prompts):
        assert gen[i] == ar_reference_n(p, len(gen[i])), f"slot {i} diverged"
