"""Perf-trajectory tooling (benchmarks/trend.py): append + compare are
what CI's bench-trend step and the BENCH_smoke.json history run on."""
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "benchmarks"))
import trend  # noqa: E402

BENCH = {
    "serve": {
        "tree": {"tokens_per_step": 4.714, "us_per_round": 200000.0},
        "tree_carry_n32": {"tokens_per_step": 4.714, "us_per_round": 180000.0},
        "tree_accept_ratio": 1.0,           # scalar entries must be skipped
    }
}


def test_serve_metrics_extracts_variants_only():
    m = trend.serve_metrics(BENCH)
    assert set(m) == {"tree", "tree_carry_n32"}
    assert m["tree"]["rounds_per_s"] == 5.0
    # accepts the serve slice directly too (artifact-shaped input)
    assert trend.serve_metrics(BENCH["serve"]) == m


def test_append_entry_builds_trajectory(tmp_path):
    path = str(tmp_path / "BENCH_smoke.json")
    trend.append_entry(path, BENCH)
    cur = {"serve": dict(BENCH["serve"], canary_failed="boom")}
    trend.append_entry(path, cur)
    with open(path) as f:
        traj = json.load(f)
    assert len(traj["entries"]) == 2
    assert traj["entries"][0]["serve"]["tree"]["tokens_per_step"] == 4.714
    assert traj["entries"][1]["canary_failed"] == "boom"
    assert "commit" in traj["entries"][0] and "utc" in traj["entries"][0]
    # a corrupt trajectory file is replaced, not a crash
    with open(path, "w") as f:
        f.write("{not json")
    trend.append_entry(path, BENCH)
    with open(path) as f:
        assert len(json.load(f)["entries"]) == 1


def test_compare_table_deltas_and_fallbacks():
    prev = {"serve": {"tree": {"tokens_per_step": 4.0, "us_per_round": 250000.0}}}
    table = trend.compare_table(prev, BENCH)
    assert "| tree |" in table and "(+17.9%)" in table     # 4.0 -> 4.714
    assert "4.00 → 5.00 (+25.0%)" in table                 # rounds/s
    # variants absent from prev render without deltas
    assert "| tree_carry_n32 | 4.714 | 5.56 |" in table
    # no previous artifact at all
    assert "deltas omitted" in trend.compare_table(None, BENCH)
    # canary failures surface in the summary
    bad = {"serve": dict(BENCH["serve"], canary_failed="ratio 0.8")}
    assert "canary tripped" in trend.compare_table(None, bad)
