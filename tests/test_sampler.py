"""Host/device sampler parity and the warp-rule regressions.

``serving.sampler.warp_probs`` (host) and ``core.verify.sampling_probs``
(device) must agree bit-for-bit on the warped target distribution — the
sampled serving stack replays device draws on the host through the host
twin, so any drift in top-k tie handling or top-p boundary semantics is a
correctness bug, not a tolerance issue. The regressions pinned here:

  - top-k ties at the kth value keep EXACTLY k tokens (stable rank — the
    pre-fix host sampler kept every tied token, i.e. > k);
  - top-p keeps a token iff the cumulative sorted mass strictly BEFORE it
    is < top_p, which equals ``searchsorted(cum, top_p, side='left') + 1``
    kept tokens even when top_p lands exactly on a cumulative boundary.
"""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.verify import sampling_probs
from repro.serving.sampler import SamplingParams, sample_token, warp_probs


def _device_probs(logits, temperature, top_k, top_p):
    B = logits.shape[0] if logits.ndim > 1 else 1
    x = jnp.asarray(np.atleast_2d(logits), jnp.float32)
    q = sampling_probs(
        x,
        jnp.full((B,), temperature, jnp.float32),
        jnp.full((B,), top_k, jnp.int32),
        jnp.full((B,), top_p, jnp.float32),
    )
    return np.asarray(q)


# ------------------------------------------------------------ SamplingParams
def test_sampling_params_greedy_flag():
    assert SamplingParams().greedy
    assert SamplingParams(temperature=0.0).greedy
    assert SamplingParams(temperature=-1.0).greedy
    assert not SamplingParams(temperature=0.7).greedy
    p = SamplingParams(temperature=0.7, top_k=40, top_p=0.9, seed=3)
    assert dataclasses.asdict(p) == {
        "temperature": 0.7, "top_k": 40, "top_p": 0.9, "seed": 3
    }


# ------------------------------------------------------- top-k tie handling
def test_top_k_ties_keep_exactly_k_tokens():
    """Regression: logits tied at the kth value must keep EXACTLY k tokens
    (lowest token indices win), never every tied token."""
    logits = np.array([0.0, 0.0, 0.0, 0.0, 1.0])
    q = warp_probs(logits, temperature=1.0, top_k=2)
    assert (q > 0).sum() == 2
    assert q[4] > 0 and q[0] > 0          # argmax + the lowest-index tie
    assert q[1] == q[2] == q[3] == 0.0


def test_top_k_all_tied():
    q = warp_probs(np.zeros(6), temperature=1.0, top_k=3)
    np.testing.assert_allclose(q, [1 / 3, 1 / 3, 1 / 3, 0, 0, 0])


def test_top_k_zero_disables():
    logits = np.array([0.3, -1.0, 2.0])
    np.testing.assert_allclose(
        warp_probs(logits, 1.0, top_k=0),
        np.exp(logits) / np.exp(logits).sum(), atol=1e-12,
    )


# -------------------------------------------------- top-p boundary semantics
def test_top_p_exact_boundary_matches_searchsorted():
    """Regression: when top_p EQUALS a cumulative mass, the kept-token count
    must be ``searchsorted(cum, top_p, side='left') + 1`` — the boundary
    token that closes the nucleus is kept, the next one is not."""
    p = np.array([0.5, 0.3, 0.2])
    logits = np.log(p)
    for top_p, want_kept in [(0.8, 2), (0.5, 1), (0.79, 2), (0.81, 3),
                             (1.0, 3), (0.2, 1)]:
        q = warp_probs(logits, temperature=1.0, top_p=top_p)
        kept = int((q > 0).sum())
        cum = np.cumsum(np.sort(p)[::-1])
        assert kept == want_kept == (
            np.searchsorted(cum, min(top_p, 1.0), side="left") + 1
            if top_p < 1.0 else len(p)
        ), (top_p, q)


def test_top_p_always_keeps_argmax():
    q = warp_probs(np.array([5.0, 0.0, -3.0]), temperature=1.0, top_p=1e-6)
    assert q[0] == 1.0 and (q > 0).sum() == 1


# ------------------------------------------------------------ greedy routing
def test_temperature_zero_is_point_mass():
    logits = np.array([0.1, 4.0, -2.0, 4.0])   # tie -> lowest index
    q = warp_probs(logits, temperature=0.0)
    np.testing.assert_array_equal(q, [0, 1, 0, 0])
    assert sample_token(logits, temperature=0.0) == 1


# --------------------------------------------------- host/device parity pins
@pytest.mark.parametrize("temperature,top_k,top_p", [
    (1.0, 0, 1.0), (0.7, 5, 1.0), (1.3, 0, 0.9), (0.8, 7, 0.85),
    (1.0, 3, 0.5), (0.0, 4, 0.9),
])
def test_host_device_warp_parity(temperature, top_k, top_p):
    rng = np.random.default_rng(11)
    V, B = 33, 6
    logits = rng.normal(size=(B, V)).astype(np.float32)
    # inject exact ties so the stable tie-break is actually exercised
    logits[:, 5] = logits[:, 9] = logits[:, 17]
    dev = _device_probs(logits, temperature, top_k, top_p)
    for b in range(B):
        host = warp_probs(logits[b], temperature, top_k, top_p)
        np.testing.assert_array_equal(dev[b] > 0, host > 0), b
        np.testing.assert_allclose(dev[b], host, atol=1e-6)


def test_device_per_slot_params_and_3d_logits():
    """One dispatch, heterogeneous per-slot params (incl. a greedy slot) —
    each row must match its own host warp."""
    rng = np.random.default_rng(3)
    B, T, V = 3, 4, 19
    logits = rng.normal(size=(B, T, V)).astype(np.float32)
    temp = np.array([0.8, 0.0, 1.2], np.float32)
    topk = np.array([4, 0, 0], np.int32)
    topp = np.array([1.0, 1.0, 0.7], np.float32)
    q = np.asarray(sampling_probs(
        jnp.asarray(logits), jnp.asarray(temp), jnp.asarray(topk),
        jnp.asarray(topp),
    ))
    assert q.shape == (B, T, V)
    for b in range(B):
        for t in range(T):
            host = warp_probs(logits[b, t], temp[b], int(topk[b]), topp[b])
            np.testing.assert_allclose(q[b, t], host, atol=1e-6)


# ---------------------------------------------------------- seeded sampling
def test_sample_token_seed_determinism():
    logits = np.random.default_rng(5).normal(size=64)
    draws = [
        sample_token(logits, temperature=0.9, top_k=10, top_p=0.95,
                     rng=np.random.default_rng(123))
        for _ in range(3)
    ]
    assert len(set(draws)) == 1
    q = warp_probs(logits, 0.9, 10, 0.95)
    assert q[draws[0]] > 0


def test_sample_token_matches_inverse_cdf_replay():
    """The host draw is the same inverse-CDF rule the device uses: replaying
    the uniform must reproduce the token exactly."""
    logits = np.random.default_rng(9).normal(size=32)
    rng = np.random.default_rng(77)
    u = np.random.default_rng(77).random()
    tok = sample_token(logits, temperature=1.1, top_p=0.8, rng=rng)
    q = warp_probs(logits, 1.1, 0, 0.8)
    cum = np.cumsum(q)
    assert tok == int(np.argmax(cum > u * cum[-1]))
