"""Training substrate: optimizer math, learnability, checkpoint roundtrip."""
import dataclasses
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import get_config
from repro.data import lm_batches, synthetic_corpus
from repro.models import model as M
from repro.training import (
    adamw_init,
    adamw_update,
    cosine_lr,
    load_checkpoint,
    make_train_step,
    save_checkpoint,
)

CFG = dataclasses.replace(get_config("vicuna-7b").reduced(), num_layers=4)


def test_cosine_schedule_shape():
    assert float(cosine_lr(jnp.asarray(0), peak=1.0, warmup=10, total=100)) == 0.0
    assert float(cosine_lr(jnp.asarray(10), peak=1.0, warmup=10, total=100)) == pytest.approx(1.0, rel=1e-2)
    end = float(cosine_lr(jnp.asarray(100), peak=1.0, warmup=10, total=100, floor=0.1))
    assert end == pytest.approx(0.1, rel=1e-2)


def test_adamw_single_quadratic():
    """AdamW minimizes a quadratic."""
    params = {"w": jnp.asarray([4.0, -3.0])}
    opt = adamw_init(params)
    for _ in range(300):
        g = {"w": 2 * params["w"]}
        params, opt = adamw_update(params, g, opt, lr=jnp.asarray(0.05), weight_decay=0.0)
    assert float(jnp.abs(params["w"]).max()) < 0.05


def test_grad_clip_bounds_update():
    params = {"w": jnp.zeros(3)}
    opt = adamw_init(params)
    g = {"w": jnp.asarray([1e6, 0.0, 0.0])}
    p2, _ = adamw_update(params, g, opt, lr=jnp.asarray(1.0), grad_clip=1.0, weight_decay=0.0)
    assert float(jnp.abs(p2["w"]).max()) < 1.5


def test_training_learns():
    params = M.init_params(CFG, jax.random.PRNGKey(0))
    opt = adamw_init(params)
    step = jax.jit(make_train_step(CFG, peak_lr=1e-3, warmup=10, total_steps=200, remat=False))
    corpus = synthetic_corpus(CFG.vocab_size, 20_000)
    it = lm_batches(corpus, 8, 64)
    first = last = None
    for i in range(30):
        b = {k: jnp.asarray(v) for k, v in next(it).items()}
        params, opt, m = step(params, opt, b)
        if i == 0:
            first = float(m["ce"])
        last = float(m["ce"])
    assert last < first - 0.3


def test_checkpoint_roundtrip():
    params = M.init_params(CFG, jax.random.PRNGKey(0))
    opt = adamw_init(params)
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, params, opt, step=7)
        p2, o2, s = load_checkpoint(d, params, opt)
    assert s == 7
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for a, b in zip(jax.tree.leaves(opt), jax.tree.leaves(o2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_loss_mask():
    from repro.training import loss_fn

    params = M.init_params(CFG, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, CFG.vocab_size)
    full, _ = loss_fn(CFG, params, {"tokens": toks}, remat=False)
    mask = jnp.zeros((2, 15)).at[:, :5].set(1.0)
    masked, _ = loss_fn(CFG, params, {"tokens": toks, "loss_mask": mask}, remat=False)
    assert float(full) != pytest.approx(float(masked))
