"""Zero-sync serving telemetry (docs/observability.md): registry/exporter
unit coverage plus the end-to-end invariants the design promises —

  - every accepted token the device tallies is accounted for host-side:
    ``accepted == delivered + overshoot + unrouted + discarded + leftover``
    (the reconciliation identity), in all four proposal modes;
  - overshoot tokens trimmed at retire are EXCLUDED from per-request
    token counts and TPOT;
  - telemetry on vs off changes NO runtime dispatch/sync counter
    (the buffer rides existing executables — the static side of the same
    claim lives in test_dispatch_contracts.py);
  - the Prometheus text rendering, /metrics endpoint, Chrome trace JSON
    and JSONL sink are well-formed.
"""
import dataclasses
import json
import urllib.request

import jax
import numpy as np
import pytest

from repro.config import get_config
from repro.core.dsia import layer_sparsity
from repro.models import model as M
from repro.serving.exporters import JsonlSink, MetricsHTTPServer
from repro.serving.scheduler import Request, RequestScheduler, ServeLoop
from repro.serving.server import BatchedSpecServer
from repro.serving.telemetry import (
    Histogram,
    MetricsRegistry,
    StatsView,
    TraceRecorder,
)

CFG = dataclasses.replace(get_config("vicuna-7b").reduced(), num_layers=3)
PARAMS = M.init_params(CFG, jax.random.PRNGKey(0))
SPEC = layer_sparsity(CFG, 0.5)
PROMPT = np.arange(1, 9, dtype=np.int32) % CFG.vocab_size


def _server(mode, **kw):
    kwargs = dict(max_batch=2, max_len=64, draft_k=4, tree_expansions=3,
                  adaptive=False, donate=True)
    if mode != "cascade_fused":
        kwargs["draft_spec"] = SPEC
    kwargs.update(kw)
    return BatchedSpecServer(CFG, PARAMS, mode=mode, **kwargs)


# ------------------------------------------------------------ registry units
def test_counter_gauge_get_or_create_by_labels():
    reg = MetricsRegistry()
    reg.counter("hits", slot=0).inc()
    reg.counter("hits", slot=0).inc(2)
    reg.counter("hits", slot=1).inc()
    assert reg.counter("hits", slot=0).value == 3
    assert reg.counter("hits", slot=1).value == 1
    reg.gauge("depth").set(7)
    assert reg.gauge("depth").value == 7
    snap = reg.snapshot()
    assert snap["counters"]['hits{slot="0"}'] == 3
    assert snap["gauges"]["depth"] == 7


def test_stats_view_int_semantics():
    reg = MetricsRegistry()
    sv = StatsView(reg)
    assert sv["steps"] == 0 and isinstance(sv["steps"], int)
    sv["steps"] += 3
    sv["draft_time"] += 0.25
    assert sv["steps"] == 3 and isinstance(sv["steps"], int)
    assert sv["draft_time"] == pytest.approx(0.25)
    assert isinstance(sv["draft_time"], float)
    # the view materializes every mapped counter at zero so a fresh
    # registry snapshot is complete (dashboards see all-zero, not absent)
    assert reg.counter("serve_host_syncs_total").value == 0
    assert set(sv.copy()) == set(iter(sv))
    assert sv.get("not_a_stat", "d") == "d" and "steps" in sv


def test_histogram_bucket_property():
    """Left-closed buckets: an observation equal to edge[i] lands in the
    bucket that edge OPENS (index i+1); below it stays in bucket i. No
    sample is lost or double-counted across the full edge sweep."""
    edges = Histogram.log_edges(1e-4, 512.0)
    assert edges == sorted(edges) and len(set(edges)) == len(edges)
    h = Histogram(list(edges))
    total = 0
    for i, e in enumerate(edges):
        assert h.bucket_index(e) == i + 1            # edge opens bucket i+1
        assert h.bucket_index(e * (1 - 1e-12)) == i  # just below: bucket i
        h.observe(e)
        total += 1
    h.observe(0.0)                                   # below lowest edge
    h.observe(float(edges[-1]) * 4)                  # above highest edge
    total += 2
    assert sum(h.counts) == h.count == total
    assert h.counts[0] == 1 and h.counts[-1] == 2    # top edge + overflow
    # middle buckets got exactly one sample each (their opening edge)
    assert all(c == 1 for c in h.counts[1:-1])


def test_render_prometheus_histogram_cumulative():
    reg = MetricsRegistry()
    hist = reg.histogram("lat_seconds", edges=[0.1, 1.0, 10.0])
    for v in (0.05, 0.5, 0.5, 5.0, 50.0):
        hist.observe(v)
    reg.counter("reqs", mode="x").inc(2)
    text = reg.render_prometheus()
    assert "# TYPE lat_seconds histogram" in text
    assert "# TYPE reqs counter" in text
    assert 'reqs{mode="x"} 2' in text
    les = []
    for line in text.splitlines():
        if line.startswith("lat_seconds_bucket"):
            les.append(float(line.rsplit(" ", 1)[1]))
    assert les == sorted(les)                        # cumulative => monotone
    assert les[-1] == 5                              # +Inf == count
    assert "lat_seconds_count 5" in text
    assert 'le="+Inf"' in text


# --------------------------------------------------------------- exporters
def test_metrics_http_endpoint():
    reg = MetricsRegistry()
    reg.counter("serve_rounds_total").inc(4)
    with MetricsHTTPServer(reg, port=0) as srv:
        assert srv.port > 0
        base = f"http://127.0.0.1:{srv.port}"
        assert srv.url == base + "/metrics"
        with urllib.request.urlopen(srv.url) as r:
            assert r.status == 200
            assert "text/plain" in r.headers["Content-Type"]
            body = r.read().decode()
        assert "serve_rounds_total 4" in body
        with urllib.request.urlopen(base + "/metrics.json") as r:
            snap = json.loads(r.read().decode())
        assert snap["counters"]["serve_rounds_total"] == 4
        with pytest.raises(urllib.error.HTTPError):
            urllib.request.urlopen(base + "/nope")


def test_chrome_trace_and_jsonl_sink(tmp_path):
    trace = TraceRecorder()
    with trace.span("dispatch", round=1):
        with trace.span("route"):
            pass
    trace.instant("sync")
    doc = trace.to_json()
    evs = doc["traceEvents"]
    assert [e["ph"] for e in evs].count("X") == 2
    for e in evs:
        assert {"name", "ph", "ts", "pid", "tid"} <= set(e)
        if e["ph"] == "X":
            assert e["dur"] >= 0
    tr_path = tmp_path / "trace.json"
    trace.save(str(tr_path))
    assert json.loads(tr_path.read_text())["traceEvents"]

    reg = MetricsRegistry()
    reg.counter("c").inc()
    sink_path = tmp_path / "metrics.jsonl"
    with JsonlSink(str(sink_path)) as sink:
        sink.write({"kind": "round", "n": 1})
        sink.write_registry(reg, step=2)
    lines = [json.loads(x) for x in sink_path.read_text().splitlines()]
    assert lines[0] == {"kind": "round", "n": 1}
    assert lines[1]["kind"] == "metrics_snapshot" and lines[1]["step"] == 2
    assert lines[1]["metrics"]["counters"]["c"] == 1


# -------------------------------------------------- end-to-end reconciliation
MODES = [
    ("chain_fused", {"round_mode": "single", "sync_every": 2}),
    ("chain_fused", {"round_mode": "split"}),
    ("tree_fused", {"round_mode": "single"}),
    ("legacy", {}),
    ("cascade_fused", {}),
]


@pytest.mark.parametrize("mode,kw", MODES)
def test_mode_accepted_matches_delivered(mode, kw):
    """Per-slot device/host telemetry tallies must equal the token stream
    the server actually returned — in every proposal mode."""
    srv = _server(mode, **kw)
    srv.add_request(0, PROMPT)
    toks = []
    for _ in range(5):
        toks += srv.step().get(0, [])
    toks += srv.flush().get(0, [])
    tot = srv.telemetry_totals()
    assert int(tot["accepted"][0]) == len(toks)
    assert int(tot["accepted"][1]) == 0              # empty slot stays silent
    assert int(tot["rounds"][0]) == 5
    assert int(tot["budget_hist"][0].sum()) == 5     # one budget pick / round
    summ = srv.metrics_summary()
    assert summ["mode"] == mode and summ["rounds"] == 5
    assert summ["accepted_per_slot"][0] == len(toks)
    if mode == "cascade_fused":
        # every level's routed/observed/accept rows are populated
        assert np.asarray(tot["casc_obs"]).sum() > 0
        acc = summ["cascade_acceptance"]
        assert len(acc) == len(srv.bank)
        assert all(a is None or 0.0 <= a <= 1.0 for a in acc)


@pytest.mark.parametrize("mode,kw", MODES)
def test_telemetry_onoff_runtime_parity(mode, kw):
    """Runtime side of the transparency contract: telemetry on vs off must
    produce identical round_dispatches/host_syncs AND identical tokens."""
    runs = {}
    for telem in (True, False):
        srv = _server(mode, telemetry=telem, **kw)
        srv.add_request(0, PROMPT)
        toks = []
        for _ in range(4):
            toks += srv.step().get(0, [])
        toks += srv.flush().get(0, [])
        runs[telem] = (toks, srv.stats["round_dispatches"],
                       srv.stats["host_syncs"], srv.stats["target_calls"])
    assert runs[True] == runs[False]


def test_serve_loop_overshoot_reconciliation():
    """The pipelined loop: device-tallied accepted tokens reconcile exactly
    with delivered + trimmed overshoot + unrouted + discarded + leftover,
    and trimmed tokens never inflate per-request counts or TPOT."""
    srv = _server("chain_fused", round_mode="single", sync_every=3,
                  max_len=96)
    sched = RequestScheduler(2)
    trace = TraceRecorder()
    loop = ServeLoop(srv, sched, trace=trace)
    for i in range(4):
        sched.submit(Request(prompt=np.arange(1, 7 + i, dtype=np.int32),
                             max_new_tokens=9))
    reqs = loop.run(max_steps=200)
    assert len(reqs) == 4
    assert all(len(r.generated) == 9 for r in reqs)  # trimmed to the cap
    leftover = srv.flush()
    tot = srv.telemetry_totals()
    snap = srv.metrics.snapshot()["counters"]
    delivered = sum(len(r.generated) for r in reqs)
    accounted = (delivered
                 + snap.get("serve_overshoot_tokens_total", 0)
                 + snap.get("serve_unrouted_tokens_total", 0)
                 + snap.get("serve_discarded_tokens_total", 0)
                 + sum(len(v) for v in leftover.values()))
    assert int(tot["accepted"].sum()) == accounted
    # overshoot is excluded from the delivered-token counter ...
    assert snap["serve_request_tokens_total"] == delivered == 4 * 9
    # ... and from TPOT: any finite tpot stays consistent with delivered-1
    for r in reqs:
        assert r.ttft is not None and r.ttft >= 0
        if r.tpot is not None:
            assert r.tpot >= 0
    # loop-phase spans + occupancy gauges came out of the same run
    names = {e["name"] for e in trace.events}
    assert {"admit", "dispatch", "route", "retire"} <= names
    gauges = srv.metrics.snapshot()["gauges"]
    assert gauges["serve_queue_depth"] == 0
    assert gauges["serve_slots_occupied"] == 0


def test_discarded_tokens_counted_on_slot_rebind():
    srv = _server("chain_fused", round_mode="single", sync_every=1)
    srv.add_request(0, PROMPT)
    srv.step()
    srv.flush()
    pend = srv._out_buf.get(0, [])
    srv.add_request(0, PROMPT)                       # rebind with buf pending
    snap = srv.metrics.snapshot()["counters"]
    assert snap.get("serve_discarded_tokens_total", 0) == len(pend)


# --------------------------------------------- optional property-based sweep
def test_histogram_random_observations_are_conserved():
    hypothesis = pytest.importorskip("hypothesis")
    from hypothesis import given, strategies as st

    edges = Histogram.log_edges(1e-3, 8.0)

    @given(st.lists(st.floats(min_value=0, max_value=32.0,
                              allow_nan=False), max_size=64))
    def check(vals):
        h = Histogram(list(edges))
        for v in vals:
            h.observe(v)
        assert sum(h.counts) == h.count == len(vals)
        for v in vals:
            i = h.bucket_index(v)
            assert (i == 0 or edges[i - 1] <= v)
            assert (i == len(edges) or v < edges[i])

    check()
