"""ActivationQuant DSIA numerics contract: the CPU simulation
(``engine.fake_quant_int8`` weight fake-quantization) and the Pallas W8A8
path (``kernels.quantized_matmul``, interpret mode off-TPU) must agree
within tolerance — one contract, two executions, so a cascade level drafts
the same way wherever the bank materialized it."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import get_config
from repro.core.engine import fake_quant_int8
from repro.kernels.ops import quantized_matmul
from repro.models import model as M
from repro.models.layers import mlp_apply, mlp_init

CFG = dataclasses.replace(get_config("vicuna-7b").reduced(), num_layers=2)
PARAMS = M.init_params(CFG, jax.random.PRNGKey(0))


def _rel(a, b):
    return float(jnp.linalg.norm(a.astype(jnp.float32) - b.astype(jnp.float32))
                 / jnp.maximum(jnp.linalg.norm(b.astype(jnp.float32)), 1e-9))


def test_quantized_matmul_recovers_fake_quant_grid():
    """Weights already on the fake-quant int8 grid pass through the
    kernel's per-column requantization losslessly: the remaining error is
    the dynamic per-row ACTIVATION quantization only (<~1%)."""
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(8, 64)).astype(np.float32)) * 2.0
    w = jnp.asarray(rng.normal(size=(64, 96)).astype(np.float32))
    wq = fake_quant_int8({"w": w})["w"]
    out = quantized_matmul(x, wq, interpret=True)
    assert _rel(out, x @ wq) < 0.02


def test_fake_quant_is_idempotent_and_per_output_channel():
    rng = np.random.default_rng(1)
    w = jnp.asarray(rng.normal(size=(32, 48)).astype(np.float32))
    w1 = fake_quant_int8({"w": w})["w"]
    w2 = fake_quant_int8({"w": w1})["w"]
    np.testing.assert_allclose(np.asarray(w1), np.asarray(w2), rtol=0, atol=1e-6)
    # per-output-channel: each column uses its own 127-step grid
    assert _rel(w1, w) < 0.01
    # 1-D and int leaves pass through untouched
    tree = {"b": jnp.ones((16,)), "i": jnp.arange(4)}
    out = fake_quant_int8(tree)
    assert out["b"] is tree["b"] and out["i"] is tree["i"]


def test_mlp_apply_kernel_vs_sim():
    """The MLP forward — the path the bank actually routes — under
    ``quantize="int8"`` (kernel) vs fake-quantized weights (sim)."""
    rng = np.random.default_rng(2)
    p = mlp_init(jax.random.PRNGKey(3), 32, 64, True, jnp.float32)
    x = jnp.asarray(rng.normal(size=(4, 6, 32)).astype(np.float32))
    out_kernel = mlp_apply(p, x, "silu", True, quantize="int8")
    out_sim = mlp_apply(fake_quant_int8(p), x, "silu", True)
    ref = mlp_apply(p, x, "silu", True)
    assert _rel(out_kernel, ref) < 0.05
    assert _rel(out_sim, ref) < 0.05
    assert _rel(out_kernel, out_sim) < 0.06


def test_mlp_apply_rejects_unknown_quantize():
    p = mlp_init(jax.random.PRNGKey(0), 16, 32, False, jnp.float32)
    with pytest.raises(ValueError, match="unsupported quantize"):
        mlp_apply(p, jnp.ones((2, 16)), "silu", False, quantize="int4")


def test_chain_draft_scan_honors_level_execution():
    """The generalized chain scan executes per-level quantize and
    attn_override (not just gates): its first drafted token must equal the
    argmax of a direct decode under the SAME execution flags."""
    import functools

    from repro.core.engine import chain_draft_scan

    rng = np.random.default_rng(5)
    cache = M.init_cache(CFG, 1, 64)
    prompt = jnp.asarray(rng.integers(2, CFG.vocab_size, size=(1, 12)), jnp.int32)
    last, cache = M.prefill(CFG, PARAMS, {"tokens": prompt}, cache)
    pending = jnp.argmax(last, -1).astype(jnp.int32)
    override = {"kind": "streaming", "window": 8, "sink": 2}
    fn = jax.jit(functools.partial(
        chain_draft_scan, CFG, 2, quantize="int8", attn_override=override
    ))
    chains, have = fn(
        PARAMS, cache, pending, jnp.zeros((1, 4), jnp.int32),
        jnp.zeros((1,), jnp.int32), jnp.full((1,), 2, jnp.int32), None,
    )
    assert int(np.asarray(have)[0]) == 2
    lg, _ = M.decode_step(CFG, PARAMS, cache, pending[:, None],
                          quantize="int8", attn_override=override)
    assert int(np.asarray(chains)[0, 0]) == int(jnp.argmax(lg[0, 0]))


def test_decode_step_int8_kernel_vs_sim():
    """Whole-model contract on a tiny model: decode against the same
    (target-committed) cache with ``quantize="int8"`` vs fake-quant params.
    The two int8 executions must be closer to each other than either is
    allowed to drift overall, and their greedy argmaxes must agree almost
    everywhere (drafting only consumes the argmax)."""
    rng = np.random.default_rng(4)
    cache = M.init_cache(CFG, 1, 64)
    prompt = jnp.asarray(rng.integers(2, CFG.vocab_size, size=(1, 12)), jnp.int32)
    _, cache = M.prefill(CFG, PARAMS, {"tokens": prompt}, cache)
    toks = jnp.asarray(rng.integers(2, CFG.vocab_size, size=(1, 6)), jnp.int32)
    lg_kernel, _ = M.decode_step(CFG, PARAMS, cache, toks, quantize="int8")
    lg_sim, _ = M.decode_step(CFG, fake_quant_int8(PARAMS), cache, toks)
    assert _rel(lg_kernel, lg_sim) < 0.10
    agree = float((jnp.argmax(lg_kernel, -1) == jnp.argmax(lg_sim, -1)).mean())
    assert agree >= 0.75
