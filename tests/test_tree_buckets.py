"""core.tree bucket padding and mask export: bucket_for boundaries, padded
vs unpadded mask equivalence, and the error path past TREE_BUCKETS[-1].
(Separate from test_tree.py so this coverage runs without hypothesis.)"""
import numpy as np
import pytest

from repro.core.tree import TREE_BUCKETS, DraftTree, bucket_for, chain_tree


def _branchy_tree(n_children):
    t = DraftTree(1)
    rng = np.random.default_rng(0)
    for i in range(n_children):
        t.add_child(int(rng.integers(0, len(t))), i + 2, "c", 0.8)
    return t


def test_bucket_for_boundary_values():
    # exact bucket sizes map to themselves; one past maps to the next bucket
    for b in TREE_BUCKETS:
        assert bucket_for(b) == b
    for lo, hi in zip(TREE_BUCKETS, TREE_BUCKETS[1:]):
        assert bucket_for(lo + 1) == hi
    assert bucket_for(0) == TREE_BUCKETS[0]
    assert bucket_for(1) == TREE_BUCKETS[0]


def test_bucket_for_past_largest_raises():
    with pytest.raises(ValueError, match="tree too large"):
        bucket_for(TREE_BUCKETS[-1] + 1)


def test_flatten_rejects_oversized_tree():
    t = chain_tree(0, list(range(TREE_BUCKETS[-1])), "c", 0.9)  # root + 128
    assert len(t) == TREE_BUCKETS[-1] + 1
    with pytest.raises(ValueError, match="tree too large"):
        t.flatten()


def test_padded_mask_equals_unpadded_prefix():
    """flatten(bucket=T') for any larger bucket must agree with the natural
    bucket on every real entry, and pad identically (self-only visibility,
    out-of-range rel positions, real=False)."""
    t = _branchy_tree(13)
    n = len(t)
    tokens, rel, mask, real = t.flatten()
    T0 = bucket_for(n)
    for T in [b for b in TREE_BUCKETS if b >= T0]:
        tk, rl, mk, re = t.flatten(bucket=T)
        assert tk.shape == (T,) and mk.shape == (T, T)
        np.testing.assert_array_equal(tk[:n], tokens[:n])
        np.testing.assert_array_equal(rl[:n], rel[:n])
        np.testing.assert_array_equal(mk[:n, :n], mask[:n, :n])
        np.testing.assert_array_equal(re[:n], real[:n])
        # padding contract
        assert not re[n:].any()
        assert not mk[:n, n:].any()          # no real node sees padding
        assert not mk[n:, :n].any()          # padding sees no real node
        np.testing.assert_array_equal(
            mk[n:, n:], np.eye(T - n, dtype=bool)
        )
        assert (rl[n:] > max(t.depth)).all()  # rope-distant pad positions


def test_root_only_tree_pads_to_smallest_bucket():
    t = DraftTree(42)
    tokens, rel, mask, real = t.flatten()
    assert tokens.shape == (TREE_BUCKETS[0],)
    assert tokens[0] == 42 and real[0] and not real[1:].any()
    assert mask[0, 0] and mask[0].sum() == 1
