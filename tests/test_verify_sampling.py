"""Speculative sampling (chain) is distribution-preserving (lossless in law)."""
import numpy as np
import pytest

from repro.core.verify import spec_sample_chain, softmax


def test_accept_all_when_identical():
    rng = np.random.default_rng(0)
    V, k = 8, 4
    p = softmax(np.random.default_rng(1).normal(size=(k + 1, V)))
    # draft distribution == target distribution and draft tokens are the
    # argmax -> p_t/p_d = 1 -> always accepted
    toks = p[:k].argmax(-1)
    n, nxt = spec_sample_chain(toks, p[:k], p, rng)
    assert n == k


def test_reject_impossible_token():
    rng = np.random.default_rng(0)
    V = 4
    target = np.zeros((2, V))
    target[0] = [0.0, 1.0, 0.0, 0.0]    # target only emits token 1
    target[1] = [0.25] * 4
    draft = np.array([[1.0, 0.0, 0.0, 0.0]])
    n, nxt = spec_sample_chain(np.array([0]), draft, target, rng)
    assert n == 0
    assert nxt == 1                     # residual = target


def test_marginal_distribution_preserved():
    """Empirical check of the Leviathan guarantee on the first token."""
    rng = np.random.default_rng(42)
    V = 5
    g = np.random.default_rng(7)
    target = softmax(g.normal(size=(2, V)))
    draft = softmax(g.normal(size=(1, V)))
    counts = np.zeros(V)
    trials = 30_000
    for _ in range(trials):
        d_tok = g.choice(V, p=draft[0])
        n, nxt = spec_sample_chain(np.array([d_tok]), draft, target, rng)
        tok = d_tok if n >= 1 else nxt
        counts[tok] += 1
    emp = counts / trials
    assert np.abs(emp - target[0]).max() < 0.015
