"""Speculative sampling (chain) is distribution-preserving (lossless in law),
and the fused device kernels replay their host oracles bit-for-bit under
identical explicit uniforms."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.verify import (
    greedy_accept_tree_batched,
    sample_accept_chain_batched,
    sample_accept_chain_host,
    sample_accept_tree_batched,
    sample_accept_tree_host,
    spec_sample_chain,
    softmax,
)


def test_accept_all_when_identical():
    rng = np.random.default_rng(0)
    V, k = 8, 4
    p = softmax(np.random.default_rng(1).normal(size=(k + 1, V)))
    # draft distribution == target distribution and draft tokens are the
    # argmax -> p_t/p_d = 1 -> always accepted
    toks = p[:k].argmax(-1)
    n, nxt = spec_sample_chain(toks, p[:k], p, rng)
    assert n == k


def test_reject_impossible_token():
    rng = np.random.default_rng(0)
    V = 4
    target = np.zeros((2, V))
    target[0] = [0.0, 1.0, 0.0, 0.0]    # target only emits token 1
    target[1] = [0.25] * 4
    draft = np.array([[1.0, 0.0, 0.0, 0.0]])
    n, nxt = spec_sample_chain(np.array([0]), draft, target, rng)
    assert n == 0
    assert nxt == 1                     # residual = target


def test_marginal_distribution_preserved():
    """Empirical check of the Leviathan guarantee on the first token."""
    rng = np.random.default_rng(42)
    V = 5
    g = np.random.default_rng(7)
    target = softmax(g.normal(size=(2, V)))
    draft = softmax(g.normal(size=(1, V)))
    counts = np.zeros(V)
    trials = 30_000
    for _ in range(trials):
        d_tok = g.choice(V, p=draft[0])
        n, nxt = spec_sample_chain(np.array([d_tok]), draft, target, rng)
        tok = d_tok if n >= 1 else nxt
        counts[tok] += 1
    emp = counts / trials
    assert np.abs(emp - target[0]).max() < 0.015


# ------------------------------------------------ device vs host oracle: chain
def _rand_probs(g, *shape):
    return softmax(g.normal(size=shape)).astype(np.float32)


def test_chain_kernel_matches_host_oracle():
    """Same chains, same q, same explicit uniforms -> identical (n, token)
    for every slot, across the full range of ``have`` (0..K)."""
    g = np.random.default_rng(101)
    B, K, V = 16, 4, 12
    for trial in range(8):
        chains = g.integers(0, V, size=(B, K)).astype(np.int32)
        have = (np.arange(B) % (K + 1)).astype(np.int32)
        q = _rand_probs(g, B, K + 1, V)
        # sharpen some rows so both accept and reject branches are hit
        q[::3] = _rand_probs(g, (B + 2) // 3, K + 1, V) ** 3
        q /= q.sum(-1, keepdims=True)
        u_acc = g.random(size=(B, K)).astype(np.float32)
        u_next = g.random(size=(B,)).astype(np.float32)
        n_dev, t_dev = sample_accept_chain_batched(
            jnp.asarray(chains), jnp.asarray(have), jnp.asarray(q),
            jnp.asarray(u_acc), jnp.asarray(u_next),
        )
        n_dev, t_dev = np.asarray(n_dev), np.asarray(t_dev)
        for b in range(B):
            n_h, t_h = sample_accept_chain_host(
                chains[b], int(have[b]), q[b], u_acc[b], float(u_next[b])
            )
            assert (n_dev[b], t_dev[b]) == (n_h, t_h), (trial, b)


def test_chain_kernel_greedy_onehot_reduction():
    """One-hot q (the temperature<=0 warp) reduces the stochastic rule to
    the greedy one: accept iff drafted token == argmax, next = argmax."""
    g = np.random.default_rng(5)
    B, K, V = 8, 3, 9
    am = g.integers(0, V, size=(B, K + 1)).astype(np.int32)
    q = np.eye(V, dtype=np.float32)[am]                      # (B, K+1, V)
    chains = am[:, :K].copy()
    chains[1, 0] = (chains[1, 0] + 1) % V                    # reject at pos 0
    chains[2, 2] = (chains[2, 2] + 1) % V                    # reject at pos 2
    have = np.full((B,), K, np.int32)
    n, t = sample_accept_chain_batched(
        jnp.asarray(chains), jnp.asarray(have), jnp.asarray(q),
        jnp.asarray(g.random(size=(B, K)), dtype=jnp.float32),
        jnp.asarray(g.random(size=(B,)), dtype=jnp.float32),
    )
    n, t = np.asarray(n), np.asarray(t)
    want_n = np.array([(chains[b] == am[b, :K]).cumprod().sum()
                       for b in range(B)])
    np.testing.assert_array_equal(n, want_n)
    # residual of a one-hot with the hit token zeroed falls back to the row
    # itself -> the greedy next token either way
    np.testing.assert_array_equal(t, am[np.arange(B), n])


# ------------------------------------------------- device vs host oracle: tree
def _tree(shape: str, N: int, V: int, g) -> tuple:
    """A padded (tokens, parents, count) tree with sibling-distinct tokens
    (matching draft-time dedup)."""
    if shape == "chain":
        parents = np.arange(-1, N - 1)
    elif shape == "star":
        parents = np.array([-1] + [0] * (N - 1))
    else:  # mixed: two children under root, then alternate attachment
        parents = np.array([-1, 0, 0] + [1 + (i % 2) for i in range(N - 3)])
        parents[4:] = [g.integers(1, i) for i in range(4, N)]
    tokens = np.zeros(N, np.int64)
    for p in np.unique(parents):
        kids = np.flatnonzero(parents == p)
        tokens[kids] = g.choice(V, size=len(kids), replace=False)
    return tokens.astype(np.int32), parents.astype(np.int32), N


@pytest.mark.parametrize("shape", ["chain", "star", "mixed"])
@pytest.mark.parametrize("N", [4, 7])
def test_tree_kernel_matches_host_oracle(shape, N):
    g = np.random.default_rng(hash((shape, N)) % 2**32)
    B, V = 12, 10
    toks = np.zeros((B, N), np.int32)
    pars = np.full((B, N), -1, np.int32)
    count = np.zeros((B,), np.int32)
    for b in range(B):
        t, p, c = _tree(shape, N, V, g)
        # vary the live node count so padding is exercised too
        c = N if b % 3 else max(2, N - 2)
        toks[b], pars[b], count[b] = t, p, c
    q = _rand_probs(g, B, N, V)
    q[1::2] = q[1::2] ** 4                      # sharp rows: high-accept slots
    q /= q.sum(-1, keepdims=True)
    u = g.random(size=(B, N)).astype(np.float32)
    path_d, n_d, t_d = sample_accept_tree_batched(
        jnp.asarray(toks), jnp.asarray(pars), jnp.asarray(count),
        jnp.asarray(q), jnp.asarray(u),
    )
    path_d, n_d, t_d = np.asarray(path_d), np.asarray(n_d), np.asarray(t_d)
    for b in range(B):
        path_h, n_h, tok_h = sample_accept_tree_host(
            toks[b], pars[b], int(count[b]), q[b], u[b]
        )
        assert n_d[b] == n_h, (shape, N, b)
        assert t_d[b] == tok_h, (shape, N, b)
        np.testing.assert_array_equal(path_d[b, : n_h], path_h), (shape, b)


def test_tree_kernel_greedy_onehot_reduction():
    """One-hot q -> the sampled walk reproduces greedy_accept_tree_batched
    exactly (path, count, and bonus token)."""
    g = np.random.default_rng(23)
    B, N, V = 9, 6, 8
    toks = np.zeros((B, N), np.int32)
    pars = np.full((B, N), -1, np.int32)
    count = np.full((B,), N, np.int32)
    for b, shape in enumerate(["chain", "star", "mixed"] * 3):
        toks[b], pars[b], _ = _tree(shape, N, V, g)
    am = g.integers(0, V, size=(B, N)).astype(np.int32)
    # force some argmax rows onto actual child tokens so walks go deep
    am[:, 0] = toks[:, 1]
    q = np.eye(V, dtype=np.float32)[am]
    u = g.random(size=(B, N)).astype(np.float32)
    path_s, n_s, t_s = sample_accept_tree_batched(
        jnp.asarray(toks), jnp.asarray(pars), jnp.asarray(count),
        jnp.asarray(q), jnp.asarray(u),
    )
    path_g, n_g, bonus = greedy_accept_tree_batched(
        jnp.asarray(toks), jnp.asarray(pars), jnp.asarray(count),
        jnp.asarray(am),
    )
    np.testing.assert_array_equal(np.asarray(n_s), np.asarray(n_g))
    np.testing.assert_array_equal(np.asarray(t_s), np.asarray(bonus))
    np.testing.assert_array_equal(np.asarray(path_s), np.asarray(path_g))
