"""Draft token tree: ancestor-closure masks, P_acc bookkeeping, flatten."""
import numpy as np
import pytest
pytest.importorskip("hypothesis", reason="needs hypothesis — pip install -r requirements-dev.txt")
from hypothesis import given, settings, strategies as st

from repro.core.tree import DraftTree, bucket_for, chain_tree
from repro.core.verify import greedy_accept_tree


def build_random_tree(structure):
    """structure: list of parent indices (clamped) defining node additions."""
    t = DraftTree(root_token=1)
    for i, p in enumerate(structure):
        parent = p % len(t)
        t.add_child(parent, token=i + 2, config="c", alpha=0.8)
    return t


@given(st.lists(st.integers(0, 30), min_size=0, max_size=25))
@settings(max_examples=80, deadline=None)
def test_mask_is_ancestor_closure(structure):
    t = build_random_tree(structure)
    tokens, rel, mask, real = t.flatten()
    n = len(t)
    for i in range(n):
        # reference ancestor set
        anc = set()
        j = i
        while j != -1:
            anc.add(j)
            j = t.parents[j]
        for j in range(n):
            assert mask[i, j] == (j in anc)
    # padded slots see only themselves, nothing sees them
    T = bucket_for(n)
    for i in range(n, T):
        assert mask[i, i] and mask[i].sum() == 1
        assert not mask[:n, i].any()
    # rel positions equal depth
    assert (rel[:n] == np.asarray(t.depth)).all()


@given(st.lists(st.integers(0, 30), min_size=1, max_size=25))
@settings(max_examples=50, deadline=None)
def test_p_acc_is_product_along_path(structure):
    t = build_random_tree(structure)
    for i in range(len(t)):
        assert abs(t.p_acc[i] - 0.8 ** t.depth[i]) < 1e-9


def test_best_leaf_prefers_high_p_acc():
    t = DraftTree(0)
    a = t.add_child(0, 1, "c", 0.9)
    b = t.add_child(0, 2, "c", 0.3)
    assert t.best_active_leaf() in (0,)   # root has P=1
    t.deactivate(0)
    assert t.best_active_leaf() == a


def test_greedy_accept_walks_matching_children():
    t = chain_tree(5, [7, 9, 11], "c", 0.8)
    # target agrees with tokens 7, 9 then diverges
    nxt = np.array([7, 9, 99, 0])
    path, bonus = greedy_accept_tree(t, nxt)
    assert path == [0, 1, 2]
    assert bonus == 99


def test_greedy_accept_tree_branch():
    t = DraftTree(5)
    c1 = t.add_child(0, 7, "c", 0.5)
    c2 = t.add_child(0, 8, "c", 0.5)
    g = t.add_child(c2, 3, "c", 0.5)
    nxt = np.zeros(4, np.int64)
    nxt[0] = 8          # target picks the second branch
    nxt[c2] = 3
    nxt[g] = 42
    path, bonus = greedy_accept_tree(t, nxt)
    assert path == [0, c2, g]
    assert bonus == 42
