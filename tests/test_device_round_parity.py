"""Host/device parity for the carried round state: the Eq. 4 EMA estimator
(`acceptance.ema_update` vs `AcceptanceTracker`), the Eq. 5 budget searches
(`latency.best_*_batched` vs the host loops), and the device tree seeding
(`tree.tree_seed_device` vs `tree.tree_seed_arrays`). These are the pieces
the single-dispatch serving round carries on device; the host paths stay the
oracles."""
import numpy as np
import jax.numpy as jnp

from repro.core.acceptance import AcceptanceTracker, ema_init, ema_update
from repro.core.latency import (
    best_chain_length,
    best_chain_length_batched,
    best_tree_expansions,
    best_tree_expansions_batched,
)
from repro.core.tree import tree_seed_arrays, tree_seed_device


def test_ema_update_matches_tracker():
    """Random per-slot observation streams (with gaps): the device ring
    buffer EMA must track the host deque EMA slot for slot."""
    rng = np.random.default_rng(0)
    B, rounds = 4, 120
    tracker = AcceptanceTracker()
    prior = 0.37
    for b in range(B):
        tracker.set_prior(f"s{b}", prior)
    alpha, hist, hist_n, hist_ptr = ema_init(B, prior=prior)
    for _ in range(rounds):
        valid = rng.random(B) < 0.7
        outcome = (rng.random(B) < 0.4).astype(np.float32)
        for b in range(B):
            if valid[b]:
                tracker.observe(f"s{b}", bool(outcome[b]))
        alpha, hist, hist_n, hist_ptr = ema_update(
            alpha, hist, hist_n, hist_ptr,
            jnp.asarray(outcome), jnp.asarray(valid),
        )
    for b in range(B):
        assert np.isclose(float(alpha[b]), tracker.alpha(f"s{b}"), atol=1e-5)
        assert int(hist_n[b]) == tracker.counts(f"s{b}")


def _assert_equiv_budget(got, want, value_of, gate_of, t_min):
    """Budgets must agree except at exact mathematical ties (e.g.
    t_sd(a, c, 1) == 1.0 == t_sd(a, c, 0) exactly when a == c), where f32
    and f64 rounding may break the tie differently — both choices then have
    equal expected speedup, so either is admissible."""
    if got == want:
        return
    if (got == 0) != (want == 0):
        # a gate flip (0 vs >0) is only admissible right at the threshold
        assert abs(gate_of(max(got, want)) - t_min) < 1e-4, (got, want)
    else:
        v_got, v_want = value_of(got), value_of(want)
        assert abs(v_got - v_want) < 1e-5, (got, want, v_got, v_want)


def test_best_chain_length_batched_matches_host():
    from repro.core.ewif import t_sd

    alphas = np.array([0.01, 0.1, 0.3, 0.5, 0.7, 0.9, 0.97, 0.999], np.float32)
    for c in (0.02, 0.1, 0.3, 0.6, 0.95):
        for t_min in (1.0, 1.05, 1.5, 1e9):
            got = np.asarray(best_chain_length_batched(
                jnp.asarray(alphas), jnp.asarray(c, jnp.float32), 8, t_min
            ))
            for a, g in zip(alphas, got):
                w = best_chain_length(float(a), c, 8, t_min)
                v = lambda k, a=a: t_sd(float(a), c, k)   # noqa: E731
                _assert_equiv_budget(int(g), w, v, v, t_min)


def test_best_tree_expansions_batched_matches_host():
    from repro.core.ewif import dytc_step_objective, t_sd

    alphas = np.array([0.05, 0.2, 0.4, 0.6, 0.8, 0.95], np.float32)
    for c in (0.05, 0.2, 0.5):
        for t_min in (1.0, 1.05, 1e9):
            got = np.asarray(best_tree_expansions_batched(
                jnp.asarray(alphas), jnp.asarray(c, jnp.float32), 6, t_min
            ))
            for a, g in zip(alphas, got):
                w = best_tree_expansions(float(a), c, 6, t_min)
                _assert_equiv_budget(
                    int(g), w,
                    lambda k, a=a: dytc_step_objective(
                        float(a), c, k, float(a), c
                    ),
                    lambda k, a=a: t_sd(float(a), c, k),
                    t_min,
                )


def test_dynamic_steps_matches_static_scan():
    """``dynamic_steps=True`` (the on-device while_loop trip count) must be
    token-identical to the static scan for BOTH draft scans and both
    draft-KV modes — iterations past the per-round need are no-ops, so
    skipping them may never change a proposal."""
    import dataclasses
    import functools

    import jax

    from repro.config import get_config
    from repro.core.dsia import layer_sparsity
    from repro.core.engine import chain_draft_scan, tree_draft_scan
    from repro.models import model as M

    cfg = dataclasses.replace(get_config("vicuna-7b").reduced(), num_layers=3)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    gates = jnp.asarray(layer_sparsity(cfg, 0.5).gates_array(cfg.num_layers))
    prompts = jnp.asarray(
        np.stack([[5, 6, 7, 8] * 3, [9, 10, 11, 9, 10, 11] * 2]), jnp.int32
    )
    cache = M.init_cache(cfg, 2, 64)
    last, cache = M.prefill(cfg, params, {"tokens": prompts}, cache)
    pending = jnp.argmax(last, -1).astype(jnp.int32)

    K = 4
    chains = jnp.zeros((2, K), jnp.int32)
    have = jnp.zeros((2,), jnp.int32)
    for kv in ("recompute", "carry"):
        for limit in ([0, 0], [2, 1], [4, 3]):   # none / partial / full need
            runs = []
            for dyn in (False, True):
                fn = jax.jit(functools.partial(
                    chain_draft_scan, cfg, K, draft_kv=kv, dynamic_steps=dyn
                ))
                runs.append([np.asarray(a) for a in fn(
                    params, cache, pending, chains, have,
                    jnp.asarray(limit, jnp.int32), gates,
                )])
            for a, b in zip(*runs):              # bitwise: same math path
                np.testing.assert_array_equal(a, b, err_msg=f"{kv} {limit}")

    seed = tree_seed_device(pending, chains, have, 16, pld_alpha=0.3)
    c = jnp.asarray(0.3, jnp.float32)
    t_min = jnp.asarray(1.0, jnp.float32)
    alpha = jnp.asarray([0.8, 0.6], jnp.float32)
    for kv in ("recompute", "carry"):
        for limit in ([0, 0], [3, 1], [5, 5]):
            runs = []
            for dyn in (False, True):
                fn = jax.jit(functools.partial(
                    tree_draft_scan, cfg, 5, 2, draft_kv=kv, dynamic_steps=dyn
                ))
                runs.append([np.asarray(a) for a in fn(
                    params, cache, *seed, jnp.asarray(limit, jnp.int32),
                    alpha, c, t_min, gates,
                )])
            for a, b in zip(*runs):
                np.testing.assert_array_equal(a, b, err_msg=f"{kv} {limit}")


def test_tree_seed_device_matches_host():
    rng = np.random.default_rng(3)
    B, K, N = 3, 4, 16
    pending = rng.integers(0, 50, B).astype(np.int32)
    chains = rng.integers(0, 50, (B, K)).astype(np.int32)
    have = np.array([0, 2, 4], np.int32)
    host = tree_seed_arrays(pending, chains, have, N, pld_alpha=0.3)
    dev = tree_seed_device(
        jnp.asarray(pending), jnp.asarray(chains), jnp.asarray(have), N,
        pld_alpha=0.3,
    )
    names = ("tokens", "parents", "depth", "p_acc", "mask", "count")
    for name, h, d in zip(names, host, dev):
        np.testing.assert_allclose(
            np.asarray(d), np.asarray(h, dtype=np.asarray(d).dtype),
            rtol=1e-6, err_msg=name,
        )
