"""Sampled serving end-to-end: greedy identity, seeded determinism, mixed
batches, and distribution preservation (lossless in law) of the fused
stochastic-verify kernels.

Two statistical tiers (docs/analysis.md):
  - the smoke checks here run everywhere (tier-1) with small trial counts
    and loose bounds — they catch gross losslessness breaks;
  - the ``@pytest.mark.stat`` variants re-run the same estimators at full
    trial counts with the acceptance bound (max-TV < 0.02). Tier-1
    deselects them via ``addopts = -m "not stat"``; the scheduled CI job
    runs ``-m stat``. Seeds are baked into every assert message so a
    failing draw is reproducible verbatim.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import get_config
from repro.core.dsia import layer_sparsity
from repro.core.verify import (
    round_uniforms,
    sample_accept_chain_batched,
    sample_accept_tree_batched,
)
from repro.models import model as M
from repro.serving.sampler import SamplingParams, warp_probs
from repro.serving.server import BatchedSpecServer

CFG = dataclasses.replace(get_config("vicuna-7b").reduced(), num_layers=3)
PARAMS = M.init_params(CFG, jax.random.PRNGKey(0))
SPEC = layer_sparsity(CFG, 0.5)
STOCH = SamplingParams(temperature=0.8, top_k=20, top_p=0.9, seed=7)
GREEDY0 = SamplingParams(temperature=0.0, seed=0)

MODES = [
    ("chain_fused", {"round_mode": "single"}),
    ("chain_fused", {"round_mode": "split"}),
    ("tree_fused", {"round_mode": "single"}),
    ("legacy", {}),
    ("cascade_fused", {}),
]


def _server(mode, sampling=None, **kw):
    kwargs = dict(max_batch=2, max_len=128, draft_k=4, tree_expansions=5,
                  adaptive=False)
    if mode != "cascade_fused":
        kwargs["draft_spec"] = SPEC
    kwargs.update(kw)
    return BatchedSpecServer(CFG, PARAMS, mode=mode, sampling=sampling,
                             **kwargs)


def _prompts():
    rng = np.random.default_rng(0)
    return [
        np.array([5, 6, 7, 8] * 4, np.int32),                   # PLD-friendly
        rng.integers(4, CFG.vocab_size - 1, size=20).astype(np.int32),
    ]


def _serve(srv, prompts, rounds=5, sampling=None):
    for i, p in enumerate(prompts):
        if sampling is None:
            srv.add_request(i, p)
        else:
            srv.add_request(i, p, sampling=sampling[i])
    gen = {i: [] for i in range(len(prompts))}
    for _ in range(rounds):
        for b, toks in srv.step().items():
            gen[b].extend(toks)
    for b, toks in srv.flush().items():
        gen[b].extend(toks)
    return gen


# -------------------------------------------------------- greedy regression
@pytest.mark.parametrize("mode,kw", MODES,
                         ids=[f"{m}-{kw.get('round_mode', 'x')}"
                              for m, kw in MODES])
def test_temperature_zero_is_token_identical_to_greedy_build(mode, kw):
    """The pinned greedy regression: a SAMPLED build serving temperature=0
    requests must emit exactly the greedy build's token streams — the
    stochastic executables reduce to the greedy rule, not just approximate
    it."""
    prompts = _prompts()
    ref = _serve(_server(mode, **kw), prompts)
    out = _serve(_server(mode, sampling=GREEDY0, **kw), prompts)
    assert out == ref, f"{mode}/{kw} sampled@T=0 diverged from greedy build"


def test_greedy_build_rejects_stochastic_request():
    srv = _server("chain_fused", round_mode="single")
    with pytest.raises(ValueError, match="sampled server build"):
        srv.add_request(0, _prompts()[0], sampling=STOCH)
    # temperature=0 overrides are fine on greedy builds
    srv.add_request(0, _prompts()[0], sampling=GREEDY0)


# ------------------------------------------------- sampled smoke + metrics
@pytest.mark.parametrize("mode,kw", MODES[:1] + MODES[2:],
                         ids=["chain_fused", "tree_fused", "legacy",
                              "cascade_fused"])
def test_sampled_serving_is_seed_deterministic(mode, kw):
    """Stochastic serving is reproducible: per-request seeds pin the whole
    PRNG stream, so two fresh servers emit identical tokens. Also checks
    the sampled metrics surface."""
    prompts = _prompts()
    samp = [SamplingParams(temperature=0.8, top_k=20, top_p=0.9, seed=11 + i)
            for i in range(len(prompts))]
    runs = []
    for _ in range(2):
        srv = _server(mode, sampling=STOCH, **kw)
        runs.append(_serve(srv, prompts, sampling=samp))
    assert runs[0] == runs[1], f"{mode} sampled serving not seed-deterministic"
    assert all(len(t) > 0 for t in runs[0].values())
    assert all(0 <= tok < CFG.vocab_size
               for toks in runs[0].values() for tok in toks)
    m = srv.metrics_summary()
    assert m["sampled"] is True
    assert m["accepted_per_round"] is not None and m["accepted_per_round"] >= 1
    assert srv.metrics.counter("serve_sampled_requests_total").value == \
        len(prompts)


def test_mixed_batch_greedy_slot_unchanged():
    """Per-request params are per-slot device state: a greedy request
    sharing a batch with a stochastic one must still emit the greedy
    build's exact stream."""
    prompts = _prompts()
    ref = _serve(_server("chain_fused", round_mode="single"), prompts)
    srv = _server("chain_fused", sampling=STOCH, round_mode="single")
    out = _serve(srv, prompts,
                 sampling=[GREEDY0,
                           SamplingParams(temperature=0.9, top_k=0,
                                          top_p=0.95, seed=3)])
    assert out[0] == ref[0], "greedy slot perturbed by stochastic neighbor"
    assert len(out[1]) > 0
    assert srv.metrics.counter("serve_sampled_requests_total").value == 1


# --------------------------------------- distribution preservation (in law)
V = 16


def _tv(emp, target):
    return 0.5 * float(np.abs(emp - target).sum())


def _warped(g, sharp=1.0):
    q = warp_probs(g.normal(size=V) * sharp, temperature=1.0, top_k=12,
                   top_p=0.97)
    return q.astype(np.float32)


def _chain_first_token_marginal(trials, q, d_tok, seed):
    """Empirical first-token marginal of the fused chain rule with a
    point-mass draft at ``d_tok`` — must equal q exactly in law."""
    keys = jax.random.split(jax.random.PRNGKey(seed), trials)
    _, u = round_uniforms(keys, 2)
    chains = jnp.full((trials, 1), d_tok, jnp.int32)
    have = jnp.ones((trials,), jnp.int32)
    qb = jnp.broadcast_to(jnp.asarray(np.stack([q, q]))[None], (trials, 2, V))
    n, nxt = sample_accept_chain_batched(chains, have, qb, u[:, :1], u[:, 1])
    tok = np.where(np.asarray(n) >= 1, d_tok, np.asarray(nxt))
    return np.bincount(tok, minlength=V) / trials


def _tree_first_token_marginal(trials, tokens, parents, q, seed):
    """Empirical first-token marginal of the stochastic tree walk — the
    root step is exact sequential speculative sampling over the root's
    children, so the marginal must equal the root row of q."""
    N = len(tokens)
    keys = jax.random.split(jax.random.PRNGKey(seed), trials)
    _, u = round_uniforms(keys, N)
    toks = jnp.broadcast_to(jnp.asarray(tokens, jnp.int32)[None], (trials, N))
    pars = jnp.broadcast_to(jnp.asarray(parents, jnp.int32)[None], (trials, N))
    count = jnp.full((trials,), N, jnp.int32)
    qb = jnp.broadcast_to(jnp.asarray(q)[None], (trials, N, V))
    path, n_acc, nxt = sample_accept_tree_batched(toks, pars, count, qb, u)
    path, n_acc, nxt = np.asarray(path), np.asarray(n_acc), np.asarray(nxt)
    first = np.where(n_acc >= 2, tokens[path[:, 1]], nxt)
    return np.bincount(first, minlength=V) / trials


def _chain_case(seed):
    g = np.random.default_rng(seed)
    q = _warped(g)
    d_tok = int(np.argsort(-q)[g.integers(0, 3)])   # a plausible draft token
    return q, d_tok


def _tree_case(shape, seed):
    g = np.random.default_rng(seed)
    q = np.stack([_warped(g, sharp=1.0 + 0.2 * i) for i in range(6)])
    if shape == "tree":
        # chain-heavy fused tree: root -> {1, 2}, 1 -> {3, 4}, 3 -> {5}
        parents = np.array([-1, 0, 0, 1, 1, 3])
    else:
        # cascade-shaped: wide sibling fan at the root (multi-level drafts
        # endorse several candidates per node before the final walk)
        parents = np.array([-1, 0, 0, 0, 1, 1])
    tokens = np.zeros(6, np.int64)
    for p in np.unique(parents):
        kids = np.flatnonzero(parents == p)
        # siblings draft the target's own head tokens (dedup'd), the
        # realistic high-acceptance regime
        tokens[kids] = np.argsort(-q[max(p, 0)])[: len(kids)]
    return tokens.astype(np.int32), parents.astype(np.int32), q


def _assert_marginal(emp, target, bound, seed, label):
    tv = _tv(emp, target)
    assert tv < bound, (
        f"{label}: first-token max-TV {tv:.4f} >= {bound} (seed={seed}, "
        f"emp={np.round(emp, 4).tolist()}, "
        f"target={np.round(target, 4).tolist()})"
    )


@pytest.mark.parametrize("seed", [0, 1])
def test_chain_marginal_smoke(seed):
    q, d_tok = _chain_case(seed)
    emp = _chain_first_token_marginal(20_000, q, d_tok, seed=100 + seed)
    _assert_marginal(emp, q, 0.05, 100 + seed, "chain smoke")


@pytest.mark.parametrize("shape", ["tree", "cascade"])
def test_tree_marginal_smoke(shape):
    tokens, parents, q = _tree_case(shape, seed=2)
    emp = _tree_first_token_marginal(20_000, tokens, parents, q, seed=200)
    _assert_marginal(emp, q[0], 0.05, 200, f"{shape} smoke")


@pytest.mark.stat
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_chain_marginal_full(seed):
    """Acceptance bound: chain first-token max-TV < 0.02 at 200k trials."""
    q, d_tok = _chain_case(seed)
    emp = _chain_first_token_marginal(200_000, q, d_tok, seed=300 + seed)
    _assert_marginal(emp, q, 0.02, 300 + seed, "chain full")


@pytest.mark.stat
@pytest.mark.parametrize("shape", ["tree", "cascade"])
@pytest.mark.parametrize("seed", [0, 1])
def test_tree_marginal_full(shape, seed):
    """Acceptance bound: tree/cascade-shaped first-token max-TV < 0.02 at
    200k trials."""
    tokens, parents, q = _tree_case(shape, seed=seed)
    emp = _tree_first_token_marginal(
        200_000, tokens, parents, q, seed=400 + seed
    )
    _assert_marginal(emp, q[0], 0.02, 400 + seed, f"{shape} full")
