"""MoE dispatch properties: dropless batch-invariance (the losslessness
prerequisite), capacity semantics, grouped == ungrouped equivalence."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis", reason="needs hypothesis — pip install -r requirements-dev.txt")
from hypothesis import given, settings, strategies as st

from repro.config.base import MoEConfig
from repro.models import moe as moe_lib

MOE = MoEConfig(num_experts=4, top_k=2, d_ff_expert=32)
D = 16


@pytest.fixture(scope="module")
def params():
    return moe_lib.moe_init(jax.random.PRNGKey(0), D, MOE, True, jnp.float32)


@given(seed=st.integers(0, 1000), n1=st.integers(1, 6), n2=st.integers(1, 6))
@settings(max_examples=20, deadline=None)
def test_dropless_batch_invariance(seed, n1, n2):
    """A token's output must not depend on co-batched tokens (infer mode)."""
    params = moe_lib.moe_init(jax.random.PRNGKey(0), D, MOE, True, jnp.float32)
    key = jax.random.PRNGKey(seed)
    x1 = jax.random.normal(key, (1, n1, D))
    x2 = jax.random.normal(jax.random.fold_in(key, 1), (1, n2, D))
    y1, _ = moe_lib.moe_apply(params, x1, MOE, "silu", True, mode="infer")
    both = jnp.concatenate([x1, x2], axis=1)
    yb, _ = moe_lib.moe_apply(params, both, MOE, "silu", True, mode="infer")
    np.testing.assert_allclose(
        np.asarray(y1[0]), np.asarray(yb[0, :n1]), atol=1e-5, rtol=1e-5
    )


def test_dropless_equals_explicit_topk(params):
    """ragged-dot dispatch == explicit per-token top-k loop."""
    x = jax.random.normal(jax.random.PRNGKey(3), (1, 5, D))
    y, _ = moe_lib.moe_apply(params, x, MOE, "silu", True, mode="infer")
    xf = x.reshape(5, D)
    logits = xf @ params["w_router"]
    probs = jax.nn.softmax(logits, -1)
    w, ids = jax.lax.top_k(probs, MOE.top_k)
    w = w / w.sum(-1, keepdims=True)
    ref = jnp.zeros_like(xf)
    for t in range(5):
        acc = jnp.zeros(D)
        for j in range(MOE.top_k):
            e = int(ids[t, j])
            g = jax.nn.silu(xf[t] @ params["w_gate"][e]) * (xf[t] @ params["w_up"][e])
            acc += w[t, j] * (g @ params["w_down"][e])
        ref = ref.at[t].set(acc)
    if "shared" in params:
        from repro.models.layers import mlp_apply
        gate = jax.nn.sigmoid(xf @ params["w_shared_gate"])
        ref = ref + mlp_apply(params["shared"], xf, "silu", True) * gate
    np.testing.assert_allclose(np.asarray(y[0]), np.asarray(ref), atol=1e-4, rtol=1e-4)


def test_grouped_equals_ungrouped_when_no_drops(params):
    """With generous capacity, exec_groups must not change the math."""
    moe_hi = dataclasses.replace(MOE, capacity_factor=8.0)
    moe_g = dataclasses.replace(moe_hi, exec_groups=4)
    x = jax.random.normal(jax.random.PRNGKey(5), (2, 8, D))
    y1, _ = moe_lib.moe_apply(params, x, moe_hi, "silu", True, mode="train")
    y2, _ = moe_lib.moe_apply(params, x, moe_g, "silu", True, mode="train")
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-5, rtol=1e-5)


def test_capacity_drops_tokens(params):
    """Tiny capacity drops overflow tokens to the residual path (output 0)."""
    moe_tiny = dataclasses.replace(MOE, capacity_factor=0.01)
    x = jax.random.normal(jax.random.PRNGKey(6), (1, 16, D))
    y, _ = moe_lib.moe_apply(params, x, moe_tiny, "silu", True, mode="train")
    y_full, _ = moe_lib.moe_apply(params, x, MOE, "silu", True, mode="infer")
    # shared expert still applies; routed contribution largely dropped
    n_same = int(np.sum(np.all(np.isclose(y, y_full, atol=1e-5), axis=-1)))
    assert n_same < 16


def test_aux_losses_positive(params):
    x = jax.random.normal(jax.random.PRNGKey(7), (1, 32, D))
    _, aux = moe_lib.moe_apply(params, x, MOE, "silu", True, mode="train")
    assert float(aux["load_balance"]) > 0
    assert float(aux["router_z"]) >= 0


def test_gradients_flow(params):
    moe = dataclasses.replace(MOE, exec_groups=2)
    x = jax.random.normal(jax.random.PRNGKey(8), (2, 8, D))

    def loss(p):
        y, aux = moe_lib.moe_apply(p, x, moe, "silu", True, mode="train")
        return jnp.sum(y ** 2) + aux["load_balance"]

    g = jax.grad(loss)(params)
    gnorm = sum(float(jnp.sum(jnp.abs(l))) for l in jax.tree.leaves(g))
    assert np.isfinite(gnorm) and gnorm > 0
