"""Batched server losslessness + data pipeline statistics."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.config import get_config
from repro.core.cascade import ARScheduler
from repro.core.dsia import layer_sparsity
from repro.core.engine import SpecEngine
from repro.data import SPEC_TASKS, lm_batches, make_task_prompts, synthetic_corpus
from repro.data.tokenizer import ByteTokenizer
from repro.models import model as M
from repro.serving.scheduler import Request, RequestScheduler
from repro.serving.sampler import sample_token
from repro.serving.server import BatchedSpecServer

CFG = dataclasses.replace(get_config("vicuna-7b").reduced(), num_layers=4)
PARAMS = M.init_params(CFG, jax.random.PRNGKey(0))


def test_batched_server_lossless_vs_ar():
    srv = BatchedSpecServer(CFG, PARAMS, max_batch=3, max_len=256, draft_k=4,
                            draft_spec=layer_sparsity(CFG, 0.5))
    prompts = [
        np.array([5, 6, 7, 8] * 4, np.int32),
        np.array([9, 10, 11] * 5, np.int32),
        np.array([3, 4] * 6, np.int32),
    ]
    for i, p in enumerate(prompts):
        srv.add_request(i, p)
    gen = {i: [] for i in range(3)}
    for _ in range(10):
        for b, toks in srv.step().items():
            gen[b].extend(toks)
    for i, p in enumerate(prompts):
        eng = SpecEngine(CFG, PARAMS, max_len=256)
        eng.start(p)
        ref = ARScheduler(eng).generate(len(gen[i]))
        assert ref == gen[i], f"slot {i} diverged"
    # speculative batched serving must beat 1 token/seq/step on these prompts
    assert srv.stats["tokens"] / srv.stats["steps"] > 3.0


def test_request_scheduler_continuous_batching():
    s = RequestScheduler(max_batch=2)
    for i in range(4):
        s.submit(Request(prompt=np.arange(4), max_new_tokens=2))
    slots = s.admit()
    assert slots == [0, 1]
    for r in list(s.active.values()):
        r.generated = [1, 2]
    done = s.retire()
    assert len(done) == 2
    assert s.admit() == [0, 1]
    assert s.busy


def test_sampler_modes():
    logits = np.array([0.0, 5.0, 1.0])
    assert sample_token(logits) == 1
    rng = np.random.default_rng(0)
    counts = [0, 0, 0]
    for _ in range(300):
        counts[sample_token(logits, temperature=1.0, rng=rng)] += 1
    assert counts[1] > counts[0] and counts[1] > counts[2]
    # top_k=1 == greedy regardless of temperature
    assert sample_token(logits, temperature=5.0, top_k=1, rng=rng) == 1


def test_task_suite_copy_ordering():
    """Summarization/RAG prompts must carry more n-gram reuse than
    translation — the property Table 1's task spread rests on."""
    def reuse_rate(task):
        prompts = make_task_prompts(SPEC_TASKS[task], 20, 512, seed=1)
        hits = total = 0
        for p in prompts:
            seen = set()
            for i in range(3, len(p)):
                tri = tuple(p[i - 3 : i])
                hits += tri in seen
                seen.add(tri)
                total += 1
        return hits / total

    assert reuse_rate("summarization") > reuse_rate("mtbench") > reuse_rate("translation")


def test_tokenizer_roundtrip():
    t = ByteTokenizer()
    s = "hello, CAS-Spec! ünïcode"
    ids = t.encode(s, bos=True, eos=True)
    assert ids[0] == t.BOS and ids[-1] == t.EOS
    assert t.decode(ids) == s
    assert t.vocab_size % 64 == 0


def test_lm_batches_shapes():
    corpus = synthetic_corpus(512, 5_000)
    b = next(lm_batches(corpus, 4, 32))
    assert b["tokens"].shape == (4, 32)
    assert b["tokens"].dtype == np.int32
