"""Single-dispatch device-resident serving rounds (``round_mode="single"``):
stream parity with the split path and with AR, the dispatch-count/sync-count
regression contract (exactly ONE jitted dispatch and zero host syncs per
steady-state round; sync only every ``sync_every`` rounds), donated-cache
parity, the jitted admission slot write, and the pipelined ``ServeLoop``."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import get_config
from repro.core.cascade import ARScheduler
from repro.core.dsia import layer_sparsity
from repro.core.engine import SpecEngine
from repro.models import model as M
from repro.serving import Request, RequestScheduler, ServeLoop
from repro.serving.server import BatchedSpecServer

CFG = dataclasses.replace(get_config("vicuna-7b").reduced(), num_layers=3)
PARAMS = M.init_params(CFG, jax.random.PRNGKey(0))
SPEC = layer_sparsity(CFG, 0.5)


def _repetitive_prompts():
    return [
        np.array([5, 6, 7, 8] * 4, np.int32),
        np.array([9, 10, 11] * 5, np.int32),
    ]


def _random_prompts(n, length, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(4, CFG.vocab_size - 1, size=length).astype(np.int32)
            for _ in range(n)]


def _serve(mode, prompts, rounds, pin_prior_c=False, **kw):
    kwargs = dict(max_batch=len(prompts), max_len=256, draft_k=4,
                  draft_spec=SPEC, adaptive=False)
    kwargs.update(kw)
    srv = BatchedSpecServer(CFG, PARAMS, mode=mode, **kwargs)
    if pin_prior_c:
        # freeze the cost tracker at the cold-start ratio: c_hat keeps
        # returning the caller's default (= the spec prior) forever
        srv.costs.observe = lambda *a, **k: None
        srv.costs.observe_target = lambda *a, **k: None
    for i, p in enumerate(prompts):
        srv.add_request(i, p)
    gen = {i: [] for i in range(len(prompts))}
    for _ in range(rounds):
        for b, toks in srv.step().items():
            gen[b].extend(toks)
    for b, toks in srv.flush().items():
        gen[b].extend(toks)
    return srv, gen


def _ar_ref(prompt, n):
    eng = SpecEngine(CFG, PARAMS, max_len=256)
    eng.start(prompt)
    return ARScheduler(eng).generate(n)


# ------------------------------------------------------------ stream parity
@pytest.mark.parametrize("mode", ["chain_fused", "tree_fused"])
def test_single_matches_split_exactly(mode):
    """The fused single-dispatch round (device PLD + device seeding +
    in-dispatch verify/commit) must emit the identical per-slot streams the
    split path emits on the same prompts — same drafts, same accepts.

    The split tree path feeds a WALL-CLOCK-measured cost coefficient into
    the Alg. 1 stop rule while single mode prices with the spec prior (it
    cannot time its own fused dispatch), so for a same-policy comparison
    the split server's tracker is pinned to the prior — without it the
    tree variant would be timing-dependent. Both remain lossless either
    way (AR parity is pinned separately below)."""
    prompts = _repetitive_prompts()
    _, g_split = _serve(mode, prompts, 6, round_mode="split",
                        pin_prior_c=True)
    _, g_single = _serve(mode, prompts, 6, round_mode="single")
    assert g_split == g_single


@pytest.mark.parametrize("mode", ["chain_fused", "tree_fused"])
def test_single_adaptive_lossless_vs_ar(mode):
    """Donation + device PLD + on-device Eq. 4/5 routing enabled: greedy
    output stays token-identical to AR for every slot."""
    prompts = _repetitive_prompts()
    _, gen = _serve(mode, prompts, 8, round_mode="single", adaptive=True,
                    min_obs=1, sync_every=2)
    for i, p in enumerate(prompts):
        assert len(gen[i]) > 8       # speculative: beats 1 token/round
        assert _ar_ref(p, len(gen[i])) == gen[i], f"slot {i} diverged"


def test_single_context_buffer_tracks_stream():
    """The round's commit step maintains the device context buffer: after
    draining, ctx[:pos] must equal prompt + generated for every slot."""
    prompts = _repetitive_prompts()
    srv, gen = _serve("chain_fused", prompts, 5, round_mode="single")
    ctx = np.asarray(srv.dstate["ctx"])
    pos = np.asarray(srv.cache["pos"])
    for i, p in enumerate(prompts):
        want = list(p) + gen[i]
        assert pos[i] == len(want)
        assert list(ctx[i, : pos[i]]) == want


# -------------------------------------------------- dispatch/sync regression
def test_one_dispatch_zero_syncs_per_steady_round():
    """THE round-pipeline contract: a steady-state single-mode round is
    exactly ONE jitted dispatch and ZERO host syncs — the host blocks only
    every ``sync_every`` rounds. The jit cache must hold exactly one
    executable (no hidden per-round retraces)."""
    prompts = _random_prompts(2, 24)
    srv, _ = _serve("chain_fused", prompts, 8, round_mode="single",
                    sync_every=4)
    assert srv.stats["round_dispatches"] == 8
    assert srv.stats["target_calls"] == 8
    assert srv.stats["draft_dispatches"] == 0      # no separate draft call
    # flush() after the loop adds nothing: rounds 1-4 and 5-8 each drained
    # at their sync point -> exactly 2 sync events for 8 rounds
    assert srv.stats["host_syncs"] == 2
    if hasattr(srv._round_fn, "_cache_size"):
        assert srv._round_fn._cache_size() == 1    # one executable, ever
    # tokens were still all accounted for despite the lazy drains
    assert srv.stats["tokens"] >= 8 * len(prompts)


def test_tree_single_dispatch_counts():
    prompts = _random_prompts(2, 24, seed=1)
    srv, _ = _serve("tree_fused", prompts, 6, round_mode="single",
                    sync_every=3)
    assert srv.stats["round_dispatches"] == 6
    assert srv.stats["draft_dispatches"] == 0
    assert srv.stats["host_syncs"] == 2
    if hasattr(srv._round_fn, "_cache_size"):
        assert srv._round_fn._cache_size() == 1


def test_cascade_dispatches_at_most_levels_plus_one():
    """An L-level cascade round stays within L+1 jitted dispatches — the
    target verify rides the LAST rescore dispatch (cascade_rescore_verify),
    so a fully-rescored round is 1 draft + (L-1) rescores = L dispatches."""
    srv = BatchedSpecServer(CFG, PARAMS, max_batch=2, max_len=256, draft_k=4,
                            mode="cascade_fused", adaptive=False)
    L = len(srv.bank)
    assert L >= 2
    for i, p in enumerate(_random_prompts(2, 24, seed=2)):
        srv.add_request(i, p)
    n_rounds = 4
    for _ in range(n_rounds):
        srv.step()
    dispatches = (srv.stats["draft_dispatches"]
                  + srv.stats["rescore_dispatches"])
    assert dispatches == n_rounds * L              # verify folded, not extra
    assert srv.stats["target_calls"] == n_rounds   # ...but still counted
    assert dispatches <= n_rounds * (L + 1)


def test_single_mode_rejected_for_legacy_and_cascade():
    with pytest.raises(ValueError):
        BatchedSpecServer(CFG, PARAMS, mode="legacy", round_mode="single")
    with pytest.raises(ValueError):
        BatchedSpecServer(CFG, PARAMS, mode="cascade_fused",
                          round_mode="single")


# -------------------------------------------------------- on-device routing
def test_device_routing_stops_drafting():
    """An unmeetable t_min must drive the on-device Eq. 5 budgets to zero
    once the carried Eq. 4 state warms up — and output stays lossless."""
    prompts = _random_prompts(2, 16, seed=3)
    srv, gen = _serve("chain_fused", prompts, 6, round_mode="single",
                      adaptive=True, min_obs=1, t_min=1e9)
    for i, p in enumerate(prompts):
        assert _ar_ref(p, len(gen[i])) == gen[i]
    assert srv._slot_limit(0) == 0 and srv._slot_limit(1) == 0
    # the device EMA actually observed outcomes (PLD-silent prompts)
    assert int(srv.dstate["hist_n"][0]) >= 1


# ------------------------------------------------------------------ donation
def test_donated_and_nondonated_rounds_agree():
    prompts = _repetitive_prompts()
    _, g_don = _serve("chain_fused", prompts, 6, round_mode="single",
                      donate=True)
    _, g_nod = _serve("chain_fused", prompts, 6, round_mode="single",
                      donate=False)
    assert g_don == g_nod


# ------------------------------------------------------------------ admission
def test_write_slot_matches_host_copy():
    """The jitted admission write (one dynamic-update per leaf, donated)
    must equal the old host-side tree.map copy."""
    cache = M.init_cache(CFG, 3, 64)
    c1 = M.init_cache(CFG, 1, 64)
    _, c1 = M.prefill(CFG, PARAMS, {"tokens": jnp.asarray(
        np.array([[5, 6, 7, 8, 9]], np.int32))}, c1)
    got = M.write_slot(CFG, cache, c1, jnp.asarray(1, jnp.int32))
    want_segments = jax.tree.map(
        lambda dst, src: dst.at[:, 1].set(src[:, 0]),
        cache["segments"], c1["segments"],
    )
    want = {"pos": cache["pos"].at[1].set(c1["pos"][0]),
            "segments": want_segments}
    for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b))


# ----------------------------------------------------------- pipelined loop
def test_pipelined_serveloop_continuous_batching():
    """More requests than slots under sync_every > 1: the loop must drain
    in-flight rounds before re-binding a slot, so every request receives
    exactly its own AR stream (no cross-request token bleed) trimmed to
    max_new_tokens."""
    srv = BatchedSpecServer(CFG, PARAMS, max_batch=2, max_len=256, draft_k=4,
                            draft_spec=SPEC, adaptive=False,
                            round_mode="single", sync_every=3)
    sched = RequestScheduler(max_batch=2)
    prompts = _repetitive_prompts() + _random_prompts(2, 12, seed=7)
    reqs = [Request(prompt=p, max_new_tokens=9) for p in prompts]
    for r in reqs:
        sched.submit(r)
    finished = ServeLoop(srv, sched).run(max_steps=200)
    assert len(finished) == len(reqs)
    for r in reqs:
        assert len(r.generated) == 9
        assert _ar_ref(r.prompt, 9) == r.generated
