"""Block-paged KV serving is LOSSLESS and keeps the dispatch discipline.

The paged cache is a placement decision, never a numerical one: attention
gathers pool pages through the slot's page table into exactly the dense
layout, and the `kv_pos` invalid-position masking (pinned at the kernel
level in test_kernels.py) makes unallocated / partial-tail pages inert.
So every server mode must produce TOKEN-IDENTICAL output on a paged build
— greedy and sampled — and the compiled round must stay one donated
executable with zero steady-state host syncs (PR 6 contracts hold on the
paged executables, not just the dense ones).

Chunked prefill (``prefill_chunk>0``) changes WHEN a prompt's tokens are
consumed, not WHAT the model computes on them: streams are per-slot
prefix-identical to the dense server (they lag by the prefill rounds),
and decoding slots keep producing tokens while a long prompt chunks in —
the non-blocking-admission property the feature exists for.

The mesh test runs in a SUBPROCESS (forced 8-device CPU mesh) like
test_server_sharded.py: the paged pool replicates over ``data``, shards
KV heads over ``model``, and the page table rides the batch axis — token
identity and the single-donated-dispatch contract must survive both.
"""
import dataclasses
import json
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

from repro.analysis.contracts import server_round_contracts
from repro.config import get_config
from repro.core.dsia import layer_sparsity
from repro.models import model as M
from repro.serving.sampler import SamplingParams
from repro.serving.server import BatchedSpecServer

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

CFG = dataclasses.replace(get_config("vicuna-7b").reduced(), num_layers=3)
PARAMS = M.init_params(CFG, jax.random.PRNGKey(0))
SPEC = layer_sparsity(CFG, 0.5)

MODES = ["chain_fused", "legacy", "tree_fused", "cascade_fused"]

_rng = np.random.default_rng(3)
PROMPTS = [_rng.integers(2, CFG.vocab_size, size=n).astype(np.int32)
           for n in (8, 19)]


def _server(mode, paged, **kw):
    kwargs = dict(max_batch=2, max_len=128, draft_k=4, tree_expansions=3,
                  adaptive=True, min_obs=1, donate=True)
    if mode != "cascade_fused":
        kwargs["draft_spec"] = SPEC
    if paged:
        # page_size chosen to force multi-page slots AND a partial tail
        # page for the 19-token prompt
        kwargs.update(paged=True, page_size=16)
    kwargs.update(kw)
    return BatchedSpecServer(CFG, PARAMS, mode=mode, **kwargs)


def _run(srv, rounds, prompts=PROMPTS, sampling=None):
    for i, p in enumerate(prompts):
        if sampling is not None:
            srv.add_request(i, p, sampling=sampling)
        else:
            srv.add_request(i, p)
    gen = {i: [] for i in range(len(prompts))}
    for _ in range(rounds):
        for b, t in srv.step().items():
            gen[b].extend(t)
    for b, t in srv.flush().items():
        gen[b].extend(t)
    return gen


# ------------------------------------------------------------ losslessness
@pytest.mark.parametrize("mode", MODES)
def test_paged_token_identity_greedy(mode):
    """Every mode, greedy: the paged build routes the EXACT dense streams."""
    dense = _run(_server(mode, paged=False), rounds=5)
    paged = _run(_server(mode, paged=True), rounds=5)
    assert sum(len(v) for v in dense.values()) > 0
    assert paged == dense, f"{mode}: paged streams diverged from dense"


@pytest.mark.parametrize("mode", MODES)
def test_paged_token_identity_sampled(mode):
    """Every mode, seeded stochastic verify: same tokens, same key walk.

    ``adaptive=False``: the DyTC planner sizes draft trees from WALL-CLOCK
    cost EMAs, so two adaptive servers only consume their sampling keys in
    lockstep when their dispatch timings agree — a bitwise dense-vs-paged
    comparison must pin the plan (greedy streams are plan-invariant, so the
    greedy test above keeps the adaptive path covered). Same reasoning as
    test_sampled_serving.py."""
    sp = SamplingParams(temperature=0.9, top_k=40, seed=11)
    dense = _run(_server(mode, paged=False, adaptive=False, sampling=sp),
                 rounds=5)
    paged = _run(_server(mode, paged=True, adaptive=False, sampling=sp),
                 rounds=5)
    assert sum(len(v) for v in dense.values()) > 0
    assert paged == dense, f"{mode}: sampled paged streams diverged"


def test_paged_partial_tail_and_table_reuse():
    """Slot release returns pages to the pool; a later admission reusing
    those (now differently ordered) physical pages still reproduces the
    dense streams — physical page identity is invisible to the model."""
    srv = _server("chain_fused", paged=True)
    ref = _run(_server("chain_fused", paged=False), rounds=4)
    first = _run(srv, rounds=4)
    assert first == ref
    for s in range(len(PROMPTS)):
        srv.release(s)
    again = _run(srv, rounds=4)
    assert again == ref, "page reuse after release changed the streams"


# ------------------------------------------------------- dispatch discipline
@pytest.mark.parametrize("mode,single", [("chain_fused", True),
                                         ("tree_fused", True),
                                         ("cascade_fused", False)])
def test_paged_round_contracts(mode, single):
    """PR 6 contracts pinned on the PAGED executables: single-mode rounds
    stay ONE donated dispatch, no executable re-enters the host, and the
    paged build costs zero extra host syncs over dense."""
    # adaptive=False for the cascade comparison: the adaptive planner may
    # skip a level's dispatch (expansions=0) based on wall-clock cost EMAs,
    # which would make the dense/paged host_syncs comparison timing-luck
    kw = dict(round_mode="single") if single else dict(adaptive=False)
    dn = _server(mode, paged=False, **kw)
    pg = _server(mode, paged=True, **kw)
    _run(dn, rounds=3)
    _run(pg, rounds=3)
    assert pg.stats["round_dispatches"] == dn.stats["round_dispatches"]
    assert pg.stats["host_syncs"] == dn.stats["host_syncs"]
    cons = server_round_contracts(pg)
    for c in cons.values():
        c.assert_no_host_callbacks()
    if single:
        cons["round"].assert_donated()


# ------------------------------------------------------------- page pool
def test_page_pool_budget_and_exhaustion():
    """``max_new_tokens`` shrinks a slot's page allocation below the full
    max_len reservation; an undersized pool fails loudly at admission."""
    srv = _server("chain_fused", paged=True)
    full = srv._pages_per_slot
    srv.add_request(0, PROMPTS[0], max_new_tokens=4)
    assert 0 < len(srv._slot_pages[0]) < full
    srv.release(0)
    assert len(srv._free_pages) == 2 * full
    # pool with a single page: a multi-page prompt cannot be admitted
    tiny = _server("chain_fused", paged=True, num_pages=1)
    with pytest.raises(RuntimeError, match="page pool"):
        tiny.add_request(0, PROMPTS[1])


def test_paged_rejects_unpageable_builds():
    with pytest.raises(ValueError):
        _server("chain_fused", paged=True, page_size=48)  # 128 % 48 != 0
    with pytest.raises(ValueError):
        BatchedSpecServer(CFG, PARAMS, draft_spec=SPEC,
                          prefill_chunk=8)  # chunked requires paged


# -------------------------------------------------------- chunked prefill
@pytest.mark.parametrize("mode", ["chain_fused", "tree_fused"])
def test_chunked_prefill_prefix_parity(mode):
    """Chunked streams are per-slot PREFIXES of the dense streams: the
    round dispatch consumes the prompt `prefill_chunk` tokens at a time,
    so tokens lag by the prefill rounds but never differ."""
    dense = _run(_server(mode, paged=False), rounds=5)
    chunk = _run(_server(mode, paged=True, prefill_chunk=8), rounds=8)
    for s, ref in dense.items():
        got = chunk[s]
        n = min(len(ref), len(got))
        assert n > 2, f"{mode} slot {s}: chunked produced almost nothing"
        assert got[:n] == ref[:n], f"{mode} slot {s}: chunked prefix diverged"


def test_chunked_prefill_sampled_prefix_parity():
    """The chunked path's on-device key split at prompt completion is
    bit-identical to dense admission's host-side split: seeded sampled
    streams stay prefix-identical too."""
    sp = SamplingParams(temperature=0.8, top_k=0, top_p=0.95, seed=5)
    dense = _run(_server("chain_fused", paged=False, sampling=sp), rounds=5)
    chunk = _run(_server("chain_fused", paged=True, prefill_chunk=8,
                         sampling=sp), rounds=8)
    for s, ref in dense.items():
        n = min(len(ref), len(chunk[s]))
        assert n > 2 and chunk[s][:n] == ref[:n], f"slot {s} diverged"


def test_chunked_prefill_is_nonblocking():
    """THE point of chunked prefill: decoding slots keep emitting tokens
    during the rounds in which a freshly admitted LONG prompt is still
    consuming its chunks — admission never stalls the batch."""
    srv = _server("chain_fused", paged=True, prefill_chunk=8,
                  max_batch=2, max_len=256)
    long_prompt = _rng.integers(2, CFG.vocab_size, size=100).astype(np.int32)
    srv.add_request(0, PROMPTS[0])
    warm = []
    for _ in range(2):
        warm.extend(srv.step().get(0, []))
    # admit the 100-token prompt: 13 chunked rounds before its first token
    srv.add_request(1, long_prompt)
    during = {0: [], 1: []}
    for _ in range(6):
        for b, t in srv.step().items():
            during[b].extend(t)
    assert len(during[0]) >= 4, "short slot stalled during chunked prefill"
    assert during[1] == [], "long prompt emitted before its prefill finished"
    after = {0: [], 1: []}
    for _ in range(16):
        for b, t in srv.step().items():
            after[b].extend(t)
    for b, t in srv.flush().items():
        after[b].extend(t)
    assert len(after[1]) > 0, "long prompt never completed its prefill"


def test_chunked_prefill_requires_single_round():
    with pytest.raises(ValueError):
        _server("legacy", paged=True, prefill_chunk=8)
    with pytest.raises(ValueError):
        _server("cascade_fused", paged=True, prefill_chunk=8)


# ------------------------------------------------------------ mesh parity
SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    os.environ.setdefault("JAX_PLATFORMS", "cpu")
    import dataclasses, json
    import jax
    import numpy as np
    from repro.analysis.contracts import server_round_contracts
    from repro.config import get_config
    from repro.core.dsia import layer_sparsity
    from repro.launch.mesh import make_mesh_compat
    from repro.models import model as M
    from repro.serving.sampler import SamplingParams
    from repro.serving.server import BatchedSpecServer

    CFG = dataclasses.replace(get_config("vicuna-7b").reduced(), num_layers=3)
    PARAMS = M.init_params(CFG, jax.random.PRNGKey(0))
    SPEC = layer_sparsity(CFG, 0.5)
    MESH = make_mesh_compat((4, 2), ("data", "model"))
    B, ROUNDS = 4, 5
    rng = np.random.default_rng(0)
    prompts = [rng.integers(2, CFG.vocab_size, size=n).astype(np.int32)
               for n in (8, 19, 6, 10)]

    def run(mode, mesh, paged, sampling=None):
        # adaptive=False: the legacy/cascade planners consume wall-clock
        # cost EMAs, so adaptive dispatch counts (and sampled key walks)
        # only agree between two servers by timing luck — this test pins
        # parity and contracts, the adaptive path is covered elsewhere
        kw = dict(max_batch=B, max_len=128, draft_k=4, tree_expansions=3,
                  adaptive=False, donate=True, sampling=sampling)
        if mode != "cascade_fused":
            kw["draft_spec"] = SPEC
        if paged:
            kw.update(paged=True, page_size=16)
        srv = BatchedSpecServer(CFG, PARAMS, mode=mode, mesh=mesh, **kw)
        for i, p in enumerate(prompts):
            srv.add_request(i, p)
        gen = {i: [] for i in range(B)}
        for _ in range(ROUNDS):
            for b, t in srv.step().items():
                gen[b].extend(t)
        for b, t in srv.flush().items():
            gen[b].extend(t)
        return gen, srv

    SP = SamplingParams(temperature=0.9, top_k=40, seed=7)
    results = {}
    for mode in ["chain_fused", "legacy", "tree_fused", "cascade_fused"]:
        sampling = SP if mode == "chain_fused" else None
        # sampled streams are only reproducible against a dense baseline
        # on the SAME mesh: resharding reorders the model-axis psum, and
        # an ulp shift in the logits can cross a sampling threshold
        # (greedy mesh-vs-single identity is pinned in
        # test_server_sharded.py, so the greedy legs keep the stronger
        # single-device dense reference here)
        g_ref, srv_ref = run(mode, MESH if sampling else None,
                             paged=False, sampling=sampling)
        g_pg, srv_pg = run(mode, MESH, paged=True, sampling=sampling)
        res = {
            "identical": g_ref == g_pg,
            "n_tokens": sum(len(v) for v in g_ref.values()),
            "round_dispatches": [srv_ref.stats["round_dispatches"],
                                 srv_pg.stats["round_dispatches"]],
            "host_syncs": [srv_ref.stats["host_syncs"],
                           srv_pg.stats["host_syncs"]],
        }
        cons = server_round_contracts(srv_pg)
        for c in cons.values():
            c.assert_no_host_callbacks()
        if srv_pg.round_mode == "single":
            con = cons["round"]
            con.assert_donated().assert_sharding()
            con.assert_no_collectives("all-to-all")
            res["sharded_entry_params"] = len(con.sharded_params)
            res["single_round"] = True
        else:
            res["sharded_entry_params"] = max(
                len(c.sharded_params) for c in cons.values()
            )
            res["single_round"] = False
        results[mode] = res
    print(json.dumps(results))
    """
)


@pytest.mark.slow
def test_paged_sharded_token_identity_and_contracts():
    """8-device mesh, paged build vs a DENSE build: exact token parity
    (greedy modes against single-device dense; the sampled chain_fused leg
    against dense on the same mesh — see the comment in SCRIPT) and the
    compiled paged round is still one donated, sharded, host-free
    executable."""
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    out = subprocess.run(
        [sys.executable, "-c", SCRIPT], capture_output=True, text=True,
        env=env, timeout=540,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert set(res) == set(MODES)
    for mode, r in res.items():
        assert r["identical"], f"{mode}: paged-on-mesh tokens diverged"
        assert r["n_tokens"] > 0, f"{mode}: generated nothing"
        assert r["round_dispatches"][0] == r["round_dispatches"][1], mode
        assert r["host_syncs"][0] == r["host_syncs"][1], mode
        assert r["sharded_entry_params"] > 0, f"{mode}: nothing sharded"
    for mode in ("chain_fused", "tree_fused"):
        assert res[mode]["single_round"]
