"""Mesh-sharded batched serving: token identity + dispatch discipline.

Runs in a SUBPROCESS because the device count must be forced before jax
initializes (the rest of the suite must see the single real device). On a
forced 8-device CPU mesh (``data=4, model=2``) every server mode must:

  - produce greedy output token-identical to the same server on a single
    device (sharding is a placement decision, never a sampling one);
  - keep its dispatch discipline: ``round_dispatches``/``host_syncs``
    identical to the single-device run — the mesh adds collectives INSIDE
    the round executable, never extra dispatches or host syncs around it;
  - prove the placement on the COMPILED artifact: the single-dispatch
    chain/tree round keeps split entry-param shardings
    (``HloContract.assert_sharding``), stays donated, never re-enters the
    host, and carries no resharding all-to-alls (``assert_no_collectives``).

The sharded and single-device servers run in the SAME process on purpose:
the server's explicit per-server placements (``mesh=`` kwarg, no global
mesh) must not leak into servers constructed without a mesh.
"""
import json
import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

MODES = ["chain_fused", "legacy", "tree_fused", "cascade_fused"]

SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    os.environ.setdefault("JAX_PLATFORMS", "cpu")
    import dataclasses, json
    import jax
    import numpy as np
    from repro.analysis.contracts import server_round_contracts
    from repro.config import get_config
    from repro.core.dsia import layer_sparsity
    from repro.launch.mesh import make_mesh_compat
    from repro.models import model as M
    from repro.serving.server import BatchedSpecServer

    CFG = dataclasses.replace(get_config("vicuna-7b").reduced(), num_layers=3)
    PARAMS = M.init_params(CFG, jax.random.PRNGKey(0))
    SPEC = layer_sparsity(CFG, 0.5)
    MESH = make_mesh_compat((4, 2), ("data", "model"))
    B, ROUNDS = 4, 6
    rng = np.random.default_rng(0)
    prompts = [rng.integers(2, CFG.vocab_size, size=n).astype(np.int32)
               for n in (8, 12, 6, 10)]

    def run(mode, mesh):
        kw = dict(max_batch=B, max_len=128, draft_k=4, tree_expansions=3,
                  adaptive=True, min_obs=1, donate=True)
        if mode != "cascade_fused":
            kw["draft_spec"] = SPEC
        srv = BatchedSpecServer(CFG, PARAMS, mode=mode, mesh=mesh, **kw)
        for i, p in enumerate(prompts):
            srv.add_request(i, p)
        gen = {i: [] for i in range(B)}
        for _ in range(ROUNDS):
            for b, t in srv.step().items():
                gen[b].extend(t)
        for b, t in srv.flush().items():
            gen[b].extend(t)
        return gen, srv

    results = {}
    for mode in ["chain_fused", "legacy", "tree_fused", "cascade_fused"]:
        g1, srv1 = run(mode, None)
        g2, srv2 = run(mode, MESH)
        res = {
            "identical": g1 == g2,
            "n_tokens": sum(len(v) for v in g1.values()),
            "round_dispatches": [srv1.stats["round_dispatches"],
                                 srv2.stats["round_dispatches"]],
            "host_syncs": [srv1.stats["host_syncs"], srv2.stats["host_syncs"]],
        }
        cons = server_round_contracts(srv2)
        for c in cons.values():
            c.assert_no_host_callbacks()
        if srv2.round_mode == "single":
            con = cons["round"]
            con.assert_donated().assert_sharding()
            con.assert_no_collectives("all-to-all")
            res["sharded_entry_params"] = len(con.sharded_params)
            res["collectives"] = con.collective_counts
            res["single_round"] = True
        else:
            res["sharded_entry_params"] = max(
                len(c.sharded_params) for c in cons.values()
            )
            res["single_round"] = False
        results[mode] = res
    print(json.dumps(results))
    """
)


@pytest.mark.slow
def test_sharded_serving_token_identity_and_contracts():
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    out = subprocess.run(
        [sys.executable, "-c", SCRIPT], capture_output=True, text=True,
        env=env, timeout=540,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert set(res) == set(MODES)
    for mode, r in res.items():
        # losslessness is placement-independent: greedy tokens must match
        # the single-device server exactly, for every slot
        assert r["identical"], f"{mode}: sharded tokens diverged"
        assert r["n_tokens"] > 0, f"{mode}: generated nothing"
        # the mesh never costs an extra dispatch or host sync
        assert r["round_dispatches"][0] == r["round_dispatches"][1], mode
        assert r["host_syncs"][0] == r["host_syncs"][1], mode
        # placement survived to the compiled executable
        assert r["sharded_entry_params"] > 0, f"{mode}: nothing sharded"
    # the tentpole: single-dispatch rounds stayed single-dispatch, donated,
    # sharded, and communicate only through TP collectives
    for mode in ("chain_fused", "tree_fused"):
        assert res[mode]["single_round"]
        assert any(k.startswith("all-") for k in res[mode]["collectives"]), (
            f"{mode}: no collectives — the model axis did nothing"
        )
