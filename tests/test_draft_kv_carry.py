"""Staged-KV carry in the fused draft scans (``draft_kv="carry"``).

The parity contract: carry-mode drafting — decode only the <= top_k newly
appended tokens per expansion step against [committed cache ++ carried
staged KV] — must produce BIT-IDENTICAL integer outputs (tokens, parents,
depth, mask, count, first_neural) to the O(E*N) full-block recompute, for
chain, tree, and cascade-drafter execution, across tree buckets. On top of
that, serving in carry mode must stay lossless (greedy == AR) and drafting
must never touch the committed cache's ``pos``.

(The bit-exact assertions rest on per-node logits being the same function
of the same visible set in both modes; the softmax partials ARE merged in
a different order, so a ~1-ULP near-tie between top-k candidates could in
principle flip a drafted token on some backend/compiler combination. The
fixed params/prompts here are deterministic per backend — if a jax/XLA
bump ever flips one, loosen to token-level equality, not allclose: parity
of the DRAFTED TREE is the contract, losslessness never depends on it.)
"""
import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import get_config
from repro.core.cascade import ARScheduler
from repro.core.dsia import layer_sparsity
from repro.core.engine import (
    SpecEngine,
    chain_draft_scan,
    fake_quant_int8,
    tree_draft_scan,
)
from repro.core.tree import tree_seed_arrays
from repro.models import model as M
from repro.serving.server import BatchedSpecServer

CFG = dataclasses.replace(get_config("vicuna-7b").reduced(), num_layers=3)
PARAMS = M.init_params(CFG, jax.random.PRNGKey(0))
SPEC = layer_sparsity(CFG, 0.5)
GATES = jnp.asarray(SPEC.gates_array(CFG.num_layers))

TREE_INT_OUTS = ("tokens", "parents", "depth", None, "mask", "count", "first_neural")


def _prefilled(B, length, seed=0, max_len=128):
    rng = np.random.default_rng(seed)
    prompts = rng.integers(4, CFG.vocab_size - 1, size=(B, length)).astype(np.int32)
    cache = M.init_cache(CFG, B, max_len)
    last, cache = M.prefill(CFG, PARAMS, {"tokens": jnp.asarray(prompts)}, cache)
    return jnp.argmax(last, -1).astype(jnp.int32), cache, rng


def _assert_tree_outs_equal(rec, car):
    for i, name in enumerate(TREE_INT_OUTS):
        if name is None:          # p_acc is float — ULP-tolerant
            np.testing.assert_allclose(rec[i], car[i], atol=1e-5)
        else:
            assert np.array_equal(rec[i], car[i]), f"{name} diverged"


def test_chain_carry_parity():
    """carry == recompute for chain drafting, including PLD prefixes that
    must not be overwritten and slots whose adaptive limit stops early."""
    pending, cache, rng = _prefilled(3, 12)
    K = 4
    chains = rng.integers(4, CFG.vocab_size - 1, size=(3, K)).astype(np.int32)
    have = jnp.asarray([0, 2, 4], jnp.int32)
    limit = jnp.asarray([4, 4, 1], jnp.int32)
    outs = {}
    for mode in ("recompute", "carry"):
        fn = jax.jit(functools.partial(chain_draft_scan, CFG, K, draft_kv=mode))
        ch, hv = fn(PARAMS, cache, pending, jnp.asarray(chains), have, limit, GATES)
        outs[mode] = (np.asarray(ch), np.asarray(hv))
    assert np.array_equal(outs["recompute"][0], outs["carry"][0])
    assert np.array_equal(outs["recompute"][1], outs["carry"][1])


@pytest.mark.parametrize("bucket", [8, 16, 32])
def test_tree_carry_parity_across_buckets(bucket):
    """carry == recompute for tree drafting at every bucket padding —
    including N=32, where recompute decodes a 32-wide block per expansion
    and carry decodes only top_k=2 candidates."""
    pending, cache, rng = _prefilled(3, 10, seed=bucket)
    pld = rng.integers(4, CFG.vocab_size - 1, size=(3, 4)).astype(np.int32)
    have = np.array([2, 0, 1], np.int32)
    seed = tree_seed_arrays(np.asarray(pending), pld, have, bucket)
    pos_before = np.asarray(cache["pos"]).copy()
    outs = {}
    for mode in ("recompute", "carry"):
        fn = jax.jit(functools.partial(tree_draft_scan, CFG, 5, 2, draft_kv=mode))
        out = fn(PARAMS, cache, *(jnp.asarray(a) for a in seed),
                 jnp.asarray([5, 5, 3], jnp.int32),
                 jnp.asarray([0.6, 0.6, 0.6], jnp.float32),
                 jnp.asarray(0.3, jnp.float32), jnp.asarray(1.0, jnp.float32),
                 GATES)
        outs[mode] = [np.asarray(a) for a in out]
    _assert_tree_outs_equal(outs["recompute"], outs["carry"])
    # something actually grew, and drafting never advanced the cache
    assert (outs["carry"][5] > have + 1).any()
    assert np.array_equal(np.asarray(cache["pos"]), pos_before)


def test_cascade_drafter_carry_parity():
    """carry == recompute under the cascade drafter's generalized execution
    (fake-quant int8 params + a streaming attention override + no gates) —
    the kwargs ``cascade_fused`` binds into its drafting scan."""
    pending, cache, rng = _prefilled(2, 10, seed=7)
    qparams = fake_quant_int8(PARAMS)
    override = {"kind": "streaming", "window": 8, "sink": 2}
    pld = rng.integers(4, CFG.vocab_size - 1, size=(2, 4)).astype(np.int32)
    have = np.array([1, 0], np.int32)
    seed = tree_seed_arrays(np.asarray(pending), pld, have, 16)
    outs = {}
    for mode in ("recompute", "carry"):
        fn = jax.jit(functools.partial(
            tree_draft_scan, CFG, 4, 2, attn_override=override, draft_kv=mode,
        ))
        out = fn(qparams, cache, *(jnp.asarray(a) for a in seed),
                 jnp.asarray([4, 4], jnp.int32),
                 jnp.asarray([0.6, 0.6], jnp.float32),
                 jnp.asarray(0.3, jnp.float32), jnp.asarray(1.0, jnp.float32),
                 None)
        outs[mode] = [np.asarray(a) for a in out]
    _assert_tree_outs_equal(outs["recompute"], outs["carry"])


def test_draft_kv_validation():
    with pytest.raises(ValueError, match="unknown draft_kv"):
        chain_draft_scan(CFG, 2, PARAMS, {}, None, jnp.zeros((1, 2), jnp.int32),
                         None, None, None, draft_kv="nope")
    ssm_cfg = get_config("mamba2-130m").reduced()
    with pytest.raises(ValueError, match="attention-only"):
        chain_draft_scan(ssm_cfg, 2, PARAMS, {}, None,
                         jnp.zeros((1, 2), jnp.int32), None, None, None,
                         draft_kv="carry")
    with pytest.raises(ValueError, match="unknown draft_kv"):
        BatchedSpecServer(CFG, PARAMS, draft_kv="nope")
    with pytest.raises(ValueError, match="attention-only"):
        BatchedSpecServer(ssm_cfg, PARAMS, draft_kv="carry")
    # auto degrades to recompute on SSM stacks instead of raising
    srv = BatchedSpecServer(ssm_cfg, PARAMS, draft_kv="auto")
    assert srv.draft_kv == "recompute"
    assert BatchedSpecServer(CFG, PARAMS).draft_kv == "carry"


def _run_server(mode, draft_kv, prompts, rounds, **kw):
    kwargs = dict(max_batch=len(prompts), max_len=256, draft_k=4,
                  adaptive=False, draft_kv=draft_kv)
    if mode != "cascade_fused":
        kwargs["draft_spec"] = SPEC
    kwargs.update(kw)
    srv = BatchedSpecServer(CFG, PARAMS, mode=mode, **kwargs)
    for i, p in enumerate(prompts):
        srv.add_request(i, p)
    gen = {i: [] for i in range(len(prompts))}
    for _ in range(rounds):
        for b, toks in srv.step().items():
            gen[b].extend(toks)
    return srv, gen


@pytest.mark.parametrize("mode", ["chain_fused", "tree_fused", "cascade_fused"])
def test_server_carry_matches_recompute(mode):
    """Every fused serving mode emits the identical greedy stream whether
    its drafting scan carries staged KV or recomputes the block."""
    rng = np.random.default_rng(11)
    prompts = [rng.integers(4, CFG.vocab_size - 1, size=14).astype(np.int32)
               for _ in range(2)]
    outs = []
    for draft_kv in ("carry", "recompute"):
        _, gen = _run_server(mode, draft_kv, prompts, rounds=5)
        outs.append(gen)
    assert outs[0] == outs[1]


def test_server_carry_lossless_vs_ar():
    """Greedy output through BatchedSpecServer in carry mode is
    token-identical to plain AR decoding for every slot (losslessness)."""
    prompts = [
        np.array([5, 6, 7, 8] * 4, np.int32),
        np.array([9, 10, 11] * 5, np.int32),
    ]
    _, gen = _run_server("tree_fused", "carry", prompts, rounds=7)
    for i, p in enumerate(prompts):
        eng = SpecEngine(CFG, PARAMS, max_len=256)
        eng.start(p)
        ref = ARScheduler(eng).generate(len(gen[i]))
        assert ref == gen[i], f"slot {i} diverged from AR"


def test_server_carry_pos_untouched_by_drafting():
    """A drafting dispatch must never advance the committed cache — only
    the verify+commit half moves ``pos`` (the losslessness invariant the
    carry buffers must not break)."""
    rng = np.random.default_rng(3)
    prompts = [rng.integers(4, CFG.vocab_size - 1, size=12).astype(np.int32)
               for _ in range(2)]
    srv = BatchedSpecServer(CFG, PARAMS, max_batch=2, max_len=256, draft_k=4,
                            draft_spec=SPEC, mode="tree_fused",
                            adaptive=False, draft_kv="carry",
                            round_mode="split")
    for i, p in enumerate(prompts):
        srv.add_request(i, p)
    orig = srv._tree_draft_fn

    def checking(expansions):
        fn = orig(expansions)

        def wrapped(*a, **kw):
            before = np.asarray(srv.cache["pos"]).copy()
            out = fn(*a, **kw)
            jax.block_until_ready(out)
            assert np.array_equal(np.asarray(srv.cache["pos"]), before), \
                "drafting moved the committed cache pos"
            return out

        return wrapped

    srv._tree_draft_fn = checking
    pos0 = np.asarray(srv.cache["pos"]).copy()
    srv.step()
    # the round as a whole DID commit (pos advanced by >= 1 per live slot)
    assert (np.asarray(srv.cache["pos"]) > pos0).all()
