"""§4.1 hierarchy construction: ``dsia.build_hierarchy`` across all four
modes (cost monotonicity, PLD bottoming) and the layer-sparsity gate
invariants the cascade bank depends on (boundary layers, exact skip count)."""
import dataclasses

import numpy as np
import pytest

from repro.config import get_config
from repro.core.dsia import (
    DraftSpec,
    PLD_SPEC,
    build_hierarchy,
    layer_sparsity,
)

CFG = get_config("vicuna-7b").reduced()
MODES = ("scaling", "mixing", "replacing", "early_exit")


@pytest.mark.parametrize("mode", MODES)
def test_hierarchy_cost_monotone_and_pld_bottom(mode):
    """Every hierarchy is ordered strongest -> cheapest: prior_c is
    non-increasing down the levels, and the bottom is the retrieval PLD."""
    h = build_hierarchy(CFG, mode)
    assert len(h) >= 3                      # >= 2 executable levels + PLD
    assert h[-1] is PLD_SPEC and h[-1].kind == "retrieval"
    cs = [s.prior_c for s in h]
    assert cs == sorted(cs, reverse=True), f"{mode}: prior_c not monotone {cs}"
    alphas = [s.prior_alpha for s in h[:-1]]
    assert alphas == sorted(alphas, reverse=True), (
        f"{mode}: prior_alpha not monotone {alphas}"
    )
    for s in h[:-1]:
        assert s.kind == "neural"


def test_hierarchy_unknown_mode():
    with pytest.raises(ValueError, match="unknown hierarchy mode"):
        build_hierarchy(CFG, "nope")


def test_mixing_has_sparsity_and_int8_levels():
    """The default cascade hierarchy carries both DSIA families: a pure
    layer-sparsity level and an int8 activation-quant level."""
    h = build_hierarchy(CFG, "mixing")
    assert any(s.gates is not None and s.quantize is None for s in h[:-1])
    assert any(s.quantize == "int8" for s in h[:-1])


@pytest.mark.parametrize("num_layers", (3, 4, 8, 12, 17, 32))
@pytest.mark.parametrize("sparsity", (0.0, 0.2, 0.4, 0.5, 0.6, 0.8, 0.95))
def test_layer_sparsity_exact_skip_and_boundaries(num_layers, sparsity):
    """``layer_sparsity`` honors the EXACT requested skip count (the
    collision-fill loop tops up any rounding-induced duplicates) and always
    keeps the boundary layers (SWIFT: embedding lift-off + pre-head)."""
    cfg = dataclasses.replace(CFG, num_layers=num_layers)
    spec = layer_sparsity(cfg, sparsity)
    gates = np.asarray(spec.gates, np.int32)
    n_skip = min(int(round(num_layers * sparsity)), max(num_layers - 2, 0))
    assert len(gates) == num_layers
    assert int((gates == 0).sum()) == n_skip
    assert gates[0] == 1 and gates[-1] == 1
    assert spec.n_active_layers == num_layers - n_skip


def test_prior_alpha_given_is_conditional_and_clipped():
    """Level-to-level cold-start prior (App. D): the ratio of the two
    target-calibrated priors, clipped to [own prior, 0.98)."""
    strong = DraftSpec(name="s", prior_alpha=0.8)
    cheap = DraftSpec(name="c", prior_alpha=0.4)
    assert cheap.prior_alpha_given(strong) == pytest.approx(0.5)
    # a cheap draft is accepted by a judge at least as often as by the target
    assert cheap.prior_alpha_given(DraftSpec(name="x", prior_alpha=0.99)) >= 0.4
    # near-equal levels clip below 1
    assert cheap.prior_alpha_given(DraftSpec(name="y", prior_alpha=0.4)) <= 0.98


def test_unsupported_by_gates_only_fields():
    assert layer_sparsity(CFG, 0.5).unsupported_by_gates_only() == ()
    from repro.core.dsia import activation_quant, streaming_attention

    q = activation_quant(CFG, 8)
    assert any("quantize" in f for f in q.unsupported_by_gates_only())
    sa = streaming_attention(CFG, window=64)
    assert any("attn_override" in f for f in sa.unsupported_by_gates_only())
