"""End-to-end system behaviour: the full CAS-Spec stack on a small model.

Covers the paper's qualitative claims at CPU scale:
  - DyTC is lossless AND reduces target-model calls vs AR (the speedup
    mechanism: wall-clock gains follow target-call reduction on real HW),
  - DyTC adapts: acceptance estimates move with observed outcomes,
  - the cascade hierarchy (§4.1 Scaling-DSIA) registers and runs,
  - engine statistics are internally consistent.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.config import get_config
from repro.core.cascade import ARScheduler, PLDScheduler
from repro.core.dsia import PLD_SPEC, build_hierarchy
from repro.core.dytc import DyTCConfig, DyTCScheduler
from repro.core.engine import SpecEngine
from repro.models import model as M


@pytest.fixture(scope="module")
def setup():
    cfg = dataclasses.replace(get_config("vicuna-7b").reduced(), num_layers=8)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


PROMPT = np.array([11, 12, 13, 14, 11, 12, 13, 14, 11, 12, 13], np.int32)
N = 32


def test_dytc_reduces_target_calls(setup):
    cfg, params = setup
    ar = SpecEngine(cfg, params, max_len=256)
    ar.start(PROMPT)
    ref = ARScheduler(ar).generate(N)

    eng = SpecEngine(cfg, params, max_len=256)
    eng.start(PROMPT)
    out = DyTCScheduler(eng, build_hierarchy(cfg)).generate(N)
    assert out == ref
    # AR needs one target call per token; DyTC must need fewer
    assert eng.stats["target_calls"] < ar.stats["target_calls"]
    assert eng.stats["accepted_tokens"] >= N


def test_acceptance_estimates_adapt(setup):
    cfg, params = setup
    eng = SpecEngine(cfg, params, max_len=256)
    eng.start(PROMPT)
    sched = DyTCScheduler(eng, build_hierarchy(cfg))
    before = dict(eng.acceptance.snapshot())
    sched.generate(N)
    after = eng.acceptance.snapshot()
    assert any(
        abs(after.get(k, 0) - before.get(k, 0)) > 1e-6 for k in after
    ), "EMA estimates never moved"


def test_hierarchy_modes_register(setup):
    cfg, params = setup
    for mode in ("scaling", "early_exit", "mixing", "replacing"):
        eng = SpecEngine(cfg, params, max_len=128, draft_exec="mask")
        hier = build_hierarchy(cfg, mode=mode)
        assert hier[-1].kind == "retrieval"
        for s in hier:
            eng.register_draft(s)
        eng.start(PROMPT)
        sched = DyTCScheduler(eng, hier, DyTCConfig(max_tree=12))
        out = sched.generate(8)
        assert len(out) == 8


def test_stats_consistency(setup):
    cfg, params = setup
    eng = SpecEngine(cfg, params, max_len=256)
    eng.start(PROMPT)
    PLDScheduler(eng, k=6).generate(N)
    s = eng.stats
    assert s["rounds"] == s["target_calls"]
    assert s["accepted_tokens"] >= s["rounds"]      # >= 1 token per round
    assert len(eng.tokens) == len(PROMPT) + s["accepted_tokens"]


def test_quantized_draft_spec(setup):
    """ActivationQuant DSIA drafts run and stay lossless."""
    from repro.core.cascade import SDScheduler
    from repro.core.dsia import activation_quant, layer_sparsity

    cfg, params = setup
    ar = SpecEngine(cfg, params, max_len=256)
    ar.start(PROMPT)
    ref = ARScheduler(ar).generate(16)

    eng = SpecEngine(cfg, params, max_len=256)
    eng.start(PROMPT)
    spec = activation_quant(cfg, 8, base=layer_sparsity(cfg, 0.4))
    out = SDScheduler(eng, spec, k=4).generate(16)
    assert out == ref
