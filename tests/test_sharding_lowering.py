"""Sharding-rule + dry-run machinery tests on a small forced-device mesh.

Runs in a SUBPROCESS because the device count must be forced before jax
initializes (and the rest of the suite must see the single real device).
"""
import json
import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import functools, json
    import jax, jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.config import get_config, get_shape
    from repro.config.base import InputShape
    from repro.launch import sharding as SH
    from repro.launch.mesh import make_mesh_compat, set_global_mesh
    from repro.models import model as M

    mesh = make_mesh_compat((2, 4), ("data", "model"))
    set_global_mesh(mesh)
    results = {}
    for arch in ["gemma3-1b", "qwen2-moe-a2.7b", "mamba2-130m"]:
        cfg = get_config(arch).reduced()
        pshape = jax.eval_shape(functools.partial(M.init_params, cfg), jax.random.key(0))
        pspec = SH.param_specs(cfg, mesh)
        psh = jax.tree.map(lambda p: NamedSharding(mesh, p), pspec,
                           is_leaf=lambda x: isinstance(x, P))
        cshape = jax.eval_shape(functools.partial(M.init_cache, cfg, 8, 64))
        cspec = SH.cache_specs(cfg, mesh)
        csh = jax.tree.map(lambda p: NamedSharding(mesh, p), cspec,
                           is_leaf=lambda x: isinstance(x, P))
        toks = jax.ShapeDtypeStruct((8, 4), jnp.int32)

        def serve(params, cache, tokens):
            logits, staged = M.decode_step(cfg, params, cache, tokens)
            cache2 = M.commit_cache(cfg, cache, staged,
                                    jnp.arange(4), jnp.full((8,), 2, jnp.int32))
            return jnp.argmax(logits, -1), cache2

        fn = jax.jit(serve, in_shardings=(psh, csh, NamedSharding(mesh, P("data", None))))
        compiled = fn.lower(pshape, cshape, toks).compile()
        results[arch] = compiled.memory_analysis().temp_size_in_bytes
    print(json.dumps(results))
    """
)


@pytest.mark.slow
def test_small_mesh_serve_lowering():
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    out = subprocess.run(
        [sys.executable, "-c", SCRIPT], capture_output=True, text=True,
        env=env, timeout=540,
    )
    assert out.returncode == 0, out.stderr[-2000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert set(res) == {"gemma3-1b", "qwen2-moe-a2.7b", "mamba2-130m"}
    assert all(v > 0 for v in res.values())


def test_param_specs_congruent_with_params():
    """Spec tree must be congruent with the real param pytree for jit."""
    import functools

    import jax
    from jax.sharding import PartitionSpec as P

    from repro.config import get_config
    from repro.launch import sharding as SH
    from repro.launch.mesh import make_host_mesh
    from repro.models import model as M

    mesh = make_host_mesh()
    for arch in ["mixtral-8x22b", "jamba-v0.1-52b", "musicgen-medium",
                 "llava-next-mistral-7b", "starcoder2-3b"]:
        cfg = get_config(arch).reduced()
        pshape = jax.eval_shape(
            functools.partial(M.init_params, cfg), jax.random.key(0)
        )
        pspec = SH.param_specs(cfg, mesh)
        # must zip without structure errors and cover every leaf
        leaves = jax.tree.leaves(
            jax.tree.map(lambda p, s: (p, s.shape), pspec, pshape,
                         is_leaf=lambda x: isinstance(x, P))
        )
        assert leaves
        up = SH.fsdp_upgrade(pspec, pshape, mesh)
        jax.tree.map(lambda p, s: None, up, pshape,
                     is_leaf=lambda x: isinstance(x, P))
