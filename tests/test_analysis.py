"""HLO cost parser calibration + roofline report semantics."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.hlo_costs import total_costs
from repro.analysis.roofline import RooflineReport, collective_bytes


def test_scan_trip_count_correction():
    """cost_analysis counts a while body once; our parser multiplies."""
    f = lambda a, b: jax.lax.scan(lambda h, w: (h @ w, None), a, b)[0]
    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    ws = jax.ShapeDtypeStruct((10, 64, 64), jnp.float32)
    compiled = jax.jit(f).lower(x, ws).compile()
    got = total_costs(compiled.as_text())["flops"]
    assert got == pytest.approx(2 * 64 ** 3 * 10, rel=0.01)
    ca = compiled.cost_analysis()
    if isinstance(ca, list):
        ca = ca[0]
    assert ca["flops"] == pytest.approx(2 * 64 ** 3, rel=0.01)  # the XLA quirk


def test_unrolled_matches_scan():
    def unrolled(a, b):
        for i in range(10):
            a = a @ b[i]
        return a

    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    ws = jax.ShapeDtypeStruct((10, 64, 64), jnp.float32)
    t1 = total_costs(jax.jit(unrolled).lower(x, ws).compile().as_text())["flops"]
    f = lambda a, b: jax.lax.scan(lambda h, w: (h @ w, None), a, b)[0]
    t2 = total_costs(jax.jit(f).lower(x, ws).compile().as_text())["flops"]
    assert t1 == pytest.approx(t2, rel=0.01)


def test_nested_scan_multiplies():
    def f(a, b):
        def outer(h, _):
            h2, _ = jax.lax.scan(lambda hh, w: (hh @ w, None), h, b)
            return h2, None
        return jax.lax.scan(outer, a, None, length=3)[0]

    x = jax.ShapeDtypeStruct((32, 32), jnp.float32)
    ws = jax.ShapeDtypeStruct((5, 32, 32), jnp.float32)
    got = total_costs(jax.jit(f).lower(x, ws).compile().as_text())["flops"]
    assert got == pytest.approx(2 * 32 ** 3 * 15, rel=0.01)


def test_collective_regex():
    txt = """
  %ag = bf16[4,1024,128]{2,1,0} all-gather(%x), dimensions={0}
  %ar = (f32[8,128]{1,0}, f32[8,128]{1,0}) all-reduce(%a, %b), to_apply=%sum
  %cp = f32[16]{0} collective-permute(%y), source_target_pairs={{0,1}}
"""
    out = collective_bytes(txt)
    assert out["all-gather"] == 4 * 1024 * 128 * 2
    assert out["all-reduce"] == 2 * 8 * 128 * 4
    assert out["collective-permute"] == 16 * 4


def test_roofline_bottleneck_selection():
    r = RooflineReport("x", flops=197e12, bytes_hbm=1.0, coll_bytes={})
    assert r.bottleneck == "compute" and r.t_compute == pytest.approx(1.0)
    r2 = RooflineReport("y", flops=1.0, bytes_hbm=819e9, coll_bytes={})
    assert r2.bottleneck == "memory" and r2.t_memory == pytest.approx(1.0)
    r3 = RooflineReport("z", flops=1.0, bytes_hbm=1.0, coll_bytes={"all-reduce": int(50e9)})
    assert r3.bottleneck == "collective" and r3.t_collective == pytest.approx(1.0)
