"""Mamba2 (SSD — state-space duality) block. [arXiv:2405.21060]

Chunked dual form for train/prefill (intra-chunk quadratic + inter-chunk
recurrence) and a per-token recurrence for decode. Decode over T staged draft
tokens returns *all* intermediate states so speculative verification can
commit the state after the accepted prefix (chain drafts; see DESIGN.md
§Arch-applicability for why SSMs use chain rather than tree drafts).

Sharding note: the input projection is stored as SEPARATE matrices
(w_z / w_x / w_B / w_C / w_dt) rather than one fused in_proj — a fused
projection's output dim mixes segments whose widths aren't divisible by the
model axis, forcing GSPMD reshards at every split. Separate matrices let
d_inner (z, x, conv channels, heads) shard cleanly over 'model' while the
small B/C/dt projections stay replicated; out_proj contracts the sharded
d_inner with ONE psum per layer.

State pytree per layer:
  ssm:     (B, nh, hd, ds)       recurrent state
  conv_x:  (B, d_conv-1, din)    causal-conv tails (split like the proj)
  conv_B:  (B, d_conv-1, g*ds)
  conv_C:  (B, d_conv-1, g*ds)
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.config.base import SSMConfig
from repro.models.layers import rms_norm


def ssm_init(key: jax.Array, d_model: int, s: SSMConfig, dtype) -> dict:
    din = s.d_inner(d_model)
    nh = s.num_heads(d_model)
    gds = s.ngroups * s.d_state
    ks = jax.random.split(key, 8)
    sc = d_model ** -0.5
    return {
        "w_z": (jax.random.normal(ks[0], (d_model, din)) * sc).astype(dtype),
        "w_x": (jax.random.normal(ks[1], (d_model, din)) * sc).astype(dtype),
        "w_B": (jax.random.normal(ks[2], (d_model, gds)) * sc).astype(dtype),
        "w_C": (jax.random.normal(ks[3], (d_model, gds)) * sc).astype(dtype),
        "w_dt": (jax.random.normal(ks[4], (d_model, nh)) * sc).astype(dtype),
        "conv_x": (jax.random.normal(ks[5], (s.d_conv, din)) * s.d_conv**-0.5).astype(dtype),
        "conv_B": (jax.random.normal(ks[6], (s.d_conv, gds)) * s.d_conv**-0.5).astype(dtype),
        "conv_C": (jax.random.normal(ks[7], (s.d_conv, gds)) * s.d_conv**-0.5).astype(dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, nh)).astype(jnp.float32),
        "D": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.log(jnp.expm1(jnp.linspace(1e-3, 0.1, nh))).astype(jnp.float32),
        "norm_w": jnp.zeros((din,), dtype),
        "out_proj": (jax.random.normal(jax.random.fold_in(key, 9), (din, d_model)) * din**-0.5).astype(dtype),
    }


def _conv_full(xs: jax.Array, w: jax.Array) -> jax.Array:
    """Depthwise causal conv over (B, S, C) with kernel (K, C)."""
    K = w.shape[0]
    pads = jnp.pad(xs, ((0, 0), (K - 1, 0), (0, 0)))
    out = sum(pads[:, i : i + xs.shape[1]] * w[i] for i in range(K))
    return jax.nn.silu(out)


def _conv_continued(stream: jax.Array, tail: jax.Array, w: jax.Array):
    """Conv with a carried tail; returns (outputs aligned to stream, new tail)."""
    K = w.shape[0]
    S = stream.shape[1]
    full = jnp.concatenate([tail.astype(stream.dtype), stream], axis=1)
    out = _conv_full(full, w)[:, -S:]
    return out, full[:, -(K - 1):]


def _segsum(x: jax.Array) -> jax.Array:
    """x (..., L) -> (..., L, L) lower-tri segment sums: out[i,j]=sum_{j<t<=i} x_t."""
    L = x.shape[-1]
    c = jnp.cumsum(x, axis=-1)
    diff = c[..., :, None] - c[..., None, :]
    tri = jnp.tril(jnp.ones((L, L), bool))
    return jnp.where(tri, diff, -jnp.inf)


def ssd_chunked(
    x: jax.Array,        # (B, S, nh, hd) conv'd inputs
    dt: jax.Array,       # (B, S, nh) softplus'd
    A: jax.Array,        # (nh,) negative
    B_: jax.Array,       # (B, S, g, ds)
    C_: jax.Array,       # (B, S, g, ds)
    init_state: jax.Array,   # (B, nh, hd, ds)
    chunk: int,
) -> Tuple[jax.Array, jax.Array]:
    """Returns (y (B,S,nh,hd), final_state). Compute in float32."""
    Bsz, S, nh, hd = x.shape
    g, ds = B_.shape[2], B_.shape[3]
    rep = nh // g
    pad = (-S) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B_ = jnp.pad(B_, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C_ = jnp.pad(C_, ((0, 0), (0, pad), (0, 0), (0, 0)))
    Sp = S + pad
    nc, L = Sp // chunk, chunk

    xc = x.reshape(Bsz, nc, L, nh, hd).astype(jnp.float32)
    dtc = dt.reshape(Bsz, nc, L, nh).astype(jnp.float32)
    Bc = B_.reshape(Bsz, nc, L, g, ds).astype(jnp.float32)
    Cc = C_.reshape(Bsz, nc, L, g, ds).astype(jnp.float32)

    dA = dtc * A                                       # (B,nc,L,nh)
    dA_cum = jnp.cumsum(dA, axis=2)                    # within-chunk cumsum
    x_dt = xc * dtc[..., None]

    # --- intra-chunk (diagonal blocks)
    Lmat = jnp.exp(_segsum(jnp.moveaxis(dA, -1, -2)))  # (B,nc,nh,L,L)
    CB = jnp.einsum("bclgn,bcsgn->bcgls", Cc, Bc)
    CB = jnp.repeat(CB, rep, axis=2)                   # groups -> heads
    scores = CB * Lmat
    y_diag = jnp.einsum("bchls,bcshp->bclhp", scores, x_dt)

    # --- per-chunk input states
    decay = jnp.exp(dA_cum[:, :, -1:, :] - dA_cum)     # (B,nc,L,nh)
    Bh = jnp.repeat(Bc, rep, axis=3)                   # groups -> heads (B,nc,L,nh,ds)
    Ch = jnp.repeat(Cc, rep, axis=3)
    # states_c = sum_s B_s (x_dt)_s decay_s  -> (B,nc,nh,hd,ds)
    states = jnp.einsum("bclhn,bclh,bclhp->bchpn", Bh, decay, x_dt)

    # --- inter-chunk recurrence
    chunk_decay = jnp.exp(dA_cum[:, :, -1, :])         # (B,nc,nh)

    def step(carry, xs):
        st = carry                                     # (B,nh,hd,ds)
        dec, new = xs                                  # (B,nh), (B,nh,hd,ds)
        out = st                                       # state BEFORE this chunk
        st = st * dec[..., None, None] + new
        return st, out

    final, prev_states = jax.lax.scan(
        step,
        init_state.astype(jnp.float32),
        (jnp.moveaxis(chunk_decay, 1, 0), jnp.moveaxis(states, 1, 0)),
    )
    prev_states = jnp.moveaxis(prev_states, 0, 1)      # (B,nc,nh,hd,ds)

    # --- contribution of carried-in state
    state_decay = jnp.exp(dA_cum)                      # (B,nc,L,nh)
    y_off = jnp.einsum("bclhn,bchpn,bclh->bclhp", Ch, prev_states, state_decay)

    y = (y_diag + y_off).reshape(Bsz, Sp, nh, hd)[:, :S]
    return y, final


def mamba_forward(
    params: dict,
    h: jax.Array,                  # (B, S, d) block input (post-norm)
    d_model: int,
    s: SSMConfig,
    layer_cache: dict,             # {"ssm", "conv_x", "conv_B", "conv_C"}
    *,
    mode: str,                     # "train" | "prefill" | "decode"
) -> Tuple[jax.Array, dict, dict]:
    """Returns (out (B,S,d), new_cache, staged).

    ``staged`` carries per-step states (B, T, ...) in decode mode for the
    speculative commit; in train/prefill it equals the finals with a
    length-1 step axis.
    """
    B, S, d = h.shape
    nh = s.num_heads(d_model)
    hd = s.head_dim
    din = s.d_inner(d_model)
    g, ds = s.ngroups, s.d_state

    z = jnp.einsum("bsd,de->bse", h, params["w_z"])
    x_raw = jnp.einsum("bsd,de->bse", h, params["w_x"])
    B_raw = jnp.einsum("bsd,de->bse", h, params["w_B"])
    C_raw = jnp.einsum("bsd,de->bse", h, params["w_C"])
    dt_raw = jnp.einsum("bsd,de->bse", h, params["w_dt"])
    A = -jnp.exp(params["A_log"])                      # (nh,)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])

    ssm0 = layer_cache["ssm"]
    if mode in ("train", "prefill"):
        xc, tail_x = _conv_continued(x_raw, layer_cache["conv_x"], params["conv_x"])
        Bc, tail_B = _conv_continued(B_raw, layer_cache["conv_B"], params["conv_B"])
        Cc, tail_C = _conv_continued(C_raw, layer_cache["conv_C"], params["conv_C"])
        x = xc.reshape(B, S, nh, hd)
        B_ = Bc.reshape(B, S, g, ds)
        C_ = Cc.reshape(B, S, g, ds)
        y, final = ssd_chunked(x, dt, A, B_, C_, ssm0, s.chunk_size)
        y = y + params["D"][None, None, :, None] * x.astype(jnp.float32)
        new_cache = {"ssm": final, "conv_x": tail_x, "conv_B": tail_B, "conv_C": tail_C}
        staged = jax.tree.map(lambda a: a[:, None], new_cache)
    else:
        K = params["conv_x"].shape[0]

        def step(carry, xs):
            cx, cB, cC, st = carry
            x_t, B_t, C_t, dt_t = xs                   # (B,din),(B,gds),(B,gds),(B,nh)
            wx = jnp.concatenate([cx, x_t[:, None]], axis=1)       # (B,K,din)
            wB = jnp.concatenate([cB, B_t[:, None]], axis=1)
            wC = jnp.concatenate([cC, C_t[:, None]], axis=1)
            xc_t = jax.nn.silu(jnp.sum(wx * params["conv_x"], axis=1))
            Bc_t = jax.nn.silu(jnp.sum(wB * params["conv_B"], axis=1))
            Cc_t = jax.nn.silu(jnp.sum(wC * params["conv_C"], axis=1))
            x_h = xc_t.reshape(B, nh, hd).astype(jnp.float32)
            B_h = Bc_t.reshape(B, g, ds).astype(jnp.float32)
            C_h = Cc_t.reshape(B, g, ds).astype(jnp.float32)
            dA_t = jnp.exp(dt_t * A)                   # (B,nh)
            Bx = jnp.einsum("bgn,bhp->bhpn", B_h, x_h * dt_t[..., None])
            st = st * dA_t[..., None, None] + Bx
            Ch = jnp.repeat(C_h, nh // g, axis=1)      # (B,nh,ds)
            y_t = jnp.einsum("bhpn,bhn->bhp", st, Ch)
            y_t = y_t + params["D"][None, :, None] * x_h
            carry = (wx[:, 1:], wB[:, 1:], wC[:, 1:], st)
            return carry, (y_t, carry[0], carry[1], carry[2], st)

        init = (
            layer_cache["conv_x"].astype(x_raw.dtype),
            layer_cache["conv_B"].astype(x_raw.dtype),
            layer_cache["conv_C"].astype(x_raw.dtype),
            ssm0.astype(jnp.float32),
        )
        (ncx, ncB, ncC, nst), (ys, ax, aB, aC, ast) = jax.lax.scan(
            step,
            init,
            (
                jnp.moveaxis(x_raw, 1, 0),
                jnp.moveaxis(B_raw, 1, 0),
                jnp.moveaxis(C_raw, 1, 0),
                jnp.moveaxis(dt, 1, 0),
            ),
        )
        y = jnp.moveaxis(ys, 0, 1)                     # (B,S,nh,hd)
        new_cache = {"ssm": nst, "conv_x": ncx, "conv_B": ncB, "conv_C": ncC}
        staged = {
            "ssm": jnp.moveaxis(ast, 0, 1),
            "conv_x": jnp.moveaxis(ax, 0, 1),
            "conv_B": jnp.moveaxis(aB, 0, 1),
            "conv_C": jnp.moveaxis(aC, 0, 1),
        }

    yf = y.reshape(B, S, din)
    yf = rms_norm(yf * jax.nn.silu(z.astype(jnp.float32)), params["norm_w"], 1e-5)
    out = jnp.einsum("bse,ed->bsd", yf.astype(h.dtype), params["out_proj"])
    return out, new_cache, staged
