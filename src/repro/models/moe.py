"""Mixture-of-Experts layer.

Two dispatch paths:

  grouped capacity ("train", and "infer_grouped" for TPU prefill):
    tokens are split into ``exec_groups`` groups (group dim sharded over the
    data axes — MaxText-style expert groups) and each group competes for a
    per-group expert capacity C = ceil(cf * N_g * K / E). Dispatch is
    gather/scatter into (G, E, C, d) buffers — HLO FLOPs stay ~= active
    FLOPs * cf, and every big intermediate carries an explicit sharding
    constraint so SPMD never materializes an unsharded dispatch buffer.

  dropless ragged ("infer" — decode & CPU prefill):
    sort-by-expert + lax.ragged_dot. Exact top-k with NO capacity drops,
    and therefore batch-invariant: a token's output never depends on
    co-batched tokens. Required for lossless speculative verification.

Shared experts (Qwen2-MoE) are an always-on sigmoid-gated MLP.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.config.base import MoEConfig
from repro.models.layers import mlp_apply, mlp_init
from repro.models.shard_utils import constrain, data_axis


def moe_init(key: jax.Array, d_model: int, moe: MoEConfig, gated: bool, dtype) -> dict:
    k_r, k_e, k_s, k_g = jax.random.split(key, 4)
    E, F = moe.num_experts, moe.d_ff_expert
    scale_in = d_model ** -0.5
    scale_out = F ** -0.5
    nmat = 3 if gated else 2
    ks = jax.random.split(k_e, nmat)
    p = {
        "w_router": (jax.random.normal(k_r, (d_model, E)) * scale_in).astype(jnp.float32),
        "w_up": (jax.random.normal(ks[0], (E, d_model, F)) * scale_in).astype(dtype),
        "w_down": (jax.random.normal(ks[1], (E, F, d_model)) * scale_out).astype(dtype),
    }
    if gated:
        p["w_gate"] = (jax.random.normal(ks[2], (E, d_model, F)) * scale_in).astype(dtype)
    if moe.num_shared_experts:
        f_sh = moe.d_ff_shared or moe.d_ff_expert * moe.num_shared_experts
        p["shared"] = mlp_init(k_s, d_model, f_sh, gated, dtype)
        p["w_shared_gate"] = (jax.random.normal(k_g, (d_model, 1)) * scale_in).astype(dtype)
    return p


def _router(params, xf, moe: MoEConfig):
    logits = jnp.einsum("nd,de->ne", xf.astype(jnp.float32), params["w_router"])
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_ids = jax.lax.top_k(probs, moe.top_k)
    top_w = top_w / jnp.maximum(jnp.sum(top_w, axis=-1, keepdims=True), 1e-9)
    E = moe.num_experts
    density = jnp.mean(
        jnp.sum(jax.nn.one_hot(top_ids, E, dtype=jnp.float32), axis=1), axis=0
    ) / moe.top_k
    mean_prob = jnp.mean(probs, axis=0)
    aux = {
        "load_balance": E * jnp.sum(density * mean_prob) * moe.load_balance_loss,
        "router_z": jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2) * moe.router_z_loss,
    }
    return top_w, top_ids, aux


def _expert_ffn(params, x, act, gated):
    """x (..., C, d) batched over leading expert dims via einsum.

    Expert weights pinned to TP spec at use site (FSDP weight-gather)."""
    from repro.models.shard_utils import constrain_full

    fn = jax.nn.silu if act == "silu" else jax.nn.gelu
    w_up = constrain_full(params["w_up"], None, None, "model")
    w_down = constrain_full(params["w_down"], None, "model", None)
    if x.ndim == 3:       # (E, C, d)
        eq_up, eq_dn = "ecd,edf->ecf", "ecf,efd->ecd"
    else:                 # (G, E, C, d)
        eq_up, eq_dn = "gecd,edf->gecf", "gecf,efd->gecd"
    if gated:
        w_gate = constrain_full(params["w_gate"], None, None, "model")
        h = fn(jnp.einsum(eq_up, x, w_gate)) * jnp.einsum(eq_up, x, w_up)
    else:
        h = fn(jnp.einsum(eq_up, x, w_up))
    dp = data_axis()
    h = constrain(h, *( (dp, None, None, "model") if h.ndim == 4 else (None, None, "model") ))
    return jnp.einsum(eq_dn, h, w_down)


def _grouped_capacity(params, xf, top_w, top_ids, moe: MoEConfig, act, gated, cf):
    N, d = xf.shape
    E, K = moe.num_experts, moe.top_k
    G = moe.exec_groups
    while N % G:
        G //= 2
    G = max(G, 1)
    Ng = N // G
    C = max(1, int(cf * Ng * K / E + 0.999))
    dp = data_axis()

    ids_g = top_ids.reshape(G, Ng * K)
    w_g = top_w.reshape(G, Ng * K)
    tok_g = jnp.tile(
        jnp.repeat(jnp.arange(Ng, dtype=jnp.int32), K)[None], (G, 1)
    )                                                    # (G, Ng*K)
    onehot = jax.nn.one_hot(ids_g, E, dtype=jnp.int32)   # (G, Ng*K, E)
    pos_in_e = jnp.cumsum(onehot, axis=1) - onehot
    pos = jnp.take_along_axis(pos_in_e, ids_g[..., None], axis=2)[..., 0]
    keep = pos < C
    slot = jnp.where(keep, ids_g * C + pos, E * C)       # (G, Ng*K), E*C = dropped

    xg = constrain(xf.reshape(G, Ng, d), dp, None, None)
    g_idx = jnp.arange(G, dtype=jnp.int32)[:, None]

    # GATHER-BASED dispatch: scattering the (G, E*C, d) data buffer makes
    # GSPMD all-gather it per shard (measured 51.5 GiB x 56 layers = 2.9 TB
    # on mixtral prefill). Instead scatter only an int32 slot->token TABLE
    # (16 MB) and build the buffer with take_along_axis — gathers partition
    # cleanly along the group dim.
    idx_tab = jnp.full((G, E * C + 1), Ng, jnp.int32)
    idx_tab = idx_tab.at[g_idx, slot].set(tok_g, mode="drop", unique_indices=True)
    xg_pad = jnp.concatenate([xg, jnp.zeros((G, 1, d), xg.dtype)], axis=1)
    eb = jnp.take_along_axis(xg_pad, idx_tab[:, : E * C, None], axis=1)
    eb = constrain(eb, dp, None, None).reshape(G, E, C, d)

    eo = _expert_ffn(params, eb, act, gated).reshape(G, E * C, d)
    eo = jnp.concatenate([eo, jnp.zeros((G, 1, d), eo.dtype)], axis=1)
    eo = constrain(eo, dp, None, None)

    # GATHER-BASED combine: each token reads its K slots (no scatter-add)
    slot_nk = slot.reshape(G, Ng, K)
    w_nk = (w_g * keep).astype(xf.dtype).reshape(G, Ng, K)
    gathered = jnp.take_along_axis(
        eo, slot_nk.reshape(G, Ng * K)[..., None], axis=1
    ).reshape(G, Ng, K, d)
    y = jnp.sum(gathered * w_nk[..., None], axis=2)
    return constrain(y, dp, None, None).reshape(N, d)


def _dropless_ragged(params, xf, top_w, top_ids, moe: MoEConfig, act, gated):
    N, d = xf.shape
    E, K = moe.num_experts, moe.top_k
    flat_e = top_ids.reshape(N * K)
    flat_t = jnp.repeat(jnp.arange(N, dtype=jnp.int32), K)
    flat_w = top_w.reshape(N * K)
    fn = jax.nn.silu if act == "silu" else jax.nn.gelu

    order = jnp.argsort(flat_e, stable=True)
    xs = xf[flat_t[order]]
    group_sizes = jnp.bincount(flat_e, length=E).astype(jnp.int32)
    if gated:
        h = fn(jax.lax.ragged_dot(xs, params["w_gate"], group_sizes)) * jax.lax.ragged_dot(
            xs, params["w_up"], group_sizes
        )
    else:
        h = fn(jax.lax.ragged_dot(xs, params["w_up"], group_sizes))
    eo_sorted = jax.lax.ragged_dot(h, params["w_down"], group_sizes)
    eo = jnp.zeros_like(eo_sorted).at[order].set(eo_sorted)
    y = jnp.zeros((N, d), xf.dtype).at[flat_t].add(
        eo.astype(xf.dtype) * flat_w.astype(xf.dtype)[:, None]
    )
    return y


def moe_apply(
    params: dict,
    x: jax.Array,                       # (B, S, d)
    moe: MoEConfig,
    act: str,
    gated: bool,
    *,
    mode: str = "train",                # train | infer | infer_grouped
) -> Tuple[jax.Array, dict]:
    """Returns (output (B,S,d), aux losses). See module docstring."""
    B, S, d = x.shape
    xf = x.reshape(B * S, d)
    top_w, top_ids, aux = _router(params, xf, moe)

    if mode == "infer":
        y = _dropless_ragged(params, xf, top_w, top_ids, moe, act, gated)
    else:
        cf = moe.capacity_factor if mode == "train" else moe.infer_capacity_factor
        y = _grouped_capacity(params, xf, top_w, top_ids, moe, act, gated, cf)

    if "shared" in params:
        gate = jax.nn.sigmoid(
            jnp.einsum(
                "nd,do->no",
                xf.astype(jnp.float32),
                params["w_shared_gate"].astype(jnp.float32),
            )
        ).astype(x.dtype)
        y = y + mlp_apply(params["shared"], xf, act, gated) * gate

    return y.reshape(B, S, d), aux
