"""Mesh-aware sharding constraints that degrade to no-ops off-mesh."""
from __future__ import annotations

from typing import Tuple, Union

import jax
from jax.sharding import PartitionSpec as P

Axis = Union[None, str, Tuple[str, ...]]

# the batch/token-parallel axes in priority order
DATA_AXES = ("pod", "data")

# Concrete-mesh fallback for JAX releases without jax.sharding.set_mesh /
# get_abstract_mesh (<= 0.4.x): launch.mesh.set_global_mesh registers the
# mesh here, and constraints are applied as NamedSharding(mesh, spec) —
# which works inside jit on every supported release — instead of the
# bare-PartitionSpec form that needs the abstract-mesh context.
_COMPAT_MESH = None


def set_compat_mesh(mesh) -> None:
    """Register (or clear, with None) the concrete fallback mesh."""
    global _COMPAT_MESH
    _COMPAT_MESH = mesh


def _abstract_axes() -> dict:
    try:
        mesh = jax.sharding.get_abstract_mesh()
        return dict(zip(mesh.axis_names, mesh.axis_sizes))
    except Exception:
        return {}


def _mesh_axes() -> dict:
    axes = _abstract_axes()
    if axes:
        return axes
    if _COMPAT_MESH is not None:
        return {a: _COMPAT_MESH.shape[a] for a in _COMPAT_MESH.axis_names}
    return {}


def _apply_constraint(x: jax.Array, spec: list) -> jax.Array:
    if not _abstract_axes() and _COMPAT_MESH is not None:
        return jax.lax.with_sharding_constraint(
            x, jax.sharding.NamedSharding(_COMPAT_MESH, P(*spec))
        )
    return jax.lax.with_sharding_constraint(x, P(*spec))


def constrain(x: jax.Array, *axes: Axis) -> jax.Array:
    """with_sharding_constraint that only names axes present in the current
    mesh AND dividing the dimension; a no-op outside any mesh (CPU tests,
    live engine, or e.g. batch=1 decode where batch can't shard)."""
    sizes = _mesh_axes()
    if not sizes:
        return x

    def resolve(a, dim):
        if a is None:
            return None
        cand = (a,) if isinstance(a, str) else tuple(a)
        kept = tuple(t for t in cand if t in sizes)
        total = 1
        for t in kept:
            total *= sizes[t]
        if not kept or total == 0 or dim % total != 0:
            return None
        return kept if len(kept) > 1 else kept[0]

    spec = [resolve(a, d) for a, d in zip(axes, x.shape)]
    if not any(s for s in spec):
        return x
    return _apply_constraint(x, spec)


def data_axis() -> Axis:
    names = _mesh_axes()
    kept = tuple(a for a in DATA_AXES if a in names)
    return kept if kept else None


def model_axis_size() -> int:
    return _mesh_axes().get("model", 1)


def constrain_full(x: jax.Array, *axes: Axis) -> jax.Array:
    """Like constrain, but an all-None spec still APPLIES (= replicate).

    Used to pin FSDP-stored weights to their TP-only spec at the use site:
    GSPMD then all-gathers the (small) weight shard over 'data' instead of
    gathering the (large) activations — the classic FSDP weight-gather.
    """
    sizes = _mesh_axes()
    if not sizes:
        return x

    def resolve(a, dim):
        if a is None:
            return None
        cand = (a,) if isinstance(a, str) else tuple(a)
        kept = tuple(t for t in cand if t in sizes)
        total = 1
        for t in kept:
            total *= sizes[t]
        if not kept or dim % total != 0:
            return None
        return kept if len(kept) > 1 else kept[0]

    spec = [resolve(a, d) for a, d in zip(axes, x.shape)]
    return _apply_constraint(x, spec)


def attention_head_policy(num_heads: int, num_kv_heads: int) -> str:
    """Trace-time mirror of launch.sharding.attention_policy (same ladder)."""
    n = model_axis_size()
    if num_kv_heads and num_kv_heads % n == 0:
        return "kv"
    if num_heads and num_heads % n == 0:
        return "q"
    return "none"
