"""Model substrate: layers, attention, MoE, SSM, and decoder assembly."""
from repro.models.model import (
    Cache,
    commit_cache,
    decode_step,
    forward_train,
    init_cache,
    init_params,
    prefill,
)

__all__ = [
    "Cache",
    "commit_cache",
    "decode_step",
    "forward_train",
    "init_cache",
    "init_params",
    "prefill",
]
