"""GQA attention: blockwise (memory-efficient) train/prefill path and a
cache + staged-draft decode path with tree masks.

Pure jnp with online softmax over KV chunks — this is the portable reference
path used for CPU execution and for multi-pod dry-runs. The Pallas kernels in
``repro.kernels`` implement the same contracts for the TPU hot spots and are
validated against these functions.

Sharding note: scores are computed in EXPANDED-head form — K/V are repeated
from KV to H = KV*rep heads before the einsum, so the contraction is only
over head_dim (never sharded) and the score/output tensors are sharded on H.
With KV the major factor of H, a KV-head sharding propagates through the
repeat; with Q-head sharding (KV < mesh axis) the replicated K/V expand into
H-sharded scores. Sharding the head_dim contraction (the naive GQA layout)
costs a per-chunk all-reduce of the score tensor — measured at up to ~10 TB
per prefill step before this layout (see EXPERIMENTS.md §Perf).

Layouts:
  q/k/v activations: (B, S, H, head_dim) / (B, S, KV, head_dim)
  KV cache:          (B, S_cache, KV, head_dim)  — seq dim shardable ("data")

Mask kinds:
  causal     — kv_pos <= q_pos
  window     — causal and kv_pos > q_pos - window
  streaming  — causal and (kv_pos < sink or kv_pos > q_pos - window)  [StreamingLLM]
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _mask(
    q_pos: jax.Array,          # (..., Tq) int32
    kv_pos: jax.Array,         # (..., Tk) int32, -1 marks an invalid slot
    kind: str,
    window: int,
    sink: int,
) -> jax.Array:
    """Boolean (..., Tq, Tk) visibility mask."""
    q = q_pos[..., :, None]
    k = kv_pos[..., None, :]
    valid = (k >= 0) & (k <= q)
    if kind == "window":
        valid &= k > q - window
    elif kind == "streaming":
        valid &= (k < sink) | (k > q - window)
    elif kind != "causal":
        raise ValueError(f"unknown mask kind {kind!r}")
    return valid


def _expand_kv(k: jax.Array, rep: int) -> jax.Array:
    """(B, S, KV, hd) -> (B, S, H, hd) with KV the major factor of H."""
    if rep == 1:
        return k
    return jnp.repeat(k, rep, axis=2)


def _scores(q: jax.Array, k: jax.Array) -> jax.Array:
    """q (B,Tq,H,hd) x k (B,Tk,H,hd) -> (B,H,Tq,Tk), float32."""
    return jnp.einsum("bqhd,bkhd->bhqk", q, k, preferred_element_type=jnp.float32)


def _out(p: jax.Array, v: jax.Array) -> jax.Array:
    """p (B,H,Tq,Tk) x v (B,Tk,H,hd) -> (B,Tq,H,hd), float32."""
    return jnp.einsum("bhqk,bkhd->bqhd", p, v, preferred_element_type=jnp.float32)


def blockwise_attention(
    q: jax.Array,              # (B, Tq, H, hd)
    k: jax.Array,              # (B, Tk, KV, hd)
    v: jax.Array,              # (B, Tk, KV, hd)
    q_pos: jax.Array,          # (Tq,) int32
    kv_pos: jax.Array,         # (Tk,) int32
    *,
    kind: str = "causal",
    window: int = 0,
    sink: int = 0,
    chunk_q: int = 512,
    chunk_kv: int = 1024,
) -> jax.Array:
    """Memory-efficient causal/window attention; returns (B, Tq, H, hd)."""
    B, Tq, H, hd = q.shape
    KV = k.shape[2]
    rep = H // KV
    scale = hd ** -0.5

    cq = min(chunk_q, Tq)
    ck = min(chunk_kv, k.shape[1])
    pq = (-Tq) % cq
    pk = (-k.shape[1]) % ck
    qp = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))
    qpos = jnp.pad(q_pos, (0, pq), constant_values=jnp.int32(2**30))
    kpos = jnp.pad(kv_pos, (0, pk), constant_values=jnp.int32(-1))
    nq = qp.shape[1] // cq
    nk = kp.shape[1] // ck

    Tkp = kp.shape[1]
    qp = (qp * scale).reshape(B, nq, cq, H, hd)
    qpos_b = qpos.reshape(nq, cq)

    # window-chunk skipping: a q block only touches KV in a fixed-size span
    # ending at its last position — O(S * window) FLOPs instead of O(S^2).
    # (causal full attention keeps the all-chunks scan + masks.)
    windowed = kind == "window" and 0 < window and window + 2 * ck < Tkp

    def scan_kv(qi, qpos_i, ks, vs, kpos_s):
        nkk = ks.shape[1] // ck

        def kv_step(carry, xs):
            m, l, acc = carry
            kj, vj, kpos_j = xs
            kj = _expand_kv(kj, rep)
            vj = _expand_kv(vj, rep)
            s = _scores(qi, kj)                      # (B,H,cq,ck)
            msk = _mask(qpos_i, kpos_j, kind, window, sink)
            s = jnp.where(msk[None, None], s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)                # (B,H,cq)
            l_new = l * corr + jnp.sum(p, axis=-1)
            acc_new = acc * corr.transpose(0, 2, 1)[..., None] + _out(
                p.astype(qi.dtype), vj
            )
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, H, cq), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, H, cq), jnp.float32)
        a0 = jnp.zeros((B, cq, H, hd), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_step,
            (m0, l0, a0),
            (
                jnp.moveaxis(ks.reshape(B, nkk, ck, KV, hd), 1, 0),
                jnp.moveaxis(vs.reshape(B, nkk, ck, KV, hd), 1, 0),
                kpos_s.reshape(nkk, ck),
            ),
        )
        l = jnp.maximum(l, 1e-30)
        return acc / l.transpose(0, 2, 1)[..., None]

    # re-pin after jnp.pad: the pad output's sharding is re-decided by GSPMD
    # and the downstream (seq-sharded) cache spec otherwise pulls S onto
    # 'model', making every kv-chunk slice of the scan an all-gather
    # (measured 805 MB/layer on musicgen prefill)
    from repro.models.shard_utils import constrain as _cst, data_axis as _dx
    kp = _cst(kp, _dx(), None, None, None)
    vp = _cst(vp, _dx(), None, None, None)
    qp = _cst(qp, _dx(), None, None, None, None)   # (B, nq, cq, H, hd)

    if windowed:
        span = ((window + cq + ck - 1) // ck + 1) * ck   # covers window + slack

        def q_block(args):
            qi, qpos_i = args
            # derive block end from the FIRST position (padded tail entries
            # carry sentinel positions)
            q_end = qpos_i[0] + cq - 1
            start = jnp.clip(q_end + 1 - span, 0, Tkp - span)
            ks = jax.lax.dynamic_slice(kp, (0, start, 0, 0), (B, span, KV, hd))
            vs = jax.lax.dynamic_slice(vp, (0, start, 0, 0), (B, span, KV, hd))
            kpos_s = jax.lax.dynamic_slice(kpos, (start,), (span,))
            return scan_kv(qi, qpos_i, ks, vs, kpos_s)
    else:
        def q_block(args):
            qi, qpos_i = args
            return scan_kv(qi, qpos_i, kp, vp, kpos)

    out = jax.lax.map(q_block, (jnp.moveaxis(qp, 1, 0), qpos_b))  # (nq,B,cq,H,hd)
    out = jnp.moveaxis(out, 0, 1).reshape(B, nq * cq, H, hd)[:, :Tq]
    return out.astype(q.dtype)


def _staged_pallas_partials(
    q: jax.Array,              # (B, T, H, hd) — ALREADY scaled
    k_new: jax.Array,          # (B, T, KV, hd)
    v_new: jax.Array,
    vis: jax.Array,            # (B, T, T) bool — tree & positional validity
    rep: int,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Intra-tree softmax partials via the Pallas tree-attention kernel.

    Same row layout as ``kernels.ops.verify_attention`` (row = r*T + t per
    (batch, kv-head) grid step, head_dim padded to the 128-lane tile);
    interpret mode off-TPU. Returns (acc (B,T,H,hd), m (B,H,T), l (B,H,T)).
    """
    from repro.kernels.tree_attention import tree_attention_partial

    B, T, H, hd = q.shape
    KV = k_new.shape[2]
    qr = q.reshape(B, T, KV, rep, hd).transpose(0, 2, 3, 1, 4).reshape(
        B, KV, rep * T, hd
    )
    kn = k_new.transpose(0, 2, 1, 3)
    vn = v_new.transpose(0, 2, 1, 3)
    pad = (-hd) % 128
    if pad:
        widths = ((0, 0), (0, 0), (0, 0), (0, pad))
        qr, kn, vn = (jnp.pad(a, widths) for a in (qr, kn, vn))
    acc, m, l = tree_attention_partial(
        qr, kn, vn, vis,
        interpret=jax.default_backend() != "tpu", scale=1.0,
    )
    acc = acc[..., :hd].reshape(B, KV, rep, T, hd).transpose(0, 3, 1, 2, 4)
    return acc.reshape(B, T, H, hd), m.reshape(B, H, T), l.reshape(B, H, T)


def decode_attention(
    q: jax.Array,              # (B, T, H, hd) — T = 1 (AR) or draft bucket
    k_cache: jax.Array,        # (B, S_c, KV, hd)
    v_cache: jax.Array,        # (B, S_c, KV, hd)
    cache_pos: jax.Array,      # (B,) int32: committed tokens per sequence
    k_new: jax.Array,          # (B, T, KV, hd) staged draft keys (not committed)
    v_new: jax.Array,          # (B, T, KV, hd)
    q_pos: jax.Array,          # (B, T) absolute positions of the draft tokens
    *,
    tree_mask: Optional[jax.Array] = None,   # (T, T) or (B, T, T) bool mask
    kind: str = "causal",
    window: int = 0,
    sink: int = 0,
    ring: bool = False,        # cache is a ring buffer of size S_c (= window)
    chunk_kv: int = 4096,
    seq_axes: Optional[Tuple[str, ...]] = None,  # context-parallel partials
    backend: Optional[str] = None,   # "pallas": kernel staged pass (tree verify)
    k_staged: Optional[jax.Array] = None,    # (B, N_s, KV, hd) carried draft KV
    v_staged: Optional[jax.Array] = None,    # (B, N_s, KV, hd)
    staged_pos: Optional[jax.Array] = None,  # (B, N_s) absolute node positions
    staged_mask: Optional[jax.Array] = None, # (B, T, N_s) bool visibility
) -> jax.Array:
    """Attention of T staged tokens over [committed cache ++ staged draft].

    Returns (B, T, H, hd). The cache is read-only here — commit happens after
    verification (see models.model.commit_cache). Tree mask gives intra-draft
    visibility (ancestor-closure of the draft token tree); None means chain.
    A 2-D (T, T) mask is shared across the batch; a 3-D (B, T, T) mask gives
    every sequence its own tree (the batched ``tree_fused`` serving mode).
    ``backend="pallas"`` routes the dense intra-tree pass through
    ``kernels.tree_attention`` and merges its partials with the cache scan.

    ``k_staged``/``v_staged`` enable the incremental drafting path
    (``draft_kv="carry"`` in the engine scans): a fixed-size block of
    PREVIOUSLY staged draft KV that the T new queries attend over in
    addition to the committed cache and themselves. ``staged_mask`` carries
    the tree/causal visibility of each staged row to each query (stale rows
    masked off by the caller), ``staged_pos`` its absolute positions so the
    window/streaming mask kinds apply exactly as they do to the in-block
    pass. Like the cache, the staged block is read-only here — the caller
    scatters the RETURNED new rows into its carried buffers.

    ``seq_axes`` switches the cache pass from the sequential chunk-scan to
    flash-decoding split-KV: the seq dim reshapes to (n, S/n) with n = the
    product of the named mesh axes, and partial (m, l, acc) are computed
    DENSELY per slice in one einsum, then merged with a logsumexp combine.
    The slice dim is pinned to ``seq_axes`` (and q/partials pinned local)
    so each shard computes its slice in place and the combine is the only
    cross-shard communication — a (B,H,T)-stat + (B,T,H,hd) all-reduce
    instead of gathering the whole cache (the GSPMD context-parallel
    decode). Without the pins, GSPMD back-propagates the H sharding of the
    output projection through the chain and gathers the cache (~2 GiB/layer
    measured on internlm2 decode_32k).
    """
    B, T, H, hd = q.shape
    KV = k_cache.shape[2]
    rep = H // KV
    scale = hd ** -0.5
    S_c = k_cache.shape[1]
    q = q * scale

    cache_pos = jnp.broadcast_to(jnp.asarray(cache_pos, jnp.int32), (B,))
    if q_pos.ndim == 1:
        q_pos = jnp.broadcast_to(q_pos[None], (B, T))

    # positions of cache slots, per sequence: (B, S_c)
    slots = jnp.arange(S_c, dtype=jnp.int32)[None]
    if ring:
        last = cache_pos[:, None] - 1
        # most recent position stored in slot j (writes go to pos % S_c)
        p = last - ((last - slots) % S_c)
        kv_pos = jnp.where((p >= 0) & (p <= last), p, jnp.int32(-1))
    else:
        kv_pos = jnp.where(slots < cache_pos[:, None], slots, jnp.int32(-1))

    n_seq = 0
    if seq_axes:
        from repro.models.shard_utils import _mesh_axes, constrain, data_axis

        sizes = _mesh_axes()
        if all(a in sizes for a in seq_axes):
            n_seq = 1
            for a in seq_axes:
                n_seq *= sizes[a]

    if n_seq > 1:
        # --- flash-decoding split-KV: dense partials per seq slice
        dp = data_axis()
        if dp is not None:  # batch axes must not repeat the seq axes
            dp = tuple(a for a in ((dp,) if isinstance(dp, str) else dp)
                       if a not in seq_axes) or None
        # q replicated over the seq axes (moving q is a few MB; the pins on
        # s/acc_p below stop GSPMD from gathering the cache instead)
        q = constrain(q, dp, None, None, None)
        n = n_seq
        pk = (-S_c) % n
        kc = jnp.pad(k_cache, ((0, 0), (0, pk), (0, 0), (0, 0)))
        vc = jnp.pad(v_cache, ((0, 0), (0, pk), (0, 0), (0, 0)))
        kpos = jnp.pad(kv_pos, ((0, 0), (0, pk)), constant_values=jnp.int32(-1))
        Sl = kc.shape[1] // n
        kc = constrain(kc.reshape(B, n, Sl, KV, hd), dp, seq_axes, None, None, None)
        vc = constrain(vc.reshape(B, n, Sl, KV, hd), dp, seq_axes, None, None, None)
        kpos = kpos.reshape(B, n, Sl)
        # grouped GQA einsum — the rep expansion is NEVER materialized
        # (repeating the cache slice costs rep x its bytes in HBM traffic;
        # measured 59 GiB/dev -> see EXPERIMENTS.md §Perf internlm2 decode)
        q5 = q.reshape(B, T, KV, rep, hd)
        s = jnp.einsum(
            "btgrd,bnsgd->bngrts", q5, kc, preferred_element_type=jnp.float32
        )                                            # (B,n,KV,rep,T,Sl)
        s = constrain(s, dp, seq_axes, None, None, None, None)
        msk = _mask(q_pos[:, None], kpos, kind, window, sink)  # (B,n,T,Sl)
        s = jnp.where(msk[:, :, None, None], s, NEG_INF)
        m_p = jnp.max(s, axis=-1)                    # (B,n,KV,rep,T)
        p = jnp.exp(s - m_p[..., None])
        l_p = jnp.sum(p, axis=-1)
        acc_p = jnp.einsum(
            "bngrts,bnsgd->bntgrd", p.astype(q.dtype), vc,
            preferred_element_type=jnp.float32,
        )                                            # (B,n,T,KV,rep,hd)
        acc_p = constrain(acc_p, dp, seq_axes, None, None, None, None)
        # flatten (KV, rep) -> H for the shared combine below
        m_p = m_p.reshape(B, n, H, T)
        l_p = l_p.reshape(B, n, H, T)
        acc_p = acc_p.reshape(B, n, T, H, hd)
        # --- logsumexp combine across slices (the only cross-shard comms)
        # the acc payload crosses the ICI in bf16 (halves the all-reduce
        # bytes; stats stay f32; the final 1/l normalization is f32)
        m_c = jnp.max(m_p, axis=1)                   # (B,H,T)
        w = jnp.exp(m_p - m_c[:, None])              # (B,n,H,T)
        l_c = jnp.sum(l_p * w, axis=1)
        acc_w = (acc_p * w.transpose(0, 1, 3, 2)[..., None]).astype(q.dtype)
        acc_c = jnp.sum(acc_w, axis=1).astype(jnp.float32)
    else:
        # --- sequential chunk-scan over the committed cache
        ck = min(chunk_kv, S_c)
        pk = (-S_c) % ck
        kc = jnp.pad(k_cache, ((0, 0), (0, pk), (0, 0), (0, 0)))
        vc = jnp.pad(v_cache, ((0, 0), (0, pk), (0, 0), (0, 0)))
        kpos = jnp.pad(kv_pos, ((0, 0), (0, pk)), constant_values=jnp.int32(-1))
        nk = kc.shape[1] // ck
        kc = kc.reshape(B, nk, ck, KV, hd)
        vc = vc.reshape(B, nk, ck, KV, hd)
        kpos = kpos.reshape(B, nk, ck)

        def kv_step(carry, xs):
            m, l, acc = carry
            kj, vj, kpos_j = xs
            kj = _expand_kv(kj, rep)
            vj = _expand_kv(vj, rep)
            s = _scores(q, kj)                           # (B,H,T,ck)
            msk = _mask(q_pos, kpos_j, kind, window, sink)   # (B, T, ck)
            s = jnp.where(msk[:, None], s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            acc_new = acc * corr.transpose(0, 2, 1)[..., None] + _out(
                p.astype(q.dtype), vj
            )
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, H, T), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, H, T), jnp.float32)
        a0 = jnp.zeros((B, T, H, hd), jnp.float32)
        (m_c, l_c, acc_c), _ = jax.lax.scan(
            kv_step,
            (m0, l0, a0),
            (jnp.moveaxis(kc, 1, 0), jnp.moveaxis(vc, 1, 0), jnp.moveaxis(kpos, 1, 0)),
        )

    # --- carried staged-KV pass (incremental drafting): merge the carried
    # draft rows into the cache partials before the in-block pass, so the
    # final merge below is untouched whichever mode runs
    if k_staged is not None:
        if staged_pos is None or staged_mask is None:
            raise ValueError("k_staged requires staged_pos and staged_mask")
        s_s = _scores(q, _expand_kv(k_staged, rep))          # (B,H,T,N_s)
        vis_s = _mask(q_pos, staged_pos, kind, window, sink) & staged_mask
        s_s = jnp.where(vis_s[:, None], s_s, NEG_INF)
        m_s = jnp.max(s_s, axis=-1)
        m_cs = jnp.maximum(m_c, m_s)
        p_s = jnp.exp(s_s - m_cs[..., None])
        corr_s = jnp.exp(m_c - m_cs)
        l_c = l_c * corr_s + jnp.sum(p_s, axis=-1)
        acc_c = acc_c * corr_s.transpose(0, 2, 1)[..., None] + _out(
            p_s.astype(q.dtype), _expand_kv(v_staged, rep)
        )
        m_c = m_cs

    # --- dense pass over the staged draft tokens
    vis = _mask(q_pos, q_pos, kind, window, sink)    # (B, T, T) positional validity
    if tree_mask is not None:
        vis = vis & (tree_mask if tree_mask.ndim == 3 else tree_mask[None])

    if backend == "pallas":
        acc_d, m_d, l_d = _staged_pallas_partials(q, k_new, v_new, vis, rep)
        m_tot = jnp.maximum(m_c, m_d)
        corr_c = jnp.exp(m_c - m_tot)
        corr_d = jnp.exp(m_d - m_tot)
        l_tot = l_c * corr_c + l_d * corr_d
        acc = (
            acc_c * corr_c.transpose(0, 2, 1)[..., None]
            + acc_d * corr_d.transpose(0, 2, 1)[..., None]
        )
    else:
        s_d = _scores(q, _expand_kv(k_new, rep))     # (B,H,T,T)
        s_d = jnp.where(vis[:, None], s_d, NEG_INF)
        # --- merge softmax accumulators
        m_d = jnp.max(s_d, axis=-1)
        m_tot = jnp.maximum(m_c, m_d)
        p_d = jnp.exp(s_d - m_tot[..., None])
        corr_c = jnp.exp(m_c - m_tot)
        l_tot = l_c * corr_c + jnp.sum(p_d, axis=-1)
        acc = acc_c * corr_c.transpose(0, 2, 1)[..., None] + _out(
            p_d.astype(q.dtype), _expand_kv(v_new, rep)
        )
    l_tot = jnp.maximum(l_tot, 1e-30)
    out = acc / l_tot.transpose(0, 2, 1)[..., None]
    return out.astype(q.dtype)
