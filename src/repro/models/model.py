"""Decoder assembly: segment layout, init, train/prefill/decode, commit.

Execution model
---------------
Layers are grouped into *segments* of repeated identical units so the stack
lowers as ``lax.scan`` over repeats (compile-time friendly for 56-layer
models) with the unit unrolled inside the body. Homogeneous models have
unit=1; gemma3 has unit=6 (5 local + 1 global); jamba unit=8 (7 mamba + 1
attn, MoE every other layer).

DSIA layer gating
-----------------
Every entry point takes ``gates`` — a float (num_layers,) vector. A gated-off
layer (gate=0) contributes nothing to the residual stream and its staged
KV/state is ignored at commit. This is how layer-sparsity and early-exit
draft models are expressed *in the same compiled executable* (``mask`` mode).
``slice_params`` additionally materializes a reduced-depth param pytree for a
fixed skip set (``slice`` mode — fewer FLOPs, one compile per draft config).

Cache semantics: stage-then-commit
----------------------------------
``decode_step`` NEVER writes the cache: it returns logits plus per-layer
staged K/V (and per-step SSM states). After verification the engine calls
``commit_cache`` with the accepted path; rejected drafts leave no trace.
This is what makes speculative verification lossless and rollback-free, and
it is ring-buffer safe for sliding-window layers.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config.base import AttentionKind, BlockKind, ModelConfig
from repro.models import attention as attn_lib
from repro.models import moe as moe_lib
from repro.models import ssm as ssm_lib
from repro.models.layers import apply_rope, embed_tokens, mlp_apply, mlp_init, rms_norm, unembed
from repro.models.shard_utils import constrain, data_axis

Cache = Dict[str, Any]


# ===================================================================== layout
@dataclasses.dataclass(frozen=True)
class LayerSpec:
    block: BlockKind
    attn: AttentionKind
    is_moe: bool
    has_mlp: bool


@dataclasses.dataclass(frozen=True)
class Segment:
    start: int                       # first layer index
    repeats: int
    unit: Tuple[LayerSpec, ...]

    @property
    def num_layers(self) -> int:
        return self.repeats * len(self.unit)


def _layer_spec(cfg: ModelConfig, i: int) -> LayerSpec:
    return LayerSpec(
        block=cfg.block_kind(i),
        attn=cfg.attention_kind(i),
        is_moe=cfg.is_moe_layer(i) and cfg.has_mlp(i),
        has_mlp=cfg.has_mlp(i),
    )


def layout(cfg: ModelConfig) -> List[Segment]:
    """Partition layers into scan segments of repeated units."""
    specs = [_layer_spec(cfg, i) for i in range(cfg.num_layers)]
    n = cfg.num_layers
    # find the smallest unit size that tiles the prefix
    for u in range(1, n + 1):
        if all(specs[i] == specs[i % u] for i in range(n - n % u)):
            reps = n // u
            segs = [Segment(0, reps, tuple(specs[:u]))]
            if n % u:
                segs.append(Segment(reps * u, 1, tuple(specs[reps * u :])))
            return segs
    return [Segment(0, 1, tuple(specs))]


# ======================================================================= init
def _attn_init(key, cfg: ModelConfig, dtype) -> dict:
    d, H, KV = cfg.d_model, cfg.num_heads, cfg.num_kv_heads
    hd = cfg.resolved_head_dim()
    k1, k2, k3, k4 = jax.random.split(key, 4)
    s = d ** -0.5
    so = (H * hd) ** -0.5
    return {
        "wq": (jax.random.normal(k1, (d, H, hd)) * s).astype(dtype),
        "wk": (jax.random.normal(k2, (d, KV, hd)) * s).astype(dtype),
        "wv": (jax.random.normal(k3, (d, KV, hd)) * s).astype(dtype),
        "wo": (jax.random.normal(k4, (H, hd, d)) * so).astype(dtype),
    }


def _layer_init(key, cfg: ModelConfig, spec: LayerSpec, dtype) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    p: dict = {"norm1": jnp.zeros((cfg.d_model,), dtype)}
    if spec.block is BlockKind.ATTENTION:
        p["attn"] = _attn_init(k1, cfg, dtype)
    else:
        p["mamba"] = ssm_lib.ssm_init(k1, cfg.d_model, cfg.ssm, dtype)
    if spec.has_mlp:
        p["norm2"] = jnp.zeros((cfg.d_model,), dtype)
        if spec.is_moe:
            p["moe"] = moe_lib.moe_init(k2, cfg.d_model, cfg.moe, cfg.mlp_gated, dtype)
        else:
            p["mlp"] = mlp_init(k2, cfg.d_model, cfg.d_ff, cfg.mlp_gated, dtype)
    return p


def init_params(cfg: ModelConfig, key: jax.Array) -> dict:
    dtype = jnp.dtype(cfg.dtype)
    keys = jax.random.split(key, 4 + len(layout(cfg)))
    d, V = cfg.d_model, cfg.padded_vocab
    nc = max(cfg.num_codebooks, 1)
    embed_shape = (nc, V, d) if cfg.num_codebooks else (V, d)
    params: dict = {
        "embed": (jax.random.normal(keys[0], embed_shape) * d ** -0.5).astype(dtype),
        "final_norm": jnp.zeros((d,), dtype),
    }
    if not cfg.tie_embeddings:
        head_shape = (nc, d, V) if cfg.num_codebooks else (d, V)
        params["lm_head"] = (
            jax.random.normal(keys[1], head_shape) * d ** -0.5
        ).astype(dtype)
    segs = []
    for si, seg in enumerate(layout(cfg)):
        seg_keys = jax.random.split(keys[3 + si], seg.repeats * len(seg.unit)).reshape(
            (seg.repeats, len(seg.unit)) + keys.shape[1:]
        )

        def init_repeat(ks, _unit=seg.unit):
            return [
                _layer_init(ks[u], cfg, spec, dtype) for u, spec in enumerate(_unit)
            ]

        segs.append(jax.vmap(init_repeat)(seg_keys))
    params["segments"] = segs
    return params


# ====================================================================== cache
def pages_for(max_len: int, page_size: int) -> int:
    """Pages spanning ``max_len`` tokens (the page-table width per slot)."""
    return -(-max_len // page_size)


def init_cache(
    cfg: ModelConfig,
    batch: int,
    max_len: int,
    *,
    ring_window: bool = False,
    dtype=None,
    paged: bool = False,
    page_size: int = 64,
    num_pages: Optional[int] = None,
) -> Cache:
    """Allocate a committed cache. ``ring_window`` stores only `sliding_window`
    slots (ring buffer) for sliding layers — required for long_500k.

    ``paged=True`` replaces the dense per-slot ``(B, max_len)`` attention
    buffers with one SHARED page pool per layer — ``k_pages``/``v_pages``
    of shape ``(repeats, num_pages, page_size, KV, hd)`` — plus a top-level
    per-slot int32 ``page_table`` of shape ``(batch, max_len // page_size)``
    mapping logical page index -> pool page (-1 = unallocated). Every read
    and write addresses through the table (decode gathers a dense per-slot
    view; write_slot/commit_cache scatter through it), so attention output
    is BIT-identical to the dense cache: garbage in unallocated pages and
    beyond ``pos`` is killed by the same ``kv_pos`` masking that already
    handles partially-filled tails. SSM per-slot states are O(1) and stay
    dense. ``num_pages`` defaults to a full allocation (batch * pages per
    slot); callers that size requests can shrink it. Rings page nothing:
    ``ring_window`` + ``paged`` is rejected."""
    dtype = dtype or jnp.dtype(cfg.dtype)
    hd = cfg.resolved_head_dim()
    if paged:
        if ring_window:
            raise ValueError("paged caches do not support ring_window")
        if max_len % page_size:
            raise ValueError(
                f"max_len={max_len} must be a multiple of page_size={page_size}"
            )
        if num_pages is None:
            num_pages = batch * pages_for(max_len, page_size)
    segs = []
    for seg in layout(cfg):
        unit_caches = []
        for spec in seg.unit:
            if spec.block is BlockKind.ATTENTION:
                if paged:
                    unit_caches.append(
                        {
                            "k_pages": jnp.zeros(
                                (seg.repeats, num_pages, page_size, cfg.num_kv_heads, hd),
                                dtype,
                            ),
                            "v_pages": jnp.zeros(
                                (seg.repeats, num_pages, page_size, cfg.num_kv_heads, hd),
                                dtype,
                            ),
                        }
                    )
                    continue
                S_c = (
                    min(cfg.sliding_window, max_len)
                    if (ring_window and spec.attn is AttentionKind.SLIDING)
                    else max_len
                )
                unit_caches.append(
                    {
                        "k": jnp.zeros((seg.repeats, batch, S_c, cfg.num_kv_heads, hd), dtype),
                        "v": jnp.zeros((seg.repeats, batch, S_c, cfg.num_kv_heads, hd), dtype),
                    }
                )
            else:
                s = cfg.ssm
                nh = s.num_heads(cfg.d_model)
                din = s.d_inner(cfg.d_model)
                gds = s.ngroups * s.d_state
                R, K = seg.repeats, s.d_conv
                unit_caches.append(
                    {
                        "ssm": jnp.zeros((R, batch, nh, s.head_dim, s.d_state), jnp.float32),
                        "conv_x": jnp.zeros((R, batch, K - 1, din), dtype),
                        "conv_B": jnp.zeros((R, batch, K - 1, gds), dtype),
                        "conv_C": jnp.zeros((R, batch, K - 1, gds), dtype),
                    }
                )
        segs.append(unit_caches)
    out: Cache = {"pos": jnp.zeros((batch,), jnp.int32), "segments": segs}
    if paged:
        out["page_table"] = jnp.full(
            (batch, pages_for(max_len, page_size)), -1, jnp.int32
        )
    return out


# ================================================================ layer bodies
def _attn_layer(
    cfg: ModelConfig,
    p: dict,
    spec: LayerSpec,
    h: jax.Array,                  # (B, T, d)
    q_pos: jax.Array,              # (T,)
    mode: str,
    layer_cache: Optional[dict],
    tree_mask: Optional[jax.Array],
    gate: jax.Array,
    attn_override: Optional[dict] = None,   # {"kind","window","sink"} DSIA
    seq_axes: Optional[tuple] = None,       # context-parallel decode partials
    attn_backend: Optional[str] = None,     # "pallas": kernel tree-verify pass
    staged_buf: Optional[dict] = None,      # {"k","v"} carried draft KV block
    staged_pos: Optional[jax.Array] = None,
    staged_mask: Optional[jax.Array] = None,
) -> Tuple[jax.Array, Optional[dict]]:
    """Returns (residual delta, staged/new cache entries)."""
    B, T, _ = h.shape
    hd = cfg.resolved_head_dim()
    x = rms_norm(h, p["norm1"], cfg.norm_eps)
    # pin weights to their TP spec at the use site: FSDP-stored weights get
    # all-gathered over 'data' here (small), instead of GSPMD gathering the
    # activations (measured 1.6 GiB/layer on mixtral prefill)
    from repro.models.shard_utils import attention_head_policy, constrain_full

    pol = attention_head_policy(cfg.num_heads, cfg.num_kv_heads)
    qh = "model" if pol in ("kv", "q") else None
    kh = "model" if pol == "kv" else None
    wq = constrain_full(p["attn"]["wq"], None, qh, None)
    wk = constrain_full(p["attn"]["wk"], None, kh, None)
    wv = constrain_full(p["attn"]["wv"], None, kh, None)
    wo = constrain_full(p["attn"]["wo"], qh, None, None)
    q = jnp.einsum("btd,dhk->bthk", x, wq)
    k = jnp.einsum("btd,dgk->btgk", x, wk)
    v = jnp.einsum("btd,dgk->btgk", x, wv)
    rope_pos = q_pos[None, :] if q_pos.ndim == 1 else q_pos   # (B, T)
    q = apply_rope(q, rope_pos, cfg.rope_theta)
    k = apply_rope(k, rope_pos, cfg.rope_theta)

    kind = {
        AttentionKind.FULL: "causal",
        AttentionKind.SLIDING: "window",
    }[spec.attn]
    window = cfg.sliding_window
    sink = 0
    if attn_override is not None and spec.attn is AttentionKind.FULL:
        # Efficient-attention DSIA (StreamingLLM-style) applies to full-attn
        # layers only; sliding layers are already windowed.
        kind = attn_override["kind"]
        window = attn_override["window"]
        sink = attn_override.get("sink", 0)

    if mode in ("train", "prefill"):
        # pin attention inputs batch-sharded/model-replicated: the cache's
        # seq-sharded output spec otherwise back-propagates into k/v and the
        # blockwise kv-chunk scan gathers every chunk across the mesh
        from repro.models.shard_utils import data_axis as _dax
        k_a = constrain(k, _dax(), None, None, None)
        v_a = constrain(v, _dax(), None, None, None)
        q_a = constrain(q, _dax(), None, None, None)
        o = attn_lib.blockwise_attention(
            q_a, k_a, v_a, q_pos, q_pos, kind=kind, window=window,
            chunk_q=min(512, T), chunk_kv=min(1024, T),
        )
        staged = {"k": k, "v": v} if mode == "prefill" else None
    else:
        if "k_pages" in layer_cache:
            # block-paged cache: gather the slot's pages into a dense
            # (B, n_pp * P, KV, hd) view and run the unchanged decode path.
            # Unallocated pages (table -1, clamped to page 0) and rows past
            # ``pos`` hold garbage VALUES only — the kv_pos rule
            # (slot < pos) masks them to NEG_INF before the softmax, so the
            # output is bit-identical to the dense cache.
            tbl = layer_cache["_table"]                  # (B, n_pp)
            pool_k, pool_v = layer_cache["k_pages"], layer_cache["v_pages"]
            NP, P_sz = pool_k.shape[0], pool_k.shape[1]
            safe = jnp.clip(tbl, 0, NP - 1)
            Bt, n_pp = tbl.shape
            k_view = jnp.take(pool_k, safe, axis=0).reshape(
                Bt, n_pp * P_sz, pool_k.shape[2], pool_k.shape[3]
            )
            v_view = jnp.take(pool_v, safe, axis=0).reshape(
                Bt, n_pp * P_sz, pool_v.shape[2], pool_v.shape[3]
            )
            cache_kv = (k_view, v_view)
            ring = False
        else:
            S_c = layer_cache["k"].shape[2]
            # ring iff the allocation is capped at the window (see init_cache)
            ring = spec.attn is AttentionKind.SLIDING and S_c <= window
            cache_kv = (layer_cache["k"], layer_cache["v"])
        o = attn_lib.decode_attention(
            q,
            cache_kv[0],
            cache_kv[1],
            layer_cache["_pos"],
            k,
            v,
            q_pos,
            tree_mask=tree_mask,
            kind=kind,
            window=window,
            sink=sink,
            ring=bool(ring),
            chunk_kv=4096,
            seq_axes=None if ring else seq_axes,    # ring caches are small
            backend=attn_backend,
            k_staged=None if staged_buf is None else staged_buf["k"],
            v_staged=None if staged_buf is None else staged_buf["v"],
            staged_pos=staged_pos,
            staged_mask=staged_mask,
        )
        staged = {"k": k, "v": v}
    out = jnp.einsum("bthk,hkd->btd", o, wo)
    return out * gate, staged


def _mamba_layer(
    cfg: ModelConfig,
    p: dict,
    h: jax.Array,
    mode: str,
    layer_cache: Optional[dict],
    gate: jax.Array,
) -> Tuple[jax.Array, Optional[dict]]:
    B, T, _ = h.shape
    s = cfg.ssm
    x = rms_norm(h, p["norm1"], cfg.norm_eps)
    if layer_cache is None:  # train: fresh zero state
        nh = s.num_heads(cfg.d_model)
        din = s.d_inner(cfg.d_model)
        gds = s.ngroups * s.d_state
        layer_cache = {
            "ssm": jnp.zeros((B, nh, s.head_dim, s.d_state), jnp.float32),
            "conv_x": jnp.zeros((B, s.d_conv - 1, din), x.dtype),
            "conv_B": jnp.zeros((B, s.d_conv - 1, gds), x.dtype),
            "conv_C": jnp.zeros((B, s.d_conv - 1, gds), x.dtype),
        }
    out, new_cache, staged = ssm_lib.mamba_forward(
        p["mamba"], x, cfg.d_model, s, layer_cache, mode=mode,
    )
    if mode == "train":
        staged = None
    return out * gate, staged


def _mlp_layer(
    cfg: ModelConfig, p: dict, spec: LayerSpec, h, gate, aux_sum, mode: str,
    quantize=None,
):
    x = rms_norm(h, p["norm2"], cfg.norm_eps)
    if spec.is_moe:
        if mode == "train":
            moe_mode = "train"
        elif mode == "prefill" and not cfg.moe.prefill_dropless:
            moe_mode = "infer_grouped"     # TPU prefill: sharded capacity path
        else:
            moe_mode = "infer"             # dropless — batch-invariant decode
        # expert matmuls stay in the model dtype: the ActivationQuant DSIA
        # quantizes the dense-MLP hot path only (see docs/cascade.md)
        y, aux = moe_lib.moe_apply(
            p["moe"], x, cfg.moe, cfg.act, cfg.mlp_gated, mode=moe_mode,
        )
        aux_sum = aux_sum + aux["load_balance"] + aux["router_z"]
    else:
        y = mlp_apply(p["mlp"], x, cfg.act, cfg.mlp_gated, quantize=quantize)
    return y * gate, aux_sum


# ================================================================== traversal
def _run_stack(
    cfg: ModelConfig,
    params: dict,
    h: jax.Array,
    *,
    mode: str,
    cache: Optional[Cache],
    gates: Optional[jax.Array],
    q_pos: jax.Array,
    tree_mask: Optional[jax.Array],
    remat: bool = False,
    attn_override: Optional[dict] = None,
    seq_axes: Optional[tuple] = None,
    attn_backend: Optional[str] = None,
    quantize: Optional[str] = None,
    staged_kv: Optional[Any] = None,        # carried draft-KV segments (decode)
    staged_pos: Optional[jax.Array] = None,
    staged_mask: Optional[jax.Array] = None,
) -> Tuple[jax.Array, Any, jax.Array]:
    """Returns (hidden, staged_or_new_cache_segments, moe_aux_sum)."""
    segs = layout(cfg)
    if gates is None:
        gates = jnp.ones((cfg.num_layers,), h.dtype)
    gates = gates.astype(h.dtype)
    cache_pos = cache["pos"] if cache is not None else jnp.zeros((), jnp.int32)
    # paged caches: the per-slot page table is closed over (like cache_pos),
    # NOT scanned — every layer of a segment shares the one (B, n_pp) table
    page_table = cache.get("page_table") if cache is not None else None

    staged_segments = []
    aux = jnp.zeros((), jnp.float32)

    for si, seg in enumerate(segs):
        U = seg.repeats * len(seg.unit)
        g_seg = jax.lax.dynamic_slice(gates, (seg.start,), (U,)).reshape(
            seg.repeats, len(seg.unit)
        )
        p_seg = params["segments"][si]
        c_seg = cache["segments"][si] if cache is not None else None
        s_seg = staged_kv[si] if staged_kv is not None else None

        def body(carry, xs, _unit=seg.unit):
            hh, aux_c = carry
            hh = constrain(hh, data_axis(), None, None)   # keep batch sharded
            p_u, g_u, c_u, s_u = xs
            staged_u = []
            for u, spec in enumerate(_unit):
                p_l = p_u[u]
                lc = None
                if c_u is not None:
                    lc = dict(c_u[u])
                    lc["_pos"] = cache_pos
                    if page_table is not None:
                        lc["_table"] = page_table
                gate = g_u[u]
                if spec.block is BlockKind.ATTENTION:
                    delta, staged = _attn_layer(
                        cfg, p_l, spec, hh, q_pos, mode, lc, tree_mask, gate,
                        attn_override, seq_axes, attn_backend,
                        staged_buf=None if s_u is None else s_u[u],
                        staged_pos=staged_pos, staged_mask=staged_mask,
                    )
                else:
                    delta, staged = _mamba_layer(cfg, p_l, hh, mode, lc, gate)
                hh = hh + delta
                if spec.has_mlp:
                    delta2, aux_c = _mlp_layer(
                        cfg, p_l, spec, hh, gate, aux_c, mode, quantize
                    )
                    hh = hh + delta2
                staged_u.append(staged)
            return (hh, aux_c), tuple(staged_u)

        body_fn = jax.checkpoint(body) if remat else body
        if seg.repeats == 1:
            (h, aux), staged = body_fn(
                (h, aux),
                (
                    jax.tree.map(lambda a: a[0], p_seg),
                    g_seg[0],
                    jax.tree.map(lambda a: a[0], c_seg) if c_seg is not None else None,
                    jax.tree.map(lambda a: a[0], s_seg) if s_seg is not None else None,
                ),
            )
            staged = jax.tree.map(lambda a: a[None], staged)
        else:
            (h, aux), staged = jax.lax.scan(
                body_fn, (h, aux), (p_seg, g_seg, c_seg, s_seg)
            )
        staged_segments.append(staged)
    return h, staged_segments, aux


def _embed(cfg: ModelConfig, params: dict, batch: Dict[str, jax.Array]) -> jax.Array:
    tokens = batch["tokens"]
    if cfg.num_codebooks:
        # (B, S, nc) codec tokens -> sum of per-codebook embeddings
        e = sum(
            embed_tokens(params["embed"][c], tokens[..., c])
            for c in range(cfg.num_codebooks)
        )
    else:
        e = embed_tokens(params["embed"], tokens)
    if cfg.num_image_tokens and "image_embeds" in batch:
        # VLM stub: splice precomputed patch embeddings where image_mask=1
        mask = batch["image_mask"][..., None].astype(e.dtype)
        img = batch["image_embeds"].astype(e.dtype)
        B, S, d = e.shape
        Ti = img.shape[1]
        pad = jnp.zeros((B, S - Ti, d), e.dtype)
        img_full = jnp.concatenate([img, pad], axis=1)
        # image tokens occupy the first Ti aligned slots marked by the mask
        e = e * (1 - mask) + img_full * mask
    return e


def _head(cfg: ModelConfig, params: dict, h: jax.Array) -> jax.Array:
    h = constrain(h, data_axis(), None, None)
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    if cfg.num_codebooks:
        if cfg.tie_embeddings:
            heads = jnp.swapaxes(params["embed"], 1, 2)    # (nc, d, V)
        else:
            heads = params["lm_head"]
        logits = jnp.einsum(
            "btd,cdv->btcv", h.astype(jnp.float32), heads.astype(jnp.float32)
        )
    else:
        head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
        logits = unembed(h, head)
    if cfg.padded_vocab != cfg.vocab_size:
        ids = jnp.arange(cfg.padded_vocab)
        logits = jnp.where(ids < cfg.vocab_size, logits, -1e30)
    return logits


# =============================================================== entry points
def forward_train(
    cfg: ModelConfig,
    params: dict,
    batch: Dict[str, jax.Array],
    *,
    gates: Optional[jax.Array] = None,
    remat: bool = True,
) -> Tuple[jax.Array, jax.Array]:
    """Full causal forward. Returns (logits (B,S,[nc,]V) f32, moe_aux)."""
    h = _embed(cfg, params, batch)
    S = h.shape[1]
    q_pos = jnp.arange(S, dtype=jnp.int32)
    h, _, aux = _run_stack(
        cfg, params, h, mode="train", cache=None, gates=gates,
        q_pos=q_pos, tree_mask=None, remat=remat,
    )
    return _head(cfg, params, h), aux


def prefill(
    cfg: ModelConfig,
    params: dict,
    batch: Dict[str, jax.Array],
    cache: Cache,
    *,
    gates: Optional[jax.Array] = None,
) -> Tuple[jax.Array, Cache]:
    """Process the prompt, fill the cache. Returns (last-token logits, cache)."""
    h = _embed(cfg, params, batch)
    B, S, _ = h.shape
    q_pos = jnp.arange(S, dtype=jnp.int32)
    h, staged, _ = _run_stack(
        cfg, params, h, mode="prefill", cache=cache, gates=gates,
        q_pos=q_pos, tree_mask=None,
    )
    new_cache = _write_prefill(cfg, cache, staged, S)
    logits = _head(cfg, params, h[:, -1:])
    return logits[:, 0], new_cache


def _write_prefill(cfg: ModelConfig, cache: Cache, staged, S: int) -> Cache:
    if "page_table" in cache:
        raise NotImplementedError(
            "prefill writes a dense cache; paged serving prefills a dense "
            "bucketed B=1 cache and scatters it with write_slot, or chunks "
            "the prompt through decode_step + commit_cache "
            "(engine.prefill_chunk_stage)"
        )
    segs = layout(cfg)
    new_segments = []
    for si, seg in enumerate(segs):
        new_unit = []
        for u, spec in enumerate(seg.unit):
            c = cache["segments"][si][u]
            st = staged[si][u]
            if spec.block is BlockKind.ATTENTION:
                S_c = c["k"].shape[2]
                k, v = st["k"], st["v"]               # (R, B, S, KV, hd)
                if S_c >= S:
                    newk = jax.lax.dynamic_update_slice_in_dim(c["k"], k.astype(c["k"].dtype), 0, axis=2)
                    newv = jax.lax.dynamic_update_slice_in_dim(c["v"], v.astype(c["v"].dtype), 0, axis=2)
                else:
                    # ring: keep last S_c tokens arranged by pos % S_c
                    last = S - 1
                    slots = jnp.arange(S_c)
                    src = last - ((last - slots) % S_c)   # position stored in slot
                    newk = jnp.take(k, src, axis=2).astype(c["k"].dtype)
                    newv = jnp.take(v, src, axis=2).astype(c["v"].dtype)
                new_unit.append({"k": newk, "v": newv})
            else:
                # staged mamba leaves carry a length-1 step axis after batch
                new_unit.append(
                    jax.tree.map(
                        lambda a, old: a[:, :, 0].astype(old.dtype), st, c
                    )
                )
        new_segments.append(new_unit)
    batch = cache["pos"].shape[0]
    return {"pos": jnp.full((batch,), S, jnp.int32), "segments": new_segments}


def decode_step(
    cfg: ModelConfig,
    params: dict,
    cache: Cache,
    tokens: jax.Array,                # (B, T) or (B, T, nc)
    *,
    gates: Optional[jax.Array] = None,
    tree_mask: Optional[jax.Array] = None,   # (T, T) or (B, T, T) ancestor-or-self
    q_pos: Optional[jax.Array] = None,       # (T,) or (B, T) absolute positions
    attn_override: Optional[dict] = None,    # efficient-attention DSIA
    seq_axes: Optional[tuple] = None,        # context-parallel cache partials
    attn_backend: Optional[str] = None,      # "pallas": kernel tree-verify pass
    quantize: Optional[str] = None,          # "int8": W8A8 MLP matmuls (DSIA)
    staged_kv: Optional[Any] = None,         # carried draft-KV buffers (carry)
    staged_pos: Optional[jax.Array] = None,  # (B, N_s) staged-row positions
    staged_mask: Optional[jax.Array] = None, # (B, T, N_s) staged visibility
) -> Tuple[jax.Array, Any]:
    """Stage-only decode of T tokens against a frozen cache.

    Returns (logits (B,T,[nc,]V), staged) — commit with ``commit_cache``.
    A 3-D tree mask carries one ancestor-closure per sequence (batched tree
    verification); paired with a (B, T) ``q_pos`` of per-node depths.
    ``quantize="int8"`` routes the dense-MLP matmuls through the Pallas
    W8A8 kernel (ActivationQuant DSIA drafting; TPU-compiled — off-TPU
    callers simulate with ``engine.fake_quant_int8`` params instead).

    Incremental mode (``draft_kv="carry"`` in the engine scans): pass
    ``staged_kv`` — a carried pytree with the same structure a previous
    ``decode_step`` returned as ``staged`` (per-layer (R, B, N_s, KV, hd)
    K/V blocks) — plus ``staged_pos``/``staged_mask``. The T new tokens then
    attend over [committed cache ++ carried staged rows ++ themselves],
    so an expansion step decodes only its appended tokens instead of
    re-decoding the whole padded block. The returned ``staged`` holds the
    NEW rows only; the caller scatters them into its carried buffers at the
    append indices (write cursor = the tree's ``count``). Attention-only
    stacks: SSM per-step states are cumulative and cannot be carried
    row-wise (the engine guards this).
    """
    h = _embed(cfg, params, {"tokens": tokens})
    B, T = tokens.shape[0], tokens.shape[1]
    if q_pos is None:
        q_pos = cache["pos"][:, None] + jnp.arange(T, dtype=jnp.int32)[None]
    elif q_pos.ndim == 1:
        q_pos = jnp.broadcast_to(q_pos[None], (B, T))
    h, staged, _ = _run_stack(
        cfg, params, h, mode="decode", cache=cache, gates=gates,
        q_pos=q_pos, tree_mask=tree_mask, attn_override=attn_override,
        seq_axes=seq_axes, attn_backend=attn_backend, quantize=quantize,
        staged_kv=staged_kv, staged_pos=staged_pos, staged_mask=staged_mask,
    )
    return _head(cfg, params, h), staged


def decode_commit_token(
    cfg: ModelConfig,
    params: dict,
    cache: Cache,
    token: jax.Array,                 # (B,) one token per sequence
    *,
    gates: Optional[jax.Array] = None,
    attn_override: Optional[dict] = None,
) -> Tuple[jax.Array, Cache]:
    """Scan-friendly single-token decode: decode one token per sequence and
    immediately commit its staged KV/state, advancing ``pos`` by one.

    Unlike ``decode_step`` this WRITES the cache. It exists for the draft
    side of chain speculation, where the k-step drafting loop runs as one
    jitted ``lax.scan`` with the cache as carry — every drafted token must be
    visible to the next draft step without a host round trip. Draft scratch
    caches are discarded after proposing, so the losslessness invariant
    (only verified tokens reach the *committed* cache) is untouched.

    Returns (logits (B, V), new_cache). Codebook (audio) models are not
    supported on this path — their tokens are (B, nc), not scalar.
    """
    logits, staged = decode_step(
        cfg, params, cache, token[:, None], gates=gates,
        attn_override=attn_override,
    )
    B = token.shape[0]
    path_idx = jnp.zeros((B, 1), jnp.int32)
    new_cache = commit_cache(
        cfg, cache, staged, path_idx, jnp.ones((B,), jnp.int32)
    )
    return logits[:, 0], new_cache


def write_slot(cfg: ModelConfig, cache: Cache, c1: Cache, slot) -> Cache:
    """Write a freshly prefilled B=1 cache into batch slot ``slot`` of the
    batched cache — one dynamic-update per leaf, jit-friendly (``slot`` may
    be traced, so one executable serves every slot). Jitted with the batched
    cache donated, admission updates the largest live buffer in place
    instead of round-tripping a full copy through the host.

    ``c1`` may be allocated at a padded BUCKET shorter than the batched
    cache's ``max_len`` (admission sizes it to the prompt, not the worst
    case): its rows land at the front of the slot, and rows past
    ``c1["pos"]`` are never read (kv_pos masking), so the leftover tail
    from the slot's previous occupant is as invisible as the zeros a
    full-length prefill cache used to write there. When the batched cache
    is PAGED, the same rows scatter through ``page_table[slot]`` instead —
    the pages must have been allocated (table row set) before the call.
    """
    segs = layout(cfg)
    paged = "page_table" in cache
    new_segments = []
    for si, seg in enumerate(segs):
        new_unit = []
        for u, spec in enumerate(seg.unit):
            dst = cache["segments"][si][u]
            src = c1["segments"][si][u]
            if spec.block is BlockKind.ATTENTION and paged:
                table = cache["page_table"]
                pool = dst["k_pages"]
                NP, P_sz = pool.shape[1], pool.shape[2]
                tbl_row = table[slot]                       # (n_pp,)
                rows = jnp.arange(src["k"].shape[2], dtype=jnp.int32)
                page = jnp.take(
                    tbl_row, jnp.clip(rows // P_sz, 0, tbl_row.shape[0] - 1)
                )
                # unallocated page -> OOB sentinel, dropped by the scatter;
                # offset by the logical page so (page, off) pairs stay
                # unique (duplicates under unique_indices=True are UB)
                page = jnp.where(page >= 0, page, NP + rows // P_sz)
                off = rows % P_sz
                ent = {}
                for name in ("k", "v"):
                    s = src[name][:, 0].astype(dst[name + "_pages"].dtype)
                    ent[name + "_pages"] = dst[name + "_pages"].at[
                        :, page, off
                    ].set(s, mode="drop", unique_indices=True)
                new_unit.append(ent)
            elif spec.block is BlockKind.ATTENTION:
                S_c = dst["k"].shape[2]
                S_src = src["k"].shape[2]
                if S_src == S_c:
                    new_unit.append(jax.tree.map(
                        lambda d, s: d.at[:, slot].set(s[:, 0].astype(d.dtype)),
                        dst, src,
                    ))
                elif S_src < S_c:
                    new_unit.append({
                        name: jax.lax.dynamic_update_slice(
                            dst[name],
                            src[name].astype(dst[name].dtype),
                            (0, slot, 0, 0, 0),
                        )
                        for name in ("k", "v")
                    })
                else:
                    raise NotImplementedError(
                        f"prefill cache seq {S_src} exceeds batched cache "
                        f"seq {S_c} (ring slots cannot take longer buckets)"
                    )
            else:
                new_unit.append(jax.tree.map(
                    lambda d, s: d.at[:, slot].set(s[:, 0].astype(d.dtype)),
                    dst, src,
                ))
        new_segments.append(new_unit)
    out = dict(cache)
    out["pos"] = cache["pos"].at[slot].set(c1["pos"][0])
    out["segments"] = new_segments
    return out


def commit_cache(
    cfg: ModelConfig,
    cache: Cache,
    staged,
    path_idx: jax.Array,              # (T,) or (B,T) indices into the staged T dim
    n_accept: jax.Array,              # scalar or (B,) int32 accepted count (<= T)
) -> Cache:
    """Write the accepted draft path into the cache and advance pos.

    Per-sequence ``path_idx``/``n_accept`` supports batched serving where
    different sequences accept different draft prefixes.
    """
    segs = layout(cfg)
    base = cache["pos"]                              # (B,)
    B = base.shape[0]
    if path_idx.ndim == 1:
        path_idx = jnp.broadcast_to(path_idx[None], (B, path_idx.shape[0]))
    T = path_idx.shape[1]
    n_acc = jnp.broadcast_to(jnp.asarray(n_accept, jnp.int32), (B,))
    step = jnp.arange(T, dtype=jnp.int32)
    live = step[None] < n_acc[:, None]               # (B, T)
    b_idx = jnp.arange(B)[:, None]
    new_segments = []
    for si, seg in enumerate(segs):
        new_unit = []
        for u, spec in enumerate(seg.unit):
            c = cache["segments"][si][u]
            st = staged[si][u]
            if spec.block is BlockKind.ATTENTION and "k_pages" in c:
                # paged commit: same gather of the accepted path, but the
                # destination row (pos + step) routes through the page
                # table — rejected rows AND rows whose page is unallocated
                # get the OOB sentinel page and are dropped in place
                NP, P_sz = c["k_pages"].shape[1], c["k_pages"].shape[2]
                table = cache["page_table"]                      # (B, n_pp)
                n_pp = table.shape[1]
                gidx = path_idx[None, :, :, None, None]          # (1,B,T,1,1)
                k = jnp.take_along_axis(
                    st["k"].astype(c["k_pages"].dtype), gidx, axis=2
                )
                v = jnp.take_along_axis(
                    st["v"].astype(c["v_pages"].dtype), gidx, axis=2
                )
                dest = base[:, None] + step[None]                # (B, T)
                pg_log = dest // P_sz
                page = jnp.take_along_axis(
                    table, jnp.clip(pg_log, 0, n_pp - 1), axis=1
                )
                ok = live & (pg_log < n_pp) & (page >= 0)
                # dropped rows need an OOB page that is UNIQUE per (b, t):
                # a shared sentinel would repeat (page, off) pairs across
                # slots, and duplicate indices under unique_indices=True
                # are undefined behavior (nondeterministic on CPU)
                oob = NP + b_idx * T + step[None]                # (B, T)
                page = jnp.where(ok, page, oob)
                off = dest % P_sz
                ck = c["k_pages"].at[:, page, off].set(
                    k, mode="drop", unique_indices=True
                )
                cv = c["v_pages"].at[:, page, off].set(
                    v, mode="drop", unique_indices=True
                )
                new_unit.append({"k_pages": ck, "v_pages": cv})
            elif spec.block is BlockKind.ATTENTION:
                S_c = c["k"].shape[2]
                gidx = path_idx[None, :, :, None, None]          # (1,B,T,1,1)
                # cast BEFORE the gather/scatter chain: the staged tensors
                # cross shards on their way to the cache owners — in bf16,
                # not f32 (halves the commit collective)
                k = jnp.take_along_axis(st["k"].astype(c["k"].dtype), gidx, axis=2)
                v = jnp.take_along_axis(st["v"].astype(c["v"].dtype), gidx, axis=2)
                dest = base[:, None] + step[None]                # (B, T)
                ring = S_c <= cfg.sliding_window and spec.attn is AttentionKind.SLIDING
                if ring:
                    dest = dest % S_c
                # copy-free in-place commit: rejected slots get an
                # OUT-OF-BOUNDS dest — jax scatter drops OOB updates
                # (mode='drop'), so no old-row gather, no trash row, and
                # the scatter can alias the donated cache in place. The
                # OOB dest is offset per step: repeated indices under
                # unique_indices=True are undefined behavior even when
                # every duplicate is dropped.
                dest = jnp.where(live, dest, S_c + step[None])
                ck = c["k"].at[:, b_idx, dest].set(
                    k, mode="drop", unique_indices=True
                )
                cv = c["v"].at[:, b_idx, dest].set(
                    v, mode="drop", unique_indices=True
                )
                new_unit.append({"k": ck, "v": cv})
            else:
                # staged mamba leaves: (R, B, T, ...) per-step states
                idx = jnp.clip(n_acc - 1, 0, T - 1)              # (B,)
                keep = (n_acc == 0)

                def commit_state(a, old):
                    idx_e = idx.reshape((1, B, 1) + (1,) * (a.ndim - 3))
                    new = jnp.take_along_axis(a, idx_e, axis=2)[:, :, 0]
                    keep_e = keep.reshape((1, B) + (1,) * (old.ndim - 2))
                    return jnp.where(keep_e, old, new.astype(old.dtype))

                new_unit.append(jax.tree.map(commit_state, st, c))
        new_segments.append(new_unit)
    out = dict(cache)                 # paged caches carry their page_table
    out["pos"] = base + n_acc
    out["segments"] = new_segments
    return out
