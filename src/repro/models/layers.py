"""Primitive layers: norms, RoPE, MLPs, embeddings. Pure functions over pytrees."""
from __future__ import annotations


import jax
import jax.numpy as jnp


def rms_norm(x: jax.Array, weight: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + weight.astype(jnp.float32))).astype(dt)


# ---------------------------------------------------------------------- RoPE
def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    """Inverse frequencies, shape (head_dim // 2,), float32."""
    exponent = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta ** exponent)


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotate (..., S, H, head_dim) by per-token integer ``positions`` (..., S)."""
    dt = x.dtype
    hd = x.shape[-1]
    inv = rope_frequencies(hd, theta)                       # (hd/2,)
    ang = positions.astype(jnp.float32)[..., None] * inv    # (..., S, hd/2)
    cos = jnp.cos(ang)[..., None, :]                        # (..., S, 1, hd/2)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(dt)


# ----------------------------------------------------------------------- MLP
def _mm(x: jax.Array, w: jax.Array, quantize) -> jax.Array:
    """(..., d) @ (d, f), optionally through the W8A8 Pallas kernel.

    ``quantize="int8"`` routes the matmul through
    ``kernels.ops.quantized_matmul`` (dynamic per-row activation / per-col
    weight int8 — the ActivationQuant DSIA's TPU execution; off-TPU the
    kernel runs interpreted, so CPU callers simulate with fake-quantized
    weights instead and never set the flag on hot paths).
    """
    if quantize is None:
        return jnp.einsum("...d,df->...f", x, w)
    if quantize != "int8":
        raise ValueError(f"unsupported quantize mode {quantize!r}")
    from repro.kernels.ops import quantized_matmul

    lead = x.shape[:-1]
    out = quantized_matmul(x.reshape(-1, x.shape[-1]), w)
    return out.reshape(*lead, w.shape[-1]).astype(x.dtype)


def mlp_apply(
    params: dict, x: jax.Array, act: str, gated: bool, quantize=None
) -> jax.Array:
    """SwiGLU/GeGLU (gated) or plain 2-matrix MLP.

    Weights are pinned to their TP spec at the use site so FSDP-stored
    shards are gathered over 'data' (cheap) rather than the activations.
    ``quantize`` routes the three projections through the W8A8 kernel (the
    MLP carries the bulk of the stack's matmul FLOPs; attention projections
    and the LM head stay in the model dtype).
    """
    from repro.models.shard_utils import constrain_full

    fn = jax.nn.silu if act == "silu" else jax.nn.gelu
    w_up = constrain_full(params["w_up"], None, "model")
    w_down = constrain_full(params["w_down"], "model", None)
    if gated:
        w_gate = constrain_full(params["w_gate"], None, "model")
        g = fn(_mm(x, w_gate, quantize))
        u = _mm(x, w_up, quantize)
        return _mm(g * u, w_down, quantize)
    h = fn(_mm(x, w_up, quantize))
    return _mm(h, w_down, quantize)


def mlp_init(key: jax.Array, d_model: int, d_ff: int, gated: bool, dtype) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    scale_in = d_model ** -0.5
    scale_out = d_ff ** -0.5
    p = {
        "w_up": (jax.random.normal(k1, (d_model, d_ff)) * scale_in).astype(dtype),
        "w_down": (jax.random.normal(k2, (d_ff, d_model)) * scale_out).astype(dtype),
    }
    if gated:
        p["w_gate"] = (jax.random.normal(k3, (d_model, d_ff)) * scale_in).astype(dtype)
    return p


# ----------------------------------------------------------------- embeddings
def embed_tokens(embedding: jax.Array, tokens: jax.Array) -> jax.Array:
    return jnp.take(embedding, tokens, axis=0)


def unembed(x: jax.Array, head: jax.Array) -> jax.Array:
    """(..., d) @ (d, V) -> logits in float32."""
    return jnp.einsum("...d,dv->...v", x.astype(jnp.float32), head.astype(jnp.float32))
