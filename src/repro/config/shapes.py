"""Canonical input shapes assigned to this paper."""
from repro.config.base import InputShape

INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", seq_len=4_096, global_batch=256, kind="train"),
    "prefill_32k": InputShape("prefill_32k", seq_len=32_768, global_batch=32, kind="prefill"),
    "decode_32k": InputShape("decode_32k", seq_len=32_768, global_batch=128, kind="decode"),
    "long_500k": InputShape("long_500k", seq_len=524_288, global_batch=1, kind="decode"),
}


def get_shape(name: str) -> InputShape:
    if name not in INPUT_SHAPES:
        raise KeyError(f"unknown shape {name!r}; known: {sorted(INPUT_SHAPES)}")
    return INPUT_SHAPES[name]
