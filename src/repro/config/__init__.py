"""Typed configuration system for repro.

`ModelConfig` is the single source of truth for an architecture; configs are
registered by id in `repro.configs` and selected with ``--arch <id>``.
"""
from repro.config.base import (
    AttentionKind,
    BlockKind,
    InputShape,
    ModelConfig,
    MoEConfig,
    SSMConfig,
    get_config,
    list_configs,
    register_config,
)
from repro.config.shapes import INPUT_SHAPES, get_shape

__all__ = [
    "AttentionKind",
    "BlockKind",
    "InputShape",
    "ModelConfig",
    "MoEConfig",
    "SSMConfig",
    "get_config",
    "list_configs",
    "register_config",
    "INPUT_SHAPES",
    "get_shape",
]
