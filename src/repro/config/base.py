"""Core config dataclasses and the architecture registry."""
from __future__ import annotations

import dataclasses
import enum
from typing import Callable, Dict, List, Optional


class AttentionKind(str, enum.Enum):
    FULL = "full"
    SLIDING = "sliding"          # sliding-window attention
    NONE = "none"                # attention-free (SSM) layer


class BlockKind(str, enum.Enum):
    """Per-layer mixer kind."""

    ATTENTION = "attention"
    MAMBA = "mamba"


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff_expert: int                 # per-expert FFN hidden dim
    num_shared_experts: int = 0      # always-on experts (Qwen2-MoE style)
    d_ff_shared: int = 0             # hidden dim of the shared expert block
    moe_layer_period: int = 1        # every `period`-th layer is MoE
    moe_layer_offset: int = 0
    capacity_factor: float = 1.25
    router_z_loss: float = 1e-3
    load_balance_loss: float = 1e-2
    # execution knobs (not architecture): see models.moe
    exec_groups: int = 1             # expert-group count for capacity dispatch
    infer_capacity_factor: float = 2.0
    prefill_dropless: bool = True    # False -> grouped-capacity prefill (TPU)

    def is_moe_layer(self, layer_idx: int) -> bool:
        return layer_idx % self.moe_layer_period == self.moe_layer_offset


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    """Mamba2 (SSD) hyperparameters."""

    d_state: int = 128
    d_conv: int = 4
    expand: int = 2                  # d_inner = expand * d_model
    head_dim: int = 64               # SSD head dim P
    chunk_size: int = 128            # SSD chunk length
    ngroups: int = 1                 # B/C groups

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def num_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """A decoder architecture. One instance per ``--arch`` id."""

    name: str
    family: str                      # dense | moe | vlm | hybrid | ssm | audio
    source: str                      # citation (paper / model card)

    num_layers: int = 12
    d_model: int = 768
    num_heads: int = 12
    num_kv_heads: int = 12
    d_ff: int = 3072
    vocab_size: int = 32000
    head_dim: int = 0                # 0 -> d_model // num_heads

    # attention layout
    attention_pattern: str = "full"  # "full" | "sliding" | "local_global:<n_local>" | "none"
    sliding_window: int = 4096
    rope_theta: float = 10_000.0
    max_position: int = 1 << 20

    # mixer layout (hybrid models)
    attn_layer_period: int = 1       # every `period`-th layer is attention (rest mamba)
    attn_layer_offset: int = 0

    # sub-configs
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None

    # modality frontends (stubbed per spec: backbone consumes embeddings)
    num_image_tokens: int = 0        # VLM: patch-embedding tokens per image
    num_codebooks: int = 0           # audio: EnCodec codebooks (0 = plain text LM)

    norm_eps: float = 1e-5
    act: str = "silu"                # silu (SwiGLU) | gelu
    mlp_gated: bool = True           # 3-matrix gated MLP (SwiGLU/GeGLU) vs 2-matrix
    tie_embeddings: bool = False
    dtype: str = "bfloat16"

    # ------------------------------------------------------------------ layout
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // max(self.num_heads, 1))

    @property
    def padded_vocab(self) -> int:
        """Vocab rounded up to a multiple of 256 — required so the embedding
        and lm_head shard cleanly over the 16-way model axis (the standard
        production padding; logits for padded ids are masked to -inf)."""
        return ((self.vocab_size + 255) // 256) * 256

    def block_kind(self, layer_idx: int) -> BlockKind:
        if self.attention_pattern == "none":
            return BlockKind.MAMBA
        if self.attn_layer_period == 1:
            return BlockKind.ATTENTION
        if layer_idx % self.attn_layer_period == self.attn_layer_offset:
            return BlockKind.ATTENTION
        return BlockKind.MAMBA

    def attention_kind(self, layer_idx: int) -> AttentionKind:
        if self.block_kind(layer_idx) is not BlockKind.ATTENTION:
            return AttentionKind.NONE
        pat = self.attention_pattern
        if pat == "full":
            return AttentionKind.FULL
        if pat == "sliding":
            return AttentionKind.SLIDING
        if pat.startswith("local_global:"):
            n_local = int(pat.split(":")[1])
            # pattern of (n_local sliding, 1 full), gemma3-style
            return (
                AttentionKind.FULL
                if layer_idx % (n_local + 1) == n_local
                else AttentionKind.SLIDING
            )
        raise ValueError(f"unknown attention_pattern: {pat}")

    def is_moe_layer(self, layer_idx: int) -> bool:
        # In hybrids (Jamba) only non-skipped MLP slots can be MoE; mamba2 has no MLP.
        if self.moe is None or self.d_ff == 0 and self.moe is None:
            return False
        return self.moe.is_moe_layer(layer_idx)

    def has_mlp(self, layer_idx: int) -> bool:
        """Pure-SSM blocks (mamba2) have no separate MLP."""
        if self.family == "ssm":
            return False
        return self.d_ff > 0 or self.is_moe_layer(layer_idx)

    @property
    def supports_long_context(self) -> bool:
        """True when decode at 500k does not need a full-attention KV per layer."""
        if self.attention_pattern == "none":
            return True
        if self.attention_pattern == "sliding":
            return True
        if self.attention_pattern.startswith("local_global:"):
            return True
        return self.attn_layer_period > 1  # hybrid: few attn layers, CP-sharded KV

    # --------------------------------------------------------------- accounting
    def param_count(self) -> int:
        """Analytic parameter count (matches models.params init exactly)."""
        d, hd = self.d_model, self.resolved_head_dim()
        total = self.vocab_size * d                    # embed
        if not self.tie_embeddings:
            total += self.vocab_size * d               # lm head
        if self.num_codebooks:
            total += (self.num_codebooks - 1) * self.vocab_size * d  # extra codebooks
            total += (self.num_codebooks - 1) * self.vocab_size * d
        total += d                                     # final norm
        for i in range(self.num_layers):
            total += d                                 # pre-mixer norm
            if self.block_kind(i) is BlockKind.ATTENTION:
                q = d * self.num_heads * hd
                kv = 2 * d * self.num_kv_heads * hd
                o = self.num_heads * hd * d
                total += q + kv + o
            else:
                s = self.ssm or SSMConfig()
                din = s.d_inner(d)
                nh = s.num_heads(d)
                total += d * (2 * din + 2 * s.ngroups * s.d_state + nh)  # in_proj
                total += s.d_conv * (din + 2 * s.ngroups * s.d_state)    # conv
                total += nh + nh + nh                                    # A_log, D, dt_bias
                total += din                                             # norm gate
                total += din * d                                         # out_proj
            if self.has_mlp(i):
                total += d                             # pre-mlp norm
                nmat = 3 if self.mlp_gated else 2
                if self.is_moe_layer(i):
                    m = self.moe
                    total += d * m.num_experts         # router
                    total += m.num_experts * nmat * d * m.d_ff_expert
                    if m.num_shared_experts:
                        total += nmat * d * (m.d_ff_shared or m.d_ff_expert * m.num_shared_experts)
                        total += d                 # shared-expert sigmoid gate
                else:
                    total += nmat * d * self.d_ff      # gated (SwiGLU/GeGLU) or plain
        return total

    def active_param_count(self) -> int:
        """Params touched per token (MoE: only top-k + shared experts)."""
        if self.moe is None:
            return self.param_count()
        m = self.moe
        total = self.param_count()
        n_moe_layers = sum(1 for i in range(self.num_layers) if self.is_moe_layer(i))
        nmat = 3 if self.mlp_gated else 2
        inactive = n_moe_layers * (m.num_experts - m.top_k) * nmat * self.d_model * m.d_ff_expert
        return total - inactive

    # ------------------------------------------------------------------ reduced
    def reduced(self) -> "ModelConfig":
        """Smoke-test variant: 2 layers, d_model<=512, <=4 experts, small vocab."""
        d = min(self.d_model, 256)
        nh = max(1, min(self.num_heads, 4))
        nkv = max(1, min(self.num_kv_heads, nh))
        while nh % nkv:
            nkv -= 1
        moe = None
        if self.moe is not None:
            moe = dataclasses.replace(
                self.moe,
                num_experts=min(self.moe.num_experts, 4),
                top_k=min(self.moe.top_k, 2),
                d_ff_expert=min(self.moe.d_ff_expert, 128),
                d_ff_shared=min(self.moe.d_ff_shared, 128) if self.moe.d_ff_shared else 0,
            )
        ssm = None
        if self.ssm is not None or self.family in ("ssm", "hybrid"):
            base = self.ssm or SSMConfig()
            ssm = dataclasses.replace(base, d_state=32, head_dim=32, chunk_size=32)
        # keep the layer-pattern periods observable in 2..8 layers
        n_layers = 2
        if self.attn_layer_period > 1 or self.attention_pattern.startswith("local_global"):
            n_layers = 4
        return dataclasses.replace(
            self,
            name=self.name + "-smoke",
            num_layers=n_layers,
            d_model=d,
            num_heads=nh,
            num_kv_heads=nkv,
            head_dim=0,
            d_ff=min(self.d_ff, 512) if self.d_ff else 0,
            vocab_size=min(self.vocab_size, 512),
            sliding_window=64,
            moe=moe,
            ssm=ssm,
            num_image_tokens=min(self.num_image_tokens, 16) if self.num_image_tokens else 0,
            max_position=1 << 14,
            dtype="float32",
            attn_layer_period=min(self.attn_layer_period, 2),
            attn_layer_offset=min(self.attn_layer_offset, 1),
            attention_pattern=(
                "local_global:1"
                if self.attention_pattern.startswith("local_global")
                else self.attention_pattern
            ),
        )


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                        # train | prefill | decode


# ----------------------------------------------------------------------- registry
_REGISTRY: Dict[str, Callable[[], ModelConfig]] = {}


def register_config(name: str):
    def deco(fn: Callable[[], ModelConfig]):
        _REGISTRY[name] = fn
        return fn
    return deco


def get_config(name: str) -> ModelConfig:
    import repro.configs  # noqa: F401  (populate registry)
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]()


def list_configs() -> List[str]:
    import repro.configs  # noqa: F401
    return sorted(_REGISTRY)
