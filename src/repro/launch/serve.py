"""Serving driver: CAS-Spec engine (single stream) or batched server.

  PYTHONPATH=src python -m repro.launch.serve --arch vicuna-7b --reduced \
      --scheduler dytc --tokens 64
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax

from repro.config import get_config
from repro.core.cascade import (
    ARScheduler, HCScheduler, PLDScheduler, SDScheduler, TreeScheduler,
    VCHCScheduler, VCScheduler,
)
from repro.core.dsia import build_hierarchy, layer_sparsity
from repro.core.dytc import DyTCScheduler
from repro.core.engine import SpecEngine
from repro.data import SPEC_TASKS, make_task_prompts
from repro.models import model as M

SCHEDULERS = {
    "ar": lambda e, cfg: ARScheduler(e),
    "pld": lambda e, cfg: PLDScheduler(e, k=8),
    "swift": lambda e, cfg: SDScheduler(e, layer_sparsity(cfg, 0.4), k=4),
    "vc": lambda e, cfg: VCScheduler(e, layer_sparsity(cfg, 0.4)),
    "hc": lambda e, cfg: HCScheduler(e, layer_sparsity(cfg, 0.4)),
    "vchc": lambda e, cfg: VCHCScheduler(e, layer_sparsity(cfg, 0.4)),
    "tree": lambda e, cfg: TreeScheduler(e, layer_sparsity(cfg, 0.4)),
    "dytc": lambda e, cfg: DyTCScheduler(e, build_hierarchy(cfg)),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="vicuna-7b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--scheduler", default="dytc", choices=sorted(SCHEDULERS))
    ap.add_argument("--tokens", type=int, default=64)
    ap.add_argument("--task", default="summarization")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = dataclasses.replace(cfg.reduced(), num_layers=8)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    prompt = make_task_prompts(SPEC_TASKS[args.task], 1, cfg.vocab_size)[0]

    eng = SpecEngine(cfg, params, max_len=1024)
    eng.start(prompt)
    sched = SCHEDULERS[args.scheduler](eng, cfg)
    t0 = time.perf_counter()
    out = sched.generate(args.tokens)
    dt = time.perf_counter() - t0
    s = eng.stats
    print(f"scheduler={args.scheduler} tokens={len(out)} time={dt:.2f}s "
          f"({dt/len(out)*1e3:.1f} ms/tok)")
    print(f"rounds={s['rounds']} target_calls={s['target_calls']} "
          f"mean_accepted={s['accepted_tokens']/max(s['rounds'],1):.2f}")
    print("output:", out[:32], "..." if len(out) > 32 else "")


if __name__ == "__main__":
    main()
