"""Serving driver: CAS-Spec engine (single stream) or batched server.

  PYTHONPATH=src python -m repro.launch.serve --arch vicuna-7b --reduced \
      --scheduler dytc --tokens 64

``--mesh model=K,data=D`` switches to the batched continuous-batching
server (``serving.server.BatchedSpecServer`` + ``ServeLoop``) with the
target tensor-parallel over ``model`` and the batch slots data-parallel
over ``data`` — the single-dispatch round runs unchanged on the mesh (see
docs/sharding.md). Off-accelerator, force host devices first:

  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
  PYTHONPATH=src python -m repro.launch.serve --arch vicuna-7b --reduced \
      --mesh model=2,data=4 --mode chain_fused --batch 4 --tokens 32

Observability (docs/observability.md): ``--metrics-port`` serves live
Prometheus text at ``/metrics`` while the run is in flight,
``--trace-out`` records Chrome-trace spans of the host-loop phases
(open in Perfetto), ``--profile-dir`` wraps the run in
``jax.profiler.trace``, and ``--metrics-jsonl`` appends the end-of-run
registry snapshot as one JSONL record. Regardless of flags, the LAST
stdout line is a single machine-readable JSON summary (``kind:
"serve_summary"``) sourced from the metrics registry.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time

import jax

from repro.config import get_config
from repro.core.cascade import (
    ARScheduler, HCScheduler, PLDScheduler, SDScheduler, TreeScheduler,
    VCHCScheduler, VCScheduler,
)
from repro.core.dsia import build_hierarchy, layer_sparsity
from repro.core.dytc import DyTCScheduler
from repro.core.engine import SpecEngine
from repro.data import SPEC_TASKS, make_task_prompts
from repro.models import model as M
from repro.serving.exporters import JsonlSink, MetricsHTTPServer
from repro.serving.telemetry import TraceRecorder, profiler_trace

SCHEDULERS = {
    "ar": lambda e, cfg: ARScheduler(e),
    "pld": lambda e, cfg: PLDScheduler(e, k=8),
    "swift": lambda e, cfg: SDScheduler(e, layer_sparsity(cfg, 0.4), k=4),
    "vc": lambda e, cfg: VCScheduler(e, layer_sparsity(cfg, 0.4)),
    "hc": lambda e, cfg: HCScheduler(e, layer_sparsity(cfg, 0.4)),
    "vchc": lambda e, cfg: VCHCScheduler(e, layer_sparsity(cfg, 0.4)),
    "tree": lambda e, cfg: TreeScheduler(e, layer_sparsity(cfg, 0.4)),
    "dytc": lambda e, cfg: DyTCScheduler(e, build_hierarchy(cfg)),
}


def _emit_summary(summary: dict, args) -> None:
    """The one machine-readable final line (+ optional JSONL record)."""
    if args.metrics_jsonl:
        with JsonlSink(args.metrics_jsonl) as sink:
            sink.write(summary)
    print(json.dumps(summary, sort_keys=True))


def run_batched(cfg, params, args) -> None:
    """``--mesh`` path: mesh-sharded batched serving rounds."""
    from repro.launch.mesh import mesh_from_spec, set_global_mesh
    from repro.serving.scheduler import Request, RequestScheduler, ServeLoop
    from repro.serving.server import BatchedSpecServer

    # this process owns serving end to end, so the global mesh is safe here
    # (and activates the engine-internal batch pins); libraries embedding
    # the server pass ``mesh=`` only — see the server docstring
    mesh = set_global_mesh(mesh_from_spec(args.mesh))
    print(f"mesh: {dict(mesh.shape)} over {len(mesh.devices.flat)} devices")
    srv_kw: dict = {}
    if args.mode != "cascade_fused":
        srv_kw["draft_spec"] = layer_sparsity(cfg, 0.4)
    if args.temperature > 0.0:
        from repro.serving.sampler import SamplingParams

        srv_kw["sampling"] = SamplingParams(
            temperature=args.temperature, top_k=args.top_k,
            top_p=args.top_p, seed=args.seed,
        )
    if args.paged or args.prefill_chunk:
        # block-paged KV cache (+ optional in-round chunked prefill) —
        # token-identical to the dense path; see docs/paging.md
        srv_kw.update(paged=True, page_size=args.page_size)
        if args.prefill_chunk:
            srv_kw["prefill_chunk"] = args.prefill_chunk
    srv = BatchedSpecServer(
        cfg, params, max_batch=args.batch, max_len=1024,
        mode=args.mode, mesh=mesh, **srv_kw,
    )
    endpoint = (MetricsHTTPServer(srv.metrics, port=args.metrics_port)
                if args.metrics_port is not None else None)
    if endpoint is not None:
        print(f"metrics: {endpoint.url}")
    trace = TraceRecorder() if args.trace_out else None
    sched = RequestScheduler(args.batch)
    for p in make_task_prompts(SPEC_TASKS[args.task], args.batch, cfg.vocab_size):
        sched.submit(Request(prompt=p, max_new_tokens=args.tokens))
    loop = ServeLoop(srv, sched, trace=trace)
    t0 = time.perf_counter()
    with profiler_trace(args.profile_dir):
        while sched.busy:
            loop.step_once()
        srv.flush()
    dt = time.perf_counter() - t0
    tok = sum(len(r.generated) for r in sched.finished)
    print(f"mode={args.mode} mesh={args.mesh} requests={len(sched.finished)} "
          f"tokens={tok} time={dt:.2f}s ({dt/max(tok,1)*1e3:.1f} ms/tok)")
    if trace is not None:
        trace.save(args.trace_out)
        print(f"trace: {args.trace_out} (open in https://ui.perfetto.dev)")
    if endpoint is not None:
        endpoint.close()
    summary = {
        "kind": "serve_summary",
        "mesh": args.mesh,
        "requests": len(sched.finished),
        "delivered_tokens": tok,
        "wall_s": dt,
        **srv.metrics_summary(),
    }
    _emit_summary(summary, args)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="vicuna-7b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--scheduler", default="dytc", choices=sorted(SCHEDULERS))
    ap.add_argument("--tokens", type=int, default=64)
    ap.add_argument("--task", default="summarization")
    ap.add_argument("--mesh", default=None,
                    help="'model=K,data=D' -> mesh-sharded batched server")
    ap.add_argument("--mode", default="chain_fused",
                    choices=["chain_fused", "legacy", "tree_fused",
                             "cascade_fused"],
                    help="batched server mode (with --mesh)")
    ap.add_argument("--batch", type=int, default=4,
                    help="batch slots (with --mesh)")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="sampling temperature (batched path; 0 = greedy, "
                         "the default — lossless stochastic verify when >0)")
    ap.add_argument("--top-k", type=int, default=0,
                    help="top-k filter for sampled serving (0 = off)")
    ap.add_argument("--top-p", type=float, default=1.0,
                    help="nucleus mass for sampled serving (1.0 = off)")
    ap.add_argument("--seed", type=int, default=None,
                    help="base PRNG seed for sampled serving (per-request "
                         "streams derive from it and the admission order)")
    ap.add_argument("--paged", action="store_true",
                    help="block-paged KV cache (batched path; lossless — "
                         "see docs/paging.md)")
    ap.add_argument("--page-size", type=int, default=64,
                    help="tokens per KV page (with --paged)")
    ap.add_argument("--prefill-chunk", type=int, default=0,
                    help=">0: non-blocking admission — prompts prefill "
                         "inside the fused rounds, this many tokens per "
                         "round (implies --paged; single-round modes only)")
    ap.add_argument("--metrics-port", type=int, default=None,
                    help="serve Prometheus /metrics on this port (0 = "
                         "ephemeral; batched path)")
    ap.add_argument("--trace-out", default=None,
                    help="write Chrome trace-event JSON of the host-loop "
                         "phases here (batched path)")
    ap.add_argument("--profile-dir", default=None,
                    help="wrap the run in jax.profiler.trace(log_dir)")
    ap.add_argument("--metrics-jsonl", default=None,
                    help="append the final summary record to this JSONL file")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = dataclasses.replace(cfg.reduced(), num_layers=8)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    if args.mesh:
        run_batched(cfg, params, args)
        return
    prompt = make_task_prompts(SPEC_TASKS[args.task], 1, cfg.vocab_size)[0]

    eng = SpecEngine(cfg, params, max_len=1024)
    eng.start(prompt)
    sched = SCHEDULERS[args.scheduler](eng, cfg)
    t0 = time.perf_counter()
    with profiler_trace(args.profile_dir):
        out = sched.generate(args.tokens)
    dt = time.perf_counter() - t0
    s = eng.stats
    print(f"scheduler={args.scheduler} tokens={len(out)} time={dt:.2f}s "
          f"({dt/len(out)*1e3:.1f} ms/tok)")
    print("output:", out[:32], "..." if len(out) > 32 else "")
    summary = {
        "kind": "serve_summary",
        "scheduler": args.scheduler,
        "delivered_tokens": len(out),
        "wall_s": dt,
        "rounds": s["rounds"],
        "target_calls": s["target_calls"],
        "mean_accepted": s["accepted_tokens"] / max(s["rounds"], 1),
    }
    _emit_summary(summary, args)


if __name__ == "__main__":
    main()
