"""Sharding rules: PartitionSpec trees for params / optimizer / cache / batch.

MaxText-style logical rules, resolved per architecture:
  - embeddings / lm_head:   vocab -> model
  - attention q/k/v/o:      heads -> model when divisible, else head_dim,
                            else replicated (tiny-head archs like gemma3 MQA)
  - dense MLP:              d_ff -> model
  - MoE experts:            expert d_ff -> model (expert count 8/60/16 is not
                            always divisible by 16; d_ff always is)
  - Mamba:                  in_proj d (contraction) -> model (psum once),
                            out_proj d_model (output) -> model
  - activations:            batch -> (pod, data); long-context batch=1 decode
                            shards the KV-cache/scan sequence dim -> data
                            (context parallelism)
  - optimizer moments:      same spec as the param (ZeRO-style along model)
"""
from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.config.base import BlockKind, ModelConfig
from repro.models import model as M


def _axis_size(mesh, name: str) -> int:
    return mesh.shape[name] if name in mesh.axis_names else 1


def attention_policy(cfg: ModelConfig, model_size: int) -> str:
    """Head-sharding policy ladder (see models.attention sharding note):

      kv   — KV heads divide the model axis: shard K/V/cache + Q on heads
      q    — only Q heads divide: shard Q heads, REPLICATE K/V over model
             (GQA K/V weights and cache are small; scores expand to H)
      none — neither divides (tiny-head archs: gemma3 H=4, musicgen H=24,
             starcoder2 H=24): attention replicated over model, the model
             axis works only in the MLP. NEVER shard head_dim — it is the
             score contraction and costs an all-reduce per KV chunk.
    """
    if cfg.num_kv_heads and cfg.num_kv_heads % model_size == 0:
        return "kv"
    if cfg.num_heads and cfg.num_heads % model_size == 0:
        return "q"
    return "none"


def attn_param_specs(cfg: ModelConfig, mesh) -> dict:
    n = _axis_size(mesh, "model")
    pol = attention_policy(cfg, n)
    qh = "model" if pol in ("kv", "q") else None
    kh = "model" if pol == "kv" else None
    return {
        "wq": P(None, qh, None),
        "wk": P(None, kh, None),
        "wv": P(None, kh, None),
        "wo": P(qh, None, None),
    }


def mamba_policy(cfg: ModelConfig, model_size: int) -> bool:
    """Shard d_inner (z/x/conv/heads) iff nh divides the model axis."""
    s = cfg.ssm
    return s is not None and s.num_heads(cfg.d_model) % model_size == 0


def mamba_param_specs(cfg: ModelConfig, mesh) -> dict:
    n = _axis_size(mesh, "model")
    din_ax = "model" if mamba_policy(cfg, n) else None
    return {
        "w_z": P(None, din_ax),
        "w_x": P(None, din_ax),
        "w_B": P(),
        "w_C": P(),
        "w_dt": P(),
        "conv_x": P(None, din_ax),
        "conv_B": P(),
        "conv_C": P(),
        "A_log": P(),
        "D": P(),
        "dt_bias": P(),
        "norm_w": P(din_ax),
        "out_proj": P(din_ax, None),     # contract sharded d_inner: one psum
    }


def layer_param_specs(cfg: ModelConfig, spec: M.LayerSpec, mesh) -> dict:
    out: dict = {"norm1": P()}
    if spec.block is BlockKind.ATTENTION:
        out["attn"] = attn_param_specs(cfg, mesh)
    else:
        out["mamba"] = mamba_param_specs(cfg, mesh)
    if spec.has_mlp:
        out["norm2"] = P()
        if spec.is_moe:
            moe = {
                "w_router": P(),
                "w_up": P(None, None, "model"),
                "w_down": P(None, "model", None),
            }
            if cfg.mlp_gated:
                moe["w_gate"] = P(None, None, "model")
            if cfg.moe.num_shared_experts:
                sh = {"w_up": P(None, "model"), "w_down": P("model", None)}
                if cfg.mlp_gated:
                    sh["w_gate"] = P(None, "model")
                moe["shared"] = sh
                moe["w_shared_gate"] = P()
            out["moe"] = moe
        else:
            mlp = {"w_up": P(None, "model"), "w_down": P("model", None)}
            if cfg.mlp_gated:
                mlp["w_gate"] = P(None, "model")
            out["mlp"] = mlp
    return out


def param_specs(cfg: ModelConfig, mesh) -> dict:
    """PartitionSpec pytree congruent with models.init_params(cfg)."""
    # stacked segment leaves carry a leading repeats dim -> prepend None
    def stack(spec_tree):
        return jax.tree.map(
            lambda p: P(*((None,) + tuple(p))), spec_tree,
            is_leaf=lambda x: isinstance(x, P),
        )

    segs = []
    for seg in M.layout(cfg):
        segs.append(stack([layer_param_specs(cfg, s, mesh) for s in seg.unit]))
    out = {
        "embed": P(None, "model", None) if cfg.num_codebooks else P("model", None),
        "final_norm": P(),
        "segments": segs,
    }
    if not cfg.tie_embeddings:
        out["lm_head"] = P(None, None, "model") if cfg.num_codebooks else P(None, "model")
    return out


def cache_seq_axes(cfg: ModelConfig, mesh, *, shard_seq: bool = False):
    """Mesh axes carrying the cache sequence dim (context parallelism).

    Policy kv keeps seq local (KV heads carry 'model'); policies q/none put
    'model' on seq — the flash-decoding split-KV partials in
    attention.decode_attention make the combine the only communication.
    Long-context batch=1 (shard_seq) adds the data axes.
    """
    n = _axis_size(mesh, "model")
    pol = attention_policy(cfg, n)
    axes = ()
    if shard_seq:
        axes += _dp_axes(mesh)
    if pol != "kv":
        axes += ("model",)
    return axes or None


def seq_shard_count(cfg: ModelConfig, mesh, *, shard_seq: bool = False) -> int:
    axes = cache_seq_axes(cfg, mesh, shard_seq=shard_seq)
    if not axes:
        return 0
    total = 1
    for a in axes:
        total *= mesh.shape[a]
    return total


def cache_specs(
    cfg: ModelConfig, mesh, *, shard_seq: bool = False,
    ring_window: bool = False, global_batch: int | None = None,
    paged: bool = False,
) -> dict:
    """Cache pytree specs. shard_seq=True -> context parallelism for batch=1
    long-context decode. global_batch (when given) gates the batch axis on
    even divisibility — serving caches with B below the data-way count stay
    replicated on batch instead of carrying a non-dividing spec.

    ``paged=True`` matches ``models.model.init_cache(paged=True)``: the
    attention units hold one SHARED page pool ``(repeats, num_pages,
    page_size, KV, hd)`` — no per-slot batch dim, so the pool shards only
    on its KV-head dim (tensor parallel) and replicates across the data
    axes; the per-slot ``page_table`` is leading-batch like the round
    state. See docs/paging.md."""
    n = _axis_size(mesh, "model")
    pol = attention_policy(cfg, n)
    kh = "model" if pol == "kv" else None
    batch_ax = None if shard_seq else (
        _dp(mesh) if global_batch is None else batch_axis(mesh, global_batch)
    )
    seq_ax = cache_seq_axes(cfg, mesh, shard_seq=shard_seq)
    segs = []
    from repro.config.base import AttentionKind

    for seg in M.layout(cfg):
        unit = []
        for spec in seg.unit:
            if spec.block is BlockKind.ATTENTION:
                if paged:
                    unit.append(
                        {
                            "k_pages": P(None, None, None, kh, None),
                            "v_pages": P(None, None, None, kh, None),
                        }
                    )
                    continue
                ring = ring_window and spec.attn is AttentionKind.SLIDING
                unit.append(
                    {
                        "k": P(None, batch_ax, None if ring else seq_ax, kh, None),
                        "v": P(None, batch_ax, None if ring else seq_ax, kh, None),
                    }
                )
            else:
                din_ax = "model" if mamba_policy(cfg, n) else None
                unit.append(
                    {
                        "ssm": P(None, batch_ax, din_ax, None, None),
                        "conv_x": P(None, batch_ax, None, din_ax),
                        "conv_B": P(None, batch_ax, None, None),
                        "conv_C": P(None, batch_ax, None, None),
                    }
                )
        segs.append(unit)
    out = {"pos": P(batch_ax), "segments": segs}
    if paged:
        out["page_table"] = P(batch_ax, None)
    return out


def _dp_axes(mesh) -> tuple:
    """The batch-parallel mesh axes, always as a tuple (callers used to
    normalize ``_dp``'s tuple-vs-str return inline at every site)."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def _dp(mesh):
    """Batch-parallel axes as a PartitionSpec entry: the compound tuple on
    pod meshes, the bare axis name otherwise. Prefer ``_dp_axes`` when
    iterating; this form only exists for spec-entry ergonomics."""
    axes = _dp_axes(mesh)
    return axes if len(axes) > 1 else axes[0]


def dp_size(mesh) -> int:
    """Total batch-parallel way count of ``mesh``."""
    return int(np.prod([mesh.shape[a] for a in _dp_axes(mesh)]))


def batch_axis(mesh, global_batch: int):
    """The data-parallel batch axis as a spec entry, or None when
    ``global_batch`` cannot shard evenly over it (GSPMD would silently
    no-op a non-dividing constraint anyway; placement must agree)."""
    d = dp_size(mesh)
    ok = global_batch % d == 0 and global_batch >= d
    return _dp(mesh) if ok else None


def batch_specs(cfg: ModelConfig, mesh, *, global_batch: int) -> dict:
    bax = batch_axis(mesh, global_batch)
    out = {"tokens": P(bax, None, None) if cfg.num_codebooks else P(bax, None)}
    if cfg.num_image_tokens:
        out["image_embeds"] = P(bax, None, None)
        out["image_mask"] = P(bax, None)
    return out


def round_state_specs(
    mesh, *, global_batch: int, sampled: bool = False, prefill: bool = False,
) -> dict:
    """Specs for the batched server's carried round state (congruent with
    ``BatchedSpecServer.dstate``): every array is per-slot, so everything
    shards on its leading batch dim along the data axes — the serving
    analogue of ``batch_specs`` (tensor parallelism lives in the params;
    the per-slot EMAs/budgets/ctx are pure data parallelism). ``sampled``
    adds the per-slot sampling state a sampled build carries: the warp
    params and the (B, 2) threefry key, all leading-batch like the rest;
    ``prefill`` adds the chunked-prefill progress counters a
    ``prefill_chunk`` build carries (docs/paging.md)."""
    bax = batch_axis(mesh, global_batch)
    out = {
        "pending": P(bax), "live": P(bax), "ctx": P(bax, None),
        "alpha": P(bax), "hist": P(bax, None),
        "hist_n": P(bax), "hist_ptr": P(bax),
    }
    if sampled:
        out.update({
            "temp": P(bax), "topk": P(bax), "topp": P(bax),
            "key": P(bax, None),
        })
    if prefill:
        out.update({"pf_done": P(bax), "pf_len": P(bax)})
    return out


def telemetry_specs(schema: dict, mesh, *, global_batch: int) -> dict:
    """Specs for the device telemetry buffer (serving.telemetry
    .telemetry_schema): per-slot tallies shard on their leading batch dim
    like the round state; the per-(level, slot) cascade rows carry batch
    on their SECOND dim (the level dim is tiny and never sharded)."""
    bax = batch_axis(mesh, global_batch)
    out = {}
    for k, (shape, _) in schema.items():
        if k.startswith("casc_"):
            out[k] = P(None, bax)
        else:
            out[k] = P(*((bax,) + (None,) * (len(shape) - 1)))
    return out


def staged_specs(cfg: ModelConfig, mesh, *, shard_seq: bool = False) -> list:
    """Specs for decode_step staged outputs (same layout as cache but with
    the T dim unsharded; mamba staged states carry an extra per-step dim)."""
    n = _axis_size(mesh, "model")
    pol = attention_policy(cfg, n)
    kh = "model" if pol == "kv" else None
    batch_ax = None if shard_seq else _dp(mesh)
    segs = []
    for seg in M.layout(cfg):
        unit = []
        for spec in seg.unit:
            if spec.block is BlockKind.ATTENTION:
                unit.append(
                    {
                        "k": P(None, batch_ax, None, kh, None),
                        "v": P(None, batch_ax, None, kh, None),
                    }
                )
            else:
                din_ax = "model" if mamba_policy(cfg, n) else None
                unit.append(
                    {
                        "ssm": P(None, batch_ax, None, din_ax, None, None),
                        "conv_x": P(None, batch_ax, None, None, din_ax),
                        "conv_B": P(None, batch_ax, None, None, None),
                        "conv_C": P(None, batch_ax, None, None, None),
                    }
                )
        segs.append(unit)
    return segs


def opt_specs(pspecs: Any) -> Any:
    """AdamW moments shard like their params."""
    from repro.training.optimizer import AdamWState

    return AdamWState(step=P(), mu=pspecs, nu=pspecs)


def fsdp_upgrade(pspecs: Any, pshapes: Any, mesh, *, min_dim: int = 512) -> Any:
    """Additionally shard layer-stack weights over 'data' on their first
    free dim.

    FSDP-style 2D weight sharding: required for training (4x f32 moments)
    and for inference of models whose TP-only shard exceeds HBM (mixtral).
    Only ``segments`` weights are upgraded: embed/lm_head stay vocab-sharded
    — 2D-sharding them puts 'data' on the unembed contraction dim, which
    makes GSPMD all-gather the (batch-sharded) activations instead of the
    small weight shard (measured: +45 GiB/device temp on stablelm train).
    The repeats dim of stacked segments is never sharded (it is scanned);
    dims smaller than ``min_dim`` are skipped, which excludes it naturally.
    """
    data = _axis_size(mesh, "data")

    def upgrade(spec: P, shape) -> P:
        dims = list(spec) + [None] * (len(shape.shape) - len(spec))
        for i, (ax, n) in enumerate(zip(dims, shape.shape)):
            if ax is None and n >= min_dim and n % data == 0:
                dims[i] = "data"
                break
        return P(*dims)

    out = dict(pspecs)
    out["segments"] = jax.tree.map(
        upgrade, pspecs["segments"], pshapes["segments"],
        is_leaf=lambda x: isinstance(x, P),
    )
    return out
