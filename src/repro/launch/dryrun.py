"""Multi-pod dry-run: lower + compile every (arch x shape) on the production
mesh, print memory/cost analysis, dump roofline JSON.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch mixtral-8x22b --shape decode_32k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] --out results/
"""
# The dry-run (and ONLY the dry-run) needs 512 placeholder devices — set
# BEFORE any other import; jax locks the device count on first init.
import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

import argparse
import functools
import json
import sys
import time
import traceback
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.analysis.roofline import analyze_compiled
from repro.config import INPUT_SHAPES, get_config, get_shape
from repro.configs import ASSIGNED_ARCHS
from repro.launch import sharding as SH
from repro.launch.mesh import make_production_mesh, set_global_mesh
from repro.models import model as M
from repro.training.optimizer import adamw_init
from repro.training.train_step import make_train_step

DRAFT_T = 8          # tree bucket lowered for serve_step (the paper's verify)


# ------------------------------------------------------------- input specs
def input_specs(cfg, shape, kind: str) -> dict:
    """ShapeDtypeStruct stand-ins for every model input — weak-type correct,
    shardable, no device allocation."""
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    tok_shape = (B, S, cfg.num_codebooks) if cfg.num_codebooks else (B, S)
    if kind == "decode":
        T = DRAFT_T
        tok_shape = (B, T, cfg.num_codebooks) if cfg.num_codebooks else (B, T)
    out = {"tokens": jax.ShapeDtypeStruct(tok_shape, i32)}
    if cfg.num_image_tokens and kind in ("train", "prefill"):
        Ti = min(cfg.num_image_tokens, S)
        out["image_embeds"] = jax.ShapeDtypeStruct(
            (B, Ti, cfg.d_model), jnp.dtype(cfg.dtype)
        )
        out["image_mask"] = jax.ShapeDtypeStruct((B, S), i32)
    return out


def _shardings(mesh, tree):
    return jax.tree.map(
        lambda p: NamedSharding(mesh, p), tree, is_leaf=lambda x: isinstance(x, P)
    )


def _local_bytes(shape_tree, spec_tree, mesh) -> float:
    """Per-device bytes of a sharded pytree (leaf bytes / sharded mesh axes)."""
    total = 0.0

    def add(shape, spec):
        nonlocal total
        n = float(np.prod(shape.shape)) * shape.dtype.itemsize
        div = 1
        for ax in spec:
            if ax is None:
                continue
            for a in (ax,) if isinstance(ax, str) else ax:
                div *= mesh.shape[a]
        total += n / div

    jax.tree.map(
        lambda sp, sh: add(sh, sp), spec_tree, shape_tree,
        is_leaf=lambda x: isinstance(x, P),
    )
    return total


def _analytic_traffic(kind: str, params_local: float, cache_local: float,
                      act_local: float) -> float:
    """Minimum HBM traffic per device per step (the roofline memory term).

    decode : weights read once + cache read once (writes are T/S, negligible)
    prefill: weights read once + cache written once + activation stream
    train  : weights read 2x (fwd + remat recompute), grads written once,
             f32 moments read+written (16B per 2B bf16 param -> 8x),
             activation stream 3x (fwd, recompute, bwd)
    """
    if kind == "decode":
        return params_local + cache_local + act_local
    if kind == "prefill":
        return params_local + cache_local + act_local
    return params_local * (2 + 1 + 8) + act_local * 3


def params_shapes(cfg):
    return jax.eval_shape(functools.partial(M.init_params, cfg), jax.random.key(0))


# ----------------------------------------------------------------- builders
def _inference_fsdp(cfg) -> bool:
    """TP-only weight shard too big for one chip's HBM -> 2D-shard weights."""
    return cfg.param_count() * 2 / 16 > 10e9


def build_train(cfg, shape, mesh):
    pshape = params_shapes(cfg)
    # training always FSDP-shards weights+moments (4x f32 moments)
    pspec = SH.fsdp_upgrade(SH.param_specs(cfg, mesh), pshape, mesh)
    ospec = SH.opt_specs(pspec)
    bspec = SH.batch_specs(cfg, mesh, global_batch=shape.global_batch)
    oshape = jax.eval_shape(adamw_init, pshape)
    batch = input_specs(cfg, shape, "train")
    step = make_train_step(cfg, remat=True)
    in_sh = (_shardings(mesh, pspec), _shardings(mesh, ospec),
             {k: _shardings(mesh, bspec[k]) for k in batch})
    out_sh = (in_sh[0], in_sh[1],
              jax.tree.map(lambda _: NamedSharding(mesh, P()),
                           {"ce": 0, "moe_aux": 0, "loss": 0, "lr": 0, "grad_norm": 0}))
    fn = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh,
                 donate_argnums=(0, 1))
    dp_total = int(np.prod([mesh.shape[a] for a in mesh.axis_names if a != "model"]))
    params_local = _local_bytes(pshape, pspec, mesh)
    act_local = (cfg.num_layers * shape.global_batch * shape.seq_len
                 * cfg.d_model * 2 * 6) / dp_total
    traffic = _analytic_traffic("train", params_local, 0.0, act_local)
    return fn, (pshape, oshape, batch), traffic


def build_prefill(cfg, shape, mesh):
    pshape = params_shapes(cfg)
    pspec = SH.param_specs(cfg, mesh)
    if _inference_fsdp(cfg):
        pspec = SH.fsdp_upgrade(pspec, pshape, mesh)
    cspec = SH.cache_specs(cfg, mesh)
    bspec = SH.batch_specs(cfg, mesh, global_batch=shape.global_batch)
    cshape = jax.eval_shape(
        functools.partial(
            M.init_cache, cfg, shape.global_batch, shape.seq_len,
            dtype=jnp.dtype(cfg.dtype),
        )
    )
    batch = input_specs(cfg, shape, "prefill")
    dp = SH._dp(mesh)
    logits_spec = (
        P(dp, None, "model") if cfg.num_codebooks else P(dp, "model")
    )

    def fn(params, batch_, cache):
        return M.prefill(cfg, params, batch_, cache)

    in_sh = (_shardings(mesh, pspec),
             {k: _shardings(mesh, bspec[k]) for k in batch},
             _shardings(mesh, cspec))
    out_sh = (NamedSharding(mesh, logits_spec), _shardings(mesh, cspec))
    jfn = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                  donate_argnums=(2,))
    dp_total = int(np.prod([mesh.shape[a] for a in mesh.axis_names if a != "model"]))
    params_local = _local_bytes(pshape, pspec, mesh)
    cache_local = _local_bytes(cshape, cspec, mesh)
    act_local = (cfg.num_layers * shape.global_batch * shape.seq_len
                 * cfg.d_model * 2 * 4) / dp_total
    traffic = _analytic_traffic("prefill", params_local, cache_local, act_local)
    return jfn, (pshape, batch, cshape), traffic


def build_serve(cfg, shape, mesh):
    """CAS-Spec verify step: tree-decode DRAFT_T staged tokens + commit the
    accepted path — the paper's technique as the lowered decode step."""
    long_ctx = shape.seq_len > 100_000
    shard_seq = long_ctx and shape.global_batch == 1
    pshape = params_shapes(cfg)
    pspec = SH.param_specs(cfg, mesh)
    if _inference_fsdp(cfg):
        pspec = SH.fsdp_upgrade(pspec, pshape, mesh)
    cspec = SH.cache_specs(cfg, mesh, shard_seq=shard_seq, ring_window=long_ctx)
    stspec = SH.staged_specs(cfg, mesh, shard_seq=shard_seq)
    cshape = jax.eval_shape(
        functools.partial(
            M.init_cache, cfg, shape.global_batch, shape.seq_len,
            ring_window=long_ctx, dtype=jnp.dtype(cfg.dtype),
        )
    )
    B = shape.global_batch
    T = DRAFT_T
    toks = input_specs(cfg, shape, "decode")["tokens"]
    tmask = jax.ShapeDtypeStruct((T, T), jnp.bool_)
    path = jax.ShapeDtypeStruct((B, T), jnp.int32)
    nacc = jax.ShapeDtypeStruct((B,), jnp.int32)
    dp = SH._dp(mesh)
    bax = dp if B >= 16 else None

    # context-parallel cache partials: axes carrying the cache seq dim
    # (see sharding.cache_seq_axes + attention.decode_attention)
    seq_axes = SH.cache_seq_axes(cfg, mesh, shard_seq=shard_seq)

    def serve_step(params, cache, tokens, tree_mask, path_idx, n_acc):
        logits, staged = M.decode_step(
            cfg, params, cache, tokens, tree_mask=tree_mask, seq_axes=seq_axes
        )
        new_cache = M.commit_cache(cfg, cache, staged, path_idx, n_acc)
        return jnp.argmax(logits, axis=-1), new_cache

    in_sh = (
        _shardings(mesh, pspec),
        _shardings(mesh, cspec),
        NamedSharding(mesh, P(bax, None, None) if cfg.num_codebooks else P(bax, None)),
        NamedSharding(mesh, P()),
        NamedSharding(mesh, P(bax, None)),
        NamedSharding(mesh, P(bax)),
    )
    out_sh = (
        NamedSharding(mesh, P(bax, None, None) if cfg.num_codebooks else P(bax, None)),
        _shardings(mesh, cspec),
    )
    jfn = jax.jit(serve_step, in_shardings=in_sh, out_shardings=out_sh,
                  donate_argnums=(1,))
    params_local = _local_bytes(pshape, pspec, mesh)
    cache_local = _local_bytes(cshape, cspec, mesh)
    traffic = _analytic_traffic("decode", params_local, cache_local, 0.0)
    return jfn, (pshape, cshape, toks, tmask, path, nacc), traffic


BUILDERS = {"train": build_train, "prefill": build_prefill, "decode": build_serve}


def applicable(cfg, shape) -> bool:
    if shape.seq_len > 100_000:
        return cfg.supports_long_context
    return True


# ----------------------------------------------------------------- runner
def run_one(arch: str, shape_name: str, *, multi_pod: bool, out_dir: Optional[str] = None,
            verbose: bool = True) -> dict:
    import dataclasses

    cfg = get_config(arch)
    if cfg.moe is not None:
        # TPU execution knobs: sharded expert-group dispatch (see models.moe)
        cfg = dataclasses.replace(
            cfg,
            moe=dataclasses.replace(
                cfg.moe, exec_groups=32, prefill_dropless=False
            ),
        )
    shape = get_shape(shape_name)
    if not applicable(cfg, shape):
        return {"arch": arch, "shape": shape_name, "status": "skipped",
                "reason": "full-attention arch at 500k (see DESIGN.md)"}
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.perf_counter()
    # set_mesh (not `with mesh:`) so with_sharding_constraint sees the
    # abstract mesh during tracing (models.shard_utils.constrain).
    set_global_mesh(mesh)
    fn, args, traffic = BUILDERS[shape.kind](cfg, shape, mesh)
    lowered = fn.lower(*args)
    compiled = lowered.compile()
    dt = time.perf_counter() - t0
    name = f"{arch}/{shape_name}/{'2pod' if multi_pod else '1pod'}"
    rep = analyze_compiled(name, compiled, analytic_bytes=traffic)
    mem = compiled.memory_analysis()
    result = {
        "arch": arch, "shape": shape_name, "status": "ok",
        "mesh": "2x16x16" if multi_pod else "16x16",
        "kind": shape.kind,
        "compile_s": round(dt, 1),
        "memory_analysis": {
            "argument_bytes": int(mem.argument_size_in_bytes),
            "output_bytes": int(mem.output_size_in_bytes),
            "temp_bytes": int(mem.temp_size_in_bytes),
            "alias_bytes": int(mem.alias_size_in_bytes),
        },
        "roofline": rep.to_dict(),
    }
    if verbose:
        ma = result["memory_analysis"]
        print(f"== {name} kind={shape.kind} compile={dt:.1f}s")
        print(f"   memory/device: args={ma['argument_bytes']/2**30:.2f}GiB "
              f"temp={ma['temp_bytes']/2**30:.2f}GiB aliased={ma['alias_bytes']/2**30:.2f}GiB")
        print(f"   flops/device={rep.flops:.3e} bytes/device={rep.bytes_hbm:.3e} "
              f"coll={rep.coll_total:.3e}")
        print(f"   t_comp={rep.t_compute*1e3:.3f}ms t_mem={rep.t_memory*1e3:.3f}ms "
              f"t_coll={rep.t_collective*1e3:.3f}ms -> {rep.bottleneck}-bound")
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        fname = f"{arch}__{shape_name}__{'2pod' if multi_pod else '1pod'}.json"
        with open(os.path.join(out_dir, fname), "w") as f:
            json.dump(result, f, indent=1)
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    args = ap.parse_args()

    pairs = []
    if args.all:
        for a in ASSIGNED_ARCHS:
            for s in INPUT_SHAPES:
                pairs.append((a, s))
    else:
        pairs = [(args.arch, args.shape)]

    failures = 0
    for arch, shp in pairs:
        try:
            r = run_one(arch, shp, multi_pod=args.multi_pod, out_dir=args.out)
            if r["status"] == "skipped":
                print(f"== {arch}/{shp}: SKIP ({r['reason']})")
        except Exception as e:
            failures += 1
            print(f"== {arch}/{shp}: FAILED: {e}")
            traceback.print_exc()
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
