"""Distributed training driver (CPU-runnable at reduced scale; the
production mesh path is exercised by the dry-run).

  PYTHONPATH=src python -m repro.launch.train --arch stablelm-1.6b --steps 20 \
      --reduced --batch 8 --seq 128
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.config import get_config
from repro.data import lm_batches, synthetic_corpus
from repro.models import model as M
from repro.training import adamw_init, make_train_step, save_checkpoint


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="vicuna-7b")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--lr", type=float, default=6e-4)
    ap.add_argument("--ckpt", default=None)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    opt = adamw_init(params)
    step = jax.jit(make_train_step(cfg, peak_lr=args.lr, warmup=10,
                                   total_steps=args.steps, remat=not args.reduced))
    corpus = synthetic_corpus(cfg.vocab_size, 100_000)
    it = lm_batches(corpus, args.batch, args.seq)
    t0 = time.perf_counter()
    for i in range(args.steps):
        b = {k: jnp.asarray(v) for k, v in next(it).items()}
        params, opt, m = step(params, opt, b)
        if i % max(args.steps // 10, 1) == 0:
            print(f"step {i:4d} ce={float(m['ce']):.4f} "
                  f"lr={float(m['lr']):.2e} gnorm={float(m['grad_norm']):.2f}")
    jax.block_until_ready(params)   # steps dispatch async; settle before timing
    print(f"{args.steps} steps in {time.perf_counter()-t0:.1f}s")
    if args.ckpt:
        save_checkpoint(args.ckpt, params, opt, step=args.steps)
        print("saved", args.ckpt)


if __name__ == "__main__":
    main()
