"""Production mesh construction.

A function (not a module constant) so importing this module never touches
jax device state. The dry-run sets XLA_FLAGS host-device-count=512 BEFORE
any jax import; tests and benches see the real single CPU device.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
    )


def data_axes(mesh) -> tuple:
    """The (possibly compound) batch-parallel axes of a mesh."""
    names = mesh.axis_names
    return ("pod", "data") if "pod" in names else ("data",)


def make_host_mesh(model: int = 1, data: int = 1):
    """Tiny mesh over real local devices (CPU tests)."""
    return jax.make_mesh(
        (data, model), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2,
    )
