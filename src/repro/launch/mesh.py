"""Production mesh construction.

A function (not a module constant) so importing this module never touches
jax device state. The dry-run sets XLA_FLAGS host-device-count=512 BEFORE
any jax import; tests and benches see the real single CPU device.

JAX-version compat: ``jax.sharding.AxisType`` (and the ``axis_types``
kwarg of ``jax.make_mesh``) only exist on newer JAX; older releases also
lack ``jax.sharding.set_mesh``. Both are guarded here so the same code
runs on either — on old JAX the mesh is built without explicit axis types
(Auto is the default there anyway) and the global-mesh setter degrades to
a no-op (sharding constraints then no-op too, see
``models.shard_utils._mesh_axes``; explicit in_shardings still apply).
"""
from __future__ import annotations

import jax


def _axis_types_kwargs(n_axes: int) -> dict:
    """``axis_types=(Auto,)*n`` where supported, ``{}`` on older JAX."""
    if hasattr(jax.sharding, "AxisType"):
        return {"axis_types": (jax.sharding.AxisType.Auto,) * n_axes}
    return {}


def make_mesh_compat(shape: tuple, axes: tuple):
    """``jax.make_mesh`` with Auto axis types where the kwarg exists."""
    return jax.make_mesh(shape, axes, **_axis_types_kwargs(len(axes)))


def set_global_mesh(mesh):
    """``jax.sharding.set_mesh`` where it exists (needed so trace-time
    ``with_sharding_constraint`` sees the abstract mesh); on older JAX the
    mesh is registered as ``models.shard_utils``' concrete fallback, so
    constraints apply as ``NamedSharding(mesh, spec)`` instead of
    no-op'ing — same placements on every supported release."""
    from repro.models import shard_utils

    shard_utils.set_compat_mesh(mesh)
    setter = getattr(jax.sharding, "set_mesh", None)
    if setter is not None:
        setter(mesh)
    return mesh


def mesh_from_spec(spec: str):
    """Build a mesh from a ``"model=K,data=D"`` CLI spec (axis order is
    normalized to the repo's ``("pod", "data", "model")`` convention, so
    ``model=2,data=4`` and ``data=4,model=2`` are the same mesh). Axis
    sizes must multiply to a divisor of the visible device count —
    ``jax.make_mesh`` enforces that; off-accelerator runs force devices
    with ``XLA_FLAGS=--xla_force_host_platform_device_count=N``."""
    sizes = {}
    for part in spec.split(","):
        name, _, val = part.partition("=")
        name = name.strip()
        if name not in ("pod", "data", "model") or not val.strip().isdigit():
            raise ValueError(
                f"bad mesh spec {spec!r}: expected 'model=K,data=D' with "
                "axes from pod/data/model and integer sizes"
            )
        sizes[name] = int(val)
    axes = tuple(a for a in ("pod", "data", "model") if a in sizes)
    if not axes:
        raise ValueError(f"bad mesh spec {spec!r}: no axes given")
    return make_mesh_compat(tuple(sizes[a] for a in axes), axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh_compat(shape, axes)


def data_axes(mesh) -> tuple:
    """The (possibly compound) batch-parallel axes of a mesh."""
    names = mesh.axis_names
    return ("pod", "data") if "pod" in names else ("data",)


def make_host_mesh(model: int = 1, data: int = 1):
    """Tiny mesh over real local devices (CPU tests)."""
    return make_mesh_compat((data, model), ("data", "model"))
