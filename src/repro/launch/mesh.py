"""Production mesh construction.

A function (not a module constant) so importing this module never touches
jax device state. The dry-run sets XLA_FLAGS host-device-count=512 BEFORE
any jax import; tests and benches see the real single CPU device.

JAX-version compat: ``jax.sharding.AxisType`` (and the ``axis_types``
kwarg of ``jax.make_mesh``) only exist on newer JAX; older releases also
lack ``jax.sharding.set_mesh``. Both are guarded here so the same code
runs on either — on old JAX the mesh is built without explicit axis types
(Auto is the default there anyway) and the global-mesh setter degrades to
a no-op (sharding constraints then no-op too, see
``models.shard_utils._mesh_axes``; explicit in_shardings still apply).
"""
from __future__ import annotations

import jax


def _axis_types_kwargs(n_axes: int) -> dict:
    """``axis_types=(Auto,)*n`` where supported, ``{}`` on older JAX."""
    if hasattr(jax.sharding, "AxisType"):
        return {"axis_types": (jax.sharding.AxisType.Auto,) * n_axes}
    return {}


def make_mesh_compat(shape: tuple, axes: tuple):
    """``jax.make_mesh`` with Auto axis types where the kwarg exists."""
    return jax.make_mesh(shape, axes, **_axis_types_kwargs(len(axes)))


def set_global_mesh(mesh):
    """``jax.sharding.set_mesh`` where it exists (needed so trace-time
    ``with_sharding_constraint`` sees the abstract mesh); no-op fallback."""
    setter = getattr(jax.sharding, "set_mesh", None)
    if setter is not None:
        setter(mesh)
    return mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh_compat(shape, axes)


def data_axes(mesh) -> tuple:
    """The (possibly compound) batch-parallel axes of a mesh."""
    names = mesh.axis_names
    return ("pod", "data") if "pod" in names else ("data",)


def make_host_mesh(model: int = 1, data: int = 1):
    """Tiny mesh over real local devices (CPU tests)."""
    return make_mesh_compat((data, model), ("data", "model"))
