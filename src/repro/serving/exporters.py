"""Exporters for serving telemetry: /metrics HTTP endpoint and JSONL sink.

Everything here is stdlib-only (http.server, threading, json) so the
exporters add no dependencies and can run inside CI smoke jobs. The
device-side story lives in serving/telemetry.py — exporters only *read*
a MetricsRegistry snapshot; they never touch jax and never block the
serving loop (the HTTP server runs on a daemon thread and renders from
registry state at request time).

Formats
-------
- ``MetricsHTTPServer`` — Prometheus text exposition 0.0.4 at ``/metrics``
  (plus a JSON snapshot at ``/metrics.json`` for humans/scripts).
- ``JsonlSink`` — appends one JSON object per line; used for periodic
  registry snapshots and for the end-of-run summary line in
  launch/serve.py.
- Chrome trace-event JSON is produced by TraceRecorder.save (re-exported
  here as ``write_chrome_trace`` for symmetry); open the file in Perfetto
  (https://ui.perfetto.dev) or chrome://tracing.
"""

from __future__ import annotations

import json
import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import IO, Any, Optional, Union

from .telemetry import MetricsRegistry, TraceRecorder

__all__ = [
    "MetricsHTTPServer",
    "JsonlSink",
    "write_chrome_trace",
]


def write_chrome_trace(trace: TraceRecorder, path: str) -> None:
    """Write recorded spans as Chrome trace-event JSON (Perfetto-viewable)."""
    trace.save(path)


class _MetricsHandler(BaseHTTPRequestHandler):
    # the registry is attached to the *server* instance (one per
    # MetricsHTTPServer); handlers are constructed per-request
    server: "_Server"

    def do_GET(self) -> None:  # noqa: N802 - http.server API name
        path = self.path.split("?", 1)[0]
        if path in ("/metrics", "/"):
            body = self.server.registry.render_prometheus().encode("utf-8")
            ctype = "text/plain; version=0.0.4; charset=utf-8"
        elif path == "/metrics.json":
            body = json.dumps(self.server.registry.snapshot()).encode("utf-8")
            ctype = "application/json"
        else:
            self.send_response(404)
            self.send_header("Content-Length", "0")
            self.end_headers()
            return
        self.send_response(200)
        self.send_header("Content-Type", ctype)
        self.send_header("Content-Length", str(len(body)))
        self.end_headers()
        self.wfile.write(body)

    def log_message(self, format: str, *args: Any) -> None:
        # default handler logs every scrape to stderr — silence it; the
        # serving loop owns stdout/stderr
        pass


class _Server(ThreadingHTTPServer):
    daemon_threads = True
    registry: MetricsRegistry


class MetricsHTTPServer:
    """Prometheus text-exposition endpoint over a daemon thread.

    ``port=0`` binds an ephemeral port (use ``.port`` to discover it —
    tests rely on this). ``close()`` shuts the listener down; it is also
    safe to leave running, the thread is a daemon.
    """

    def __init__(self, registry: MetricsRegistry, port: int = 0, host: str = "127.0.0.1"):
        self._httpd = _Server((host, port), _MetricsHandler)
        self._httpd.registry = registry
        self._thread = threading.Thread(
            target=self._httpd.serve_forever, name="metrics-http", daemon=True
        )
        self._thread.start()

    @property
    def port(self) -> int:
        return int(self._httpd.server_address[1])

    @property
    def url(self) -> str:
        host = self._httpd.server_address[0]
        return f"http://{host}:{self.port}/metrics"

    def close(self) -> None:
        self._httpd.shutdown()
        self._httpd.server_close()
        self._thread.join(timeout=5.0)

    def __enter__(self) -> "MetricsHTTPServer":
        return self

    def __exit__(self, *exc: Any) -> None:
        self.close()


class JsonlSink:
    """Append-mode JSONL writer for registry snapshots and summaries.

    Accepts a path (opened lazily, append mode) or an already-open text
    stream. Each ``write`` emits exactly one line; ``write_registry``
    wraps a registry snapshot with a record kind so mixed streams stay
    greppable.
    """

    def __init__(self, path_or_stream: Union[str, IO[str]]):
        if isinstance(path_or_stream, str):
            self._path: Optional[str] = path_or_stream
            self._stream: Optional[IO[str]] = None
        else:
            self._path = None
            self._stream = path_or_stream

    def _out(self) -> IO[str]:
        if self._stream is None:
            assert self._path is not None
            self._stream = open(self._path, "a", encoding="utf-8")
        return self._stream

    def write(self, record: dict) -> None:
        out = self._out()
        out.write(json.dumps(record, sort_keys=True) + "\n")
        out.flush()

    def write_registry(self, registry: MetricsRegistry, **extra: Any) -> None:
        rec = {"kind": "metrics_snapshot", **extra, "metrics": registry.snapshot()}
        self.write(rec)

    def close(self) -> None:
        if self._stream is not None and self._path is not None:
            self._stream.close()
            self._stream = None

    def __enter__(self) -> "JsonlSink":
        return self

    def __exit__(self, *exc: Any) -> None:
        self.close()
