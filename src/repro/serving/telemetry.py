"""Zero-sync serving telemetry: the host registry and the device buffer.

Two halves, one discipline (docs/observability.md):

Host half — ``MetricsRegistry``
    Counters, gauges and log-bucketed histograms with Prometheus text
    exposition (``render_prometheus``) and a JSON-able ``snapshot``. It
    absorbs the server's legacy ``stats`` dict through ``StatsView`` (a
    MutableMapping over registry counters keyed by the old names), so
    every existing ``srv.stats["round_dispatches"]``-style read keeps
    working while the same numbers become scrapeable. ``TraceRecorder``
    rides along: host-loop phase spans (admit / dispatch / drain / route /
    retire) as Chrome trace-event JSON, viewable in Perfetto.

Device half — the round telemetry buffer
    PRs 5–7 made the steady serving round ONE donated dispatch with ZERO
    host syncs between rounds, so per-round instrumentation must not read
    anything back. The buffer is a fixed-shape dict of small device arrays
    (per-slot accepted/drafted token counts, chosen draft budgets, PLD
    hits, per-(level, slot) cascade routing + acceptance tallies) that is
    carried and DONATED through the round executables exactly like the
    server's ``dstate`` — ``accumulate_round`` / ``accumulate_cascade``
    are pure jnp updates composed into the jitted round at the jit
    boundary, never a callback. The host reads the buffer only at the
    existing ``sync_every``/flush/admission drain points, where the
    blocked-on round outputs already guarantee the buffer is resolved, so
    ``round_dispatches`` and ``host_syncs`` stay bit-identical with
    telemetry on (tests/test_telemetry.py, tests/test_dispatch_contracts
    .py prove it at runtime AND on the compiled HLO).

Rounds that host-sync anyway (split / legacy, and the cascade's bounded
per-level dispatches) accumulate the SAME schema host-side from arrays
they already materialized — the device carry is reserved for exactly the
rounds that have no sync to piggyback on. ``merge_totals`` folds the two
halves into one cumulative view, drained as deltas into the registry.
"""
from __future__ import annotations

import bisect
import contextlib
import json
import math
import os
import time
from typing import Any, Dict, Iterator, List, Optional, Tuple

import numpy as np

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "StatsView",
    "STATS_METRICS",
    "TraceRecorder",
    "maybe_span",
    "profiler_trace",
    "telemetry_schema",
    "init_device_telemetry",
    "init_host_telemetry",
    "accumulate_round",
    "accumulate_cascade",
    "merge_totals",
    "fold_telemetry",
]


# =========================================================== host registry
def _label_key(labels: Dict[str, Any]) -> Tuple[Tuple[str, str], ...]:
    return tuple(sorted((k, str(v)) for k, v in labels.items()))


def _render_labels(labels: Tuple[Tuple[str, str], ...]) -> str:
    if not labels:
        return ""
    inner = ",".join(f'{k}="{v}"' for k, v in labels)
    return "{" + inner + "}"


class Counter:
    """A monotonically increasing value (fractional increments allowed:
    the legacy ``*_time`` stats are second-counters)."""

    __slots__ = ("value",)

    def __init__(self) -> None:
        self.value = 0.0

    def inc(self, v: float = 1.0) -> None:
        self.value += v


class Gauge:
    """A point-in-time value (queue depth, slot occupancy)."""

    __slots__ = ("value",)

    def __init__(self) -> None:
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)


class Histogram:
    """A log-bucketed histogram with left-closed buckets.

    ``edges`` are the finite bucket boundaries; observations land in
    ``(-inf, e0), [e0, e1), ..., [e_{n-1}, +inf)`` via ``bisect_right`` on
    the precomputed edge list — no float ``log`` at observe time, so a
    value exactly equal to an edge deterministically lands in the bucket
    the edge OPENS (never lost, never double-counted; pinned by the
    property test in tests/test_telemetry.py). Prometheus exposition
    renders the standard cumulative ``le`` form.
    """

    __slots__ = ("edges", "counts", "sum", "count")

    def __init__(self, edges: List[float]) -> None:
        if sorted(edges) != list(edges) or len(set(edges)) != len(edges):
            raise ValueError("histogram edges must be strictly increasing")
        self.edges = list(edges)
        self.counts = [0] * (len(edges) + 1)
        self.sum = 0.0
        self.count = 0

    @staticmethod
    def log_edges(lo: float, hi: float, base: float = 2.0) -> List[float]:
        """Geometric bucket edges ``lo, lo*base, ...`` up to (and
        including the first edge >=) ``hi``."""
        if lo <= 0 or base <= 1 or hi <= lo:
            raise ValueError("need 0 < lo < hi and base > 1")
        edges, e = [], lo
        # ~ceil(log_base(hi/lo)) + 1 edges, built multiplicatively so the
        # edge values are stable products (no log/pow roundtrip)
        for _ in range(int(math.log(hi / lo, base)) + 2):
            edges.append(e)
            if e >= hi:
                break
            e *= base
        return edges

    def bucket_index(self, v: float) -> int:
        return bisect.bisect_right(self.edges, v)

    def observe(self, v: float) -> None:
        self.counts[self.bucket_index(v)] += 1
        self.sum += v
        self.count += 1


# legacy BatchedSpecServer.stats key -> registry counter name. StatsView
# keeps every existing stats read/mutation working against the registry.
STATS_METRICS: Dict[str, str] = {
    "steps": "serve_rounds_total",
    "tokens": "serve_tokens_total",
    "target_calls": "serve_target_calls_total",
    "draft_dispatches": "serve_draft_dispatches_total",
    "draft_time": "serve_draft_seconds_total",
    "verify_time": "serve_verify_seconds_total",
    "drafted_tokens": "serve_drafted_tokens_total",
    "rescore_dispatches": "serve_rescore_dispatches_total",
    "rescore_time": "serve_rescore_seconds_total",
    "round_dispatches": "serve_round_dispatches_total",
    "host_syncs": "serve_host_syncs_total",
    "device_wait": "serve_device_wait_seconds_total",
}

# integer-semantics stats keys: reads come back as int so existing
# ``== 8``-style pins and dict reprs stay exact
_INT_STATS = {
    "steps", "tokens", "target_calls", "draft_dispatches", "drafted_tokens",
    "rescore_dispatches", "round_dispatches", "host_syncs",
}

_LATENCY_EDGES = Histogram.log_edges(1e-4, 512.0)   # 100us .. ~512s


class MetricsRegistry:
    """Counters + gauges + histograms, keyed by (name, labels).

    One registry per server; exporters (``serving.exporters``) render it
    as Prometheus text or JSONL snapshots. Creation is get-or-create so
    hot paths just call ``registry.counter(...).inc()``."""

    def __init__(self) -> None:
        self._counters: Dict[Tuple[str, Tuple[Tuple[str, str], ...]], Counter] = {}
        self._gauges: Dict[Tuple[str, Tuple[Tuple[str, str], ...]], Gauge] = {}
        self._hists: Dict[Tuple[str, Tuple[Tuple[str, str], ...]], Histogram] = {}

    # ------------------------------------------------------------- factories
    def counter(self, name: str, **labels: Any) -> Counter:
        key = (name, _label_key(labels))
        c = self._counters.get(key)
        if c is None:
            c = self._counters[key] = Counter()
        return c

    def gauge(self, name: str, **labels: Any) -> Gauge:
        key = (name, _label_key(labels))
        g = self._gauges.get(key)
        if g is None:
            g = self._gauges[key] = Gauge()
        return g

    def histogram(
        self, name: str, edges: Optional[List[float]] = None, **labels: Any
    ) -> Histogram:
        key = (name, _label_key(labels))
        h = self._hists.get(key)
        if h is None:
            h = self._hists[key] = Histogram(
                list(_LATENCY_EDGES) if edges is None else edges
            )
        return h

    # --------------------------------------------------------------- export
    def render_prometheus(self) -> str:
        """Prometheus text exposition (format 0.0.4), stable ordering."""
        lines: List[str] = []
        typed: set = set()

        def _head(name: str, kind: str) -> None:
            if name not in typed:
                typed.add(name)
                lines.append(f"# TYPE {name} {kind}")

        for (name, labels), c in sorted(self._counters.items()):
            _head(name, "counter")
            lines.append(f"{name}{_render_labels(labels)} {_num(c.value)}")
        for (name, labels), g in sorted(self._gauges.items()):
            _head(name, "gauge")
            lines.append(f"{name}{_render_labels(labels)} {_num(g.value)}")
        for (name, labels), h in sorted(self._hists.items()):
            _head(name, "histogram")
            # prometheus 'le' buckets are right-closed cumulative; our raw
            # buckets are left-closed — le=edges[i] accumulates every raw
            # bucket strictly below edge i (counts[0..i]), and since a
            # sample exactly ON an edge lands in the bucket the edge opens,
            # it is excluded from that le and included in the next: the
            # exposition stays a valid monotone cumulative either way
            for i, e in enumerate(h.edges):
                lines.append(
                    f"{name}_bucket{_merge_le(labels, e)} {sum(h.counts[: i + 1])}"
                )
            lines.append(f'{name}_bucket{_merge_le(labels, "+Inf")} {h.count}')
            lines.append(f"{name}_sum{_render_labels(labels)} {_num(h.sum)}")
            lines.append(f"{name}_count{_render_labels(labels)} {h.count}")
        return "\n".join(lines) + "\n"

    def snapshot(self) -> Dict[str, Any]:
        """JSON-able snapshot: rendered-name -> value/summary."""

        def nm(name: str, labels: Tuple[Tuple[str, str], ...]) -> str:
            return name + _render_labels(labels)

        return {
            "counters": {
                nm(n, la): c.value for (n, la), c in sorted(self._counters.items())
            },
            "gauges": {
                nm(n, la): g.value for (n, la), g in sorted(self._gauges.items())
            },
            "histograms": {
                nm(n, la): {
                    "edges": h.edges, "counts": h.counts,
                    "sum": h.sum, "count": h.count,
                }
                for (n, la), h in sorted(self._hists.items())
            },
        }


def _num(v: float) -> str:
    return str(int(v)) if float(v).is_integer() else repr(float(v))


def _merge_le(labels: Tuple[Tuple[str, str], ...], le: Any) -> str:
    return _render_labels(tuple(sorted(labels + (("le", str(le)),))))


class StatsView:
    """MutableMapping facade: the legacy ``server.stats`` dict, backed by
    registry counters (``STATS_METRICS``). Reads, ``+=`` mutations, and
    dict-style iteration all operate on the live registry, so the stats
    the tests pin and the /metrics endpoint exports cannot drift apart."""

    def __init__(self, registry: MetricsRegistry) -> None:
        self._registry = registry
        for name in STATS_METRICS.values():
            registry.counter(name)          # materialize at zero

    def __getitem__(self, key: str):
        v = self._registry.counter(STATS_METRICS[key]).value
        return int(v) if key in _INT_STATS else v

    def __setitem__(self, key: str, value: float) -> None:
        self._registry.counter(STATS_METRICS[key]).value = float(value)

    def __contains__(self, key: str) -> bool:
        return key in STATS_METRICS

    def __iter__(self) -> Iterator[str]:
        return iter(STATS_METRICS)

    def __len__(self) -> int:
        return len(STATS_METRICS)

    def get(self, key: str, default=None):
        return self[key] if key in STATS_METRICS else default

    def items(self):
        return [(k, self[k]) for k in STATS_METRICS]

    def copy(self) -> Dict[str, float]:
        return {k: self[k] for k in STATS_METRICS}

    def __repr__(self) -> str:
        return f"StatsView({self.copy()!r})"


# ============================================================ trace spans
class TraceRecorder:
    """Chrome trace-event recorder for host-loop phases.

    ``span(name)`` records one complete ("ph": "X") event; ``save`` writes
    the ``{"traceEvents": [...]}`` JSON that chrome://tracing and Perfetto
    (https://ui.perfetto.dev) open directly. Timestamps are microseconds
    relative to recorder creation — only ``time.perf_counter`` deltas,
    per the REPRO005 timing discipline. Host-phase spans deliberately do
    NOT force device syncs: a "dispatch" span times the host-side dispatch
    of a pipelined round (device completion is accounted separately by the
    ``device_wait`` counter at the drain points)."""

    def __init__(self) -> None:
        self.events: List[Dict[str, Any]] = []
        self._t0 = time.perf_counter()
        self._pid = os.getpid()

    @contextlib.contextmanager
    def span(self, name: str, **args: Any) -> Iterator[None]:
        t0 = time.perf_counter()
        try:
            yield
        finally:
            t1 = time.perf_counter()
            ev = {
                "name": name, "ph": "X", "pid": self._pid, "tid": 0,
                "ts": (t0 - self._t0) * 1e6, "dur": (t1 - t0) * 1e6,
            }
            if args:
                ev["args"] = args
            self.events.append(ev)

    def instant(self, name: str, **args: Any) -> None:
        ev = {
            "name": name, "ph": "i", "s": "t", "pid": self._pid, "tid": 0,
            "ts": (time.perf_counter() - self._t0) * 1e6,
        }
        if args:
            ev["args"] = args
        self.events.append(ev)

    def to_json(self) -> Dict[str, Any]:
        return {"traceEvents": list(self.events), "displayTimeUnit": "ms"}

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_json(), f)


def maybe_span(trace: Optional[TraceRecorder], name: str, **args: Any):
    """``with maybe_span(trace, "drain"):`` — a no-op when tracing is off,
    so call sites don't branch."""
    if trace is None:
        return contextlib.nullcontext()
    return trace.span(name, **args)


@contextlib.contextmanager
def profiler_trace(log_dir: Optional[str]) -> Iterator[None]:
    """Optional ``jax.profiler.trace`` hook: profiles the wrapped region
    into ``log_dir`` (TensorBoard/XPlane format) when a directory is
    given; a no-op otherwise."""
    if not log_dir:
        yield
        return
    import jax

    with jax.profiler.trace(log_dir):
        yield


# ====================================================== device telemetry
def telemetry_schema(
    batch: int, budget_max: int, levels: int = 0
) -> Dict[str, Tuple[Tuple[int, ...], Any]]:
    """The fixed-shape buffer layout (docs/observability.md), shared by
    the device buffer and its host-side numpy twin:

      rounds          (B,)            live rounds per slot
      accepted        (B,)            committed tokens per slot (n_acc sums)
      drafted         (B,)            NEURAL drafted tokens per slot
      pld_tokens      (B,)            PLD-proposed tokens per slot
      pld_hit_rounds  (B,)            rounds with >= 1 PLD proposal
      budget_hist     (B, budget_max+1)  chosen draft budget / tree
                                      expansion count histogram (column j =
                                      rounds the Eq. 5 routing picked j)
      casc_routed     (L, B)          rounds level l participated in
      casc_obs        (L, B)          Eq. 4 observations of level l's first
                                      token (row 0 = target judging the
                                      strongest level — the bank's
                                      slot_key(l) tally)
      casc_accept     (L, B)          ... of which accepted

    Every array leads with the batch (or (level, batch)) dim and is i32 —
    small, fixed-shape, donation-friendly. Cascade rows exist only for
    cascade servers (``levels > 0``)."""
    B, K = batch, budget_max
    schema: Dict[str, Tuple[Tuple[int, ...], Any]] = {
        "rounds": ((B,), np.int32),
        "accepted": ((B,), np.int32),
        "drafted": ((B,), np.int32),
        "pld_tokens": ((B,), np.int32),
        "pld_hit_rounds": ((B,), np.int32),
        "budget_hist": ((B, K + 1), np.int32),
    }
    if levels:
        schema["casc_routed"] = ((levels, B), np.int32)
        schema["casc_obs"] = ((levels, B), np.int32)
        schema["casc_accept"] = ((levels, B), np.int32)
    return schema


def init_device_telemetry(schema: Dict[str, Tuple[Tuple[int, ...], Any]]):
    """Fresh all-zero device buffer (a dict of jnp arrays, ready to be
    carried + donated through the round executables)."""
    import jax.numpy as jnp

    return {k: jnp.zeros(shape, dtype) for k, (shape, dtype) in schema.items()}


def init_host_telemetry(
    schema: Dict[str, Tuple[Tuple[int, ...], Any]]
) -> Dict[str, np.ndarray]:
    """The numpy twin, accumulated by rounds that host-sync anyway."""
    return {k: np.zeros(shape, dtype) for k, (shape, dtype) in schema.items()}


def accumulate_round(telem: dict, out: dict, live) -> dict:
    """Pure-jnp buffer update for one fused chain/tree round — composed
    into the SAME jitted executable as the round (the server wraps
    ``chain_round``/``tree_round`` with this at the jit boundary), so the
    round stays one dispatch and the buffer rides the donation.

    ``out`` is the round's output dict (``acc``/``n_acc`` plus the
    per-slot ``drafted``/``pld_have``/``budget`` diagnostics the engine
    exposes for exactly this purpose); dead slots contribute zeros by the
    engine's masking."""
    import jax.numpy as jnp

    t = dict(telem)
    li = live.astype(jnp.int32)
    B, K1 = t["budget_hist"].shape
    t["rounds"] = t["rounds"] + li
    t["accepted"] = t["accepted"] + out["n_acc"].astype(jnp.int32)
    t["drafted"] = t["drafted"] + out["drafted"].astype(jnp.int32)
    t["pld_tokens"] = t["pld_tokens"] + out["pld_have"].astype(jnp.int32)
    t["pld_hit_rounds"] = t["pld_hit_rounds"] + (
        (out["pld_have"] > 0) & live
    ).astype(jnp.int32)
    # one-hot broadcast rather than a scatter-add: scatters can lower to a
    # per-update loop, which would add a scan the transparency contract
    # (assert_telemetry_transparent) forbids
    col = jnp.clip(out["budget"], 0, K1 - 1)
    hit = (col[:, None] == jnp.arange(K1)[None, :]).astype(jnp.int32)
    t["budget_hist"] = t["budget_hist"] + hit * li[:, None]
    return t


def accumulate_cascade(
    telem: dict,
    *,
    live,
    n_acc,
    count,
    pld_have,
    budget,
    routed,
    probe_ok,
    probe_valid,
    rescorer_rows: Tuple[int, ...],
    drafter_row: int,
    obs_row: int,
) -> dict:
    """Pure-jnp buffer update composed into the cascade's LAST rescore
    dispatch (``cascade_rescore_verify`` — the one that also carries the
    folded target verify). The cascade round is bounded at L dispatches
    with a host sync per dispatch, but the buffer still rides the donated
    final dispatch so every mode drains through one schema.

    Row bookkeeping (see ``DraftBank``): ``rescorer_rows`` are the level
    indices that rescored this round (they share one routing decision),
    ``drafter_row`` participates whenever a neural budget was granted, and
    ``obs_row`` is the level whose first token THIS dispatch judged (the
    strongest rescorer prices level ``obs_row = its index + 1``).
    Intermediate rescorers' verdicts and the target-facing row 0 are
    accumulated host-side by the server from the same arrays it already
    materializes for the Eq. 4 trackers."""
    import jax.numpy as jnp

    t = dict(telem)
    li = live.astype(jnp.int32)
    B, K1 = t["budget_hist"].shape
    t["rounds"] = t["rounds"] + li
    t["accepted"] = t["accepted"] + n_acc.astype(jnp.int32)
    t["drafted"] = t["drafted"] + jnp.clip(
        count.astype(jnp.int32) - pld_have.astype(jnp.int32) - 1, 0, None
    ) * li
    t["pld_tokens"] = t["pld_tokens"] + pld_have.astype(jnp.int32) * li
    t["pld_hit_rounds"] = t["pld_hit_rounds"] + (
        (pld_have > 0) & live
    ).astype(jnp.int32)
    col = jnp.clip(budget, 0, K1 - 1)   # one-hot add, not scatter (no scan)
    hit = (col[:, None] == jnp.arange(K1)[None, :]).astype(jnp.int32)
    t["budget_hist"] = t["budget_hist"] + hit * li[:, None]
    routed_i = (routed & live).astype(jnp.int32)
    cr = t["casc_routed"]
    for r in rescorer_rows:
        cr = cr.at[r].add(routed_i)
    cr = cr.at[drafter_row].add(((budget > 0) & live).astype(jnp.int32))
    t["casc_routed"] = cr
    pv = probe_valid.astype(jnp.int32)
    t["casc_obs"] = t["casc_obs"].at[obs_row].add(pv)
    t["casc_accept"] = t["casc_accept"].at[obs_row].add(
        (probe_valid & probe_ok).astype(jnp.int32)
    )
    return t


def merge_totals(
    device: Optional[dict], host: Dict[str, np.ndarray]
) -> Dict[str, np.ndarray]:
    """Cumulative totals = resolved device buffer + host twin. Call only
    at a drain point: the server guarantees the device buffer belongs to
    an already-completed round there, so reading it is a plain D2H copy,
    not a new sync point."""
    out = {k: v.copy() for k, v in host.items()}
    if device is not None:
        for k, v in device.items():
            out[k] = out[k] + np.asarray(v)
    return out


def fold_telemetry(
    registry: MetricsRegistry,
    delta: Dict[str, np.ndarray],
    prefix: str = "serve",
) -> None:
    """Fold a drained per-slot delta into labeled registry counters."""
    per_slot = {
        "rounds": f"{prefix}_slot_rounds_total",
        "accepted": f"{prefix}_slot_accepted_tokens_total",
        "drafted": f"{prefix}_slot_drafted_tokens_total",
        "pld_tokens": f"{prefix}_slot_pld_tokens_total",
        "pld_hit_rounds": f"{prefix}_slot_pld_hit_rounds_total",
    }
    for key, name in per_slot.items():
        arr = delta.get(key)
        if arr is None:
            continue
        for b, v in enumerate(arr):
            if v:
                registry.counter(name, slot=b).inc(int(v))
    bh = delta.get("budget_hist")
    if bh is not None:
        for b in range(bh.shape[0]):
            for j in range(bh.shape[1]):
                if bh[b, j]:
                    registry.counter(
                        f"{prefix}_draft_budget_rounds_total", slot=b, budget=j
                    ).inc(int(bh[b, j]))
    per_level = {
        "casc_routed": f"{prefix}_cascade_routed_rounds_total",
        "casc_obs": f"{prefix}_cascade_obs_total",
        "casc_accept": f"{prefix}_cascade_accept_total",
    }
    for key, name in per_level.items():
        arr = delta.get(key)
        if arr is None:
            continue
        for lvl in range(arr.shape[0]):
            for b in range(arr.shape[1]):
                if arr[lvl, b]:
                    registry.counter(name, level=lvl, slot=b).inc(
                        int(arr[lvl, b])
                    )
