"""Draft-level bank: a §4.1 DSIA hierarchy materialized into executable
batched levels for the ``cascade_fused`` serving mode.

``dsia.build_hierarchy`` describes a hierarchy *symbolically* (DraftSpec
per level: gates / quantize / attn_override + App. D cold-start priors).
The bank turns each neural level into something the batched runtime can
dispatch directly:

  - **gates** — the per-layer 0/1 vector as a device-ready float array
    (layer-sparsity / early-exit levels share the target's params and
    executable, exactly like ``chain_fused``/``tree_fused`` drafting);
  - **int8 levels** — execution is backend-aware. On TPU the level shares
    the ORIGINAL params and sets ``quantize="int8"`` on its decode calls,
    which routes the dense-MLP matmuls through the Pallas
    ``kernels.quantized_matmul`` W8A8 kernel (dynamic quantization in the
    kernel: no second parameter copy in HBM). Off-TPU the kernel would run
    interpreted (orders of magnitude slower than XLA), so the bank
    materializes a fake-quantized parameter copy ONCE via
    ``engine.fake_quant_int8`` — the CPU numerics simulation of the same
    contract (``tests/test_int8_parity.py`` pins the two paths together).
    ``param_bytes`` reports the memory cost of every materialized copy;
  - **attn_override** — StreamingAttention levels carry the override dict
    that ``models.model.decode_step`` applies to full-attention layers.

Level order follows the hierarchy: ``levels[0]`` is the strongest (closest
to the target), ``levels[-1]`` the cheapest — the cascade drafter. The
retrieval bottom (PLD) is kept as ``bank.pld`` for priors; it never
executes on device.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import jax
import numpy as np

from repro.config.base import ModelConfig
from repro.core.dsia import DraftSpec, PLD_SPEC
from repro.core.engine import fake_quant_int8


@dataclasses.dataclass(frozen=True)
class DraftLevel:
    """One executable cascade level (see module docstring)."""
    index: int                       # 0 = strongest, len-1 = cheapest/drafter
    spec: DraftSpec
    params: dict                     # executable params (shared or int8 copy)
    gates: Optional[np.ndarray]      # (num_layers,) f32, None = all layers on
    quantize: Optional[str]          # "int8" -> W8A8 kernel path at decode
    attn_override: Optional[dict]    # {"kind","window","sink"} or None
    owns_params: bool                # True iff ``params`` is a quantized copy

    @property
    def name(self) -> str:
        return self.spec.name


class DraftBank:
    """Materialized DSIA hierarchy + per-(level, slot) tracker key schema.

    ``int8_exec`` picks the ActivationQuant execution:
      - ``"auto"``   — kernel on TPU, fake-quant simulation elsewhere;
      - ``"kernel"`` — force the Pallas W8A8 path (interpret-mode off TPU;
        only sensible in parity tests);
      - ``"sim"``    — force the fake-quant parameter copy.

    ``param_sharding`` (a NamedSharding tree congruent with ``params``)
    places any materialized int8 copy like the target weights, so sharded
    servers keep every cascade level tensor-parallel on the same mesh.
    """

    def __init__(
        self,
        cfg: ModelConfig,
        params: dict,
        hierarchy: Sequence[DraftSpec],
        *,
        int8_exec: str = "auto",
        param_sharding=None,
    ):
        if int8_exec not in ("auto", "kernel", "sim"):
            raise ValueError(f"unknown int8_exec {int8_exec!r}")
        if int8_exec == "auto":
            int8_exec = "kernel" if jax.default_backend() == "tpu" else "sim"
        self.cfg = cfg
        neural = [s for s in hierarchy if s.kind == "neural"]
        retrieval = [s for s in hierarchy if s.kind == "retrieval"]
        if not neural:
            raise ValueError("hierarchy has no neural level to execute")
        self.pld: DraftSpec = retrieval[0] if retrieval else PLD_SPEC
        self.param_bytes = 0
        self.levels: List[DraftLevel] = []
        quant_cache: Dict[int, dict] = {}    # share one int8 copy per base
        for i, spec in enumerate(neural):
            gates = None
            if spec.gates is not None:
                gates = spec.gates_array(cfg.num_layers)
            level_params, quantize, owns = params, None, False
            if spec.quantize is not None:
                if spec.quantize != "int8":
                    raise ValueError(
                        f"level {spec.name!r}: unsupported quantize "
                        f"{spec.quantize!r} (only 'int8')"
                    )
                if int8_exec == "kernel":
                    quantize = "int8"        # dynamic in-kernel quantization
                else:
                    if id(params) not in quant_cache:
                        q = fake_quant_int8(params)
                        if param_sharding is not None:
                            # int8 sim copies inherit the target's mesh
                            # placement — the fake-quant tree is congruent
                            # with params, so the same sharding tree applies
                            q = jax.device_put(q, param_sharding)
                        quant_cache[id(params)] = q
                    level_params, owns = quant_cache[id(params)], True
            override = None
            if spec.attn_override is not None:
                kind, window, sink = spec.attn_override
                override = {"kind": kind, "window": window, "sink": sink}
            self.levels.append(DraftLevel(
                index=i, spec=spec, params=level_params, gates=gates,
                quantize=quantize, attn_override=override, owns_params=owns,
            ))
        self.param_bytes = sum(
            leaf.nbytes
            for p in quant_cache.values()
            for leaf in jax.tree.leaves(p)
            if hasattr(leaf, "nbytes")
        )

    # ------------------------------------------------------------- accessors
    def __len__(self) -> int:
        return len(self.levels)

    @property
    def drafter(self) -> DraftLevel:
        """The cheapest level — runs the drafting scan."""
        return self.levels[-1]

    @property
    def rescorers(self) -> List[DraftLevel]:
        """Stronger levels in rescore order: just-above-drafter first, the
        strongest (target-adjacent) level last."""
        return self.levels[-2::-1]

    # ------------------------------------------------- tracker key schema
    def slot_key(self, level: int, slot: int) -> str:
        """Acceptance key for (level, slot): level 0's alpha prices target
        acceptance of the strongest level's tokens; level i>0's alpha prices
        level i-1's acceptance of level i's tokens."""
        return f"casc{level}:{slot}"

    def direct_key(self, slot: int) -> str:
        """Acceptance of the CHEAPEST level's tokens directly by the target
        (observed only on rounds routed single-level — prices the
        no-rescore plan in ``latency.best_cascade_plan``)."""
        return f"cascdir:{slot}"

    def cost_key(self, level: int) -> str:
        return f"casc_rescore:{self.levels[level].name}"

    # ------------------------------------------------------- App. D priors
    def alpha_prior(self, level: int) -> float:
        """Cold-start acceptance prior for ``slot_key(level, ·)``."""
        spec = self.levels[level].spec
        if level == 0:
            return float(spec.prior_alpha)
        return spec.prior_alpha_given(self.levels[level - 1].spec)

    def direct_prior(self) -> float:
        """Compositional cold-start prior for the cheapest-vs-target plan."""
        p = 1.0
        for i in range(len(self.levels)):
            p *= self.alpha_prior(i)
        return float(p)

    def c_prior(self, level: int) -> float:
        return float(self.levels[level].spec.prior_c)
