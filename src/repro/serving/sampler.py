"""Token samplers: greedy / temperature / top-k / top-p (host-side numpy)."""
from __future__ import annotations

from typing import Optional

import numpy as np


def sample_token(
    logits: np.ndarray,
    *,
    temperature: float = 0.0,
    top_k: int = 0,
    top_p: float = 1.0,
    rng: Optional[np.random.Generator] = None,
) -> int:
    """Sample one token from (V,) logits. temperature=0 -> greedy."""
    logits = np.asarray(logits, np.float64)
    if temperature <= 0.0:
        return int(np.argmax(logits))
    rng = rng or np.random.default_rng()
    x = logits / temperature
    if top_k > 0 and top_k < len(x):
        kth = np.partition(x, -top_k)[-top_k]
        x = np.where(x < kth, -np.inf, x)
    x = x - x.max()
    p = np.exp(x)
    p /= p.sum()
    if top_p < 1.0:
        order = np.argsort(-p)
        cum = np.cumsum(p[order])
        cutoff = np.searchsorted(cum, top_p) + 1
        mask = np.zeros_like(p)
        mask[order[:cutoff]] = 1.0
        p = p * mask
        p /= p.sum()
    return int(rng.choice(len(p), p=p))
