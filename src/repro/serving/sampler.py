"""Token samplers: greedy / temperature / top-k / top-p.

``SamplingParams`` is the per-request sampling contract carried through
admission into the fused rounds (serving/server.py); ``warp_probs`` is the
host twin of the device-side ``core.verify.sampling_probs`` — same
temperature scaling, same EXACT-k top-k (ties at the kth value broken by
token index, stable sort), same top-p boundary rule (a token is kept iff
the cumulative mass BEFORE it is < top_p, which matches
``searchsorted(cum, top_p, side='left') + 1`` tokens even when top_p lands
exactly on a cumulative boundary). The two are pinned bit-for-bit against
each other in tests/test_sampler.py.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

__all__ = ["SamplingParams", "warp_probs", "sample_token"]


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """Per-request sampling configuration.

    temperature <= 0 means greedy: the request routes through the existing
    greedy kernels and its output is token-identical to a no-sampling
    server. top_k <= 0 disables the top-k filter; top_p >= 1 disables the
    nucleus filter. ``seed`` fixes the slot's PRNG stream (None -> the
    server derives one from its base seed and the admission counter).
    """

    temperature: float = 0.0
    top_k: int = 0
    top_p: float = 1.0
    seed: Optional[int] = None

    @property
    def greedy(self) -> bool:
        return self.temperature <= 0.0


def warp_probs(
    logits: np.ndarray,
    temperature: float = 1.0,
    top_k: int = 0,
    top_p: float = 1.0,
) -> np.ndarray:
    """The warped target distribution q over (V,) logits (host reference).

    temperature=0 -> a point mass at argmax. Otherwise: scale by the
    temperature, keep the exact top-k logits (stable rank — ties at the
    kth value keep the LOWEST token indices, never more than k tokens),
    softmax, then keep the shortest prefix of the sorted probabilities
    whose exclusive cumulative mass is < top_p, and renormalize.
    """
    logits = np.asarray(logits, np.float64)
    if temperature <= 0.0:
        q = np.zeros_like(logits)
        q[np.argmax(logits)] = 1.0
        return q
    x = logits / max(temperature, 1e-6)
    order = np.argsort(-x, kind="stable")       # ties -> lower index first
    rank = np.argsort(order, kind="stable")
    if top_k > 0:
        x = np.where(rank < top_k, x, -np.inf)
    x = x - x.max()
    p = np.exp(x)
    p /= p.sum()
    if top_p < 1.0:
        p_sorted = p[order]
        cum = np.cumsum(p_sorted)
        keep_sorted = (cum - p_sorted) < max(top_p, 1e-9)
        p = np.where(keep_sorted[rank], p, 0.0)
        p /= p.sum()
    return p


def sample_token(
    logits: np.ndarray,
    *,
    temperature: float = 0.0,
    top_k: int = 0,
    top_p: float = 1.0,
    rng: Optional[np.random.Generator] = None,
) -> int:
    """Sample one token from (V,) logits. temperature=0 -> greedy."""
    q = warp_probs(logits, temperature, top_k, top_p)
    if temperature <= 0.0:
        return int(np.argmax(q))
    rng = rng or np.random.default_rng()
    # inverse-CDF draw — the same rule as the device `_inv_cdf`, so a host
    # replay with the same uniform reproduces the device token exactly
    cum = np.cumsum(q)
    return int(np.argmax(cum > rng.random() * cum[-1]))
