"""Batched speculative serving (continuous batching + cascades).

Four proposal modes (see docs/serving.md):

  - ``chain_fused``  — per-slot PLD proposals merged with a batched
    layer-sparse neural *chain* draft, one ``lax.scan`` dispatch per round
    (App. A's large-batch degradation path; the production default).
  - ``legacy``       — the seed's per-step chain drafting loop (one jitted
    dispatch + host sync per draft token); kept only as the A/B baseline.
  - ``tree_fused``   — the paper's headline Dynamic Tree Cascade (§4.2)
    run batched and on-device: every slot grows a bucketed token tree in a
    single fused ``tree_draft_scan`` dispatch, and tree verification +
    longest-accepted-path commit is one fused target call whose intra-tree
    attention can route through ``kernels.tree_attention``.
  - ``cascade_fused`` — the paper's namesake multi-level cascade (§4.1 +
    Alg. 1), batched: a ``DraftBank`` materializes a DSIA hierarchy
    (layer-sparsity gates, int8 activation-quant params, attention
    overrides), the CHEAPEST level grows every slot's tree in one scan
    dispatch, each stronger level rescores the proposal in one
    intermediate-verify dispatch (``core.engine.cascade_rescore`` —
    level-to-level endorsement, hedge siblings, and extension), and the
    target verifies + commits as in ``tree_fused``. Dispatches per round
    are bounded at (1 per cascade level) + 1 target verify. See
    docs/cascade.md.

All modes verify jointly in one target forward and commit per-sequence
(divergent accepted lengths are supported by the (B,)-pos cache).

Draft-KV execution (``draft_kv=``): the fused drafting scans run either in
``"recompute"`` (every step re-decodes the whole padded node block — O(E*N)
node-forwards per round) or ``"carry"`` (staged draft KV is carried in the
scan and each step decodes only the <= top_k newly appended tokens against
[committed cache ++ carried staged KV] — O(N + E*top_k)). ``"auto"`` picks
carry on attention-only stacks and recompute for SSM stacks, whose per-step
states cannot be carried row-wise. Both modes are token-identical
(tests/test_draft_kv_carry.py); carry is what lets tree buckets grow past
N=32 without the per-step block recompute eating the latency headroom.

Fused drafting
--------------
The k-step neural chain draft runs as ONE jitted ``lax.scan`` over draft
steps (``core.engine.chain_draft_scan``): each step re-decodes the fixed
(B, k+1) block under a causal tree mask, so later draft steps see earlier
drafted tokens through the staged-KV block path entirely on device, with
the committed cache read-only. One dispatch per proposal round replaces
the seed's k ``_decode`` calls with a host sync between each.
Verification + acceptance + commit are likewise one jitted call
(``_verify_accept_commit``): the per-slot Python acceptance loop is
replaced by a vectorized cumprod over the chain-match mask. Drafts never
write the real cache — only target verification does — so serving stays
lossless.

Fused tree drafting (DyTC §4.2, batched)
----------------------------------------
``tree_fused`` seeds every slot's tree with its PLD chain
(``core.tree.tree_seed_arrays``), then grows it on device with
``core.engine.tree_draft_scan``: one jitted ``lax.scan`` over expansion
steps, each re-decoding the padded (B, N) node block under per-slot dense
ancestor-closure masks, selecting the best P_acc leaf with ``jnp.argmax``
and appending TOP-P-filtered top-K children — Alg. 1 without host loops.
Per-slot expansion budgets come from the Eq. 5 objective
(``latency.best_tree_expansions`` over the slot's ``AcceptanceTracker``
alpha and the measured ``CostTracker`` cost), and trees are padded to a
fixed ``TREE_BUCKETS`` size so every round reuses one executable. The
verify half (``_tree_verify_accept_commit``) decodes the whole padded tree
once, walks the longest target-greedy path per slot with a vectorized tree
walk (``verify.greedy_accept_tree_batched``) and commits it — one drafting
dispatch + one verify dispatch per round, and greedy outputs stay
token-identical to AR decoding (drafts only change speed, never content).

Adaptive chain-cascade drafting (DyTC Eq. 5 analogue)
-----------------------------------------------------
Each slot carries an EMA acceptance estimate of its first NEURAL draft
token (Eq. 4, ``AcceptanceTracker`` keyed per slot; PLD outcomes are
excluded so the alpha prices the same drafter whose cost c is measured
from the neural scan) and the server maintains an online
draft-cost coefficient c = draft-token-latency / verify-round-latency
(``CostTracker``). Per round, each slot's draft length is the k maximizing
the chain EWIF T_SD(alpha_b, c, k) (``latency.best_chain_length``); a slot
whose best expected speedup falls below ``t_min`` stops neural drafting
(limit 0) and degrades to plain AR inside the same batched verify — the
chain analogue of DyTC's stop rule. PLD proposals are effectively free
(host-side retrieval, fixed-width verify), so they are never truncated by
the adaptive limit. Slot estimates reset on request admission (continuous
batching reuses slots across requests).
"""
from __future__ import annotations

import functools
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.config.base import BlockKind, ModelConfig
from repro.core.acceptance import AcceptanceTracker
from repro.core.dsia import DraftSpec, PLD_SPEC, build_hierarchy
from repro.core.engine import cascade_rescore, chain_draft_scan, tree_draft_scan
from repro.core.latency import (
    CostTracker,
    best_cascade_plan,
    best_chain_length,
    best_tree_expansions,
)
from repro.core.pld import PromptLookup
from repro.core.tree import bucket_for, tree_seed_arrays
from repro.core.verify import greedy_accept_tree_batched
from repro.models import model as M
from repro.serving.draft_bank import DraftBank

PROPOSAL_MODES = ("chain_fused", "legacy", "tree_fused", "cascade_fused")


def _tree_verify_accept_commit(
    cfg: ModelConfig,
    params: dict,
    cache: dict,
    tokens: jax.Array,                # (B, N) int32 padded tree node tokens
    parents: jax.Array,               # (B, N) int32, -1 at root/unused
    depth: jax.Array,                 # (B, N) int32
    mask: jax.Array,                  # (B, N, N) bool ancestor closure
    count: jax.Array,                 # (B,) int32 real nodes per slot
    live: jax.Array,                  # (B,) bool
    *,
    attn_backend: Optional[str] = None,
):
    """One fused target round for tree proposals: decode the whole padded
    node block jointly under per-slot ancestor-closure masks (the intra-tree
    attention half routes through ``kernels.tree_attention`` when
    ``attn_backend="pallas"``), walk the longest target-greedy path per slot
    with a vectorized tree walk, and commit the accepted path's staged KV.
    Returns (cache, path_idx (B,N), n_acc (B,), bonus (B,))."""
    qpos = cache["pos"][:, None] + depth
    logits, staged = M.decode_step(
        cfg, params, cache, tokens, tree_mask=mask, q_pos=qpos,
        attn_backend=attn_backend,
    )
    nxt = jnp.argmax(logits, -1).astype(jnp.int32)               # (B, N)
    path, n_acc, bonus = greedy_accept_tree_batched(tokens, parents, count, nxt)
    n_acc = jnp.where(live, n_acc, 0).astype(jnp.int32)
    new_cache = M.commit_cache(cfg, cache, staged, path, n_acc)
    return new_cache, path, n_acc, bonus


def _verify_accept_commit(
    cfg: ModelConfig,
    params: dict,
    cache: dict,
    pending: jax.Array,               # (B,) int32
    chains: jax.Array,                # (B, k) int32
    have: jax.Array,                  # (B,) int32
    live: jax.Array,                  # (B,) bool
):
    """One fused target round: verify [pending, chain] jointly, accept the
    longest matching prefix per slot (vectorized — no per-slot Python), and
    commit the accepted path. Returns (cache, nxt, n_chain, new_pending)."""
    toks = jnp.concatenate([pending[:, None], chains], axis=1)   # (B, k+1)
    logits, staged = M.decode_step(cfg, params, cache, toks)
    nxt = jnp.argmax(logits, -1).astype(jnp.int32)               # (B, k+1)
    B, K = chains.shape
    ok = (chains == nxt[:, :K]) & (jnp.arange(K)[None] < have[:, None])
    # accepted chain prefix length: leading run of matches
    n_chain = jnp.cumprod(ok.astype(jnp.int32), axis=1).sum(axis=1)
    n_chain = jnp.where(live, n_chain, 0)
    n_acc = jnp.where(live, n_chain + 1, 0).astype(jnp.int32)    # + pending
    new_pending = jnp.take_along_axis(nxt, n_chain[:, None], axis=1)[:, 0]
    path_idx = jnp.broadcast_to(
        jnp.arange(K + 1, dtype=jnp.int32)[None], (B, K + 1)
    )
    new_cache = M.commit_cache(cfg, cache, staged, path_idx, n_acc)
    return new_cache, nxt, n_chain, new_pending


class BatchedSpecServer:
    def __init__(
        self,
        cfg: ModelConfig,
        params: dict,
        max_batch: int = 4,
        max_len: int = 1024,
        draft_k: int = 4,
        draft_spec: Optional[DraftSpec] = None,   # None -> PLD-only drafting
        fused: bool = True,            # False: seed-style per-step drafting (A/B)
        adaptive: bool = True,         # per-slot adaptive draft length
        t_min: float = 1.05,           # min expected speedup to keep drafting
        min_obs: int = 4,              # per-slot observations before adapting
        mode: Optional[str] = None,    # chain_fused | legacy | tree_fused | cascade_fused
        tree_expansions: int = 5,      # max tree expansion steps per round
        tree_top_k: int = 2,           # sibling candidates per expansion
        tree_top_p: float = 0.3,       # TOP-P sibling filter (P_tree)
        tree_bucket: Optional[int] = None,   # padded tree size (default: fit)
        attn_backend: Optional[str] = "auto",    # tree-verify staged pass
        hierarchy: Optional[List[DraftSpec]] = None,  # cascade_fused levels
        int8_exec: str = "auto",       # bank int8 path: auto | kernel | sim
        draft_kv: str = "auto",        # drafting scans: auto | carry | recompute
    ):
        self.cfg, self.params = cfg, params
        self.B, self.max_len, self.k = max_batch, max_len, draft_k
        self.draft_spec = draft_spec
        if mode is None:
            mode = "chain_fused" if fused else "legacy"
        if mode not in PROPOSAL_MODES:
            raise ValueError(f"unknown proposal mode {mode!r}; pick one of {PROPOSAL_MODES}")
        if draft_kv not in ("auto", "carry", "recompute"):
            raise ValueError(
                f"unknown draft_kv {draft_kv!r}; pick auto, carry or recompute"
            )
        attention_only = not cfg.num_codebooks and all(
            cfg.block_kind(i) is BlockKind.ATTENTION
            for i in range(cfg.num_layers)
        )
        if draft_kv == "auto":
            # carry: O(top_k) new-token decodes per expansion step instead of
            # the O(N) padded-block recompute — the win everywhere except SSM
            # stacks, whose per-step states cannot be carried row-wise
            draft_kv = "carry" if attention_only else "recompute"
        if draft_kv == "carry" and not attention_only:
            raise ValueError(
                "draft_kv='carry' requires an attention-only text stack "
                "(SSM per-step states are cumulative); use 'recompute'"
            )
        self.draft_kv = draft_kv
        if draft_spec is not None:
            if mode == "cascade_fused":
                raise ValueError(
                    "cascade_fused drafts from a hierarchy, not a single "
                    "draft_spec — pass hierarchy=[...] (or leave both unset "
                    "for the default mixing hierarchy)"
                )
            unsupported = draft_spec.unsupported_by_gates_only()
            if unsupported:
                raise ValueError(
                    f"mode {mode!r} drafts gates-only and cannot honor "
                    f"{', '.join(unsupported)} on draft_spec "
                    f"{draft_spec.name!r}; mode='cascade_fused' executes "
                    "quantize/attn_override levels through the draft bank"
                )
        if hierarchy is not None and mode != "cascade_fused":
            raise ValueError("hierarchy=... requires mode='cascade_fused'")
        self.mode = mode
        self.fused = mode != "legacy"
        self.adaptive = adaptive
        self.t_min = t_min
        self.min_obs = min_obs
        self.tree_expansions = tree_expansions
        self.tree_top_k = tree_top_k
        self.tree_top_p = tree_top_p
        if attn_backend == "auto":
            # the Pallas kernel only beats the jnp dense pass when compiled
            # for real; off-TPU it would run in interpret mode (emulation)
            attn_backend = "pallas" if jax.default_backend() == "tpu" else None
        self.attn_backend = attn_backend
        self.tree_bucket = tree_bucket
        self.bank: Optional[DraftBank] = None
        if mode in ("tree_fused", "cascade_fused"):
            if cfg.num_codebooks or any(
                cfg.block_kind(i) is not BlockKind.ATTENTION
                for i in range(cfg.num_layers)
            ):
                raise ValueError(
                    f"{mode} requires an attention-only text stack: staged "
                    "SSM states are chain-ordered and cannot follow tree paths"
                )
            # worst case: root + PLD chain + top_k children per expansion
            # step (an explicit too-small tree_bucket is rejected by
            # tree_seed_arrays when the first round seeds the trees)
            extra = 0
            if mode == "cascade_fused":
                self.bank = DraftBank(
                    cfg, params,
                    hierarchy if hierarchy is not None
                    else build_hierarchy(cfg, "mixing"),
                    int8_exec=int8_exec,
                )
                # one hedge sibling + one extension node per rescore level
                extra = 2 * len(self.bank.rescorers)
            self.tree_bucket = tree_bucket or bucket_for(
                1 + draft_k + tree_top_k * tree_expansions + extra
            )
        self.pld = PromptLookup(max_draft=draft_k)
        self.acceptance = AcceptanceTracker()
        self.costs = CostTracker()
        self.cache = M.init_cache(cfg, max_batch, max_len, dtype=jnp.dtype(cfg.dtype))
        self.pending = np.zeros(max_batch, np.int64)
        self.contexts: List[List[int]] = [[] for _ in range(max_batch)]
        self.live = np.zeros(max_batch, bool)
        self._pld_have = np.zeros(max_batch, np.int32)   # PLD prefix per round

        self._prefill1 = jax.jit(lambda p, b, c: M.prefill(cfg, p, b, c))
        # legacy (unfused) drafting path — kept for A/B benchmarking
        self._decode = jax.jit(
            lambda p, c, t, g: M.decode_step(cfg, p, c, t, gates=g)
        )
        self._verify = jax.jit(functools.partial(_verify_accept_commit, cfg))
        self._tree_verify = jax.jit(functools.partial(
            _tree_verify_accept_commit, cfg, attn_backend=attn_backend,
        ))
        self._draft_fns: Dict[int, callable] = {}   # scan steps -> jitted fn
        self._tree_draft_fns: Dict[int, callable] = {}   # expansions -> jitted fn
        self._casc_draft_fns: Dict[int, callable] = {}   # expansions -> jitted fn
        self._rescore_fns: Dict[int, callable] = {}      # level index -> jitted fn
        self._gates = (
            None
            if draft_spec is None
            else jnp.asarray(draft_spec.gates_array(cfg.num_layers))
        )
        self._level_gates: Dict[int, Optional[jax.Array]] = {}
        if self.bank is not None:
            for lvl in self.bank.levels:
                self._level_gates[lvl.index] = (
                    None if lvl.gates is None else jnp.asarray(lvl.gates)
                )
        self.stats = {
            "steps": 0, "tokens": 0, "target_calls": 0,
            "draft_dispatches": 0, "draft_time": 0.0, "verify_time": 0.0,
            "drafted_tokens": 0,
            "rescore_dispatches": 0, "rescore_time": 0.0,
        }

    # ------------------------------------------------------------ admission
    def add_request(self, slot: int, prompt: np.ndarray) -> None:
        """Prefill one prompt into a batch slot."""
        prompt = np.asarray(prompt, np.int32)
        c1 = M.init_cache(self.cfg, 1, self.max_len, dtype=jnp.dtype(self.cfg.dtype))
        last, c1 = self._prefill1(self.params, {"tokens": jnp.asarray(prompt[None])}, c1)
        self._write_slot(slot, c1)
        self.pending[slot] = int(np.argmax(np.asarray(last)[0]))
        self.contexts[slot] = list(map(int, prompt))
        self.live[slot] = True
        # slot estimators restart with the draft's cold-start prior —
        # continuous batching reuses slots across unrelated requests
        prior = self.draft_spec.prior_alpha if self.draft_spec else 0.5
        self.acceptance.reset(self._slot_key(slot), alpha0=prior)
        if self.bank is not None:
            for i in range(len(self.bank)):
                self.acceptance.reset(
                    self.bank.slot_key(i, slot), alpha0=self.bank.alpha_prior(i)
                )
            self.acceptance.reset(
                self.bank.direct_key(slot), alpha0=self.bank.direct_prior()
            )

    def release(self, slot: int) -> None:
        """Mark a slot free (its request finished or was cancelled)."""
        self.live[slot] = False

    def _slot_key(self, slot: int) -> str:
        return f"chain:{slot}"

    def _write_slot(self, slot: int, c1: dict) -> None:
        # cache leaves: segments (R, B, ...) and pos (B,)
        new_segments = jax.tree.map(
            lambda dst, src: dst.at[:, slot].set(src[:, 0]),
            self.cache["segments"],
            c1["segments"],
        )
        pos = self.cache["pos"].at[slot].set(c1["pos"][0])
        self.cache = {"pos": pos, "segments": new_segments}

    # ----------------------------------------------------- adaptive lengths
    def _slot_limit(self, slot: int) -> int:
        """Neural draft budget for a slot this round (PLD is never capped)."""
        if self.draft_spec is None:
            return 0
        key = self._slot_key(slot)
        if not self.adaptive or self.acceptance.counts(key) < self.min_obs:
            return self.k
        alpha = self.acceptance.alpha(key)
        c = self.costs.c_hat(
            "chain_draft", default=float(self.draft_spec.prior_c)
        )
        return best_chain_length(alpha, max(c, 1e-3), self.k, self.t_min)

    def _slot_tree_budget(self, slot: int) -> int:
        """Tree expansion budget for a slot this round (Eq. 5 objective)."""
        if self.draft_spec is None:
            return 0
        key = self._slot_key(slot)
        if not self.adaptive or self.acceptance.counts(key) < self.min_obs:
            return self.tree_expansions
        alpha = self.acceptance.alpha(key)
        c = self.costs.c_hat(
            "tree_draft", default=float(self.draft_spec.prior_c)
        )
        return best_tree_expansions(
            alpha, max(c, 1e-3), self.tree_expansions, self.t_min
        )

    def _draft_fn(self, steps: int):
        fn = self._draft_fns.get(steps)
        if fn is None:
            fn = jax.jit(functools.partial(
                chain_draft_scan, self.cfg, steps, draft_kv=self.draft_kv,
            ))
            self._draft_fns[steps] = fn
        return fn

    def _tree_draft_fn(self, expansions: int):
        fn = self._tree_draft_fns.get(expansions)
        if fn is None:
            fn = jax.jit(functools.partial(
                tree_draft_scan, self.cfg, expansions, self.tree_top_k,
                top_p=self.tree_top_p, draft_kv=self.draft_kv,
            ))
            self._tree_draft_fns[expansions] = fn
        return fn

    def _casc_draft_fn(self, expansions: int):
        """The cascade's drafting scan: ``tree_draft_scan`` bound to the
        CHEAPEST bank level's static execution (quantize/attn_override);
        its params/gates arrive as call arguments."""
        fn = self._casc_draft_fns.get(expansions)
        if fn is None:
            drafter = self.bank.drafter
            fn = jax.jit(functools.partial(
                tree_draft_scan, self.cfg, expansions, self.tree_top_k,
                top_p=self.tree_top_p, quantize=drafter.quantize,
                attn_override=drafter.attn_override, draft_kv=self.draft_kv,
            ))
            self._casc_draft_fns[expansions] = fn
        return fn

    def _rescore_fn(self, level: int):
        """One jitted intermediate-verify dispatch for bank level
        ``level`` (Alg. 1 level-to-level acceptance)."""
        fn = self._rescore_fns.get(level)
        if fn is None:
            lvl = self.bank.levels[level]
            fn = jax.jit(functools.partial(
                cascade_rescore, self.cfg, quantize=lvl.quantize,
                attn_override=lvl.attn_override,
                attn_backend=self.attn_backend,
            ))
            self._rescore_fns[level] = fn
        return fn

    # ------------------------------------------------------------- stepping
    def _pld_chains(self):
        """Per-slot PLD proposals (B, k) — free host-side retrieval drafts.

        Also records where PLD ends per slot: the acceptance estimator that
        prices the NEURAL draft must only see neural-token outcomes."""
        chains = np.zeros((self.B, self.k), np.int32)
        have = np.zeros(self.B, np.int32)
        for b in range(self.B):
            if not self.live[b]:
                continue
            ctx = np.asarray(self.contexts[b] + [int(self.pending[b])], np.int64)
            toks = self.pld.propose(ctx, self.k)
            chains[b, : len(toks)] = toks
            have[b] = len(toks)
        self._pld_have = have.copy()
        return chains, have

    def _propose(self):
        """Per-slot draft chains (B, k) — PLD first, neural fill-in.

        Returns (chains (B,k) int32, have (B,) int32). The neural fill-in is
        a single fused scan dispatch covering every slot and draft step."""
        chains, have = self._pld_chains()
        limit = np.zeros(self.B, np.int32)
        for b in range(self.B):
            if self.live[b]:
                limit[b] = self._slot_limit(b)
        if self.draft_spec is None:
            return chains, have
        if self.fused:
            return self._propose_fused(chains, have, limit)
        return self._propose_legacy(chains, have, limit)

    def _propose_fused(self, chains, have, limit):
        # one jitted lax.scan over draft steps; trip count = the largest
        # per-slot budget still needing neural fill (<= k distinct compiles)
        steps = int(np.max(np.where(limit > have, limit, 0), initial=0))
        if steps == 0:
            return chains, have
        t0 = time.perf_counter()
        ch_d, hv_d = jax.block_until_ready(
            self._draft_fn(steps)(
                self.params, self.cache,
                jnp.asarray(self.pending, jnp.int32),
                jnp.asarray(chains), jnp.asarray(have), jnp.asarray(limit),
                self._gates,
            )
        )
        dt = time.perf_counter() - t0
        chains, have = np.asarray(ch_d), np.asarray(hv_d)
        self.stats["draft_dispatches"] += 1
        self.stats["draft_time"] += dt
        self.stats["drafted_tokens"] += steps
        # per-draft-step latency (the whole batch advances one token per
        # step) -> c_hat = draft-step / verify-round, the c in T_SD
        self.costs.observe("chain_draft", dt, tokens=steps)
        return chains, have

    def _propose_legacy(self, chains, have, limit):
        # seed behavior: one _decode dispatch per draft step, host syncs
        # between steps (kept only as the A/B baseline for benchmarks)
        need = self.live & (limit > have)
        if not need.any():
            return chains, have
        lo, hi = int(have[need].min()), int(limit[need].max())
        for j in range(lo, hi):
            toks = np.concatenate(
                [self.pending[:, None], chains[:, :j]], axis=1
            ).astype(np.int32)
            t0 = time.perf_counter()
            logits, _ = self._decode(
                self.params, self.cache, jnp.asarray(toks), self._gates
            )
            nxt = np.asarray(jnp.argmax(logits[:, -1], -1))
            self.stats["draft_dispatches"] += 1
            self.stats["draft_time"] += time.perf_counter() - t0
            fill = (have <= j) & (j < limit)
            chains[fill, j] = nxt[fill]
            have = np.maximum(have, np.where(fill, j + 1, have)).astype(np.int32)
        return chains, have

    def step(self) -> Dict[int, List[int]]:
        """One speculative round for the whole batch; returns new tokens."""
        if self.mode == "tree_fused":
            return self._step_tree()
        if self.mode == "cascade_fused":
            return self._step_cascade()
        chains, have = self._propose()
        t0 = time.perf_counter()
        new_cache, nxt, n_chain, new_pending = jax.block_until_ready(
            self._verify(
                self.params, self.cache,
                jnp.asarray(self.pending, jnp.int32),
                jnp.asarray(chains), jnp.asarray(have),
                jnp.asarray(self.live),
            )
        )
        dt = time.perf_counter() - t0
        self.cache = new_cache
        self.stats["target_calls"] += 1
        self.stats["verify_time"] += dt
        self.costs.observe_target(dt, tokens=1)   # per-round target latency

        n_chain = np.asarray(n_chain)
        new_pending = np.asarray(new_pending)
        out: Dict[int, List[int]] = {}
        for b in range(self.B):
            if not self.live[b]:
                continue
            acc = [int(self.pending[b])] + [int(t) for t in chains[b, : n_chain[b]]]
            self.contexts[b].extend(acc)
            out[b] = acc
            self.stats["tokens"] += len(acc)
            # Eq. 4 EMA over the NEURAL drafter (the alpha paired with the
            # neural scan's c in T_SD): observe the first neural position's
            # outcome, and only when its PLD prefix was fully accepted —
            # otherwise the neural token was never evaluated (DyTC's
            # parent-accepted rule). PLD outcomes never enter this alpha.
            pld_n = int(self._pld_have[b])
            if have[b] > pld_n and n_chain[b] >= pld_n:
                self.acceptance.observe(self._slot_key(b), n_chain[b] > pld_n)
        self.pending = np.where(self.live, new_pending.astype(np.int64), self.pending)
        self.stats["steps"] += 1
        return out

    def _step_tree(self) -> Dict[int, List[int]]:
        """One DyTC round for the whole batch: PLD-seeded on-device tree
        growth (ONE fused scan dispatch), then fused verify + path commit
        (ONE target dispatch). Returns accepted tokens per live slot."""
        chains, have = self._pld_chains()
        limits = np.zeros(self.B, np.int32)
        alphas = np.full(self.B, 0.5, np.float32)
        for b in range(self.B):
            if self.live[b]:
                limits[b] = self._slot_tree_budget(b)
                alphas[b] = self.acceptance.alpha(self._slot_key(b))
        seed = tree_seed_arrays(
            self.pending.astype(np.int32), chains, have, self.tree_bucket,
            pld_alpha=PLD_SPEC.prior_alpha,
        )
        d_tokens, d_parents, d_depth, d_p_acc, d_mask, d_count = (
            jnp.asarray(a) for a in seed
        )
        tokens, parents, count = seed[0], seed[1], seed[5]
        first_neural = np.full(self.B, -1, np.int32)
        expansions = int(limits.max(initial=0))
        if expansions > 0:
            c = self.costs.c_hat(
                "tree_draft", default=float(self.draft_spec.prior_c)
            )
            t0 = time.perf_counter()
            out = jax.block_until_ready(self._tree_draft_fn(expansions)(
                self.params, self.cache,
                d_tokens, d_parents, d_depth, d_p_acc, d_mask, d_count,
                jnp.asarray(limits), jnp.asarray(alphas),
                jnp.asarray(max(c, 1e-3), jnp.float32),
                jnp.asarray(self.t_min, jnp.float32),
                self._gates,
            ))
            dt = time.perf_counter() - t0
            # depth/mask stay on device (only the verify reads them); the
            # host bookkeeping below needs tokens/parents/count/first only
            d_tokens, d_parents, d_depth, _, d_mask, d_count, d_first = out
            tokens, parents, count, first_neural = (
                np.asarray(a) for a in (d_tokens, d_parents, d_count, d_first)
            )
            self.stats["draft_dispatches"] += 1
            self.stats["draft_time"] += dt
            self.stats["drafted_tokens"] += int(
                np.clip(count - have - 1, 0, None).sum()
            )
            # per-expansion-step latency -> the c in the Eq. 5 budgets
            self.costs.observe("tree_draft", dt, tokens=expansions)

        t0 = time.perf_counter()
        new_cache, path, n_acc, bonus = jax.block_until_ready(self._tree_verify(
            self.params, self.cache,
            d_tokens, d_parents, d_depth, d_mask, d_count,
            jnp.asarray(self.live),
        ))
        dt = time.perf_counter() - t0
        self.cache = new_cache
        self.stats["target_calls"] += 1
        self.stats["verify_time"] += dt
        self.costs.observe_target(dt, tokens=1)

        path, n_acc, bonus = np.asarray(path), np.asarray(n_acc), np.asarray(bonus)
        out_toks: Dict[int, List[int]] = {}
        for b in range(self.B):
            if not self.live[b]:
                continue
            nodes = path[b, : n_acc[b]]
            acc = [int(tokens[b, i]) for i in nodes]
            self.contexts[b].extend(acc)
            out_toks[b] = acc
            self.stats["tokens"] += len(acc)
            # Eq. 4 EMA: observe the slot's first NEURAL top-1 prediction,
            # and only when its parent was accepted (DyTC's parent-accepted
            # rule; the root is always accepted). When the drafter's top-1
            # duplicated an existing PLD child, first_neural aliases that
            # node — the outcome priced is still the neural prediction's.
            fn = int(first_neural[b])
            if fn >= 0:
                node_set = {int(i) for i in nodes}
                if int(parents[b, fn]) in node_set:
                    self.acceptance.observe(self._slot_key(b), fn in node_set)
        self.pending = np.where(self.live, bonus.astype(np.int64), self.pending)
        self.stats["steps"] += 1
        return out_toks

    # --------------------------------------------------------- cascade round
    def _slot_cascade_plan(self, b: int):
        """Eq. 5 routing + budget split for one slot: returns
        ``(expansions, use_rescore, alpha_eff, rescorer_alphas)``. A slot
        whose trackers say the cascade doesn't pay collapses to single-level
        drafting (no rescores) or to PLD-only (no neural work at all)."""
        bank = self.bank
        L = len(bank)
        alphas = [
            self.acceptance.alpha(bank.slot_key(i, b), default=bank.alpha_prior(i))
            for i in range(L)
        ]
        cs = [
            max(self.costs.c_hat(bank.cost_key(i), default=bank.c_prior(i)), 1e-3)
            for i in range(L - 1)
        ] + [max(self.costs.c_hat("cascade_draft", default=bank.c_prior(L - 1)), 1e-3)]
        alpha_eff = float(np.prod(alphas))
        # warm-up counts whichever keys this slot's rounds actually feed:
        # rescored rounds observe slot_key(0), single-level rounds (the only
        # kind a 1-level hierarchy has) observe direct_key
        warm = (self.acceptance.counts(bank.slot_key(0, b))
                + self.acceptance.counts(bank.direct_key(b)))
        if not self.adaptive or warm < self.min_obs:
            return self.tree_expansions, L > 1, alpha_eff, alphas[: L - 1]
        a_dir = self.acceptance.alpha(
            bank.direct_key(b), default=bank.direct_prior()
        )
        exp, use_rescore = best_cascade_plan(
            alphas, cs, a_dir, self.tree_expansions, self.t_min
        )
        use_rescore = use_rescore and L > 1
        if not use_rescore:
            # single-level rounds are priced (and observed) by the direct
            # tracker — the scan's stop rule must use the same alpha the
            # plan chose the budget with, not the stale compositional prior
            alpha_eff = a_dir
        return exp, use_rescore, alpha_eff, alphas[: L - 1]

    def _step_cascade(self) -> Dict[int, List[int]]:
        """One multi-level cascade round for the whole batch (Alg. 1 + §4.1
        hierarchy, fully batched): PLD-seeded trees, ONE drafting scan by
        the cheapest bank level, ONE intermediate-verify dispatch per
        stronger level (skipped when no slot is routed through it), ONE
        fused target verify + commit. Returns accepted tokens per slot."""
        bank = self.bank
        L = len(bank)
        chains, have = self._pld_chains()
        exp_b = np.zeros(self.B, np.int32)
        use_rescore = np.zeros(self.B, bool)
        alpha_eff = np.full(self.B, 0.5, np.float32)
        resc_alphas = np.full((max(L - 1, 1), self.B), 0.5, np.float32)
        for b in range(self.B):
            if not self.live[b]:
                continue
            exp_b[b], use_rescore[b], alpha_eff[b], r_alphas = (
                self._slot_cascade_plan(b)
            )
            for i, a in enumerate(r_alphas):
                resc_alphas[i, b] = a
        seed = tree_seed_arrays(
            self.pending.astype(np.int32), chains, have, self.tree_bucket,
            pld_alpha=bank.pld.prior_alpha,
        )
        d_tokens, d_parents, d_depth, d_p_acc, d_mask, d_count = (
            jnp.asarray(a) for a in seed
        )
        first_neural = jnp.full((self.B,), -1, jnp.int32)
        expansions = int(exp_b.max(initial=0))
        c_draft = self.costs.c_hat("cascade_draft", default=bank.c_prior(L - 1))
        if expansions > 0:
            t0 = time.perf_counter()
            out = jax.block_until_ready(self._casc_draft_fn(expansions)(
                bank.drafter.params, self.cache,
                d_tokens, d_parents, d_depth, d_p_acc, d_mask, d_count,
                jnp.asarray(exp_b), jnp.asarray(alpha_eff),
                jnp.asarray(max(c_draft, 1e-3), jnp.float32),
                jnp.asarray(self.t_min, jnp.float32),
                self._level_gates[bank.drafter.index],
            ))
            dt = time.perf_counter() - t0
            (d_tokens, d_parents, d_depth, d_p_acc, d_mask, d_count,
             first_neural) = out
            self.stats["draft_dispatches"] += 1
            self.stats["draft_time"] += dt
            self.stats["drafted_tokens"] += int(
                np.clip(np.asarray(d_count) - have - 1, 0, None).sum()
            )
            self.costs.observe("cascade_draft", dt, tokens=expansions)

        # vertical rescores: just-above-drafter first, strongest level last,
        # each ONE jitted dispatch; the probe chain carries each level's
        # first own prediction to the next level's Eq. 4 judgement
        probe = first_neural
        level_node = np.full(self.B, -1, np.int32)
        if use_rescore.any():
            apply = jnp.asarray(use_rescore & self.live)
            for lvl in bank.rescorers:
                r = lvl.index
                t0 = time.perf_counter()
                out = jax.block_until_ready(self._rescore_fn(r)(
                    lvl.params, self.cache,
                    d_tokens, d_parents, d_depth, d_p_acc, d_mask, d_count,
                    probe, apply, jnp.asarray(resc_alphas[r]),
                    self._level_gates[r],
                ))
                dt = time.perf_counter() - t0
                (d_tokens, d_parents, d_depth, d_p_acc, d_mask, d_count,
                 lvl_node_d, probe_ok, probe_valid) = out
                self.stats["rescore_dispatches"] += 1
                self.stats["rescore_time"] += dt
                self.costs.observe(bank.cost_key(r), dt, tokens=1)
                # Eq. 4: this level's verdict on level r+1's first token
                pv, pk = np.asarray(probe_valid), np.asarray(probe_ok)
                for b in range(self.B):
                    if pv[b]:
                        self.acceptance.observe(
                            bank.slot_key(r + 1, b), bool(pk[b])
                        )
                probe = lvl_node_d
            level_node = np.asarray(probe)

        t0 = time.perf_counter()
        new_cache, path, n_acc, bonus = jax.block_until_ready(self._tree_verify(
            self.params, self.cache,
            d_tokens, d_parents, d_depth, d_mask, d_count,
            jnp.asarray(self.live),
        ))
        dt = time.perf_counter() - t0
        self.cache = new_cache
        self.stats["target_calls"] += 1
        self.stats["verify_time"] += dt
        self.costs.observe_target(dt, tokens=1)

        tokens_h = np.asarray(d_tokens)
        parents_h = np.asarray(d_parents)
        first_h = np.asarray(first_neural)
        path, n_acc, bonus = np.asarray(path), np.asarray(n_acc), np.asarray(bonus)
        out_toks: Dict[int, List[int]] = {}
        for b in range(self.B):
            if not self.live[b]:
                continue
            nodes = path[b, : n_acc[b]]
            acc = [int(tokens_h[b, i]) for i in nodes]
            self.contexts[b].extend(acc)
            out_toks[b] = acc
            self.stats["tokens"] += len(acc)
            node_set = {int(i) for i in nodes}
            # Eq. 4, target-facing (parent-accepted rule): on cascade
            # rounds the observation point is the STRONGEST level's own
            # node; on single-level rounds it is the drafter's first
            # prediction, priced under the slot's direct tracker
            if use_rescore[b]:
                fn = int(level_node[b])
                if fn >= 0 and int(parents_h[b, fn]) in node_set:
                    self.acceptance.observe(
                        bank.slot_key(0, b), fn in node_set
                    )
            else:
                fn = int(first_h[b])
                if fn >= 0 and int(parents_h[b, fn]) in node_set:
                    self.acceptance.observe(bank.direct_key(b), fn in node_set)
                    if L == 1:
                        # a 1-level bank's direct acceptance IS its
                        # target-facing level alpha — keep the plan's
                        # cascade leg priced too
                        self.acceptance.observe(
                            bank.slot_key(0, b), fn in node_set
                        )
        self.pending = np.where(self.live, bonus.astype(np.int64), self.pending)
        self.stats["steps"] += 1
        return out_toks
