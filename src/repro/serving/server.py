"""Batched speculative serving (continuous batching + cascades).

Four proposal modes (see docs/serving.md):

  - ``chain_fused``  — per-slot PLD proposals merged with a batched
    layer-sparse neural *chain* draft, one ``lax.scan`` dispatch per round
    (App. A's large-batch degradation path; the production default).
  - ``legacy``       — the seed's per-step chain drafting loop (one jitted
    dispatch + host sync per draft token); kept only as the A/B baseline.
  - ``tree_fused``   — the paper's headline Dynamic Tree Cascade (§4.2)
    run batched and on-device: every slot grows a bucketed token tree in a
    single fused ``tree_draft_scan`` dispatch, and tree verification +
    longest-accepted-path commit is one fused target call whose intra-tree
    attention can route through ``kernels.tree_attention``.
  - ``cascade_fused`` — the paper's namesake multi-level cascade (§4.1 +
    Alg. 1), batched: a ``DraftBank`` materializes a DSIA hierarchy
    (layer-sparsity gates, int8 activation-quant params, attention
    overrides), the CHEAPEST level grows every slot's tree in one scan
    dispatch, each stronger level rescores the proposal in one
    intermediate-verify dispatch (``core.engine.cascade_rescore`` —
    level-to-level endorsement, hedge siblings, and extension), and the
    target verifies + commits as in ``tree_fused``. Dispatches per round
    are bounded at (1 per cascade level) + 1 target verify. See
    docs/cascade.md.

All modes verify jointly in one target forward and commit per-sequence
(divergent accepted lengths are supported by the (B,)-pos cache).

Round execution (``round_mode=``): ``chain_fused``/``tree_fused`` run either
``"single"`` (the default) — ONE fused, device-resident jitted dispatch per
round (``core.engine.chain_round``/``tree_round``: device PLD over a carried
(B, max_len) context buffer, Eq. 4 EMAs + Eq. 5 budgets as carried device
arrays, draft + verify + accept + commit in one executable, cache and state
donated so the commit scatter aliases in place) — or ``"split"`` (the PR-4
structure: host PLD + one drafting dispatch + one verify dispatch with host
syncs between them; kept as the A/B baseline and the host-side oracle). In
single mode the host loop is a pipelined consumer: ``step()`` dispatches the
next round immediately and only drains accepted tokens from already-resolved
device futures every ``sync_every`` rounds (or on admission/retire), so
steady state has zero ``block_until_ready`` between rounds. ``legacy``
is always split (it IS the per-step baseline); ``cascade_fused`` keeps its
bounded one-dispatch-per-level structure but folds the target verify into
the last rescore dispatch (``core.engine.cascade_rescore_verify``) and
donates the cache into it.

Draft-KV execution (``draft_kv=``): the fused drafting scans run either in
``"recompute"`` (every step re-decodes the whole padded node block — O(E*N)
node-forwards per round) or ``"carry"`` (staged draft KV is carried in the
scan and each step decodes only the <= top_k newly appended tokens against
[committed cache ++ carried staged KV] — O(N + E*top_k)). ``"auto"`` picks
carry on attention-only stacks and recompute for SSM stacks, whose per-step
states cannot be carried row-wise. Both modes are token-identical
(tests/test_draft_kv_carry.py); carry is what lets tree buckets grow past
N=32 without the per-step block recompute eating the latency headroom.

Fused drafting
--------------
The k-step neural chain draft runs as ONE jitted ``lax.scan`` over draft
steps (``core.engine.chain_draft_scan``): each step re-decodes the fixed
(B, k+1) block under a causal tree mask, so later draft steps see earlier
drafted tokens through the staged-KV block path entirely on device, with
the committed cache read-only. One dispatch per proposal round replaces
the seed's k ``_decode`` calls with a host sync between each.
Verification + acceptance + commit are likewise one jitted call
(``_verify_accept_commit``): the per-slot Python acceptance loop is
replaced by a vectorized cumprod over the chain-match mask. Drafts never
write the real cache — only target verification does — so serving stays
lossless.

Fused tree drafting (DyTC §4.2, batched)
----------------------------------------
``tree_fused`` seeds every slot's tree with its PLD chain
(``core.tree.tree_seed_arrays``), then grows it on device with
``core.engine.tree_draft_scan``: one jitted ``lax.scan`` over expansion
steps, each re-decoding the padded (B, N) node block under per-slot dense
ancestor-closure masks, selecting the best P_acc leaf with ``jnp.argmax``
and appending TOP-P-filtered top-K children — Alg. 1 without host loops.
Per-slot expansion budgets come from the Eq. 5 objective
(``latency.best_tree_expansions`` over the slot's ``AcceptanceTracker``
alpha and the measured ``CostTracker`` cost), and trees are padded to a
fixed ``TREE_BUCKETS`` size so every round reuses one executable. The
verify half (``_tree_verify_accept_commit``) decodes the whole padded tree
once, walks the longest target-greedy path per slot with a vectorized tree
walk (``verify.greedy_accept_tree_batched``) and commits it — one drafting
dispatch + one verify dispatch per round, and greedy outputs stay
token-identical to AR decoding (drafts only change speed, never content).

Adaptive chain-cascade drafting (DyTC Eq. 5 analogue)
-----------------------------------------------------
Each slot carries an EMA acceptance estimate of its first NEURAL draft
token (Eq. 4, ``AcceptanceTracker`` keyed per slot; PLD outcomes are
excluded so the alpha prices the same drafter whose cost c is measured
from the neural scan) and the server maintains an online
draft-cost coefficient c = draft-token-latency / verify-round-latency
(``CostTracker``). Per round, each slot's draft length is the k maximizing
the chain EWIF T_SD(alpha_b, c, k) (``latency.best_chain_length``); a slot
whose best expected speedup falls below ``t_min`` stops neural drafting
(limit 0) and degrades to plain AR inside the same batched verify — the
chain analogue of DyTC's stop rule. PLD proposals are effectively free
(host-side retrieval, fixed-width verify), so they are never truncated by
the adaptive limit. Slot estimates reset on request admission (continuous
batching reuses slots across requests).

Dispatch contracts (PR 6)
-------------------------
``round_executables()`` enumerates every jitted executable a steady-state
round dispatches as ``{name: (jitted_fn, example_args)}``, and
``expected_dispatches_per_round()`` is the static count the runtime
``round_dispatches``/``host_syncs`` counters are held to.
``analysis.contracts.server_round_contracts`` lowers + compiles each
executable and asserts the discipline on the COMPILED artifact: donation
lowered to real ``input_output_alias`` entries, no host callbacks or
transfers inside a round body, the expected scan trip counts, and — on a
mesh — param/cache sharding annotations (``assert_sharding``) plus the
absence of resharding collectives. See docs/analysis.md.

Mesh-sharded serving (``mesh=``)
--------------------------------
Pass a ``("data", "model")`` mesh (``launch.mesh.make_mesh_compat`` /
``mesh_from_spec``) and the server places the target AND every draft-bank
level tensor-parallel over ``model`` (``launch.sharding.param_specs``;
int8 bank copies inherit the target's placements) and shards the per-slot
round state — the KV cache, the carried ctx buffer, Eq. 4 EMAs, budgets —
over the data axes (``launch.sharding.cache_specs`` /
``round_state_specs``; batch stays replicated when ``max_batch`` doesn't
divide the data-way count). The fused rounds stay ONE donated dispatch on
the mesh: the engine pins carried state to its placement inside the round
(``core.engine._pin_batch``) and the server pins the jit boundary with
concrete ``NamedSharding`` out-constraints, so aliasing survives lowering
and no resharding collective runs between rounds. Greedy output is
token-identical to the single-device server in every mode
(tests/test_server_sharded.py). See docs/sharding.md.

Sampled serving (``sampling=``)
-------------------------------
Pass ``sampling=SamplingParams(temperature, top_k, top_p, seed)`` and every
mode verifies with the lossless stochastic accept/residual-resample rule
against the WARPED target distribution instead of greedy argmax — chain
rounds run the Leviathan accept, tree and cascade rounds the tree-native
walk, and cascades additionally use the stochastic level-to-level rescore
rule (core/verify.py, core/engine.py). The per-slot warp params and threefry
PRNG keys are carried device state (``dstate``), split in-dispatch, never
host-materialized, so sampling adds ZERO dispatches and ZERO host syncs to
any round shape; ``add_request(..., sampling=...)`` overrides params per
request. ``temperature=0`` requests stay token-identical to greedy, and a
greedy build (``sampling=None``) compiles byte-identical executables to
before sampling existed. See docs/serving.md.
"""
from __future__ import annotations

import functools
import os
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config.base import BlockKind, ModelConfig
from repro.core.acceptance import AcceptanceTracker, ema_init
from repro.core.dsia import DraftSpec, PLD_SPEC, build_hierarchy
from repro.core.engine import (
    cascade_rescore,
    cascade_rescore_verify,
    chain_draft_scan,
    chain_round,
    prefill_chunk_stage,
    tree_draft_scan,
    tree_round,
    tree_verify_accept_commit as _tree_verify_accept_commit,
    tree_verify_accept_commit_sampled as _tree_verify_accept_commit_sampled,
    verify_accept_commit as _verify_accept_commit,
    verify_accept_commit_sampled as _verify_accept_commit_sampled,
)
from repro.core.latency import (
    CostTracker,
    best_cascade_plan,
    best_chain_length,
    best_tree_expansions,
)
from repro.core.pld import PromptLookup
from repro.core.tree import bucket_for, tree_seed_arrays
from repro.core.verify import round_uniforms
from repro.models import model as M
from repro.serving import telemetry as TM
from repro.serving.draft_bank import DraftBank
from repro.serving.sampler import SamplingParams, warp_probs

PROPOSAL_MODES = ("chain_fused", "legacy", "tree_fused", "cascade_fused")
ROUND_MODES = ("auto", "single", "split")


def _prefill_bucket(n: int) -> int:
    """Padded admission-prefill length: next power of two >= n (floor 16).

    Bounds jit specializations of the B=1 prefill to O(log max_len) shapes
    while cutting its HBM and FLOPs to ~the prompt's size (satellite S1)."""
    b = 16
    while b < n:
        b *= 2
    return b


class BatchedSpecServer:
    def __init__(
        self,
        cfg: ModelConfig,
        params: dict,
        max_batch: int = 4,
        max_len: int = 1024,
        draft_k: int = 4,
        draft_spec: Optional[DraftSpec] = None,   # None -> PLD-only drafting
        fused: bool = True,            # False: seed-style per-step drafting (A/B)
        adaptive: bool = True,         # per-slot adaptive draft length
        t_min: float = 1.05,           # min expected speedup to keep drafting
        min_obs: int = 4,              # per-slot observations before adapting
        mode: Optional[str] = None,    # chain_fused | legacy | tree_fused | cascade_fused
        tree_expansions: int = 5,      # max tree expansion steps per round
        tree_top_k: int = 2,           # sibling candidates per expansion
        tree_top_p: float = 0.3,       # TOP-P sibling filter (P_tree)
        tree_bucket: Optional[int] = None,   # padded tree size (default: fit)
        attn_backend: Optional[str] = "auto",    # tree-verify staged pass
        hierarchy: Optional[List[DraftSpec]] = None,  # cascade_fused levels
        int8_exec: str = "auto",       # bank int8 path: auto | kernel | sim
        draft_kv: str = "auto",        # drafting scans: auto | carry | recompute
        round_mode: str = "auto",      # auto | single (one dispatch/round) | split
        sync_every: Optional[int] = None,   # single: drain every N rounds
        donate: Optional[bool] = None,      # None = auto (see below)
        mesh=None,                     # jax Mesh: TP params + DP slots (docstring)
        telemetry: bool = True,        # device-carried round telemetry buffer
        metrics: Optional[TM.MetricsRegistry] = None,   # shared host registry
        sampling: Optional[SamplingParams] = None,  # None -> greedy build
        paged: bool = False,           # block-paged KV cache (docs/paging.md)
        page_size: int = 64,           # tokens per KV page
        num_pages: Optional[int] = None,    # pool size (default: full per-slot)
        prefill_chunk: int = 0,        # >0: in-round chunked prefill (paged only)
    ):
        self.cfg, self.params = cfg, params
        self.B, self.max_len, self.k = max_batch, max_len, draft_k
        self.draft_spec = draft_spec
        # ---- block-paged KV cache + chunked prefill (docs/paging.md):
        # paged=True swaps the dense per-slot (B, max_len) attention buffers
        # for a shared page pool addressed through per-slot tables — BIT-
        # identical reads, so every mode below runs unchanged on it.
        # prefill_chunk>0 additionally makes admission enqueue-only: the
        # fused round dispatch itself consumes up to `prefill_chunk` prompt
        # tokens per slot per round (engine.prefill_chunk_stage), so a long
        # prompt never stalls the pipelined host loop.
        self.paged = bool(paged)
        self.page_size = int(page_size)
        self.prefill_chunk = int(prefill_chunk or 0)
        if self.prefill_chunk and not self.paged:
            raise ValueError(
                "prefill_chunk requires paged=True: chunked prompts commit "
                "through the page table, not a dense per-slot block"
            )
        # ---- sampled serving (module docstring): server-level defaults for
        # the per-slot warp params; per-request overrides ride admission.
        # A greedy build (None) compiles byte-identical executables to a
        # pre-sampling server — nothing below may branch on `sampling`
        # in a way that changes the greedy trace.
        self.sampling = sampling
        self._admit_seq = 0            # admissions so far (PRNG stream derivation)
        self._base_key = None
        if sampling is not None:
            self._base_key = jax.random.PRNGKey(
                sampling.seed if sampling.seed is not None else 0
            )
        # ---- mesh placement (tensor-parallel params, data-parallel slots).
        # Shardings are held per-server and applied with explicit
        # device_put / NamedSharding constraints — never via the global
        # mesh — so a sharded and a single-device server can coexist in
        # one process (the parity tests do exactly that).
        self.mesh = mesh
        self._param_sharding: Any = None       # NamedSharding trees when
        self._cache_sharding: Any = None       # mesh is set, else None
        self._c1_sharding: Any = None
        self._state_sharding: Any = None
        self._replicated: Any = None
        if mesh is not None:
            from jax.sharding import NamedSharding, PartitionSpec
            from repro.launch import sharding as SH

            def ns_tree(spec_tree):
                return jax.tree.map(
                    lambda s: NamedSharding(mesh, s), spec_tree,
                    is_leaf=lambda x: isinstance(x, PartitionSpec),
                )

            self._param_sharding = ns_tree(SH.param_specs(cfg, mesh))
            self._cache_sharding = ns_tree(
                SH.cache_specs(cfg, mesh, global_batch=max_batch, paged=paged)
            )
            # the B=1 admission prefill cache is ALWAYS dense (write_slot
            # scatters it through the page table on paged builds)
            self._c1_sharding = ns_tree(
                SH.cache_specs(cfg, mesh, global_batch=1)
            )
            self._state_sharding = ns_tree(
                SH.round_state_specs(
                    mesh, global_batch=max_batch,
                    sampled=sampling is not None,
                    prefill=self.prefill_chunk > 0,
                )
            )
            self._replicated = NamedSharding(mesh, PartitionSpec())
            self.params = jax.device_put(self.params, self._param_sharding)
        if mode is None:
            mode = "chain_fused" if fused else "legacy"
        if mode not in PROPOSAL_MODES:
            raise ValueError(f"unknown proposal mode {mode!r}; pick one of {PROPOSAL_MODES}")
        if round_mode not in ROUND_MODES:
            raise ValueError(
                f"unknown round_mode {round_mode!r}; pick one of {ROUND_MODES}"
            )
        if round_mode == "auto":
            round_mode = "single" if mode in ("chain_fused", "tree_fused") else "split"
        if round_mode == "single" and mode not in ("chain_fused", "tree_fused"):
            raise ValueError(
                "round_mode='single' applies to chain_fused/tree_fused; "
                "legacy IS the per-step split baseline, and cascade_fused "
                "keeps one dispatch per level (the target verify rides the "
                "last rescore dispatch instead)"
            )
        self.round_mode = round_mode
        if sync_every is None:
            sync_every = int(os.environ.get("REPRO_SYNC_EVERY") or 1)
        self.sync_every = max(int(sync_every), 1)
        if donate is None:
            # donate on accelerators (aliasing the KV cache in place is the
            # HBM win); keep it OFF on CPU, where donating a buffer that an
            # in-flight round is still producing blocks the dispatching
            # thread until the producer finishes — serializing exactly the
            # async pipeline single mode exists for (measured ~3x round
            # slowdown in benchmarks/serve_batched.py's round arms)
            donate = jax.default_backend() != "cpu"
        self.donate = bool(donate)
        if draft_kv not in ("auto", "carry", "recompute"):
            raise ValueError(
                f"unknown draft_kv {draft_kv!r}; pick auto, carry or recompute"
            )
        attention_only = not cfg.num_codebooks and all(
            cfg.block_kind(i) is BlockKind.ATTENTION
            for i in range(cfg.num_layers)
        )
        if draft_kv == "auto":
            # carry: O(top_k) new-token decodes per expansion step instead of
            # the O(N) padded-block recompute — the win everywhere except SSM
            # stacks, whose per-step states cannot be carried row-wise
            draft_kv = "carry" if attention_only else "recompute"
        if draft_kv == "carry" and not attention_only:
            raise ValueError(
                "draft_kv='carry' requires an attention-only text stack "
                "(SSM per-step states are cumulative); use 'recompute'"
            )
        self.draft_kv = draft_kv
        if draft_spec is not None:
            if mode == "cascade_fused":
                raise ValueError(
                    "cascade_fused drafts from a hierarchy, not a single "
                    "draft_spec — pass hierarchy=[...] (or leave both unset "
                    "for the default mixing hierarchy)"
                )
            unsupported = draft_spec.unsupported_by_gates_only()
            if unsupported:
                raise ValueError(
                    f"mode {mode!r} drafts gates-only and cannot honor "
                    f"{', '.join(unsupported)} on draft_spec "
                    f"{draft_spec.name!r}; mode='cascade_fused' executes "
                    "quantize/attn_override levels through the draft bank"
                )
        if self.prefill_chunk:
            if self.round_mode != "single":
                raise ValueError(
                    "prefill_chunk rides the fused round dispatch — build "
                    "with round_mode='single' (chain_fused / tree_fused)"
                )
            if not attention_only:
                raise ValueError(
                    "prefill_chunk requires an attention-only text stack: "
                    "chunked prompt commits address KV through the page "
                    "table, and SSM per-step states are cumulative"
                )
        if hierarchy is not None and mode != "cascade_fused":
            raise ValueError("hierarchy=... requires mode='cascade_fused'")
        self.mode = mode
        self.fused = mode != "legacy"
        self.adaptive = adaptive
        self.t_min = t_min
        self.min_obs = min_obs
        self.tree_expansions = tree_expansions
        self.tree_top_k = tree_top_k
        self.tree_top_p = tree_top_p
        if attn_backend == "auto":
            # the Pallas kernel only beats the jnp dense pass when compiled
            # for real; off-TPU it would run in interpret mode (emulation)
            attn_backend = "pallas" if jax.default_backend() == "tpu" else None
        self.attn_backend = attn_backend
        self.tree_bucket = tree_bucket
        self.bank: Optional[DraftBank] = None
        if mode in ("tree_fused", "cascade_fused"):
            if cfg.num_codebooks or any(
                cfg.block_kind(i) is not BlockKind.ATTENTION
                for i in range(cfg.num_layers)
            ):
                raise ValueError(
                    f"{mode} requires an attention-only text stack: staged "
                    "SSM states are chain-ordered and cannot follow tree paths"
                )
            # worst case: root + PLD chain + top_k children per expansion
            # step (an explicit too-small tree_bucket is rejected by
            # tree_seed_arrays when the first round seeds the trees)
            extra = 0
            if mode == "cascade_fused":
                self.bank = DraftBank(
                    cfg, self.params,
                    hierarchy if hierarchy is not None
                    else build_hierarchy(cfg, "mixing"),
                    int8_exec=int8_exec,
                    param_sharding=self._param_sharding,
                )
                # one hedge sibling + one extension node per rescore level
                extra = 2 * len(self.bank.rescorers)
            self.tree_bucket = tree_bucket or bucket_for(
                1 + draft_k + tree_top_k * tree_expansions + extra
            )
        self.pld = PromptLookup(max_draft=draft_k)
        self.acceptance = AcceptanceTracker()
        self.costs = CostTracker()
        self.cache = M.init_cache(
            cfg, max_batch, max_len, dtype=jnp.dtype(cfg.dtype),
            paged=self.paged, page_size=self.page_size, num_pages=num_pages,
        )
        if mesh is not None:
            self.cache = jax.device_put(self.cache, self._cache_sharding)
        # host-side page allocator (paged builds): a plain free list touched
        # only at admission/retire — both existing sync points — so the
        # steady-state rounds never see an allocation decision
        self._pages_per_slot = 0
        self._free_pages: List[int] = []
        self._slot_pages: Dict[int, List[int]] = {}
        if self.paged:
            self._pages_per_slot = M.pages_for(max_len, self.page_size)
            pool = (
                int(num_pages) if num_pages is not None
                else max_batch * self._pages_per_slot
            )
            # pop() from the end -> lowest page indices hand out first
            self._free_pages = list(range(pool))[::-1]
        self.pending = np.zeros(max_batch, np.int64)
        self.contexts: List[List[int]] = [[] for _ in range(max_batch)]
        self.live = np.zeros(max_batch, bool)
        self._pld_have = np.zeros(max_batch, np.int32)   # PLD prefix per round

        # device-resident round state (single mode): the carried arrays the
        # fused round reads AND maintains — pending/live, the PLD context
        # buffer, and the per-slot Eq. 4 estimator (see acceptance.ema_init)
        prior0 = float(draft_spec.prior_alpha) if draft_spec else 0.5
        al0, h0, hn0, hp0 = ema_init(max_batch, prior=prior0)
        self.dstate = {
            "pending": jnp.zeros((max_batch,), jnp.int32),
            "live": jnp.zeros((max_batch,), bool),
            "ctx": jnp.zeros((max_batch, max_len), jnp.int32),
            "alpha": al0, "hist": h0, "hist_n": hn0, "hist_ptr": hp0,
        }
        if sampling is not None:
            # per-slot sampling state carried INSIDE the fused rounds: warp
            # params and the threefry keys the dispatches split themselves
            self.dstate.update(
                temp=jnp.zeros((max_batch,), jnp.float32),
                topk=jnp.zeros((max_batch,), jnp.int32),
                topp=jnp.ones((max_batch,), jnp.float32),
                key=jnp.zeros((max_batch, 2), jnp.uint32),
            )
        if self.prefill_chunk:
            # chunked-prefill progress per slot: prompt tokens committed so
            # far vs prompt length; a slot with pf_done < pf_len is masked
            # dead for the decode half of the round (it is still prefilling)
            self.dstate.update(
                pf_done=jnp.zeros((max_batch,), jnp.int32),
                pf_len=jnp.zeros((max_batch,), jnp.int32),
            )
        if mesh is not None:
            self.dstate = jax.device_put(self.dstate, self._state_sharding)
        self._prior_alpha = prior0
        c0 = float(draft_spec.prior_c) if draft_spec else 0.5
        self._c_dev = jnp.asarray(max(c0, 1e-3), jnp.float32)
        if mesh is not None:
            self._c_dev = jax.device_put(self._c_dev, self._replicated)
        self._inflight: List[dict] = []     # undrained round outputs (single)
        self._out_buf: Dict[int, List[int]] = {}
        self._last_limit = np.zeros(max_batch, np.int32)   # split-round budgets

        # ---- telemetry (docs/observability.md): the host registry is
        # ALWAYS on (it backs .stats, so existing counter reads cost what
        # they always did); ``telemetry=`` gates only the device-carried
        # round buffer, which single-mode rounds accumulate inside THE
        # round dispatch and host-synced rounds mirror into a numpy twin.
        # Drains happen exclusively at existing sync points (flush /
        # admission), so round_dispatches/host_syncs stay bit-identical.
        self.telemetry = bool(telemetry)
        self.metrics = metrics if metrics is not None else TM.MetricsRegistry()
        budget_max = self.k if mode in ("chain_fused", "legacy") else tree_expansions
        self._telem_schema = TM.telemetry_schema(
            max_batch, budget_max,
            levels=len(self.bank) if self.bank is not None else 0,
        )
        self._telem_host = TM.init_host_telemetry(self._telem_schema)
        self._telem_seen = TM.init_host_telemetry(self._telem_schema)
        self._telem_dev = None
        self._telem_sharding = None
        if self.telemetry:
            self._telem_dev = TM.init_device_telemetry(self._telem_schema)
            if mesh is not None:
                # per-slot tallies are pure data parallelism, like dstate
                self._telem_sharding = ns_tree(SH.telemetry_specs(
                    self._telem_schema, mesh, global_batch=max_batch
                ))
                self._telem_dev = jax.device_put(
                    self._telem_dev, self._telem_sharding
                )

        don = lambda *idx: idx if self.donate else ()   # noqa: E731
        # admission: the fresh B=1 cache is donated into the prefill, and
        # the batched cache is donated into the jitted slot write — no host
        # round trip, no full-cache copy
        self._prefill1 = jax.jit(
            lambda p, b, c: M.prefill(cfg, p, b, c), donate_argnums=don(2)
        )
        if self.paged:
            # paged admission: bind the slot's page-table row, then scatter
            # the (dense, bucketed) B=1 prefill cache through it — one
            # jitted dispatch, same as the dense write
            def _wslot_paged(cache, c1, slot, table_row):
                cache = dict(
                    cache,
                    page_table=cache["page_table"].at[slot].set(table_row),
                )
                return M.write_slot(cfg, cache, c1, slot)

            self._write_slot_fn = jax.jit(_wslot_paged, donate_argnums=don(0))
        else:
            self._write_slot_fn = jax.jit(
                functools.partial(M.write_slot, cfg), donate_argnums=don(0)
            )

        def _admit(state, slot, ctx_row, last_logits, *samp):
            prior = jnp.float32(self._prior_alpha)
            W = state["hist"].shape[1]
            out = {
                "pending": state["pending"].at[slot].set(
                    jnp.argmax(last_logits[0], -1).astype(jnp.int32)
                ),
                "live": state["live"].at[slot].set(True),
                "ctx": state["ctx"].at[slot].set(ctx_row),
                "alpha": state["alpha"].at[slot].set(prior),
                "hist": state["hist"].at[slot].set(jnp.zeros((W,), jnp.float32)),
                "hist_n": state["hist_n"].at[slot].set(0),
                "hist_ptr": state["hist_ptr"].at[slot].set(0),
            }
            if samp:
                # sampled build: bind the request's (host-sampled) first
                # token, warp params and PRNG key row to the slot
                pend0, temp, topk, topp, key_row = samp
                out["pending"] = state["pending"].at[slot].set(pend0)
                out["temp"] = state["temp"].at[slot].set(temp)
                out["topk"] = state["topk"].at[slot].set(topk)
                out["topp"] = state["topp"].at[slot].set(topp)
                out["key"] = state["key"].at[slot].set(key_row)
            return out

        self._admit_fn = jax.jit(_admit, donate_argnums=don(0))

        self._admit_pf_fn = None
        if self.prefill_chunk:
            # enqueue-only admission: bind the table row, zero the slot's
            # position, park the prompt in the ctx row and arm the pf_*
            # counters — NO prefill dispatch, no B=1 cache, no model FLOPs;
            # the next fused round starts consuming the prompt in chunks
            def _admit_pf(cache, state, slot, ctx_row, pf_len, table_row,
                          *samp):
                cache = dict(
                    cache,
                    page_table=cache["page_table"].at[slot].set(table_row),
                    pos=cache["pos"].at[slot].set(0),
                )
                prior = jnp.float32(self._prior_alpha)
                W = state["hist"].shape[1]
                out = dict(
                    state,
                    # ctx_row[0] is a "safe" pending: the round prologue
                    # scatters pending at ctx[pos] for EVERY slot, so for a
                    # mid-prefill slot it must be a value no-op on the
                    # prompt (prefill_chunk_stage keeps the invariant)
                    pending=state["pending"].at[slot].set(ctx_row[0]),
                    live=state["live"].at[slot].set(True),
                    ctx=state["ctx"].at[slot].set(ctx_row),
                    alpha=state["alpha"].at[slot].set(prior),
                    hist=state["hist"].at[slot].set(
                        jnp.zeros((W,), jnp.float32)
                    ),
                    hist_n=state["hist_n"].at[slot].set(0),
                    hist_ptr=state["hist_ptr"].at[slot].set(0),
                    pf_done=state["pf_done"].at[slot].set(0),
                    pf_len=state["pf_len"].at[slot].set(pf_len),
                )
                if samp:
                    temp, topk, topp, key_row = samp
                    out["temp"] = state["temp"].at[slot].set(temp)
                    out["topk"] = state["topk"].at[slot].set(topk)
                    out["topp"] = state["topp"].at[slot].set(topp)
                    # the UNSPLIT request key: prefill_chunk_stage splits
                    # it when the prompt completes, reproducing the dense
                    # path's host-side admission split bit-for-bit
                    out["key"] = state["key"].at[slot].set(key_row)
                return cache, out

            self._admit_pf_fn = jax.jit(_admit_pf, donate_argnums=don(0, 1))

        # legacy (unfused) drafting path — kept for A/B benchmarking
        self._decode = jax.jit(
            lambda p, c, t, g: M.decode_step(cfg, p, c, t, gates=g)
        )
        self._verify = jax.jit(
            functools.partial(_verify_accept_commit, cfg), donate_argnums=don(1)
        )
        self._tree_verify = jax.jit(functools.partial(
            _tree_verify_accept_commit, cfg, attn_backend=attn_backend,
        ), donate_argnums=don(1))
        self._verify_sampled = None
        self._tree_verify_sampled = None
        if sampling is not None:
            # split/legacy verify with the stochastic accept fused in: the
            # slot keys are split into the round uniforms INSIDE the jitted
            # dispatch and the advanced keys return as device arrays — the
            # split round keeps its dispatch/sync counts exactly
            def _sverify(p, cache, pending, chains, have, live,
                         temp, topk, topp, key):
                key, u = round_uniforms(key, draft_k + 1)
                cache, n_chain, nxt = _verify_accept_commit_sampled(
                    cfg, p, cache, pending, chains, have, live,
                    temp, topk, topp, u,
                )
                return cache, n_chain, nxt, key

            self._verify_sampled = jax.jit(_sverify, donate_argnums=don(1))
            if self.tree_bucket:
                bucket = int(self.tree_bucket)

                def _stree_verify(p, cache, tok, par, dep, msk, cnt, live,
                                  temp, topk, topp, key):
                    key, u = round_uniforms(key, bucket)
                    cache, path, n_acc, bonus = (
                        _tree_verify_accept_commit_sampled(
                            cfg, p, cache, tok, par, dep, msk, cnt, live,
                            temp, topk, topp, u, attn_backend=attn_backend,
                        )
                    )
                    return cache, path, n_acc, bonus, key

                self._tree_verify_sampled = jax.jit(
                    _stree_verify, donate_argnums=don(1)
                )
        self._round_fn = None
        if self.round_mode == "single":
            pld_kw = {
                "max_ngram": self.pld.max_ngram, "min_ngram": self.pld.min_ngram,
            }
            # `sampled=True` is only passed on sampled builds so a greedy
            # build's round partial (and its trace) stays byte-identical
            samp_kw = {"sampled": True} if sampling is not None else {}
            if mode == "chain_fused":
                fn = functools.partial(
                    chain_round, cfg, draft_k=draft_k,
                    use_draft=draft_spec is not None, adaptive=adaptive,
                    min_obs=min_obs, t_min=float(t_min),
                    draft_kv=self.draft_kv, **pld_kw, **samp_kw,
                )
            else:
                fn = functools.partial(
                    tree_round, cfg, draft_k=draft_k,
                    expansions=tree_expansions, top_k=tree_top_k,
                    top_p=tree_top_p, bucket=self.tree_bucket,
                    pld_alpha=float(PLD_SPEC.prior_alpha),
                    use_draft=draft_spec is not None, adaptive=adaptive,
                    min_obs=min_obs, t_min=float(t_min),
                    draft_kv=self.draft_kv, attn_backend=attn_backend,
                    **pld_kw, **samp_kw,
                )
            if mesh is not None:
                # belt-and-braces on a mesh: pin the donated outputs to the
                # exact input placements at the jit boundary (concrete
                # NamedShardings work on every supported JAX, unlike the
                # abstract-mesh form), so the cache/state aliasing can never
                # be dropped by an output-sharding drift — the single
                # dispatch stays resharding-free between rounds
                inner_round = fn
                csh, ssh = self._cache_sharding, self._state_sharding

                def fn(p, cache, state, c, gates):
                    cache, state, out = inner_round(p, cache, state, c, gates)
                    cache = jax.tree.map(
                        jax.lax.with_sharding_constraint, cache, csh
                    )
                    state = jax.tree.map(
                        jax.lax.with_sharding_constraint, state, ssh
                    )
                    return cache, state, out

            # donate the cache AND the carried state: the commit scatter and
            # the state updates alias in place instead of copying the
            # largest live buffers every round
            if self.telemetry:
                # compose the telemetry accumulation INTO the round at the
                # jit boundary: the buffer rides the same dispatch (and the
                # same donation) as the cache/state, so the round stays ONE
                # dispatch with zero host syncs — proven on the compiled
                # HLO against the telemetry-off executable by
                # analysis.contracts.assert_telemetry_transparent
                inner_fn = fn
                tsh = self._telem_sharding

                def fn_t(p, cache, state, telem, c, gates):
                    live = state["live"]
                    cache, state, out = inner_fn(p, cache, state, c, gates)
                    telem = TM.accumulate_round(telem, out, live)
                    if tsh is not None:
                        telem = jax.tree.map(
                            jax.lax.with_sharding_constraint, telem, tsh
                        )
                    return cache, state, telem, out

                round_core, round_don = fn_t, don(1, 2, 3)
            else:
                round_core, round_don = fn, don(1, 2)
            if self.prefill_chunk:
                # chunked prefill rides the SAME dispatch, outermost: first
                # consume up to `prefill_chunk` pending prompt tokens per
                # slot, then run the decode round with mid-prefill slots
                # masked dead — the speculative machinery skips them and
                # telemetry credits them no decode rounds. Their real live
                # bit is restored on the way out.
                inner_core = round_core
                chunk = int(self.prefill_chunk)
                pf_sampled = sampling is not None

                def round_core(p, cache, state, *rest):
                    cache, state = prefill_chunk_stage(
                        cfg, p, cache, state, chunk=chunk, sampled=pf_sampled
                    )
                    live0 = state["live"]
                    state = dict(
                        state,
                        live=live0 & (state["pf_done"] >= state["pf_len"]),
                    )
                    outs = inner_core(p, cache, state, *rest)
                    state2 = dict(outs[1], live=live0)
                    return (outs[0], state2) + tuple(outs[2:])

            self._round_fn = jax.jit(round_core, donate_argnums=round_don)
        self._rescore_verify_fns: Dict[int, Callable] = {}
        self._draft_fns: Dict[int, Callable] = {}   # scan steps -> jitted fn
        self._tree_draft_fns: Dict[int, Callable] = {}   # expansions -> jitted fn
        self._casc_draft_fns: Dict[int, Callable] = {}   # expansions -> jitted fn
        self._rescore_fns: Dict[int, Callable] = {}      # level index -> jitted fn
        self._gates = (
            None
            if draft_spec is None
            else jnp.asarray(draft_spec.gates_array(cfg.num_layers))
        )
        if mesh is not None and self._gates is not None:
            self._gates = jax.device_put(self._gates, self._replicated)
        self._level_gates: Dict[int, Optional[jax.Array]] = {}
        if self.bank is not None:
            for lvl in self.bank.levels:
                g = None if lvl.gates is None else jnp.asarray(lvl.gates)
                if mesh is not None and g is not None:
                    g = jax.device_put(g, self._replicated)
                self._level_gates[lvl.index] = g
        # the legacy stats facade: same keys (incl. the round-pipeline
        # accounting — jitted dispatches per round, block_until_ready
        # events, host wall time blocked on device results), same integer
        # semantics, now backed by registry counters (telemetry
        # .STATS_METRICS) so pinned test reads and the /metrics endpoint
        # can never drift apart
        self.stats: TM.StatsView = TM.StatsView(self.metrics)

    # ------------------------------------------------------------ admission
    def add_request(
        self, slot: int, prompt: np.ndarray,
        sampling: Optional[SamplingParams] = None,
        max_new_tokens: Optional[int] = None,
    ) -> None:
        """Prefill one prompt into a batch slot.

        ``max_new_tokens`` (paged builds) bounds the slot's KV page
        allocation to prompt + budget + round slack instead of the full
        ``max_len`` reservation — the HBM win paging exists for; dense
        builds ignore it. On ``prefill_chunk`` builds admission is
        ENQUEUE-ONLY: no prefill dispatch runs here at all — the prompt is
        parked in the slot's context row and the next fused round starts
        consuming it ``prefill_chunk`` tokens at a time alongside the
        decoding slots (docs/paging.md).

        ``sampling`` overrides the server build's default ``SamplingParams``
        for this request (sampled builds only — a stochastic request on a
        greedy build raises, since the greedy executables cannot honor it;
        ``temperature=0`` overrides are accepted anywhere and stay
        token-identical to greedy). On sampled builds the request's FIRST
        token is drawn host-side from the warped prefill distribution
        (admission is already a sync point) and its slot PRNG stream is
        seeded from ``sampling.seed`` or derived from the server's base
        seed and the admission counter.

        The fresh B=1 cache is donated into the prefill dispatch and the
        batched cache into one jitted dynamic-update (``models.model
        .write_slot``) — admission never round-trips cache buffers through
        the host. In pipelined single mode, any in-flight rounds are drained
        first (sync-on-admit) and whatever the RE-BOUND slot had buffered is
        discarded: those tokens belong to the previous request and can no
        longer be attributed once the slot is re-bound. Call ``flush()``
        before re-binding to collect them — ``ServeLoop`` drains and routes
        under the old mapping before every admission, so it never loses
        any."""
        if (sampling is not None and not sampling.greedy
                and self.sampling is None):
            raise ValueError(
                "stochastic per-request sampling requires a sampled server "
                "build — construct BatchedSpecServer(..., sampling="
                "SamplingParams(...)); this greedy build compiled only the "
                "greedy round executables"
            )
        if self._inflight:
            self._drain()
        dropped = self._out_buf.pop(slot, None)
        if dropped:
            # tokens committed for the PREVIOUS binding of this slot that
            # no caller collected before re-binding: counted so drained
            # telemetry reconciles exactly with routed request streams
            # (tests/test_telemetry.py)
            self.metrics.counter("serve_discarded_tokens_total").inc(
                len(dropped)
            )
        prompt = np.asarray(prompt, np.int32)
        table_row = None
        if self.paged:
            alloc = (
                self.max_len if max_new_tokens is None
                else min(
                    self.max_len,
                    len(prompt) + int(max_new_tokens) + self._alloc_slack(),
                )
            )
            table_row = self._alloc_pages(slot, alloc)
        if self.prefill_chunk:
            self._admit_chunked(slot, prompt, table_row, sampling)
            return
        # admission prefill at the prompt's padded power-of-two bucket, not
        # max_len — write_slot places the short cache into the batched one
        # (dense: dynamic_update_slice; paged: table scatter) and positions
        # past the prompt stay invisible via kv_pos masking
        bucket = min(_prefill_bucket(len(prompt)), self.max_len)
        c1 = M.init_cache(self.cfg, 1, bucket, dtype=jnp.dtype(self.cfg.dtype))
        if self.mesh is not None:
            # B=1 prefill cache: batch can't shard, but layout must match the
            # sharded weights it is written from (TP head placement)
            c1 = jax.device_put(c1, self._c1_sharding)
        last, c1 = self._prefill1(self.params, {"tokens": jnp.asarray(prompt[None])}, c1)
        slot_d = jnp.asarray(slot, jnp.int32)
        if self.paged:
            self.cache = self._write_slot_fn(
                self.cache, c1, slot_d, jnp.asarray(table_row)
            )
        else:
            self.cache = self._write_slot_fn(self.cache, c1, slot_d)
        # device round state: pending/live/context row + a fresh Eq. 4
        # estimator seeded with the draft's cold-start prior
        row = np.zeros(self.max_len, np.int32)
        row[: len(prompt)] = prompt
        samp_args = ()
        first: Optional[int] = None
        if self.sampling is not None:
            eff = sampling if sampling is not None else self.sampling
            if eff.seed is not None:
                key = jax.random.PRNGKey(eff.seed)
            else:
                key = jax.random.fold_in(self._base_key, self._admit_seq)
            self._admit_seq += 1
            # the request's FIRST token is sampled from the warped prefill
            # distribution right here — admission is already a host sync
            # point — with the same inverse-CDF rule the device uses; the
            # consumed subkey advances the slot stream like a round split
            key, sub = jax.random.split(key)
            u0 = float(jax.random.uniform(sub))
            q0 = warp_probs(
                np.asarray(last)[0], eff.temperature, eff.top_k, eff.top_p
            )
            cum = np.cumsum(q0)
            first = int(np.argmax(cum > u0 * cum[-1]))
            samp_args = (
                jnp.asarray(first, jnp.int32),
                jnp.asarray(max(eff.temperature, 0.0), jnp.float32),
                jnp.asarray(eff.top_k, jnp.int32),
                jnp.asarray(eff.top_p, jnp.float32),
                key,
            )
            if not eff.greedy:
                self.metrics.counter("serve_sampled_requests_total").inc()
        self.dstate = self._admit_fn(
            self.dstate, slot_d, jnp.asarray(row), last, *samp_args
        )
        # host mirrors (split/legacy/cascade rounds + inspection)
        self.pending[slot] = (
            int(np.argmax(np.asarray(last)[0])) if first is None else first
        )
        self.contexts[slot] = [int(t) for t in prompt]
        self.live[slot] = True
        # slot estimators restart with the draft's cold-start prior —
        # continuous batching reuses slots across unrelated requests
        prior = self.draft_spec.prior_alpha if self.draft_spec else 0.5
        self.acceptance.reset(self._slot_key(slot), alpha0=prior)
        if self.bank is not None:
            for i in range(len(self.bank)):
                self.acceptance.reset(
                    self.bank.slot_key(i, slot), alpha0=self.bank.alpha_prior(i)
                )
            self.acceptance.reset(
                self.bank.direct_key(slot), alpha0=self.bank.direct_prior()
            )

    def _admit_chunked(
        self, slot: int, prompt: np.ndarray,
        table_row: np.ndarray, sampling: Optional[SamplingParams],
    ) -> None:
        """Enqueue-only admission (``prefill_chunk`` builds): one jitted
        state/table bind and the host loop moves on — the prompt prefills
        inside the next fused round dispatches."""
        row = np.zeros(self.max_len, np.int32)
        row[: len(prompt)] = prompt
        samp_args = ()
        if self.sampling is not None:
            eff = sampling if sampling is not None else self.sampling
            if eff.seed is not None:
                key = jax.random.PRNGKey(eff.seed)
            else:
                key = jax.random.fold_in(self._base_key, self._admit_seq)
            self._admit_seq += 1
            samp_args = (
                jnp.asarray(max(eff.temperature, 0.0), jnp.float32),
                jnp.asarray(eff.top_k, jnp.int32),
                jnp.asarray(eff.top_p, jnp.float32),
                key,    # unsplit: the completing round splits it in-dispatch
            )
            if not eff.greedy:
                self.metrics.counter("serve_sampled_requests_total").inc()
        slot_d = jnp.asarray(slot, jnp.int32)
        self.cache, self.dstate = self._admit_pf_fn(
            self.cache, self.dstate, slot_d, jnp.asarray(row),
            jnp.asarray(len(prompt), jnp.int32), jnp.asarray(table_row),
            *samp_args,
        )
        # host mirrors: pending is unknown until the prompt finishes
        # prefilling in-round; chunked builds are single-mode only, so the
        # mirror is purely informational
        self.pending[slot] = int(prompt[-1])
        self.contexts[slot] = [int(t) for t in prompt]
        self.live[slot] = True
        prior = self.draft_spec.prior_alpha if self.draft_spec else 0.5
        self.acceptance.reset(self._slot_key(slot), alpha0=prior)

    # -------------------------------------------------- page pool (paged)
    def _alloc_slack(self) -> int:
        """Worst-case commit overshoot past ``max_new_tokens``: pipelined
        rounds in flight when the finish is observed keep committing."""
        per_round = self.tree_bucket or (self.k + 1)
        return (self.sync_every + 1) * per_round

    def _alloc_pages(self, slot: int, n_tokens: int) -> np.ndarray:
        """Reserve pool pages covering ``n_tokens`` for a slot; returns the
        slot's full table row (-1 padded past the allocation)."""
        need = min(
            -(-int(n_tokens) // self.page_size), self._pages_per_slot
        )
        self._free_slot_pages(slot)
        if need > len(self._free_pages):
            raise RuntimeError(
                f"KV page pool exhausted: slot {slot} needs {need} pages, "
                f"{len(self._free_pages)} free — raise num_pages or admit "
                "fewer/shorter concurrent requests"
            )
        pages = [self._free_pages.pop() for _ in range(need)]
        self._slot_pages[slot] = pages
        self.metrics.gauge("serve_free_pages").set(len(self._free_pages))
        row = np.full(self._pages_per_slot, -1, np.int32)
        row[:need] = pages
        return row

    def _free_slot_pages(self, slot: int) -> None:
        pages = self._slot_pages.pop(slot, None)
        if pages:
            self._free_pages.extend(pages)
            self.metrics.gauge("serve_free_pages").set(len(self._free_pages))

    def release(self, slot: int) -> None:
        """Mark a slot free (its request finished or was cancelled)."""
        self.live[slot] = False
        upd = dict(
            self.dstate, live=self.dstate["live"].at[slot].set(False)
        )
        if self.prefill_chunk:
            # a request cancelled mid-prefill must stop consuming chunks
            upd["pf_len"] = self.dstate["pf_len"].at[slot].set(0)
            upd["pf_done"] = self.dstate["pf_done"].at[slot].set(0)
        self.dstate = upd
        if self.paged:
            # host-side free at an existing sync point; the stale device
            # table row is harmless (a dead slot never commits — its writes
            # carry the out-of-pool sentinel page) and the row is re-bound
            # at the slot's next admission
            self._free_slot_pages(slot)

    def _slot_key(self, slot: int) -> str:
        return f"chain:{slot}"

    # ----------------------------------------------------- adaptive lengths
    def _slot_limit(self, slot: int) -> int:
        """Neural draft budget for a slot this round (PLD is never capped).

        In single round mode this is an inspection mirror of the on-device
        Eq. 5 selection (the round computes budgets from the carried state
        arrays itself); split rounds compute it here from the host trackers."""
        if self.draft_spec is None:
            return 0
        if self.round_mode == "single":
            if not self.adaptive or int(self.dstate["hist_n"][slot]) < self.min_obs:
                return self.k
            alpha = float(self.dstate["alpha"][slot])
            return best_chain_length(
                alpha, float(self._c_dev), self.k, self.t_min
            )
        key = self._slot_key(slot)
        if not self.adaptive or self.acceptance.counts(key) < self.min_obs:
            return self.k
        alpha = self.acceptance.alpha(key)
        c = self.costs.c_hat(
            "chain_draft", default=float(self.draft_spec.prior_c)
        )
        return best_chain_length(alpha, max(c, 1e-3), self.k, self.t_min)

    def _slot_tree_budget(self, slot: int) -> int:
        """Tree expansion budget for a slot this round (Eq. 5 objective).
        Single round mode: inspection mirror of the on-device selection."""
        if self.draft_spec is None:
            return 0
        if self.round_mode == "single":
            if not self.adaptive or int(self.dstate["hist_n"][slot]) < self.min_obs:
                return self.tree_expansions
            alpha = float(self.dstate["alpha"][slot])
            return best_tree_expansions(
                alpha, float(self._c_dev), self.tree_expansions, self.t_min
            )
        key = self._slot_key(slot)
        if not self.adaptive or self.acceptance.counts(key) < self.min_obs:
            return self.tree_expansions
        alpha = self.acceptance.alpha(key)
        c = self.costs.c_hat(
            "tree_draft", default=float(self.draft_spec.prior_c)
        )
        return best_tree_expansions(
            alpha, max(c, 1e-3), self.tree_expansions, self.t_min
        )

    def _draft_fn(self, steps: int):
        fn = self._draft_fns.get(steps)
        if fn is None:
            fn = jax.jit(functools.partial(
                chain_draft_scan, self.cfg, steps, draft_kv=self.draft_kv,
            ))
            self._draft_fns[steps] = fn
        return fn

    def _tree_draft_fn(self, expansions: int):
        fn = self._tree_draft_fns.get(expansions)
        if fn is None:
            fn = jax.jit(functools.partial(
                tree_draft_scan, self.cfg, expansions, self.tree_top_k,
                top_p=self.tree_top_p, draft_kv=self.draft_kv,
            ))
            self._tree_draft_fns[expansions] = fn
        return fn

    def _casc_draft_fn(self, expansions: int):
        """The cascade's drafting scan: ``tree_draft_scan`` bound to the
        CHEAPEST bank level's static execution (quantize/attn_override);
        its params/gates arrive as call arguments."""
        fn = self._casc_draft_fns.get(expansions)
        if fn is None:
            drafter = self.bank.drafter
            fn = jax.jit(functools.partial(
                tree_draft_scan, self.cfg, expansions, self.tree_top_k,
                top_p=self.tree_top_p, quantize=drafter.quantize,
                attn_override=drafter.attn_override, draft_kv=self.draft_kv,
            ))
            self._casc_draft_fns[expansions] = fn
        return fn

    def _rescore_fn(self, level: int):
        """One jitted intermediate-verify dispatch for bank level
        ``level`` (Alg. 1 level-to-level acceptance)."""
        fn = self._rescore_fns.get(level)
        if fn is None:
            lvl = self.bank.levels[level]
            base = functools.partial(
                cascade_rescore, self.cfg, quantize=lvl.quantize,
                attn_override=lvl.attn_override,
                attn_backend=self.attn_backend,
            )
            if self.sampling is not None:
                inner = base

                def base(lp, cache, tk, pr, dp, pa, mk, ct, probe, apply,
                         alphas, gates, temp, topk, topp, key):
                    # stochastic level-to-level rescore: the slot keys split
                    # in-dispatch into the N endorse draws + hedge +
                    # extension uniforms; advanced keys come back last
                    key, u = round_uniforms(key, tk.shape[1] + 2)
                    out = inner(lp, cache, tk, pr, dp, pa, mk, ct, probe,
                                apply, alphas, gates,
                                sampling=(temp, topk, topp, u))
                    return out + (key,)

            fn = jax.jit(base)
            self._rescore_fns[level] = fn
        return fn

    def _rescore_verify_fn(self, level: int):
        """The LAST rescore dispatch with the target verify folded in
        (``core.engine.cascade_rescore_verify``): the strongest level's
        intermediate verify and the target's verify + commit ride one
        jitted call, with the cache donated so the commit aliases in
        place — an L-level cascade round stays at L dispatches."""
        fn = self._rescore_verify_fns.get(level)
        if fn is None:
            lvl = self.bank.levels[level]
            base = functools.partial(
                cascade_rescore_verify, self.cfg, quantize=lvl.quantize,
                attn_override=lvl.attn_override,
                attn_backend=self.attn_backend,
            )
            if self.sampling is not None:
                # forward the trailing (temp, top_k, top_p, key) as the
                # fused call's sampling tuple; the keys split in-dispatch
                # (2N+2 uniforms: stochastic rescore + stochastic walk) and
                # the 13-tuple grows a trailing new_key output
                inner_rv = base

                def base(lp, p, cache, tk, pr, dp, pa, mk, ct, probe, apply,
                         alphas, gates, live, temp, topk, topp, key):
                    return inner_rv(lp, p, cache, tk, pr, dp, pa, mk, ct,
                                    probe, apply, alphas, gates, live,
                                    sampling=(temp, topk, topp, key))
            if self.telemetry:
                # the telemetry buffer rides the cascade's FINAL (donated)
                # dispatch: the per-slot tallies, routing rows, and THIS
                # dispatch's Eq. 4 verdict (level ``index + 1``'s first
                # token) accumulate inside the same executable — the
                # bounded L-dispatch round stays L dispatches. Verdicts of
                # intermediate rescorers and of the target (row 0) are
                # host-mirrored by _step_cascade from arrays it already
                # materializes.
                bank = self.bank
                rescorer_rows = tuple(lv.index for lv in bank.rescorers)
                drafter_row = bank.drafter.index
                obs_row = lvl.index + 1
                tsh = self._telem_sharding

                def wrapped(lp, p, cache, tk, pr, dp, pa, mk, ct, probe,
                            apply, alphas, gates, live, telem, pld_have,
                            budget, *samp):
                    # *samp = (temp, topk, topp, key) on sampled builds —
                    # appended after the telemetry args so the greedy
                    # signature (and its trace) is untouched
                    out = base(lp, p, cache, tk, pr, dp, pa, mk, ct, probe,
                               apply, alphas, gates, live, *samp)
                    # out[5]=count, out[7]=probe_ok, out[8]=probe_valid,
                    # out[11]=n_acc (see cascade_rescore_verify)
                    telem = TM.accumulate_cascade(
                        telem, live=live, n_acc=out[11], count=out[5],
                        pld_have=pld_have, budget=budget, routed=apply,
                        probe_ok=out[7], probe_valid=out[8],
                        rescorer_rows=rescorer_rows,
                        drafter_row=drafter_row, obs_row=obs_row,
                    )
                    if tsh is not None:
                        telem = jax.tree.map(
                            jax.lax.with_sharding_constraint, telem, tsh
                        )
                    return out + (telem,)

                fn = jax.jit(
                    wrapped, donate_argnums=(2, 14) if self.donate else ()
                )
            else:
                fn = jax.jit(base, donate_argnums=(2,) if self.donate else ())
            self._rescore_verify_fns[level] = fn
        return fn

    # ------------------------------------------------- dispatch contracts
    def expected_dispatches_per_round(self) -> int:
        """Jitted dispatches a fully-drafting steady-state round performs —
        the static claim the runtime ``round_dispatches``/
        ``draft_dispatches``/``rescore_dispatches`` counters and the
        compiled contracts (``analysis.contracts``) are both held to.

        single:  1 (THE fused round executable)
        split:   2 (draft scan + verify), 1 with no neural drafter
        legacy:  draft_k decode dispatches + 1 verify
        cascade: L = 1 drafting scan + (L-1) rescores, target verify folded
                 into the last rescore (the paper's <= L+1 bound, met with
                 room to spare); a 1-level bank is drafting scan + verify.
        """
        if self.round_mode == "single":
            return 1
        if self.mode == "legacy":
            return (self.k if self.draft_spec is not None else 0) + 1
        if self.mode == "cascade_fused":
            return max(len(self.bank), 2)
        return 2 if self.draft_spec is not None else 1

    def round_executables(self) -> Dict[str, Tuple[Callable, tuple]]:
        """Every jitted executable a steady-state round dispatches, as
        ``{name: (jitted_fn, example_args)}`` ready for ``.lower()`` —
        the input ``analysis.contracts.server_round_contracts`` compiles
        and checks. Example args mirror the live call sites (lowering never
        executes, so handing over donated buffers is safe)."""
        B, k = self.B, self.k
        toks_i = jnp.zeros((B,), jnp.int32)
        chains = jnp.zeros((B, k), jnp.int32)
        live = jnp.zeros((B,), bool)
        # sampled builds: the trailing (temp, topk, topp, key) every
        # sampled split/cascade dispatch takes (single mode carries them
        # inside dstate, so its entry needs nothing extra)
        samp_ex = ()
        if self.sampling is not None:
            samp_ex = (
                jnp.ones((B,), jnp.float32), jnp.zeros((B,), jnp.int32),
                jnp.ones((B,), jnp.float32), jnp.zeros((B, 2), jnp.uint32),
            )
        if self.round_mode == "single":
            if self.telemetry:
                return {"round": (self._round_fn, (
                    self.params, self.cache, self.dstate, self._telem_dev,
                    self._c_dev, self._gates,
                ))}
            return {"round": (self._round_fn, (
                self.params, self.cache, self.dstate, self._c_dev, self._gates
            ))}
        verify_args = (self.params, self.cache, toks_i, chains, toks_i, live)
        verify_entry = (
            (self._verify_sampled, verify_args + samp_ex)
            if self.sampling is not None else (self._verify, verify_args)
        )
        if self.mode == "legacy":
            out = {"decode": (self._decode, (
                self.params, self.cache, jnp.zeros((B, 1), jnp.int32),
                self._gates,
            ))}
            out["verify"] = verify_entry
            return out
        if self.mode == "chain_fused":
            out = {}
            if self.draft_spec is not None:
                out["chain_draft"] = (self._draft_fn(k), (
                    self.params, self.cache, toks_i, chains, toks_i,
                    jnp.full((B,), k, jnp.int32), self._gates,
                ))
            out["verify"] = verify_entry
            return out
        # tree_fused / cascade_fused (split): a seeded padded tree
        from repro.core.tree import tree_seed_arrays as _seed

        seed = _seed(np.zeros(B, np.int32), np.zeros((B, k), np.int32),
                     np.zeros(B, np.int32), self.tree_bucket, pld_alpha=0.5)
        tree = tuple(jnp.asarray(a) for a in seed)
        tok, par, dep, pac, msk, cnt = tree
        scal = (jnp.zeros((B,), jnp.int32), jnp.zeros((B,), jnp.float32),
                jnp.asarray(0.5, jnp.float32),
                jnp.asarray(self.t_min, jnp.float32))
        tv_args = (self.params, self.cache, tok, par, dep, msk, cnt, live)
        tv_entry = (
            (self._tree_verify_sampled, tv_args + samp_ex)
            if self.sampling is not None else (self._tree_verify, tv_args)
        )
        if self.mode == "tree_fused":
            out = {}
            if self.draft_spec is not None:
                out["tree_draft"] = (
                    self._tree_draft_fn(self.tree_expansions),
                    (self.params, self.cache) + tree + scal + (self._gates,),
                )
            out["tree_verify"] = tv_entry
            return out
        bank = self.bank
        probe = jnp.full((B,), -1, jnp.int32)
        apply = jnp.zeros((B,), bool)
        alphas = jnp.full((B,), 0.5, jnp.float32)
        out = {"cascade_draft": (
            self._casc_draft_fn(self.tree_expansions),
            (bank.drafter.params, self.cache) + tree + scal
            + (self._level_gates[bank.drafter.index],),
        )}
        if bank.rescorers:
            for lvl in bank.rescorers[:-1]:
                out[f"rescore_l{lvl.index}"] = (self._rescore_fn(lvl.index), (
                    lvl.params, self.cache) + tree
                    + (probe, apply, alphas, self._level_gates[lvl.index])
                    + samp_ex,
                )
            last = bank.rescorers[-1]
            telem_args = (
                (self._telem_dev, toks_i, toks_i) if self.telemetry else ()
            )
            out["rescore_verify"] = (self._rescore_verify_fn(last.index), (
                last.params, self.params, self.cache) + tree
                + (probe, apply, alphas, self._level_gates[last.index], live)
                + telem_args + samp_ex,
            )
        else:
            out["tree_verify"] = tv_entry
        return out

    # ------------------------------------------------------------- stepping
    def _pld_chains(self):
        """Per-slot PLD proposals (B, k) — free host-side retrieval drafts.

        Also records where PLD ends per slot: the acceptance estimator that
        prices the NEURAL draft must only see neural-token outcomes."""
        chains = np.zeros((self.B, self.k), np.int32)
        have = np.zeros(self.B, np.int32)
        for b in range(self.B):
            if not self.live[b]:
                continue
            ctx = np.asarray(self.contexts[b] + [int(self.pending[b])], np.int64)
            toks = self.pld.propose(ctx, self.k)
            chains[b, : len(toks)] = toks
            have[b] = len(toks)
        self._pld_have = have.copy()
        return chains, have

    def _propose(self):
        """Per-slot draft chains (B, k) — PLD first, neural fill-in.

        Returns (chains (B,k) int32, have (B,) int32). The neural fill-in is
        a single fused scan dispatch covering every slot and draft step."""
        chains, have = self._pld_chains()
        limit = np.zeros(self.B, np.int32)
        for b in range(self.B):
            if self.live[b]:
                limit[b] = self._slot_limit(b)
        self._last_limit = limit.copy()   # split-round telemetry (budget_hist)
        if self.draft_spec is None:
            return chains, have
        if self.fused:
            return self._propose_fused(chains, have, limit)
        return self._propose_legacy(chains, have, limit)

    def _propose_fused(self, chains, have, limit):
        # one jitted lax.scan over draft steps; trip count = the largest
        # per-slot budget still needing neural fill (<= k distinct compiles)
        steps = int(np.max(np.where(limit > have, limit, 0), initial=0))
        if steps == 0:
            return chains, have
        t0 = time.perf_counter()
        ch_d, hv_d = jax.block_until_ready(
            self._draft_fn(steps)(
                self.params, self.cache,
                jnp.asarray(self.pending, jnp.int32),
                jnp.asarray(chains), jnp.asarray(have), jnp.asarray(limit),
                self._gates,
            )
        )
        dt = time.perf_counter() - t0
        chains, have = np.asarray(ch_d), np.asarray(hv_d)
        self.stats["draft_dispatches"] += 1
        self.stats["draft_time"] += dt
        self.stats["host_syncs"] += 1
        self.stats["device_wait"] += dt
        self.stats["drafted_tokens"] += steps
        # per-draft-step latency (the whole batch advances one token per
        # step) -> c_hat = draft-step / verify-round, the c in T_SD
        self.costs.observe("chain_draft", dt, tokens=steps)
        return chains, have

    def _propose_legacy(self, chains, have, limit):
        # seed behavior: one _decode dispatch per draft step, host syncs
        # between steps (kept only as the A/B baseline for benchmarks)
        need = self.live & (limit > have)
        if not need.any():
            return chains, have
        lo, hi = int(have[need].min()), int(limit[need].max())
        for j in range(lo, hi):
            toks = np.concatenate(
                [self.pending[:, None], chains[:, :j]], axis=1
            ).astype(np.int32)
            t0 = time.perf_counter()
            logits, _ = self._decode(
                self.params, self.cache, jnp.asarray(toks), self._gates
            )
            nxt = np.asarray(jnp.argmax(logits[:, -1], -1))
            dt = time.perf_counter() - t0
            self.stats["draft_dispatches"] += 1
            self.stats["draft_time"] += dt
            self.stats["host_syncs"] += 1
            self.stats["device_wait"] += dt
            fill = (have <= j) & (j < limit)
            chains[fill, j] = nxt[fill]
            have = np.maximum(have, np.where(fill, j + 1, have)).astype(np.int32)
        return chains, have

    def _host_round_telemetry(self, n_acc, drafted, pld_have, budget) -> None:
        """Accumulate ONE host-synced round into the numpy telemetry twin
        (``telemetry_schema`` layout). Split/legacy/tree/cascade rounds
        materialize these arrays anyway for their Eq. 4 bookkeeping, so
        mirroring them costs no extra device traffic — the device-carried
        buffer is reserved for the single-dispatch rounds that have no sync
        to piggyback on."""
        th = self._telem_host
        li = self.live.astype(np.int32)
        th["rounds"] += li
        th["accepted"] += np.asarray(n_acc, np.int32) * li
        th["drafted"] += np.asarray(drafted, np.int32) * li
        th["pld_tokens"] += np.asarray(pld_have, np.int32) * li
        th["pld_hit_rounds"] += (
            (np.asarray(pld_have) > 0) & self.live
        ).astype(np.int32)
        K1 = th["budget_hist"].shape[1]
        th["budget_hist"][
            np.arange(self.B), np.clip(np.asarray(budget), 0, K1 - 1)
        ] += li

    # ------------------------------------------------- pipelined single rounds
    def _drain(self) -> None:
        """Block once on every in-flight round's outputs (they are usually
        already resolved — later rounds were dispatched behind them) and
        fold their accepted tokens into the output buffer, in round order."""
        if not self._inflight:
            return
        outs, self._inflight = self._inflight, []
        t0 = time.perf_counter()
        jax.block_until_ready([o["n_acc"] for o in outs])
        self.stats["host_syncs"] += 1
        self.stats["device_wait"] += time.perf_counter() - t0
        for o in outs:
            acc, n_acc = np.asarray(o["acc"]), np.asarray(o["n_acc"])
            self.stats["drafted_tokens"] += int(np.asarray(o["drafted"]).sum())
            for b in range(self.B):
                nb = int(n_acc[b])
                if nb:
                    self._out_buf.setdefault(b, []).extend(
                        int(t) for t in acc[b, :nb]
                    )
                    self.stats["tokens"] += nb

    def flush(self) -> Dict[int, List[int]]:
        """Drain every in-flight round and return the buffered tokens per
        slot. The pipelined loop calls this every ``sync_every`` rounds and
        before re-binding a slot (admission/retire); split rounds have
        nothing in flight and this is a cheap no-op."""
        self._drain()
        self._drain_telemetry()
        out, self._out_buf = self._out_buf, {}
        return out

    def _drain_telemetry(self) -> None:
        """Fold NEW (since the last drain) telemetry counts into the
        registry. Callers guarantee nothing is in flight (``_drain`` ran),
        so the device buffer belongs to a completed round — reading it is a
        plain D2H copy of resolved arrays, never a new host sync (the
        runtime ``host_syncs`` parity with telemetry off is pinned by
        tests/test_telemetry.py)."""
        totals = TM.merge_totals(self._telem_dev, self._telem_host)
        delta = {k: v - self._telem_seen[k] for k, v in totals.items()}
        self._telem_seen = totals
        TM.fold_telemetry(self.metrics, delta)

    def telemetry_totals(self) -> Dict[str, np.ndarray]:
        """Cumulative drained telemetry (device buffer + host twin), keyed
        by the ``telemetry_schema`` names. Drains in-flight rounds first
        (their tokens stay buffered for the next ``flush``)."""
        self._drain()
        self._drain_telemetry()
        return {k: v.copy() for k, v in self._telem_seen.items()}

    def metrics_summary(self) -> Dict[str, Any]:
        """One JSON-able end-of-run summary sourced from the registry and
        the drained telemetry: tokens/step, dispatch/sync accounting, and
        per-level cascade acceptance — what launch/serve.py prints as its
        machine-readable final line."""
        tot = self.telemetry_totals()
        s = self.stats
        steps = max(s["steps"], 1)
        out: Dict[str, Any] = {
            "mode": self.mode,
            "round_mode": self.round_mode,
            "rounds": s["steps"],
            "tokens": s["tokens"],
            "tokens_per_step": s["tokens"] / steps,
            "round_dispatches": s["round_dispatches"],
            "host_syncs": s["host_syncs"],
            "device_wait_s": s["device_wait"],
            "rounds_per_slot": tot["rounds"].tolist(),
            "accepted_per_slot": tot["accepted"].tolist(),
            "drafted_per_slot": tot["drafted"].tolist(),
            "pld_tokens_per_slot": tot["pld_tokens"].tolist(),
        }
        # accept-rate telemetry (meaningful for greedy AND sampled runs;
        # the sampled CI leg pins that sampling reports them): mean tokens
        # committed per round, and the fraction of PROPOSED (PLD + neural)
        # tokens the verify accepted — the always-emitted pending/bonus
        # token is excluded from the numerator
        out["sampled"] = self.sampling is not None
        rounds_t = float(tot["rounds"].sum())
        acc_t = float(tot["accepted"].sum())
        prop_t = float(tot["drafted"].sum() + tot["pld_tokens"].sum())
        out["accepted_per_round"] = acc_t / rounds_t if rounds_t else None
        out["spec_accept_rate"] = (
            (acc_t - rounds_t) / prop_t if prop_t > 0 else None
        )
        if "casc_obs" in tot:
            obs = tot["casc_obs"].sum(axis=1)
            acc = tot["casc_accept"].sum(axis=1)
            out["cascade_acceptance"] = [
                (float(a) / float(o) if o else None)
                for a, o in zip(acc.tolist(), obs.tolist())
            ]
            out["cascade_routed_rounds"] = (
                tot["casc_routed"].sum(axis=1).tolist()
            )
        return out

    def _step_single(self) -> Dict[int, List[int]]:
        """One fused round: dispatch the single jitted round executable and
        return immediately — accepted tokens are drained from already-
        resolved device futures every ``sync_every`` rounds, so the device
        never waits for the host between rounds."""
        if self.telemetry:
            # the donated buffer is re-bound in the same statement, like
            # the cache/state (REPRO002) — accumulation happened inside
            # the one round dispatch
            self.cache, self.dstate, self._telem_dev, out = self._round_fn(
                self.params, self.cache, self.dstate, self._telem_dev,
                self._c_dev, self._gates,
            )
        else:
            self.cache, self.dstate, out = self._round_fn(
                self.params, self.cache, self.dstate, self._c_dev, self._gates
            )
        self._inflight.append(out)
        self.stats["steps"] += 1
        self.stats["round_dispatches"] += 1
        self.stats["target_calls"] += 1
        if len(self._inflight) >= self.sync_every:
            return self.flush()
        if self._out_buf:    # drained out-of-band (e.g. by an admission)
            out_b, self._out_buf = self._out_buf, {}
            return out_b
        return {}

    def step(self) -> Dict[int, List[int]]:
        """One speculative round for the whole batch; returns new tokens
        (in pipelined single mode: the tokens drained *so far* — possibly
        from earlier rounds, possibly empty between sync points)."""
        if self.round_mode == "single":
            return self._step_single()
        if self.mode == "tree_fused":
            return self._step_tree()
        if self.mode == "cascade_fused":
            return self._step_cascade()
        chains, have = self._propose()
        t0 = time.perf_counter()
        if self.sampling is not None:
            ds = self.dstate
            new_cache, n_chain, new_pending, new_key = jax.block_until_ready(
                self._verify_sampled(
                    self.params, self.cache,
                    jnp.asarray(self.pending, jnp.int32),
                    jnp.asarray(chains), jnp.asarray(have),
                    jnp.asarray(self.live),
                    ds["temp"], ds["topk"], ds["topp"], ds["key"],
                )
            )
            self.dstate = dict(ds, key=new_key)
        else:
            new_cache, _, n_chain, new_pending = jax.block_until_ready(
                self._verify(
                    self.params, self.cache,
                    jnp.asarray(self.pending, jnp.int32),
                    jnp.asarray(chains), jnp.asarray(have),
                    jnp.asarray(self.live),
                )
            )
        dt = time.perf_counter() - t0
        self.stats["host_syncs"] += 1
        self.stats["device_wait"] += dt
        self.cache = new_cache
        self.stats["target_calls"] += 1
        self.stats["verify_time"] += dt
        self.costs.observe_target(dt, tokens=1)   # per-round target latency

        n_chain = np.asarray(n_chain)
        new_pending = np.asarray(new_pending)
        out: Dict[int, List[int]] = {}
        for b in range(self.B):
            if not self.live[b]:
                continue
            acc = [int(self.pending[b])] + [int(t) for t in chains[b, : n_chain[b]]]
            self.contexts[b].extend(acc)
            out[b] = acc
            self.stats["tokens"] += len(acc)
            # Eq. 4 EMA over the NEURAL drafter (the alpha paired with the
            # neural scan's c in T_SD): observe the first neural position's
            # outcome, and only when its PLD prefix was fully accepted —
            # otherwise the neural token was never evaluated (DyTC's
            # parent-accepted rule). PLD outcomes never enter this alpha.
            pld_n = int(self._pld_have[b])
            if have[b] > pld_n and n_chain[b] >= pld_n:
                self.acceptance.observe(self._slot_key(b), n_chain[b] > pld_n)
        self._host_round_telemetry(
            n_chain + 1, np.maximum(have - self._pld_have, 0),
            self._pld_have, self._last_limit,
        )
        self.pending = np.where(self.live, new_pending.astype(np.int64), self.pending)
        self.stats["steps"] += 1
        return out

    def _step_tree(self) -> Dict[int, List[int]]:
        """One DyTC round for the whole batch: PLD-seeded on-device tree
        growth (ONE fused scan dispatch), then fused verify + path commit
        (ONE target dispatch). Returns accepted tokens per live slot."""
        chains, have = self._pld_chains()
        limits = np.zeros(self.B, np.int32)
        alphas = np.full(self.B, 0.5, np.float32)
        for b in range(self.B):
            if self.live[b]:
                limits[b] = self._slot_tree_budget(b)
                alphas[b] = self.acceptance.alpha(self._slot_key(b))
        seed = tree_seed_arrays(
            self.pending.astype(np.int32), chains, have, self.tree_bucket,
            pld_alpha=PLD_SPEC.prior_alpha,
        )
        d_tokens, d_parents, d_depth, d_p_acc, d_mask, d_count = (
            jnp.asarray(a) for a in seed
        )
        tokens, parents, count = seed[0], seed[1], seed[5]
        first_neural = np.full(self.B, -1, np.int32)
        expansions = int(limits.max(initial=0))
        if expansions > 0:
            c = self.costs.c_hat(
                "tree_draft", default=float(self.draft_spec.prior_c)
            )
            t0 = time.perf_counter()
            out = jax.block_until_ready(self._tree_draft_fn(expansions)(
                self.params, self.cache,
                d_tokens, d_parents, d_depth, d_p_acc, d_mask, d_count,
                jnp.asarray(limits), jnp.asarray(alphas),
                jnp.asarray(max(c, 1e-3), jnp.float32),
                jnp.asarray(self.t_min, jnp.float32),
                self._gates,
            ))
            dt = time.perf_counter() - t0
            # depth/mask stay on device (only the verify reads them); the
            # host bookkeeping below needs tokens/parents/count/first only
            d_tokens, d_parents, d_depth, _, d_mask, d_count, d_first = out
            tokens, parents, count, first_neural = (
                np.asarray(a) for a in (d_tokens, d_parents, d_count, d_first)
            )
            self.stats["draft_dispatches"] += 1
            self.stats["draft_time"] += dt
            self.stats["host_syncs"] += 1
            self.stats["device_wait"] += dt
            self.stats["drafted_tokens"] += int(
                np.clip(count - have - 1, 0, None).sum()
            )
            # per-expansion-step latency -> the c in the Eq. 5 budgets
            self.costs.observe("tree_draft", dt, tokens=expansions)

        t0 = time.perf_counter()
        if self.sampling is not None:
            ds = self.dstate
            new_cache, path, n_acc, bonus, new_key = jax.block_until_ready(
                self._tree_verify_sampled(
                    self.params, self.cache,
                    d_tokens, d_parents, d_depth, d_mask, d_count,
                    jnp.asarray(self.live),
                    ds["temp"], ds["topk"], ds["topp"], ds["key"],
                )
            )
            self.dstate = dict(ds, key=new_key)
        else:
            new_cache, path, n_acc, bonus = jax.block_until_ready(
                self._tree_verify(
                    self.params, self.cache,
                    d_tokens, d_parents, d_depth, d_mask, d_count,
                    jnp.asarray(self.live),
                )
            )
        dt = time.perf_counter() - t0
        self.cache = new_cache
        self.stats["target_calls"] += 1
        self.stats["verify_time"] += dt
        self.stats["host_syncs"] += 1
        self.stats["device_wait"] += dt
        self.costs.observe_target(dt, tokens=1)

        path, n_acc, bonus = np.asarray(path), np.asarray(n_acc), np.asarray(bonus)
        out_toks: Dict[int, List[int]] = {}
        for b in range(self.B):
            if not self.live[b]:
                continue
            nodes = path[b, : n_acc[b]]
            acc = [int(tokens[b, i]) for i in nodes]
            self.contexts[b].extend(acc)
            out_toks[b] = acc
            self.stats["tokens"] += len(acc)
            # Eq. 4 EMA: observe the slot's first NEURAL top-1 prediction,
            # and only when its parent was accepted (DyTC's parent-accepted
            # rule; the root is always accepted). When the drafter's top-1
            # duplicated an existing PLD child, first_neural aliases that
            # node — the outcome priced is still the neural prediction's.
            fn = int(first_neural[b])
            if fn >= 0:
                node_set = {int(i) for i in nodes}
                if int(parents[b, fn]) in node_set:
                    self.acceptance.observe(self._slot_key(b), fn in node_set)
        self._host_round_telemetry(
            n_acc, np.clip(count - have - 1, 0, None), have, limits,
        )
        self.pending = np.where(self.live, bonus.astype(np.int64), self.pending)
        self.stats["steps"] += 1
        return out_toks

    # --------------------------------------------------------- cascade round
    def _slot_cascade_plan(self, b: int):
        """Eq. 5 routing + budget split for one slot: returns
        ``(expansions, use_rescore, alpha_eff, rescorer_alphas)``. A slot
        whose trackers say the cascade doesn't pay collapses to single-level
        drafting (no rescores) or to PLD-only (no neural work at all)."""
        bank = self.bank
        L = len(bank)
        alphas = [
            self.acceptance.alpha(bank.slot_key(i, b), default=bank.alpha_prior(i))
            for i in range(L)
        ]
        cs = [
            max(self.costs.c_hat(bank.cost_key(i), default=bank.c_prior(i)), 1e-3)
            for i in range(L - 1)
        ] + [max(self.costs.c_hat("cascade_draft", default=bank.c_prior(L - 1)), 1e-3)]
        alpha_eff = float(np.prod(alphas))
        # warm-up counts whichever keys this slot's rounds actually feed:
        # rescored rounds observe slot_key(0), single-level rounds (the only
        # kind a 1-level hierarchy has) observe direct_key
        warm = (self.acceptance.counts(bank.slot_key(0, b))
                + self.acceptance.counts(bank.direct_key(b)))
        if not self.adaptive or warm < self.min_obs:
            return self.tree_expansions, L > 1, alpha_eff, alphas[: L - 1]
        a_dir = self.acceptance.alpha(
            bank.direct_key(b), default=bank.direct_prior()
        )
        exp, use_rescore = best_cascade_plan(
            alphas, cs, a_dir, self.tree_expansions, self.t_min
        )
        use_rescore = use_rescore and L > 1
        if not use_rescore:
            # single-level rounds are priced (and observed) by the direct
            # tracker — the scan's stop rule must use the same alpha the
            # plan chose the budget with, not the stale compositional prior
            alpha_eff = a_dir
        return exp, use_rescore, alpha_eff, alphas[: L - 1]

    def _step_cascade(self) -> Dict[int, List[int]]:
        """One multi-level cascade round for the whole batch (Alg. 1 + §4.1
        hierarchy, fully batched): PLD-seeded trees, ONE drafting scan by
        the cheapest bank level, ONE intermediate-verify dispatch per
        stronger level (skipped when no slot is routed through it), ONE
        fused target verify + commit. Returns accepted tokens per slot."""
        bank = self.bank
        L = len(bank)
        chains, have = self._pld_chains()
        exp_b = np.zeros(self.B, np.int32)
        use_rescore = np.zeros(self.B, bool)
        alpha_eff = np.full(self.B, 0.5, np.float32)
        resc_alphas = np.full((max(L - 1, 1), self.B), 0.5, np.float32)
        for b in range(self.B):
            if not self.live[b]:
                continue
            exp_b[b], use_rescore[b], alpha_eff[b], r_alphas = (
                self._slot_cascade_plan(b)
            )
            for i, a in enumerate(r_alphas):
                resc_alphas[i, b] = a
        seed = tree_seed_arrays(
            self.pending.astype(np.int32), chains, have, self.tree_bucket,
            pld_alpha=bank.pld.prior_alpha,
        )
        d_tokens, d_parents, d_depth, d_p_acc, d_mask, d_count = (
            jnp.asarray(a) for a in seed
        )
        first_neural = jnp.full((self.B,), -1, jnp.int32)
        expansions = int(exp_b.max(initial=0))
        c_draft = self.costs.c_hat("cascade_draft", default=bank.c_prior(L - 1))
        if expansions > 0:
            t0 = time.perf_counter()
            out = jax.block_until_ready(self._casc_draft_fn(expansions)(
                bank.drafter.params, self.cache,
                d_tokens, d_parents, d_depth, d_p_acc, d_mask, d_count,
                jnp.asarray(exp_b), jnp.asarray(alpha_eff),
                jnp.asarray(max(c_draft, 1e-3), jnp.float32),
                jnp.asarray(self.t_min, jnp.float32),
                self._level_gates[bank.drafter.index],
            ))
            dt = time.perf_counter() - t0
            (d_tokens, d_parents, d_depth, d_p_acc, d_mask, d_count,
             first_neural) = out
            self.stats["draft_dispatches"] += 1
            self.stats["draft_time"] += dt
            self.stats["host_syncs"] += 1
            self.stats["device_wait"] += dt
            self.stats["drafted_tokens"] += int(
                np.clip(np.asarray(d_count) - have - 1, 0, None).sum()
            )
            self.costs.observe("cascade_draft", dt, tokens=expansions)

        # vertical rescores: just-above-drafter first, strongest level last,
        # each ONE jitted dispatch; the probe chain carries each level's
        # first own prediction to the next level's Eq. 4 judgement. The
        # STRONGEST level's dispatch also carries the target verify + commit
        # (cascade_rescore_verify, donated cache) — L dispatches per
        # rescored round, not L + 1.
        probe = first_neural
        level_node = np.full(self.B, -1, np.int32)
        live_d = jnp.asarray(self.live)
        # sampled builds: the slot keys thread sequentially through every
        # rescore dispatch (each splits its own uniforms in-dispatch and
        # returns the advanced keys) — mutable so each hop rebinds samp[3]
        samp = None
        if self.sampling is not None:
            ds = self.dstate
            samp = [ds["temp"], ds["topk"], ds["topp"], ds["key"]]
        if use_rescore.any():
            apply = jnp.asarray(use_rescore & self.live)
            for lvl in bank.rescorers:
                r = lvl.index
                last_level = lvl is bank.rescorers[-1]
                extra = tuple(samp) if samp is not None else ()
                t0 = time.perf_counter()
                if last_level and self.telemetry:
                    # the donated telemetry buffer rides the final fused
                    # dispatch (re-bound in the same statement, REPRO002);
                    # it absorbs the whole round's per-slot tallies plus
                    # this dispatch's own Eq. 4 verdict
                    out = jax.block_until_ready(self._rescore_verify_fn(r)(
                        lvl.params, self.params, self.cache,
                        d_tokens, d_parents, d_depth, d_p_acc, d_mask, d_count,
                        probe, apply, jnp.asarray(resc_alphas[r]),
                        self._level_gates[r], live_d,
                        self._telem_dev, jnp.asarray(have),
                        jnp.asarray(exp_b), *extra,
                    ))
                    if samp is not None:
                        (d_tokens, d_parents, d_depth, d_p_acc, d_mask,
                         d_count, lvl_node_d, probe_ok, probe_valid,
                         new_cache, path, n_acc, bonus, samp[3],
                         self._telem_dev) = out
                    else:
                        (d_tokens, d_parents, d_depth, d_p_acc, d_mask,
                         d_count, lvl_node_d, probe_ok, probe_valid,
                         new_cache, path, n_acc, bonus,
                         self._telem_dev) = out
                elif last_level:
                    out = jax.block_until_ready(self._rescore_verify_fn(r)(
                        lvl.params, self.params, self.cache,
                        d_tokens, d_parents, d_depth, d_p_acc, d_mask, d_count,
                        probe, apply, jnp.asarray(resc_alphas[r]),
                        self._level_gates[r], live_d, *extra,
                    ))
                    if samp is not None:
                        (d_tokens, d_parents, d_depth, d_p_acc, d_mask,
                         d_count, lvl_node_d, probe_ok, probe_valid,
                         new_cache, path, n_acc, bonus, samp[3]) = out
                    else:
                        (d_tokens, d_parents, d_depth, d_p_acc, d_mask,
                         d_count, lvl_node_d, probe_ok, probe_valid,
                         new_cache, path, n_acc, bonus) = out
                else:
                    out = jax.block_until_ready(self._rescore_fn(r)(
                        lvl.params, self.cache,
                        d_tokens, d_parents, d_depth, d_p_acc, d_mask, d_count,
                        probe, apply, jnp.asarray(resc_alphas[r]),
                        self._level_gates[r], *extra,
                    ))
                    if samp is not None:
                        (d_tokens, d_parents, d_depth, d_p_acc, d_mask,
                         d_count, lvl_node_d, probe_ok, probe_valid,
                         samp[3]) = out
                    else:
                        (d_tokens, d_parents, d_depth, d_p_acc, d_mask,
                         d_count, lvl_node_d, probe_ok, probe_valid) = out
                dt = time.perf_counter() - t0
                self.stats["rescore_dispatches"] += 1
                self.stats["host_syncs"] += 1
                self.stats["device_wait"] += dt
                if last_level:
                    # the fused dispatch contains the target verify; its
                    # wall time prices the TARGET round (the level's own
                    # cost coefficient keeps its prior / last split-mode
                    # estimate — see docs/cascade.md)
                    self.stats["target_calls"] += 1
                    self.stats["verify_time"] += dt
                    self.costs.observe_target(dt, tokens=1)
                else:
                    self.stats["rescore_time"] += dt
                    self.costs.observe(bank.cost_key(r), dt, tokens=1)
                # Eq. 4: this level's verdict on level r+1's first token
                pv, pk = np.asarray(probe_valid), np.asarray(probe_ok)
                if not (last_level and self.telemetry):
                    # device carriage covered only the final dispatch's
                    # verdict — intermediate rescorers mirror theirs into
                    # the host twin from the same arrays the trackers read
                    self._telem_host["casc_obs"][r + 1] += pv.astype(np.int32)
                    self._telem_host["casc_accept"][r + 1] += (
                        pv & pk
                    ).astype(np.int32)
                for b in range(self.B):
                    if pv[b]:
                        self.acceptance.observe(
                            bank.slot_key(r + 1, b), bool(pk[b])
                        )
                probe = lvl_node_d
            level_node = np.asarray(probe)
            self.cache = new_cache
        else:
            t0 = time.perf_counter()
            if samp is not None:
                new_cache, path, n_acc, bonus, samp[3] = jax.block_until_ready(
                    self._tree_verify_sampled(
                        self.params, self.cache,
                        d_tokens, d_parents, d_depth, d_mask, d_count,
                        live_d, *samp,
                    )
                )
            else:
                new_cache, path, n_acc, bonus = jax.block_until_ready(
                    self._tree_verify(
                        self.params, self.cache,
                        d_tokens, d_parents, d_depth, d_mask, d_count,
                        live_d,
                    )
                )
            dt = time.perf_counter() - t0
            self.cache = new_cache
            self.stats["target_calls"] += 1
            self.stats["verify_time"] += dt
            self.stats["host_syncs"] += 1
            self.stats["device_wait"] += dt
            self.costs.observe_target(dt, tokens=1)

        tokens_h = np.asarray(d_tokens)
        parents_h = np.asarray(d_parents)
        first_h = np.asarray(first_neural)
        path, n_acc, bonus = np.asarray(path), np.asarray(n_acc), np.asarray(bonus)
        rescored_round = bool(use_rescore.any())
        if not (rescored_round and self.telemetry):
            # no rescore_verify dispatch carried the buffer this round
            # (single-level routing, or telemetry off) — host twin carries
            # the per-slot tallies and routing rows instead
            self._host_round_telemetry(
                n_acc, np.clip(np.asarray(d_count) - have - 1, 0, None),
                have, exp_b,
            )
            routed = (use_rescore & self.live).astype(np.int32)
            for lv in bank.rescorers:
                self._telem_host["casc_routed"][lv.index] += routed
            self._telem_host["casc_routed"][bank.drafter.index] += (
                (exp_b > 0) & self.live
            ).astype(np.int32)
        out_toks: Dict[int, List[int]] = {}
        for b in range(self.B):
            if not self.live[b]:
                continue
            nodes = path[b, : n_acc[b]]
            acc = [int(tokens_h[b, i]) for i in nodes]
            self.contexts[b].extend(acc)
            out_toks[b] = acc
            self.stats["tokens"] += len(acc)
            node_set = {int(i) for i in nodes}
            # Eq. 4, target-facing (parent-accepted rule): on cascade
            # rounds the observation point is the STRONGEST level's own
            # node; on single-level rounds it is the drafter's first
            # prediction, priced under the slot's direct tracker
            if use_rescore[b]:
                fn = int(level_node[b])
                if fn >= 0 and int(parents_h[b, fn]) in node_set:
                    self.acceptance.observe(
                        bank.slot_key(0, b), fn in node_set
                    )
                    # target-facing verdict: row 0 of the cascade tallies
                    # (the device dispatch cannot see the accepted path's
                    # host-side membership test — always host-mirrored)
                    self._telem_host["casc_obs"][0, b] += 1
                    self._telem_host["casc_accept"][0, b] += int(
                        fn in node_set
                    )
            else:
                fn = int(first_h[b])
                if fn >= 0 and int(parents_h[b, fn]) in node_set:
                    self.acceptance.observe(bank.direct_key(b), fn in node_set)
                    if L == 1:
                        # a 1-level bank's direct acceptance IS its
                        # target-facing level alpha — keep the plan's
                        # cascade leg priced too
                        self.acceptance.observe(
                            bank.slot_key(0, b), fn in node_set
                        )
                        self._telem_host["casc_obs"][0, b] += 1
                        self._telem_host["casc_accept"][0, b] += int(
                            fn in node_set
                        )
        if samp is not None:
            # the advanced slot keys (threaded through every dispatch above)
            # re-enter the carried state as device arrays — no host copy
            self.dstate = dict(self.dstate, key=samp[3])
        self.pending = np.where(self.live, bonus.astype(np.int64), self.pending)
        self.stats["steps"] += 1
        return out_toks
