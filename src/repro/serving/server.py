"""Batched speculative serving (continuous batching + chain cascades).

The paper notes DyTC's tree adaptivity pays off at small batch; at larger
batch sizes CAS-Spec degrades gracefully to *chain* cascades (App. A). This
server implements that production path: per-slot PLD proposals merged with a
batched layer-sparse neural draft, verified jointly in one target forward,
committed per-sequence (divergent accepted lengths are supported by the
(B,)-pos cache).
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.config.base import ModelConfig
from repro.core.dsia import DraftSpec
from repro.core.pld import PromptLookup
from repro.models import model as M


class BatchedSpecServer:
    def __init__(
        self,
        cfg: ModelConfig,
        params: dict,
        max_batch: int = 4,
        max_len: int = 1024,
        draft_k: int = 4,
        draft_spec: Optional[DraftSpec] = None,   # None -> PLD-only drafting
    ):
        self.cfg, self.params = cfg, params
        self.B, self.max_len, self.k = max_batch, max_len, draft_k
        self.draft_spec = draft_spec
        self.pld = PromptLookup(max_draft=draft_k)
        self.cache = M.init_cache(cfg, max_batch, max_len, dtype=jnp.dtype(cfg.dtype))
        self.pending = np.zeros(max_batch, np.int64)
        self.contexts: List[List[int]] = [[] for _ in range(max_batch)]
        self.live = np.zeros(max_batch, bool)

        self._prefill1 = jax.jit(lambda p, b, c: M.prefill(cfg, p, b, c))
        self._decode = jax.jit(
            lambda p, c, t, g: M.decode_step(cfg, p, c, t, gates=g)
        )
        self._commit = jax.jit(lambda c, st, pi, na: M.commit_cache(cfg, c, st, pi, na))
        self._gates = (
            None
            if draft_spec is None
            else jnp.asarray(draft_spec.gates_array(cfg.num_layers))
        )
        self.stats = {"steps": 0, "tokens": 0, "target_calls": 0}

    # ------------------------------------------------------------ admission
    def add_request(self, slot: int, prompt: np.ndarray) -> None:
        """Prefill one prompt into a batch slot."""
        prompt = np.asarray(prompt, np.int32)
        c1 = M.init_cache(self.cfg, 1, self.max_len, dtype=jnp.dtype(self.cfg.dtype))
        last, c1 = self._prefill1(self.params, {"tokens": jnp.asarray(prompt[None])}, c1)
        self._write_slot(slot, c1)
        self.pending[slot] = int(np.argmax(np.asarray(last)[0]))
        self.contexts[slot] = list(map(int, prompt))
        self.live[slot] = True

    def _write_slot(self, slot: int, c1: dict) -> None:
        # cache leaves: segments (R, B, ...) and pos (B,)
        new_segments = jax.tree.map(
            lambda dst, src: dst.at[:, slot].set(src[:, 0]),
            self.cache["segments"],
            c1["segments"],
        )
        pos = self.cache["pos"].at[slot].set(c1["pos"][0])
        self.cache = {"pos": pos, "segments": new_segments}

    # ------------------------------------------------------------- stepping
    def _propose(self) -> np.ndarray:
        """Per-slot draft chains (B, k) — PLD first, neural fill-in."""
        chains = np.zeros((self.B, self.k), np.int64)
        have = np.zeros(self.B, np.int32)
        for b in range(self.B):
            if not self.live[b]:
                continue
            ctx = np.asarray(self.contexts[b] + [int(self.pending[b])], np.int64)
            toks = self.pld.propose(ctx, self.k)
            chains[b, : len(toks)] = toks
            have[b] = len(toks)
        if self.draft_spec is not None and (have < self.k).any():
            # batched neural chain drafting to fill remaining positions
            for j in range(int(have.min()), self.k):
                toks = np.concatenate(
                    [self.pending[:, None], chains[:, :j]], axis=1
                ).astype(np.int32)
                logits, _ = self._decode(
                    self.params, self.cache, jnp.asarray(toks), self._gates
                )
                nxt = np.asarray(jnp.argmax(logits[:, -1], -1))
                fill = have <= j
                chains[fill, j] = nxt[fill]
                have = np.maximum(have, np.where(fill, j + 1, have))
        return chains, have

    def step(self) -> Dict[int, List[int]]:
        """One speculative round for the whole batch; returns new tokens."""
        chains, have = self._propose()
        toks = np.concatenate([self.pending[:, None], chains], axis=1).astype(np.int32)
        logits, staged = self._decode(self.params, self.cache, jnp.asarray(toks), None)
        self.stats["target_calls"] += 1
        nxt = np.asarray(jnp.argmax(logits, -1))           # (B, k+1)

        n_acc = np.ones(self.B, np.int32)                  # pending always accepted
        new_pending = np.zeros_like(self.pending)
        out: Dict[int, List[int]] = {}
        for b in range(self.B):
            if not self.live[b]:
                n_acc[b] = 0
                continue
            acc = [int(self.pending[b])]
            j = 0
            while j < have[b] and int(chains[b, j]) == int(nxt[b, j]):
                acc.append(int(chains[b, j]))
                j += 1
            n_acc[b] = len(acc)
            new_pending[b] = int(nxt[b, j])
            self.contexts[b].extend(acc)
            out[b] = acc
            self.stats["tokens"] += len(acc)
        path_idx = jnp.broadcast_to(jnp.arange(self.k + 1), (self.B, self.k + 1))
        self.cache = self._commit(
            self.cache, staged, path_idx, jnp.asarray(n_acc)
        )
        self.pending = np.where(self.live, new_pending, self.pending)
        self.stats["steps"] += 1
        return out
