"""Batched speculative serving (continuous batching + chain cascades).

The paper notes DyTC's tree adaptivity pays off at small batch; at larger
batch sizes CAS-Spec degrades gracefully to *chain* cascades (App. A). This
server implements that production path: per-slot PLD proposals merged with a
batched layer-sparse neural draft, verified jointly in one target forward,
committed per-sequence (divergent accepted lengths are supported by the
(B,)-pos cache).

Fused drafting
--------------
The k-step neural chain draft runs as ONE jitted ``lax.scan`` over draft
steps (``core.engine.chain_draft_scan``): each step re-decodes the fixed
(B, k+1) block under a causal tree mask, so later draft steps see earlier
drafted tokens through the staged-KV block path entirely on device, with
the committed cache read-only. One dispatch per proposal round replaces
the seed's k ``_decode`` calls with a host sync between each.
Verification + acceptance + commit are likewise one jitted call
(``_verify_accept_commit``): the per-slot Python acceptance loop is
replaced by a vectorized cumprod over the chain-match mask. Drafts never
write the real cache — only target verification does — so serving stays
lossless.

Adaptive chain-cascade drafting (DyTC Eq. 5 analogue)
-----------------------------------------------------
Each slot carries an EMA acceptance estimate of its first NEURAL draft
token (Eq. 4, ``AcceptanceTracker`` keyed per slot; PLD outcomes are
excluded so the alpha prices the same drafter whose cost c is measured
from the neural scan) and the server maintains an online
draft-cost coefficient c = draft-token-latency / verify-round-latency
(``CostTracker``). Per round, each slot's draft length is the k maximizing
the chain EWIF T_SD(alpha_b, c, k) (``latency.best_chain_length``); a slot
whose best expected speedup falls below ``t_min`` stops neural drafting
(limit 0) and degrades to plain AR inside the same batched verify — the
chain analogue of DyTC's stop rule. PLD proposals are effectively free
(host-side retrieval, fixed-width verify), so they are never truncated by
the adaptive limit. Slot estimates reset on request admission (continuous
batching reuses slots across requests).
"""
from __future__ import annotations

import functools
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.config.base import ModelConfig
from repro.core.acceptance import AcceptanceTracker
from repro.core.dsia import DraftSpec
from repro.core.engine import chain_draft_scan
from repro.core.latency import CostTracker, best_chain_length
from repro.core.pld import PromptLookup
from repro.models import model as M


def _verify_accept_commit(
    cfg: ModelConfig,
    params: dict,
    cache: dict,
    pending: jax.Array,               # (B,) int32
    chains: jax.Array,                # (B, k) int32
    have: jax.Array,                  # (B,) int32
    live: jax.Array,                  # (B,) bool
):
    """One fused target round: verify [pending, chain] jointly, accept the
    longest matching prefix per slot (vectorized — no per-slot Python), and
    commit the accepted path. Returns (cache, nxt, n_chain, new_pending)."""
    toks = jnp.concatenate([pending[:, None], chains], axis=1)   # (B, k+1)
    logits, staged = M.decode_step(cfg, params, cache, toks)
    nxt = jnp.argmax(logits, -1).astype(jnp.int32)               # (B, k+1)
    B, K = chains.shape
    ok = (chains == nxt[:, :K]) & (jnp.arange(K)[None] < have[:, None])
    # accepted chain prefix length: leading run of matches
    n_chain = jnp.cumprod(ok.astype(jnp.int32), axis=1).sum(axis=1)
    n_chain = jnp.where(live, n_chain, 0)
    n_acc = jnp.where(live, n_chain + 1, 0).astype(jnp.int32)    # + pending
    new_pending = jnp.take_along_axis(nxt, n_chain[:, None], axis=1)[:, 0]
    path_idx = jnp.broadcast_to(
        jnp.arange(K + 1, dtype=jnp.int32)[None], (B, K + 1)
    )
    new_cache = M.commit_cache(cfg, cache, staged, path_idx, n_acc)
    return new_cache, nxt, n_chain, new_pending


class BatchedSpecServer:
    def __init__(
        self,
        cfg: ModelConfig,
        params: dict,
        max_batch: int = 4,
        max_len: int = 1024,
        draft_k: int = 4,
        draft_spec: Optional[DraftSpec] = None,   # None -> PLD-only drafting
        fused: bool = True,            # False: seed-style per-step drafting (A/B)
        adaptive: bool = True,         # per-slot adaptive draft length
        t_min: float = 1.05,           # min expected chain speedup to keep drafting
        min_obs: int = 4,              # per-slot observations before adapting
    ):
        self.cfg, self.params = cfg, params
        self.B, self.max_len, self.k = max_batch, max_len, draft_k
        self.draft_spec = draft_spec
        self.fused = fused
        self.adaptive = adaptive
        self.t_min = t_min
        self.min_obs = min_obs
        self.pld = PromptLookup(max_draft=draft_k)
        self.acceptance = AcceptanceTracker()
        self.costs = CostTracker()
        self.cache = M.init_cache(cfg, max_batch, max_len, dtype=jnp.dtype(cfg.dtype))
        self.pending = np.zeros(max_batch, np.int64)
        self.contexts: List[List[int]] = [[] for _ in range(max_batch)]
        self.live = np.zeros(max_batch, bool)
        self._pld_have = np.zeros(max_batch, np.int32)   # PLD prefix per round

        self._prefill1 = jax.jit(lambda p, b, c: M.prefill(cfg, p, b, c))
        # legacy (unfused) drafting path — kept for A/B benchmarking
        self._decode = jax.jit(
            lambda p, c, t, g: M.decode_step(cfg, p, c, t, gates=g)
        )
        self._verify = jax.jit(functools.partial(_verify_accept_commit, cfg))
        self._draft_fns: Dict[int, callable] = {}   # scan steps -> jitted fn
        self._gates = (
            None
            if draft_spec is None
            else jnp.asarray(draft_spec.gates_array(cfg.num_layers))
        )
        self.stats = {
            "steps": 0, "tokens": 0, "target_calls": 0,
            "draft_dispatches": 0, "draft_time": 0.0, "verify_time": 0.0,
            "drafted_tokens": 0,
        }

    # ------------------------------------------------------------ admission
    def add_request(self, slot: int, prompt: np.ndarray) -> None:
        """Prefill one prompt into a batch slot."""
        prompt = np.asarray(prompt, np.int32)
        c1 = M.init_cache(self.cfg, 1, self.max_len, dtype=jnp.dtype(self.cfg.dtype))
        last, c1 = self._prefill1(self.params, {"tokens": jnp.asarray(prompt[None])}, c1)
        self._write_slot(slot, c1)
        self.pending[slot] = int(np.argmax(np.asarray(last)[0]))
        self.contexts[slot] = list(map(int, prompt))
        self.live[slot] = True
        # slot estimators restart with the draft's cold-start prior —
        # continuous batching reuses slots across unrelated requests
        prior = self.draft_spec.prior_alpha if self.draft_spec else 0.5
        self.acceptance.reset(self._slot_key(slot), alpha0=prior)

    def release(self, slot: int) -> None:
        """Mark a slot free (its request finished or was cancelled)."""
        self.live[slot] = False

    def _slot_key(self, slot: int) -> str:
        return f"chain:{slot}"

    def _write_slot(self, slot: int, c1: dict) -> None:
        # cache leaves: segments (R, B, ...) and pos (B,)
        new_segments = jax.tree.map(
            lambda dst, src: dst.at[:, slot].set(src[:, 0]),
            self.cache["segments"],
            c1["segments"],
        )
        pos = self.cache["pos"].at[slot].set(c1["pos"][0])
        self.cache = {"pos": pos, "segments": new_segments}

    # ----------------------------------------------------- adaptive lengths
    def _slot_limit(self, slot: int) -> int:
        """Neural draft budget for a slot this round (PLD is never capped)."""
        if self.draft_spec is None:
            return 0
        key = self._slot_key(slot)
        if not self.adaptive or self.acceptance.counts(key) < self.min_obs:
            return self.k
        alpha = self.acceptance.alpha(key)
        c = self.costs.c_hat(
            "chain_draft", default=float(self.draft_spec.prior_c)
        )
        return best_chain_length(alpha, max(c, 1e-3), self.k, self.t_min)

    def _draft_fn(self, steps: int):
        fn = self._draft_fns.get(steps)
        if fn is None:
            fn = jax.jit(functools.partial(chain_draft_scan, self.cfg, steps))
            self._draft_fns[steps] = fn
        return fn

    # ------------------------------------------------------------- stepping
    def _propose(self):
        """Per-slot draft chains (B, k) — PLD first, neural fill-in.

        Returns (chains (B,k) int32, have (B,) int32). The neural fill-in is
        a single fused scan dispatch covering every slot and draft step."""
        chains = np.zeros((self.B, self.k), np.int32)
        have = np.zeros(self.B, np.int32)
        limit = np.zeros(self.B, np.int32)
        for b in range(self.B):
            if not self.live[b]:
                continue
            ctx = np.asarray(self.contexts[b] + [int(self.pending[b])], np.int64)
            toks = self.pld.propose(ctx, self.k)
            chains[b, : len(toks)] = toks
            have[b] = len(toks)
            limit[b] = self._slot_limit(b)
        # remember where PLD ends per slot: the acceptance estimator that
        # prices the NEURAL draft must only see neural-token outcomes
        self._pld_have = have.copy()
        if self.draft_spec is None:
            return chains, have
        if self.fused:
            return self._propose_fused(chains, have, limit)
        return self._propose_legacy(chains, have, limit)

    def _propose_fused(self, chains, have, limit):
        # one jitted lax.scan over draft steps; trip count = the largest
        # per-slot budget still needing neural fill (<= k distinct compiles)
        steps = int(np.max(np.where(limit > have, limit, 0), initial=0))
        if steps == 0:
            return chains, have
        t0 = time.perf_counter()
        ch_d, hv_d = jax.block_until_ready(
            self._draft_fn(steps)(
                self.params, self.cache,
                jnp.asarray(self.pending, jnp.int32),
                jnp.asarray(chains), jnp.asarray(have), jnp.asarray(limit),
                self._gates,
            )
        )
        dt = time.perf_counter() - t0
        chains, have = np.asarray(ch_d), np.asarray(hv_d)
        self.stats["draft_dispatches"] += 1
        self.stats["draft_time"] += dt
        self.stats["drafted_tokens"] += steps
        # per-draft-step latency (the whole batch advances one token per
        # step) -> c_hat = draft-step / verify-round, the c in T_SD
        self.costs.observe("chain_draft", dt, tokens=steps)
        return chains, have

    def _propose_legacy(self, chains, have, limit):
        # seed behavior: one _decode dispatch per draft step, host syncs
        # between steps (kept only as the A/B baseline for benchmarks)
        need = self.live & (limit > have)
        if not need.any():
            return chains, have
        lo, hi = int(have[need].min()), int(limit[need].max())
        for j in range(lo, hi):
            toks = np.concatenate(
                [self.pending[:, None], chains[:, :j]], axis=1
            ).astype(np.int32)
            t0 = time.perf_counter()
            logits, _ = self._decode(
                self.params, self.cache, jnp.asarray(toks), self._gates
            )
            nxt = np.asarray(jnp.argmax(logits[:, -1], -1))
            self.stats["draft_dispatches"] += 1
            self.stats["draft_time"] += time.perf_counter() - t0
            fill = (have <= j) & (j < limit)
            chains[fill, j] = nxt[fill]
            have = np.maximum(have, np.where(fill, j + 1, have)).astype(np.int32)
        return chains, have

    def step(self) -> Dict[int, List[int]]:
        """One speculative round for the whole batch; returns new tokens."""
        chains, have = self._propose()
        t0 = time.perf_counter()
        new_cache, nxt, n_chain, new_pending = jax.block_until_ready(
            self._verify(
                self.params, self.cache,
                jnp.asarray(self.pending, jnp.int32),
                jnp.asarray(chains), jnp.asarray(have),
                jnp.asarray(self.live),
            )
        )
        dt = time.perf_counter() - t0
        self.cache = new_cache
        self.stats["target_calls"] += 1
        self.stats["verify_time"] += dt
        self.costs.observe_target(dt, tokens=1)   # per-round target latency

        n_chain = np.asarray(n_chain)
        new_pending = np.asarray(new_pending)
        out: Dict[int, List[int]] = {}
        for b in range(self.B):
            if not self.live[b]:
                continue
            acc = [int(self.pending[b])] + [int(t) for t in chains[b, : n_chain[b]]]
            self.contexts[b].extend(acc)
            out[b] = acc
            self.stats["tokens"] += len(acc)
            # Eq. 4 EMA over the NEURAL drafter (the alpha paired with the
            # neural scan's c in T_SD): observe the first neural position's
            # outcome, and only when its PLD prefix was fully accepted —
            # otherwise the neural token was never evaluated (DyTC's
            # parent-accepted rule). PLD outcomes never enter this alpha.
            pld_n = int(self._pld_have[b])
            if have[b] > pld_n and n_chain[b] >= pld_n:
                self.acceptance.observe(self._slot_key(b), n_chain[b] > pld_n)
        self.pending = np.where(self.live, new_pending.astype(np.int64), self.pending)
        self.stats["steps"] += 1
        return out
