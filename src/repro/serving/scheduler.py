"""Request scheduling for the batched server: FIFO admission into fixed
batch slots with continuous batching (a finished slot is refilled on the
next step boundary). ``ServeLoop`` is the admit/step/retire glue between a
``RequestScheduler`` and a ``BatchedSpecServer`` — examples, benchmarks and
tests all drive serving through it. Scheduling is orthogonal to the
server's proposal mode (``chain_fused`` / ``legacy`` / ``tree_fused``):
every mode exposes the same add_request/step/release slot contract."""
from __future__ import annotations

import dataclasses
import itertools
from collections import deque
from typing import Deque, Dict, List, Optional

import numpy as np

_ids = itertools.count()


@dataclasses.dataclass
class Request:
    prompt: np.ndarray
    max_new_tokens: int
    request_id: int = dataclasses.field(default_factory=lambda: next(_ids))
    generated: List[int] = dataclasses.field(default_factory=list)
    done: bool = False

    @property
    def remaining(self) -> int:
        return self.max_new_tokens - len(self.generated)


class RequestScheduler:
    def __init__(self, max_batch: int):
        self.max_batch = max_batch
        self.queue: Deque[Request] = deque()
        self.active: Dict[int, Request] = {}   # slot -> request
        self.finished: List[Request] = []

    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def admit(self) -> List[int]:
        """Fill free slots from the queue; returns newly admitted slots."""
        new = []
        for slot in range(self.max_batch):
            if slot not in self.active and self.queue:
                self.active[slot] = self.queue.popleft()
                new.append(slot)
        return new

    def retire(self) -> List[Request]:
        done = [s for s, r in self.active.items() if r.done or r.remaining <= 0]
        out = []
        for s in done:
            r = self.active.pop(s)
            r.done = True
            self.finished.append(r)
            out.append(r)
        return out

    @property
    def busy(self) -> bool:
        return bool(self.queue or self.active)


class ServeLoop:
    """Continuous-batching driver: admits queued requests into server slots,
    steps the server, routes per-slot tokens back to their requests, and
    releases slots of finished requests (freeing their per-slot adaptive
    draft-length estimators for the next admission).

    Pipelined servers (``round_mode="single"`` with ``sync_every > 1``)
    return tokens lazily: a ``step()`` may return nothing (rounds still in
    flight) or several rounds' worth at a sync point. The loop stays
    correct under that contract by draining the server *before* re-binding
    any slot: in-flight tokens are routed under the slot→request mapping
    they were produced under, and only then does admission rebind the slot.
    A finished request may overshoot ``max_new_tokens`` by the rounds that
    were in flight when it crossed the line — the surplus is trimmed at
    retire, exactly like the synchronous path trims a long accepted chain."""

    def __init__(self, server, scheduler: RequestScheduler):
        self.server = server
        self.scheduler = scheduler
        self._slot_req: Dict[int, Request] = {}
        self._req_slot: Dict[int, int] = {}   # request_id -> slot

    def _route(self, out: Dict[int, List[int]]) -> None:
        for slot, toks in out.items():
            req = self._slot_req.get(slot)
            if req is not None and not req.done:
                req.generated.extend(toks)

    def step_once(self) -> Dict[int, List[int]]:
        out: Dict[int, List[int]] = {}
        will_admit = bool(self.scheduler.queue) and (
            len(self.scheduler.active) < self.scheduler.max_batch
        )
        if will_admit:
            # sync-on-admit: drain in-flight rounds and route them under the
            # OLD slot mapping before any slot is re-bound
            flush = getattr(self.server, "flush", None)
            if flush is not None:
                out = flush()
                self._route(out)
        for slot in self.scheduler.admit():
            req = self.scheduler.active[slot]
            self.server.add_request(slot, req.prompt)
            self._slot_req[slot] = req
            self._req_slot[req.request_id] = slot
        step_out = self.server.step()
        self._route(step_out)
        for slot, toks in step_out.items():
            out.setdefault(slot, []).extend(toks)
        for req in self.scheduler.retire():
            req.generated = req.generated[: req.max_new_tokens]
            slot = self._req_slot.pop(req.request_id)
            del self._slot_req[slot]
            self.server.release(slot)
        return out

    def run(self, max_steps: Optional[int] = None) -> List[Request]:
        """Serve until the queue drains (or ``max_steps``); returns the
        finished requests in completion order."""
        steps = 0
        while self.scheduler.busy and (max_steps is None or steps < max_steps):
            self.step_once()
            steps += 1
        return self.scheduler.finished
