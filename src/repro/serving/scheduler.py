"""Request scheduling for the batched server: FIFO admission into fixed
batch slots with continuous batching (a finished slot is refilled on the
next step boundary). ``ServeLoop`` is the admit/step/retire glue between a
``RequestScheduler`` and a ``BatchedSpecServer`` — examples, benchmarks and
tests all drive serving through it. Scheduling is orthogonal to the
server's proposal mode (``chain_fused`` / ``legacy`` / ``tree_fused``):
every mode exposes the same add_request/step/release slot contract.

Observability (docs/observability.md): the loop measures what only IT can
see — per-request TTFT/TPOT/ITL (token arrivals are logged as the loop
routes them, so pipelined sync batches are attributed at their real drain
times), queue depth and slot occupancy gauges, and Chrome-trace spans for
the host-loop phases (admit / dispatch / drain / route / retire). Overshoot
tokens trimmed at retire are EXCLUDED from per-request token counts and
TPOT (they were never delivered), and counted separately so drained device
telemetry reconciles exactly with the routed streams."""
from __future__ import annotations

import dataclasses
import itertools
import time
from collections import deque
from typing import Deque, Dict, List, Optional, Tuple

import numpy as np

from repro.serving.sampler import SamplingParams
from repro.serving.telemetry import (
    Histogram,
    MetricsRegistry,
    TraceRecorder,
    maybe_span,
)

_ids = itertools.count()

# per-request latency buckets: 100us .. ~512s (geometric, base 2)
_LAT_EDGES = Histogram.log_edges(1e-4, 512.0)


@dataclasses.dataclass
class Request:
    prompt: np.ndarray
    max_new_tokens: int
    # per-request sampling override; None inherits the server build's
    # default. The loop forwards it verbatim at admission — a stochastic
    # request on a greedy server build raises there.
    sampling: Optional[SamplingParams] = None
    request_id: int = dataclasses.field(default_factory=lambda: next(_ids))
    generated: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    # --- measured by the loop (perf_counter timestamps; REPRO005-safe:
    # only deltas between them are ever reported)
    submitted_at: Optional[float] = None
    # (timestamp, cumulative tokens routed) per routed batch — pipelined
    # servers deliver several rounds at one sync point, which is ONE
    # arrival here: attribution follows what the caller could observe
    arrivals: List[Tuple[float, int]] = dataclasses.field(default_factory=list)
    # --- computed at retire (seconds; None when not measurable)
    ttft: Optional[float] = None
    tpot: Optional[float] = None
    overshoot: int = 0

    @property
    def remaining(self) -> int:
        return self.max_new_tokens - len(self.generated)

    def record_arrival(self, n: int) -> None:
        if n <= 0:
            return
        prev = self.arrivals[-1][1] if self.arrivals else 0
        self.arrivals.append((time.perf_counter(), prev + n))

    def finalize_latency(self) -> None:
        """TTFT/TPOT from the arrival log, counting only DELIVERED tokens:
        the arrival that crossed ``max_new_tokens`` is the effective last
        one — overshoot routed beyond it (in-flight rounds at the finish
        line) never contributes to per-request throughput."""
        if not self.arrivals or self.submitted_at is None:
            return
        delivered = min(self.arrivals[-1][1], self.max_new_tokens)
        t_first = self.arrivals[0][0]
        self.ttft = t_first - self.submitted_at
        t_eff = next(t for t, cum in self.arrivals if cum >= delivered)
        if delivered > 1 and t_eff > t_first:
            self.tpot = (t_eff - t_first) / (delivered - 1)

    def itl_gaps(self) -> List[float]:
        """Inter-arrival gaps (seconds) between delivered-token batches."""
        delivered = min(
            self.arrivals[-1][1] if self.arrivals else 0, self.max_new_tokens
        )
        ts = []
        for t, cum in self.arrivals:
            ts.append(t)
            if cum >= delivered:
                break
        return [b - a for a, b in zip(ts, ts[1:])]


class RequestScheduler:
    def __init__(self, max_batch: int):
        self.max_batch = max_batch
        self.queue: Deque[Request] = deque()
        self.active: Dict[int, Request] = {}   # slot -> request
        self.finished: List[Request] = []

    def submit(self, req: Request) -> None:
        if req.submitted_at is None:
            req.submitted_at = time.perf_counter()
        self.queue.append(req)

    def admit(self) -> List[int]:
        """Fill free slots from the queue; returns newly admitted slots."""
        new = []
        for slot in range(self.max_batch):
            if slot not in self.active and self.queue:
                self.active[slot] = self.queue.popleft()
                new.append(slot)
        return new

    def retire(self) -> List[Request]:
        done = [s for s, r in self.active.items() if r.done or r.remaining <= 0]
        out = []
        for s in done:
            r = self.active.pop(s)
            r.done = True
            self.finished.append(r)
            out.append(r)
        return out

    @property
    def busy(self) -> bool:
        return bool(self.queue or self.active)


class ServeLoop:
    """Continuous-batching driver: admits queued requests into server slots,
    steps the server, routes per-slot tokens back to their requests, and
    releases slots of finished requests (freeing their per-slot adaptive
    draft-length estimators for the next admission).

    Pipelined servers (``round_mode="single"`` with ``sync_every > 1``)
    return tokens lazily: a ``step()`` may return nothing (rounds still in
    flight) or several rounds' worth at a sync point. The loop stays
    correct under that contract by draining the server *before* re-binding
    any slot: in-flight tokens are routed under the slot→request mapping
    they were produced under, and only then does admission rebind the slot.
    A finished request may overshoot ``max_new_tokens`` by the rounds that
    were in flight when it crossed the line — the surplus is trimmed at
    retire, exactly like the synchronous path trims a long accepted chain.

    ``metrics`` defaults to the server's own registry (so loop metrics and
    server telemetry land on one /metrics endpoint); ``trace`` (a
    ``TraceRecorder``) turns on Chrome-trace spans for the loop phases."""

    def __init__(
        self,
        server,
        scheduler: RequestScheduler,
        metrics: Optional[MetricsRegistry] = None,
        trace: Optional[TraceRecorder] = None,
    ):
        self.server = server
        self.scheduler = scheduler
        self.metrics = (
            metrics if metrics is not None
            else getattr(server, "metrics", None)
        ) or MetricsRegistry()
        self.trace = trace
        self._slot_req: Dict[int, Request] = {}
        self._req_slot: Dict[int, int] = {}   # request_id -> slot

    def _route(self, out: Dict[int, List[int]]) -> None:
        for slot, toks in out.items():
            req = self._slot_req.get(slot)
            if req is not None and not req.done:
                req.generated.extend(toks)
                req.record_arrival(len(toks))
            elif toks:
                # committed for a slot with no live request to credit
                # (request already done, or drained after an unmapped
                # release) — counted so telemetry reconciliation closes
                self.metrics.counter("serve_unrouted_tokens_total").inc(
                    len(toks)
                )

    def _observe_retired(self, req: Request, trimmed: int) -> None:
        req.overshoot = trimmed
        req.finalize_latency()
        m = self.metrics
        m.counter("serve_requests_finished_total").inc()
        # delivered tokens only — the trimmed surplus goes to its own
        # counter (and is what device-telemetry reconciliation adds back)
        m.counter("serve_request_tokens_total").inc(len(req.generated))
        if trimmed:
            m.counter("serve_overshoot_tokens_total").inc(trimmed)
        if req.ttft is not None:
            m.histogram(
                "serve_request_ttft_seconds", edges=_LAT_EDGES
            ).observe(req.ttft)
        if req.tpot is not None:
            m.histogram(
                "serve_request_tpot_seconds", edges=_LAT_EDGES
            ).observe(req.tpot)
        for gap in req.itl_gaps():
            m.histogram(
                "serve_request_itl_seconds", edges=_LAT_EDGES
            ).observe(gap)

    def step_once(self) -> Dict[int, List[int]]:
        out: Dict[int, List[int]] = {}
        will_admit = bool(self.scheduler.queue) and (
            len(self.scheduler.active) < self.scheduler.max_batch
        )
        if will_admit:
            # sync-on-admit: drain in-flight rounds and route them under the
            # OLD slot mapping before any slot is re-bound
            flush = getattr(self.server, "flush", None)
            if flush is not None:
                with maybe_span(self.trace, "drain"):
                    out = flush()
                self._route(out)
        with maybe_span(self.trace, "admit"):
            for slot in self.scheduler.admit():
                req = self.scheduler.active[slot]
                # the request's token budget rides admission so paged
                # servers can size the slot's KV page allocation to
                # prompt + budget instead of a full max_len reservation
                if req.sampling is not None:
                    self.server.add_request(
                        slot, req.prompt, sampling=req.sampling,
                        max_new_tokens=req.max_new_tokens,
                    )
                else:
                    self.server.add_request(
                        slot, req.prompt,
                        max_new_tokens=req.max_new_tokens,
                    )
                self._slot_req[slot] = req
                self._req_slot[req.request_id] = slot
        # the "dispatch" span times the HOST side of a round (pipelined
        # rounds return before the device finishes; device completion is
        # accounted by the server's device_wait counter at drain points)
        with maybe_span(self.trace, "dispatch"):
            step_out = self.server.step()
        with maybe_span(self.trace, "route"):
            self._route(step_out)
            for slot, toks in step_out.items():
                out.setdefault(slot, []).extend(toks)
        with maybe_span(self.trace, "retire"):
            for req in self.scheduler.retire():
                trimmed = max(len(req.generated) - req.max_new_tokens, 0)
                req.generated = req.generated[: req.max_new_tokens]
                slot = self._req_slot.pop(req.request_id)
                del self._slot_req[slot]
                self.server.release(slot)
                self._observe_retired(req, trimmed)
        self.metrics.gauge("serve_queue_depth").set(len(self.scheduler.queue))
        self.metrics.gauge("serve_slots_occupied").set(
            len(self.scheduler.active)
        )
        return out

    def run(self, max_steps: Optional[int] = None) -> List[Request]:
        """Serve until the queue drains (or ``max_steps``); returns the
        finished requests in completion order."""
        steps = 0
        while self.scheduler.busy and (max_steps is None or steps < max_steps):
            self.step_once()
            steps += 1
        return self.scheduler.finished
