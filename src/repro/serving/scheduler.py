"""Request scheduling for the batched server: FIFO admission into fixed
batch slots with continuous batching (a finished slot is refilled on the
next step boundary)."""
from __future__ import annotations

import dataclasses
import itertools
from collections import deque
from typing import Deque, Dict, List, Optional

import numpy as np

_ids = itertools.count()


@dataclasses.dataclass
class Request:
    prompt: np.ndarray
    max_new_tokens: int
    request_id: int = dataclasses.field(default_factory=lambda: next(_ids))
    generated: List[int] = dataclasses.field(default_factory=list)
    done: bool = False

    @property
    def remaining(self) -> int:
        return self.max_new_tokens - len(self.generated)


class RequestScheduler:
    def __init__(self, max_batch: int):
        self.max_batch = max_batch
        self.queue: Deque[Request] = deque()
        self.active: Dict[int, Request] = {}   # slot -> request
        self.finished: List[Request] = []

    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def admit(self) -> List[int]:
        """Fill free slots from the queue; returns newly admitted slots."""
        new = []
        for slot in range(self.max_batch):
            if slot not in self.active and self.queue:
                self.active[slot] = self.queue.popleft()
                new.append(slot)
        return new

    def retire(self) -> List[Request]:
        done = [s for s, r in self.active.items() if r.done or r.remaining <= 0]
        out = []
        for s in done:
            r = self.active.pop(s)
            r.done = True
            self.finished.append(r)
            out.append(r)
        return out

    @property
    def busy(self) -> bool:
        return bool(self.queue or self.active)
