"""Serving runtime: samplers, request scheduling, batched speculative server."""
from repro.serving.telemetry import (
    MetricsRegistry, StatsView, TraceRecorder,
)
from repro.serving.exporters import JsonlSink, MetricsHTTPServer
from repro.serving.draft_bank import DraftBank, DraftLevel
from repro.serving.sampler import sample_token
from repro.serving.scheduler import Request, RequestScheduler, ServeLoop
from repro.serving.server import BatchedSpecServer

__all__ = [
    "sample_token", "Request", "RequestScheduler", "ServeLoop",
    "BatchedSpecServer", "DraftBank", "DraftLevel",
    "MetricsRegistry", "StatsView", "TraceRecorder",
    "JsonlSink", "MetricsHTTPServer",
]
