"""Static cascade baselines (CS-Drafting-style) + SWIFT-style tree baseline.

These are the paper's comparison points (Fig. 3):
  SD(spec)  — vanilla self-speculative chain drafting with a fixed k
  PLD       — prompt-lookup alone
  VC        — vertical cascade: PLD drafts, M_d1 verifies/extends, n rounds
  HC        — horizontal cascade: M_d1 drafts k1 early tokens, PLD continues
  VC+HC     — CS-Drafting combination
  Tree (Tr) — fixed top-K tree with a single draft model (SWIFT w/ tree attn)
  Tr+VC     — fixed tree over the vertical cascade

All build a DraftTree and verify through the same engine, so every baseline
is lossless by construction and differs only in scheduling.
"""
from __future__ import annotations

from typing import List

import numpy as np

from repro.core import verify as verify_lib
from repro.core.dsia import DraftSpec, PLD_SPEC
from repro.core.engine import SpecEngine
from repro.core.tree import DraftTree


class BaseScheduler:
    def __init__(self, engine: SpecEngine):
        self.engine = engine

    def build_tree(self) -> DraftTree:
        raise NotImplementedError

    def step(self) -> List[int]:
        tree = self.build_tree()
        return self.engine.verify_and_commit(tree)

    def generate(self, n_tokens: int) -> List[int]:
        start = len(self.engine.tokens)
        while len(self.engine.tokens) - start < n_tokens:
            self.step()
        return self.engine.tokens[start : start + n_tokens]


class ARScheduler(BaseScheduler):
    """Autoregressive baseline (tree = root only)."""

    def build_tree(self) -> DraftTree:
        return DraftTree(self.engine.pending)


class PLDScheduler(BaseScheduler):
    def __init__(self, engine: SpecEngine, k: int = 8):
        super().__init__(engine)
        self.k = k
        engine.register_draft(PLD_SPEC)

    def build_tree(self) -> DraftTree:
        eng = self.engine
        tree = DraftTree(eng.pending)
        toks = eng.pld.propose(eng.context, self.k)
        node = 0
        for t in toks:
            node = tree.add_child(node, int(t), "PLD", 0.5)
        return tree


class SDScheduler(BaseScheduler):
    """Vanilla (self-)speculative chain drafting with fixed draft length."""

    def __init__(self, engine: SpecEngine, spec: DraftSpec, k: int = 5):
        super().__init__(engine)
        self.spec, self.k = spec, k
        engine.register_draft(spec)

    def _draft_chain(self, tree: DraftTree, start_node: int, k: int) -> int:
        node = start_node
        for _ in range(k):
            path = tree.path_to(node)
            tokens = np.asarray([tree.tokens[i] for i in path], np.int32)
            rel = np.asarray([tree.depth[i] for i in path], np.int32)
            mask = np.tril(np.ones((len(path), len(path)), bool))
            logits = self.engine.draft_logits(self.spec.name, tokens, rel, mask)
            t = int(np.argmax(logits[len(path) - 1]))
            node = tree.add_child(node, t, self.spec.name, 0.5)
        return node

    def build_tree(self) -> DraftTree:
        tree = DraftTree(self.engine.pending)
        self._draft_chain(tree, 0, self.k)
        return tree


class VCScheduler(SDScheduler):
    """Vertical cascade: PLD drafts k2, M_d1 verifies + extends, n rounds."""

    def __init__(self, engine: SpecEngine, spec: DraftSpec, n: int = 2, k2: int = 6):
        super().__init__(engine, spec, k=0)
        self.n, self.k2 = n, k2

    def build_tree(self) -> DraftTree:
        eng = self.engine
        tree = DraftTree(eng.pending)
        node = 0
        for _ in range(self.n):
            ctx = np.concatenate(
                [np.asarray(eng.tokens, np.int32),
                 np.asarray(tree.path_tokens(node), np.int32)]
            )
            pld = eng.pld.propose(ctx, self.k2)
            path = tree.path_to(node)
            base_tokens = np.asarray([tree.tokens[i] for i in path], np.int32)
            base_rel = np.asarray([tree.depth[i] for i in path], np.int32)
            n0 = len(path)
            ext = np.concatenate([base_tokens, pld.astype(np.int32)])
            rel = np.concatenate(
                [base_rel, base_rel[-1] + 1 + np.arange(len(pld), dtype=np.int32)]
            )
            mask = np.tril(np.ones((len(ext), len(ext)), bool))
            logits = eng.draft_logits(self.spec.name, ext, rel, mask)
            nxt = np.argmax(logits, axis=-1)
            for i, t in enumerate(pld):
                if int(nxt[n0 - 1 + i]) != int(t):
                    break
                node = tree.add_child(node, int(t), self.spec.name, 0.5)
            # extend by the draft model's own token at the accepted frontier
            last_row = n0 - 1 + _accepted_prefix(nxt[n0 - 1 :], pld)
            node = tree.add_child(node, int(nxt[last_row]), self.spec.name, 0.5)
        return tree


class HCScheduler(SDScheduler):
    """Horizontal cascade: M_d1 drafts k1 early tokens, PLD appends k2."""

    def __init__(self, engine: SpecEngine, spec: DraftSpec, k1: int = 3, k2: int = 5):
        super().__init__(engine, spec, k=k1)
        self.k2 = k2

    def build_tree(self) -> DraftTree:
        tree = DraftTree(self.engine.pending)
        node = self._draft_chain(tree, 0, self.k)
        ctx = np.concatenate(
            [np.asarray(self.engine.tokens, np.int32),
             np.asarray(tree.path_tokens(node), np.int32)]
        )
        pld = self.engine.pld.propose(ctx, self.k2)
        for t in pld:
            node = tree.add_child(node, int(t), "PLD", 0.4)
        return tree


class VCHCScheduler(VCScheduler):
    """CS-Drafting: vertical + horizontal — VC rounds, then a PLD tail."""

    def __init__(self, engine: SpecEngine, spec: DraftSpec, n: int = 2, k2: int = 5, tail: int = 4):
        super().__init__(engine, spec, n=n, k2=k2)
        self.tail = tail

    def build_tree(self) -> DraftTree:
        tree = super().build_tree()
        # deepest node
        node = max(range(len(tree)), key=lambda i: tree.depth[i])
        ctx = np.concatenate(
            [np.asarray(self.engine.tokens, np.int32),
             np.asarray(tree.path_tokens(node), np.int32)]
        )
        pld = self.engine.pld.propose(ctx, self.tail)
        for t in pld:
            node = tree.add_child(node, int(t), "PLD", 0.4)
        return tree


class TreeScheduler(SDScheduler):
    """SWIFT-with-tree-attention baseline: fixed-depth top-K branching."""

    def __init__(self, engine: SpecEngine, spec: DraftSpec, depth: int = 4,
                 top_k: int = 2, max_tree: int = 16):
        super().__init__(engine, spec, k=depth)
        self.top_k, self.max_tree = top_k, max_tree

    def build_tree(self) -> DraftTree:
        tree = DraftTree(self.engine.pending)
        frontier = [0]
        for _ in range(self.k):
            nxt_frontier = []
            for node in frontier:
                if len(tree) >= self.max_tree:
                    break
                path = tree.path_to(node)
                tokens = np.asarray([tree.tokens[i] for i in path], np.int32)
                rel = np.asarray([tree.depth[i] for i in path], np.int32)
                mask = np.tril(np.ones((len(path), len(path)), bool))
                logits = self.engine.draft_logits(self.spec.name, tokens, rel, mask)
                probs = verify_lib.softmax(logits[len(path) - 1])
                top = np.argsort(-probs)[: self.top_k]
                for rank, t in enumerate(top):
                    if len(tree) >= self.max_tree:
                        break
                    c = tree.add_child(node, int(t), self.spec.name, 0.5)
                    if rank == 0:
                        nxt_frontier.append(c)
            # branch only at the first level (SpecInfer-style narrow tree)
            frontier = nxt_frontier[:1] if len(tree) > 1 + self.top_k else nxt_frontier
        return tree


class TreeVCScheduler(TreeScheduler):
    """Tree attention over the vertical cascade (Tr+VC in Fig. 3)."""

    def build_tree(self) -> DraftTree:
        tree = super().build_tree()
        node = max(range(len(tree)), key=lambda i: tree.depth[i])
        ctx = np.concatenate(
            [np.asarray(self.engine.tokens, np.int32),
             np.asarray(tree.path_tokens(node), np.int32)]
        )
        pld = self.engine.pld.propose(ctx, 4)
        for t in pld:
            if len(tree) >= self.max_tree + 4:
                break
            node = tree.add_child(node, int(t), "PLD", 0.4)
        return tree


def _accepted_prefix(nxt: np.ndarray, proposed: np.ndarray) -> int:
    n = 0
    for i, t in enumerate(proposed):
        if int(nxt[i]) != int(t):
            break
        n += 1
    return n
