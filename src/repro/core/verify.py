"""Lossless verification.

Greedy mode: the accepted path is exactly the target model's own greedy
continuation — spec-decoded output is token-identical to AR decoding.

Sampling mode: chain speculative sampling [Leviathan et al. 2023] — accept
draft token with prob min(1, p_t/p_d), else resample from the residual
distribution; distribution-preserving (lossless in law).
"""
from __future__ import annotations

from typing import List, Tuple

import numpy as np

from repro.core.tree import DraftTree


def greedy_accept_tree(
    tree: DraftTree, next_argmax: np.ndarray
) -> Tuple[List[int], int]:
    """Walk the tree following the target's argmax at every node.

    ``next_argmax[i]`` = target's argmax next-token after node i (from the
    verify forward). Returns (accepted node path incl. root, bonus token).
    """
    path = [0]
    node = 0
    while True:
        want = int(next_argmax[node])
        nxt = None
        for c in tree.children.get(node, ()):
            if tree.tokens[c] == want:
                nxt = c
                break
        if nxt is None:
            return path, want
        path.append(nxt)
        node = nxt


def greedy_accept_tree_batched(
    tokens: "jax.Array",            # (B, N) int32 node tokens (node 0 = root)
    parents: "jax.Array",           # (B, N) int32, -1 at root/unused
    count: "jax.Array",             # (B,) int32 real nodes per slot
    next_argmax: "jax.Array",       # (B, N) int32 target argmax after each node
) -> Tuple["jax.Array", "jax.Array", "jax.Array"]:
    """Vectorized ``greedy_accept_tree`` over a batch of padded device trees.

    Walks every slot's tree following the target's argmax at each node —
    the accepted path is exactly the target model's own greedy continuation,
    so committing it is lossless. One ``fori_loop`` of N-1 masked steps (max
    path length), no host sync.

    Returns (path_idx (B, N) int32 — accepted node indices in path order,
    zero-padded; n_acc (B,) int32 — accepted nodes incl. the root; bonus
    (B,) int32 — the target's next token after the last accepted node).
    """
    import jax
    import jax.numpy as jnp

    B, N = tokens.shape
    b_idx = jnp.arange(B)
    real = jnp.arange(N)[None, :] < count[:, None]

    def step(_, carry):
        node, n_acc, done, path = carry
        want = jnp.take_along_axis(next_argmax, node[:, None], 1)[:, 0]
        cand = real & (parents == node[:, None]) & (tokens == want[:, None])
        found = cand.any(axis=1) & ~done
        child = jnp.argmax(cand, axis=1).astype(jnp.int32)  # first matching child
        path = path.at[b_idx, jnp.where(found, n_acc, N)].set(child, mode="drop")
        node = jnp.where(found, child, node)
        n_acc = n_acc + found.astype(jnp.int32)
        return node, n_acc, done | ~found, path

    node0 = jnp.zeros((B,), jnp.int32)
    carry = (node0, jnp.ones((B,), jnp.int32), jnp.zeros((B,), bool),
             jnp.zeros((B, N), jnp.int32))
    node, n_acc, _, path = jax.lax.fori_loop(0, N - 1, step, carry)
    bonus = jnp.take_along_axis(next_argmax, node[:, None], 1)[:, 0]
    return path, n_acc, bonus


def sampling_probs(
    logits: "jax.Array",            # (B, V) or (B, T, V) float logits
    temperature: "jax.Array",       # (B,) float32, <= 0 -> greedy point mass
    top_k: "jax.Array",             # (B,) int32, <= 0 -> no top-k filter
    top_p: "jax.Array",             # (B,) float32, >= 1 -> no nucleus filter
) -> "jax.Array":
    """Warped target distribution q per slot (device twin of
    ``serving.sampler.warp_probs``).

    Exact-k top-k with stable index tie-break (jnp.argsort is stable, so
    ties at the kth value keep the LOWEST token indices — matching
    lax.top_k and the host reference), exclusive-cumulative top-p (keep a
    token iff the sorted mass strictly BEFORE it is < top_p), and a greedy
    reduction: slots with temperature <= 0 get a one-hot at argmax, which
    makes every downstream accept/resample kernel reproduce the greedy
    kernels token-for-token.
    """
    import jax
    import jax.numpy as jnp

    squeeze = logits.ndim == 2
    if squeeze:
        logits = logits[:, None, :]
    V = logits.shape[-1]
    t = temperature[:, None, None]
    k = top_k[:, None, None]
    tp = top_p[:, None, None]
    x = logits.astype(jnp.float32) / jnp.maximum(t, 1e-6)
    order = jnp.argsort(-x, axis=-1)            # stable: ties -> lower index
    rank = jnp.argsort(order, axis=-1)
    x = jnp.where((k <= 0) | (rank < k), x, -jnp.inf)
    p = jax.nn.softmax(x, axis=-1)
    p_sorted = jnp.take_along_axis(p, order, axis=-1)
    cum = jnp.cumsum(p_sorted, axis=-1)
    keep_sorted = (cum - p_sorted) < jnp.maximum(tp, 1e-9)
    p = jnp.where(jnp.take_along_axis(keep_sorted, rank, axis=-1), p, 0.0)
    p = p / jnp.maximum(p.sum(-1, keepdims=True), 1e-30)
    onehot = jax.nn.one_hot(jnp.argmax(logits, -1), V, dtype=p.dtype)
    q = jnp.where(t <= 0.0, onehot, p)
    return q[:, 0] if squeeze else q


def _inv_cdf(p: "jax.Array", u: "jax.Array") -> "jax.Array":
    """Deterministic inverse-CDF draw from unnormalized nonneg (B, V) mass
    rows at uniforms u (B,) in [0, 1): first index whose inclusive
    cumulative mass exceeds u * total."""
    import jax.numpy as jnp

    cum = jnp.cumsum(p, axis=-1)
    return jnp.argmax(cum > u[:, None] * cum[:, -1:], axis=-1).astype(jnp.int32)


def round_uniforms(keys: "jax.Array", n: int) -> Tuple["jax.Array", "jax.Array"]:
    """Split per-slot threefry keys (B, 2) uint32 in-dispatch and draw n
    uniforms per slot. Returns (new_keys (B, 2), u (B, n) float32). The keys
    are carried device state — splitting here keeps the PRNG stream inside
    the round executable, never host-materialized."""
    import jax

    sub = jax.vmap(lambda k: jax.random.split(k, 2))(keys)
    u = jax.vmap(lambda k: jax.random.uniform(k, (n,)))(sub[:, 1])
    return sub[:, 0], u


def sample_accept_chain_batched(
    chains: "jax.Array",            # (B, K) int32 drafted chain tokens
    have: "jax.Array",              # (B,) int32 real drafted tokens per slot
    q: "jax.Array",                 # (B, K+1, V) warped target dist per position
    u_acc: "jax.Array",             # (B, K) accept uniforms
    u_next: "jax.Array",            # (B,) residual/bonus uniform
) -> Tuple["jax.Array", "jax.Array"]:
    """Batched speculative sampling acceptance for point-mass drafts.

    The self-drafts in this repo are deterministic (PLD lookup / argmax
    neural draft), i.e. the draft distribution is a one-hot at the proposed
    token — so Leviathan's accept-with-prob min(1, q/p_d) reduces to
    ``u < q[token]`` and the residual at the rejection point is q with the
    rejected token zeroed, renormalized. All-accepted slots draw the bonus
    token from the (K+1)-th row. Returns (n_chain (B,) accepted drafted
    tokens, next_tok (B,) — residual resample or bonus draw).

    With greedy (one-hot) q this is exactly the greedy rule: ``u < q[tok]``
    accepts iff tok == argmax, and the inverse-CDF draw on a one-hot row
    returns the argmax — token-identical to the greedy verify.
    """
    import jax.numpy as jnp

    B, K = chains.shape
    V = q.shape[-1]
    tok_q = jnp.take_along_axis(q[:, :K], chains[..., None], axis=-1)[..., 0]
    ok = (jnp.arange(K)[None, :] < have[:, None]) & (u_acc < tok_q)
    n_chain = jnp.cumprod(ok.astype(jnp.int32), axis=1).sum(axis=1)
    row = jnp.take_along_axis(q, n_chain[:, None, None], axis=1)[:, 0]
    rejected = n_chain < have
    rej_pos = jnp.minimum(n_chain, K - 1)
    rej_tok = jnp.take_along_axis(chains, rej_pos[:, None], axis=1)[:, 0]
    zero = rejected[:, None] & (jnp.arange(V)[None, :] == rej_tok[:, None])
    resid = jnp.where(zero, 0.0, row)
    use = jnp.where(resid.sum(-1, keepdims=True) > 0, resid, row)
    return n_chain, _inv_cdf(use, u_next)


def sample_accept_tree_batched(
    tokens: "jax.Array",            # (B, N) int32 node tokens (node 0 = root)
    parents: "jax.Array",           # (B, N) int32, -1 at root/unused
    count: "jax.Array",             # (B,) int32 real nodes per slot
    q: "jax.Array",                 # (B, N, V) warped target dist after each node
    u: "jax.Array",                 # (B, N) one uniform per walk step
) -> Tuple["jax.Array", "jax.Array", "jax.Array"]:
    """Stochastic tree walk: the tree-native speculative-sampling rule for
    point-mass drafts (SpecInfer-style sequential sibling fallback).

    At each node the children c_1..c_m (index order, tokens distinct by
    draft-time dedup) are tried in sequence, child c_j accepted with prob
    q(x_j) / (1 - sum_{i<j} q(x_i)); equivalently ONE uniform per step
    drives an inverse-CDF over the segments [q(x_1), .., q(x_m), rest]:
    accept the first child whose inclusive cumulative mass exceeds u, and
    if u falls in the trailing ``rest`` segment stop and resample from the
    residual (q with every child token zeroed) using the leftover uniform
    rescaled — exact in law AND deterministic given u, so the host oracle
    (``sample_accept_tree_host``) replays it bit-for-bit.

    One fori_loop of N masked steps (one MORE than the greedy walk: a
    fully-accepted maximal chain still needs its leaf step to draw the
    bonus token). Returns (path_idx (B, N), n_acc (B,), next_tok (B,)).
    With greedy one-hot q the walk follows argmax-matching children and
    the stop-step draw returns argmax — token-identical to
    ``greedy_accept_tree_batched``.
    """
    import jax
    import jax.numpy as jnp

    B, N = tokens.shape
    V = q.shape[-1]
    b_idx = jnp.arange(B)
    real = jnp.arange(N)[None, :] < count[:, None]

    def step(s, carry):
        node, n_acc, done, path, nxt_tok = carry
        u_s = u[:, s]
        q_v = jnp.take_along_axis(q, node[:, None, None], axis=1)[:, 0]
        is_child = real & (parents == node[:, None])
        m = jnp.take_along_axis(q_v, tokens, axis=1) * is_child
        cum = jnp.cumsum(m, axis=1)
        S = cum[:, -1]
        hit = is_child & (m > 0) & (cum > u_s[:, None])
        found = hit.any(axis=1) & ~done
        child = jnp.argmax(hit, axis=1).astype(jnp.int32)
        # no child segment contains u -> stop here: residual resample with
        # the leftover uniform rescaled onto [0, 1)
        stop_now = ~done & ~found
        u_left = jnp.clip((u_s - S) / jnp.maximum(1.0 - S, 1e-9),
                          0.0, 1.0 - 1e-7)
        resid = q_v.at[b_idx[:, None], jnp.where(is_child, tokens, V)].set(
            0.0, mode="drop")
        use = jnp.where(resid.sum(-1, keepdims=True) > 0, resid, q_v)
        draw = _inv_cdf(use, u_left)
        nxt_tok = jnp.where(stop_now, draw, nxt_tok)
        path = path.at[b_idx, jnp.where(found, n_acc, N)].set(child, mode="drop")
        node = jnp.where(found, child, node)
        n_acc = n_acc + found.astype(jnp.int32)
        return node, n_acc, done | ~found, path, nxt_tok

    carry = (jnp.zeros((B,), jnp.int32), jnp.ones((B,), jnp.int32),
             jnp.zeros((B,), bool), jnp.zeros((B, N), jnp.int32),
             jnp.zeros((B,), jnp.int32))
    _, n_acc, _, path, nxt_tok = jax.lax.fori_loop(0, N, step, carry)
    return path, n_acc, nxt_tok


def sample_accept_chain_host(
    chains: np.ndarray, have: int, q: np.ndarray,
    u_acc: np.ndarray, u_next: float,
) -> Tuple[int, int]:
    """Host oracle twin of ``sample_accept_chain_batched`` for ONE slot:
    identical accept rule and inverse-CDF residual/bonus draw under the
    same explicit uniforms. (chains (K,), q (K+1, V), u_acc (K,).)"""
    K = len(chains)
    n = 0
    while n < min(have, K) and u_acc[n] < q[n, chains[n]]:
        n += 1
    row = np.asarray(q[n], np.float64).copy()
    if n < have:
        row[int(chains[n])] = 0.0
        if row.sum() <= 0:
            row = np.asarray(q[n], np.float64)
    cum = np.cumsum(row)
    return n, int(np.argmax(cum > u_next * cum[-1]))


def sample_accept_tree_host(
    tokens: np.ndarray, parents: np.ndarray, count: int,
    q: np.ndarray, u: np.ndarray,
) -> Tuple[List[int], int, int]:
    """Host oracle twin of ``sample_accept_tree_batched`` for ONE slot: the
    sequential sibling walk written plainly. Returns (path node indices
    incl. root, n_acc, next_token)."""
    path = [0]
    node = 0
    for s in range(len(tokens)):
        u_s = float(u[s])
        q_v = np.asarray(q[node], np.float64)
        kids = [j for j in range(count) if parents[j] == node]
        acc = 0.0
        nxt = None
        for c in kids:
            mass = float(q_v[int(tokens[c])])
            if mass > 0 and acc + mass > u_s:
                nxt = c
                break
            acc += mass
        if nxt is not None:
            path.append(nxt)
            node = nxt
            continue
        u_left = min(max((u_s - acc) / max(1.0 - acc, 1e-9), 0.0), 1.0 - 1e-7)
        resid = q_v.copy()
        for c in kids:
            resid[int(tokens[c])] = 0.0
        if resid.sum() <= 0:
            resid = q_v
        cum = np.cumsum(resid)
        return path, len(path), int(np.argmax(cum > u_left * cum[-1]))
    raise AssertionError("walk must stop within N steps")


def spec_sample_chain(
    draft_tokens: np.ndarray,       # (k,)
    draft_probs: np.ndarray,        # (k, V) draft distribution per position
    target_probs: np.ndarray,       # (k+1, V) target distribution (incl. bonus)
    rng: np.random.Generator,
) -> Tuple[int, int]:
    """Returns (n_accepted, next_token). next_token is the residual-resampled
    token at the rejection point, or a fresh sample from the bonus position
    when everything is accepted."""
    k = len(draft_tokens)
    for i in range(k):
        tok = int(draft_tokens[i])
        p_t = float(target_probs[i, tok])
        p_d = float(draft_probs[i, tok])
        if p_d <= 0.0 or rng.random() < min(1.0, p_t / max(p_d, 1e-30)):
            if p_d <= 0.0 and p_t <= 0.0:
                pass  # fall through to rejection
            else:
                continue
        residual = np.clip(target_probs[i] - draft_probs[i], 0.0, None)
        z = residual.sum()
        if z <= 0:
            residual = target_probs[i]
            z = residual.sum()
        nxt = int(rng.choice(len(residual), p=residual / z))
        return i, nxt
    p = target_probs[k]
    nxt = int(rng.choice(len(p), p=p / p.sum()))
    return k, nxt


def softmax(x: np.ndarray, temperature: float = 1.0, axis: int = -1) -> np.ndarray:
    x = np.asarray(x, np.float64) / max(temperature, 1e-6)
    x = x - x.max(axis=axis, keepdims=True)
    e = np.exp(x)
    return e / e.sum(axis=axis, keepdims=True)
