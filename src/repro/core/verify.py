"""Lossless verification.

Greedy mode: the accepted path is exactly the target model's own greedy
continuation — spec-decoded output is token-identical to AR decoding.

Sampling mode: chain speculative sampling [Leviathan et al. 2023] — accept
draft token with prob min(1, p_t/p_d), else resample from the residual
distribution; distribution-preserving (lossless in law).
"""
from __future__ import annotations

from typing import List, Tuple

import numpy as np

from repro.core.tree import DraftTree


def greedy_accept_tree(
    tree: DraftTree, next_argmax: np.ndarray
) -> Tuple[List[int], int]:
    """Walk the tree following the target's argmax at every node.

    ``next_argmax[i]`` = target's argmax next-token after node i (from the
    verify forward). Returns (accepted node path incl. root, bonus token).
    """
    path = [0]
    node = 0
    while True:
        want = int(next_argmax[node])
        nxt = None
        for c in tree.children.get(node, ()):
            if tree.tokens[c] == want:
                nxt = c
                break
        if nxt is None:
            return path, want
        path.append(nxt)
        node = nxt


def greedy_accept_tree_batched(
    tokens: "jax.Array",            # (B, N) int32 node tokens (node 0 = root)
    parents: "jax.Array",           # (B, N) int32, -1 at root/unused
    count: "jax.Array",             # (B,) int32 real nodes per slot
    next_argmax: "jax.Array",       # (B, N) int32 target argmax after each node
) -> Tuple["jax.Array", "jax.Array", "jax.Array"]:
    """Vectorized ``greedy_accept_tree`` over a batch of padded device trees.

    Walks every slot's tree following the target's argmax at each node —
    the accepted path is exactly the target model's own greedy continuation,
    so committing it is lossless. One ``fori_loop`` of N-1 masked steps (max
    path length), no host sync.

    Returns (path_idx (B, N) int32 — accepted node indices in path order,
    zero-padded; n_acc (B,) int32 — accepted nodes incl. the root; bonus
    (B,) int32 — the target's next token after the last accepted node).
    """
    import jax
    import jax.numpy as jnp

    B, N = tokens.shape
    b_idx = jnp.arange(B)
    real = jnp.arange(N)[None, :] < count[:, None]

    def step(_, carry):
        node, n_acc, done, path = carry
        want = jnp.take_along_axis(next_argmax, node[:, None], 1)[:, 0]
        cand = real & (parents == node[:, None]) & (tokens == want[:, None])
        found = cand.any(axis=1) & ~done
        child = jnp.argmax(cand, axis=1).astype(jnp.int32)  # first matching child
        path = path.at[b_idx, jnp.where(found, n_acc, N)].set(child, mode="drop")
        node = jnp.where(found, child, node)
        n_acc = n_acc + found.astype(jnp.int32)
        return node, n_acc, done | ~found, path

    node0 = jnp.zeros((B,), jnp.int32)
    carry = (node0, jnp.ones((B,), jnp.int32), jnp.zeros((B,), bool),
             jnp.zeros((B, N), jnp.int32))
    node, n_acc, _, path = jax.lax.fori_loop(0, N - 1, step, carry)
    bonus = jnp.take_along_axis(next_argmax, node[:, None], 1)[:, 0]
    return path, n_acc, bonus


def spec_sample_chain(
    draft_tokens: np.ndarray,       # (k,)
    draft_probs: np.ndarray,        # (k, V) draft distribution per position
    target_probs: np.ndarray,       # (k+1, V) target distribution (incl. bonus)
    rng: np.random.Generator,
) -> Tuple[int, int]:
    """Returns (n_accepted, next_token). next_token is the residual-resampled
    token at the rejection point, or a fresh sample from the bonus position
    when everything is accepted."""
    k = len(draft_tokens)
    for i in range(k):
        tok = int(draft_tokens[i])
        p_t = float(target_probs[i, tok])
        p_d = float(draft_probs[i, tok])
        if p_d <= 0.0 or rng.random() < min(1.0, p_t / max(p_d, 1e-30)):
            if p_d <= 0.0 and p_t <= 0.0:
                pass  # fall through to rejection
            else:
                continue
        residual = np.clip(target_probs[i] - draft_probs[i], 0.0, None)
        z = residual.sum()
        if z <= 0:
            residual = target_probs[i]
            z = residual.sum()
        nxt = int(rng.choice(len(residual), p=residual / z))
        return i, nxt
    p = target_probs[k]
    nxt = int(rng.choice(len(p), p=p / p.sum()))
    return k, nxt


def softmax(x: np.ndarray, temperature: float = 1.0, axis: int = -1) -> np.ndarray:
    x = np.asarray(x, np.float64) / max(temperature, 1e-6)
    x = x - x.max(axis=axis, keepdims=True)
    e = np.exp(x)
    return e / e.sum(axis=axis, keepdims=True)
