"""Online acceptance-rate estimation (Eq. 4).

EMA over a local history window of *first-token* acceptance outcomes:
  a_new = lambda * a_prev + (1 - lambda) * mean(last H outcomes)

Estimates for inactive configurations are preserved (Appendix D); cold-start
uses heuristic priors based on DSIA aggressiveness.
"""
from __future__ import annotations

from collections import deque
from typing import Deque, Dict, Optional


class AcceptanceTracker:
    def __init__(self, lam: float = 0.7, window: int = 20, prior: float = 0.5):
        self.lam = lam
        self.window = window
        self.prior = prior
        self._alpha: Dict[str, float] = {}
        self._hist: Dict[str, Deque[float]] = {}

    def set_prior(self, config: str, alpha0: float) -> None:
        self._alpha.setdefault(config, float(alpha0))

    def observe(self, config: str, first_token_accepted: bool) -> None:
        h = self._hist.setdefault(config, deque(maxlen=self.window))
        h.append(1.0 if first_token_accepted else 0.0)
        recent = sum(h) / len(h)
        prev = self._alpha.get(config, self.prior)
        self._alpha[config] = self.lam * prev + (1.0 - self.lam) * recent

    def alpha(self, config: str, default: Optional[float] = None) -> float:
        """Current estimate; ``default`` overrides the global cold-start
        prior for configurations with their own App. D heuristic (e.g. the
        per-level priors a ``DraftSpec`` carries)."""
        return self._alpha.get(config, self.prior if default is None else default)

    def reset(self, config: str, alpha0: Optional[float] = None) -> None:
        """Drop a configuration's history (e.g. a server slot being reused
        by a new request under continuous batching); optionally re-seed the
        cold-start prior."""
        self._alpha.pop(config, None)
        self._hist.pop(config, None)
        if alpha0 is not None:
            self.set_prior(config, alpha0)

    def counts(self, config: str) -> int:
        return len(self._hist.get(config, ()))

    def snapshot(self) -> Dict[str, float]:
        return dict(self._alpha)
