"""Online acceptance-rate estimation (Eq. 4).

EMA over a local history window of *first-token* acceptance outcomes:
  a_new = lambda * a_prev + (1 - lambda) * mean(last H outcomes)

Estimates for inactive configurations are preserved (Appendix D); cold-start
uses heuristic priors based on DSIA aggressiveness.

Two implementations with pinned identical semantics:

  - ``AcceptanceTracker`` — host-side, per-config string keys (the split
    serving rounds and the B=1 engine). The reference implementation.
  - ``ema_init``/``ema_update`` — the same estimator as per-slot device
    arrays (alpha + an outcome ring buffer), pure jnp, carried through the
    single-dispatch serving round so round r+1's Eq. 5 budgets are computed
    inside round r's executable (tests/test_device_round_parity.py pins the
    host/device parity).
"""
from __future__ import annotations

from collections import deque
from typing import Deque, Dict, Optional

EMA_LAM = 0.7
EMA_WINDOW = 20


def ema_init(batch: int, window: int = EMA_WINDOW, prior: float = 0.5):
    """Device-array form of a fresh per-slot ``AcceptanceTracker``: returns
    ``(alpha (B,) f32, hist (B, W) f32, hist_n (B,) i32, hist_ptr (B,) i32)``."""
    import jax.numpy as jnp

    return (
        jnp.full((batch,), prior, jnp.float32),
        jnp.zeros((batch, window), jnp.float32),
        jnp.zeros((batch,), jnp.int32),
        jnp.zeros((batch,), jnp.int32),
    )


def ema_update(alpha, hist, hist_n, hist_ptr, outcome, valid, lam: float = EMA_LAM):
    """One vectorized ``AcceptanceTracker.observe`` over per-slot arrays.

    ``outcome`` (B,) f32 in {0, 1}; slots where ``valid`` is False pass
    through untouched (no observation this round). The ring buffer holds the
    last ``W`` outcomes — its masked mean equals the host deque's mean, so
    the device alpha tracks the host tracker exactly (up to f32)."""
    import jax.numpy as jnp

    B, W = hist.shape
    b_idx = jnp.arange(B)
    hist = hist.at[b_idx, jnp.where(valid, hist_ptr, W)].set(
        outcome.astype(jnp.float32), mode="drop"
    )
    hist_n = jnp.where(valid, jnp.minimum(hist_n + 1, W), hist_n)
    hist_ptr = jnp.where(valid, (hist_ptr + 1) % W, hist_ptr)
    live_rows = jnp.arange(W)[None, :] < hist_n[:, None]
    recent = (hist * live_rows).sum(axis=1) / jnp.maximum(hist_n, 1)
    alpha = jnp.where(valid, lam * alpha + (1.0 - lam) * recent, alpha)
    return alpha, hist, hist_n, hist_ptr


class AcceptanceTracker:
    def __init__(self, lam: float = EMA_LAM, window: int = EMA_WINDOW, prior: float = 0.5):
        self.lam = lam
        self.window = window
        self.prior = prior
        self._alpha: Dict[str, float] = {}
        self._hist: Dict[str, Deque[float]] = {}

    def set_prior(self, config: str, alpha0: float) -> None:
        self._alpha.setdefault(config, float(alpha0))

    def observe(self, config: str, first_token_accepted: bool) -> None:
        h = self._hist.setdefault(config, deque(maxlen=self.window))
        h.append(1.0 if first_token_accepted else 0.0)
        recent = sum(h) / len(h)
        prev = self._alpha.get(config, self.prior)
        self._alpha[config] = self.lam * prev + (1.0 - self.lam) * recent

    def alpha(self, config: str, default: Optional[float] = None) -> float:
        """Current estimate; ``default`` overrides the global cold-start
        prior for configurations with their own App. D heuristic (e.g. the
        per-level priors a ``DraftSpec`` carries)."""
        return self._alpha.get(config, self.prior if default is None else default)

    def reset(self, config: str, alpha0: Optional[float] = None) -> None:
        """Drop a configuration's history (e.g. a server slot being reused
        by a new request under continuous batching); optionally re-seed the
        cold-start prior."""
        self._alpha.pop(config, None)
        self._hist.pop(config, None)
        if alpha0 is not None:
            self.set_prior(config, alpha0)

    def counts(self, config: str) -> int:
        return len(self._hist.get(config, ()))

    def snapshot(self) -> Dict[str, float]:
        return dict(self._alpha)
