"""EWIF theory from §3 and Appendix B of CAS-Spec (following CS-Drafting).

Expected Walltime Improvement Factor under i.i.d. Bernoulli acceptance:

  T_SD(a, c, k)  — vanilla speculative decoding, Eq. in §3
  T_VC           — vertical cascade (Eq. 1)
  T_HC           — horizontal cascade (Eq. 2)
  bounds         — Appendix B effective bounds on c_d1
  optimal-k search + the paper's §4.2 worked example are covered in tests.

All functions are plain-float (host math — used by the DyTC scheduler), with
numpy-vectorized variants where the benchmarks sweep grids.
"""
from __future__ import annotations

import math
from typing import Sequence, Tuple

import numpy as np


def t_sd(alpha: float, c: float, k: int) -> float:
    """EWIF of vanilla SD: (1 - a^{k+1}) / ((1-a)(ck + 1))."""
    if alpha >= 1.0:
        return (k + 1) / (c * k + 1)
    return (1.0 - alpha ** (k + 1)) / ((1.0 - alpha) * (c * k + 1.0))


def t_sd_grid(alpha, c, k_max: int):
    """Vectorized ``t_sd`` over slots and chain lengths, jnp.

    ``alpha`` (B,) f32, ``c`` scalar, static ``k_max``: returns a
    ``(B, k_max + 1)`` grid of T_SD(alpha_b, c, k) for k = 0..k_max —
    the device form of the per-slot Eq. 5 searches, traced into the
    single-dispatch serving round (k=0 is plain AR, exactly 1.0)."""
    import jax.numpy as jnp

    ks = jnp.arange(k_max + 1, dtype=jnp.float32)[None, :]
    a = alpha.astype(jnp.float32)[:, None]
    a_safe = jnp.minimum(a, 1.0 - 1e-9)
    v = (1.0 - a_safe ** (ks + 1.0)) / ((1.0 - a_safe) * (c * ks + 1.0))
    return jnp.where(a >= 1.0, (ks + 1.0) / (c * ks + 1.0), v)


def dytc_objective_grid(alpha, c, k_max: int):
    """Vectorized ``dytc_step_objective`` with the drafter as its own
    continuation (alpha_dn = alpha, c_dn = c — the homogeneous-hierarchy
    specialization ``best_tree_expansions`` searches). Returns a
    ``(B, k_max)`` grid over k = 1..k_max, jnp."""
    import jax.numpy as jnp

    ks = jnp.arange(1, k_max + 1, dtype=jnp.float32)[None, :]
    a = alpha.astype(jnp.float32)[:, None]
    a_safe = jnp.minimum(a, 1.0 - 1e-9)
    e_acc = jnp.where(
        a >= 1.0, ks, a_safe * (1.0 - a_safe ** ks) / (1.0 - a_safe)
    )
    return (e_acc + (a_safe ** ks) * a_safe) / (c * ks + c)


def expected_accepted(alpha: float, k: int) -> float:
    """E[# accepted draft tokens] = a(1-a^k)/(1-a)."""
    if alpha >= 1.0:
        return float(k)
    return alpha * (1.0 - alpha ** k) / (1.0 - alpha)


def phi_sd(alpha: float, c: float, k: int) -> float:
    """Inner-stage EWIF used in the Appendix-B vertical-cascade bound."""
    return t_sd(alpha, c, k)


def t_vc(
    alpha_t_d1: float,
    alpha_d1_d2: float,
    c_d1: float,
    c_d2: float,
    n: int,
    k: int,
) -> float:
    """Vertical cascade EWIF (Eq. 1 / Appendix B form).

    M_d1 drafts n rounds for the target; each M_d1 round is itself
    accelerated by M_d2 drafting k tokens (e.g. PLD under a layer-sparse
    draft). phi is the EWIF of the inner (M_d1, M_d2) stage.
    """
    a = alpha_t_d1
    # Eq. 1: T_VC = (1 - a·phi^n(a)) / ((1-a)(1 + n c_d1 + n k c_d2)).
    # Under the i.i.d. Bernoulli model, phi is the pgf of the inner
    # (M_d1, M_d2) stage and a·phi^n(a) = a^{n·E_inner} where E_inner is the
    # expected tokens produced per inner round, (1 - a2^{k+1}) / (1 - a2).
    a2 = alpha_d1_d2
    e_inner = (1.0 - a2 ** (k + 1)) / (1.0 - a2) if a2 < 1 else float(k + 1)
    den_time = 1.0 + n * c_d1 + n * k * c_d2
    if a >= 1.0:
        return (n * e_inner) / den_time
    return (1.0 - a ** (n * e_inner)) / ((1.0 - a) * den_time)


def t_hc(
    alpha_d1: float,
    alpha_d2: float,
    c_d1: float,
    c_d2: float,
    k_d1: int,
    k_d2: int,
) -> float:
    """Horizontal cascade EWIF (Eq. 2): early tokens by the better draft."""
    a1, a2 = alpha_d1, alpha_d2
    num1 = (1.0 - a1 ** (k_d1 + 1)) / (1.0 - a1) if a1 < 1 else k_d1 + 1
    num2 = a1 ** k_d1 * (a2 * (1.0 - a2 ** k_d2) / (1.0 - a2) if a2 < 1 else k_d2)
    den = 1.0 + k_d1 * c_d1 + k_d2 * c_d2
    return (num1 + num2) / den


def best_sd(alpha: float, c: float, k_max: int = 32) -> Tuple[float, int]:
    vals = [(t_sd(alpha, c, k), k) for k in range(1, k_max + 1)]
    return max(vals)


def best_hc(
    alpha_d1: float, alpha_d2: float, c_d1: float, c_d2: float, k_max: int = 16
) -> Tuple[float, Tuple[int, int]]:
    best = (-1.0, (1, 1))
    for k1 in range(1, k_max + 1):
        for k2 in range(0, k_max + 1):
            v = t_hc(alpha_d1, alpha_d2, c_d1, c_d2, k1, k2)
            if v > best[0]:
                best = (v, (k1, k2))
    return best


def best_vc(
    alpha_t_d1: float,
    alpha_d1_d2: float,
    c_d1: float,
    c_d2: float,
    n_max: int = 8,
    k_max: int = 16,
) -> Tuple[float, Tuple[int, int]]:
    best = (-1.0, (1, 1))
    for n in range(1, n_max + 1):
        for k in range(1, k_max + 1):
            v = t_vc(alpha_t_d1, alpha_d1_d2, c_d1, c_d2, n, k)
            if v > best[0]:
                best = (v, (n, k))
    return best


# --------------------------------------------------------- Appendix B bounds
def hc_bound_c_d1(
    alpha_d1: float, alpha_d2: float, c_d2: float, k_d1: int, k_d2: int, k_0: int
) -> float:
    """Max c_d1 such that T_HC >= T_SD(M_d2) at the given hyperparameters."""
    a1, a2 = alpha_d1, alpha_d2
    num1 = (1.0 - a1 ** (k_d1 + 1)) / (1.0 - a1)
    num2 = a1 ** k_d1 * a2 * (1.0 - a2 ** k_d2) / (1.0 - a2)
    rhs = (1.0 - a2) * (c_d2 * k_d2 + 1.0) / (1.0 - a2 ** (k_d2 + 1))
    # NOTE: Appendix B writes the SD reference at k_d2; we use k_0 for the
    # standalone-SD leg per the inequality T_HC >= T_SD(M_d2; k_0).
    rhs0 = (1.0 - a2) * (c_d2 * k_0 + 1.0) / (1.0 - a2 ** (k_0 + 1))
    return ((num1 + num2) * rhs0 - (1.0 + k_d2 * c_d2)) / k_d1


def vc_bound_c_d1_numeric(
    alpha_t_d1: float,
    alpha_d1_d2: float,
    alpha_t_d2: float,
    c_d2: float,
    n_max: int = 8,
    k_max: int = 16,
    tol: float = 1e-4,
) -> float:
    """Largest c_d1 with max-hyperparam T_VC >= max-hyperparam T_SD(M_d2).

    Eq. 3 has no closed form over the integer hyperparameters — numeric
    bisection over c_d1, exactly as the paper's simulation (Fig. 1b).
    """
    target, _ = best_sd(alpha_t_d2, c_d2)
    lo, hi = 0.0, 1.0
    if best_vc(alpha_t_d1, alpha_d1_d2, lo, c_d2, n_max, k_max)[0] < target:
        return 0.0
    while hi - lo > tol:
        mid = 0.5 * (lo + hi)
        if best_vc(alpha_t_d1, alpha_d1_d2, mid, c_d2, n_max, k_max)[0] >= target:
            lo = mid
        else:
            hi = mid
    return lo


def hc_bound_c_d1_numeric(
    alpha_t_d1: float,
    alpha_t_d2: float,
    c_d2: float,
    k_max: int = 16,
    tol: float = 1e-4,
) -> float:
    """Largest c_d1 with max-hyperparam T_HC >= max-hyperparam T_SD(M_d2)."""
    target, _ = best_sd(alpha_t_d2, c_d2)
    lo, hi = 0.0, 1.0
    if best_hc(alpha_t_d1, alpha_t_d2, lo, c_d2, k_max)[0] < target:
        return 0.0
    while hi - lo > tol:
        mid = 0.5 * (lo + hi)
        if best_hc(alpha_t_d1, alpha_t_d2, mid, c_d2, k_max)[0] >= target:
            lo = mid
        else:
            hi = mid
    return lo


# -------------------------------------------------- multi-level cascade EWIF
def t_cascade(alphas: Sequence[float], cs: Sequence[float], k: int) -> float:
    """EWIF of an L-level vertical draft cascade, one inner round per level.

    Generalizes Eq. 1 / ``t_vc`` to the fused serving runtime, where the
    cheapest level drafts ``k`` tokens in one scan and every stronger level
    verifies-and-extends the proposal in ONE block forward before the target
    verifies (``cascade_fused``):

      - ``alphas[0]``   — target's acceptance of the strongest level's tokens
      - ``alphas[i>0]`` — level i-1's acceptance of level i's tokens
      - ``cs[i]``       — cost coefficient of level i (vs one target forward)

    Time per round: ``cs[-1]*k`` (the drafting scan) + one block forward per
    rescoring level (``sum(cs[:-1])``) + 1 (target verify). Tokens per
    round: the endorsement recursion — each level turns an e-token proposal
    into an expected ``(1 - a^{e+1}) / (1 - a)`` endorsed chain (accepted
    prefix + its own one-token extension), and the target's acceptance of
    the final chain uses the same form.
    """
    if len(alphas) != len(cs) or not alphas:
        raise ValueError("alphas and cs must be equal-length, non-empty")
    e = float(k)
    for a in reversed(list(alphas)):           # cheapest-adjacent level first
        a = min(float(a), 1.0 - 1e-9)
        e = (1.0 - a ** (e + 1.0)) / (1.0 - a)
    # after folding alphas[0] the recursion already counts the bonus token
    time = 1.0 + cs[-1] * k + sum(cs[:-1])
    return e / time


def best_cascade_k(
    alphas: Sequence[float], cs: Sequence[float], k_max: int
) -> Tuple[float, int]:
    """argmax_k of the cascade EWIF (the Eq. 5 budget for the cheapest
    level's drafting scan). Returns (best value, best k); k=0 means the
    cascade never beats plain verification."""
    best_v, best_k = -math.inf, 0
    for k in range(1, max(k_max, 0) + 1):
        v = t_cascade(alphas, cs, k)
        if v > best_v:
            best_v, best_k = v, k
    return best_v, best_k


# ------------------------------------------------------------- DyTC objective
def dytc_step_objective(
    alpha: float, c: float, k: int, alpha_dn: float, c_dn: float
) -> float:
    """Eq. 5 admissible objective: (E_acc + a^k a_dn) / (c k + c_dn)."""
    if c * k + c_dn <= 1e-12:
        return -math.inf
    e_acc = k if alpha >= 1.0 else alpha * (1.0 - alpha ** k) / (1.0 - alpha)
    return (e_acc + (alpha ** k) * alpha_dn) / (c * k + c_dn)


def best_dytc_k(
    alpha: float, c: float, alpha_dn: float, c_dn: float, k_max: int
) -> Tuple[float, int]:
    """argmax_k of the Eq. 5 objective for one configuration.

    Shared by the host DyTC scheduler (per candidate configuration) and the
    batched server's per-slot tree budgets. Returns (best value, best k).
    """
    best_v, best_k = -math.inf, 0
    for k in range(1, max(k_max, 0) + 1):
        v = dytc_step_objective(alpha, c, k, alpha_dn, c_dn)
        if v > best_v:
            best_v, best_k = v, k
    return best_v, best_k


def greedy_step_objective(alpha: float, c: float, k: int) -> float:
    """Greedy local speedup (the §4.2 strawman): a(1-a^k)/((1-a) c k)."""
    if c * k <= 1e-12:
        return math.inf
    e_acc = k if alpha >= 1.0 else alpha * (1.0 - alpha ** k) / (1.0 - alpha)
    return e_acc / (c * k)


# -------------------------------------------------- Monte-Carlo cross-check
def simulate_ewif_sd(
    alpha: float, c: float, k: int, steps: int = 20000, seed: int = 0
) -> float:
    """MC estimate of SD EWIF under i.i.d. Bernoulli acceptance."""
    rng = np.random.default_rng(seed)
    acc = rng.random((steps, k)) < alpha
    # tokens per round: accepted prefix + 1 bonus
    prefix = np.argmin(acc, axis=1)
    prefix = np.where(acc.all(axis=1), k, prefix)
    tokens = prefix + 1
    time_per_round = c * k + 1.0
    return float(tokens.mean() / time_per_round)
