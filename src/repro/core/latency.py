"""Hardware-aware latency prediction (§4.2): Bayesian linear regression over
roofline features.

The paper fits BLR on GPU timings. Our TPU-target adaptation feeds the same
regressor with *roofline terms derived from compiled HLO* (see
repro.analysis.roofline): [1, flops/peak, bytes/hbm_bw, coll_bytes/ici_bw].
On CPU (live benchmarks) the same class is updated online from measured
wall-times, so `c_hat` adapts to the actual machine — exactly the paper's
mechanism, different feature source.
"""
from __future__ import annotations

from typing import Optional, Sequence

import numpy as np

# TPU v5e hardware constants (per chip)
PEAK_FLOPS = 197e12          # bf16 FLOP/s
HBM_BW = 819e9               # bytes/s
ICI_BW = 50e9                # bytes/s per link


class BayesianLinearLatency:
    """Gaussian BLR: posterior over w in t = w . phi(x) + noise."""

    def __init__(self, dim: int = 4, prior_scale: float = 10.0, noise: float = 1e-3):
        self.dim = dim
        self.noise = noise
        self.precision = np.eye(dim) / (prior_scale ** 2)
        self.mean_times_prec = np.zeros(dim)

    # ------------------------------------------------------------------ update
    def observe(self, features: Sequence[float], latency: float) -> None:
        x = np.asarray(features, dtype=np.float64)
        self.precision += np.outer(x, x) / self.noise
        self.mean_times_prec += x * latency / self.noise

    @property
    def weights(self) -> np.ndarray:
        return np.linalg.solve(self.precision, self.mean_times_prec)

    def predict(self, features: Sequence[float]) -> float:
        x = np.asarray(features, dtype=np.float64)
        return float(self.weights @ x)

    def predict_with_var(self, features: Sequence[float]) -> tuple:
        x = np.asarray(features, dtype=np.float64)
        cov = np.linalg.inv(self.precision)
        return float(self.weights @ x), float(x @ cov @ x + self.noise)


def roofline_features(flops: float, bytes_hbm: float, coll_bytes: float) -> list:
    """phi(x) = [1, compute-term, memory-term, collective-term] (seconds)."""
    return [1.0, flops / PEAK_FLOPS, bytes_hbm / HBM_BW, coll_bytes / ICI_BW]


def roofline_latency(flops: float, bytes_hbm: float, coll_bytes: float = 0.0) -> float:
    """Max-of-terms roofline estimate (used as the BLR prior's anchor)."""
    return max(flops / PEAK_FLOPS, bytes_hbm / HBM_BW, coll_bytes / ICI_BW)


class CostTracker:
    """Per-config cost coefficients c(M_t, M_d) with online refinement.

    Keeps a BLR per config keyed by (tokens_processed,) plus an EMA of the
    measured per-call latency; `c_hat(config)` returns the latency ratio to
    the target model's single-step latency.
    """

    def __init__(self, ema: float = 0.8):
        self.ema = ema
        self._lat: dict = {}
        self._target_lat: Optional[float] = None

    def observe(self, config: str, seconds: float, tokens: int = 1) -> None:
        per_tok = seconds / max(tokens, 1)
        prev = self._lat.get(config)
        self._lat[config] = per_tok if prev is None else self.ema * prev + (1 - self.ema) * per_tok

    def observe_target(self, seconds: float, tokens: int = 1) -> None:
        per_tok = seconds / max(tokens, 1)
        prev = self._target_lat
        self._target_lat = per_tok if prev is None else self.ema * prev + (1 - self.ema) * per_tok

    def set_prior(self, config: str, c: float) -> None:
        self._lat.setdefault(config, c)  # stored as ratio until target known

    def c_hat(self, config: str, default: float = 0.5) -> float:
        lat = self._lat.get(config)
        if lat is None:
            return default
        if self._target_lat is None or self._target_lat <= 0:
            return lat if lat < 10 else default   # prior stored as ratio
        return min(lat / self._target_lat, 10.0)


def best_chain_length(
    alpha: float, c: float, k_max: int, t_min: float = 1.0
) -> int:
    """Per-slot adaptive draft length — the chain-cascade analogue of DyTC's
    Eq. 5 objective for the batched server (where trees degrade to chains,
    App. A): pick the k maximizing the chain EWIF

        T_SD(alpha, c, k) = (1 - alpha^{k+1}) / ((1 - alpha)(ck + 1)),

    and stop drafting entirely (return 0) when even the best k's expected
    speedup falls below ``t_min`` — a slot whose draft economics have gone
    bad degrades to plain AR inside the same verify round.
    """
    from repro.core.ewif import t_sd

    best_k, best_v = 0, 1.0          # k=0 == autoregressive, speedup 1.0
    for k in range(1, max(k_max, 0) + 1):
        v = t_sd(alpha, c, k)
        if v > best_v:
            best_k, best_v = k, v
    return best_k if best_v >= t_min else 0


def best_chain_length_batched(alpha, c, k_max: int, t_min: float):
    """Device twin of ``best_chain_length`` over per-slot alphas, jnp.

    ``alpha`` (B,) f32, ``c`` scalar array, static ``k_max``/``t_min``;
    returns (B,) int32 budgets. Argmax over the same T_SD grid with
    first-max tie-breaking (the host loop only replaces on strictly
    greater), gated to 0 below ``t_min`` — so the single-dispatch round
    computes round r+1's draft lengths inside round r's executable."""
    import jax.numpy as jnp

    from repro.core.ewif import t_sd_grid

    vals = t_sd_grid(alpha, c, k_max)                 # (B, k_max+1), k=0 first
    best_k = jnp.argmax(vals, axis=1).astype(jnp.int32)
    best_v = jnp.max(vals, axis=1)
    return jnp.where(best_v >= t_min, best_k, 0)


def best_tree_expansions_batched(alpha, c, e_max: int, t_min: float):
    """Device twin of ``best_tree_expansions`` over per-slot alphas, jnp:
    argmax of the Eq. 5 objective (drafter as its own continuation), gated
    on the chain EWIF at the chosen budget. Returns (B,) int32."""
    import jax.numpy as jnp

    from repro.core.ewif import dytc_objective_grid, t_sd_grid

    if e_max <= 0:
        return jnp.zeros(alpha.shape, jnp.int32)
    obj = dytc_objective_grid(alpha, c, e_max)        # (B, e_max), k=1 first
    best_k = (1 + jnp.argmax(obj, axis=1)).astype(jnp.int32)
    gate = jnp.take_along_axis(
        t_sd_grid(alpha, c, e_max), best_k[:, None], axis=1
    )[:, 0]
    return jnp.where(gate >= t_min, best_k, 0)


def best_cascade_plan(
    alphas: Sequence[float],
    cs: Sequence[float],
    alpha_direct: float,
    e_max: int,
    t_min: float = 1.0,
) -> tuple:
    """Per-slot routing + budget split for the ``cascade_fused`` mode.

    Compares the Eq. 5 objective of three executions of one serving round
    and returns ``(expansions, use_rescore)``:

      - **cascade**  — the cheapest level drafts ``k`` tokens, every
        stronger level rescores in one block forward, the target verifies:
        ``ewif.t_cascade(alphas, cs, k)`` maximized over k;
      - **single-level** — the cheapest level drafts straight for the
        target (no intermediate rescores), priced with ``alpha_direct``
        (the slot's tracked cheap-vs-target acceptance, or the App. D
        compositional prior ``prod(alphas)`` before any observation);
      - **PLD-only** — ``(0, False)``: no neural work, speedup 1.0.

    A slot whose best option misses ``t_min`` collapses to PLD-only — the
    DyTC stop rule applied to the whole hierarchy.
    """
    from repro.core.ewif import best_cascade_k, t_sd

    v_casc, k_casc = best_cascade_k(alphas, cs, e_max)
    if len(alphas) < 2:
        v_casc = -1.0                       # no level to rescore with
    v_single, k_single = 1.0, 0
    for k in range(1, max(e_max, 0) + 1):
        v = t_sd(alpha_direct, max(cs[-1], 1e-3), k)
        if v > v_single:
            v_single, k_single = v, k
    best = max(v_casc, v_single)
    if best < t_min:
        return 0, False
    if v_casc >= v_single:
        return k_casc, True
    return k_single, False


def best_tree_expansions(
    alpha: float, c: float, e_max: int, t_min: float = 1.0
) -> int:
    """Per-slot tree expansion budget for the batched ``tree_fused`` mode.

    Picks the budget maximizing the Eq. 5 admissible objective with the
    drafter as its own continuation (the homogeneous-hierarchy
    specialization — the batched server runs ONE neural drafter, so the
    "least future speedup" term prices more of the same drafter), then
    gates on the chain EWIF the same way ``best_chain_length`` does: a slot
    whose best expected speedup falls below ``t_min`` stops tree drafting
    entirely and degrades to PLD + AR inside the same batched verify.
    """
    from repro.core.ewif import best_dytc_k, t_sd

    _, best_k = best_dytc_k(alpha, c, alpha, c, e_max)
    if best_k <= 0:
        return 0
    return best_k if t_sd(alpha, c, best_k) >= t_min else 0
