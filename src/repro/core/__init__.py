"""CAS-Spec core: the paper's contribution.

  ewif        — EWIF theory (§3, App. B)
  pld         — Prompt Lookup bottom draft model
  acceptance  — EMA acceptance tracking (Eq. 4)
  latency     — BLR latency model over roofline features
  dsia        — DSIA strategies / draft hierarchy (§4.1)
  tree        — draft token tree + dense tree masks
  verify      — lossless greedy / speculative-sampling verification
  cascade     — static VC/HC/tree baselines (CS-Drafting, SWIFT-tree)
  dytc        — Dynamic Tree Cascade (Alg. 1+2)
  engine      — SpecEngine runtime (stage-then-commit)
"""
from repro.core.acceptance import AcceptanceTracker
from repro.core.dsia import DraftSpec, PLD_SPEC, build_hierarchy, early_exit, layer_sparsity
from repro.core.dytc import DyTCConfig, DyTCScheduler
from repro.core.engine import SpecEngine
from repro.core.pld import PromptLookup
from repro.core.tree import DraftTree

__all__ = [
    "AcceptanceTracker",
    "DraftSpec",
    "PLD_SPEC",
    "build_hierarchy",
    "early_exit",
    "layer_sparsity",
    "DyTCConfig",
    "DyTCScheduler",
    "SpecEngine",
    "PromptLookup",
    "DraftTree",
]
