"""Prompt Lookup Decoding (PLD) — the bottom draft model M_dn.

Retrieval-based n-gram drafting [Saxena 2023]: find the longest suffix of the
current context that re-occurs earlier in the context and propose the tokens
that followed it. Negligible cost (c ~ 0.01), host-side numpy.
"""
from __future__ import annotations

from typing import Optional, Tuple

import numpy as np


class PromptLookup:
    def __init__(self, max_ngram: int = 4, min_ngram: int = 1, max_draft: int = 10):
        self.max_ngram = max_ngram
        self.min_ngram = min_ngram
        self.max_draft = max_draft

    def propose(self, context: np.ndarray, k: Optional[int] = None) -> np.ndarray:
        """Return up to ``k`` draft tokens (possibly empty).

        Also returns a confidence proxy: longer n-gram matches rank higher
        (used by DyTC for token-level branch scoring of non-neural drafts).
        """
        tokens, _ = self.propose_with_confidence(context, k)
        return tokens

    def propose_with_confidence(
        self, context: np.ndarray, k: Optional[int] = None
    ) -> Tuple[np.ndarray, float]:
        k = k or self.max_draft
        ctx = np.asarray(context).ravel()
        n = len(ctx)
        empty = np.zeros((0,), dtype=ctx.dtype)
        if n < self.min_ngram + 1:
            return empty, 0.0
        for ng in range(min(self.max_ngram, n - 1), self.min_ngram - 1, -1):
            suffix = ctx[n - ng :]
            # all windows of length ng ending strictly before the suffix
            limit = n - ng
            if limit <= 0:
                continue
            windows = np.lib.stride_tricks.sliding_window_view(ctx[: n - 1], ng)
            hits = np.flatnonzero((windows == suffix).all(axis=1))
            hits = hits[hits + ng < n]          # must have a continuation
            hits = hits[hits + ng <= n - 1]
            # prefer the most recent occurrence (better locality)
            for start in hits[::-1]:
                cont_start = start + ng
                cont_end = min(cont_start + k, n - ng)  # avoid trivially matching the suffix itself
                cont_end = min(cont_start + k, n)
                cont = ctx[cont_start : cont_end]
                # never propose past the suffix start (that's the suffix itself)
                cont = cont[: max(0, (n - ng) - cont_start)]
                if len(cont):
                    conf = ng / self.max_ngram
                    return cont[:k].copy(), conf
        return empty, 0.0
