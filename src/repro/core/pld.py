"""Prompt Lookup Decoding (PLD) — the bottom draft model M_dn.

Retrieval-based n-gram drafting [Saxena 2023]: find the longest suffix of the
current context that re-occurs earlier in the context and propose the tokens
that followed it. Negligible cost (c ~ 0.01).

Two implementations with pinned identical semantics:

  - ``PromptLookup`` — host-side numpy, one context at a time. The reference
    implementation and the parity oracle for the device path
    (tests/test_pld_device.py).
  - ``propose_device`` — batched jnp window-compare over a device-resident
    ``(B, L)`` context buffer; jit-safe, so the single-dispatch serving
    round (``core.engine.chain_round``/``tree_round``) retrieves PLD drafts
    *inside* the round dispatch instead of a per-slot Python loop.

Pinned semantics (both paths): the proposal is the continuation of the most
recent earlier occurrence of the longest matching context suffix, where

  - the occurrence must have a continuation (tokens follow the match), and
  - the continuation must not run into the suffix itself — tokens at or past
    the suffix start ``n - ng`` are never proposed (an occurrence whose
    continuation would start there is skipped entirely).
"""
from __future__ import annotations

from typing import Optional, Tuple

import numpy as np


class PromptLookup:
    def __init__(self, max_ngram: int = 4, min_ngram: int = 1, max_draft: int = 10):
        self.max_ngram = max_ngram
        self.min_ngram = min_ngram
        self.max_draft = max_draft

    def propose(self, context: np.ndarray, k: Optional[int] = None) -> np.ndarray:
        """Return up to ``k`` draft tokens (possibly empty).

        Also returns a confidence proxy: longer n-gram matches rank higher
        (used by DyTC for token-level branch scoring of non-neural drafts).
        """
        tokens, _ = self.propose_with_confidence(context, k)
        return tokens

    def propose_with_confidence(
        self, context: np.ndarray, k: Optional[int] = None
    ) -> Tuple[np.ndarray, float]:
        k = k or self.max_draft
        ctx = np.asarray(context).ravel()
        n = len(ctx)
        empty = np.zeros((0,), dtype=ctx.dtype)
        if n < self.min_ngram + 1:
            return empty, 0.0
        for ng in range(min(self.max_ngram, n - 1), self.min_ngram - 1, -1):
            suffix = ctx[n - ng :]
            windows = np.lib.stride_tricks.sliding_window_view(ctx[: n - 1], ng)
            hits = np.flatnonzero((windows == suffix).all(axis=1))
            # the continuation must exist AND start strictly before the
            # suffix itself (start + ng < n - ng): a later occurrence only
            # yields suffix tokens, which are never proposed
            hits = hits[hits + 2 * ng < n]
            if len(hits):
                # the most recent admissible occurrence (better locality)
                cont_start = int(hits[-1]) + ng
                cont = ctx[cont_start : min(cont_start + k, n - ng)]
                return cont.copy(), ng / self.max_ngram
        return empty, 0.0


def propose_device(
    ctx: "jax.Array",                  # noqa: F821 — (B, L) int32 context buffer
    length: "jax.Array",               # noqa: F821 — (B,) int32 context length (incl. pending)
    k: int,
    *,
    max_ngram: int = 4,
    min_ngram: int = 1,
):
    """Batched on-device PLD: exact parity with ``PromptLookup.propose``.

    ``ctx[b, :length[b]]`` is slot b's context (committed tokens + the
    pending bonus token); positions past ``length`` are ignored. Returns
    ``(chains (B, k) int32, have (B,) int32)`` — the per-slot proposal
    zero-padded past ``have``, exactly the layout the serving round's
    drafting scans consume. Pure jnp (one fused window-compare per n-gram
    size, O(B * L * max_ngram^2) integer compares), so it traces into the
    single-dispatch round executable with no host loop.
    """
    import jax.numpy as jnp

    B, L = ctx.shape
    s_idx = jnp.arange(L)
    n = length.astype(jnp.int32)
    chains = jnp.zeros((B, k), jnp.int32)
    have = jnp.zeros((B,), jnp.int32)
    found = jnp.zeros((B,), bool)
    for ng in range(max_ngram, min_ngram - 1, -1):
        # window-compare: eq[b, s] <=> ctx[b, s:s+ng] == suffix(b, ng)
        eq = jnp.ones((B, L), bool)
        for i in range(ng):
            win = jnp.take(ctx, jnp.minimum(s_idx + i, L - 1), axis=1)
            suf_pos = jnp.clip(n - ng + i, 0, L - 1)[:, None]
            eq &= win == jnp.take_along_axis(ctx, suf_pos, axis=1)
        # admissible: continuation exists and starts before the suffix
        # (s + 2*ng < n) — and the suffix itself must fit (n >= ng + 1)
        valid = (s_idx[None, :] + 2 * ng < n[:, None]) & (n[:, None] >= ng + 1)
        best_s = jnp.max(jnp.where(eq & valid, s_idx[None, :], -1), axis=1)
        hit = best_s >= 0
        cont0 = best_s + ng
        idx = jnp.clip(cont0[:, None] + jnp.arange(k)[None, :], 0, L - 1)
        toks = jnp.take_along_axis(ctx, idx, axis=1).astype(jnp.int32)
        h_ng = jnp.clip(n - ng - cont0, 0, k).astype(jnp.int32)
        use = hit & ~found                 # longest n-gram wins
        chains = jnp.where(use[:, None], toks, chains)
        have = jnp.where(use, h_ng, have)
        found |= hit
    chains = jnp.where(jnp.arange(k)[None, :] < have[:, None], chains, 0)
    return chains, have
