"""Dynamic Tree Cascade (DyTC) — Algorithm 1 + 2 of CAS-Spec (§4.2).

Per decoding round, grow a draft token tree:
  1. pick the active leaf with the highest accumulated acceptance P_acc
     (Alg. 1 line 5),
  2. pick (configuration, draft length k) maximizing the A*-style admissible
     objective Eq. 5 — local speedup + the *least future speedup* of ending
     with the bottom model (Alg. 2),
  3. expand: neural configs draft k tokens (top-K children per step, TOP-P
     filtered); VC(M_di, PLD) configs let PLD propose and M_di verify/extend
     in a single joint forward; PLD proposes retrieval chains,
  4. stop when P_acc·(alpha_dn/c_dn) < t_min or the tree is full,
then verify once with the target model (engine.verify_and_commit) and update
the EMA acceptance estimates from first-token outcomes (Eq. 4).
"""
from __future__ import annotations

import dataclasses
import math
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.core import verify as verify_lib
from repro.core.dsia import DraftSpec, PLD_SPEC
from repro.core.engine import SpecEngine
from repro.core.ewif import best_dytc_k
from repro.core.tree import DraftTree


@dataclasses.dataclass
class DyTCConfig:
    max_tree: int = 24               # M_tree_max
    k_max: int = 5                   # max draft length per expansion (paper: 5)
    t_min: float = 1.1               # min overall speedup threshold (paper: 1.1)
    top_k: int = 2                   # sibling candidates per step
    top_p: float = 0.3               # tree probability threshold P_tree
    max_expansions: int = 8
    token_level: bool = True         # §4.2 token-level P_acc refinement


@dataclasses.dataclass
class Candidate:
    """A scheduling configuration: single DSIA model or VC(model, PLD)."""
    name: str
    spec: Optional[DraftSpec]        # None for pure PLD
    vc_with_pld: bool = False


class DyTCScheduler:
    def __init__(
        self,
        engine: SpecEngine,
        hierarchy: Sequence[DraftSpec],
        cfg: Optional[DyTCConfig] = None,
    ):
        self.engine = engine
        self.cfg = cfg or DyTCConfig()
        self.bottom = next((s for s in hierarchy if s.kind == "retrieval"), PLD_SPEC)
        neural = [s for s in hierarchy if s.kind == "neural"]
        for s in hierarchy:
            engine.register_draft(s)
        self.candidates: List[Candidate] = []
        for s in neural:
            self.candidates.append(Candidate(name=s.name, spec=s))
            self.candidates.append(
                Candidate(name=f"VC({s.name},{self.bottom.name})", spec=s, vc_with_pld=True)
            )
            engine.acceptance.set_prior(f"VC({s.name},{self.bottom.name})", s.prior_alpha)
            engine.costs.set_prior(f"VC({s.name},{self.bottom.name})", s.prior_c)
        self.candidates.append(Candidate(name=self.bottom.name, spec=None))

    # ----------------------------------------------------------------- Alg. 2
    def find_best_configuration(
        self, pld_available: bool
    ) -> Tuple[Optional[Candidate], int, float]:
        acc, costs = self.engine.acceptance, self.engine.costs
        a_dn = acc.alpha(self.bottom.name)
        c_dn = max(costs.c_hat(self.bottom.name, self.bottom.prior_c), 1e-3)
        best: Tuple[Optional[Candidate], int, float] = (None, 0, -math.inf)
        for cand in self.candidates:
            if cand.spec is None and not pld_available:
                continue
            a = acc.alpha(cand.name)
            c = max(costs.c_hat(cand.name, 0.5), 1e-3)
            if cand.spec is None:
                c = c_dn
            val, k = best_dytc_k(a, c, a_dn, c_dn, self.cfg.k_max)
            if val > best[2]:
                best = (cand, k, val)
        if best[2] <= 0:
            return None, 0, best[2]
        return best

    # ----------------------------------------------------------- expansions
    def _chain_arrays(self, tree: DraftTree, leaf: int):
        path = tree.path_to(leaf)
        tokens = np.asarray([tree.tokens[i] for i in path], np.int32)
        rel = np.asarray([tree.depth[i] for i in path], np.int32)
        n = len(path)
        mask = np.tril(np.ones((n, n), bool))
        return path, tokens, rel, mask

    def _expand_neural(
        self, tree: DraftTree, leaf: int, cand: Candidate, k: int
    ) -> Optional[int]:
        """Draft k tokens with a DSIA model along a chain from ``leaf``.
        Returns the first added node (for acceptance bookkeeping)."""
        ecfg = self.cfg
        alpha = self.engine.acceptance.alpha(cand.name)
        first_node = None
        node = leaf
        for _ in range(k):
            path, tokens, rel, mask = self._chain_arrays(tree, node)
            logits = self.engine.draft_logits(cand.spec.name, tokens, rel, mask)
            last = logits[len(path) - 1]
            probs = verify_lib.softmax(last)
            top_idx = np.argsort(-probs)[: ecfg.top_k]
            # TOP-P filter over sibling candidates (Alg. 1 line 19)
            kept = [int(t) for t in top_idx if probs[t] >= ecfg.top_p * probs[top_idx[0]]]
            if not kept:
                kept = [int(top_idx[0])]
            child_main = None
            for rank, t in enumerate(kept):
                if len(tree) >= ecfg.max_tree:
                    break
                a_node = alpha
                if ecfg.token_level:
                    a_node = min(1.0, alpha * float(probs[t] / max(probs[kept[0]], 1e-9)) ** 0.5)
                c = tree.add_child(node, t, cand.name, a_node)
                if rank == 0:
                    child_main = c
                if first_node is None and rank == 0:
                    first_node = c
            if child_main is None:
                break
            node = child_main
        return first_node

    def _expand_vc(
        self, tree: DraftTree, leaf: int, cand: Candidate, k: int
    ) -> Optional[int]:
        """VC(M_di, PLD): PLD proposes, M_di verifies + extends — one joint
        draft forward over [chain .. pld tokens]."""
        ctx = np.concatenate(
            [np.asarray(self.engine.tokens, np.int32),
             np.asarray(tree.path_tokens(leaf), np.int32)]
        )
        pld_toks, conf = self.engine.pld.propose_with_confidence(ctx, k)
        if len(pld_toks) == 0:
            return self._expand_neural(tree, leaf, cand, k)
        path, tokens, rel, mask = self._chain_arrays(tree, leaf)
        n0 = len(path)
        ext_tokens = np.concatenate([tokens, pld_toks.astype(np.int32)])
        ext_rel = np.concatenate(
            [rel, rel[-1] + 1 + np.arange(len(pld_toks), dtype=np.int32)]
        )
        n = len(ext_tokens)
        ext_mask = np.tril(np.ones((n, n), bool))
        logits = self.engine.draft_logits(cand.spec.name, ext_tokens, ext_rel, ext_mask)
        nxt = np.argmax(logits, axis=-1)
        alpha = self.engine.acceptance.alpha(cand.name)
        node = leaf
        first_node = None
        # accept pld tokens the draft model agrees with, then extend by one
        for i, tok in enumerate(pld_toks):
            if int(nxt[n0 - 1 + i]) != int(tok):
                break
            if len(tree) >= self.cfg.max_tree:
                return first_node
            node = tree.add_child(node, int(tok), cand.name, alpha)
            first_node = first_node or node
        if len(tree) < self.cfg.max_tree:
            ext = int(nxt[min(n0 - 1 + len(pld_toks), n - 1)]) if node != leaf else int(nxt[n0 - 1])
            node = tree.add_child(node, ext, cand.name, alpha)
            first_node = first_node or node
        return first_node

    def _expand_pld(self, tree: DraftTree, leaf: int, k: int) -> Optional[int]:
        ctx = np.concatenate(
            [np.asarray(self.engine.tokens, np.int32),
             np.asarray(tree.path_tokens(leaf), np.int32)]
        )
        toks, conf = self.engine.pld.propose_with_confidence(ctx, k)
        if len(toks) == 0:
            return None
        alpha = self.engine.acceptance.alpha(self.bottom.name)
        if self.cfg.token_level:
            alpha = min(1.0, alpha * (0.5 + conf))   # n-gram length confidence
        node = leaf
        first = None
        for t in toks:
            if len(tree) >= self.cfg.max_tree:
                break
            node = tree.add_child(node, int(t), self.bottom.name, alpha)
            first = first or node
        return first

    # ----------------------------------------------------------------- Alg. 1
    def build_tree(self) -> Tuple[DraftTree, List[Tuple[str, int]]]:
        eng = self.engine
        tree = DraftTree(eng.pending)
        expansions: List[Tuple[str, int]] = []   # (config name, first node)
        a_dn = eng.acceptance.alpha(self.bottom.name)
        c_dn = max(eng.costs.c_hat(self.bottom.name, self.bottom.prior_c), 1e-3)
        n_exp = 0
        while len(tree) < self.cfg.max_tree and n_exp < self.cfg.max_expansions:
            leaf = tree.best_active_leaf()
            if leaf is None:
                break
            # stop rule: least-future-speedup below threshold
            if tree.p_acc[leaf] * (a_dn / c_dn) < self.cfg.t_min and leaf != 0:
                tree.deactivate(leaf)
                continue
            ctx = np.concatenate(
                [np.asarray(eng.tokens, np.int32),
                 np.asarray(tree.path_tokens(leaf), np.int32)]
            )
            pld_ok = len(eng.pld.propose(ctx, 1)) > 0
            cand, k, val = self.find_best_configuration(pld_ok)
            if cand is None:
                tree.deactivate(leaf)
                break
            if cand.spec is None:
                first = self._expand_pld(tree, leaf, k)
            elif cand.vc_with_pld:
                first = self._expand_vc(tree, leaf, cand, k)
            else:
                first = self._expand_neural(tree, leaf, cand, k)
            tree.deactivate(leaf)
            n_exp += 1
            if first is not None:
                expansions.append((cand.name, first))
        return tree, expansions

    def step(self) -> List[int]:
        """One DyTC round: build tree, verify, commit, update estimators."""
        tree, expansions = self.build_tree()
        accepted = self.engine.verify_and_commit(tree)
        # first-token outcomes (Eq. 4): an expansion is observed iff its
        # parent was accepted; outcome = its first node accepted.
        acc_set = self._last_path(tree, accepted)
        for name, first in expansions:
            parent = tree.parents[first]
            if parent in acc_set or parent == 0:
                self.engine.acceptance.observe(name, first in acc_set)
        return accepted

    @staticmethod
    def _last_path(tree: DraftTree, accepted: List[int]) -> set:
        """Recover the accepted node path from the committed token list."""
        nodes = {0}
        node = 0
        for tok in accepted[1:]:
            nxt = None
            for c in tree.children.get(node, ()):
                if tree.tokens[c] == tok:
                    nxt = c
                    break
            if nxt is None:
                break
            nodes.add(nxt)
            node = nxt
        return nodes

    def generate(self, n_tokens: int) -> List[int]:
        out_start = len(self.engine.tokens)
        while len(self.engine.tokens) - out_start < n_tokens:
            self.step()
        return self.engine.tokens[out_start : out_start + n_tokens]
