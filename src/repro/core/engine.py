"""CAS-Spec inference engine: DSIA draft execution + tree verification.

Execution modes for layer-gated drafts:
  - "slice": materialize a reduced-depth param pytree per draft config
    (fewer FLOPs — the honest speed of a layer-sparse draft; requires a
    homogeneous layer stack).
  - "mask": one shared executable, gates passed as a traced vector (zero
    recompiles; the TPU serve_step lowers this form).

Cache discipline: drafts are STAGE-ONLY (never committed); only the full
target model's verification staged KV/states are committed, so the cache is
always exact — the losslessness invariant (see models.model docstring).
"""
from __future__ import annotations

import functools
import time
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config.base import ModelConfig
from repro.core.acceptance import AcceptanceTracker
from repro.core.dsia import DraftSpec
from repro.core.latency import CostTracker
from repro.core.pld import PromptLookup
from repro.core.tree import DraftTree, bucket_for
from repro.core import verify as verify_lib
from repro.models import model as M

import dataclasses


def fake_quant_int8(params: dict) -> dict:
    """Per-output-channel symmetric int8 weight fake-quantization (QSpec sim)."""

    def q(w):
        if not isinstance(w, jax.Array) or w.dtype not in (jnp.float32, jnp.bfloat16):
            return w
        if w.ndim < 2:
            return w
        w32 = w.astype(jnp.float32)
        scale = jnp.max(jnp.abs(w32), axis=tuple(range(w.ndim - 1)), keepdims=True) / 127.0
        scale = jnp.maximum(scale, 1e-8)
        return (jnp.round(w32 / scale).clip(-127, 127) * scale).astype(w.dtype)

    return jax.tree.map(q, params)


def chain_draft_scan(
    cfg: ModelConfig,
    steps: int,                       # static scan trip count (<= k)
    params: dict,
    cache: dict,                      # batched committed cache (scratch copy semantics)
    pending: jax.Array,               # (B,) int32 last verified token per slot
    chains: jax.Array,                # (B, k) int32, PLD-prefilled prefix
    have: jax.Array,                  # (B,) int32 tokens already proposed (PLD)
    limit: jax.Array,                 # (B,) int32 per-slot adaptive draft cap
    gates: Optional[jax.Array],       # (num_layers,) DSIA layer gates or None
) -> Tuple[jax.Array, jax.Array]:
    """Fused k-step neural chain drafting: one ``lax.scan`` over draft steps.

    Each step re-decodes the fixed (B, k+1) block ``[pending, chain]`` under
    a causal tree mask — earlier draft tokens are visible to later positions
    through the staged-KV block path (the same mechanism verification uses),
    so the committed cache is READ-ONLY here: no scratch commits, no cache
    copy, and the whole loop is a single dispatch per proposal round instead
    of ``k`` host-synchronized decode calls. Step ``j`` writes the argmax at
    position ``j`` into chain position ``j`` only where ``have <= j <
    limit``; PLD-prefilled positions are never overwritten, and slots past
    their adaptive ``limit`` stop contributing draft tokens. Unfilled tail
    positions hold stale tokens during the scan — the causal mask keeps them
    invisible to every filled position.

    The block recompute costs O(k^2) token-forwards per round; for chain
    drafting at the paper's k <= 5 that is cheaper on every backend we run
    than the O(k) state-carrying alternative (``M.decode_commit_token``),
    which must functionally copy the cache into the scan carry. Drafts never
    write the real cache either way, so losslessness is untouched.

    Returns (chains, have) with ``have = max(have, min(limit, steps))``.
    """
    B, K = chains.shape
    toks = jnp.concatenate([pending[:, None], chains], axis=1)   # (B, K+1)
    mask = jnp.tril(jnp.ones((K + 1, K + 1), bool))

    def body(toks, j):
        logits, _ = M.decode_step(
            cfg, params, cache, toks, gates=gates, tree_mask=mask
        )
        nxt = jnp.argmax(logits, -1).astype(toks.dtype)          # (B, K+1)
        fill = (have <= j) & (j < limit)
        col = jnp.where(fill, nxt[:, j], toks[:, j + 1])
        return toks.at[:, j + 1].set(col), None

    toks, _ = jax.lax.scan(body, toks, jnp.arange(steps, dtype=jnp.int32))
    have = jnp.maximum(have, jnp.minimum(limit, jnp.int32(steps)))
    return toks[:, 1:], have


class SpecEngine:
    """Single-sequence (B=1) speculative engine; the batched path lives in
    repro.serving.server."""

    def __init__(
        self,
        cfg: ModelConfig,
        params: dict,
        max_len: int = 2048,
        draft_exec: str = "auto",          # auto | slice | mask
        greedy: bool = True,
    ):
        self.cfg = cfg
        self.params = params
        self.max_len = max_len
        self.greedy = greedy
        segs = M.layout(cfg)
        homogeneous = len(segs) == 1 and len(segs[0].unit) == 1
        if draft_exec == "auto":
            draft_exec = "slice" if homogeneous else "mask"
        if draft_exec == "slice" and not homogeneous:
            raise ValueError("slice exec requires a homogeneous layer stack")
        self.draft_exec = draft_exec
        self.pld = PromptLookup()
        self.acceptance = AcceptanceTracker()
        self.costs = CostTracker()

        self._variants: Dict[str, Tuple[ModelConfig, dict, Optional[np.ndarray]]] = {
            "full": (cfg, params, None)
        }
        self._spec_by_name: Dict[str, DraftSpec] = {}
        self._decode_fns: Dict[Tuple[str, int], Callable] = {}
        self._commit_fns: Dict[int, Callable] = {}
        self._prefill_fn = jax.jit(
            functools.partial(M.prefill, cfg), static_argnames=()
        )
        # runtime state
        self.cache: Optional[dict] = None
        self.tokens: List[int] = []
        self.pending: Optional[int] = None
        self.stats = {"target_calls": 0, "draft_calls": 0, "rounds": 0,
                      "accepted_tokens": 0, "draft_time": 0.0, "verify_time": 0.0,
                      "modeled_draft_cost": 0.0}

    # ------------------------------------------------------------- variants
    def register_draft(self, spec: DraftSpec) -> None:
        if spec.kind == "retrieval" or spec.name in self._variants:
            self.acceptance.set_prior(spec.name, spec.prior_alpha)
            self.costs.set_prior(spec.name, spec.prior_c)
            return
        cfg, params = self.cfg, self.params
        gates = spec.gates_array(self.cfg.num_layers)
        if spec.quantize == "int8":
            params = fake_quant_int8(params)
        if self.draft_exec == "slice" and spec.gates is not None:
            kept = np.flatnonzero(gates > 0)
            cfg = dataclasses.replace(cfg, num_layers=len(kept))
            seg = params["segments"][0]
            params = dict(params)
            params["segments"] = [jax.tree.map(lambda a: a[kept], seg)]
            gates_arr = None
        else:
            gates_arr = gates
        self._variants[spec.name] = (cfg, params, gates_arr)
        self.acceptance.set_prior(spec.name, spec.prior_alpha)
        self.costs.set_prior(spec.name, spec.prior_c)
        self._spec_by_name[spec.name] = spec

    def _slice_cache(self, variant: str) -> dict:
        cfg_v, _, _ = self._variants[variant]
        if variant == "full" or self.draft_exec != "slice" or cfg_v.num_layers == self.cfg.num_layers:
            return self.cache
        spec = self._spec_by_name[variant]
        kept = np.flatnonzero(spec.gates_array(self.cfg.num_layers) > 0)
        seg = self.cache["segments"][0]
        return {
            "pos": self.cache["pos"],
            "segments": [jax.tree.map(lambda a: a[kept], seg)],
        }

    # --------------------------------------------------------------- jitting
    def _decode_fn(self, variant: str, bucket: int) -> Callable:
        key = (variant, bucket)
        if key in self._decode_fns:
            return self._decode_fns[key]
        cfg_v, params_v, gates = self._variants[variant]
        spec = getattr(self, "_spec_by_name", {}).get(variant)
        override = None
        if spec is not None and spec.attn_override is not None:
            kind, window, sink = spec.attn_override
            override = {"kind": kind, "window": window, "sink": sink}

        @jax.jit
        def fn(params, cache, tokens, tmask, qpos, gates_arr):
            return M.decode_step(
                cfg_v, params, cache, tokens,
                gates=gates_arr, tree_mask=tmask, q_pos=qpos,
                attn_override=override,
            )

        self._decode_fns[key] = (fn, params_v, gates)
        return self._decode_fns[key]

    def _commit_fn(self, bucket: int) -> Callable:
        if bucket not in self._commit_fns:
            self._commit_fns[bucket] = jax.jit(
                functools.partial(M.commit_cache, self.cfg)
            )
        return self._commit_fns[bucket]

    # ---------------------------------------------------------------- runtime
    def start(self, prompt: np.ndarray) -> None:
        prompt = np.asarray(prompt, np.int32)
        self.cache = M.init_cache(self.cfg, 1, self.max_len, dtype=jnp.dtype(self.cfg.dtype))
        t0 = time.perf_counter()
        last, self.cache = jax.block_until_ready(
            self._prefill_fn(self.params, {"tokens": jnp.asarray(prompt[None])}, self.cache)
        )
        self.costs.observe_target(time.perf_counter() - t0, tokens=max(len(prompt), 1))
        self.tokens = list(map(int, prompt))
        self.pending = int(np.argmax(np.asarray(last)[0]))

    @property
    def context(self) -> np.ndarray:
        return np.asarray(self.tokens + [self.pending], np.int32)

    def _run_nodes(
        self,
        variant: str,
        tokens: np.ndarray,     # (n,)
        rel_pos: np.ndarray,    # (n,)
        mask: np.ndarray,       # (n, n)
    ):
        n = len(tokens)
        T = bucket_for(n)
        toks = np.zeros(T, np.int32)
        toks[:n] = tokens
        rel = np.zeros(T, np.int32)
        rel[:n] = rel_pos
        rel[n:] = (rel_pos.max() if n else 0) + 1 + np.arange(T - n)
        m = np.eye(T, dtype=bool)
        m[:n, :n] = mask
        fn, params_v, gates = self._decode_fn(variant, T)
        cache = self._slice_cache(variant)
        qpos = jnp.asarray(self.cache["pos"] + jnp.asarray(rel))
        logits, staged = fn(
            params_v, cache, jnp.asarray(toks[None]), jnp.asarray(m), qpos,
            None if gates is None else jnp.asarray(gates),
        )
        return logits, staged, T

    # draft call: logits for a node set under a draft config (stage-only)
    def draft_logits(self, spec_name: str, tokens, rel_pos, mask) -> np.ndarray:
        t0 = time.perf_counter()
        logits, _, _ = self._run_nodes(spec_name, tokens, rel_pos, mask)
        logits = np.asarray(jax.block_until_ready(logits))[0]
        dt = time.perf_counter() - t0
        self.stats["draft_calls"] += 1
        self.stats["draft_time"] += dt
        # modeled TPU cost: one target-forward-equivalent x the DSIA cost
        # coefficient per draft call (a KV-cached draft computes ~1 new
        # token per call; chain recomputation is a CPU-engine artifact)
        spec = self._spec_by_name.get(spec_name)
        self.stats["modeled_draft_cost"] += spec.prior_c if spec else 0.5
        self.costs.observe(spec_name, dt, tokens=len(tokens))
        return logits[: len(tokens)]

    # verification: full model over the tree, then commit the accepted path
    def verify_and_commit(self, tree: DraftTree) -> List[int]:
        tokens, rel, mask, real = tree.flatten()
        n = len(tree)
        t0 = time.perf_counter()
        logits, staged, T = self._run_nodes("full", tokens[:n], rel[:n], mask[:n, :n])
        logits = np.asarray(jax.block_until_ready(logits))[0]   # (T, V)
        self.stats["verify_time"] += time.perf_counter() - t0
        self.stats["target_calls"] += 1
        self.costs.observe_target(time.perf_counter() - t0, tokens=1)
        next_argmax = np.argmax(logits[:n], axis=-1)
        path, bonus = verify_lib.greedy_accept_tree(tree, next_argmax)

        # commit: accepted nodes' staged KV/states, in path order
        T_pad = bucket_for(n)
        path_idx = np.zeros(T_pad, np.int32)
        path_idx[: len(path)] = path
        commit = self._commit_fn(T_pad)
        self.cache = commit(
            self.cache, staged, jnp.asarray(path_idx), jnp.asarray(len(path), jnp.int32)
        )
        accepted = [tree.tokens[i] for i in path]
        self.tokens.extend(accepted)
        self.pending = int(bonus)
        self.stats["rounds"] += 1
        self.stats["accepted_tokens"] += len(accepted)
        return accepted

    # ------------------------------------------------------------ baselines
    def ar_step(self) -> int:
        """Plain autoregressive: verify a root-only tree (1 token/step)."""
        tree = DraftTree(self.pending)
        self.verify_and_commit(tree)
        return self.tokens[-1]

    def generate_ar(self, n_tokens: int) -> List[int]:
        out = []
        while len(out) < n_tokens:
            self.ar_step()
            out.append(self.tokens[-1])
        return out[:n_tokens]
