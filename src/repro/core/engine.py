"""CAS-Spec inference engine: DSIA draft execution + tree verification.

Execution modes for layer-gated drafts:
  - "slice": materialize a reduced-depth param pytree per draft config
    (fewer FLOPs — the honest speed of a layer-sparse draft; requires a
    homogeneous layer stack).
  - "mask": one shared executable, gates passed as a traced vector (zero
    recompiles; the TPU serve_step lowers this form).

Cache discipline: drafts are STAGE-ONLY (never committed); only the full
target model's verification staged KV/states are committed, so the cache is
always exact — the losslessness invariant (see models.model docstring).
"""
from __future__ import annotations

import functools
import time
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config.base import BlockKind, ModelConfig
from repro.core import pld as pld_lib
from repro.core.acceptance import AcceptanceTracker, ema_update
from repro.core.dsia import DraftSpec
from repro.core.latency import (
    CostTracker,
    best_chain_length_batched,
    best_tree_expansions_batched,
)
from repro.core.pld import PromptLookup
from repro.core.tree import DraftTree, bucket_for, tree_seed_device
from repro.core import verify as verify_lib
from repro.models import model as M
from repro.models.shard_utils import constrain, data_axis

import dataclasses


def fake_quant_int8(params: dict) -> dict:
    """Per-output-channel symmetric int8 weight fake-quantization (QSpec sim)."""

    def q(w):
        if not isinstance(w, jax.Array) or w.dtype not in (jnp.float32, jnp.bfloat16):
            return w
        if w.ndim < 2:
            return w
        w32 = w.astype(jnp.float32)
        scale = jnp.max(jnp.abs(w32), axis=tuple(range(w.ndim - 1)), keepdims=True) / 127.0
        scale = jnp.maximum(scale, 1e-8)
        return (jnp.round(w32 / scale).clip(-127, 127) * scale).astype(w.dtype)

    return jax.tree.map(q, params)


DRAFT_KV_MODES = ("recompute", "carry")


def _bounded_loop(body, init, steps: int, j_max):
    """Run ``body`` (a ``lax.scan``-style ``(carry, j) -> (carry, None)``)
    either as a static-trip scan (``j_max is None``) or as a
    ``lax.while_loop`` bounded by the traced ``j_max`` (clipped to
    ``steps``). The while form is what lets the single-dispatch round use
    the SAME per-round trip count the split path computes on host —
    decided on device, no sync. Iterations past the point where every
    slot's fill mask is dead are no-ops, so the two forms are
    token-identical."""
    if j_max is None:
        carry, _ = jax.lax.scan(body, init, jnp.arange(steps, dtype=jnp.int32))
        return carry
    j_hi = jnp.minimum(j_max.astype(jnp.int32), steps)

    def w_cond(c):
        return c[1] < j_hi

    def w_body(c):
        carry, j = c
        carry, _ = body(carry, j)
        return carry, j + 1

    carry, _ = jax.lax.while_loop(w_cond, w_body, (init, jnp.int32(0)))
    return carry


def _check_draft_kv(cfg: ModelConfig, draft_kv: str, who: str) -> None:
    if draft_kv not in DRAFT_KV_MODES:
        raise ValueError(
            f"unknown draft_kv {draft_kv!r}; pick one of {DRAFT_KV_MODES}"
        )
    if draft_kv == "carry" and (
        cfg.num_codebooks
        or any(
            cfg.block_kind(i) is not BlockKind.ATTENTION
            for i in range(cfg.num_layers)
        )
    ):
        raise ValueError(
            f"{who}: draft_kv='carry' requires an attention-only text stack "
            "— SSM per-step states are cumulative (not row-scatterable) and "
            "codebook tokens are not scalar; use draft_kv='recompute'"
        )


def chain_draft_scan(
    cfg: ModelConfig,
    steps: int,                       # static scan trip count (<= k)
    params: dict,
    cache: dict,                      # batched committed cache (scratch copy semantics)
    pending: jax.Array,               # (B,) int32 last verified token per slot
    chains: jax.Array,                # (B, k) int32, PLD-prefilled prefix
    have: jax.Array,                  # (B,) int32 tokens already proposed (PLD)
    limit: jax.Array,                 # (B,) int32 per-slot adaptive draft cap
    gates: Optional[jax.Array],       # (num_layers,) DSIA layer gates or None
    *,
    quantize: Optional[str] = None,   # "int8": W8A8 MLP matmuls (static)
    attn_override: Optional[dict] = None,   # efficient-attention DSIA (static)
    draft_kv: str = "recompute",      # "recompute" | "carry" (static)
    dynamic_steps: bool = False,      # trip count from (have, limit), on device
) -> Tuple[jax.Array, jax.Array]:
    """Fused k-step neural chain drafting: one ``lax.scan`` over draft steps.

    Step ``j`` writes the draft argmax at position ``j`` into chain position
    ``j`` only where ``have <= j < limit``; PLD-prefilled positions are never
    overwritten, and slots past their adaptive ``limit`` stop contributing
    draft tokens. Unfilled tail positions hold stale tokens during the scan
    — the causal mask keeps them invisible to every filled position. The
    committed cache is READ-ONLY either way: no scratch commits, no cache
    copy, one dispatch per proposal round instead of ``k`` host-synchronized
    decode calls, and losslessness is untouched.

    ``draft_kv`` picks how draft steps see each other:

      - ``"recompute"`` — each step re-decodes the fixed (B, k+1) block
        ``[pending, chain]`` under a causal tree mask (the same staged-KV
        block mechanism verification uses). O(k^2) token-forwards per round;
        at the paper's k <= 5 the padded block is MXU-absorbed on TPU, and
        this is the only mode that supports SSM stacks (their per-step
        states are recomputed inside the block, never carried).
      - ``"carry"`` — ONE initial (B, k+1) block decode fills carried
        staged-KV buffers and an argmax table, then each step decodes only
        the single appended token against [committed cache ++ carried
        staged KV], scattering its K/V back into the buffers. O(k)
        token-forwards per round; attention-only stacks.

    ``dynamic_steps=True`` replaces the static trip count with the exact
    per-round need ``max_b(limit_b where limit_b > have_b)`` computed on
    device (a ``lax.while_loop``) — what the split serving path computes on
    host per round, available to the fused single-dispatch round without a
    sync. Token-identical to the static scan (the skipped iterations have
    dead fill masks). Caveat: on CPU XLA a dynamic-trip While runs each
    iteration noticeably slower than the known-trip scan, so the fused
    rounds keep the static trip and skip the WHOLE scan via ``lax.cond``
    when no slot needs neural fill; prefer ``dynamic_steps`` only where
    the saved iterations beat the While overhead (accelerators).

    Returns (chains, have) with ``have = max(have, min(limit, steps))``.
    """
    _check_draft_kv(cfg, draft_kv, "chain_draft_scan")
    B, K = chains.shape
    toks = jnp.concatenate([pending[:, None], chains], axis=1)   # (B, K+1)
    mask = jnp.tril(jnp.ones((K + 1, K + 1), bool))
    j_max = (
        jnp.max(jnp.where(limit > have, limit, 0)) if dynamic_steps else None
    )

    if draft_kv == "recompute":
        def body(toks, j):
            logits, _ = M.decode_step(
                cfg, params, cache, toks, gates=gates, tree_mask=mask,
                quantize=quantize, attn_override=attn_override,
            )
            nxt = jnp.argmax(logits, -1).astype(toks.dtype)      # (B, K+1)
            fill = (have <= j) & (j < limit)
            col = jnp.where(fill, nxt[:, j], toks[:, j + 1])
            return toks.at[:, j + 1].set(col), None

        toks = _bounded_loop(body, toks, steps, j_max)
        have = jnp.maximum(have, jnp.minimum(limit, jnp.int32(steps)))
        return toks[:, 1:], have

    # --- carry: one block decode seeds the buffers, then 1-token steps
    base = cache["pos"]                                          # (B,)
    col_ids = jnp.arange(K + 1, dtype=jnp.int32)
    logits0, staged0 = M.decode_step(
        cfg, params, cache, toks, gates=gates, tree_mask=mask,
        quantize=quantize, attn_override=attn_override,
    )
    nxt_buf = jnp.argmax(logits0, -1).astype(toks.dtype)         # (B, K+1)

    def body_carry(carry, j):
        toks, nxt_buf, staged = carry
        fill = (have <= j) & (j < limit)
        col = jnp.where(fill, nxt_buf[:, j], toks[:, j + 1])
        toks = toks.at[:, j + 1].set(col)
        # decode ONLY the appended token; staged rows 0..j are final for
        # every slot by step j (PLD rows from the seed decode, drafted rows
        # re-staged by their own step), so causal row visibility is exact
        smask = jnp.broadcast_to(
            (col_ids[None, None, :] <= j), (B, 1, K + 1)
        )
        logits1, st1 = M.decode_step(
            cfg, params, cache, toks[:, j + 1][:, None], gates=gates,
            q_pos=(base + j + 1)[:, None],
            staged_kv=staged, staged_pos=base[:, None] + col_ids[None],
            staged_mask=smask, quantize=quantize, attn_override=attn_override,
        )
        nxt_buf = nxt_buf.at[:, j + 1].set(
            jnp.argmax(logits1[:, 0], -1).astype(toks.dtype)
        )
        staged = jax.tree.map(
            lambda buf, st: buf.at[:, :, j + 1].set(st[:, :, 0].astype(buf.dtype)),
            staged, st1,
        )
        return (toks, nxt_buf, staged), None

    toks, _, _ = _bounded_loop(body_carry, (toks, nxt_buf, staged0), steps, j_max)
    have = jnp.maximum(have, jnp.minimum(limit, jnp.int32(steps)))
    return toks[:, 1:], have


def tree_draft_scan(
    cfg: ModelConfig,
    expansions: int,                  # static scan trip count (max per-slot budget)
    top_k: int,                       # static sibling candidates per expansion
    params: dict,
    cache: dict,                      # batched committed cache (read-only here)
    tokens: jax.Array,                # (B, N) int32 seeded node tokens (node 0 = pending)
    parents: jax.Array,               # (B, N) int32, -1 at root/unused
    depth: jax.Array,                 # (B, N) int32
    p_acc: jax.Array,                 # (B, N) f32 accumulated acceptance per node
    mask: jax.Array,                  # (B, N, N) bool ancestor-closure (self-only unused)
    count: jax.Array,                 # (B,) int32 nodes used (root + PLD seed)
    limit: jax.Array,                 # (B,) int32 per-slot expansion budget (Eq. 5)
    alpha: jax.Array,                 # (B,) f32 per-slot neural acceptance estimate
    c: jax.Array,                     # () f32 draft cost coefficient (stop rule)
    t_min: jax.Array,                 # () f32 min-speedup threshold (stop rule)
    gates: Optional[jax.Array],       # (num_layers,) DSIA layer gates or None
    *,
    top_p: float = 0.3,
    quantize: Optional[str] = None,   # "int8": W8A8 MLP matmuls (static)
    attn_override: Optional[dict] = None,   # efficient-attention DSIA (static)
    draft_kv: str = "recompute",      # "recompute" | "carry" (static)
    dynamic_steps: bool = False,      # trip count = max per-slot limit, on device
) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array, jax.Array, jax.Array,
           jax.Array]:
    """Fused DyTC tree growth: one ``lax.scan`` over expansion steps (§4.2).

    The batched, on-device analogue of ``DyTCScheduler.build_tree``. Each
    scan step obtains the draft's next-token distribution for the padded
    (B, N) node block (the committed cache stays READ-ONLY), then per slot:

      1. picks the active node with the highest accumulated P_acc with a
         ``jnp.argmax`` over the node axis (Alg. 1 line 5 — no host loop),
      2. applies the stop rule P_acc * (alpha/c) < t_min (Alg. 1; the root
         is exempt, mirroring the host scheduler), deactivating the node,
      3. expands: the draft's ``top_k`` next-token candidates become
         children (TOP-P filtered against the top candidate, Alg. 1
         line 19), with token-level P_acc refinement
         ``alpha * sqrt(p_i / p_top)`` as in the host DyTC path. A
         candidate that duplicates an existing child of the leaf (e.g. the
         PLD-seeded chain node the drafter agrees with) is NOT re-added —
         and when the duplicate is the drafter's top-1, ``first_neural``
         aliases the existing node, so the Eq. 4 estimator observes the
         prediction's true accept/reject outcome instead of a spurious
         rejection (the greedy walk always takes the first matching child).

    Slots past their per-slot ``limit`` (the Eq. 5 budget chosen by the
    server from its acceptance/cost trackers) and slots whose tree bucket
    is full stop growing; their carries pass through unchanged, keeping
    every shape jit-stable at the ``TREE_BUCKETS`` padding. Unused node
    slots hold stale tokens — their self-only mask rows keep them invisible
    to every real node, exactly as host-side ``DraftTree.flatten`` pads.

    ``draft_kv`` picks the drafting cost model:

      - ``"recompute"`` — each step re-decodes the whole padded block under
        the dense ancestor-closure mask (the same mechanism verification
        uses): O(E*N) node-forwards per round. Dispatch-free and
        buffer-free; the MXU absorbs the padded block on TPU at small N.
      - ``"carry"`` — ONE seed-block decode fills carried staged-KV buffers
        plus a per-node top-k candidate table, then each expansion step
        decodes only its <= ``top_k`` appended candidates against
        [committed cache ++ carried staged KV] (ancestors via the carried
        buffers, self via the new block, siblings mutually invisible):
        O(N + E*top_k) node-forwards per round — the mode that makes tree
        buckets past N=32 pay. A node's logits depend only on its ancestor
        closure, which never changes after creation, so the cached
        candidates equal what recompute re-derives each step and the two
        modes are token-identical (tests/test_draft_kv_carry.py).

    Returns (tokens, parents, depth, p_acc, mask, count, first_neural)
    where ``first_neural[b]`` is the node index carrying the slot's first
    neural top-1 prediction (-1 if none) — the Eq. 4 observation point.
    """
    _check_draft_kv(cfg, draft_kv, "tree_draft_scan")
    B, N = tokens.shape
    b_idx = jnp.arange(B)
    slot_j = jnp.arange(N)
    active = slot_j[None, :] < count[:, None]          # every seeded node
    first_neural = jnp.full((B,), -1, jnp.int32)
    # dynamic trip count: expansion steps past every slot's limit are
    # complete no-ops (dead select + dropped writes), so stopping at the
    # max per-slot budget is token-identical — the on-device analogue of
    # the split path's host-computed `expansions = limits.max()`
    e_max = jnp.max(limit) if dynamic_steps else None
    alpha = alpha.astype(jnp.float32)
    rate = alpha / jnp.maximum(c.astype(jnp.float32), 1e-6)
    # invariant across expansion steps — read ONCE outside the scan body
    # (drafting never writes the committed cache, so ``pos`` cannot move;
    # tests assert it is untouched after a drafting round)
    base = cache["pos"][:, None]                       # (B, 1)

    def _select(p_acc, active, e):
        """Alg. 1 line 5 + stop rule; returns (leaf, leaf_p, grow, active)."""
        score = jnp.where(active, p_acc, -jnp.inf)
        leaf = jnp.argmax(score, axis=1).astype(jnp.int32)           # (B,)
        valid = jnp.any(active, axis=1) & (e < limit)
        leaf_p = jnp.take_along_axis(p_acc, leaf[:, None], 1)[:, 0]
        # stop rule: least-future-speedup below threshold (root exempt)
        grow = valid & ((leaf == 0) | (leaf_p * rate >= t_min))
        # the selected node is consumed either way (expanded or stopped)
        active = active.at[b_idx, jnp.where(valid, leaf, N)].set(
            False, mode="drop"
        )
        return leaf, leaf_p, grow, active

    def _append(state, grow, leaf, leaf_p, top_vals, top_idx):
        """Expansion bookkeeping — shared VERBATIM by both draft_kv modes,
        which is what makes carry-mode parity with recompute exact: only
        the source of (top_vals, top_idx) differs between them."""
        tokens, parents, depth, p_acc, mask, count, active, first_neural = state
        parent_row = jnp.take_along_axis(mask, leaf[:, None, None], axis=1)[:, 0]
        parent_depth = jnp.take_along_axis(depth, leaf[:, None], 1)[:, 0]
        idxs = []
        for r in range(top_k):   # kept candidates land contiguously at count
            tok_r = top_idx[:, r].astype(jnp.int32)
            # dedup: an existing same-token child of this leaf (PLD seed or
            # earlier expansion) already covers this candidate in the walk
            real_now = slot_j[None, :] < count[:, None]
            dup_cand = (parents == leaf[:, None]) & (tokens == tok_r[:, None]) & real_now
            dup = dup_cand.any(axis=1)
            dup_idx = jnp.argmax(dup_cand, axis=1).astype(jnp.int32)
            keep = grow & ~dup & (count < N)
            if r > 0:   # TOP-P sibling filter (Alg. 1 line 19)
                keep &= top_vals[:, r] >= top_p * top_vals[:, 0]
            idx = jnp.where(keep, count, N)            # N = dropped write
            a_node = jnp.minimum(
                1.0,
                alpha
                * jnp.sqrt(top_vals[:, r] / jnp.maximum(top_vals[:, 0], 1e-9)),
            )
            # a duplicated child was seeded with the PLD prior — the neural
            # drafter just confirmed it, so refresh its P_acc to the neural
            # score (else best-leaf selection undervalues the agreed chain)
            ridx = jnp.where(grow & dup, dup_idx, N)
            old_p = jnp.take_along_axis(
                p_acc, jnp.minimum(ridx, N - 1)[:, None], 1
            )[:, 0]
            p_acc = p_acc.at[b_idx, ridx].set(
                jnp.maximum(old_p, leaf_p * a_node), mode="drop"
            )
            tokens = tokens.at[b_idx, idx].set(tok_r, mode="drop")
            parents = parents.at[b_idx, idx].set(leaf, mode="drop")
            depth = depth.at[b_idx, idx].set(parent_depth + 1, mode="drop")
            p_acc = p_acc.at[b_idx, idx].set(leaf_p * a_node, mode="drop")
            row = parent_row | (slot_j[None, :] == idx[:, None])
            mask = mask.at[b_idx, idx].set(row, mode="drop")
            active = active.at[b_idx, idx].set(True, mode="drop")
            if r == 0:
                # the node carrying the drafter's top-1 outcome: the new
                # child, or the existing duplicate it agrees with
                outcome = jnp.where(grow & dup, dup_idx, jnp.where(keep, idx, N))
                first_neural = jnp.where(
                    (first_neural < 0) & (outcome < N), outcome, first_neural
                )
            count = count + keep.astype(jnp.int32)
            idxs.append(idx)
        state = (tokens, parents, depth, p_acc, mask, count, active, first_neural)
        return state, idxs, parent_row, parent_depth

    if draft_kv == "recompute":
        def body(carry, e):
            tokens, parents, depth, p_acc, mask, count, active, first_neural = carry
            qpos = base + depth
            logits, _ = M.decode_step(
                cfg, params, cache, tokens, gates=gates, tree_mask=mask, q_pos=qpos,
                quantize=quantize, attn_override=attn_override,
            )
            leaf, leaf_p, grow, active = _select(p_acc, active, e)
            lg = jnp.take_along_axis(logits, leaf[:, None, None], axis=1)[:, 0]
            probs = jax.nn.softmax(lg.astype(jnp.float32), axis=-1)  # (B, V)
            top_vals, top_idx = jax.lax.top_k(probs, top_k)
            state = (tokens, parents, depth, p_acc, mask, count, active, first_neural)
            state, _, _, _ = _append(state, grow, leaf, leaf_p, top_vals, top_idx)
            return state, None

        carry = (tokens, parents, depth, p_acc.astype(jnp.float32), mask, count,
                 active, first_neural)
        carry = _bounded_loop(body, carry, expansions, e_max)
        tokens, parents, depth, p_acc, mask, count, _, first_neural = carry
        return tokens, parents, depth, p_acc, mask, count, first_neural

    # --- carry: seed decode fills the buffers + per-node candidate table
    logits0, staged0 = M.decode_step(
        cfg, params, cache, tokens, gates=gates, tree_mask=mask,
        q_pos=base + depth, quantize=quantize, attn_override=attn_override,
    )
    probs0 = jax.nn.softmax(logits0.astype(jnp.float32), axis=-1)    # (B, N, V)
    cand_v, cand_i = jax.lax.top_k(probs0, top_k)                    # (B, N, k)
    cand_i = cand_i.astype(jnp.int32)

    def body_carry(carry, e):
        (tokens, parents, depth, p_acc, mask, count, active, first_neural,
         staged, cand_v, cand_i) = carry
        leaf, leaf_p, grow, active = _select(p_acc, active, e)
        top_vals = jnp.take_along_axis(cand_v, leaf[:, None, None], axis=1)[:, 0]
        top_idx = jnp.take_along_axis(cand_i, leaf[:, None, None], axis=1)[:, 0]
        state = (tokens, parents, depth, p_acc, mask, count, active, first_neural)
        state, idxs, parent_row, parent_depth = _append(
            state, grow, leaf, leaf_p, top_vals, top_idx
        )
        tokens, parents, depth, p_acc, mask, count, active, first_neural = state
        # decode ONLY the <= top_k appended candidates against [committed
        # cache ++ carried staged KV]: ancestors come from the buffers via
        # the leaf's closure row, self-visibility from the new block, and
        # siblings stay mutually invisible (eye mask) — exactly the rows
        # the recompute block decode exposes to these nodes. Dropped
        # (duplicate) candidates decode too (jit-stable block); their
        # buffer writes land on index N and are dropped.
        qpos_new = jnp.broadcast_to(
            (base[:, 0] + parent_depth + 1)[:, None], (B, top_k)
        )
        svis = jnp.broadcast_to(parent_row[:, None, :], (B, top_k, N))
        logits_n, st_n = M.decode_step(
            cfg, params, cache, top_idx.astype(jnp.int32), gates=gates,
            tree_mask=jnp.eye(top_k, dtype=bool), q_pos=qpos_new,
            staged_kv=staged, staged_pos=base + depth, staged_mask=svis,
            quantize=quantize, attn_override=attn_override,
        )
        probs_n = jax.nn.softmax(logits_n.astype(jnp.float32), axis=-1)
        cv_n, ci_n = jax.lax.top_k(probs_n, top_k)       # (B, top_k, top_k)
        idxs_arr = jnp.stack(idxs, axis=1)               # (B, top_k)
        staged = jax.tree.map(
            lambda buf, st: buf.at[:, b_idx[:, None], idxs_arr].set(
                st.astype(buf.dtype), mode="drop"
            ),
            staged, st_n,
        )
        cand_v = cand_v.at[b_idx[:, None], idxs_arr].set(cv_n, mode="drop")
        cand_i = cand_i.at[b_idx[:, None], idxs_arr].set(
            ci_n.astype(jnp.int32), mode="drop"
        )
        return (tokens, parents, depth, p_acc, mask, count, active,
                first_neural, staged, cand_v, cand_i), None

    carry = (tokens, parents, depth, p_acc.astype(jnp.float32), mask, count,
             active, first_neural, staged0, cand_v, cand_i)
    carry = _bounded_loop(body_carry, carry, expansions, e_max)
    tokens, parents, depth, p_acc, mask, count, _, first_neural = carry[:8]
    return tokens, parents, depth, p_acc, mask, count, first_neural


def cascade_rescore(
    cfg: ModelConfig,
    params: dict,
    cache: dict,                      # batched committed cache (read-only here)
    tokens: jax.Array,                # (B, N) int32 node tokens from the level below
    parents: jax.Array,               # (B, N) int32 (-1 root, -2 pruned, N unused)
    depth: jax.Array,                 # (B, N) int32
    p_acc: jax.Array,                 # (B, N) f32
    mask: jax.Array,                  # (B, N, N) bool ancestor closure
    count: jax.Array,                 # (B,) int32 node slots consumed
    probe: jax.Array,                 # (B,) int32 node whose verdict to report (-1 none)
    apply: jax.Array,                 # (B,) bool: slots routed through this level
    alpha: jax.Array,                 # (B,) f32 this level's acceptance estimate
    gates: Optional[jax.Array],       # (num_layers,) this level's DSIA gates
    *,
    quantize: Optional[str] = None,   # "int8": W8A8 MLP matmuls (static)
    attn_override: Optional[dict] = None,   # efficient-attention DSIA (static)
    attn_backend: Optional[str] = None,     # "pallas": kernel intra-tree pass
    sampling: Optional[tuple] = None, # (temp (B,), top_k (B,), top_p (B,),
                                      #  u (B, N+2)) -> stochastic rescore
):
    """ONE intermediate-verify dispatch of a stronger cascade level — the
    batched, on-device form of Alg. 1's level-to-level acceptance (the
    vertical-cascade "verify and extend" that ``VCScheduler`` runs host-side
    one request at a time, recast tree-natively).

    The level decodes the whole padded node block under the ancestor-closure
    masks (committed cache READ-ONLY — exactly the verification mechanism)
    and then, per slot where ``apply``:

      1. **endorse** — a node whose token equals this level's argmax at its
         parent, with every proper ancestor likewise endorsed, is confirmed:
         its P_acc is refreshed to this level's (stronger) estimate;
      2. **hedge** — at the SHALLOWEST first-mismatch node, the level adds
         its own argmax continuation as a *sibling* (skipped when an
         endorsed sibling already carries that token). The cheaper level's
         node is KEPT: the target may still accept it, and a tree hedges
         instead of overwriting — this makes the rescored tree a strict
         superset of the drafted tree, so a cascade round can never accept
         fewer tokens than the drafter alone (the tree-cascade analogue of
         "verify"; a chain cascade would truncate here);
      3. **extend** — the deepest fully-endorsed node gets one new child
         carrying this level's argmax continuation (the analogue of
         "extend"; skipped when a sibling already carries that token, e.g.
         the hedge node, or when the bucket is full).

    Slots with ``apply=False`` pass through untouched (they ride the same
    dispatch — per-slot routing never changes the executable).

    Returns ``(tokens, parents, depth, p_acc, mask, count, level_node,
    probe_ok, probe_valid)``: ``level_node[b]`` is the depth-1 node carrying
    this level's own continuation of the root (-1 if none) — the next
    level's Eq. 4 observation point, always judgeable because the root is
    the target's own pending token; ``probe_ok``/``probe_valid`` report this
    level's verdict on the INPUT node ``probe[b]`` (the level below's first
    own prediction), valid only when the probe's ancestors were all
    endorsed (DyTC's parent-accepted rule).

    ``sampling`` switches on the level-to-level STOCHASTIC rescore rule:
    a node is endorsed with prob q_level[parent](token) — one carried
    uniform per node against this level's warped distribution — and the
    hedge/extend continuations become inverse-CDF draws from q_level
    instead of argmaxes (the last two uniforms). This is proposal shaping
    only: losslessness is owned entirely by the FINAL target verify (the
    stochastic tree walk in ``cascade_rescore_verify``), which is
    distribution-preserving for ANY proposal tree.
    """
    B, N = tokens.shape
    b_idx = jnp.arange(B)
    slot_j = jnp.arange(N)
    qpos = cache["pos"][:, None] + depth
    logits, _ = M.decode_step(
        cfg, params, cache, tokens, gates=gates, tree_mask=mask, q_pos=qpos,
        quantize=quantize, attn_override=attn_override,
        attn_backend=attn_backend,
    )
    nxt = jnp.argmax(logits, -1).astype(jnp.int32)               # (B, N)

    real = slot_j[None, :] < count[:, None]
    has_parent = real & (parents >= 0)                           # non-root live
    p_clip = jnp.clip(parents, 0, N - 1)
    parent_nxt = jnp.take_along_axis(nxt, p_clip, axis=1)        # (B, N)
    if sampling is None:
        ok = jnp.where(has_parent, tokens == parent_nxt, True)
    else:
        s_temp, s_topk, s_topp, s_u = sampling
        q_lvl = verify_lib.sampling_probs(logits, s_temp, s_topk, s_topp)
        q_par = jnp.take_along_axis(q_lvl, p_clip[:, :, None], axis=1)
        tok_p = jnp.take_along_axis(q_par, tokens[..., None], -1)[..., 0]
        ok = jnp.where(has_parent, s_u[:, :N] < tok_p, True)
    bad = has_parent & ~ok
    eye = jnp.eye(N, dtype=bool)[None]
    anc_bad = (mask & ~eye & bad[:, None, :]).any(-1)            # bad proper ancestor
    # probe verdict BEFORE any mutation (the level below's first prediction)
    probe_c = jnp.clip(probe, 0, N - 1)
    probe_valid = apply & (probe >= 0) & ~jnp.take_along_axis(
        anc_bad, probe_c[:, None], 1
    )[:, 0]
    probe_ok = jnp.take_along_axis(ok, probe_c[:, None], 1)[:, 0] & probe_valid

    alpha = alpha.astype(jnp.float32)
    parent_p = jnp.take_along_axis(p_acc, p_clip, axis=1)
    endorsed = real & ~bad & ~anc_bad                            # root included
    # endorse: refresh P_acc to this (stronger) level's estimate, exactly
    # like the drafting scan refreshes a confirmed PLD seed
    p_acc = jnp.where(
        endorsed & has_parent & apply[:, None],
        jnp.maximum(p_acc, parent_p * alpha[:, None]), p_acc,
    )

    def _append(tokens, parents, depth, p_acc, mask, count, at, tok, want):
        """Add one child per slot under node ``at`` carrying ``tok`` (drop
        when a sibling already has that token, the bucket is full, or
        ``want`` is off). Returns updated arrays + the kept mask."""
        real_now = slot_j[None, :] < count[:, None]
        sib = (parents == at[:, None]) & real_now & (tokens == tok[:, None])
        keep = want & ~sib.any(axis=1) & (count < N)
        idx = jnp.where(keep, count, N)                          # N = dropped
        a_depth = jnp.take_along_axis(depth, at[:, None], 1)[:, 0]
        a_p = jnp.take_along_axis(p_acc, at[:, None], 1)[:, 0]
        a_row = jnp.take_along_axis(mask, at[:, None, None], axis=1)[:, 0]
        tokens = tokens.at[b_idx, idx].set(tok, mode="drop")
        parents = parents.at[b_idx, idx].set(at, mode="drop")
        depth = depth.at[b_idx, idx].set(a_depth + 1, mode="drop")
        p_acc = p_acc.at[b_idx, idx].set(a_p * alpha, mode="drop")
        mask = mask.at[b_idx, idx].set(
            a_row | (slot_j[None, :] == idx[:, None]), mode="drop"
        )
        count = count + keep.astype(jnp.int32)
        return tokens, parents, depth, p_acc, mask, count, keep

    state = (tokens, parents, depth, p_acc, mask, count)
    # hedge: a sibling with this level's own continuation at the SHALLOWEST
    # first-mismatch (the most probable rejection point of the drafted tree)
    cand = bad & ~anc_bad
    has_hedge = cand.any(axis=1)
    hedge_src = jnp.argmin(jnp.where(cand, depth, N + 1), axis=1).astype(jnp.int32)
    hedge_at = jnp.take_along_axis(p_clip, hedge_src[:, None], 1)[:, 0]
    if sampling is None:
        hedge_tok = jnp.take_along_axis(parent_nxt, hedge_src[:, None], 1)[:, 0]
    else:
        q_h = jnp.take_along_axis(q_lvl, hedge_at[:, None, None], axis=1)[:, 0]
        hedge_tok = verify_lib._inv_cdf(q_h, s_u[:, N])
    state = _append(*state, jnp.where(has_hedge, hedge_at, 0),
                    hedge_tok, apply & has_hedge)[:-1]
    # extend: one child below the deepest fully-endorsed node
    frontier = jnp.argmax(jnp.where(endorsed, depth, -1), axis=1).astype(jnp.int32)
    if sampling is None:
        ext_tok = jnp.take_along_axis(nxt, frontier[:, None], 1)[:, 0]
    else:
        q_f = jnp.take_along_axis(q_lvl, frontier[:, None, None], axis=1)[:, 0]
        ext_tok = verify_lib._inv_cdf(q_f, s_u[:, N + 1])
    state = _append(*state, frontier, ext_tok, apply)[:-1]
    tokens, parents, depth, p_acc, mask, count = state

    # this level's Eq. 4 observation point: the depth-1 node carrying its
    # argmax continuation of the ROOT (the root is the target's own pending
    # token, so the node's parent is ALWAYS accepted — first-token
    # acceptance, exactly the chain path's estimator). After substitution /
    # extension such a node exists whenever the slot was rescored: an
    # endorsed draft child, a substituted child, or the appended extension
    # when the tree was empty. An endorsed child counts as this level's own
    # prediction — endorsement means its token EQUALS this level's argmax.
    root_nxt = nxt[:, 0]
    real_now = slot_j[None, :] < count[:, None]                  # incl. appended
    lvl_cand = (parents == 0) & real_now & (tokens == root_nxt[:, None])
    level_node = jnp.where(
        apply & lvl_cand.any(axis=1),
        jnp.argmax(lvl_cand, axis=1).astype(jnp.int32),
        jnp.int32(-1),
    )
    return (tokens, parents, depth, p_acc, mask, count,
            level_node, probe_ok, probe_valid)


def verify_accept_commit(
    cfg: ModelConfig,
    params: dict,
    cache: dict,
    pending: jax.Array,               # (B,) int32
    chains: jax.Array,                # (B, k) int32
    have: jax.Array,                  # (B,) int32
    live: jax.Array,                  # (B,) bool
):
    """One fused target round for chain proposals: verify [pending, chain]
    jointly, accept the longest matching prefix per slot (vectorized — no
    per-slot Python), and commit the accepted path.
    Returns (cache, nxt, n_chain, new_pending)."""
    toks = jnp.concatenate([pending[:, None], chains], axis=1)   # (B, k+1)
    logits, staged = M.decode_step(cfg, params, cache, toks)
    nxt = jnp.argmax(logits, -1).astype(jnp.int32)               # (B, k+1)
    B, K = chains.shape
    ok = (chains == nxt[:, :K]) & (jnp.arange(K)[None] < have[:, None])
    # accepted chain prefix length: leading run of matches
    n_chain = jnp.cumprod(ok.astype(jnp.int32), axis=1).sum(axis=1)
    n_chain = jnp.where(live, n_chain, 0)
    n_acc = jnp.where(live, n_chain + 1, 0).astype(jnp.int32)    # + pending
    new_pending = jnp.take_along_axis(nxt, n_chain[:, None], axis=1)[:, 0]
    path_idx = jnp.broadcast_to(
        jnp.arange(K + 1, dtype=jnp.int32)[None], (B, K + 1)
    )
    new_cache = M.commit_cache(cfg, cache, staged, path_idx, n_acc)
    return new_cache, nxt, n_chain, new_pending


def verify_accept_commit_sampled(
    cfg: ModelConfig,
    params: dict,
    cache: dict,
    pending: jax.Array,               # (B,) int32
    chains: jax.Array,                # (B, k) int32
    have: jax.Array,                  # (B,) int32
    live: jax.Array,                  # (B,) bool
    temp: jax.Array,                  # (B,) f32, <= 0 -> greedy point mass
    top_k: jax.Array,                 # (B,) int32
    top_p: jax.Array,                 # (B,) f32
    u: jax.Array,                     # (B, k+1) f32 round uniforms
):
    """Sampled twin of ``verify_accept_commit``: the same fused target
    round, but acceptance is Leviathan speculative sampling against the
    warped target distribution (point-mass drafts — see
    ``verify.sample_accept_chain_batched``) instead of argmax matching.
    Slots with ``temp <= 0`` get a one-hot q, which reproduces the greedy
    accept/bonus rule token-for-token. The uniforms arrive pre-split from
    the carried per-slot PRNG key — no key ever leaves the device.
    Returns (cache, n_chain, new_pending)."""
    toks = jnp.concatenate([pending[:, None], chains], axis=1)   # (B, k+1)
    logits, staged = M.decode_step(cfg, params, cache, toks)
    B, K = chains.shape
    q = verify_lib.sampling_probs(logits, temp, top_k, top_p)    # (B, k+1, V)
    n_chain, nxt_tok = verify_lib.sample_accept_chain_batched(
        chains, have, q, u[:, :K], u[:, K]
    )
    n_chain = jnp.where(live, n_chain, 0)
    n_acc = jnp.where(live, n_chain + 1, 0).astype(jnp.int32)    # + pending
    path_idx = jnp.broadcast_to(
        jnp.arange(K + 1, dtype=jnp.int32)[None], (B, K + 1)
    )
    new_cache = M.commit_cache(cfg, cache, staged, path_idx, n_acc)
    return new_cache, n_chain, nxt_tok


def tree_verify_accept_commit(
    cfg: ModelConfig,
    params: dict,
    cache: dict,
    tokens: jax.Array,                # (B, N) int32 padded tree node tokens
    parents: jax.Array,               # (B, N) int32, -1 at root/unused
    depth: jax.Array,                 # (B, N) int32
    mask: jax.Array,                  # (B, N, N) bool ancestor closure
    count: jax.Array,                 # (B,) int32 real nodes per slot
    live: jax.Array,                  # (B,) bool
    *,
    attn_backend: Optional[str] = None,
):
    """One fused target round for tree proposals: decode the whole padded
    node block jointly under per-slot ancestor-closure masks (the intra-tree
    attention half routes through ``kernels.tree_attention`` when
    ``attn_backend="pallas"``), walk the longest target-greedy path per slot
    with a vectorized tree walk, and commit the accepted path's staged KV.
    Returns (cache, path_idx (B,N), n_acc (B,), bonus (B,))."""
    qpos = cache["pos"][:, None] + depth
    logits, staged = M.decode_step(
        cfg, params, cache, tokens, tree_mask=mask, q_pos=qpos,
        attn_backend=attn_backend,
    )
    nxt = jnp.argmax(logits, -1).astype(jnp.int32)               # (B, N)
    path, n_acc, bonus = verify_lib.greedy_accept_tree_batched(
        tokens, parents, count, nxt
    )
    n_acc = jnp.where(live, n_acc, 0).astype(jnp.int32)
    new_cache = M.commit_cache(cfg, cache, staged, path, n_acc)
    return new_cache, path, n_acc, bonus


def tree_verify_accept_commit_sampled(
    cfg: ModelConfig,
    params: dict,
    cache: dict,
    tokens: jax.Array,                # (B, N) int32 padded tree node tokens
    parents: jax.Array,               # (B, N) int32, -1 at root/unused
    depth: jax.Array,                 # (B, N) int32
    mask: jax.Array,                  # (B, N, N) bool ancestor closure
    count: jax.Array,                 # (B,) int32 real nodes per slot
    live: jax.Array,                  # (B,) bool
    temp: jax.Array,                  # (B,) f32, <= 0 -> greedy point mass
    top_k: jax.Array,                 # (B,) int32
    top_p: jax.Array,                 # (B,) f32
    u: jax.Array,                     # (B, N) f32 one uniform per walk step
    *,
    attn_backend: Optional[str] = None,
):
    """Sampled twin of ``tree_verify_accept_commit``: the same fused target
    decode + commit, but the accepted path comes from the stochastic tree
    walk (``verify.sample_accept_tree_batched`` — the tree-native
    speculative-sampling rule for point-mass drafts, distribution-
    preserving at every step). temp <= 0 slots reproduce the greedy walk
    token-for-token. Returns (cache, path, n_acc, next_tok)."""
    qpos = cache["pos"][:, None] + depth
    logits, staged = M.decode_step(
        cfg, params, cache, tokens, tree_mask=mask, q_pos=qpos,
        attn_backend=attn_backend,
    )
    q = verify_lib.sampling_probs(logits, temp, top_k, top_p)    # (B, N, V)
    path, n_acc, nxt_tok = verify_lib.sample_accept_tree_batched(
        tokens, parents, count, q, u
    )
    n_acc = jnp.where(live, n_acc, 0).astype(jnp.int32)
    new_cache = M.commit_cache(cfg, cache, staged, path, n_acc)
    return new_cache, path, n_acc, nxt_tok


def cascade_rescore_verify(
    cfg: ModelConfig,
    level_params: dict,
    target_params: dict,
    cache: dict,
    tokens: jax.Array,
    parents: jax.Array,
    depth: jax.Array,
    p_acc: jax.Array,
    mask: jax.Array,
    count: jax.Array,
    probe: jax.Array,
    apply: jax.Array,
    alpha: jax.Array,
    gates: Optional[jax.Array],
    live: jax.Array,
    *,
    quantize: Optional[str] = None,
    attn_override: Optional[dict] = None,
    attn_backend: Optional[str] = None,
    sampling: Optional[tuple] = None,  # (temp, top_k, top_p, key (B,2) u32)
):
    """The cascade's LAST rescore dispatch with the target verify folded in:
    one jitted call runs the strongest level's ``cascade_rescore`` and then
    the target's ``tree_verify_accept_commit`` over the rescored tree, so an
    L-level cascade round is 1 draft + (L-2) rescores + 1 rescore-and-verify
    dispatch — and the commit scatter can alias a donated cache in place.
    On a mesh the per-slot tree arrays are pinned to their data-parallel
    placement on entry and exit (``_pin_batch``; no-op off-mesh), so the
    fused dispatch neither regathers the proposal nor reshards the cache it
    commits into.

    ``sampling`` carries the per-slot warp params and the slot PRNG keys:
    the keys are split IN-dispatch into the stochastic-rescore uniforms
    (N+2) plus the stochastic tree-walk uniforms (N), the rescore runs the
    level-to-level stochastic rule, and the final verify becomes the
    distribution-preserving stochastic walk against the warped TARGET
    distribution — same dispatch count, zero host syncs, and an extra
    trailing output: the advanced keys.

    Returns the rescore outputs followed by (cache, path, n_acc, bonus)
    [+ new_key when sampled]."""
    dax = data_axis()
    (tokens, parents, depth, p_acc, mask, count, probe, apply, alpha,
     live) = _pin_batch(
        (tokens, parents, depth, p_acc, mask, count, probe, apply, alpha,
         live), dax,
    )
    N = tokens.shape[1]
    resc_sampling = None
    if sampling is not None:
        s_temp, s_topk, s_topp, key = sampling
        new_key, u = verify_lib.round_uniforms(key, 2 * N + 2)
        resc_sampling = (s_temp, s_topk, s_topp, u[:, :N + 2])
    (tokens, parents, depth, p_acc, mask, count, level_node, probe_ok,
     probe_valid) = cascade_rescore(
        cfg, level_params, cache, tokens, parents, depth, p_acc, mask, count,
        probe, apply, alpha, gates,
        quantize=quantize, attn_override=attn_override,
        attn_backend=attn_backend, sampling=resc_sampling,
    )
    if sampling is None:
        new_cache, path, n_acc, bonus = tree_verify_accept_commit(
            cfg, target_params, cache, tokens, parents, depth, mask, count,
            live, attn_backend=attn_backend,
        )
    else:
        new_cache, path, n_acc, bonus = tree_verify_accept_commit_sampled(
            cfg, target_params, cache, tokens, parents, depth, mask, count,
            live, s_temp, s_topk, s_topp, u[:, N + 2:],
            attn_backend=attn_backend,
        )
    (tokens, parents, depth, p_acc, mask, count, level_node, probe_ok,
     probe_valid, path, n_acc, bonus) = _pin_batch(
        (tokens, parents, depth, p_acc, mask, count, level_node, probe_ok,
         probe_valid, path, n_acc, bonus), dax,
    )
    out = (tokens, parents, depth, p_acc, mask, count, level_node, probe_ok,
           probe_valid, new_cache, path, n_acc, bonus)
    return out if sampling is None else out + (new_key,)


# ===================================================== single-dispatch rounds
def _pin_batch(tree, dax):
    """Pin every array in ``tree`` (a dict or flat sequence of per-slot
    arrays, leading dim = batch) to the data-parallel axes. On a mesh this
    keeps the carried round state resident in its data-sharded placement —
    the round's outputs then alias the donated inputs with NO resharding
    collective between rounds; off-mesh ``constrain`` no-ops, so
    single-device rounds lower to byte-identical executables."""
    if dax is None:
        return tree
    if isinstance(tree, dict):
        return {
            k: constrain(v, dax, *([None] * (v.ndim - 1)))
            for k, v in tree.items()
        }
    return type(tree)(
        constrain(v, dax, *([None] * (v.ndim - 1))) for v in tree
    )


def _round_prologue(cfg, cache, state, draft_k, max_ngram, min_ngram):
    """Shared head of the fused rounds: append the pending token to the
    device context buffer and retrieve PLD proposals for every slot inside
    the round executable. Returns (ctx, chains, have) with dead slots'
    proposals zeroed."""
    B, L = state["ctx"].shape
    b_idx = jnp.arange(B)
    n = cache["pos"]
    live = state["live"]
    # writing pending at position n IS the commit of this round's first
    # accepted token (the pending token is always accepted when live), so
    # the buffer stays consistent whatever the round accepts
    ctx = state["ctx"].at[b_idx, jnp.where(n < L, n, L)].set(
        state["pending"].astype(jnp.int32), mode="drop"
    )
    chains, have = pld_lib.propose_device(
        ctx, jnp.minimum(n + 1, L), draft_k,
        max_ngram=max_ngram, min_ngram=min_ngram,
    )
    have = jnp.where(live, have, 0)
    chains = jnp.where(jnp.arange(draft_k)[None] < have[:, None], chains, 0)
    return ctx, chains, have


def _commit_ctx(ctx, n, acc_tok, n_acc):
    """Scatter this round's accepted tokens into the context buffer at
    positions [n, n + n_acc) — the device-side maintenance that keeps the
    next round's PLD exact without any host contexts."""
    B, L = ctx.shape
    T = acc_tok.shape[1]
    t_ids = jnp.arange(T)
    dest = jnp.where(
        t_ids[None, :] < n_acc[:, None], n[:, None] + t_ids[None, :], L
    )
    return ctx.at[jnp.arange(B)[:, None], dest].set(acc_tok, mode="drop")


def prefill_chunk_stage(
    cfg: ModelConfig,
    params: dict,
    cache: dict,
    state: dict,
    *,
    chunk: int,
    sampled: bool = False,
) -> Tuple[dict, dict]:
    """Continuous chunked prefill, fused into the serving round.

    Consumes up to ``chunk`` prompt tokens for every slot whose prompt is
    still being prefilled (``state["pf_done"] < state["pf_len"]``; the
    prompt itself sits in the carried ``ctx`` buffer) through ONE
    ``decode_step`` + ``commit_cache`` — the staged rows of a chunk are
    committed unconditionally (the prompt needs no verification), advancing
    ``cache["pos"]`` and ``pf_done`` together. The whole stage is
    ``lax.cond``-gated on any slot being mid-prefill, so steady-state
    rounds (nobody prefilling) skip its compute entirely while keeping one
    executable.

    On the round a slot finishes its prompt, its first generated token is
    produced HERE — greedy argmax of the last prompt position's logits, or
    (``sampled=True``) the same split + uniform + warp + inverse-CDF
    sequence the dense admission path runs on host — and stored as the
    slot's ``pending``, so the slot joins the decode round in the SAME
    dispatch and its token stream matches the dense path's from the first
    token. Slots still mid-prefill get their ``pending`` set to
    ``ctx[pos]`` (the prompt token already there), which turns the round
    prologue's pending scatter into a value no-op — the prompt is never
    corrupted, and the serving wrapper masks those slots out of ``live``
    for the decode half.

    Restrictions (enforced at server build time): attention-only stacks
    (SSM states would need per-slot zeroing at enqueue), non-ring paged
    caches, ``round_mode="single"``.
    """
    pf_done, pf_len = state["pf_done"], state["pf_len"]
    active = pf_done < pf_len

    def _run(ops):
        cache, state = ops
        state = dict(state)
        ctx = state["ctx"]
        B, L = ctx.shape
        pf_done, pf_len = state["pf_done"], state["pf_len"]
        n_new = jnp.where(
            active, jnp.minimum(pf_len - pf_done, chunk), 0
        ).astype(jnp.int32)
        offs = pf_done[:, None] + jnp.arange(chunk, dtype=jnp.int32)[None]
        toks = jnp.take_along_axis(ctx, jnp.clip(offs, 0, L - 1), axis=1)
        logits, staged = M.decode_step(cfg, params, cache, toks, q_pos=offs)
        path = jnp.broadcast_to(
            jnp.arange(chunk, dtype=jnp.int32)[None], (B, chunk)
        )
        new_cache = M.commit_cache(cfg, cache, staged, path, n_new)
        done_now = active & (pf_done + n_new >= pf_len)
        last_i = jnp.clip(n_new - 1, 0, chunk - 1)
        last = jnp.take_along_axis(logits, last_i[:, None, None], axis=1)[:, 0]
        if sampled:
            # device twin of the host admission draw (add_request): split
            # the admission-bound key, one uniform from the sub-key, warp,
            # inverse-CDF — and carry the advanced key only on completion
            ks = jax.vmap(lambda k: jax.random.split(k, 2))(state["key"])
            u0 = jax.vmap(lambda k: jax.random.uniform(k, ()))(ks[:, 1])
            q = verify_lib.sampling_probs(
                last, state["temp"], state["topk"], state["topp"]
            )
            first = verify_lib._inv_cdf(q, u0)
            state["key"] = jnp.where(
                done_now[:, None], ks[:, 0], state["key"]
            )
        else:
            first = jnp.argmax(last, -1).astype(jnp.int32)
        pend = jnp.where(done_now, first, state["pending"])
        new_done = pf_done + n_new
        still = new_done < pf_len
        safe = jnp.take_along_axis(
            ctx, jnp.clip(new_cache["pos"], 0, L - 1)[:, None], axis=1
        )[:, 0]
        state["pending"] = jnp.where(still, safe, pend).astype(jnp.int32)
        state["pf_done"] = new_done
        return new_cache, state

    return jax.lax.cond(
        jnp.any(active), _run, lambda ops: ops, (cache, dict(state))
    )


def chain_round(
    cfg: ModelConfig,
    params: dict,
    cache: dict,                      # donated: the commit aliases in place
    state: dict,                      # donated carried device state (see server)
    c: jax.Array,                     # () f32 draft cost coefficient
    gates: Optional[jax.Array],       # (num_layers,) DSIA layer gates or None
    *,
    draft_k: int,
    use_draft: bool,
    adaptive: bool,
    min_obs: int,
    t_min: float,
    draft_kv: str = "recompute",
    max_ngram: int = 4,
    min_ngram: int = 1,
    sampled: bool = False,
):
    """ONE fused, device-resident ``chain_fused`` serving round.

    PLD retrieval over the carried context buffer, Eq. 5 per-slot draft
    budgets from the carried Eq. 4 EMA state, the k-step neural chain scan,
    target verification, acceptance, cache + context commit, and the EMA
    update for round r+1 — all inside a single jitted dispatch, so the host
    never blocks between rounds (the pipelined server drains the returned
    ``out`` arrays whenever it chooses to sync).

    ``state`` carries ``pending (B,) i32``, ``live (B,) bool``,
    ``ctx (B, max_len) i32``, and the Eq. 4 estimator arrays ``alpha``,
    ``hist``, ``hist_n``, ``hist_ptr`` (see ``acceptance.ema_init``).
    With ``sampled=True`` it additionally carries the per-slot sampling
    state — ``key (B, 2) u32`` threefry keys plus ``temp``/``topk``/
    ``topp`` warp params — and verification becomes speculative SAMPLING
    acceptance (``verify_accept_commit_sampled``): the keys are split
    in-dispatch, the advanced keys ride the carried state, and slots with
    ``temp <= 0`` reproduce the greedy round token-for-token. Same
    executable count, zero extra host syncs.
    Returns ``(cache, state, out)`` where ``out`` holds the round's
    accepted tokens: ``acc (B, k+1)`` (valid prefix ``n_acc``), plus
    ``pld_have``/``have`` for host-side stats.

    On a mesh the carried state is pinned to its data-parallel placement
    on entry AND exit (see ``_pin_batch``): one donated dispatch per round
    stays one dispatch — no resharding round-trips between rounds.
    """
    dax = data_axis()
    state = _pin_batch(dict(state), dax)
    live = state["live"]
    pending = state["pending"]
    n = cache["pos"]
    ctx, chains, have = _round_prologue(
        cfg, cache, state, draft_k, max_ngram, min_ngram
    )
    pld_have = have
    limit = jnp.zeros_like(have)
    if use_draft:
        if adaptive:
            budget = best_chain_length_batched(
                state["alpha"], c, draft_k, t_min
            )
            limit = jnp.where(state["hist_n"] >= min_obs, budget, draft_k)
        else:
            limit = jnp.full(live.shape, draft_k, jnp.int32)
        limit = jnp.where(live, limit, 0)

        def _draft(ops):
            ch, hv = ops
            return chain_draft_scan(
                cfg, draft_k, params, cache, pending, ch, hv, limit, gates,
                draft_kv=draft_kv,
            )

        # runtime skip: rounds where PLD covered every budget (or routing
        # stopped drafting) pay NO neural draft compute — the economics the
        # split path gets from its host-computed trip count, decided
        # entirely on device. (The scan keeps its static trip inside the
        # taken branch: XLA's known-trip While beats the dynamic-trip form
        # on CPU — see chain_draft_scan(dynamic_steps=...).)
        chains, have = jax.lax.cond(
            jnp.any(limit > have), _draft, lambda ops: ops, (chains, have)
        )
    if sampled:
        # live-gated key advance: a dead slot's stream is dead, and a
        # chunk-prefilling slot (serving's prefill_chunk wrapper masks it
        # out of `live`) must reach its first decode round with the exact
        # key admission bound — the same key the dense admission path
        # leaves it with. Live slots' uniforms are unchanged (per-slot
        # threefry streams are independent).
        new_key, u = verify_lib.round_uniforms(state["key"], draft_k + 1)
        state["key"] = jnp.where(live[:, None], new_key, state["key"])
        new_cache, n_chain, new_pending = verify_accept_commit_sampled(
            cfg, params, cache, pending, chains, have, live,
            state["temp"], state["topk"], state["topp"], u,
        )
    else:
        new_cache, nxt, n_chain, new_pending = verify_accept_commit(
            cfg, params, cache, pending, chains, have, live
        )
    n_acc = jnp.where(live, n_chain + 1, 0).astype(jnp.int32)
    acc_tok = jnp.concatenate([pending[:, None], chains], axis=1)
    state["ctx"] = _commit_ctx(ctx, n, acc_tok, n_acc)
    state["pending"] = jnp.where(live, new_pending, pending).astype(jnp.int32)
    # Eq. 4 EMA over the NEURAL drafter: first neural position's outcome,
    # only when the PLD prefix was fully accepted (parent-accepted rule)
    obs = live & (have > pld_have) & (n_chain >= pld_have)
    outcome = (n_chain > pld_have).astype(jnp.float32)
    (state["alpha"], state["hist"], state["hist_n"],
     state["hist_ptr"]) = ema_update(
        state["alpha"], state["hist"], state["hist_n"], state["hist_ptr"],
        outcome, obs,
    )
    # per-slot round facts: the server's pipelined drain sums "drafted"
    # when it resolves the future, and the device telemetry accumulator
    # (serving/telemetry.py) folds the rest without any extra dispatch
    out = {
        "acc": acc_tok, "n_acc": n_acc,
        "drafted": jnp.maximum(have - pld_have, 0),
        "pld_have": pld_have, "budget": limit,
    }
    return new_cache, _pin_batch(state, dax), _pin_batch(out, dax)


def tree_round(
    cfg: ModelConfig,
    params: dict,
    cache: dict,                      # donated: the commit aliases in place
    state: dict,                      # donated carried device state (see server)
    c: jax.Array,                     # () f32 draft cost coefficient
    gates: Optional[jax.Array],       # (num_layers,) DSIA layer gates or None
    *,
    draft_k: int,
    expansions: int,
    top_k: int,
    top_p: float,
    bucket: int,
    pld_alpha: float,
    use_draft: bool,
    adaptive: bool,
    min_obs: int,
    t_min: float,
    draft_kv: str = "recompute",
    attn_backend: Optional[str] = None,
    max_ngram: int = 4,
    min_ngram: int = 1,
    sampled: bool = False,
):
    """ONE fused, device-resident ``tree_fused`` (DyTC §4.2) serving round:
    PLD retrieval + tree seeding + the expansion scan + target verify + the
    vectorized accepted-path walk + cache/context commit + the Eq. 4 EMA
    update, all in a single jitted dispatch. Same carried ``state`` contract
    (and the same entry/exit ``_pin_batch`` placement pins on a mesh)
    as ``chain_round``; ``out["acc"]`` holds the accepted path tokens.
    ``sampled=True`` swaps the greedy accepted-path walk for the stochastic
    tree walk (``tree_verify_accept_commit_sampled``) driven by carried
    per-slot keys/warp params — see ``chain_round``; same dispatch story,
    temp <= 0 slots stay token-identical to greedy."""
    dax = data_axis()
    state = _pin_batch(dict(state), dax)
    live = state["live"]
    pending = state["pending"]
    n = cache["pos"]
    B = live.shape[0]
    ctx, chains, have = _round_prologue(
        cfg, cache, state, draft_k, max_ngram, min_ngram
    )
    pld_have = have
    tokens, parents, depth, p_acc, mask, count = tree_seed_device(
        pending, chains, have, bucket, pld_alpha
    )
    first_neural = jnp.full((B,), -1, jnp.int32)
    limits = jnp.zeros((B,), jnp.int32)
    if use_draft and expansions > 0:
        if adaptive:
            budget = best_tree_expansions_batched(
                state["alpha"], c, expansions, t_min
            )
            limits = jnp.where(state["hist_n"] >= min_obs, budget, expansions)
        else:
            limits = jnp.full((B,), expansions, jnp.int32)
        limits = jnp.where(live, limits, 0)

        def _grow(ops):
            tk, pr, dp, pa, mk, ct, fn = ops
            return tree_draft_scan(
                cfg, expansions, top_k, params, cache,
                tk, pr, dp, pa, mk, ct,
                limits, state["alpha"],
                jnp.maximum(c.astype(jnp.float32), 1e-3),
                jnp.asarray(t_min, jnp.float32), gates,
                top_p=top_p, draft_kv=draft_kv,
            )

        # runtime skip (see chain_round): PLD-only / routing-stopped rounds
        # pay no expansion compute inside the same executable
        tokens, parents, depth, p_acc, mask, count, first_neural = (
            jax.lax.cond(
                jnp.any(limits > 0), _grow, lambda ops: ops,
                (tokens, parents, depth, p_acc, mask, count, first_neural),
            )
        )
    if sampled:
        # live-gated key advance — see chain_round: frozen keys for dead
        # and chunk-prefilling slots, identical uniforms for live ones
        new_key, u = verify_lib.round_uniforms(state["key"], bucket)
        state["key"] = jnp.where(live[:, None], new_key, state["key"])
        new_cache, path, n_acc, bonus = tree_verify_accept_commit_sampled(
            cfg, params, cache, tokens, parents, depth, mask, count, live,
            state["temp"], state["topk"], state["topp"], u,
            attn_backend=attn_backend,
        )
    else:
        new_cache, path, n_acc, bonus = tree_verify_accept_commit(
            cfg, params, cache, tokens, parents, depth, mask, count, live,
            attn_backend=attn_backend,
        )
    acc_tok = jnp.take_along_axis(tokens, path, axis=1)          # (B, N)
    state["ctx"] = _commit_ctx(ctx, n, acc_tok, n_acc)
    state["pending"] = jnp.where(live, bonus, pending).astype(jnp.int32)
    # Eq. 4 EMA at the slot's first NEURAL node (parent-accepted rule; the
    # same bookkeeping the split round does on host after draining)
    N = tokens.shape[1]
    t_ids = jnp.arange(N)
    acc_mask = jnp.zeros((B, N), bool).at[
        jnp.arange(B)[:, None],
        jnp.where(t_ids[None, :] < n_acc[:, None], path, N),
    ].set(True, mode="drop")
    fn_c = jnp.clip(first_neural, 0, N - 1)
    fn_parent = jnp.take_along_axis(parents, fn_c[:, None], 1)[:, 0]
    parent_ok = jnp.take_along_axis(
        acc_mask, jnp.clip(fn_parent, 0, N - 1)[:, None], 1
    )[:, 0]
    obs = live & (first_neural >= 0) & (fn_parent >= 0) & parent_ok
    outcome = jnp.take_along_axis(acc_mask, fn_c[:, None], 1)[:, 0]
    (state["alpha"], state["hist"], state["hist_n"],
     state["hist_ptr"]) = ema_update(
        state["alpha"], state["hist"], state["hist_n"], state["hist_ptr"],
        outcome.astype(jnp.float32), obs,
    )
    # per-slot round facts (see chain_round): drained sums + telemetry
    # accumulation happen downstream, inside the same executable or on
    # already-resolved futures — never as an extra dispatch
    out = {
        "acc": acc_tok, "n_acc": n_acc,
        "drafted": jnp.clip(count - pld_have - 1, 0, None),
        "pld_have": pld_have, "budget": limits,
    }
    return new_cache, _pin_batch(state, dax), _pin_batch(out, dax)


class SpecEngine:
    """Single-sequence (B=1) speculative engine; the batched path lives in
    repro.serving.server."""

    def __init__(
        self,
        cfg: ModelConfig,
        params: dict,
        max_len: int = 2048,
        draft_exec: str = "auto",          # auto | slice | mask
        greedy: bool = True,
    ):
        self.cfg = cfg
        self.params = params
        self.max_len = max_len
        self.greedy = greedy
        segs = M.layout(cfg)
        homogeneous = len(segs) == 1 and len(segs[0].unit) == 1
        if draft_exec == "auto":
            draft_exec = "slice" if homogeneous else "mask"
        if draft_exec == "slice" and not homogeneous:
            raise ValueError("slice exec requires a homogeneous layer stack")
        self.draft_exec = draft_exec
        self.pld = PromptLookup()
        self.acceptance = AcceptanceTracker()
        self.costs = CostTracker()

        self._variants: Dict[str, Tuple[ModelConfig, dict, Optional[np.ndarray]]] = {
            "full": (cfg, params, None)
        }
        self._spec_by_name: Dict[str, DraftSpec] = {}
        self._decode_fns: Dict[Tuple[str, int], Callable] = {}
        self._commit_fns: Dict[int, Callable] = {}
        self._prefill_fn = jax.jit(
            functools.partial(M.prefill, cfg), static_argnames=()
        )
        # runtime state
        self.cache: Optional[dict] = None
        self.tokens: List[int] = []
        self.pending: Optional[int] = None
        self.stats = {"target_calls": 0, "draft_calls": 0, "rounds": 0,
                      "accepted_tokens": 0, "draft_time": 0.0, "verify_time": 0.0,
                      "modeled_draft_cost": 0.0}

    # ------------------------------------------------------------- variants
    def register_draft(self, spec: DraftSpec) -> None:
        if spec.kind == "retrieval" or spec.name in self._variants:
            self.acceptance.set_prior(spec.name, spec.prior_alpha)
            self.costs.set_prior(spec.name, spec.prior_c)
            return
        cfg, params = self.cfg, self.params
        gates = spec.gates_array(self.cfg.num_layers)
        if spec.quantize == "int8":
            params = fake_quant_int8(params)
        if self.draft_exec == "slice" and spec.gates is not None:
            kept = np.flatnonzero(gates > 0)
            cfg = dataclasses.replace(cfg, num_layers=len(kept))
            seg = params["segments"][0]
            params = dict(params)
            params["segments"] = [jax.tree.map(lambda a: a[kept], seg)]
            gates_arr = None
        else:
            gates_arr = gates
        self._variants[spec.name] = (cfg, params, gates_arr)
        self.acceptance.set_prior(spec.name, spec.prior_alpha)
        self.costs.set_prior(spec.name, spec.prior_c)
        self._spec_by_name[spec.name] = spec

    def _slice_cache(self, variant: str) -> dict:
        cfg_v, _, _ = self._variants[variant]
        if variant == "full" or self.draft_exec != "slice" or cfg_v.num_layers == self.cfg.num_layers:
            return self.cache
        spec = self._spec_by_name[variant]
        kept = np.flatnonzero(spec.gates_array(self.cfg.num_layers) > 0)
        seg = self.cache["segments"][0]
        return {
            "pos": self.cache["pos"],
            "segments": [jax.tree.map(lambda a: a[kept], seg)],
        }

    # --------------------------------------------------------------- jitting
    def _decode_fn(self, variant: str, bucket: int) -> Callable:
        key = (variant, bucket)
        if key in self._decode_fns:
            return self._decode_fns[key]
        cfg_v, params_v, gates = self._variants[variant]
        spec = getattr(self, "_spec_by_name", {}).get(variant)
        override = None
        if spec is not None and spec.attn_override is not None:
            kind, window, sink = spec.attn_override
            override = {"kind": kind, "window": window, "sink": sink}

        @jax.jit
        def fn(params, cache, tokens, tmask, qpos, gates_arr):
            return M.decode_step(
                cfg_v, params, cache, tokens,
                gates=gates_arr, tree_mask=tmask, q_pos=qpos,
                attn_override=override,
            )

        self._decode_fns[key] = (fn, params_v, gates)
        return self._decode_fns[key]

    def _commit_fn(self, bucket: int) -> Callable:
        if bucket not in self._commit_fns:
            self._commit_fns[bucket] = jax.jit(
                functools.partial(M.commit_cache, self.cfg)
            )
        return self._commit_fns[bucket]

    # ---------------------------------------------------------------- runtime
    def start(self, prompt: np.ndarray) -> None:
        prompt = np.asarray(prompt, np.int32)
        self.cache = M.init_cache(self.cfg, 1, self.max_len, dtype=jnp.dtype(self.cfg.dtype))
        t0 = time.perf_counter()
        last, self.cache = jax.block_until_ready(
            self._prefill_fn(self.params, {"tokens": jnp.asarray(prompt[None])}, self.cache)
        )
        self.costs.observe_target(time.perf_counter() - t0, tokens=max(len(prompt), 1))
        self.tokens = [int(t) for t in prompt]
        self.pending = int(np.argmax(np.asarray(last)[0]))

    @property
    def context(self) -> np.ndarray:
        return np.asarray(self.tokens + [self.pending], np.int32)

    def _run_nodes(
        self,
        variant: str,
        tokens: np.ndarray,     # (n,)
        rel_pos: np.ndarray,    # (n,)
        mask: np.ndarray,       # (n, n)
    ):
        n = len(tokens)
        T = bucket_for(n)
        toks = np.zeros(T, np.int32)
        toks[:n] = tokens
        rel = np.zeros(T, np.int32)
        rel[:n] = rel_pos
        rel[n:] = (rel_pos.max() if n else 0) + 1 + np.arange(T - n)
        m = np.eye(T, dtype=bool)
        m[:n, :n] = mask
        fn, params_v, gates = self._decode_fn(variant, T)
        cache = self._slice_cache(variant)
        qpos = jnp.asarray(self.cache["pos"] + jnp.asarray(rel))
        logits, staged = fn(
            params_v, cache, jnp.asarray(toks[None]), jnp.asarray(m), qpos,
            None if gates is None else jnp.asarray(gates),
        )
        return logits, staged, T

    # draft call: logits for a node set under a draft config (stage-only)
    def draft_logits(self, spec_name: str, tokens, rel_pos, mask) -> np.ndarray:
        t0 = time.perf_counter()
        logits, _, _ = self._run_nodes(spec_name, tokens, rel_pos, mask)
        logits = np.asarray(jax.block_until_ready(logits))[0]
        dt = time.perf_counter() - t0
        self.stats["draft_calls"] += 1
        self.stats["draft_time"] += dt
        # modeled TPU cost: one target-forward-equivalent x the DSIA cost
        # coefficient per draft call (a KV-cached draft computes ~1 new
        # token per call; chain recomputation is a CPU-engine artifact)
        spec = self._spec_by_name.get(spec_name)
        self.stats["modeled_draft_cost"] += spec.prior_c if spec else 0.5
        self.costs.observe(spec_name, dt, tokens=len(tokens))
        return logits[: len(tokens)]

    # verification: full model over the tree, then commit the accepted path
    def verify_and_commit(self, tree: DraftTree) -> List[int]:
        tokens, rel, mask, real = tree.flatten()
        n = len(tree)
        t0 = time.perf_counter()
        logits, staged, T = self._run_nodes("full", tokens[:n], rel[:n], mask[:n, :n])
        logits = np.asarray(jax.block_until_ready(logits))[0]   # (T, V)
        self.stats["verify_time"] += time.perf_counter() - t0
        self.stats["target_calls"] += 1
        self.costs.observe_target(time.perf_counter() - t0, tokens=1)
        next_argmax = np.argmax(logits[:n], axis=-1)
        path, bonus = verify_lib.greedy_accept_tree(tree, next_argmax)

        # commit: accepted nodes' staged KV/states, in path order
        T_pad = bucket_for(n)
        path_idx = np.zeros(T_pad, np.int32)
        path_idx[: len(path)] = path
        commit = self._commit_fn(T_pad)
        self.cache = commit(
            self.cache, staged, jnp.asarray(path_idx), jnp.asarray(len(path), jnp.int32)
        )
        accepted = [tree.tokens[i] for i in path]
        self.tokens.extend(accepted)
        self.pending = int(bonus)
        self.stats["rounds"] += 1
        self.stats["accepted_tokens"] += len(accepted)
        return accepted

    # ------------------------------------------------------------ baselines
    def ar_step(self) -> int:
        """Plain autoregressive: verify a root-only tree (1 token/step)."""
        tree = DraftTree(self.pending)
        self.verify_and_commit(tree)
        return self.tokens[-1]

    def generate_ar(self, n_tokens: int) -> List[int]:
        out = []
        while len(out) < n_tokens:
            self.ar_step()
            out.append(self.tokens[-1])
        return out[:n_tokens]
