"""Dynamically Switchable Inference Acceleration (DSIA) strategies (§4.1).

A DSIA strategy turns the target model into a cheaper *virtual* draft model
at runtime — no training, switchable per decoding step. Each strategy
produces a ``DraftSpec`` the engine can execute:

  - LayerSparsity   (SWIFT-style)      -> layer gate vector
  - EarlyExit       (Kangaroo-style)   -> prefix gate vector (+ optional adapter)
  - ActivationQuant (QSpec-style)      -> int8 weight/act simulation flag
  - StreamingAttention (TriForce/MagicDec-style) -> attention override

Hierarchy constructions (§4.1): Scaling-DSIA (same strategy, different
parameter), Mixing-DSIA (orthogonal strategies combined), Replacing-DSIA
(conflicting strategies as alternatives). See ``build_hierarchy``.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import numpy as np

from repro.config.base import ModelConfig


@dataclasses.dataclass(frozen=True)
class DraftSpec:
    name: str
    kind: str = "neural"                 # neural | retrieval
    gates: Optional[Tuple[int, ...]] = None   # per-layer 0/1 (None = all on)
    quantize: Optional[str] = None       # "int8" | None
    attn_override: Optional[Tuple[str, int, int]] = None  # (kind, window, sink)
    prior_alpha: float = 0.5             # cold-start acceptance prior (App. D)
    prior_c: float = 0.5                 # cold-start cost-coefficient prior

    @property
    def n_active_layers(self) -> Optional[int]:
        return None if self.gates is None else int(sum(self.gates))

    def gates_array(self, num_layers: int) -> np.ndarray:
        if self.gates is None:
            return np.ones((num_layers,), np.float32)
        assert len(self.gates) == num_layers
        return np.asarray(self.gates, np.float32)

    def prior_alpha_given(self, stronger: "DraftSpec") -> float:
        """App. D cold-start prior for LEVEL-TO-LEVEL acceptance: how often
        ``stronger`` (the next level up a cascade) agrees with this draft's
        tokens. Both priors are calibrated against the target, so the
        conditional prior is their ratio — a weaker judge accepts the same
        draft at least as often as the target does — clipped to [prior, 1)."""
        if stronger.prior_alpha <= 0:
            return self.prior_alpha
        return float(np.clip(self.prior_alpha / stronger.prior_alpha,
                             self.prior_alpha, 0.98))

    def unsupported_by_gates_only(self) -> Tuple[str, ...]:
        """Spec fields a gates-only execution path silently could not honor
        (the serving modes that draft with one shared executable + a gate
        vector). ``cascade_fused`` is the mode that honors them."""
        bad = []
        if self.quantize is not None:
            bad.append(f"quantize={self.quantize!r}")
        if self.attn_override is not None:
            bad.append(f"attn_override={self.attn_override!r}")
        return tuple(bad)


def layer_sparsity(cfg: ModelConfig, sparsity: float, name: Optional[str] = None) -> DraftSpec:
    """Skip ``sparsity`` fraction of layers, evenly interleaved, keeping the
    first and last layers (SWIFT keeps boundary layers — they carry the
    embedding lift-off and the pre-head consolidation)."""
    L = cfg.num_layers
    n_skip = int(round(L * sparsity))
    n_skip = min(n_skip, max(L - 2, 0))
    gates = np.ones(L, np.int32)
    if n_skip > 0 and L > 2:
        # evenly spaced skip indices in [1, L-2]
        cand = np.linspace(1, L - 2, n_skip)
        idx = np.unique(np.round(cand).astype(int))
        i = 1
        while len(idx) < n_skip and i < L - 1:   # fill collisions
            if i not in idx:
                idx = np.sort(np.append(idx, i))
            i += 1
        gates[idx[:n_skip]] = 0
    frac = 1.0 - gates.mean()
    return DraftSpec(
        name=name or f"LS{sparsity:.1f}",
        gates=tuple(int(g) for g in gates),
        prior_alpha=max(0.05, 0.95 - 1.1 * frac),   # aggressiveness heuristic
        prior_c=max(0.05, 1.0 - frac),
    )


def early_exit(cfg: ModelConfig, fraction: float, name: Optional[str] = None) -> DraftSpec:
    """Exit after the first ``fraction`` of layers (Kangaroo's shallow net)."""
    L = cfg.num_layers
    e = max(1, int(round(L * fraction)))
    gates = np.zeros(L, np.int32)
    gates[:e] = 1
    return DraftSpec(
        name=name or f"EE{fraction:.2f}",
        gates=tuple(int(g) for g in gates),
        prior_alpha=max(0.05, 0.9 * fraction),
        prior_c=max(0.05, fraction),
    )


def activation_quant(cfg: ModelConfig, bits: int = 8, base: Optional[DraftSpec] = None) -> DraftSpec:
    """QSpec-style quantized drafting. On TPU this runs the int8 Pallas
    matmul path; on CPU the engine simulates with fake-quantized weights
    (same numerics contract), and the cost prior models the HW speedup."""
    name = f"{base.name}+Q{bits}" if base else f"Q{bits}"
    return DraftSpec(
        name=name,
        gates=base.gates if base else None,
        quantize=f"int{bits}",
        prior_alpha=(base.prior_alpha if base else 0.9) * 0.95,
        prior_c=(base.prior_c if base else 1.0) * 0.55,   # ~2x matmul throughput
    )


def streaming_attention(
    cfg: ModelConfig, window: int = 512, sink: int = 4, base: Optional[DraftSpec] = None
) -> DraftSpec:
    """StreamingLLM-style efficient attention for drafting (long-context)."""
    name = f"{base.name}+SA{window}" if base else f"SA{window}"
    return DraftSpec(
        name=name,
        gates=base.gates if base else None,
        attn_override=("streaming", window, sink),
        prior_alpha=(base.prior_alpha if base else 0.9) * 0.95,
        prior_c=(base.prior_c if base else 1.0) * 0.7,
    )


PLD_SPEC = DraftSpec(name="PLD", kind="retrieval", prior_alpha=0.3, prior_c=0.01)


def build_hierarchy(
    cfg: ModelConfig,
    mode: str = "scaling",
    sparsities: Tuple[float, ...] = (0.4, 0.6),
) -> List[DraftSpec]:
    """Draft-model hierarchy per §4.1 (decreasing cost, decreasing alpha),
    bottomed by PLD. Matches the paper's main config for mode='scaling'."""
    if mode == "scaling":
        drafts = [layer_sparsity(cfg, s) for s in sparsities]
    elif mode == "mixing":
        ls = layer_sparsity(cfg, sparsities[0])
        drafts = [ls, activation_quant(cfg, 8, base=layer_sparsity(cfg, sparsities[-1]))]
    elif mode == "replacing":
        # conflicting strategies as alternatives, cost-ordered: streaming
        # attention (c~0.7) above the cheaper int8 quant level (c~0.55)
        drafts = [streaming_attention(cfg), activation_quant(cfg, 8)]
    elif mode == "early_exit":
        drafts = [early_exit(cfg, 0.5), early_exit(cfg, 0.25)]
    else:
        raise ValueError(f"unknown hierarchy mode {mode!r}")
    return drafts + [PLD_SPEC]
