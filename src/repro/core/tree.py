"""Draft token tree for DyTC (host-side structure + device mask export).

Node 0 is the root: the *pending bonus token* from the previous verification
(Alg. 1 line 1 — "N_root representing the last bonus token x_0"). Its KV is
not yet committed; every verification pass therefore processes the full tree
including the root, and the root is accepted unconditionally (it is the
target model's own token).

TPU adaptation: trees are padded to fixed bucket sizes before lowering, and
the visibility mask is a dense (T, T) ancestor-closure matrix — MXU-friendly
(see DESIGN.md §3).
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

TREE_BUCKETS = (8, 16, 32, 64, 128)


def bucket_for(n: int) -> int:
    for b in TREE_BUCKETS:
        if n <= b:
            return b
    raise ValueError(f"tree too large: {n} > {TREE_BUCKETS[-1]}")


class DraftTree:
    def __init__(self, root_token: int):
        self.tokens: List[int] = [int(root_token)]
        self.parents: List[int] = [-1]
        self.depth: List[int] = [0]
        self.config: List[str] = ["root"]
        self.p_acc: List[float] = [1.0]
        self.active: List[bool] = [True]
        self.children: Dict[int, List[int]] = {0: []}

    # ------------------------------------------------------------- structure
    def __len__(self) -> int:
        return len(self.tokens)

    def add_child(
        self, parent: int, token: int, config: str, alpha: float
    ) -> int:
        idx = len(self.tokens)
        self.tokens.append(int(token))
        self.parents.append(parent)
        self.depth.append(self.depth[parent] + 1)
        self.config.append(config)
        self.p_acc.append(self.p_acc[parent] * float(alpha))
        self.active.append(True)
        self.children[idx] = []
        self.children[parent].append(idx)
        return idx

    def deactivate(self, node: int) -> None:
        self.active[node] = False

    def best_active_leaf(self) -> Optional[int]:
        """argmax P_acc over active nodes (Alg. 1 line 5)."""
        best, best_p = None, -1.0
        for i in range(len(self.tokens)):
            if self.active[i] and self.p_acc[i] > best_p:
                best, best_p = i, self.p_acc[i]
        return best

    def path_to(self, node: int) -> List[int]:
        path = []
        while node != -1:
            path.append(node)
            node = self.parents[node]
        return path[::-1]

    def path_tokens(self, node: int) -> List[int]:
        return [self.tokens[i] for i in self.path_to(node)]

    def siblings(self, node: int) -> List[int]:
        p = self.parents[node]
        if p == -1:
            return []
        return [c for c in self.children[p] if c != node]

    # -------------------------------------------------------------- flatten
    def flatten(
        self, bucket: Optional[int] = None
    ) -> Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
        """Returns (tokens (T,), rel_pos (T,), mask (T,T), real (T,)).

        rel_pos[i] = depth[i] (absolute position = cache_pos + depth).
        mask[i, j] = True iff j is an ancestor-or-self of i.
        Padded nodes have real=False, self-only visibility, rel_pos = depth 0.
        """
        n = len(self.tokens)
        T = bucket or bucket_for(n)
        tokens = np.zeros(T, np.int32)
        rel = np.zeros(T, np.int32)
        mask = np.eye(T, dtype=bool)
        real = np.zeros(T, bool)
        tokens[:n] = self.tokens
        rel[:n] = self.depth
        real[:n] = True
        for i in range(n):
            j = i
            while j != -1:
                mask[i, j] = True
                j = self.parents[j]
        # padded slots: positions far away so they never interfere via rope;
        # they only see themselves and nothing attends to them.
        rel[n:] = np.arange(T - n) + max(self.depth) + 1 if n else 0
        return tokens, rel, mask, real


def chain_tree(root_token: int, chain: Sequence[int], config: str, alpha: float) -> DraftTree:
    """Convenience: a pure-chain tree (vanilla SD / cascades)."""
    t = DraftTree(root_token)
    node = 0
    for tok in chain:
        node = t.add_child(node, tok, config, alpha)
    return t


def tree_seed_arrays(
    pending: np.ndarray,          # (B,) int
    chains: np.ndarray,           # (B, K) int — PLD-prefilled chain per slot
    have: np.ndarray,             # (B,) int — chain tokens actually proposed
    bucket: int,
    pld_alpha: float = 0.3,
) -> Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """Batched device-tree seed: per-slot chain trees padded to ``bucket``.

    Node 0 is the pending bonus token; nodes 1..have[b] are the slot's PLD
    chain (parent = previous node). This is the array form the fused
    ``tree_draft_scan`` expands on device — same node layout and mask
    convention as ``DraftTree.flatten``: unused slots see only themselves
    and no real node sees them.

    Returns (tokens (B,N) i32, parents (B,N) i32 with -1 at roots/unused,
    depth (B,N) i32, p_acc (B,N) f32, mask (B,N,N) bool, count (B,) i32).
    """
    pending = np.asarray(pending)
    chains = np.asarray(chains)
    have = np.asarray(have)
    B, K = chains.shape
    N = bucket
    if N < K + 1:
        raise ValueError(f"bucket {N} cannot hold a {K}-token chain + root")
    j = np.arange(N)
    seeded = (j[None, :] >= 1) & (j[None, :] <= have[:, None])   # (B, N)
    tokens = np.zeros((B, N), np.int32)
    tokens[:, 0] = pending
    tokens[:, 1 : K + 1] = np.where(seeded[:, 1 : K + 1], chains, 0)
    parents = np.where(seeded, j[None, :] - 1, -1).astype(np.int32)
    depth = np.where(seeded, j[None, :], 0).astype(np.int32)
    p_acc = np.where(seeded, pld_alpha ** depth.astype(np.float64), 0.0)
    p_acc[:, 0] = 1.0
    p_acc = p_acc.astype(np.float32)
    # chain ancestor closure: node i sees j <= i; unused slots are self-only
    mask = np.broadcast_to(np.eye(N, dtype=bool), (B, N, N)).copy()
    mask |= (j[None, None, :] < j[None, :, None]) & seeded[:, :, None]
    count = (have + 1).astype(np.int32)
    return tokens, parents, depth, p_acc, mask, count


def tree_seed_device(
    pending,                      # (B,) int32 device
    chains,                       # (B, K) int32 device — PLD chain per slot
    have,                         # (B,) int32 device
    bucket: int,
    pld_alpha: float = 0.3,
):
    """jnp twin of ``tree_seed_arrays`` — same node layout, mask convention
    and P_acc seeding, but traced on device so the single-dispatch serving
    round (``core.engine.tree_round``) seeds its trees inside the round
    executable instead of a host numpy step. Shapes are static (``bucket``),
    values all come from carried device state."""
    import jax.numpy as jnp

    B, K = chains.shape
    N = bucket
    if N < K + 1:
        raise ValueError(f"bucket {N} cannot hold a {K}-token chain + root")
    j = jnp.arange(N)
    seeded = (j[None, :] >= 1) & (j[None, :] <= have[:, None])    # (B, N)
    tokens = jnp.zeros((B, N), jnp.int32).at[:, 0].set(pending.astype(jnp.int32))
    tokens = tokens.at[:, 1 : K + 1].set(
        jnp.where(seeded[:, 1 : K + 1], chains.astype(jnp.int32), 0)
    )
    parents = jnp.where(seeded, j[None, :] - 1, -1).astype(jnp.int32)
    depth = jnp.where(seeded, j[None, :], 0).astype(jnp.int32)
    p_acc = jnp.where(
        seeded, jnp.float32(pld_alpha) ** depth.astype(jnp.float32), 0.0
    ).at[:, 0].set(1.0).astype(jnp.float32)
    mask = jnp.broadcast_to(jnp.eye(N, dtype=bool), (B, N, N))
    mask = mask | ((j[None, None, :] < j[None, :, None]) & seeded[:, :, None])
    count = (have + 1).astype(jnp.int32)
    return tokens, parents, depth, p_acc, mask, count
