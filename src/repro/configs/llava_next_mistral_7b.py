"""LLaVA-NeXT (Mistral-7B backbone) — VLM; vision frontend stubbed per spec.

[hf:llava-hf/llava-v1.6-mistral-7b-hf] — anyres tiling produces up to ~2880
patch-embedding tokens (5 tiles x 576); ``input_specs`` supplies precomputed
patch embeddings of the right shape, the backbone interleaves them with text.
"""
from repro.config.base import ModelConfig, register_config


@register_config("llava-next-mistral-7b")
def llava_next_mistral_7b() -> ModelConfig:
    return ModelConfig(
        name="llava-next-mistral-7b",
        family="vlm",
        source="[hf:llava-hf/llava-v1.6-mistral-7b-hf] LLaVA-NeXT, Mistral-7B backbone",
        num_layers=32,
        d_model=4096,
        num_heads=32,
        num_kv_heads=8,            # GQA kv=8
        d_ff=14336,
        vocab_size=32000,
        attention_pattern="full",
        rope_theta=1_000_000.0,
        num_image_tokens=2880,     # anyres: 4 tiles + base image, 576 tokens each
    )
