"""Mamba2-130m — pure SSM with state-space duality (SSD). [arXiv:2405.21060]"""
from repro.config.base import ModelConfig, SSMConfig, register_config


@register_config("mamba2-130m")
def mamba2_130m() -> ModelConfig:
    return ModelConfig(
        name="mamba2-130m",
        family="ssm",
        source="[arXiv:2405.21060] Transformers are SSMs (Mamba-2)",
        num_layers=24,
        d_model=768,
        num_heads=0,               # attention-free
        num_kv_heads=0,
        d_ff=0,                    # Mamba2 block has no separate MLP
        vocab_size=50280,
        attention_pattern="none",
        ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64, chunk_size=256),
        tie_embeddings=True,
    )
