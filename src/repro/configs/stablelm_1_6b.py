"""StableLM-2-1.6B — dense decoder. [hf:stabilityai/stablelm-2-1_6b]"""
from repro.config.base import ModelConfig, register_config


@register_config("stablelm-1.6b")
def stablelm_1_6b() -> ModelConfig:
    return ModelConfig(
        name="stablelm-1.6b",
        family="dense",
        source="[hf:stabilityai/stablelm-2-1_6b]",
        num_layers=24,
        d_model=2048,
        num_heads=32,
        num_kv_heads=32,           # MHA (kv=32)
        d_ff=5632,
        vocab_size=100352,
        attention_pattern="full",
        rope_theta=10_000.0,
    )
