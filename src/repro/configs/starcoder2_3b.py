"""StarCoder2-3B — dense code model, GQA + RoPE. [arXiv:2402.19173]"""
from repro.config.base import ModelConfig, register_config


@register_config("starcoder2-3b")
def starcoder2_3b() -> ModelConfig:
    return ModelConfig(
        name="starcoder2-3b",
        family="dense",
        source="[arXiv:2402.19173] StarCoder 2 and The Stack v2",
        num_layers=30,
        d_model=3072,
        num_heads=24,
        num_kv_heads=2,            # GQA kv=2
        d_ff=12288,
        vocab_size=49152,
        attention_pattern="full",
        rope_theta=100_000.0,
        act="gelu",
        mlp_gated=False,
    )
