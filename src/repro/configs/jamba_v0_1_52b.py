"""Jamba-v0.1 (52B) — hybrid Mamba+attention with MoE. [arXiv:2403.19887]

Attn:Mamba 1:7 interleave (1 attention layer per 8-layer block), MoE every
other layer with 16 experts top-2.
"""
from repro.config.base import ModelConfig, MoEConfig, SSMConfig, register_config


@register_config("jamba-v0.1-52b")
def jamba_v0_1_52b() -> ModelConfig:
    return ModelConfig(
        name="jamba-v0.1-52b",
        family="hybrid",
        source="[arXiv:2403.19887] Jamba: A Hybrid Transformer-Mamba Language Model",
        num_layers=32,
        d_model=4096,
        num_heads=32,
        num_kv_heads=8,            # GQA kv=8
        d_ff=14336,
        vocab_size=65536,
        attention_pattern="full",
        rope_theta=10_000.0,
        attn_layer_period=8,       # 1:7 attn:mamba
        attn_layer_offset=4,       # attention sits mid-block, per the paper
        moe=MoEConfig(
            num_experts=16,
            top_k=2,
            d_ff_expert=14336,
            moe_layer_period=2,    # every other layer is MoE
            moe_layer_offset=1,
        ),
        ssm=SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=64, chunk_size=256),
    )
