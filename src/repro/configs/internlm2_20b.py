"""InternLM2-20B — dense decoder with GQA. [arXiv:2403.17297]"""
from repro.config.base import ModelConfig, register_config


@register_config("internlm2-20b")
def internlm2_20b() -> ModelConfig:
    return ModelConfig(
        name="internlm2-20b",
        family="dense",
        source="[arXiv:2403.17297] InternLM2 Technical Report",
        num_layers=48,
        d_model=6144,
        num_heads=48,
        num_kv_heads=8,            # GQA kv=8
        d_ff=16384,
        vocab_size=92544,
        attention_pattern="full",
        rope_theta=1_000_000.0,
    )
