"""MusicGen-medium — decoder-only LM over EnCodec tokens. [arXiv:2306.05284]

4 codebooks with the delay interleave pattern; the EnCodec conv codec is a
stub per spec — ``input_specs`` supplies the (B, S, 4) code indices, the
backbone sums 4 codebook embeddings per step and predicts 4 heads.
"""
from repro.config.base import ModelConfig, register_config


@register_config("musicgen-medium")
def musicgen_medium() -> ModelConfig:
    return ModelConfig(
        name="musicgen-medium",
        family="audio",
        source="[arXiv:2306.05284] Simple and Controllable Music Generation (MusicGen)",
        num_layers=48,
        d_model=1536,
        num_heads=24,
        num_kv_heads=24,           # MHA (kv=24)
        d_ff=6144,
        vocab_size=2048,           # EnCodec codebook size
        attention_pattern="full",
        num_codebooks=4,
        act="gelu",
        mlp_gated=False,
    )
