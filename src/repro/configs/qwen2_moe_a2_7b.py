"""Qwen1.5-MoE-A2.7B — fine-grained MoE with shared experts.

[hf:Qwen/Qwen1.5-MoE-A2.7B] 60 routed experts top-4 + 4 shared experts,
per-expert FFN dim 1408 (shared block = 4x1408 = 5632).
"""
from repro.config.base import ModelConfig, MoEConfig, register_config


@register_config("qwen2-moe-a2.7b")
def qwen2_moe_a2_7b() -> ModelConfig:
    return ModelConfig(
        name="qwen2-moe-a2.7b",
        family="moe",
        source="[hf:Qwen/Qwen1.5-MoE-A2.7B]",
        num_layers=24,
        d_model=2048,
        num_heads=16,
        num_kv_heads=16,
        d_ff=1408,                 # per-expert dim (config d_ff)
        vocab_size=151936,
        attention_pattern="full",
        rope_theta=1_000_000.0,
        moe=MoEConfig(
            num_experts=60,
            top_k=4,
            d_ff_expert=1408,
            num_shared_experts=4,
            d_ff_shared=5632,      # 4 shared experts fused: 4 * 1408
        ),
    )
