"""Gemma-3-1B — 5:1 local:global attention, 128k context, huge vocab.

[hf:google/gemma-3-1b-pt] — local layers use a 1024-token sliding window,
every 6th layer is global full attention.
"""
from repro.config.base import ModelConfig, register_config


@register_config("gemma3-1b")
def gemma3_1b() -> ModelConfig:
    return ModelConfig(
        name="gemma3-1b",
        family="dense",
        source="[hf:google/gemma-3-1b-pt]",
        num_layers=26,
        d_model=1152,
        num_heads=4,
        num_kv_heads=1,            # MQA (kv=1)
        d_ff=6912,
        vocab_size=262144,
        attention_pattern="local_global:5",   # 5 sliding : 1 full
        sliding_window=1024,
        rope_theta=1_000_000.0,
        max_position=131_072,
        act="gelu",
        tie_embeddings=True,
    )
