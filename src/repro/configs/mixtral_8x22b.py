"""Mixtral-8x22B — sparse MoE with sliding-window attention. [arXiv:2401.04088]"""
from repro.config.base import ModelConfig, MoEConfig, register_config


@register_config("mixtral-8x22b")
def mixtral_8x22b() -> ModelConfig:
    return ModelConfig(
        name="mixtral-8x22b",
        family="moe",
        source="[arXiv:2401.04088] Mixtral of Experts",
        num_layers=56,
        d_model=6144,
        num_heads=48,
        num_kv_heads=8,            # GQA kv=8
        d_ff=16384,
        vocab_size=32768,
        attention_pattern="sliding",
        sliding_window=4096,       # SWA per the Mixtral report
        rope_theta=1_000_000.0,
        moe=MoEConfig(
            num_experts=8,
            top_k=2,
            d_ff_expert=16384,
        ),
    )
