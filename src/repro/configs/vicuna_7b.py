"""Vicuna-7B-v1.3 (Llama-7B class) — the paper's own evaluation target. [36]"""
from repro.config.base import ModelConfig, register_config


@register_config("vicuna-7b")
def vicuna_7b() -> ModelConfig:
    return ModelConfig(
        name="vicuna-7b",
        family="dense",
        source="[lmsys Vicuna-7B-v1.3 / arXiv:2302.13971 Llama] paper's eval target",
        num_layers=32,
        d_model=4096,
        num_heads=32,
        num_kv_heads=32,           # Llama-1 class: MHA
        d_ff=11008,
        vocab_size=32000,
        attention_pattern="full",
        rope_theta=10_000.0,
    )
