"""Architecture registry — importing this package registers every config.

Assigned pool (10 archs) + the paper's own Vicuna/Llama-7B-class target.
"""
from repro.configs import (  # noqa: F401
    gemma3_1b,
    internlm2_20b,
    jamba_v0_1_52b,
    llava_next_mistral_7b,
    mamba2_130m,
    mixtral_8x22b,
    musicgen_medium,
    qwen2_moe_a2_7b,
    stablelm_1_6b,
    starcoder2_3b,
    vicuna_7b,
)

ASSIGNED_ARCHS = [
    "mixtral-8x22b",
    "llava-next-mistral-7b",
    "stablelm-1.6b",
    "qwen2-moe-a2.7b",
    "jamba-v0.1-52b",
    "starcoder2-3b",
    "gemma3-1b",
    "mamba2-130m",
    "musicgen-medium",
    "internlm2-20b",
]

PAPER_ARCHS = ["vicuna-7b"]
