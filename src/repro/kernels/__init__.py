"""Pallas TPU kernels for the perf-critical layers of CAS-Spec.

  flash_decode   — chunked KV-cache attention partials (verify / AR decode)
  tree_attention — dense tree-masked staged-token attention partials
  int8_matmul    — W8A8 quantized matmul (ActivationQuant DSIA)
  ops            — jit wrappers + flash-decoding combine
  ref            — pure-jnp oracles

Kernels target TPU (pl.pallas_call + BlockSpec VMEM tiling) and are
validated on CPU with interpret=True against ref.py.
"""
from repro.kernels.ops import quantized_matmul, verify_attention

__all__ = ["quantized_matmul", "verify_attention"]
