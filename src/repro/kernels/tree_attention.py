"""Pallas TPU kernel: dense tree-masked attention over staged draft tokens.

The intra-tree half of verification attention: T staged tokens attend over
each other under the ancestor-closure mask (dense (T, T) — MXU-friendly; see
DESIGN.md §3). The whole padded tree bucket lives in VMEM; one grid step per
(batch, kv-head). Returns partials (acc, m, l) merged with the flash-decode
cache partials in ops.py.

Layouts (rep = H // KV, R = rep * T rows, row = r * T + t):
  q:     (B, KV, R, hd)
  k/v:   (B, KV, T, hd)      staged draft keys/values
  mask:  (B, T, T) bool      ancestor-or-self & positional validity
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, mask_ref, acc_ref, m_ref, l_ref, *, scale, rep):
    q = q_ref[0, 0].astype(jnp.float32) * scale       # (R, hd)
    k = k_ref[0, 0].astype(jnp.float32)               # (T, hd)
    v = v_ref[0, 0].astype(jnp.float32)
    mask = mask_ref[0]                                # (T, T)
    R = q.shape[0]
    T = k.shape[0]

    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )                                                 # (R, T)
    # row r*T + t corresponds to tree node t — tile the mask over rep
    row_node = jax.lax.broadcasted_iota(jnp.int32, (R, T), 0) % T
    col_node = jax.lax.broadcasted_iota(jnp.int32, (R, T), 1)
    vis = mask[row_node, col_node]
    s = jnp.where(vis, s, NEG_INF)

    m = jnp.max(s, axis=-1)                           # (R,)
    p = jnp.exp(s - m[:, None])
    l = jnp.sum(p, axis=-1)
    o = jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    acc_ref[0, 0] = o
    m_ref[0, 0] = m
    l_ref[0, 0] = l


def tree_attention_partial(
    q: jax.Array,        # (B, KV, R, hd)
    k_new: jax.Array,    # (B, KV, T, hd)
    v_new: jax.Array,
    mask: jax.Array,     # (B, T, T) bool
    *,
    interpret: bool = True,
    scale: float | None = None,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    B, KV, R, hd = q.shape
    T = k_new.shape[2]
    rep = R // T
    kernel = functools.partial(
        _kernel, scale=hd ** -0.5 if scale is None else scale, rep=rep
    )
    return pl.pallas_call(
        kernel,
        grid=(B, KV),
        in_specs=[
            pl.BlockSpec((1, 1, R, hd), lambda b, g: (b, g, 0, 0)),
            pl.BlockSpec((1, 1, T, hd), lambda b, g: (b, g, 0, 0)),
            pl.BlockSpec((1, 1, T, hd), lambda b, g: (b, g, 0, 0)),
            pl.BlockSpec((1, T, T), lambda b, g: (b, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, R, hd), lambda b, g: (b, g, 0, 0)),
            pl.BlockSpec((1, 1, R), lambda b, g: (b, g, 0)),
            pl.BlockSpec((1, 1, R), lambda b, g: (b, g, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, KV, R, hd), jnp.float32),
            jax.ShapeDtypeStruct((B, KV, R), jnp.float32),
            jax.ShapeDtypeStruct((B, KV, R), jnp.float32),
        ],
        interpret=interpret,
    )(q, k_new, v_new, mask)
