"""Pallas TPU kernel: W8A8 dynamic-quantized matmul (ActivationQuant DSIA).

QSpec-style quantized drafting: activations are per-row symmetric int8,
weights per-column int8; the MXU runs the int8 x int8 -> int32 dot and the
epilogue rescales. Tiled (bm, bn, bk) with an f32 VMEM accumulator carried
over the K grid dimension.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, w_ref, xs_ref, ws_ref, o_ref, acc_scr, *, nk: int):
    kk = pl.program_id(2)

    @pl.when(kk == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    x = x_ref[...]                                     # (bm, bk) int8
    w = w_ref[...]                                     # (bk, bn) int8
    acc_scr[...] += jax.lax.dot_general(
        x, w, (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32
    ).astype(jnp.float32)

    @pl.when(kk == nk - 1)
    def _fini():
        xs = xs_ref[...]                               # (bm, 1) f32
        ws = ws_ref[...]                               # (1, bn) f32
        o_ref[...] = acc_scr[...] * xs * ws


def quantize_rows(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Per-row symmetric int8: returns (x_int8 (M,K), scale (M,1) f32)."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1, keepdims=True)
    scale = jnp.maximum(amax, 1e-8) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127).astype(jnp.int8)
    return q, scale


def quantize_cols(w: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Per-column symmetric int8: returns (w_int8 (K,N), scale (1,N) f32)."""
    amax = jnp.max(jnp.abs(w.astype(jnp.float32)), axis=0, keepdims=True)
    scale = jnp.maximum(amax, 1e-8) / 127.0
    q = jnp.clip(jnp.round(w.astype(jnp.float32) / scale), -127, 127).astype(jnp.int8)
    return q, scale


def int8_matmul(
    x_q: jax.Array,      # (M, K) int8
    w_q: jax.Array,      # (K, N) int8
    x_scale: jax.Array,  # (M, 1) f32
    w_scale: jax.Array,  # (1, N) f32
    *,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 128,
    interpret: bool = True,
) -> jax.Array:
    M, K = x_q.shape
    N = w_q.shape[1]
    bm, bn, bk = min(block_m, M), min(block_n, N), min(block_k, K)
    assert M % bm == 0 and N % bn == 0 and K % bk == 0, "pad in ops.py"
    nk = K // bk
    kernel = functools.partial(_kernel, nk=nk)
    return pl.pallas_call(
        kernel,
        grid=(M // bm, N // bn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bm, 1), lambda i, j, kk: (i, 0)),
            pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x_q, w_q, x_scale, w_scale)
