"""Pure-jnp oracles for every kernel (the correctness contract)."""
from __future__ import annotations


import jax
import jax.numpy as jnp

NEG_INF = -1e30


def ref_verify_attention(
    q: jax.Array,        # (B, KV, R, hd)  R = rep * T
    k_cache: jax.Array,  # (B, KV, S, hd)
    v_cache: jax.Array,
    kv_pos: jax.Array,   # (B, S)
    q_pos: jax.Array,    # (B, R)
    k_new: jax.Array,    # (B, KV, T, hd)
    v_new: jax.Array,
    tree_mask: jax.Array,    # (B, T, T)
    *,
    kind: str = "causal",
    window: int = 0,
    sink: int = 0,
) -> jax.Array:
    """Full softmax over [cache ++ staged]; returns (B, KV, R, hd) f32."""
    B, KV, R, hd = q.shape
    T = k_new.shape[2]
    scale = hd ** -0.5
    qf = q.astype(jnp.float32) * scale

    s_c = jnp.einsum("bgrh,bgsh->bgrs", qf, k_cache.astype(jnp.float32))
    qp = q_pos[:, None, :, None]
    kp = kv_pos[:, None, None, :]
    valid = (kp >= 0) & (kp <= qp)
    if kind == "window":
        valid &= kp > qp - window
    elif kind == "streaming":
        valid &= (kp < sink) | (kp > qp - window)
    s_c = jnp.where(valid, s_c, NEG_INF)

    s_d = jnp.einsum("bgrh,bgth->bgrt", qf, k_new.astype(jnp.float32))
    row_node = jnp.arange(R) % T
    vis = tree_mask[:, row_node, :]                   # (B, R, T)
    s_d = jnp.where(vis[:, None], s_d, NEG_INF)

    s = jnp.concatenate([s_c, s_d], axis=-1)
    p = jax.nn.softmax(s, axis=-1)
    vcat = jnp.concatenate([v_cache, v_new], axis=2).astype(jnp.float32)
    return jnp.einsum("bgrs,bgsh->bgrh", p, vcat)


def ref_paged_gather(
    pages: jax.Array,       # (NP, KV, P, hd) shared pool
    page_table: jax.Array,  # (B, n_pp) int32, -1 = unallocated
) -> jax.Array:
    """Materialize the dense per-slot view of a block-paged pool.

    Unallocated entries (-1) are clamped to page 0 — the garbage they pull
    in must be masked by the caller's ``kv_pos = -1`` rows, mirroring the
    kernel's index_map clamp exactly. Returns (B, KV, n_pp * P, hd)."""
    NP, KV, P, hd = pages.shape
    B, n_pp = page_table.shape
    safe = jnp.clip(page_table, 0, NP - 1)
    gathered = jnp.take(pages, safe, axis=0)              # (B, n_pp, KV, P, hd)
    return gathered.transpose(0, 2, 1, 3, 4).reshape(B, KV, n_pp * P, hd)


def ref_paged_verify_attention(
    q: jax.Array,           # (B, KV, R, hd)
    k_pages: jax.Array,     # (NP, KV, P, hd)
    v_pages: jax.Array,
    page_table: jax.Array,  # (B, n_pp)
    kv_pos: jax.Array,      # (B, n_pp * P)
    q_pos: jax.Array,       # (B, R)
    k_new: jax.Array,       # (B, KV, T, hd)
    v_new: jax.Array,
    tree_mask: jax.Array,   # (B, T, T)
    *,
    kind: str = "causal",
    window: int = 0,
    sink: int = 0,
) -> jax.Array:
    """Paged oracle: gather pool pages to the dense view, then the dense
    oracle — the page table only changes *where* KV lives, never the math."""
    return ref_verify_attention(
        q,
        ref_paged_gather(k_pages, page_table),
        ref_paged_gather(v_pages, page_table),
        kv_pos, q_pos, k_new, v_new, tree_mask,
        kind=kind, window=window, sink=sink,
    )


def ref_int8_matmul(
    x_q: jax.Array, w_q: jax.Array, x_scale: jax.Array, w_scale: jax.Array
) -> jax.Array:
    acc = jnp.einsum(
        "mk,kn->mn",
        x_q.astype(jnp.int32),
        w_q.astype(jnp.int32),
    ).astype(jnp.float32)
    return acc * x_scale * w_scale
