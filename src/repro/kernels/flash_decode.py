"""Pallas TPU kernel: flash-decode attention over a committed KV cache.

The memory-bound hot loop of speculative *verification*: T staged query rows
(tree bucket, or T=1 for plain AR decode) attend over an S-long KV cache.
KV is streamed HBM->VMEM in ``block_s`` chunks along the innermost grid dim
with online-softmax scratch carried in VMEM across chunks; the (small) query
block stays resident in VMEM. Returns un-normalized partials (acc, m, l) so
the caller can merge with the staged-token tree attention (see ops.py) —
exactly the flash-decoding split-KV combine, adapted to the verify step.

Layouts (per kv-head group g, GQA rep = H // KV):
  q:      (B, KV, R, hd)   R = rep * T query rows, hd padded to 128
  k/v:    (B, KV, S, hd)   S padded to block_s
  kv_pos: (B, S) int32     slot position, -1 = invalid (ring/empty)
  q_pos:  (B, R) int32     absolute position per query row
Outputs: acc (B, KV, R, hd) f32, m/l (B, KV, R) f32.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(
    q_ref, k_ref, v_ref, kvpos_ref, qpos_ref,          # inputs
    acc_ref, m_ref, l_ref,                             # outputs
    m_scr, l_scr, o_scr,                               # VMEM scratch
    *, kind: str, window: int, sink: int, scale: float, nk: int,
):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        o_scr[...] = jnp.zeros_like(o_scr)

    q = q_ref[0, 0].astype(jnp.float32) * scale        # (R, hd)
    k = k_ref[0, 0].astype(jnp.float32)                # (blk, hd)
    v = v_ref[0, 0].astype(jnp.float32)
    kvp = kvpos_ref[0]                                 # (blk,)
    qp = qpos_ref[0]                                   # (R,)

    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )                                                  # (R, blk)
    qpc = qp[:, None]
    kpc = kvp[None, :]
    valid = (kpc >= 0) & (kpc <= qpc)
    if kind == "window":
        valid &= kpc > qpc - window
    elif kind == "streaming":
        valid &= (kpc < sink) | (kpc > qpc - window)
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_scr[...]                                # (R, 1)
    m_new = jnp.maximum(m_prev[:, 0], jnp.max(s, axis=-1))[:, None]
    p = jnp.exp(s - m_new)                             # (R, blk)
    corr = jnp.exp(m_prev - m_new)                     # (R, 1)
    l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=-1)[:, None]
    o_scr[...] = o_scr[...] * corr + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    m_scr[...] = m_new

    @pl.when(j == nk - 1)
    def _fini():
        acc_ref[0, 0] = o_scr[...]
        m_ref[0, 0] = m_scr[...][:, 0]
        l_ref[0, 0] = l_scr[...][:, 0]


def _paged_kernel(
    tbl_ref,                                           # scalar prefetch (B, n_pp)
    q_ref, k_ref, v_ref, kvpos_ref, qpos_ref,          # inputs
    acc_ref, m_ref, l_ref,                             # outputs
    m_scr, l_scr, o_scr,                               # VMEM scratch
    *, kind: str, window: int, sink: int, scale: float, nk: int,
):
    # identical math to _kernel — only the k/v BlockSpec index_maps differ
    # (they dereference the prefetched page table), so the masking contract
    # is shared verbatim
    del tbl_ref
    _kernel(
        q_ref, k_ref, v_ref, kvpos_ref, qpos_ref,
        acc_ref, m_ref, l_ref, m_scr, l_scr, o_scr,
        kind=kind, window=window, sink=sink, scale=scale, nk=nk,
    )


def flash_decode_paged_partial(
    q: jax.Array,           # (B, KV, R, hd)
    k_pages: jax.Array,     # (NP, KV, P, hd) shared page pool
    v_pages: jax.Array,
    page_table: jax.Array,  # (B, n_pp) int32, -1 = unallocated
    kv_pos: jax.Array,      # (B, n_pp * P) int32, -1 = invalid
    q_pos: jax.Array,       # (B, R) int32
    *,
    kind: str = "causal",
    window: int = 0,
    sink: int = 0,
    interpret: bool = True,
    scale: float | None = None,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Block-paged flash-decode partials: the page table rides as a SCALAR
    PREFETCH operand and the k/v BlockSpec index_maps dereference it, so the
    j-th KV chunk streamed HBM->VMEM is pool page ``page_table[b, j]`` — the
    gather costs no extra pass. Unallocated entries (-1) are clamped to page
    0; whatever garbage that block holds is killed by the caller's
    ``kv_pos = -1`` rows, exactly the invalid-slot contract the dense kernel
    already enforces (partially-filled tail pages work the same way).
    Returns (acc, m, l) like ``flash_decode_partial``."""
    B, KV, R, hd = q.shape
    NP, _, P, _ = k_pages.shape
    n_pp = page_table.shape[1]
    assert kv_pos.shape[1] == n_pp * P, (
        f"kv_pos covers {kv_pos.shape[1]} slots, table spans {n_pp * P}"
    )
    nk = n_pp
    scale = hd ** -0.5 if scale is None else scale

    kernel = functools.partial(
        _paged_kernel, kind=kind, window=window, sink=sink, scale=scale, nk=nk
    )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, KV, nk),
        in_specs=[
            pl.BlockSpec((1, 1, R, hd), lambda b, g, j, tbl: (b, g, 0, 0)),
            pl.BlockSpec(
                (1, 1, P, hd),
                lambda b, g, j, tbl: (jnp.maximum(tbl[b, j], 0), g, 0, 0),
            ),
            pl.BlockSpec(
                (1, 1, P, hd),
                lambda b, g, j, tbl: (jnp.maximum(tbl[b, j], 0), g, 0, 0),
            ),
            pl.BlockSpec((1, P), lambda b, g, j, tbl: (b, j)),
            pl.BlockSpec((1, R), lambda b, g, j, tbl: (b, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, R, hd), lambda b, g, j, tbl: (b, g, 0, 0)),
            pl.BlockSpec((1, 1, R), lambda b, g, j, tbl: (b, g, 0)),
            pl.BlockSpec((1, 1, R), lambda b, g, j, tbl: (b, g, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((R, 1), jnp.float32),
            pltpu.VMEM((R, 1), jnp.float32),
            pltpu.VMEM((R, hd), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((B, KV, R, hd), jnp.float32),
            jax.ShapeDtypeStruct((B, KV, R), jnp.float32),
            jax.ShapeDtypeStruct((B, KV, R), jnp.float32),
        ],
        interpret=interpret,
    )(page_table, q, k_pages, v_pages, kv_pos, q_pos)


def flash_decode_partial(
    q: jax.Array,        # (B, KV, R, hd)
    k: jax.Array,        # (B, KV, S, hd)
    v: jax.Array,
    kv_pos: jax.Array,   # (B, S) int32
    q_pos: jax.Array,    # (B, R) int32
    *,
    kind: str = "causal",
    window: int = 0,
    sink: int = 0,
    block_s: int = 512,
    interpret: bool = True,
    scale: float | None = None,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    B, KV, R, hd = q.shape
    S = k.shape[2]
    blk = min(block_s, S)
    assert S % blk == 0, f"S={S} must be a multiple of block_s={blk} (pad in ops)"
    nk = S // blk
    scale = hd ** -0.5 if scale is None else scale

    kernel = functools.partial(
        _kernel, kind=kind, window=window, sink=sink, scale=scale, nk=nk
    )
    grid = (B, KV, nk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, R, hd), lambda b, g, j: (b, g, 0, 0)),
            pl.BlockSpec((1, 1, blk, hd), lambda b, g, j: (b, g, j, 0)),
            pl.BlockSpec((1, 1, blk, hd), lambda b, g, j: (b, g, j, 0)),
            pl.BlockSpec((1, blk), lambda b, g, j: (b, j)),
            pl.BlockSpec((1, R), lambda b, g, j: (b, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, R, hd), lambda b, g, j: (b, g, 0, 0)),
            pl.BlockSpec((1, 1, R), lambda b, g, j: (b, g, 0)),
            pl.BlockSpec((1, 1, R), lambda b, g, j: (b, g, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, KV, R, hd), jnp.float32),
            jax.ShapeDtypeStruct((B, KV, R), jnp.float32),
            jax.ShapeDtypeStruct((B, KV, R), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((R, 1), jnp.float32),
            pltpu.VMEM((R, 1), jnp.float32),
            pltpu.VMEM((R, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v, kv_pos, q_pos)
