"""jit'd wrappers: padding, layout, and the flash-decode + tree combine.

``verify_attention`` is the full TPU hot-spot op: cache partials from the
flash_decode kernel merged with staged-tree partials from the tree_attention
kernel — one logsumexp-consistent softmax over [cache ++ tree], identical to
ref.ref_verify_attention (and to models.attention.decode_attention).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_decode import flash_decode_paged_partial, flash_decode_partial
from repro.kernels.int8_matmul import int8_matmul, quantize_cols, quantize_rows
from repro.kernels.tree_attention import tree_attention_partial


def _pad_to(x: jax.Array, axis: int, multiple: int, value=0):
    n = x.shape[axis]
    pad = (-n) % multiple
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


@functools.partial(
    jax.jit,
    static_argnames=("kind", "window", "sink", "block_s", "interpret"),
)
def verify_attention(
    q: jax.Array,        # (B, T, H, hd) staged queries
    k_cache: jax.Array,  # (B, S, KV, hd)
    v_cache: jax.Array,
    kv_pos: jax.Array,   # (B, S) int32 (-1 invalid)
    q_pos: jax.Array,    # (B, T)
    k_new: jax.Array,    # (B, T, KV, hd)
    v_new: jax.Array,
    tree_mask: jax.Array,    # (B, T, T) bool (incl. positional validity)
    *,
    kind: str = "causal",
    window: int = 0,
    sink: int = 0,
    block_s: int = 512,
    interpret: bool = True,
) -> jax.Array:
    """Returns (B, T, H, hd). TPU path for the verification step."""
    B, T, H, hd0 = q.shape
    KV = k_cache.shape[2]
    rep = H // KV

    # layout: (B, KV, rep*T, hd), rows ordered r*T + t; pad hd to 128
    qr = q.reshape(B, T, KV, rep, hd0).transpose(0, 2, 3, 1, 4).reshape(B, KV, rep * T, hd0)
    qr = _pad_to(qr, 3, 128)
    kc = _pad_to(k_cache.transpose(0, 2, 1, 3), 3, 128)   # (B, KV, S, hd)
    vc = _pad_to(v_cache.transpose(0, 2, 1, 3), 3, 128)
    kn = _pad_to(k_new.transpose(0, 2, 1, 3), 3, 128)
    vn = _pad_to(v_new.transpose(0, 2, 1, 3), 3, 128)
    hd = qr.shape[-1]

    # pad S to block multiple with invalid slots
    S = kc.shape[2]
    blk = min(block_s, S) if S else 1
    kc = _pad_to(kc, 2, blk)
    vc = _pad_to(vc, 2, blk)
    kvp = _pad_to(kv_pos, 1, blk, value=-1)

    qp_rows = jnp.tile(q_pos, (1, rep))                   # (B, rep*T)

    scale = hd0 ** -0.5
    acc_c, m_c, l_c = flash_decode_partial(
        qr, kc, vc, kvp, qp_rows,
        kind=kind, window=window, sink=sink, block_s=blk, interpret=interpret,
        scale=scale,
    )
    acc_d, m_d, l_d = tree_attention_partial(
        qr, kn, vn, tree_mask, interpret=interpret, scale=scale
    )

    m = jnp.maximum(m_c, m_d)
    cc = jnp.exp(m_c - m)[..., None]
    cd = jnp.exp(m_d - m)[..., None]
    out = (acc_c * cc + acc_d * cd) / jnp.maximum(
        (l_c[..., None] * cc + l_d[..., None] * cd), 1e-30
    )
    out = out[..., :hd0]                                  # drop hd padding
    out = out.reshape(B, KV, rep, T, hd0).transpose(0, 3, 1, 2, 4).reshape(B, T, H, hd0)
    return out


@functools.partial(
    jax.jit,
    static_argnames=("kind", "window", "sink", "interpret"),
)
def paged_verify_attention(
    q: jax.Array,           # (B, T, H, hd) staged queries
    k_pages: jax.Array,     # (NP, P, KV, hd) shared pool, model layout
    v_pages: jax.Array,
    page_table: jax.Array,  # (B, n_pp) int32 (-1 unallocated)
    kv_pos: jax.Array,      # (B, n_pp * P) int32 (-1 invalid)
    q_pos: jax.Array,       # (B, T)
    k_new: jax.Array,       # (B, T, KV, hd)
    v_new: jax.Array,
    tree_mask: jax.Array,   # (B, T, T) bool (incl. positional validity)
    *,
    kind: str = "causal",
    window: int = 0,
    sink: int = 0,
    interpret: bool = True,
) -> jax.Array:
    """Block-paged twin of ``verify_attention``: cache partials come from
    ``flash_decode_paged_partial`` (page table scalar-prefetched into the
    kernel's index_maps), the staged-tree partials and the logsumexp merge
    are byte-for-byte the dense path's — paging changes where committed KV
    lives, never how the two softmax halves combine."""
    B, T, H, hd0 = q.shape
    KV = k_pages.shape[2]
    rep = H // KV

    qr = q.reshape(B, T, KV, rep, hd0).transpose(0, 2, 3, 1, 4).reshape(B, KV, rep * T, hd0)
    qr = _pad_to(qr, 3, 128)
    kp = _pad_to(k_pages.transpose(0, 2, 1, 3), 3, 128)   # (NP, KV, P, hd)
    vp = _pad_to(v_pages.transpose(0, 2, 1, 3), 3, 128)
    kn = _pad_to(k_new.transpose(0, 2, 1, 3), 3, 128)
    vn = _pad_to(v_new.transpose(0, 2, 1, 3), 3, 128)

    qp_rows = jnp.tile(q_pos, (1, rep))                   # (B, rep*T)

    scale = hd0 ** -0.5
    acc_c, m_c, l_c = flash_decode_paged_partial(
        qr, kp, vp, page_table, kv_pos, qp_rows,
        kind=kind, window=window, sink=sink, interpret=interpret, scale=scale,
    )
    acc_d, m_d, l_d = tree_attention_partial(
        qr, kn, vn, tree_mask, interpret=interpret, scale=scale
    )

    m = jnp.maximum(m_c, m_d)
    cc = jnp.exp(m_c - m)[..., None]
    cd = jnp.exp(m_d - m)[..., None]
    out = (acc_c * cc + acc_d * cd) / jnp.maximum(
        (l_c[..., None] * cc + l_d[..., None] * cd), 1e-30
    )
    out = out[..., :hd0]
    out = out.reshape(B, KV, rep, T, hd0).transpose(0, 3, 1, 2, 4).reshape(B, T, H, hd0)
    return out


@functools.partial(jax.jit, static_argnames=("interpret",))
def quantized_matmul(
    x: jax.Array, w: jax.Array, *, interpret: bool | None = None
) -> jax.Array:
    """W8A8 dynamic quantized x @ w with padding to 128-tiles.

    ``interpret`` defaults to backend-aware: compiled on TPU, interpreter
    everywhere else (the kernel only lowers on TPU) — callers on TPU get
    the real kernel without remembering the flag. Pass an explicit bool to
    override (e.g. CPU parity tests force ``interpret=True``).
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    M0, K0 = x.shape
    N0 = w.shape[1]
    x_q, xs = quantize_rows(x)
    w_q, ws = quantize_cols(w)
    x_q = _pad_to(_pad_to(x_q, 0, 128), 1, 128)
    w_q = _pad_to(_pad_to(w_q, 0, 128), 1, 128)
    xs = _pad_to(xs, 0, 128, value=1.0)
    ws = _pad_to(ws, 1, 128, value=1.0)
    out = int8_matmul(x_q, w_q, xs, ws, interpret=interpret)
    return out[:M0, :N0]
