"""Roofline terms from a compiled dry-run artifact.

  compute term    = HLO_FLOPs / (chips x peak_FLOP/s)
  memory term     = HLO_bytes / (chips x HBM_bw)
  collective term = collective_bytes / (chips x link_bw)

``compiled.cost_analysis()`` reports the per-device (post-SPMD) program, so
"/(chips)" is already applied — we verify this invariant in tests against
analytic 6·N·D. collective_bytes is not in cost_analysis: we parse the HLO
text and sum output-shape bytes of every collective op.

Hardware: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "token": 0,
}

_COLLECTIVES = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum of result bytes per collective kind (proxy for moved bytes).

    NOT trip-count aware — see analysis.hlo_costs for the corrected totals;
    this helper is kept for quick flat-HLO inspection.
    """
    out: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        for kind in _COLLECTIVES:
            marker = f" {kind}("
            if marker in line and "=" in line.split(marker)[0]:
                head = line.split(marker)[0].split("=", 1)[1]
                for dtype, dims in _SHAPE_RE.findall(head):
                    out[kind] += _shape_bytes(dtype, dims)
                break
    return out


@dataclasses.dataclass
class RooflineReport:
    name: str
    flops: float                 # per-device, trip-count corrected (HLO dots)
    bytes_hbm: float             # per-device (max of analytic-min and XLA)
    coll_bytes: Dict[str, int]   # per-device, by kind, trip-count corrected
    peak_memory: Optional[float] = None   # bytes/device from memory_analysis
    flops_xla: float = 0.0       # raw cost_analysis (loop bodies counted once)
    bytes_xla: float = 0.0       # raw cost_analysis
    bytes_analytic: float = 0.0  # parameter+cache+activation traffic model

    @property
    def coll_total(self) -> float:
        return float(sum(self.coll_bytes.values()))

    @property
    def t_compute(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        """Memory term from the ANALYTIC traffic model (params + cache +
        activation streams per device). XLA's 'bytes accessed' is reported
        alongside (bytes_xla) but not used: it counts loop bodies once,
        counts functional scatters as full read+write even when aliased
        in-place, and on the CPU backend includes f32 upcast copies of
        every bf16 buffer (verified in the buffer assignment — TPU keeps
        bf16 native)."""
        return (self.bytes_analytic or self.bytes_hbm) / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.coll_total / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {
            "compute": self.t_compute,
            "memory": self.t_memory,
            "collective": self.t_collective,
        }
        return max(terms, key=terms.get)

    @property
    def t_bound(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    def to_dict(self) -> dict:
        return {
            "name": self.name,
            "flops": self.flops,
            "flops_xla": self.flops_xla,
            "bytes_hbm": self.bytes_hbm,
            "bytes_xla": self.bytes_xla,
            "bytes_analytic": self.bytes_analytic,
            "coll_bytes": self.coll_bytes,
            "peak_memory": self.peak_memory,
            "t_compute": self.t_compute,
            "t_memory": self.t_memory,
            "t_collective": self.t_collective,
            "bottleneck": self.bottleneck,
        }


def analyze_compiled(name: str, compiled, analytic_bytes: float = 0.0) -> RooflineReport:
    """Roofline terms from a compiled executable.

    FLOPs and collective bytes come from the trip-count-corrected HLO parse
    (repro.analysis.hlo_costs) — XLA's cost_analysis counts while bodies
    once. The memory term is max(analytic traffic model, XLA bytes): XLA
    under-counts loops, the analytic model is the data-movement minimum.
    """
    from repro.analysis.hlo_costs import total_costs

    cost = compiled.cost_analysis()
    if isinstance(cost, list):       # older jax returns [dict]
        cost = cost[0]
    flops_xla = float(cost.get("flops", 0.0))
    bytes_xla = float(cost.get("bytes accessed", 0.0))
    try:
        hlo = compiled.as_text()
    except Exception:
        hlo = ""
    parsed = total_costs(hlo) if hlo else {"flops": 0.0, "collective_bytes": {}}
    coll = {k: int(v) for k, v in parsed["collective_bytes"].items()}
    flops = max(parsed["flops"], flops_xla)
    bytes_hbm = max(analytic_bytes, bytes_xla)
    peak = None
    try:
        ma = compiled.memory_analysis()
        peak = float(
            ma.temp_size_in_bytes + ma.argument_size_in_bytes + ma.output_size_in_bytes
        )
    except Exception:
        pass
    return RooflineReport(
        name, flops, bytes_hbm, coll, peak,
        flops_xla=flops_xla, bytes_xla=bytes_xla, bytes_analytic=analytic_bytes,
    )
