"""Render EXPERIMENTS.md roofline tables from results/dryrun/*.json.

  PYTHONPATH=src python -m repro.analysis.report [--dir results/dryrun]
"""
from __future__ import annotations

import argparse
import glob
import json
import os

from repro.config import get_config

CHIPS = {"1pod": 256, "2pod": 512}


def model_flops_per_step(arch: str, kind: str, seq: int, batch: int, draft_t: int = 8) -> float:
    """MODEL_FLOPS: 6·N·D (train, dense) / 6·N_active·D (MoE); inference
    2·N_active·tokens."""
    cfg = get_config(arch)
    n_active = cfg.active_param_count()
    tokens = batch * seq
    if kind == "train":
        return 6.0 * n_active * tokens
    if kind == "prefill":
        return 2.0 * n_active * tokens
    return 2.0 * n_active * batch * draft_t      # decode: T staged tokens


SHAPES = {"train_4k": (4096, 256), "prefill_32k": (32768, 32),
          "decode_32k": (32768, 128), "long_500k": (524288, 1)}


def load(dirname: str):
    rows = []
    for f in sorted(glob.glob(os.path.join(dirname, "*.json"))):
        with open(f) as fh:
            rows.append(json.load(fh))
    return rows


def render(rows, mesh="1pod") -> str:
    out = []
    out.append(
        "| arch | shape | bottleneck | t_comp (ms) | t_mem (ms) | t_coll (ms) "
        "| FLOPs/dev | HBM GiB/dev | coll GB/dev | useful-FLOP ratio | fits? |"
    )
    out.append("|---|---|---|---:|---:|---:|---:|---:|---:|---:|---|")
    for r in rows:
        if r.get("status") != "ok" or r["mesh"] != ("16x16" if mesh == "1pod" else "2x16x16"):
            continue
        rf = r["roofline"]
        seq, batch = SHAPES[r["shape"]]
        mf = model_flops_per_step(r["arch"], r["kind"], seq, batch)
        chips = CHIPS[mesh]
        ratio = mf / chips / max(rf["flops"], 1.0)
        mem = r["memory_analysis"]
        # CPU-backend compiles upcast every bf16 buffer to f32 (verified in
        # the buffer assignment); the TPU estimate halves temp accordingly.
        peak = (mem["argument_bytes"] + mem["temp_bytes"] / 2) / 2 ** 30
        fits = "yes" if peak <= 16 else f"NO ({peak:.0f}GiB)"
        out.append(
            f"| {r['arch']} | {r['shape']} | **{rf['bottleneck']}** "
            f"| {rf['t_compute']*1e3:.2f} | {rf['t_memory']*1e3:.2f} "
            f"| {rf['t_collective']*1e3:.2f} | {rf['flops']:.2e} "
            f"| {rf['bytes_hbm']/2**30:.2f} | {rf['coll_bytes'] and sum(rf['coll_bytes'].values())/1e9 or 0:.2f} "
            f"| {min(ratio, 9.99):.2f} | {fits} |"
        )
    return "\n".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="results/dryrun")
    ap.add_argument("--mesh", default="1pod")
    args = ap.parse_args()
    rows = load(args.dir)
    print(render(rows, args.mesh))
    skips = [r for r in rows if r.get("status") == "skipped"]
    if skips:
        print("\nSkipped (documented in DESIGN.md §Arch-applicability):")
        for r in skips:
            print(f"- {r['arch']} x {r['shape']}: {r['reason']}")


if __name__ == "__main__":
    main()
