"""Compiled-artifact + static analysis: roofline terms, HLO collective
accounting, dispatch-discipline lint (REPRO001-005, ``analysis.lint``) and
compiled-HLO dispatch contracts (``analysis.contracts``)."""
from repro.analysis.contracts import (
    ContractViolation,
    HloContract,
    server_round_contracts,
)
from repro.analysis.lint import Finding, run_paths
from repro.analysis.roofline import RooflineReport, analyze_compiled, collective_bytes

__all__ = [
    "ContractViolation",
    "Finding",
    "HloContract",
    "RooflineReport",
    "analyze_compiled",
    "collective_bytes",
    "run_paths",
    "server_round_contracts",
]
