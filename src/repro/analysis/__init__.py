"""Compiled-artifact analysis: roofline terms + HLO collective accounting."""
from repro.analysis.roofline import RooflineReport, analyze_compiled, collective_bytes

__all__ = ["RooflineReport", "analyze_compiled", "collective_bytes"]
