"""Compiled-HLO dispatch contracts for the serving rounds.

The runtime counters in ``BatchedSpecServer.stats`` observe the dispatch
discipline (one executable per chain/tree round, <= L+1 for the cascade,
donated caches, no host syncs between rounds); this module proves the same
facts on the COMPILED artifact, so the contract holds before a single round
runs and cannot drift from what XLA actually lowered:

  - donation lowered for real: ``donate_argnums`` must show up as
    ``input_output_alias`` entries in the HloModule header — if jax ever
    silently drops the aliasing (dtype mismatch, sharding change), the
    "in-place commit scatter" claim in docs/serving.md is a copy again;
  - no host round-trips inside a round body: callbacks
    (``jax.debug.print`` / ``pure_callback`` / ``io_callback`` lower to
    ``custom-call`` with a python-callback target) and infeed/outfeed/
    send/recv ops are all grounds for rejection;
  - expected ``known_trip_count``s: the fused rounds are lax.scans over
    draft steps / tree expansions — the trip counts pin that the scan
    structure survived lowering (a full unroll or a dynamic while both
    break the one-executable-many-steps story);
  - mesh placement lowered for real: on a sharded server the entry params
    must keep split ``sharding={devices=[...]}`` annotations
    (``assert_sharding``), and ``collective_counts`` /
    ``assert_no_collectives`` pin which cross-device collectives the round
    body is allowed — a single-device round compiles collective-free, a
    sharded one carries TP all-reduces but no resharding all-to-alls.

Built on the HLO text parser in ``analysis.hlo_costs`` (same grammar, same
``known_trip_count`` source) and the lowering idiom of
``tests/test_sharding_lowering.py``. Pinned for all four server modes in
``tests/test_dispatch_contracts.py``, cross-validated there against the
runtime ``round_dispatches``/``host_syncs`` stats.

Typical use::

    con = HloContract.from_jitted(srv._round_fn, *args, name="round")
    con.assert_donated(1, 2)          # cache + dstate alias into outputs
    con.assert_no_host_callbacks()
    con.assert_trip_count(draft_k)    # the draft scan survived lowering

    cons = server_round_contracts(srv)        # every executable of a round
    assert len(cons) <= srv.expected_dispatches_per_round()
"""
from __future__ import annotations

import dataclasses
import functools
import re
from typing import Dict, Tuple

from repro.analysis.hlo_costs import parse_hlo

__all__ = [
    "ContractViolation",
    "HloContract",
    "server_round_contracts",
    "assert_telemetry_transparent",
]


class ContractViolation(AssertionError):
    """A compiled artifact broke a dispatch-discipline contract."""


# (param_number, param_index_tree, kind) triples inside input_output_alias
_ALIAS_PAIR = re.compile(r"\((\d+),\s*\{[^{}]*\},\s*(may-alias|must-alias)\)")
_TRIP = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
# python host callbacks lower to custom-calls whose target embeds
# "callback" (xla_python_cpu_callback, xla_ffi_python_cpu_callback, ...)
_CUSTOM_TARGET = re.compile(r'custom_call_target="([^"]+)"')
_HOST_TRANSFER_OPS = ("infeed(", "outfeed(", " send(", " recv(",
                      "send-done(", "recv-done(")
# entry-parameter sharding annotations: `parameter(N), sharding={...}`;
# the tile shape lives in `devices=[d0,d1,...]<=[n]`, optionally with a
# trailing replicated tile dim (last_tile_dim_replicate)
_PARAM_SHARDING = re.compile(
    r"parameter\((\d+)\)[^\n]*?sharding=(\{[^\n]*?\})"
)
_TILE_DIMS = re.compile(r"devices=\[([\d,]+)\]")
# cross-device collectives, with or without async -start/-done splitting
_COLLECTIVE = re.compile(
    r"= \S+ (all-reduce|all-gather|reduce-scatter|all-to-all|"
    r"collective-permute)(?:-start)?\("
)


def _balanced_block(text: str, start: int) -> str:
    """The ``{...}`` block starting at ``text[start]`` with nesting honored
    (alias maps nest tuple-index braces inside the outer map braces)."""
    assert text[start] == "{"
    depth = 0
    for i in range(start, len(text)):
        if text[i] == "{":
            depth += 1
        elif text[i] == "}":
            depth -= 1
            if depth == 0:
                return text[start:i + 1]
    return text[start:]


@dataclasses.dataclass(frozen=True)
class HloContract:
    """Parsed dispatch-discipline facts of one compiled executable."""

    name: str
    text: str

    # ------------------------------------------------------------- builders
    @classmethod
    def from_compiled(cls, compiled, name: str = "jit") -> "HloContract":
        return cls(name, compiled.as_text())

    @classmethod
    def from_jitted(cls, fn, *args, name: str = "jit", **kwargs) -> "HloContract":
        """Lower + compile a jitted callable on example args (lowering does
        NOT execute, so donated example buffers stay valid)."""
        return cls.from_compiled(fn.lower(*args, **kwargs).compile(), name=name)

    # ---------------------------------------------------------------- facts
    @functools.cached_property
    def donated_params(self) -> Tuple[int, ...]:
        """Flat entry-parameter numbers that alias an output buffer
        (``donate_argnums`` that actually survived lowering). NOTE: these
        are positions in the FLATTENED argument list, not pytree argnums —
        assert non-emptiness / counts, or membership of position 0 only
        when the signature starts with a donated leaf."""
        m = re.search(r"input_output_alias=\{", self.text)
        if not m:
            return ()
        block = _balanced_block(self.text, m.end() - 1)
        return tuple(sorted({int(p) for p, _ in _ALIAS_PAIR.findall(block)}))

    @functools.cached_property
    def alias_count(self) -> int:
        """Number of output buffers aliased onto inputs."""
        m = re.search(r"input_output_alias=\{", self.text)
        if not m:
            return 0
        block = _balanced_block(self.text, m.end() - 1)
        return len(_ALIAS_PAIR.findall(block))

    @functools.cached_property
    def trip_counts(self) -> Tuple[int, ...]:
        """``known_trip_count`` of every while loop, descending (the layer
        stack, KV chunk streams, and the draft/expansion scans all lower as
        counted whiles)."""
        return tuple(sorted((int(n) for n in _TRIP.findall(self.text)),
                            reverse=True))

    @functools.cached_property
    def host_callbacks(self) -> Tuple[str, ...]:
        """custom-call targets that re-enter python on the host."""
        return tuple(
            t for t in _CUSTOM_TARGET.findall(self.text)
            if "callback" in t.lower()
        )

    @functools.cached_property
    def host_transfer_ops(self) -> Tuple[str, ...]:
        """infeed/outfeed/send/recv ops (host transfers inside the body)."""
        found = []
        for line in self.text.splitlines():
            for op in _HOST_TRANSFER_OPS:
                if op in line:
                    found.append(op.strip().rstrip("("))
                    break
        return tuple(found)

    @functools.cached_property
    def entry_text(self) -> str:
        """The ENTRY computation's text (XLA prints it last)."""
        i = self.text.rfind("\nENTRY")
        return self.text[i:] if i >= 0 else self.text

    @functools.cached_property
    def param_shardings(self) -> Dict[int, str]:
        """Entry-parameter number -> raw ``sharding={...}`` annotation.

        Parameters without an annotation (or an executable compiled off-mesh)
        are absent; ``{replicated}`` entries are kept — distinguishing
        "explicitly replicated" from "unannotated" matters for the gates/c
        scalars of a sharded round."""
        return {
            int(n): s
            for n, s in _PARAM_SHARDING.findall(self.entry_text)
        }

    @functools.cached_property
    def sharded_params(self) -> Tuple[int, ...]:
        """Flat entry-parameter numbers actually SPLIT across devices (some
        tile dim > 1 after dropping a ``last_tile_dim_replicate`` dim) —
        the compiled-artifact proof that ``NamedSharding`` placements
        survived to the executable instead of degrading to replication."""
        out = []
        for n, s in self.param_shardings.items():
            m = _TILE_DIMS.search(s)
            if not m:
                continue
            dims = [int(d) for d in m.group(1).split(",")]
            if "last_tile_dim_replicate" in s and len(dims) > 1:
                dims = dims[:-1]
            if any(d > 1 for d in dims):
                out.append(n)
        return tuple(sorted(out))

    @functools.cached_property
    def collective_counts(self) -> Dict[str, int]:
        """Cross-device collective op -> instruction count over the whole
        module (async ``-start`` forms count once; ``-done`` is not an op
        name match). Empty off-mesh — a single-device lowering that emits
        collectives would be a compile bug worth failing on."""
        counts: Dict[str, int] = {}
        for op in _COLLECTIVE.findall(self.text):
            counts[op] = counts.get(op, 0) + 1
        return counts

    @functools.cached_property
    def executable_costs(self) -> dict:
        """Trip-count-aware flops/collective bytes (analysis.hlo_costs)."""
        from repro.analysis.hlo_costs import total_costs

        return total_costs(self.text)

    def computations(self):
        """The parsed computation call graph (analysis.hlo_costs grammar)."""
        return parse_hlo(self.text)

    # ----------------------------------------------------------- assertions
    def _fail(self, msg: str) -> None:
        raise ContractViolation(f"[{self.name}] {msg}")

    def assert_donated(self, *expect_flat: int, at_least: int = 1) -> "HloContract":
        """Donation survived lowering: at least ``at_least`` aliased
        outputs, and (when given) each flat param position in
        ``expect_flat`` aliases."""
        if self.alias_count < at_least:
            self._fail(
                f"expected >= {at_least} input_output_alias entries, found "
                f"{self.alias_count} — donation did not survive lowering"
            )
        missing = [p for p in expect_flat if p not in self.donated_params]
        if missing:
            self._fail(
                f"flat params {missing} not aliased "
                f"(aliased: {list(self.donated_params)})"
            )
        return self

    def assert_not_donated(self) -> "HloContract":
        if self.alias_count:
            self._fail(
                f"expected no aliasing, found {self.alias_count} "
                f"input_output_alias entries on params {list(self.donated_params)}"
            )
        return self

    def assert_no_host_callbacks(self) -> "HloContract":
        if self.host_callbacks:
            self._fail(
                "host python callbacks inside the executable: "
                f"{list(self.host_callbacks)} — a round body must not "
                "re-enter the host"
            )
        if self.host_transfer_ops:
            self._fail(
                f"host transfer ops inside the executable: "
                f"{list(self.host_transfer_ops)}"
            )
        return self

    def assert_trip_count(self, n: int) -> "HloContract":
        """Some counted while loop runs exactly ``n`` times (the fused scan
        over draft steps / expansions survived lowering at its trip count)."""
        if n not in self.trip_counts:
            self._fail(
                f"no while loop with known_trip_count={n} "
                f"(found: {list(self.trip_counts)})"
            )
        return self

    def assert_sharding(self, *expect_flat: int, at_least: int = 1) -> "HloContract":
        """Mesh placement survived lowering: at least ``at_least`` entry
        parameters are genuinely split across devices, and (when given)
        each flat position in ``expect_flat`` is among them. Like
        ``assert_donated``, positions index the FLATTENED argument list."""
        if len(self.sharded_params) < at_least:
            self._fail(
                f"expected >= {at_least} sharded entry params, found "
                f"{len(self.sharded_params)} "
                f"(annotated: {sorted(self.param_shardings)}) — mesh "
                "placement did not survive lowering"
            )
        missing = [p for p in expect_flat if p not in self.sharded_params]
        if missing:
            self._fail(
                f"flat params {missing} not sharded "
                f"(sharded: {list(self.sharded_params)})"
            )
        return self

    def assert_no_collectives(self, *kinds: str) -> "HloContract":
        """No cross-device collectives of the given kinds (all kinds when
        none given). A single-device round must compile collective-free;
        a sharded round uses this with e.g. ``"all-to-all"`` to pin that
        resharding round-trips never crept into the round body."""
        bad = {
            op: n for op, n in self.collective_counts.items()
            if not kinds or op in kinds
        }
        if bad:
            self._fail(f"unexpected collectives in the executable: {bad}")
        return self


def server_round_contracts(server) -> Dict[str, HloContract]:
    """Compile-and-parse every executable a steady-state round of
    ``server`` dispatches (``BatchedSpecServer.round_executables``).

    ``len(result)`` is the per-round executable count the runtime
    ``round_dispatches``/``draft_dispatches``/``rescore_dispatches``
    counters must agree with (cross-validated in
    tests/test_dispatch_contracts.py)."""
    return {
        name: HloContract.from_jitted(fn, *args, name=name)
        for name, (fn, args) in server.round_executables().items()
    }


def assert_telemetry_transparent(
    off: Dict[str, HloContract], on: Dict[str, HloContract]
) -> None:
    """Prove — on the compiled artifacts — that the device telemetry buffer
    changed NOTHING about the dispatch discipline (ISSUE 8's tentpole
    gate): ``off``/``on`` are ``server_round_contracts`` results from two
    servers identical except ``telemetry=``.

      - same executable set: telemetry adds no dispatch of its own;
      - no host callbacks or transfers on the telemetry-on side (the
        accumulation is pure jnp composed at the jit boundary, never a
        callback);
      - scan trip counts identical per executable (the round structure
        survived the composition);
      - donation aliasing preserved or extended: every telemetry-on
        executable keeps AT LEAST the telemetry-off alias count (the
        buffer may add its own aliased entries, it must never cost the
        cache/state theirs).
    """
    if set(off) != set(on):
        raise ContractViolation(
            f"telemetry changed the executable set: off={sorted(off)} "
            f"on={sorted(on)}"
        )
    for name, con_on in on.items():
        con_off = off[name]
        con_on.assert_no_host_callbacks()
        if con_on.trip_counts != con_off.trip_counts:
            raise ContractViolation(
                f"[{name}] telemetry changed scan trip counts: "
                f"{list(con_off.trip_counts)} -> {list(con_on.trip_counts)}"
            )
        if con_on.alias_count < con_off.alias_count:
            raise ContractViolation(
                f"[{name}] telemetry LOST donation aliasing: "
                f"{con_off.alias_count} -> {con_on.alias_count} aliased "
                "outputs"
            )
