"""Trip-count-aware HLO cost accounting.

XLA's ``compiled.cost_analysis()`` counts a while-loop body ONCE, not
multiplied by its trip count (verified empirically — a scan of 10 matmuls
reports the flops of 1). Since the layer stack lowers as lax.scan and the
attention streams KV chunks with inner scans, both the FLOPs and the
collective bytes would be underestimated by up to ~num_layers x num_chunks.

This module parses ``compiled.as_text()`` into a computation call graph,
multiplies through ``known_trip_count`` annotations on while ops, and sums:
  - dot FLOPs (2 x prod(result_shape) x prod(contracted lhs dims))
  - collective result bytes per kind (all-gather / all-reduce /
    reduce-scatter / all-to-all / collective-permute)
"""
from __future__ import annotations

import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s4": 1, "u4": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "token": 0, "f8e4m3fn": 1, "f8e5m2": 1,
}

COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_COMP_HEADER = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->.*\{")
_OP_DEF = re.compile(r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(.+)$")
_SHAPE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")
# operands may carry their type, e.g. dot(f32[64,64]{1,0} %a, f32[64,64] %b)
_OPERAND_TYPE = r"(?:[a-z][a-z0-9]*\[[0-9,]*\](?:\{[^}]*\})?\s+)?"
_DOT = re.compile(
    r"dot\(\s*" + _OPERAND_TYPE + r"%([\w\.\-]+)\s*,\s*"
    + _OPERAND_TYPE + r"%([\w\.\-]+)\s*\)"
)
_LHS_CDIMS = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_LHS_BDIMS = re.compile(r"lhs_batch_dims=\{([0-9,]*)\}")
_CALLS = re.compile(r"calls=%?([\w\.\-]+)")
_TO = re.compile(r"to_apply=%?([\w\.\-]+)|\bto=%?([\w\.\-]+)")
_BODY = re.compile(r"body=%?([\w\.\-]+)")
_COND = re.compile(r"condition=%?([\w\.\-]+)")
_TRIP = re.compile(r"\"known_trip_count\":\{\"n\":\"(\d+)\"\}")
_PARAM = re.compile(r"([\w\.\-]+):\s*([a-z][a-z0-9]*\[[0-9,]*\])")
_BRANCHES = re.compile(r"branch_computations=\{([^}]*)\}")


def _first_shape(text: str) -> Tuple[str, List[int]]:
    m = _SHAPE.search(text)
    if not m:
        return "f32", []
    dims = [int(d) for d in m.group(2).split(",") if d]
    return m.group(1), dims


def _all_shapes_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE.findall(text):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES.get(dt, 4)
    return total


class Computation:
    def __init__(self, name: str):
        self.name = name
        self.flops = 0.0
        self.coll: Dict[str, float] = {}
        # (callee, multiplier)
        self.calls: List[Tuple[str, float]] = []


def parse_hlo(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    shapes: Dict[str, Tuple[str, List[int]]] = {}
    entry = None
    for raw in text.splitlines():
        line = raw.rstrip()
        hm = _COMP_HEADER.match(line)
        if hm:
            cur = Computation(hm.group(1))
            comps[cur.name] = cur
            if line.startswith("ENTRY"):
                entry = cur.name
            shapes = {}
            # parameter shapes from the header
            for pname, pshape in _PARAM.findall(line):
                shapes[pname] = _first_shape(pshape)
            continue
        if cur is None:
            continue
        om = _OP_DEF.match(line)
        if not om:
            continue
        opname, rest = om.groups()
        # record result shape: the first shape token on the RHS (or tuple)
        shapes[opname] = _first_shape(rest)

        if " dot(" in rest or rest.startswith("dot("):
            dm = _DOT.search(rest)
            if dm:
                lhs = dm.group(1)
                res_dt, res_dims = _first_shape(rest.split(" dot(")[0] if " dot(" in rest else rest)
                cd = _LHS_CDIMS.search(rest)
                cdims = [int(d) for d in cd.group(1).split(",") if d] if cd else []
                lhs_shape = shapes.get(lhs, ("f32", []))[1]
                k = 1
                for d in cdims:
                    if d < len(lhs_shape):
                        k *= lhs_shape[d]
                n = 1
                for d in res_dims:
                    n *= d
                cur.flops += 2.0 * n * k
        for kind in COLLECTIVES:
            if f" {kind}(" in rest or rest.startswith(f"{kind}("):
                # result bytes (tuple-aware): everything before the op name
                head = rest.split(kind + "(")[0]
                cur.coll[kind] = cur.coll.get(kind, 0) + _all_shapes_bytes(head)
                break

        if "while(" in rest:
            bm = _BODY.search(rest)
            cm = _COND.search(rest)
            tm = _TRIP.search(rest)
            trip = float(tm.group(1)) if tm else 1.0
            if bm:
                cur.calls.append((bm.group(1), trip))
            if cm:
                cur.calls.append((cm.group(1), trip + 1))
        elif "fusion(" in rest or "custom-call" in rest:
            km = _CALLS.search(rest)
            if km:
                cur.calls.append((km.group(1), 1.0))
        elif "conditional(" in rest:
            brm = _BRANCHES.search(rest)
            if brm:
                for b in brm.group(1).split(","):
                    b = b.strip().lstrip("%")
                    if b:
                        cur.calls.append((b, 1.0))   # upper bound: all branches
        elif " call(" in rest or rest.startswith("call("):
            tm2 = _TO.search(rest)
            if tm2:
                callee = tm2.group(1) or tm2.group(2)
                cur.calls.append((callee, 1.0))
    comps["__entry__"] = comps.get(entry, Computation("__none__"))
    return comps


def total_costs(text: str) -> dict:
    comps = parse_hlo(text)
    memo: Dict[str, Tuple[float, Dict[str, float]]] = {}

    def visit(name: str, stack=()) -> Tuple[float, Dict[str, float]]:
        if name in memo:
            return memo[name]
        if name not in comps or name in stack:
            return 0.0, {}
        c = comps[name]
        fl = c.flops
        co = dict(c.coll)
        for callee, mult in c.calls:
            cf, cc = visit(callee, stack + (name,))
            fl += mult * cf
            for k, v in cc.items():
                co[k] = co.get(k, 0.0) + mult * v
        memo[name] = (fl, co)
        return memo[name]

    entry = comps["__entry__"].name
    fl, co = visit(entry)
    return {"flops": fl, "collective_bytes": co,
            "coll_total": float(sum(co.values()))}
