"""Dispatch-discipline lint: JAX-aware AST rules for the serving hot paths.

The invariants that deliver CAS-Spec's speedup — one device dispatch per
chain/tree round, <= L+1 for the cascade, zero host syncs between rounds,
donated caches actually reused — are runtime-enforced by the counters in
``tests/test_server_round.py``. This module enforces the same discipline
*statically*, at lint time, so a new code path cannot silently reintroduce
the host-gated regime (see also ``analysis.contracts`` for the compiled-HLO
half of the story).

Rules (each documented with a bad/good example in ``docs/analysis.md``):

  REPRO001  host-sync hazards inside device-reachable code: ``.item()``,
            ``np.asarray``/``np.array``, ``float()/int()/bool()`` applied to
            indexed or jnp-produced values, ``jax.device_get`` and
            ``block_until_ready`` inside any function reachable (via a
            static call-graph walk) from the fused round/scan roots
            (``chain_round``, ``tree_round``, ``cascade_rescore*``,
            ``chain_draft_scan``, ``tree_draft_scan``).
  REPRO002  use-after-donate: reading a variable after it was passed in a
            donated argument position of a jitted call — the buffer may
            already be aliased by the callee's outputs.
  REPRO003  recompilation hazards: ``jax.jit`` constructed inside a
            ``for``/``while`` loop, or constructed-and-immediately-called
            inside a function (a fresh executable per invocation).
  REPRO004  scan/cond/while body purity: host side effects (``print``,
            ``open``, ``time.*``), ``np.asarray``/``np.array`` on tracers,
            ``.item()``, or mutation of enclosing state (``self.*`` stores,
            ``global``/``nonlocal``) inside a ``lax.scan``/``cond``/
            ``while_loop``/``fori_loop``/``switch`` body.
  REPRO005  timing hygiene: ``time.time()`` anywhere (wall-clock is not
            monotonic; use ``time.perf_counter()``), and perf-counter
            deltas that time a jitted dispatch without a
            ``block_until_ready`` between start and stop (async dispatch
            returns immediately — the measurement is a lie).

Waivers: append ``# repro: noqa-REPRO00x: <why this is safe here>`` to the
flagged line. The justification text is REQUIRED — a bare waiver is itself
reported (REPRO000), so every suppression carries its reasoning in-line.

CLI::

    python -m repro.analysis.lint src/repro            # exit 1 on findings
    python -m repro.analysis.lint --roots my_round f.py

The implementation is stdlib-only (ast + re) so the lint gate runs without
jax installed.
"""
from __future__ import annotations

import ast
import dataclasses
import os
import re
import sys
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

# Functions whose (transitive) callees must stay host-sync free. Matching is
# by bare name so fixture/test files defining their own `chain_round` are
# rooted too; `cascade_rescore` is a prefix match (covers _verify fold).
DEFAULT_ROOTS = (
    "chain_round",
    "tree_round",
    "cascade_rescore",
    "chain_draft_scan",
    "tree_draft_scan",
)

RULES = {
    "REPRO000": "lint waiver without a justification",
    "REPRO001": "host-sync hazard in device-reachable code",
    "REPRO002": "variable read after being donated into a jitted call",
    "REPRO003": "recompilation hazard (jit constructed per call)",
    "REPRO004": "host side effect inside a traced loop/cond body",
    "REPRO005": "timing hygiene (wall clock / unsynced device timing)",
}

_NUMPY_HOST_FNS = {"asarray", "array", "ascontiguousarray", "copy", "save"}
_LAX_BODY_FNS = {
    # callee suffix -> argument indices holding traced function references
    "scan": (0,),
    "while_loop": (0, 1),
    "fori_loop": (2,),
    "cond": (1, 2, 3),
    "switch": (1, 2, 3, 4, 5, 6, 7),
}
_WAIVER_RE = re.compile(
    r"#\s*repro:\s*noqa-(REPRO\d{3})\b[:\s-]*(.*?)\s*$"
)


@dataclasses.dataclass(frozen=True)
class Finding:
    path: str
    line: int
    col: int
    rule: str
    msg: str

    def render(self) -> str:
        return f"{self.path}:{self.line}:{self.col}: {self.rule} {self.msg}"


@dataclasses.dataclass
class _Jitted:
    """A callable known to be ``jax.jit(...)`` output: where its result is
    bound, and which argument positions/names are donated."""
    name: str                      # "fn" | "self.attr" | "factory:self.attr"
    donate_pos: Tuple[int, ...]
    donate_names: Tuple[str, ...]


def _dotted(node: ast.AST) -> Optional[str]:
    """'a.b.c' for a Name/Attribute chain, else None."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


class _Module:
    """One parsed file: imports, function defs, parents, jit registry."""

    def __init__(self, path: str, source: str, name: str):
        self.path = path
        self.name = name
        self.source_lines = source.splitlines()
        self.tree = ast.parse(source, filename=path)
        self.parents: Dict[ast.AST, ast.AST] = {}
        for parent in ast.walk(self.tree):
            for child in ast.iter_child_nodes(parent):
                self.parents[child] = parent
        # local alias -> imported module fqname ("np" -> "numpy")
        self.mod_alias: Dict[str, str] = {}
        # local symbol -> imported fqname ("ema_update" -> "...acceptance.ema_update")
        self.sym_alias: Dict[str, str] = {}
        # qualname within module -> def node ("Server.step", "chain_round")
        self.functions: Dict[str, ast.FunctionDef] = {}
        self.jitted: Dict[str, _Jitted] = {}
        self._collect_imports()
        self._collect_functions()
        self._collect_jitted()

    # ------------------------------------------------------------ collection
    def _collect_imports(self) -> None:
        for node in ast.walk(self.tree):
            if isinstance(node, ast.Import):
                for a in node.names:
                    self.mod_alias[a.asname or a.name.split(".")[0]] = (
                        a.name if a.asname else a.name.split(".")[0]
                    )
                    if a.asname:
                        self.mod_alias[a.asname] = a.name
            elif isinstance(node, ast.ImportFrom) and node.module:
                for a in node.names:
                    fq = f"{node.module}.{a.name}"
                    local = a.asname or a.name
                    # could be a module or a symbol; record as both
                    self.mod_alias.setdefault(local, fq)
                    self.sym_alias[local] = fq

    def _collect_functions(self) -> None:
        def visit(node: ast.AST, prefix: str) -> None:
            for child in ast.iter_child_nodes(node):
                if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    q = f"{prefix}{child.name}"
                    self.functions[q] = child  # type: ignore[assignment]
                    visit(child, q + ".")
                elif isinstance(child, ast.ClassDef):
                    visit(child, f"{prefix}{child.name}.")
                else:
                    visit(child, prefix)

        visit(self.tree, "")

    def resolve_base(self, name: str) -> Optional[str]:
        """Module fqname a bare name refers to (via import), if any."""
        return self.mod_alias.get(name)

    def is_numpy(self, node: ast.AST) -> bool:
        d = _dotted(node)
        if not d:
            return False
        base = d.split(".")[0]
        return self.mod_alias.get(base, "") == "numpy" or base == "numpy"

    def is_jax_name(self, node: ast.AST, suffix: str) -> bool:
        """Does ``node`` (a call's func) denote jax.<suffix> under this
        module's imports (jax.jit, jax.lax.scan, ...)?"""
        d = _dotted(node)
        if not d:
            return False
        base = d.split(".")[0]
        fq = self.mod_alias.get(base)
        if fq:
            d = fq + d[len(base):]
        if d == f"jax.{suffix}" or d.endswith(f"jax.{suffix}"):
            return True
        # from jax import lax; lax.scan / from jax import jit; jit(...)
        sym = self.sym_alias.get(d.split(".")[0])
        if sym:
            d2 = sym + d[len(d.split(".")[0]):]
            return d2 == f"jax.{suffix}" or d2.endswith(f"jax.{suffix}")
        return False

    # ---------------------------------------------------------- jit registry
    @staticmethod
    def _donate_values(node: ast.AST) -> Tuple[int, ...]:
        """Int positions out of a donate_argnums value expression; handles
        literals, tuples, ``cond(...) if flag else ()`` and the repo's
        ``don(1, 2)`` helper-call idiom (conservatively: donation ON)."""
        if isinstance(node, ast.Constant) and isinstance(node.value, int):
            return (node.value,)
        if isinstance(node, (ast.Tuple, ast.List)):
            out: List[int] = []
            for e in node.elts:
                out.extend(_Module._donate_values(e))
            return tuple(out)
        if isinstance(node, ast.IfExp):
            return tuple(
                sorted(
                    set(_Module._donate_values(node.body))
                    | set(_Module._donate_values(node.orelse))
                )
            )
        if isinstance(node, ast.Call):
            out = []
            for e in node.args:
                out.extend(_Module._donate_values(e))
            return tuple(out)
        return ()

    def jit_donation(self, call: ast.Call) -> Optional[Tuple[Tuple[int, ...], Tuple[str, ...]]]:
        """(positions, names) if ``call`` is jax.jit(...), else None."""
        if not self.is_jax_name(call.func, "jit"):
            return None
        pos: Tuple[int, ...] = ()
        names: Tuple[str, ...] = ()
        for kw in call.keywords:
            if kw.arg == "donate_argnums":
                pos = self._donate_values(kw.value)
            elif kw.arg == "donate_argnames":
                vals = kw.value
                if isinstance(vals, ast.Constant) and isinstance(vals.value, str):
                    names = (vals.value,)
                elif isinstance(vals, (ast.Tuple, ast.List)):
                    names = tuple(
                        e.value for e in vals.elts
                        if isinstance(e, ast.Constant) and isinstance(e.value, str)
                    )
        return pos, names

    def _collect_jitted(self) -> None:
        for node in ast.walk(self.tree):
            if not isinstance(node, ast.Assign) or not isinstance(node.value, ast.Call):
                continue
            don = self.jit_donation(node.value)
            if don is None:
                continue
            pos, names = don
            for tgt in node.targets:
                d = _dotted(tgt)
                if d is None:
                    continue
                self.jitted[d] = _Jitted(d, pos, names)
                # factory idiom: `fn = jax.jit(...)` inside a method that
                # returns `fn` — register the factory so call sites like
                # `self._rescore_verify_fn(r)(args...)` resolve donation
                fn = self.enclosing_function(node)
                if fn is not None and any(
                    isinstance(r, ast.Return)
                    and isinstance(r.value, ast.Name)
                    and r.value.id == d
                    for r in ast.walk(fn)
                ):
                    for key in (f"factory:{fn.name}", f"factory:self.{fn.name}"):
                        self.jitted[key] = _Jitted(key, pos, names)

    def enclosing_function(self, node: ast.AST) -> Optional[ast.FunctionDef]:
        cur = self.parents.get(node)
        while cur is not None:
            if isinstance(cur, (ast.FunctionDef, ast.AsyncFunctionDef)):
                return cur  # type: ignore[return-value]
            cur = self.parents.get(cur)
        return None

    def enclosing_class(self, node: ast.AST) -> Optional[str]:
        cur = self.parents.get(node)
        while cur is not None:
            if isinstance(cur, ast.ClassDef):
                return cur.name
            cur = self.parents.get(cur)
        return None

    # ------------------------------------------------------------ call graph
    def call_targets(self, call: ast.Call) -> List[str]:
        """Candidate fully-qualified callees for a call node (plus any
        function-reference arguments — bodies passed into scans/partials
        count as called for reachability)."""
        out: List[str] = []
        refs = [call.func] + [
            a for a in call.args if isinstance(a, (ast.Name, ast.Attribute))
        ]
        for i, f in enumerate(refs):
            d = _dotted(f)
            if not d:
                continue
            parts = d.split(".")
            if parts[0] == "self":
                cls = self.enclosing_class(call)
                if cls:
                    out.append(f"{self.name}.{cls}.{parts[-1]}")
                continue
            if i == 0 and d in self.sym_alias:
                out.append(self.sym_alias[d])
            if parts[0] in self.mod_alias and len(parts) > 1:
                out.append(self.mod_alias[parts[0]] + "." + ".".join(parts[1:]))
            # local / same-module function
            out.append(f"{self.name}.{d}")
            out.append(d)
        return out


class Linter:
    def __init__(self, roots: Sequence[str] = DEFAULT_ROOTS):
        self.roots = tuple(roots)
        self.modules: List[_Module] = []
        self.findings: List[Finding] = []
        # fq function name -> (module, node)
        self.index: Dict[str, Tuple[_Module, ast.FunctionDef]] = {}

    # ------------------------------------------------------------- loading
    @staticmethod
    def _module_name(path: str) -> str:
        norm = path.replace(os.sep, "/")
        for anchor in ("/src/", "src/"):
            if anchor in norm:
                tail = norm.split(anchor, 1)[1]
                return tail[:-3].replace("/", ".") if tail.endswith(".py") else tail
        return os.path.splitext(os.path.basename(norm))[0]

    def add_file(self, path: str) -> None:
        with open(path, encoding="utf-8") as f:
            source = f.read()
        mod = _Module(path, source, self._module_name(path))
        self.modules.append(mod)
        for q, node in mod.functions.items():
            self.index[f"{mod.name}.{q}"] = (mod, node)

    def add_paths(self, paths: Iterable[str]) -> None:
        for p in paths:
            if os.path.isdir(p):
                for dirpath, dirnames, filenames in os.walk(p):
                    dirnames[:] = [
                        d for d in dirnames
                        if d not in ("__pycache__", "results", ".git")
                    ]
                    for fn in sorted(filenames):
                        if fn.endswith(".py"):
                            self.add_file(os.path.join(dirpath, fn))
            elif p.endswith(".py"):
                self.add_file(p)

    # --------------------------------------------------------- reachability
    def _is_root(self, fq: str) -> bool:
        leaf = fq.rsplit(".", 1)[-1]
        return any(leaf == r or leaf.startswith(r) for r in self.roots)

    def reachable_functions(self) -> Set[str]:
        work = [fq for fq in self.index if self._is_root(fq)]
        seen: Set[str] = set(work)
        while work:
            fq = work.pop()
            mod, node = self.index[fq]
            for call in ast.walk(node):
                if not isinstance(call, ast.Call):
                    continue
                for cand in mod.call_targets(call):
                    for key in (cand, f"{mod.name}.{cand}"):
                        if key in self.index and key not in seen:
                            seen.add(key)
                            work.append(key)
        return seen

    # -------------------------------------------------------------- running
    def run(self) -> List[Finding]:
        reachable = self.reachable_functions()
        # a nested function is scanned as part of its parent — drop children
        # whose parent is already in the set so findings aren't doubled
        tops = {
            fq for fq in reachable
            if fq.rsplit(".", 1)[0] not in reachable
        }
        for mod in self.modules:
            self._check_repro002(mod)
            self._check_repro003(mod)
            self._check_repro004(mod)
            self._check_repro005(mod)
        for fq in sorted(tops):
            mod, node = self.index[fq]
            self._check_repro001(mod, node, fq)
        return self._apply_waivers()

    def _emit(self, mod: _Module, node: ast.AST, rule: str, msg: str) -> None:
        self.findings.append(
            Finding(mod.path, getattr(node, "lineno", 0),
                    getattr(node, "col_offset", 0), rule, msg)
        )

    # ------------------------------------------------------------- REPRO001
    def _check_repro001(self, mod: _Module, fn: ast.FunctionDef, fq: str) -> None:
        where = f"reachable from round/scan roots via {fq.rsplit('.', 1)[-1]}"
        for node in ast.walk(fn):
            if not isinstance(node, ast.Call):
                continue
            f = node.func
            if isinstance(f, ast.Attribute) and f.attr == "item" and not node.args:
                self._emit(mod, node, "REPRO001",
                           f".item() forces a host sync ({where})")
            elif isinstance(f, ast.Attribute) and f.attr == "block_until_ready":
                self._emit(mod, node, "REPRO001",
                           f"block_until_ready stalls the dispatch pipeline ({where})")
            elif mod.is_jax_name(f, "device_get"):
                self._emit(mod, node, "REPRO001",
                           f"jax.device_get copies device->host ({where})")
            elif mod.is_numpy(f):
                d = _dotted(f) or ""
                if d.rsplit(".", 1)[-1] in _NUMPY_HOST_FNS:
                    self._emit(
                        mod, node, "REPRO001",
                        f"{d}() materializes a device value on host ({where})",
                    )
            elif (
                isinstance(f, ast.Name)
                and f.id in ("float", "int", "bool")
                and node.args
                and self._devicey_arg(mod, node.args[0])
            ):
                self._emit(
                    mod, node, "REPRO001",
                    f"{f.id}() on a device value forces a host sync ({where})",
                )

    @staticmethod
    def _devicey_arg(mod: _Module, arg: ast.AST) -> bool:
        """Heuristic: indexed values and jnp/jax call results are (likely)
        device arrays; names/attributes/arithmetic are config scalars."""
        if isinstance(arg, ast.Subscript):
            return True
        if isinstance(arg, ast.Call):
            d = _dotted(arg.func) or ""
            base = d.split(".")[0]
            fq = mod.mod_alias.get(base, base)
            return fq.startswith("jax") or base in ("jnp", "lax")
        return False

    # ------------------------------------------------------------- REPRO002
    def _check_repro002(self, mod: _Module) -> None:
        if not mod.jitted:
            return
        for fn in mod.functions.values():
            for node in ast.walk(fn):
                if not isinstance(node, ast.Call):
                    continue
                jit = self._donating_callee(mod, node)
                if jit is None:
                    continue
                donated = [
                    node.args[i] for i in jit.donate_pos if i < len(node.args)
                ] + [
                    kw.value for kw in node.keywords
                    if kw.arg in jit.donate_names
                ]
                for expr in donated:
                    d = _dotted(expr)
                    if d is None:
                        continue
                    read = self._read_after(mod, fn, node, d)
                    if read is not None:
                        self._emit(
                            mod, read, "REPRO002",
                            f"'{d}' is read after being donated to "
                            f"{_dotted(node.func) or 'a jitted call'}() — the "
                            "buffer may already be aliased by its outputs",
                        )

    @staticmethod
    def _donating_callee(mod: _Module, call: ast.Call) -> Optional[_Jitted]:
        d = _dotted(call.func)
        if d is not None:
            jit = mod.jitted.get(d)
            if jit is not None and (jit.donate_pos or jit.donate_names):
                return jit
        # factory: self._fn(level)(args...) / direct jax.jit(f, ...)(args...)
        if isinstance(call.func, ast.Call):
            inner = call.func
            don = mod.jit_donation(inner)
            if don is not None and (don[0] or don[1]):
                return _Jitted("<inline jit>", don[0], don[1])
            di = _dotted(inner.func)
            if di is not None:
                jit = mod.jitted.get(f"factory:{di}")
                if jit is not None and (jit.donate_pos or jit.donate_names):
                    return jit
        return None

    def _read_after(
        self, mod: _Module, fn: ast.FunctionDef, call: ast.Call, expr: str
    ) -> Optional[ast.AST]:
        """First Load of ``expr`` after the statement containing ``call``,
        stopping at the first re-assignment. Walks out of enclosing If/With
        blocks (skipping the sibling branch) but NOT back around loops."""
        stmt = self._enclosing_stmt(mod, call)
        if stmt is None:
            return None
        if self._stmt_stores(stmt, expr):
            return None      # result rebinds the donated name in-place
        for later in self._statements_after(mod, stmt):
            hit = self._first_load(later, expr)
            stored = self._stmt_stores(later, expr)
            if stored and hit is None:
                return None
            if hit is not None:
                return hit
        return None

    def _enclosing_stmt(self, mod: _Module, node: ast.AST) -> Optional[ast.stmt]:
        cur: Optional[ast.AST] = node
        while cur is not None and not isinstance(cur, ast.stmt):
            cur = mod.parents.get(cur)
        return cur  # type: ignore[return-value]

    def _statements_after(self, mod: _Module, stmt: ast.stmt):
        cur: ast.AST = stmt
        while True:
            parent = mod.parents.get(cur)
            if parent is None:
                return
            for field in ("body", "orelse", "finalbody"):
                block = getattr(parent, field, None)
                if isinstance(block, list) and cur in block:
                    idx = block.index(cur)
                    for later in block[idx + 1:]:
                        yield later
                    break
            if isinstance(parent, (ast.FunctionDef, ast.AsyncFunctionDef)):
                return
            cur = parent

    @staticmethod
    def _stmt_stores(stmt: ast.stmt, expr: str) -> bool:
        targets: List[ast.AST] = []
        if isinstance(stmt, ast.Assign):
            targets = list(stmt.targets)
        elif isinstance(stmt, (ast.AugAssign, ast.AnnAssign)):
            targets = [stmt.target]
        flat: List[ast.AST] = []
        for t in targets:
            flat.extend(t.elts if isinstance(t, (ast.Tuple, ast.List)) else [t])
        return any(_dotted(t) == expr for t in flat)

    @staticmethod
    def _first_load(stmt: ast.stmt, expr: str) -> Optional[ast.AST]:
        # exclude the assignment-target occurrence itself
        skip: Set[ast.AST] = set()
        if isinstance(stmt, ast.Assign):
            for t in stmt.targets:
                for n in ast.walk(t):
                    skip.add(n)
        for node in ast.walk(stmt):
            if node in skip:
                continue
            if isinstance(node, (ast.Name, ast.Attribute)) and _dotted(node) == expr:
                if isinstance(getattr(node, "ctx", None), ast.Load):
                    return node
        return None

    # ------------------------------------------------------------- REPRO003
    def _check_repro003(self, mod: _Module) -> None:
        for node in ast.walk(mod.tree):
            if not (isinstance(node, ast.Call) and mod.is_jax_name(node.func, "jit")):
                continue
            # (a) jit constructed inside a for/while loop
            cur = mod.parents.get(node)
            immediately_called = isinstance(cur, ast.Call) and cur.func is node
            while cur is not None:
                if isinstance(cur, (ast.For, ast.While)):
                    self._emit(
                        mod, node, "REPRO003",
                        "jax.jit constructed inside a loop — a fresh "
                        "executable (and retrace) per iteration; hoist or "
                        "memoize it",
                    )
                    break
                if isinstance(cur, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    break
                cur = mod.parents.get(cur)
            # (b) construct-and-call inside a function body
            if immediately_called and mod.enclosing_function(node) is not None:
                self._emit(
                    mod, node, "REPRO003",
                    "jax.jit(...)(...) constructed and called in one "
                    "expression — recompiles on every invocation; bind the "
                    "jitted callable once",
                )

    # ------------------------------------------------------------- REPRO004
    def _body_functions(self, mod: _Module, call: ast.Call) -> List[ast.AST]:
        d = _dotted(call.func) or ""
        base = d.split(".")[0]
        fq = mod.mod_alias.get(base, base) + d[len(base):]
        leaf = d.rsplit(".", 1)[-1]
        if leaf not in _LAX_BODY_FNS or "lax" not in fq:
            return []
        out: List[ast.AST] = []
        for i in _LAX_BODY_FNS[leaf]:
            if i >= len(call.args):
                continue
            arg = call.args[i]
            if isinstance(arg, ast.Lambda):
                out.append(arg)
            elif isinstance(arg, ast.Name):
                fn = self._resolve_local_function(mod, call, arg.id)
                if fn is not None:
                    out.append(fn)
        return out

    def _resolve_local_function(
        self, mod: _Module, at: ast.AST, name: str
    ) -> Optional[ast.FunctionDef]:
        """Find ``def name`` in the scopes enclosing ``at`` (innermost
        first), falling back to module level."""
        encl = mod.enclosing_function(at)
        chain: List[str] = []
        cur: Optional[ast.AST] = encl
        while cur is not None:
            if isinstance(cur, (ast.FunctionDef, ast.AsyncFunctionDef,
                                ast.ClassDef)):
                chain.append(cur.name)
            cur = mod.parents.get(cur)
        chain.reverse()
        for depth in range(len(chain), -1, -1):
            q = ".".join(chain[:depth] + [name])
            if q in mod.functions:
                return mod.functions[q]
        return None

    def _check_repro004(self, mod: _Module) -> None:
        for call in ast.walk(mod.tree):
            if not isinstance(call, ast.Call):
                continue
            for body in self._body_functions(mod, call):
                self._check_body_purity(mod, body)

    def _check_body_purity(self, mod: _Module, body: ast.AST) -> None:
        for node in ast.walk(body):
            if isinstance(node, (ast.Global, ast.Nonlocal)):
                self._emit(mod, node, "REPRO004",
                           "global/nonlocal mutation inside a traced body")
            elif isinstance(node, (ast.Assign, ast.AugAssign)):
                tgts = node.targets if isinstance(node, ast.Assign) else [node.target]
                for t in tgts:
                    d = _dotted(t) or _dotted(getattr(t, "value", None) or ast.Pass())
                    if d and d.split(".")[0] == "self":
                        self._emit(
                            mod, node, "REPRO004",
                            "mutating self state inside a traced body — the "
                            "write happens once at trace time, not per step",
                        )
            elif isinstance(node, ast.Call):
                f = node.func
                d = _dotted(f) or ""
                if isinstance(f, ast.Name) and f.id in ("print", "open", "input"):
                    self._emit(mod, node, "REPRO004",
                               f"{f.id}() is a host side effect inside a traced body")
                elif d.split(".")[0] == "time" and mod.mod_alias.get("time", "time") == "time":
                    self._emit(mod, node, "REPRO004",
                               "time.* inside a traced body runs at trace time only")
                elif mod.is_numpy(f) and d.rsplit(".", 1)[-1] in _NUMPY_HOST_FNS:
                    self._emit(mod, node, "REPRO004",
                               f"{d}() on a tracer fails or silently constant-folds")
                elif isinstance(f, ast.Attribute) and f.attr == "item" and not node.args:
                    self._emit(mod, node, "REPRO004",
                               ".item() inside a traced body")

    # ------------------------------------------------------------- REPRO005
    def _check_repro005(self, mod: _Module) -> None:
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.Call):
                d = _dotted(node.func) or ""
                if d == "time.time" and mod.mod_alias.get("time", "") == "time":
                    self._emit(
                        mod, node, "REPRO005",
                        "time.time() is not monotonic — use time.perf_counter()",
                    )
        for fn in mod.functions.values():
            self._check_unsynced_timing(mod, fn)

    def _check_unsynced_timing(self, mod: _Module, fn: ast.FunctionDef) -> None:
        starts: Dict[str, int] = {}
        for node in ast.walk(fn):
            if (
                isinstance(node, ast.Assign)
                and isinstance(node.value, ast.Call)
                and (_dotted(node.value.func) or "") == "time.perf_counter"
                and len(node.targets) == 1
                and isinstance(node.targets[0], ast.Name)
            ):
                starts.setdefault(node.targets[0].id, node.lineno)
        if not starts:
            return
        deltas: List[Tuple[str, int, ast.AST]] = []
        for node in ast.walk(fn):
            if (
                isinstance(node, ast.BinOp)
                and isinstance(node.op, ast.Sub)
                and isinstance(node.left, ast.Call)
                and (_dotted(node.left.func) or "") == "time.perf_counter"
                and isinstance(node.right, ast.Name)
                and node.right.id in starts
            ):
                deltas.append((node.right.id, node.lineno, node))
        for var, end_line, dnode in deltas:
            start_line = starts[var]
            if end_line <= start_line:
                continue
            jit_call = None
            synced = False
            for node in ast.walk(fn):
                if not isinstance(node, ast.Call):
                    continue
                line = getattr(node, "lineno", 0)
                if not (start_line <= line <= end_line):
                    continue
                f = node.func
                if (isinstance(f, ast.Attribute) and f.attr == "block_until_ready") or \
                        mod.is_jax_name(f, "block_until_ready"):
                    synced = True
                # materializing on host blocks on the device value too
                if isinstance(f, ast.Attribute) and f.attr == "item" and not node.args:
                    synced = True
                if mod.is_numpy(f) and (
                    (_dotted(f) or "").rsplit(".", 1)[-1] in ("asarray", "array")
                ):
                    synced = True
                d = _dotted(f)
                if d is not None and (
                    d in mod.jitted or f"factory:{d}" in mod.jitted
                ):
                    jit_call = d
                if isinstance(f, ast.Call):
                    di = _dotted(f.func)
                    if di is not None and f"factory:{di}" in mod.jitted:
                        jit_call = di
            if jit_call is not None and not synced:
                self._emit(
                    mod, dnode, "REPRO005",
                    f"perf_counter delta times jitted '{jit_call}' without a "
                    "block_until_ready — async dispatch returns before the "
                    "device work finishes",
                )

    # -------------------------------------------------------------- waivers
    def _apply_waivers(self) -> List[Finding]:
        out: List[Finding] = []
        waived: Dict[Tuple[str, int], Tuple[str, str, bool]] = {}
        for mod in self.modules:
            for i, line in enumerate(mod.source_lines, start=1):
                m = _WAIVER_RE.search(line)
                if m:
                    rule, why = m.group(1), m.group(2).strip()
                    waived[(mod.path, i)] = (rule, why, bool(why))
                    if not why:
                        out.append(Finding(
                            mod.path, i, 0, "REPRO000",
                            f"waiver for {rule} has no justification — "
                            "explain why the finding is safe here",
                        ))
        for f in self.findings:
            w = waived.get((f.path, f.line))
            if w is not None and w[0] == f.rule and w[2]:
                continue
            out.append(f)
        return sorted(out, key=lambda f: (f.path, f.line, f.rule))


def run_paths(paths: Sequence[str], roots: Optional[Sequence[str]] = None) -> List[Finding]:
    linter = Linter(roots=tuple(roots) if roots else DEFAULT_ROOTS)
    linter.add_paths(paths)
    return linter.run()


def main(argv: Optional[Sequence[str]] = None) -> int:
    import argparse

    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.lint",
        description="Dispatch-discipline lint (REPRO001-005) over JAX code.",
    )
    ap.add_argument("paths", nargs="*", default=["src/repro"],
                    help="files or directories to lint (default: src/repro)")
    ap.add_argument("--roots", default=None,
                    help="comma-separated extra root function names for the "
                         "REPRO001 call-graph walk")
    ap.add_argument("--list-rules", action="store_true")
    args = ap.parse_args(argv)
    if args.list_rules:
        for rule, desc in sorted(RULES.items()):
            print(f"{rule}  {desc}")
        return 0
    roots = list(DEFAULT_ROOTS)
    if args.roots:
        roots.extend(r.strip() for r in args.roots.split(",") if r.strip())
    findings = run_paths(args.paths, roots=roots)
    for f in findings:
        print(f.render())
    n = len(findings)
    print(
        f"repro-lint: {n} finding{'s' if n != 1 else ''} in "
        f"{', '.join(args.paths)}",
        file=sys.stderr,
    )
    return 1 if findings else 0


if __name__ == "__main__":
    sys.exit(main())
