"""Checkpointing: pytree <-> flat npz with structure manifest (offline-safe)."""
from __future__ import annotations

import json
import os
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


def _flatten_with_paths(tree: Any):
    flat = jax.tree_util.tree_flatten_with_path(tree)
    leaves, treedef = flat
    out = {}
    for path, leaf in leaves:
        key = "/".join(str(p) for p in path)
        out[key] = np.asarray(leaf)
    return out, treedef


def save_checkpoint(path: str, params: Any, opt_state: Any = None, step: int = 0) -> None:
    os.makedirs(path, exist_ok=True)
    arrays, _ = _flatten_with_paths(params)
    np.savez(os.path.join(path, "params.npz"), **arrays)
    if opt_state is not None:
        oarr, _ = _flatten_with_paths(opt_state)
        np.savez(os.path.join(path, "opt.npz"), **oarr)
    with open(os.path.join(path, "meta.json"), "w") as f:
        json.dump({"step": int(step)}, f)


def load_checkpoint(path: str, params_template: Any, opt_template: Any = None):
    """Restore into the shapes/treedef of the provided templates."""
    data = np.load(os.path.join(path, "params.npz"))
    arrays, treedef = _flatten_with_paths(params_template)
    restored = {}
    for k in arrays:
        if k not in data:
            raise KeyError(f"checkpoint missing leaf {k}")
        restored[k] = data[k]
    leaves = [jnp.asarray(restored[k]) for k in arrays]
    params = jax.tree.unflatten(treedef, leaves)
    out = [params]
    if opt_template is not None:
        odata = np.load(os.path.join(path, "opt.npz"))
        oarrays, otreedef = _flatten_with_paths(opt_template)
        oleaves = [jnp.asarray(odata[k]) for k in oarrays]
        out.append(jax.tree.unflatten(otreedef, oleaves))
    with open(os.path.join(path, "meta.json")) as f:
        out.append(json.load(f)["step"])
    return tuple(out)
