"""AdamW with decoupled weight decay + cosine schedule — pure JAX pytrees.

Optimizer state shards like the params (the dry-run applies the same
PartitionSpec tree to ``mu``/``nu``), giving ZeRO-style sharded moments for
free along the `model` axis.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    mu: Any
    nu: Any


def adamw_init(params: Any) -> AdamWState:
    zeros = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        mu=jax.tree.map(zeros, params),
        nu=jax.tree.map(zeros, params),
    )


def cosine_lr(
    step: jax.Array,
    *,
    peak: float = 3e-4,
    warmup: int = 100,
    total: int = 10_000,
    floor: float = 0.1,
) -> jax.Array:
    s = step.astype(jnp.float32)
    warm = peak * s / max(warmup, 1)
    frac = jnp.clip((s - warmup) / max(total - warmup, 1), 0.0, 1.0)
    cos = peak * (floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * frac)))
    return jnp.where(s < warmup, warm, cos)


def adamw_update(
    params: Any,
    grads: Any,
    state: AdamWState,
    *,
    lr: jax.Array,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
    grad_clip: float = 1.0,
) -> Tuple[Any, AdamWState]:
    # global-norm clip
    leaves = jax.tree.leaves(grads)
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in leaves))
    scale = jnp.minimum(1.0, grad_clip / jnp.maximum(gnorm, 1e-9))

    step = state.step + 1
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        update = (m / bc1) / (jnp.sqrt(v / bc2) + eps)
        decay = weight_decay * p.astype(jnp.float32) if p.ndim >= 2 else 0.0
        new_p = p.astype(jnp.float32) - lr * (update + decay)
        return new_p.astype(p.dtype), m, v

    p_flat, treedef = jax.tree.flatten(params)
    g_flat = treedef.flatten_up_to(grads)
    m_flat = treedef.flatten_up_to(state.mu)
    v_flat = treedef.flatten_up_to(state.nu)
    res = [upd(p, g, m, v) for p, g, m, v in zip(p_flat, g_flat, m_flat, v_flat)]
    new_params = treedef.unflatten([r[0] for r in res])
    new_mu = treedef.unflatten([r[1] for r in res])
    new_nu = treedef.unflatten([r[2] for r in res])
    return new_params, AdamWState(step=step, mu=new_mu, nu=new_nu)
