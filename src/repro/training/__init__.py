"""Training substrate: optimizer, train step, checkpointing."""
from repro.training.optimizer import AdamWState, adamw_init, adamw_update, cosine_lr
from repro.training.train_step import loss_fn, make_train_step
from repro.training.checkpoint import load_checkpoint, save_checkpoint

__all__ = [
    "AdamWState",
    "adamw_init",
    "adamw_update",
    "cosine_lr",
    "loss_fn",
    "make_train_step",
    "load_checkpoint",
    "save_checkpoint",
]
