"""Train step: causal-LM cross entropy + MoE aux losses + AdamW update."""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.config.base import ModelConfig
from repro.models import model as M
from repro.training.optimizer import AdamWState, adamw_update, cosine_lr


def loss_fn(
    cfg: ModelConfig,
    params: Any,
    batch: Dict[str, jax.Array],
    *,
    remat: bool = True,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Next-token CE (shift-by-one inside) + MoE aux. ``batch['tokens']`` is
    (B, S) or (B, S, nc); optional ``loss_mask`` (B, S-1)."""
    logits, aux = M.forward_train(cfg, params, batch, remat=remat)
    tokens = batch["tokens"]
    if cfg.num_codebooks:
        targets = tokens[:, 1:]                      # (B, S-1, nc)
        lg = logits[:, :-1]                          # (B, S-1, nc, V)
        logp = jax.nn.log_softmax(lg, axis=-1)
        nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
        nll = nll.mean(axis=-1)                      # mean over codebooks
    else:
        targets = tokens[:, 1:]
        lg = logits[:, :-1]
        logp = jax.nn.log_softmax(lg, axis=-1)
        nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    mask = batch.get("loss_mask")
    if mask is None:
        ce = nll.mean()
    else:
        m = mask.astype(jnp.float32)
        ce = jnp.sum(nll * m) / jnp.maximum(jnp.sum(m), 1.0)
    total = ce + aux
    return total, {"ce": ce, "moe_aux": aux, "loss": total}


def make_train_step(
    cfg: ModelConfig,
    *,
    peak_lr: float = 3e-4,
    warmup: int = 100,
    total_steps: int = 10_000,
    remat: bool = True,
):
    """Returns jit-able train_step(params, opt_state, batch) -> (params, opt, metrics)."""

    def train_step(params, opt_state: AdamWState, batch):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: loss_fn(cfg, p, batch, remat=remat), has_aux=True
        )(params)
        lr = cosine_lr(opt_state.step, peak=peak_lr, warmup=warmup, total=total_steps)
        new_params, new_opt = adamw_update(params, grads, opt_state, lr=lr)
        metrics = dict(metrics)
        metrics["lr"] = lr
        metrics["grad_norm"] = jnp.sqrt(
            sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(grads))
        )
        return new_params, new_opt, metrics

    return train_step
