"""Synthetic data: Spec-Bench-style task suite + LM training stream.

Spec-Bench spans MT-Bench/translation/summarization/QA/math/RAG. We cannot
ship those datasets offline, so each task is modeled as a synthetic token
process with the *property that matters to speculative decoding*: its
n-gram re-use rate (how often the continuation copies from the prompt) and
its local predictability (how well a shallow model guesses the next token).
Summarization/RAG are copy-heavy (PLD shines, cf. Table 1); translation is
low-reuse (PLD weak); math is mid-reuse with long runs.
"""
from __future__ import annotations

import dataclasses
import zlib
from typing import Dict, Iterator, List, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class TaskSpec:
    name: str
    copy_rate: float        # P(continuation copies a prompt span)
    span_len: Tuple[int, int]   # copied-span length range
    vocab_hot: int          # size of the "hot" local vocabulary
    prompt_len: int = 96


SPEC_TASKS: Dict[str, TaskSpec] = {
    "mtbench": TaskSpec("mtbench", copy_rate=0.30, span_len=(2, 6), vocab_hot=64),
    "translation": TaskSpec("translation", copy_rate=0.05, span_len=(1, 3), vocab_hot=96),
    "summarization": TaskSpec("summarization", copy_rate=0.65, span_len=(4, 10), vocab_hot=48),
    "qa": TaskSpec("qa", copy_rate=0.20, span_len=(2, 5), vocab_hot=80),
    "math": TaskSpec("math", copy_rate=0.35, span_len=(2, 7), vocab_hot=32),
    "rag": TaskSpec("rag", copy_rate=0.60, span_len=(4, 9), vocab_hot=56),
}


def make_task_prompts(
    task: TaskSpec, n: int, vocab_size: int, seed: int = 0
) -> List[np.ndarray]:
    """Prompts whose statistics induce the task's n-gram reuse profile."""
    # stable per-task seed: Python's str hash is randomized per process
    # (PYTHONHASHSEED), which silently made "deterministic" benchmark
    # streams differ between runs — crc32 is process-invariant
    rng = np.random.default_rng(seed + zlib.crc32(task.name.encode()) % 10_000)
    prompts = []
    for _ in range(n):
        hot = rng.integers(2, vocab_size, size=task.vocab_hot)
        toks = []
        while len(toks) < task.prompt_len:
            if toks and rng.random() < task.copy_rate:
                # repeat an earlier span (the raw material for PLD)
                L = int(rng.integers(*task.span_len))
                start = int(rng.integers(0, max(len(toks) - L, 1)))
                toks.extend(toks[start : start + L])
            else:
                toks.append(int(hot[rng.integers(task.vocab_hot)]))
        prompts.append(np.asarray(toks[: task.prompt_len], np.int32))
    return prompts


def synthetic_corpus(
    vocab_size: int, n_tokens: int, seed: int = 0, order: int = 2
) -> np.ndarray:
    """A learnable Markov token stream for the training example: a fixed
    random order-`order` transition structure with copy bursts."""
    rng = np.random.default_rng(seed)
    n_states = 256
    table = rng.integers(2, vocab_size, size=(n_states, 8))
    out = np.zeros(n_tokens, np.int32)
    state = 0
    for i in range(n_tokens):
        nxt = table[state, rng.integers(0, 8 if rng.random() < 0.2 else 2)]
        out[i] = nxt
        state = int((state * 31 + nxt) % n_states)
    return out


def lm_batches(
    corpus: np.ndarray, batch: int, seq_len: int, seed: int = 0
) -> Iterator[Dict[str, np.ndarray]]:
    """Infinite iterator of {tokens (B, S)} windows."""
    rng = np.random.default_rng(seed)
    n = len(corpus) - seq_len - 1
    while True:
        starts = rng.integers(0, n, size=batch)
        toks = np.stack([corpus[s : s + seq_len] for s in starts])
        yield {"tokens": toks.astype(np.int32)}
