"""Byte-level tokenizer (no external vocab files — offline-safe)."""
from __future__ import annotations

from typing import Iterable

import numpy as np


class ByteTokenizer:
    """UTF-8 bytes + <pad>=256, <bos>=257, <eos>=258. vocab_size=259 padded
    up to a multiple of 64 for MXU-friendly heads."""

    PAD, BOS, EOS = 256, 257, 258

    def __init__(self, pad_to_multiple: int = 64):
        v = 259
        self.vocab_size = ((v + pad_to_multiple - 1) // pad_to_multiple) * pad_to_multiple

    def encode(self, text: str, bos: bool = True, eos: bool = False) -> np.ndarray:
        ids = list(text.encode("utf-8"))
        if bos:
            ids = [self.BOS] + ids
        if eos:
            ids = ids + [self.EOS]
        return np.asarray(ids, np.int32)

    def decode(self, ids: Iterable[int]) -> str:
        bs = bytes(i for i in ids if 0 <= i < 256)
        return bs.decode("utf-8", errors="replace")
