"""Data substrate: byte tokenizer + synthetic Spec-Bench-style task suite."""
from repro.data.tokenizer import ByteTokenizer
from repro.data.pipeline import (
    SPEC_TASKS,
    TaskSpec,
    lm_batches,
    make_task_prompts,
    synthetic_corpus,
)

__all__ = [
    "ByteTokenizer",
    "SPEC_TASKS",
    "TaskSpec",
    "lm_batches",
    "make_task_prompts",
    "synthetic_corpus",
]
