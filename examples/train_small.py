"""Train a ~100M-class model for a few hundred steps, then accelerate its
decoding with CAS-Spec.

  PYTHONPATH=src python examples/train_small.py [--steps 300] [--small]

The full pipeline: synthetic corpus -> AdamW + cosine + remat train loop ->
checkpoint -> CAS-Spec inference on the trained weights, demonstrating that
acceptance rates (and therefore speedups) IMPROVE on a trained model —
drafts and target agree more after training (the paper's premise that
layer-skip drafts track the full model).
"""
import argparse
import dataclasses
import sys
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import get_config
from repro.core import DyTCScheduler, SpecEngine, build_hierarchy
from repro.core.cascade import ARScheduler
from repro.data import lm_batches, synthetic_corpus
from repro.models import init_params
from repro.training import adamw_init, make_train_step, save_checkpoint

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=300)
ap.add_argument("--small", action="store_true", help="CPU-quick variant")
ap.add_argument("--out", default="results/train_small_ckpt")
args = ap.parse_args()

if args.small:
    cfg = dataclasses.replace(get_config("vicuna-7b").reduced(), num_layers=6)
    batch, seq = 8, 96
else:
    # ~100M params: 12L x 512d, byte-level vocab
    cfg = dataclasses.replace(
        get_config("vicuna-7b"),
        num_layers=12, d_model=512, num_heads=8, num_kv_heads=8,
        d_ff=2048, vocab_size=4096, dtype="float32",
    )
    batch, seq = 16, 256

params = init_params(cfg, jax.random.PRNGKey(0))
n_params = sum(x.size for x in jax.tree.leaves(params))
print(f"model: {cfg.num_layers}L d={cfg.d_model} params={n_params/1e6:.1f}M")

opt = adamw_init(params)
step_fn = jax.jit(make_train_step(cfg, peak_lr=6e-4, warmup=20,
                                  total_steps=args.steps, remat=False))
corpus = synthetic_corpus(cfg.vocab_size, 200_000)
it = lm_batches(corpus, batch, seq)

t0 = time.perf_counter()
for i in range(args.steps):
    b = {k: jnp.asarray(v) for k, v in next(it).items()}
    params, opt, m = step_fn(params, opt, b)
    if i % max(args.steps // 10, 1) == 0:
        print(f"step {i:4d}  ce={float(m['ce']):.3f}  lr={float(m['lr']):.2e}  "
              f"gnorm={float(m['grad_norm']):.2f}")
print(f"trained {args.steps} steps in {time.perf_counter()-t0:.0f}s; "
      f"final ce={float(m['ce']):.3f}")
save_checkpoint(args.out, params, opt, step=args.steps)
print(f"checkpoint -> {args.out}")

# --- CAS-Spec on the trained model
prompt = np.asarray(corpus[:64], np.int32)
N = 48
ar = SpecEngine(cfg, params, max_len=512)
ar.start(prompt)
t0 = time.perf_counter()
ref = ARScheduler(ar).generate(N)
t_ar = time.perf_counter() - t0

eng = SpecEngine(cfg, params, max_len=512)
eng.start(prompt)
sched = DyTCScheduler(eng, build_hierarchy(cfg))
t0 = time.perf_counter()
out = sched.generate(N)
t_spec = time.perf_counter() - t0

print(f"lossless: {out == ref}")
print(f"AR {t_ar:.2f}s vs CAS-Spec {t_spec:.2f}s -> speedup {t_ar/t_spec:.2f}x")
print(f"target calls: {ar.stats['target_calls']} -> {eng.stats['target_calls']}")
assert out == ref
