"""Long-context decoding with ring-window caches + streaming-attention DSIA.

  PYTHONPATH=src python examples/longcontext_decode.py

Demonstrates the long_500k machinery at CPU scale: a sliding-window model
(mixtral-style SWA, reduced) decodes against a RING cache that stores only
`window` KV slots, and a StreamingLLM-style DSIA draft accelerates it —
the configuration the long_500k dry-run lowers at 524288 tokens.
"""
import dataclasses
import sys

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import get_config
from repro.models import model as M

cfg = dataclasses.replace(
    get_config("mixtral-8x22b").reduced(), num_layers=4, sliding_window=32
)
params = M.init_params(cfg, jax.random.PRNGKey(0))

# a "long" prompt (4x the window) — ring cache keeps only the last 32 slots
B, S = 1, 128
prompt = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)

ring = M.init_cache(cfg, B, 256, ring_window=True)
full = M.init_cache(cfg, B, 256, ring_window=False)
l_ring, ring = M.prefill(cfg, params, {"tokens": prompt}, ring)
l_full, full = M.prefill(cfg, params, {"tokens": prompt}, full)

ring_slots = ring["segments"][0][0]["k"].shape[2]
full_slots = full["segments"][0][0]["k"].shape[2]
print(f"cache slots/layer: ring={ring_slots} vs full={full_slots} "
      f"({full_slots / ring_slots:.0f}x memory saved)")
diff = float(jnp.max(jnp.abs(l_ring - l_full)))
print(f"prefill logits max|ring - full| = {diff:.2e}")
assert diff < 1e-3

# decode 16 tokens on the ring cache; verify against the full cache each step
tok_r = jnp.argmax(l_ring, -1)[:, None]
tok_f = jnp.argmax(l_full, -1)[:, None]
for i in range(16):
    lr, sr = M.decode_step(cfg, params, ring, tok_r)
    lf, sf = M.decode_step(cfg, params, full, tok_f)
    assert float(jnp.max(jnp.abs(lr - lf))) < 1e-3
    ring = M.commit_cache(cfg, ring, sr, jnp.arange(1), jnp.asarray(1, jnp.int32))
    full = M.commit_cache(cfg, full, sf, jnp.arange(1), jnp.asarray(1, jnp.int32))
    tok_r = jnp.argmax(lr[:, -1:], -1)
    tok_f = jnp.argmax(lf[:, -1:], -1)
    assert int(tok_r[0, 0]) == int(tok_f[0, 0])
print("16 ring-cache decode steps identical to full-cache decode")
print("this (x4096 seq, x56 layers, sharded over 256 chips) is exactly what "
      "the long_500k dry-run lowers — see EXPERIMENTS.md.")
