"""End-to-end driver: batched multi-level cascade serving.

  PYTHONPATH=src python examples/serve_cascade.py

Serves a small model over a stream of Spec-Bench-style requests (mixed
tasks) with the paper's namesake ``cascade_fused`` mode: a DSIA hierarchy
(layer-sparsity level + int8 activation-quant level + PLD) materialized by
the draft bank, the cheapest level drafting every slot's tree in one scan,
the stronger level rescoring in one intermediate-verify dispatch, one
joint target verify per round, per-slot Eq. 5 routing across levels.
Reports throughput (tokens/step) and verifies every completed request
against its own single-stream AR reference.
"""
import dataclasses
import sys
import time

sys.path.insert(0, "src")

import jax
import numpy as np

from repro.config import get_config
from repro.core.cascade import ARScheduler
from repro.core.engine import SpecEngine
from repro.data import SPEC_TASKS, make_task_prompts
from repro.models import init_params
from repro.serving import BatchedSpecServer, Request, RequestScheduler, ServeLoop

cfg = dataclasses.replace(get_config("vicuna-7b").reduced(), num_layers=6)
params = init_params(cfg, jax.random.PRNGKey(0))

# a request stream across tasks
requests = []
for task in ("summarization", "qa", "rag", "translation"):
    for p in make_task_prompts(SPEC_TASKS[task], 2, cfg.vocab_size, seed=3):
        requests.append(Request(prompt=p[:48], max_new_tokens=32))

MAX_BATCH = 4
srv = BatchedSpecServer(cfg, params, max_batch=MAX_BATCH, max_len=512,
                        draft_k=4, mode="cascade_fused")
print("cascade levels:", " > ".join(l.name for l in srv.bank.levels), "> PLD",
      f"(int8 sim copies: {srv.bank.param_bytes/1e6:.1f} MB)")
sched = RequestScheduler(max_batch=MAX_BATCH)
for r in requests:
    sched.submit(r)

t0 = time.perf_counter()
finished = ServeLoop(srv, sched).run()
elapsed = time.perf_counter() - t0
steps = srv.stats["steps"]

print(f"served {len(requests)} requests in {steps} steps, {elapsed:.1f}s")
print(f"throughput: {srv.stats['tokens'] / steps:.2f} accepted tokens/step "
      f"(batch={MAX_BATCH})")
print(f"dispatches/round: "
      f"{srv.stats['draft_dispatches'] / max(steps, 1):.2f} draft + "
      f"{srv.stats['rescore_dispatches'] / max(steps, 1):.2f} rescore "
      f"(bounded: one per cascade level — the target verify rides the "
      f"last rescore dispatch)")

# verify losslessness of every completed request
bad = 0
for req in finished:
    eng = SpecEngine(cfg, params, max_len=512)
    eng.start(req.prompt)
    ref = ARScheduler(eng).generate(len(req.generated))
    bad += ref != req.generated
print(f"lossless requests: {len(finished) - bad}/{len(finished)}")
assert bad == 0
