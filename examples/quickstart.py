"""Quickstart: CAS-Spec lossless acceleration in ~40 lines.

  PYTHONPATH=src python examples/quickstart.py

Builds a small Llama-class model, runs autoregressive decoding and CAS-Spec
(DyTC over a Scaling-DSIA hierarchy + PLD), and shows that the outputs are
token-identical while CAS-Spec needs far fewer target-model forward passes.
"""
import dataclasses
import sys

sys.path.insert(0, "src")

import jax
import numpy as np

from repro.config import get_config
from repro.core import DyTCScheduler, SpecEngine, build_hierarchy
from repro.core.cascade import ARScheduler
from repro.models import init_params

# 1. a small target model (the paper's Vicuna family, scaled for CPU)
cfg = dataclasses.replace(get_config("vicuna-7b").reduced(), num_layers=8)
params = init_params(cfg, jax.random.PRNGKey(0))

prompt = np.array([5, 6, 7, 8, 9, 5, 6, 7, 8, 9, 5, 6], np.int32)
N = 48

# 2. autoregressive reference
ar = SpecEngine(cfg, params, max_len=256)
ar.start(prompt)
reference = ARScheduler(ar).generate(N)

# 3. CAS-Spec: hierarchy of layer-sparse virtual drafts + PLD, DyTC-scheduled
engine = SpecEngine(cfg, params, max_len=256)
engine.start(prompt)
scheduler = DyTCScheduler(engine, build_hierarchy(cfg, mode="scaling"))
output = scheduler.generate(N)

print("lossless:", output == reference)
print(f"AR target calls:       {ar.stats['target_calls']}")
print(f"CAS-Spec target calls: {engine.stats['target_calls']}")
print(f"mean accepted/round:   "
      f"{engine.stats['accepted_tokens'] / engine.stats['rounds']:.2f}")
print("acceptance estimates:", {k: round(v, 3) for k, v in engine.acceptance.snapshot().items()})
assert output == reference
