#!/usr/bin/env bash
# Tier-1 test runner: install dev deps (best-effort) and run the suite.
# Usage: scripts/run_tests.sh [extra pytest args]
set -euo pipefail
cd "$(dirname "$0")/.."

# Best-effort: offline containers skip the install and run the suite anyway
# (hypothesis-based modules are then skipped with a reason, not errored).
python -m pip install -q -r requirements-dev.txt 2>/dev/null \
  || echo "warning: could not install dev deps; property-based modules will be skipped"

PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" python -m pytest -x -q "$@"
