#!/usr/bin/env bash
# Tier-1 test runner — THE entrypoint CI runs (.github/workflows/ci.yml calls
# this script, so local and CI runs cannot drift: same env, same flags).
# Usage: scripts/run_tests.sh [extra pytest args]
set -euo pipefail
cd "$(dirname "$0")/.."

# CPU JAX everywhere: CI runners have no accelerator, and local runs must
# reproduce CI. Override by exporting JAX_PLATFORMS before invoking.
export JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}"

# Round pipelining for the server suite: REPRO_SYNC_EVERY>1 makes
# single-mode servers drain accepted tokens only every N rounds (async
# steady state). Default empty = sync_every 1 (synchronous step returns).
# CI's pipelined leg exports REPRO_SYNC_EVERY=3 and re-runs the server
# test modules through this same entrypoint.
export REPRO_SYNC_EVERY="${REPRO_SYNC_EVERY:-}"

# Best-effort: offline containers skip the install and run the suite anyway
# (hypothesis-based modules are then skipped with a reason, not errored).
python -m pip install -q -r requirements-dev.txt 2>/dev/null \
  || echo "warning: could not install dev deps; property-based modules will be skipped"

# Dispatch-discipline lint (REPRO001-005, stdlib-only — see docs/analysis.md)
# runs before the suite so a host-sync/use-after-donate regression fails
# fast with a file:line instead of a counter mismatch deep in a server test.
# REPRO_SKIP_LINT=1 skips it (e.g. when iterating on a single test module).
if [ -z "${REPRO_SKIP_LINT:-}" ]; then
  PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" python -m repro.analysis.lint src/repro
fi

# "sampled" first arg expands to the sampled-serving modules (the CI
# sampled-serving leg runs this on both jax versions): host/device sampler
# parity, kernel-vs-oracle replay, sampled e2e serving + greedy identity,
# and the compiled dispatch contracts (which pin the sampled rounds too).
if [ "${1:-}" = "sampled" ]; then
  shift
  set -- tests/test_sampler.py tests/test_verify_sampling.py \
         tests/test_sampled_serving.py tests/test_dispatch_contracts.py "$@"
fi

# "paged" first arg expands to the paged-serving modules (the CI
# paged-serving leg runs this on both jax versions): kernel-level page
# gather + invalid-position masking contracts, paged-vs-dense token
# identity in every mode (greedy + sampled, single-device + mesh),
# chunked-prefill prefix parity / non-blocking admission, and the
# dispatch contracts on the paged executables.
if [ "${1:-}" = "paged" ]; then
  shift
  set -- tests/test_kernels.py tests/test_paged_serving.py "$@"
fi

PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" python -m pytest -x -q "$@"
